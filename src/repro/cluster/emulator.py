"""PBS-like cluster emulator — the *physical* half of the twin loop.

Plays the role of the production scheduler + 32-node CloudLab cluster
of §4.1.  It owns the ground truth (true runtimes, real node counts),
emits the PBS hook events the paper streams through Redis
(``queuejob`` / ``runjob`` / ``jobobit``), and accepts ``qrun``
feedback (§3.5).

Two scheduler modes:
  * static  — the emulator itself schedules with one fixed policy
              (+ EASY backfill) through a k=1 ``DrainEngine`` pass —
              the *same* engine backend as the twin's simulator, so
              baseline semantics are bit-identical to the what-if
              model under any backend.  ``run(..., fast=True)`` lifts
              the whole event loop onto the device via the engine's
              batched replay (DESIGN.md §6) — same results bit-for-bit
              (this host loop is kept as the oracle the replay engine
              is parity-tested against), one device computation
              instead of one engine pass per event;
  * twin    — scheduling authority is delegated: the emulator only
              starts jobs the twin selects via ``qrun``.

Crucially, scheduling (both modes) reasons over *predicted* job ends
(start + user estimate) while actual completions occur at the true
runtime — the §3.2 pull-back/push-forward asymmetry.

Job fields are quantized to f32 at ingestion (the device dtype): all
event times are then sums of in-range f32 values, which f64 host
arithmetic reproduces exactly, so host and device event loops stay
bit-identical.  Failure times are NOT quantized — failures exist only
on the host path.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.des import SLOWDOWN_TAU
from repro.core.engine import DrainEngine
from repro.core.events import Event, EventBus, EventKind
from repro.core.state import (DONE, INVALID, QUEUED, RUNNING, JobTable,
                              SimState)
from repro.cluster.workload import JobSpec


@dataclasses.dataclass(frozen=True)
class FailureSpec:
    """Take ``nodes`` down at ``time`` for ``duration`` seconds."""
    time: float
    nodes: int
    duration: float


@dataclasses.dataclass
class RunReport:
    start_t: np.ndarray
    end_t: np.ndarray
    submit_t: np.ndarray
    nodes: np.ndarray
    true_runtime: np.ndarray
    est_runtime: np.ndarray
    n_jobs: int
    total_nodes: int
    makespan: float
    avg_wait: float
    max_wait: float
    avg_slowdown: float
    max_slowdown: float
    utilization: float
    n_events: int
    n_restarts: int = 0
    # The administrator goal this run was evaluated under (grammar
    # spec), stamped when ``run`` is given ``objective=``; scored
    # through the SAME compiled cost semantics as device decisions.
    # ``objective_cost`` is the scalar cost for elementwise goals
    # (score/weighted) and None for rank-based goals (lex/constrained:
    # a single candidate's composed rank is identically 0 — only the
    # per-term values in ``objective_terms`` carry information).
    objective: Optional[str] = None
    objective_cost: Optional[float] = None
    objective_terms: Optional[Dict[str, float]] = None

    def metric_dict(self) -> Dict[str, float]:
        return {
            "avg_wait": self.avg_wait, "max_wait": self.max_wait,
            "avg_slowdown": self.avg_slowdown,
            "max_slowdown": self.max_slowdown,
            "utilization": self.utilization, "makespan": self.makespan,
        }


_ARRIVAL, _END, _FAIL, _RECOVER = 0, 1, 2, 3


class ClusterEmulator:
    def __init__(self,
                 trace: Sequence[JobSpec],
                 total_nodes: int,
                 bus: Optional[EventBus] = None,
                 max_jobs: Optional[int] = None,
                 failures: Sequence[FailureSpec] = (),
                 check_invariants: bool = False,
                 engine: Optional[DrainEngine] = None) -> None:
        self.trace = list(trace)
        self.bus = bus if bus is not None else EventBus()
        self._external_bus = bus is not None
        self.engine = engine if engine is not None else DrainEngine()
        self.total_nodes = int(total_nodes)
        self.capacity_nodes = int(total_nodes)  # shrinks on failures
        self.free_nodes = int(total_nodes)
        n = len(self.trace)
        self.max_jobs = max_jobs if max_jobs is not None else max(
            64, 1 << int(np.ceil(np.log2(max(n, 1) + 1))))
        if n > self.max_jobs:
            raise ValueError(f"trace has {n} jobs > capacity {self.max_jobs}")
        self.failures = list(failures)
        self.check_invariants = check_invariants

        # ground-truth job arrays
        m = self.max_jobs
        self.submit_t = np.full(m, -1.0, dtype=np.float64)
        self.nodes = np.zeros(m, dtype=np.int64)
        self.est = np.zeros(m, dtype=np.float64)
        self.true_rt = np.zeros(m, dtype=np.float64)
        self.start_t = np.full(m, -1.0, dtype=np.float64)
        self.end_t = np.full(m, -1.0, dtype=np.float64)
        self.state = np.full(m, INVALID, dtype=np.int64)
        self.remaining = np.zeros(m, dtype=np.float64)  # for restarts
        self.now = 0.0
        self.n_events = 0
        self.n_restarts = 0
        self._heap: List[Tuple[float, int, int, int]] = []
        self._seq = 0
        self._end_seq = np.full(m, -1, dtype=np.int64)  # stale-end guards

        # capacity timeline for utilization accounting: (time, capacity)
        self._capacity_log: List[Tuple[float, int]] = [(0.0, int(total_nodes))]

        for spec in self.trace:
            if spec.nodes > total_nodes:
                raise ValueError(
                    f"job {spec.job_id} requests {spec.nodes} > cluster "
                    f"{total_nodes} nodes")
            # arrival times quantized to f32 (see module docstring)
            self._push(float(np.float32(spec.submit_t)), _ARRIVAL,
                       spec.job_id)
        for i, f in enumerate(self.failures):
            self._push(f.time, _FAIL, i)

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: int, ident: int) -> None:
        heapq.heappush(self._heap, (t, self._seq, kind, ident))
        self._seq += 1

    def _publish(self, kind: EventKind, t: float, job_id: int = -1,
                 **payload: float) -> None:
        self.bus.publish(Event(kind=kind, time=t, job_id=job_id,
                               payload=payload))

    # ------------------------------------------------------------------
    # qrun: decision feedback from the twin (§3.5)
    def qrun(self, job_ids: List[int], t: float) -> None:
        for j in job_ids:
            if self.state[j] != QUEUED:
                continue  # stale decision (already started/finished)
            if self.nodes[j] > self.free_nodes:
                raise RuntimeError(
                    f"qrun job {j}: needs {self.nodes[j]} nodes, "
                    f"only {self.free_nodes} free — twin/mirror divergence")
            self._start_job(j, t)

    def _start_job(self, j: int, t: float) -> None:
        self.state[j] = RUNNING
        self.start_t[j] = t
        self.free_nodes -= int(self.nodes[j])
        run = self.remaining[j] if self.remaining[j] > 0 else self.true_rt[j]
        self._end_seq[j] = self._seq
        # end times quantize to f32 like every other event time: the
        # f64 sum of f32-representable operands is exact, so the cast
        # equals the device replay's f32 add bit-for-bit
        end = float(np.float32(t + run))
        self.end_t[j] = end
        self._push(end, _END, j)
        self._publish(EventKind.RUNJOB, t, j)

    # ------------------------------------------------------------------
    # static-mode scheduling: same pass as the twin's simulator
    def _mirror_state(self) -> SimState:
        """SimState view with *predicted* ends (start + estimate)."""
        running = self.state == RUNNING
        pred_end = np.where(running, self.start_t + self.est, self.end_t)
        jobs = JobTable(
            submit_t=jnp.asarray(self.submit_t, dtype=jnp.float32),
            nodes=jnp.asarray(self.nodes, dtype=jnp.int32),
            est_runtime=jnp.asarray(self.est, dtype=jnp.float32),
            start_t=jnp.asarray(self.start_t, dtype=jnp.float32),
            end_t=jnp.asarray(pred_end, dtype=jnp.float32),
            state=jnp.asarray(self.state, dtype=jnp.int32),
        )
        return SimState(
            jobs=jobs,
            free_nodes=jnp.int32(self.free_nodes),
            total_nodes=jnp.int32(self.capacity_nodes),
            now=jnp.float32(self.now),
        )

    def jobs_view(self) -> Dict:
        """Authoritative full job-table probe — the ``qstat`` analogue
        of the ``free_nodes`` probe, consumed by ``sync.resync_jobs``
        when the twin declares stream events LOST (DESIGN.md §12).
        Exposes exactly what a scheduler CLI would: submit/start times,
        node counts, USER estimates (never true runtimes — the §3.2
        asymmetry), actual ends for finished jobs only, and the current
        capacity/availability scalars."""
        return {
            "submit_t": self.submit_t.copy(),
            "nodes": self.nodes.copy(),
            "est_runtime": self.est.copy(),
            "start_t": self.start_t.copy(),
            "end_t": np.where(self.state == DONE, self.end_t, -1.0),
            "state": self.state.copy(),
            "free_nodes": int(self.free_nodes),
            "total_nodes": int(self.capacity_nodes),
        }

    # -- crash-safe co-simulation resume (DESIGN.md §12) ----------------
    def snapshot_state(self) -> Dict:
        """JSON-serializable ground-truth snapshot: job arrays, event
        heap, stale-end guards, and capacity log — everything ``run``
        needs to continue mid-trace after a process restart (used by
        ``twin_loop --snapshot-dir/--resume``)."""
        return {
            "submit_t": self.submit_t.tolist(),
            "nodes": self.nodes.tolist(),
            "est": self.est.tolist(),
            "true_rt": self.true_rt.tolist(),
            "start_t": self.start_t.tolist(),
            "end_t": self.end_t.tolist(),
            "state": self.state.tolist(),
            "remaining": self.remaining.tolist(),
            "now": float(self.now),
            "n_events": int(self.n_events),
            "n_restarts": int(self.n_restarts),
            "free_nodes": int(self.free_nodes),
            "capacity_nodes": int(self.capacity_nodes),
            "heap": [list(item) for item in self._heap],
            "seq": int(self._seq),
            "end_seq": self._end_seq.tolist(),
            "capacity_log": [list(item) for item in self._capacity_log],
        }

    def restore_state(self, d: Dict) -> None:
        """Inverse of ``snapshot_state`` on an emulator built with the
        same trace/failures; ``run`` then resumes the event loop from
        exactly where the snapshot cut."""
        self.submit_t[:] = np.asarray(d["submit_t"], dtype=np.float64)
        self.nodes[:] = np.asarray(d["nodes"], dtype=np.int64)
        self.est[:] = np.asarray(d["est"], dtype=np.float64)
        self.true_rt[:] = np.asarray(d["true_rt"], dtype=np.float64)
        self.start_t[:] = np.asarray(d["start_t"], dtype=np.float64)
        self.end_t[:] = np.asarray(d["end_t"], dtype=np.float64)
        self.state[:] = np.asarray(d["state"], dtype=np.int64)
        self.remaining[:] = np.asarray(d["remaining"], dtype=np.float64)
        self.now = float(d["now"])
        self.n_events = int(d["n_events"])
        self.n_restarts = int(d["n_restarts"])
        self.free_nodes = int(d["free_nodes"])
        self.capacity_nodes = int(d["capacity_nodes"])
        self._heap = [(float(t), int(s), int(k), int(i))
                      for t, s, k, i in d["heap"]]
        heapq.heapify(self._heap)
        self._seq = int(d["seq"])
        self._end_seq[:] = np.asarray(d["end_seq"], dtype=np.int64)
        self._capacity_log = [(float(t), int(c))
                              for t, c in d["capacity_log"]]

    def _static_schedule(self, policy) -> None:
        started = np.asarray(self.engine.schedule_pass_starts(
            self._mirror_state(), policy))
        for j in np.nonzero(started)[0]:
            self._start_job(int(j), self.now)

    # ------------------------------------------------------------------
    def run(self,
            policy_id=None,
            on_event: Optional[Callable[[], None]] = None,
            fast: bool = False,
            objective=None,
            on_quiesce: Optional[Callable[[], bool]] = None) -> RunReport:
        """Run the full trace.

        static mode: pass ``policy_id`` — a legacy integer id or a
        parametric ``policies.PolicySpec`` fork (e.g. ``wfp_spec(a=2)``
        to baseline one sweep point); both run through the same k=1
        engine pass as the twin's simulator.  ``fast=True`` replays the
        whole trace in ONE device computation (``engine.replay``,
        DESIGN.md §6) — bit-identical results, no per-event engine
        dispatch; the host event loop here remains the oracle.  The
        fast path supports neither failures nor event-bus streaming.
        twin mode:   pass ``on_event`` = twin.pump (the co-simulation
        hook called after every published event).

        ``objective`` (an ``objective.Objective`` or grammar string)
        stamps the report with the run's cost under that goal
        (``RunReport.objective`` / ``objective_cost``) — scheduling
        itself is unaffected (static mode runs ONE fixed policy; twin
        mode's goal lives on the ``SchedTwin``).

        ``on_quiesce`` (twin mode only, e.g. ``twin.flush``) fires when
        the event heap empties while queued jobs remain — which on a
        clean stream never happens, but under a lossy bus (chaos
        testing, real deployments) means the consumer missed the events
        that would have started them.  If the hook returns True (it
        reconciled and issued qruns, pushing fresh end events) the loop
        resumes; otherwise the run ends and ``_report`` raises as
        before.
        """
        if (policy_id is None) == (on_event is None):
            raise ValueError("exactly one of policy_id / on_event required")
        if on_quiesce is not None and on_event is None:
            raise ValueError("on_quiesce requires twin (on_event) mode")
        return self._stamp_objective(
            self._run(policy_id, on_event, fast, on_quiesce), objective)

    def _stamp_objective(self, report: RunReport, objective) -> RunReport:
        if objective is not None:
            from repro.core.objective import (metrics_from_rows,
                                              normalize_objective,
                                              report_costs)
            goal = normalize_objective(objective)
            row = report.metric_dict()
            report.objective = str(goal)
            if goal.elementwise:
                report.objective_cost = float(
                    report_costs(goal, [row])[0])
            report.objective_terms = {
                term: float(v[0]) for term, v in
                goal.cost_terms(metrics_from_rows([row])).items()}
        return report

    def _run(self,
             policy_id,
             on_event: Optional[Callable[[], None]],
             fast: bool,
             on_quiesce: Optional[Callable[[], bool]] = None) -> RunReport:
        if fast:
            if policy_id is None:
                raise ValueError("fast=True requires static mode")
            if self.failures:
                raise ValueError(
                    "fast=True does not support failure scenarios; "
                    "run the host event loop instead")
            if self._external_bus or self.bus.has_listeners:
                raise ValueError(
                    "fast=True does not stream bus events, but this "
                    "emulator has an attached bus (someone may consume "
                    "it, even after the run); run the host event loop "
                    "instead")
            return self._run_fast(policy_id)

        while self._heap:
            t, seq, kind, ident = heapq.heappop(self._heap)
            self.now = max(self.now, t)
            self.n_events += 1

            if kind == _ARRIVAL:
                spec = self.trace[ident]
                j = spec.job_id
                self.submit_t[j] = np.float32(spec.submit_t)
                self.nodes[j] = spec.nodes
                self.est[j] = np.float32(spec.est_runtime)
                self.true_rt[j] = np.float32(spec.true_runtime)
                self.state[j] = QUEUED
                self._publish(EventKind.QUEUEJOB, t, j,
                              nodes=float(spec.nodes),
                              est_runtime=float(spec.est_runtime))
            elif kind == _END:
                j = ident
                # stale end events (the job was killed and restarted):
                # each end event carries the sequence number of the run
                # instance that pushed it, so a restart whose new end
                # collides with the stale time cannot mis-retire (a
                # float-epsilon time check here used to stand in for
                # this and misfired on collisions).
                if self.state[j] != RUNNING or seq != self._end_seq[j]:
                    self.n_events -= 1
                    continue
                self.state[j] = DONE
                self.end_t[j] = t
                self.remaining[j] = 0.0
                self.free_nodes += int(self.nodes[j])
                self._publish(EventKind.JOBOBIT, t, j)
            elif kind == _FAIL:
                self._handle_failure(self.failures[ident], t)
            elif kind == _RECOVER:
                nodes = ident
                self.capacity_nodes += nodes
                self.free_nodes += nodes
                self._capacity_log.append((t, self.capacity_nodes))
                self._publish(EventKind.NODEUP, t, nodes=float(nodes))
            else:  # pragma: no cover
                raise AssertionError(kind)

            if policy_id is not None:
                self._static_schedule(policy_id)
            else:
                on_event()

            if self.check_invariants:
                self._assert_invariants()

            if not self._heap and on_quiesce is not None and \
                    bool((self.state == QUEUED).any()):
                # Stream quiesced with jobs stuck in QUEUED: on a lossy
                # bus the consumer may have missed the very events that
                # would have started them (and no future event will
                # re-prompt it).  Let it reconcile; any qruns it issues
                # push fresh end events and the loop resumes.
                on_quiesce()

        return self._report()

    # ------------------------------------------------------------------
    def _run_fast(self, policy) -> RunReport:
        """Static mode on the device: one batched replay instead of one
        engine pass per host event.  Writes the replayed ground truth
        back into the host arrays so ``_report`` (and any later
        inspection) is identical to a host-loop run."""
        from repro.cluster.workload import make_scenario

        scen = make_scenario(self.trace, self.total_nodes,
                             max_jobs=self.max_jobs)
        out = self.engine.replay(scen, policy)
        n = len(self.trace)
        # f32 device times are exact in the f64 host arrays (ingestion
        # quantizes to f32, and all sums stay in f32-exact range)
        self.start_t[:] = np.asarray(out.start_t[0], dtype=np.float64)
        self.end_t[:] = np.asarray(out.end_t[0], dtype=np.float64)
        self.state[:] = np.asarray(out.result.state.jobs.state[0],
                                   dtype=np.int64)
        self.submit_t[:n] = scen.submit_t[0, :n]
        self.nodes[:n] = scen.nodes[0, :n]
        self.est[:n] = scen.est_runtime[0, :n]
        self.true_rt[:n] = scen.true_runtime[0, :n]
        self.free_nodes = self.total_nodes
        if n:
            self.now = float(self.end_t[:n].max())
        # one arrival + one completion per job, as the host loop counts
        self.n_events = 2 * n
        return self._report()

    # ------------------------------------------------------------------
    def _handle_failure(self, f: FailureSpec, t: float) -> None:
        """NODEFAIL: shrink capacity; kill+requeue victims if needed."""
        self.capacity_nodes -= f.nodes
        self.free_nodes -= f.nodes
        self._capacity_log.append((t, self.capacity_nodes))
        victims: List[int] = []
        # free deficit -> kill running jobs (largest first = fewest kills)
        running = [int(j) for j in np.nonzero(self.state == RUNNING)[0]]
        running.sort(key=lambda j: -self.nodes[j])
        while self.free_nodes < 0 and running:
            v = running.pop(0)
            victims.append(v)
            self.free_nodes += int(self.nodes[v])
            # full rerun on restart (no app checkpoint assumed)
            self.remaining[v] = self.true_rt[v]
            self.state[v] = QUEUED
            self.start_t[v] = -1.0
            self.end_t[v] = -1.0
            self.n_restarts += 1
        first_victim = victims[0] if victims else -1
        self._publish(EventKind.NODEFAIL, t, nodes=float(f.nodes),
                      victim_job=float(first_victim))
        for v in victims[1:]:
            self._publish(EventKind.NODEFAIL, t, nodes=0.0,
                          victim_job=float(v))
        if f.duration > 0:
            self._push(t + f.duration, _RECOVER, f.nodes)

    # ------------------------------------------------------------------
    def _assert_invariants(self) -> None:
        used = int(self.nodes[self.state == RUNNING].sum())
        assert used + self.free_nodes == self.capacity_nodes, (
            used, self.free_nodes, self.capacity_nodes)
        assert self.free_nodes >= 0
        started = self.start_t >= 0
        assert np.all(self.start_t[started] >= self.submit_t[started] - 1e-9)

    def _available_node_seconds(self, t0: float, t1: float) -> float:
        """∫ capacity(t) dt over [t0, t1] along the failure timeline —
        the utilization denominator.  Dividing by the original
        ``total_nodes`` overstates availability whenever ``FailureSpec``s
        shrink ``capacity_nodes`` (permanently for duration=0 failures).
        Reduces to ``total_nodes * (t1 - t0)`` with no failures."""
        if len(self._capacity_log) == 1:
            return self.total_nodes * (t1 - t0)
        total = 0.0
        for i, (t_seg, cap) in enumerate(self._capacity_log):
            t_next = (self._capacity_log[i + 1][0]
                      if i + 1 < len(self._capacity_log) else t1)
            lo, hi = max(t_seg, t0), min(t_next, t1)
            if hi > lo:
                total += cap * (hi - lo)
        return total

    def _report(self) -> RunReport:
        done = self.state == DONE
        if not np.all(done[:len(self.trace)]):
            stuck = np.nonzero(~done[:len(self.trace)])[0]
            raise RuntimeError(f"jobs never completed: {stuck[:8]}...")
        n = len(self.trace)
        s, e = self.start_t[:n], self.end_t[:n]
        sub, rt = self.submit_t[:n], self.true_rt[:n]
        wait = np.maximum(s - sub, 0.0)
        sd = np.maximum((wait + rt) / np.maximum(rt, SLOWDOWN_TAU), 1.0)
        makespan = float(e.max() - sub.min())
        avail = self._available_node_seconds(float(sub.min()), float(e.max()))
        util = float((self.nodes[:n] * rt).sum() / max(avail, 1e-9))
        return RunReport(
            start_t=s.copy(), end_t=e.copy(), submit_t=sub.copy(),
            nodes=self.nodes[:n].copy(), true_runtime=rt.copy(),
            est_runtime=self.est[:n].copy(),
            n_jobs=n, total_nodes=self.total_nodes, makespan=makespan,
            avg_wait=float(wait.mean()), max_wait=float(wait.max()),
            avg_slowdown=float(sd.mean()), max_slowdown=float(sd.max()),
            utilization=min(util, 1.0), n_events=self.n_events,
            n_restarts=self.n_restarts)
