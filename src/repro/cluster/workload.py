"""Workload generation.

``paper_synthetic_trace`` reproduces the §4.1 evaluation trace exactly:
150 jobs in four phases on a 32-node cluster, 5 s inter-arrival —
deliberately constructed so that large/long phase-2 jobs block the
short/small jobs behind them (the regime where SJF shines but hurts
tail latency, which is what makes adaptive selection pay off).

True runtimes are drawn as a fraction of the requested walltime
(users overestimate — §3.2); the twin never sees them.

``poisson_trace`` / ``bursty_trace`` are the generic scenario family:
flat Poisson arrivals and the same process under sinusoidal (diurnal)
arrival-rate modulation, so policy sweeps are evaluated on more than
flat-Poisson scenarios (``python -m benchmarks.run bursty``).

``arch_job_mix`` maps the assigned LM architectures onto job classes so
the same twin schedules a TPU training/serving fleet (examples/).
``swf`` helpers read/write the Standard Workload Format for replaying
real center logs (e.g. the Polaris-like distribution of Figure 1).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class JobSpec:
    job_id: int
    submit_t: float
    nodes: int
    est_runtime: float   # user-requested walltime (visible to scheduler/twin)
    true_runtime: float  # ground truth (emulator only)
    tag: str = ""        # phase or job-class label


@dataclasses.dataclass(frozen=True)
class Phase:
    n_jobs: int
    nodes: Tuple[int, int]        # inclusive range
    walltime: Tuple[float, float] # seconds, inclusive range
    tag: str


PAPER_PHASES: Sequence[Phase] = (
    Phase(25, (2, 4), (60.0, 180.0), "warmup"),
    Phase(35, (16, 20), (500.0, 700.0), "burst"),
    Phase(40, (6, 8), (200.0, 300.0), "steady"),
    Phase(50, (2, 4), (30.0, 90.0), "tail"),  # "short-job tail ... of seconds"
)
PAPER_TOTAL_NODES = 32
PAPER_ARRIVAL_GAP = 5.0  # seconds per job


def paper_synthetic_trace(seed: int = 0,
                          accuracy: Tuple[float, float] = (0.5, 1.0),
                          arrival_gap: float = PAPER_ARRIVAL_GAP,
                          phases: Sequence[Phase] = PAPER_PHASES,
                          rng: Optional[np.random.Generator] = None,
                          ) -> List[JobSpec]:
    """The §4.1 four-phase synthetic workload (150 jobs).

    ``accuracy`` is the true/estimated runtime ratio range; estimates are
    the phase walltimes.  Deterministic given ``seed``; pass ``rng=`` to
    draw from an explicit caller-owned generator instead (resumable
    streams — ``seed`` is then ignored).
    """
    rng = np.random.default_rng(seed) if rng is None else rng
    jobs: List[JobSpec] = []
    t = 0.0
    jid = 0
    for ph in phases:
        for _ in range(ph.n_jobs):
            nodes = int(rng.integers(ph.nodes[0], ph.nodes[1] + 1))
            est = float(rng.uniform(ph.walltime[0], ph.walltime[1]))
            acc = float(rng.uniform(accuracy[0], accuracy[1]))
            jobs.append(JobSpec(
                job_id=jid, submit_t=t, nodes=nodes,
                est_runtime=est, true_runtime=max(1.0, est * acc),
                tag=ph.tag))
            jid += 1
            t += arrival_gap
    return jobs


def _sample_job(rng: np.random.Generator, jid: int, t: float,
                total_nodes: int, node_range: Tuple[int, int],
                walltime_range: Tuple[float, float],
                accuracy: Tuple[float, float], heavy_tail: bool,
                tag: str) -> JobSpec:
    """One job draw shared by the Poisson-family trace generators
    (identical RNG call order: nodes, est, acc)."""
    lo_w, hi_w = walltime_range
    nodes = int(rng.integers(node_range[0],
                             min(node_range[1], total_nodes) + 1))
    if heavy_tail:
        mu = np.log(np.sqrt(lo_w * hi_w))
        sigma = np.log(hi_w / lo_w) / 4.0
        est = float(np.clip(rng.lognormal(mu, sigma), lo_w, hi_w))
    else:
        est = float(rng.uniform(lo_w, hi_w))
    acc = float(rng.uniform(accuracy[0], accuracy[1]))
    return JobSpec(jid, t, nodes, est, max(1.0, est * acc), tag)


def poisson_trace(n_jobs: int, total_nodes: int, mean_gap: float,
                  node_range: Tuple[int, int],
                  walltime_range: Tuple[float, float],
                  seed: int = 0,
                  accuracy: Tuple[float, float] = (0.3, 1.0),
                  heavy_tail: bool = True,
                  rng: Optional[np.random.Generator] = None,
                  ) -> List[JobSpec]:
    """Generic Poisson-arrival workload with (optionally) lognormal
    walltimes — matches the wide Polaris-style variability of Figure 1.
    ``rng=`` substitutes an explicit caller-owned generator for the
    ``seed``-derived one (deterministic, resumable streams)."""
    rng = np.random.default_rng(seed) if rng is None else rng
    jobs: List[JobSpec] = []
    t = 0.0
    for jid in range(n_jobs):
        t += float(rng.exponential(mean_gap))
        jobs.append(_sample_job(rng, jid, t, total_nodes, node_range,
                                walltime_range, accuracy, heavy_tail,
                                "poisson"))
    return jobs


def bursty_trace(n_jobs: int, total_nodes: int, mean_gap: float,
                 node_range: Tuple[int, int],
                 walltime_range: Tuple[float, float],
                 seed: int = 0,
                 accuracy: Tuple[float, float] = (0.3, 1.0),
                 heavy_tail: bool = True,
                 period: float = 3600.0,
                 amplitude: float = 0.8,
                 phase: float = 0.0,
                 rng: Optional[np.random.Generator] = None,
                 ) -> List[JobSpec]:
    """Bursty/diurnal arrivals: a nonhomogeneous Poisson process whose
    rate is sinusoidally modulated on top of ``poisson_trace``'s flat
    rate,

        rate(t) = (1 + amplitude * sin(2*pi*t/period + phase)) / mean_gap,

    so arrivals alternate between rush-hour bursts (rate up to
    (1+amplitude)x the mean) and quiet troughs — the regime where
    backfill-friendly policies and aggressive aging pull apart, which a
    flat-Poisson evaluation never exercises.  ``amplitude`` in [0, 1);
    0 reduces to ``poisson_trace``'s marginal statistics.  Job sizes
    and walltimes are drawn exactly as in ``poisson_trace``.  ``rng=``
    substitutes an explicit caller-owned generator for the
    ``seed``-derived one — the same knob the on-device fan exposes via
    ``FanSpec.seed``, so host traces and device fans are both
    deterministic and resumable.
    """
    if not 0.0 <= amplitude < 1.0:
        raise ValueError(f"amplitude must be in [0, 1), got {amplitude}")
    rng = np.random.default_rng(seed) if rng is None else rng
    jobs: List[JobSpec] = []
    t = 0.0
    for jid in range(n_jobs):
        # thin an exponential draw by the instantaneous rate at t: the
        # local mean gap is mean_gap / (1 + A sin(...)).
        rate = 1.0 + amplitude * np.sin(2.0 * np.pi * t / period + phase)
        t += float(rng.exponential(mean_gap) / max(rate, 1e-9))
        jobs.append(_sample_job(rng, jid, t, total_nodes, node_range,
                                walltime_range, accuracy, heavy_tail,
                                "bursty"))
    return jobs


# ----------------------------------------------------------------------
# LM-fleet job classes: the twin as a TPU-cluster scheduler (examples/).
# ----------------------------------------------------------------------

#: pods requested per job class, per architecture scale bucket.
_ARCH_PODS = {
    "granite-20b": 2, "granite-3-2b": 1, "llama3.2-1b": 1,
    "qwen2-72b": 8, "internvl2-76b": 8, "deepseek-v2-lite-16b": 2,
    "olmoe-1b-7b": 1, "rwkv6-7b": 2, "recurrentgemma-2b": 1,
    "whisper-small": 1,
}


def arch_job_mix(n_jobs: int, total_pods: int = 32, seed: int = 0,
                 mean_gap: float = 30.0,
                 rng: Optional[np.random.Generator] = None) -> List[JobSpec]:
    """Jobs for a TPU fleet: training jobs (long, many pods), prefill
    batches (short, few pods), decode services (medium).  Node counts
    come from each architecture's pod footprint (`_ARCH_PODS`)."""
    rng = np.random.default_rng(seed) if rng is None else rng
    arches = list(_ARCH_PODS)
    classes = (
        ("train", 4.0, (1800.0, 7200.0)),
        ("prefill", 1.0, (120.0, 600.0)),
        ("decode", 2.0, (600.0, 1800.0)),
    )
    jobs: List[JobSpec] = []
    t = 0.0
    for jid in range(n_jobs):
        t += float(rng.exponential(mean_gap))
        arch = arches[int(rng.integers(len(arches)))]
        cname, scale, wt = classes[int(rng.integers(len(classes)))]
        pods = min(max(1, int(_ARCH_PODS[arch] * scale)), total_pods)
        est = float(rng.uniform(*wt))
        acc = float(rng.uniform(0.4, 1.0))
        jobs.append(JobSpec(jid, t, pods, est, max(1.0, est * acc),
                            tag=f"{arch}:{cname}"))
    return jobs


# ----------------------------------------------------------------------
# Scenario stacking — the replay engine's scenario axis (DESIGN.md §6).
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScenarioSet:
    """S heterogeneous traces padded and stacked to one (S, J) block.

    The device-side input of ``engine.replay`` / ``engine.replay_grid``:
    slot j of scenario s is job j of trace s (submission order), padding
    slots carry ``valid=False`` and an ``inf`` arrival so they never
    enter any simulation.  ``total_nodes`` is per-scenario — scenarios
    of different cluster sizes ride the same batch.

    Job fields are quantized to f32 — the device dtype — so host-side
    (f64) and device-side event arithmetic agree bit-for-bit (sums of
    in-range f32 values are exact in both precisions).
    """

    submit_t: np.ndarray      # (S, J) f32, 0.0 on padding
    nodes: np.ndarray         # (S, J) i32, 0 on padding
    est_runtime: np.ndarray   # (S, J) f32, 0.0 on padding
    true_runtime: np.ndarray  # (S, J) f32, 0.0 on padding
    valid: np.ndarray         # (S, J) bool — real (non-padding) jobs
    n_jobs: np.ndarray        # (S,) i32
    total_nodes: np.ndarray   # (S,) i32

    @property
    def n_scenarios(self) -> int:
        return self.submit_t.shape[0]

    @property
    def capacity(self) -> int:
        return self.submit_t.shape[1]


def stack_scenarios(traces: Sequence[Sequence[JobSpec]],
                    total_nodes: Union[int, Sequence[int]],
                    max_jobs: Optional[int] = None) -> ScenarioSet:
    """Pad + stack traces into a ``ScenarioSet``.

    ``max_jobs`` defaults to the next power of two above the longest
    trace (matching ``ClusterEmulator``'s slot sizing; padding slots
    never influence replay dynamics, so any J ≥ max trace length gives
    identical results).  Traces must be in submission order — slot
    index is the arrival cursor — and every job must fit its scenario's
    cluster *or* the replay will flag that scenario deadlocked.
    """
    S = len(traces)
    if S == 0:
        raise ValueError("need at least one trace")
    if isinstance(total_nodes, (int, np.integer)):
        totals = [int(total_nodes)] * S
    else:
        totals = [int(t) for t in total_nodes]
        if len(totals) != S:
            raise ValueError(
                f"{len(totals)} total_nodes for {S} traces")
    longest = max(len(t) for t in traces)
    if max_jobs is None:
        max_jobs = max(64, 1 << int(np.ceil(np.log2(max(longest, 1) + 1))))
    if longest > max_jobs:
        raise ValueError(f"longest trace has {longest} jobs > {max_jobs}")

    shape = (S, max_jobs)
    out = ScenarioSet(
        submit_t=np.zeros(shape, dtype=np.float32),
        nodes=np.zeros(shape, dtype=np.int32),
        est_runtime=np.zeros(shape, dtype=np.float32),
        true_runtime=np.zeros(shape, dtype=np.float32),
        valid=np.zeros(shape, dtype=bool),
        n_jobs=np.asarray([len(t) for t in traces], dtype=np.int32),
        total_nodes=np.asarray(totals, dtype=np.int32),
    )
    for s, trace in enumerate(traces):
        ids = [j.job_id for j in trace]
        if ids != list(range(len(trace))):
            # slot j IS job j: the host emulator keys its arrays by
            # job_id, the replay by position — permuted ids would make
            # the two silently disagree
            raise ValueError(
                f"trace {s}: job_id must equal trace position")
        sub = np.asarray([j.submit_t for j in trace], dtype=np.float32)
        if np.any(np.diff(sub) < 0):
            raise ValueError(f"trace {s} not in submission order")
        n = len(trace)
        out.submit_t[s, :n] = sub
        out.nodes[s, :n] = [j.nodes for j in trace]
        out.est_runtime[s, :n] = [j.est_runtime for j in trace]
        out.true_runtime[s, :n] = [j.true_runtime for j in trace]
        out.valid[s, :n] = True
    return out


def make_scenario(trace: Sequence[JobSpec], total_nodes: int,
                  max_jobs: Optional[int] = None) -> ScenarioSet:
    """One trace as an S=1 ``ScenarioSet`` (``engine.replay``'s input)."""
    return stack_scenarios([trace], total_nodes, max_jobs=max_jobs)


def split_scenarios(rng: np.random.Generator,
                    trace_fn: Callable[[np.random.Generator],
                                       Sequence[JobSpec]],
                    n_train: int, n_heldout: int,
                    total_nodes: Union[int, Sequence[int]],
                    max_jobs: Optional[int] = None,
                    ) -> Tuple[ScenarioSet, ScenarioSet]:
    """Deterministic train/held-out split for the ``learn`` trainer.

    Draws ``n_train + n_heldout`` traces SEQUENTIALLY from the single
    caller-owned ``rng`` (``trace_fn(rng)`` per trace, the PR-7 ``rng=``
    generator idiom), then partitions by index: the first ``n_train``
    traces are the training set, the last ``n_heldout`` the held-out
    set.  The two sets are disjoint segments of one stream — an index
    partition, not a re-draw — so train/eval leakage is structurally
    impossible, and the same rng state reproduces the same split
    bitwise.  Both sets are padded to a COMMON ``max_jobs`` so a θ
    evaluated on either sees identical table shapes (one compiled
    replay shape per S).
    """
    if n_train < 1 or n_heldout < 1:
        raise ValueError(
            f"need n_train >= 1 and n_heldout >= 1, got "
            f"{n_train}/{n_heldout}")
    traces = [list(trace_fn(rng)) for _ in range(n_train + n_heldout)]
    if max_jobs is None:
        longest = max(len(t) for t in traces)
        max_jobs = max(64, 1 << int(np.ceil(np.log2(longest + 1))))
    if isinstance(total_nodes, (int, np.integer)):
        tn_train: Union[int, Sequence[int]] = int(total_nodes)
        tn_held: Union[int, Sequence[int]] = int(total_nodes)
    else:
        totals = [int(t) for t in total_nodes]
        if len(totals) != n_train + n_heldout:
            raise ValueError(
                f"{len(totals)} total_nodes for {n_train + n_heldout} "
                f"traces")
        tn_train, tn_held = totals[:n_train], totals[n_train:]
    return (stack_scenarios(traces[:n_train], tn_train, max_jobs=max_jobs),
            stack_scenarios(traces[n_train:], tn_held, max_jobs=max_jobs))


def slice_scenarios(scenarios: ScenarioSet, start: int,
                    stop: int) -> ScenarioSet:
    """Rows ``[start, stop)`` as a ``ScenarioSet`` of numpy VIEWS — no
    copies; the fleet streamer (``whatif.sharded_replay_grid``) cuts
    its fixed-size blocks with this, so slicing a 10k-scenario set into
    blocks costs nothing on the host."""
    cut = lambda x: x[start:stop]
    return ScenarioSet(
        submit_t=cut(scenarios.submit_t),
        nodes=cut(scenarios.nodes),
        est_runtime=cut(scenarios.est_runtime),
        true_runtime=cut(scenarios.true_runtime),
        valid=cut(scenarios.valid),
        n_jobs=cut(scenarios.n_jobs),
        total_nodes=cut(scenarios.total_nodes),
    )


def pad_scenarios(scenarios: ScenarioSet, multiple: int) -> ScenarioSet:
    """Pad the scenario axis up to the next multiple of ``multiple``
    with INERT rows: ``valid`` all-False (so every arrival is ``inf``
    and the fork is born drained — it never becomes live, never queues
    a job, and therefore never influences the lock-step dynamic pass
    bound of real forks), zero jobs, ``total_nodes=1`` (keeps the
    per-scenario metric denominators finite; padded-row metrics are
    dropped before selection anyway).  Identity when S already divides.
    """
    S = scenarios.n_scenarios
    if multiple <= 0:
        raise ValueError(f"multiple must be positive, got {multiple}")
    pad = (-S) % multiple
    if pad == 0:
        return scenarios
    J = scenarios.capacity
    z = lambda dt: np.zeros((pad, J), dtype=dt)
    cat = np.concatenate
    return ScenarioSet(
        submit_t=cat([scenarios.submit_t, z(np.float32)]),
        nodes=cat([scenarios.nodes, z(np.int32)]),
        est_runtime=cat([scenarios.est_runtime, z(np.float32)]),
        true_runtime=cat([scenarios.true_runtime, z(np.float32)]),
        valid=cat([scenarios.valid, z(bool)]),
        n_jobs=cat([scenarios.n_jobs,
                    np.zeros((pad,), dtype=np.int32)]),
        total_nodes=cat([scenarios.total_nodes,
                         np.ones((pad,), dtype=np.int32)]),
    )


# ----------------------------------------------------------------------
# Conversions & SWF I/O
# ----------------------------------------------------------------------

def trace_to_arrays(trace: Sequence[JobSpec]) -> Dict[str, np.ndarray]:
    return {
        "submit_t": np.array([j.submit_t for j in trace], dtype=np.float32),
        "nodes": np.array([j.nodes for j in trace], dtype=np.int32),
        "est_runtime": np.array([j.est_runtime for j in trace],
                                dtype=np.float32),
        "true_runtime": np.array([j.true_runtime for j in trace],
                                 dtype=np.float32),
    }


def write_swf(trace: Sequence[JobSpec], path: str) -> None:
    """Minimal Standard Workload Format writer (fields we use)."""
    with open(path, "w") as f:
        f.write("; SchedTwin synthetic trace\n")
        for j in trace:
            # id submit wait run nproc cpu mem reqproc reqtime ...
            f.write(f"{j.job_id + 1} {j.submit_t:.0f} -1 "
                    f"{j.true_runtime:.0f} {j.nodes} -1 -1 "
                    f"{j.nodes} {j.est_runtime:.0f} -1\n")


def read_swf(path: str, max_jobs: Optional[int] = None) -> List[JobSpec]:
    jobs: List[JobSpec] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(";"):
                continue
            parts = line.split()
            jid = len(jobs)
            submit = float(parts[1])
            run = max(1.0, float(parts[3]))
            nproc = int(parts[7]) if int(parts[7]) > 0 else int(parts[4])
            req = float(parts[8]) if float(parts[8]) > 0 else run
            jobs.append(JobSpec(jid, submit, max(1, nproc), req, run, "swf"))
            if max_jobs is not None and len(jobs) >= max_jobs:
                break
    return jobs


def trace_stats(trace: Sequence[JobSpec]) -> Dict[str, float]:
    """Figure-1-style distribution summary."""
    nodes = np.array([j.nodes for j in trace])
    rt = np.array([j.true_runtime for j in trace])
    return {
        "n_jobs": len(trace),
        "nodes_min": float(nodes.min()), "nodes_p50": float(np.median(nodes)),
        "nodes_max": float(nodes.max()),
        "runtime_min_s": float(rt.min()),
        "runtime_p50_s": float(np.median(rt)),
        "runtime_max_s": float(rt.max()),
        "node_seconds": float((nodes * rt).sum()),
    }
