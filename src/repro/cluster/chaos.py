"""Chaos layer: deterministic fault injection for the twin's event feed.

``ChaosBus`` wraps a real ``events.EventBus`` and corrupts ONLY the
consumer-facing ``read()`` view — the producer's append-only log (and
therefore the emulator's ground truth and ``recover()``'s full-log
replay) stays intact, exactly like a flaky transport between a durable
stream and a subscriber.  Injected faults:

  - **drops**       — an event never reaches the consumer,
  - **duplicates**  — an event is delivered twice,
  - **reordering**  — an event is held back and delivered late, behind
                      newer sequence numbers,
  - **corruption**  — the delivered copy is mangled (bad time / job id /
                      kind / payload) so ``validate_event`` must
                      quarantine it,
  - **read failures** — ``read()`` raises a transient ``BusReadError``.

Every decision is a PURE function of ``(spec.seed, event.seq)`` (read
failures: of the read-call count), via a splitmix64-style hash — no
sequential RNG state.  That is what makes the chaos benchmark's
mid-run kill + ``SchedTwin.restore()`` gate meaningful: the resumed
twin observes the *identical* corrupted stream, so any decision
divergence is the twin's fault, not the harness's.

``failure_storm`` builds the emulator-side half of the default chaos
profile: a burst of correlated ``FailureSpec`` waves (rack/power-domain
style), stressing NODEFAIL/NODEUP ingestion and capacity collapse at
the same time the bus is misbehaving.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.cluster.emulator import FailureSpec
from repro.core.events import BusReadError, Event, EventBus

_M64 = (1 << 64) - 1

# Per-fault hash tags so one event's drop/duplicate/... draws are
# independent of each other.
_TAG_DROP = 0xD209
_TAG_DUP = 0xD4B1
_TAG_REORDER = 0x2E02
_TAG_DELAY = 0xDE1A
_TAG_CORRUPT = 0xC022
_TAG_MODE = 0x30DE
_TAG_READ = 0x2EAD


def _unit(seed: int, *keys: int) -> float:
    """Deterministic uniform in [0, 1) from integer keys (splitmix64)."""
    x = (seed * 0x9E3779B97F4A7C15) & _M64
    for k in keys:
        x ^= (k + 0x9E3779B97F4A7C15 + ((x << 6) & _M64) + (x >> 2)) & _M64
        x = (x * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    x ^= x >> 31
    return x / 2.0 ** 64


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """Fault-injection profile.  All probabilities are per-event (read
    failures: per read call); ``reorder_delay`` is how many later
    sequence numbers must be delivered before a held-back event is
    released (1 = swap with its successor)."""

    drop_prob: float = 0.0
    duplicate_prob: float = 0.0
    reorder_prob: float = 0.0
    reorder_delay: int = 3
    corrupt_prob: float = 0.0
    read_failure_prob: float = 0.0
    seed: int = 0

    def __post_init__(self):
        for f in ("drop_prob", "duplicate_prob", "reorder_prob",
                  "corrupt_prob", "read_failure_prob"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f} must be in [0, 1], got {v}")
        if self.reorder_delay < 1:
            raise ValueError("reorder_delay must be >= 1")


# The profile benchmarks/chaos.py gates CI on: every fault class active
# at rates aggressive enough to exercise every hardened path on a
# paper-scale trace, mild enough that resyncs keep the mirror usable.
DEFAULT_PROFILE = ChaosSpec(drop_prob=0.05, duplicate_prob=0.05,
                            reorder_prob=0.10, reorder_delay=3,
                            corrupt_prob=0.03, read_failure_prob=0.05,
                            seed=0)


def failure_storm(start: float, waves: int = 3, nodes: int = 4,
                  spacing_s: float = 200.0,
                  duration_s: float = 400.0) -> List[FailureSpec]:
    """A correlated node-failure storm: ``waves`` back-to-back outages
    of ``nodes`` nodes each, ``spacing_s`` apart, each healing after
    ``duration_s`` — the emulator-side companion to bus-level chaos."""
    return [FailureSpec(time=start + w * spacing_s, nodes=nodes,
                        duration=duration_s) for w in range(waves)]


class ChaosBus:
    """``EventBus`` facade that injects ``spec``'s faults into ``read``.

    Everything else (``publish``, ``replay``, offsets, ``health`` …)
    delegates to the wrapped bus untouched.  ``stats`` counts what was
    actually injected so tests and the chaos benchmark can assert the
    run exercised every fault class rather than silently passing on a
    calm draw.
    """

    def __init__(self, inner: EventBus, spec: ChaosSpec):
        self.inner = inner
        self.spec = spec
        self._held: List[Event] = []     # reordered, awaiting release
        self._read_calls = 0
        self._released_until = -1        # highest seq delivered in order
        self.stats: Dict[str, int] = {
            "drops": 0, "duplicates": 0, "reorders": 0,
            "corruptions": 0, "read_failures": 0,
        }

    # -- the one corrupted surface -------------------------------------
    def read(self, consumer: str,
             max_events: Optional[int] = None) -> List[Event]:
        spec = self.spec
        self._read_calls += 1
        if _unit(spec.seed, _TAG_READ, self._read_calls) \
                < spec.read_failure_prob:
            # Raised BEFORE consuming: the inner offset is untouched, so
            # a retry (``read_with_retry``) re-reads the same window.
            self.stats["read_failures"] += 1
            raise BusReadError(
                f"chaos: transient read failure (call {self._read_calls})")

        fresh = self.inner.read(consumer, max_events)
        out: List[Event] = []
        for ev in fresh:
            s = int(ev.seq)
            self._released_until = max(self._released_until, s)
            if _unit(spec.seed, _TAG_DROP, s) < spec.drop_prob:
                self.stats["drops"] += 1
                continue
            if _unit(spec.seed, _TAG_REORDER, s) < spec.reorder_prob:
                self.stats["reorders"] += 1
                self._held.append(ev)
                continue
            out.extend(self._deliver(ev))
        # Release held-back events whose delay has elapsed — AFTER the
        # fresh batch, i.e. behind newer seqs: a genuine reordering.
        still: List[Event] = []
        for ev in self._held:
            if int(ev.seq) + spec.reorder_delay <= self._released_until:
                out.extend(self._deliver(ev))
            else:
                still.append(ev)
        self._held = still
        return out

    def _deliver(self, ev: Event) -> List[Event]:
        """Apply corruption/duplication to one surviving event."""
        spec = self.spec
        s = int(ev.seq)
        if _unit(spec.seed, _TAG_CORRUPT, s) < spec.corrupt_prob:
            self.stats["corruptions"] += 1
            ev = self._corrupt(ev)
        if _unit(spec.seed, _TAG_DUP, s) < spec.duplicate_prob:
            self.stats["duplicates"] += 1
            return [ev, ev]
        return [ev]

    def _corrupt(self, ev: Event) -> Event:
        """Mangle the delivered copy so ``validate_event`` rejects it.
        The good copy is gone (realistic transport corruption) — the
        twin must heal through quarantine + gap-triggered resync."""
        mode = int(_unit(self.spec.seed, _TAG_MODE, int(ev.seq)) * 4)
        if mode == 0:
            return dataclasses.replace(ev, time=float("nan"))
        if mode == 1:
            return dataclasses.replace(ev, job_id=10 ** 9)
        if mode == 2:
            return dataclasses.replace(ev, kind=99)  # unknown kind
        return dataclasses.replace(
            ev, payload={k: float("inf") for k in ev.payload} or
            {"nodes": -1.0})

    # -- everything else is the real bus -------------------------------
    def __getattr__(self, name: str):
        return getattr(self.inner, name)

    def __len__(self) -> int:
        return len(self.inner)
