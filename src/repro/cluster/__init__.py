"""Physical-system side: PBS-like cluster emulator, workloads, failures."""
from repro.cluster.workload import (JobSpec, bursty_trace,
                                    paper_synthetic_trace, poisson_trace,
                                    arch_job_mix, trace_to_arrays)
from repro.cluster.emulator import ClusterEmulator, RunReport

__all__ = [
    "JobSpec", "paper_synthetic_trace", "poisson_trace", "bursty_trace",
    "arch_job_mix", "trace_to_arrays",
    "ClusterEmulator", "RunReport",
]
