"""Physical-system side: PBS-like cluster emulator, workloads, failures."""
from repro.cluster.workload import (JobSpec, ScenarioSet, bursty_trace,
                                    make_scenario, paper_synthetic_trace,
                                    poisson_trace, arch_job_mix,
                                    stack_scenarios, trace_to_arrays)
from repro.cluster.emulator import ClusterEmulator, FailureSpec, RunReport
from repro.cluster.chaos import (ChaosBus, ChaosSpec, DEFAULT_PROFILE,
                                 failure_storm)

__all__ = [
    "JobSpec", "paper_synthetic_trace", "poisson_trace", "bursty_trace",
    "arch_job_mix", "trace_to_arrays",
    "ScenarioSet", "stack_scenarios", "make_scenario",
    "ClusterEmulator", "FailureSpec", "RunReport",
    "ChaosBus", "ChaosSpec", "DEFAULT_PROFILE", "failure_storm",
]
