"""Config module for --arch (see registry for the source entry)."""
from repro.configs.registry import GRANITE_3_2B as CONFIG

__all__ = ["CONFIG"]
