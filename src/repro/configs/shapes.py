"""Assigned input-shape cells (identical for every LM-family arch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
KV cache of ``seq_len``); the others lower ``train_step`` /
``prefill``.  ``long_500k`` requires sub-quadratic attention and is
skipped (recorded, not compiled) for pure full-attention archs — see
DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def cell_applicable(shape_name: str, supports_long_context: bool) -> bool:
    if shape_name == "long_500k":
        return supports_long_context
    return True
