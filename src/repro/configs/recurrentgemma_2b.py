"""Config module for --arch (see registry for the source entry)."""
from repro.configs.registry import RECURRENTGEMMA_2B as CONFIG

__all__ = ["CONFIG"]
