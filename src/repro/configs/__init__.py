"""Config package."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, reduced
from repro.configs.registry import (ARCHS, ARCH_ORDER, get_config,
                                    get_smoke_config)
from repro.configs.shapes import SHAPES, SHAPE_ORDER, ShapeCell, cell_applicable

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "reduced", "ARCHS",
           "ARCH_ORDER", "get_config", "get_smoke_config", "SHAPES",
           "SHAPE_ORDER", "ShapeCell", "cell_applicable"]
