"""Config module for --arch (see registry for the source entry)."""
from repro.configs.registry import WHISPER_SMALL as CONFIG

__all__ = ["CONFIG"]
