"""Architecture/config schema.

One ``ModelConfig`` instance per assigned architecture (see sibling
modules), plus ``reduced()`` variants for CPU smoke tests.  Shape cells
(train_4k / prefill_32k / decode_32k / long_500k) live in ``shapes.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0          # per-expert intermediate size
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2
    first_dense_layers: int = 0   # leading dense layers (DeepSeek V2)
    d_ff_dense: int = 0           # intermediate size of those dense layers
    # "gather": index-permutation dispatch (§Perf H3, default);
    # "gshard": one-hot einsum dispatch (paper-era baseline, kept for
    # the ablation benchmark + as the oracle in tests).
    dispatch: str = "gather"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 = no query compression (V2-Lite)
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # -- MoE / MLA -----------------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # -- hybrid (RecurrentGemma) ----------------------------------------
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru","rglru","local")
    local_window: int = 0
    rnn_width: int = 0            # RG-LRU recurrent width (0 -> d_model)
    conv_width: int = 4
    # -- RWKV ------------------------------------------------------------
    rwkv_head_size: int = 64
    # -- encoder-decoder (Whisper) ----------------------------------------
    n_encoder_layers: int = 0
    encoder_seq_ratio: float = 1.0  # S_enc = ratio * S_dec (stub frontend)
    # -- VLM stub ----------------------------------------------------------
    n_patches: int = 0            # prepended patch embeddings per sample
    # -- runtime ------------------------------------------------------------
    use_scan: bool = True
    remat: bool = True
    q_block: int = 512
    logit_chunk: int = 1024
    accum_steps: int = 1          # gradient-accumulation microbatches
    # roofline bookkeeping: sub-quadratic context support
    supports_long_context: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def validate(self) -> "ModelConfig":
        assert self.n_kv_heads == 0 or self.n_heads % self.n_kv_heads == 0
        if self.family == "encdec":
            assert self.n_encoder_layers > 0
        if self.family == "moe":
            assert self.moe is not None
        if self.block_pattern:
            assert self.local_window > 0
        return self


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant of the same family: tiny widths/layers, small
    vocab, few experts — runs a real forward/train step on CPU."""
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe, n_experts=8, top_k=min(moe.top_k, 2),
            d_ff_expert=64, d_ff_dense=128,
            first_dense_layers=min(moe.first_dense_layers, 1))
    mla = cfg.mla
    if mla is not None:
        mla = dataclasses.replace(mla, kv_lora_rank=32, qk_nope_dim=16,
                                  qk_rope_dim=8, v_head_dim=16)
    n_layers = min(cfg.n_layers, len(cfg.block_pattern) + 2
                   if cfg.block_pattern else 2)
    if cfg.block_pattern:
        n_layers = len(cfg.block_pattern) + 1  # one full pattern + remainder
    base = dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        moe=moe,
        mla=mla,
        rnn_width=64 if cfg.rnn_width else 0,
        local_window=min(cfg.local_window, 16) if cfg.local_window else 0,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
        n_patches=8 if cfg.n_patches else 0,
        rwkv_head_size=16,
        q_block=16,
        logit_chunk=32,
        accum_steps=1,
    )
    return dataclasses.replace(base, **overrides).validate()
