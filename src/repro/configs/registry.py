"""Architecture registry — exact assigned configs.

Sources are public literature/HF configs; see per-entry comments.
Select with ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, reduced

# ---------------------------------------------------------------------------
# Dense llama-family
# ---------------------------------------------------------------------------

GRANITE_20B = ModelConfig(                       # [arXiv:2405.04324; hf]
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,   # MQA
    d_ff=24576, vocab_size=49152, head_dim=128,
    accum_steps=8,
).validate()

GRANITE_3_2B = ModelConfig(      # [hf:ibm-granite/granite-3.0-2b-base]
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,   # GQA
    d_ff=8192, vocab_size=49155, head_dim=64,
    accum_steps=4,
).validate()

LLAMA3_2_1B = ModelConfig(         # [hf:meta-llama/Llama-3.2-1B; unverified]
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab_size=128256, head_dim=64,
    rope_theta=500000.0, tie_embeddings=True,
    accum_steps=4,
).validate()

QWEN2_72B = ModelConfig(                         # [arXiv:2407.10671; hf]
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064, head_dim=128,
    qkv_bias=True,                               # Qwen2 QKV bias
    rope_theta=1000000.0,
    accum_steps=8,   # microbatch 32 divides the multi-pod DP axes (2x16)
).validate()

# ---------------------------------------------------------------------------
# VLM — InternViT frontend is a STUB (precomputed patch embeddings);
# backbone is the InternLM2-76B decoder.          [arXiv:2404.16821]
# ---------------------------------------------------------------------------

INTERNVL2_76B = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    n_patches=256,
    accum_steps=8,   # microbatch 32 divides the multi-pod DP axes (2x16)
).validate()

# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

DEEPSEEK_V2_LITE_16B = ModelConfig(              # [arXiv:2405.04434; hf]
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408,                                    # routed-expert intermediate
    vocab_size=102400,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    # 64 routed experts top-6 + 2 shared (HF V2-Lite config; the
    # assignment's "160 routed" is full V2 — see DESIGN.md §7).
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
                  first_dense_layers=1, d_ff_dense=10944),
    accum_steps=8,
).validate()

OLMOE_1B_7B = ModelConfig(                       # [arXiv:2409.02060; hf]
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304,
    moe=MoEConfig(n_experts=64, top_k=8, n_shared=0, d_ff_expert=1024),
    accum_steps=4,
).validate()

# ---------------------------------------------------------------------------
# SSM / hybrid — sub-quadratic: these run long_500k
# ---------------------------------------------------------------------------

RWKV6_7B = ModelConfig(                          # [arXiv:2404.05892; hf]
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=0,   # attn-free
    d_ff=14336, vocab_size=65536,
    rwkv_head_size=64,
    supports_long_context=True,
    accum_steps=8,
).validate()

RECURRENTGEMMA_2B = ModelConfig(                 # [arXiv:2402.19427; hf]
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,   # MQA local attn
    d_ff=7680, vocab_size=256000, head_dim=256,
    block_pattern=("rglru", "rglru", "local"),             # 1:2 attn:rglru
    local_window=2048, rnn_width=2560, conv_width=4,
    supports_long_context=True,
    accum_steps=4,
).validate()

# ---------------------------------------------------------------------------
# Audio enc-dec — conv frontend is a STUB (precomputed frame embeddings)
# ---------------------------------------------------------------------------

WHISPER_SMALL = ModelConfig(                     # [arXiv:2212.04356]
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    n_encoder_layers=12, encoder_seq_ratio=1.0,
    accum_steps=2,
).validate()


ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in (
        GRANITE_20B, GRANITE_3_2B, LLAMA3_2_1B, QWEN2_72B, INTERNVL2_76B,
        DEEPSEEK_V2_LITE_16B, OLMOE_1B_7B, RWKV6_7B, RECURRENTGEMMA_2B,
        WHISPER_SMALL,
    )
}

ARCH_ORDER = tuple(ARCHS)


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {list(ARCHS)}")
    return ARCHS[arch]


def get_smoke_config(arch: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch), **overrides)
