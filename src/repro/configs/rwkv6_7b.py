"""Config module for --arch (see registry for the source entry)."""
from repro.configs.registry import RWKV6_7B as CONFIG

__all__ = ["CONFIG"]
