"""The paper's own configuration (§4.1): cluster, policy pool, score."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

from repro.core.engine import DrainEngine
from repro.core.objective import Objective, resolve_goal
from repro.core.policies import (EXTENDED_POOL, PAPER_POOL, PolicyPool,
                                 normalize_pool)
from repro.core.scoring import ScoreWeights

#: DRAS-style 25-point sweep (5x5 grid over the WFP exponent and the
#: aging timescale) riding alongside the 7 static specs -> k=32 forks
#: in ONE batched drain.  Also the acceptance benchmark's pool
#: (benchmarks/overhead.py "dras_sweep") and a ``--pool`` value for
#: ``repro.launch.twin_loop``.
DRAS_SWEEP_POOL = "extended,wfp:a=1..5x5:tau=600..7200x5"


@dataclasses.dataclass(frozen=True)
class TwinConfig:
    total_nodes: int = 32             # 32-node PBS cluster (CloudLab)
    max_jobs: int = 256
    # Candidate pool: a tuple of legacy policy ids (lifted to their
    # parametric fixed points) or a sweep-grammar string such as
    # ``"paper"`` or ``DRAS_SWEEP_POOL`` (see policies.parse_pool).
    pool: Union[str, Tuple[int, ...]] = tuple(PAPER_POOL)  # WFP, FCFS, SJF
    # The administrator-configured optimization goal (§3.4; DESIGN.md
    # §8): an objective-grammar string ("score", "avg_wait",
    # "min:avg_wait@util>=0.85", ...) or an ``objective.Objective``.
    # "score" is the paper's 4-term score, bit-identical to the
    # pre-objective ScoreWeights path.
    objective: Union[str, Objective] = "score"
    # DEPRECATED: legacy goal spelling.  When set, it lifts to the
    # bit-identical paper-score objective (with a DeprecationWarning)
    # and must not be combined with a non-default ``objective``.
    weights: Optional[ScoreWeights] = None
    ensemble: int = 1                 # >1 -> uncertainty ensemble (beyond)
    ensemble_noise: float = 0.3
    trace_seed: int = 0
    accuracy: Tuple[float, float] = (0.5, 1.0)     # true/estimated runtime
    # What-if engine: scheduling-pass backend ("reference" = pure-JAX
    # oracle, "pallas" = the TPU kernel, "auto" = reference on CPU /
    # pallas on TPU — interpret-mode pallas is ~2.3x slower than
    # reference on CPU, BENCH_overhead.json) and Pallas interpret
    # override (None auto-detects: interpret on CPU, compiled on TPU).
    backend: str = "auto"
    interpret: Optional[bool] = None

    def make_engine(self) -> DrainEngine:
        """The policy-batched drain engine this config selects."""
        return DrainEngine(backend=self.backend, interpret=self.interpret)

    def make_pool(self) -> PolicyPool:
        """The parametric candidate pool this config describes."""
        return normalize_pool(self.pool)

    def make_objective(self) -> Objective:
        """The resolved optimization goal (legacy ``weights`` lifted)."""
        if self.weights is not None and self.objective == "score":
            return resolve_goal(None, self.weights)   # legacy spelling
        return resolve_goal(self.objective, self.weights)


PAPER_TWIN = TwinConfig()
EXTENDED_TWIN = TwinConfig(pool=tuple(EXTENDED_POOL))
PALLAS_TWIN = TwinConfig(backend="pallas")
SWEEP_TWIN = TwinConfig(pool=DRAS_SWEEP_POOL)


@dataclasses.dataclass(frozen=True)
class ReplayGridConfig:
    """A (scenario × policy) baseline grid for the replay engine
    (DESIGN.md §6): S traces of one workload family × the candidate
    pool, evaluated as ONE device computation
    (``engine.replay_grid``).  Used by ``twin_loop --replay-grid`` and
    ``benchmarks/baseline_sweep.py``."""

    scenarios: int = 8
    trace: str = "poisson"            # poisson | bursty | paper
    n_jobs: int = 48
    total_nodes: int = 32
    mean_gap: float = 8.0
    node_range: Tuple[int, int] = (1, 16)
    walltime_range: Tuple[float, float] = (30.0, 900.0)
    pool: Union[str, Tuple[int, ...]] = tuple(EXTENDED_POOL)   # P=7
    # Goal for the grid's per-scenario selection (``ReplayOutcome.best``)
    objective: Union[str, Objective] = "score"
    seed: int = 0
    backend: str = "auto"
    interpret: Optional[bool] = None

    def make_engine(self) -> DrainEngine:
        return DrainEngine(backend=self.backend, interpret=self.interpret)

    def make_pool(self) -> PolicyPool:
        return normalize_pool(self.pool)

    def make_objective(self) -> Objective:
        return resolve_goal(self.objective)

    def make_traces(self):
        """One trace per scenario: the same family, consecutive seeds —
        the 'many what-if futures' axis."""
        from repro.cluster.workload import (bursty_trace,
                                            paper_synthetic_trace,
                                            poisson_trace)
        traces = []
        for s in range(self.scenarios):
            seed = self.seed + s
            if self.trace == "paper":
                traces.append(paper_synthetic_trace(seed=seed))
            elif self.trace == "bursty":
                traces.append(bursty_trace(
                    self.n_jobs, self.total_nodes, self.mean_gap,
                    self.node_range, self.walltime_range, seed=seed))
            elif self.trace == "poisson":
                traces.append(poisson_trace(
                    self.n_jobs, self.total_nodes, self.mean_gap,
                    self.node_range, self.walltime_range, seed=seed))
            else:
                raise ValueError(f"unknown trace family {self.trace!r}")
        return traces

    def make_scenarios(self):
        """The stacked, padded ``workload.ScenarioSet``."""
        from repro.cluster.workload import stack_scenarios
        return stack_scenarios(self.make_traces(), self.total_nodes)


REPLAY_GRID = ReplayGridConfig()
