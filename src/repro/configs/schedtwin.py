"""The paper's own configuration (§4.1): cluster, policy pool, score."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core.engine import DrainEngine
from repro.core.policies import EXTENDED_POOL, PAPER_POOL
from repro.core.scoring import PAPER_WEIGHTS, ScoreWeights


@dataclasses.dataclass(frozen=True)
class TwinConfig:
    total_nodes: int = 32             # 32-node PBS cluster (CloudLab)
    max_jobs: int = 256
    pool: Tuple[int, ...] = tuple(PAPER_POOL)      # WFP, FCFS, SJF
    weights: ScoreWeights = PAPER_WEIGHTS          # 0.25 * each term
    ensemble: int = 1                 # >1 -> uncertainty ensemble (beyond)
    ensemble_noise: float = 0.3
    trace_seed: int = 0
    accuracy: Tuple[float, float] = (0.5, 1.0)     # true/estimated runtime
    # What-if engine: scheduling-pass backend ("reference" = pure-JAX
    # oracle, "pallas" = the TPU kernel) and Pallas interpret override
    # (None auto-detects: interpret on CPU, compiled on TPU).
    backend: str = "reference"
    interpret: Optional[bool] = None

    def make_engine(self) -> DrainEngine:
        """The policy-batched drain engine this config selects."""
        return DrainEngine(backend=self.backend, interpret=self.interpret)


PAPER_TWIN = TwinConfig()
EXTENDED_TWIN = TwinConfig(pool=tuple(EXTENDED_POOL))
PALLAS_TWIN = TwinConfig(backend="pallas")
