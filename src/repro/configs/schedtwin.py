"""The paper's own configuration (§4.1): cluster, policy pool, score."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

from repro.core.engine import DrainEngine
from repro.core.policies import (EXTENDED_POOL, PAPER_POOL, PolicyPool,
                                 normalize_pool)
from repro.core.scoring import PAPER_WEIGHTS, ScoreWeights

#: DRAS-style 25-point sweep (5x5 grid over the WFP exponent and the
#: aging timescale) riding alongside the 7 static specs -> k=32 forks
#: in ONE batched drain.  Also the acceptance benchmark's pool
#: (benchmarks/overhead.py "dras_sweep") and a ``--pool`` value for
#: ``repro.launch.twin_loop``.
DRAS_SWEEP_POOL = "extended,wfp:a=1..5x5:tau=600..7200x5"


@dataclasses.dataclass(frozen=True)
class TwinConfig:
    total_nodes: int = 32             # 32-node PBS cluster (CloudLab)
    max_jobs: int = 256
    # Candidate pool: a tuple of legacy policy ids (lifted to their
    # parametric fixed points) or a sweep-grammar string such as
    # ``"paper"`` or ``DRAS_SWEEP_POOL`` (see policies.parse_pool).
    pool: Union[str, Tuple[int, ...]] = tuple(PAPER_POOL)  # WFP, FCFS, SJF
    weights: ScoreWeights = PAPER_WEIGHTS          # 0.25 * each term
    ensemble: int = 1                 # >1 -> uncertainty ensemble (beyond)
    ensemble_noise: float = 0.3
    trace_seed: int = 0
    accuracy: Tuple[float, float] = (0.5, 1.0)     # true/estimated runtime
    # What-if engine: scheduling-pass backend ("reference" = pure-JAX
    # oracle, "pallas" = the TPU kernel, "auto" = reference on CPU /
    # pallas on TPU — interpret-mode pallas is ~2.3x slower than
    # reference on CPU, BENCH_overhead.json) and Pallas interpret
    # override (None auto-detects: interpret on CPU, compiled on TPU).
    backend: str = "auto"
    interpret: Optional[bool] = None

    def make_engine(self) -> DrainEngine:
        """The policy-batched drain engine this config selects."""
        return DrainEngine(backend=self.backend, interpret=self.interpret)

    def make_pool(self) -> PolicyPool:
        """The parametric candidate pool this config describes."""
        return normalize_pool(self.pool)


PAPER_TWIN = TwinConfig()
EXTENDED_TWIN = TwinConfig(pool=tuple(EXTENDED_POOL))
PALLAS_TWIN = TwinConfig(backend="pallas")
SWEEP_TWIN = TwinConfig(pool=DRAS_SWEEP_POOL)
