"""Config module for --arch (see registry for the source entry)."""
from repro.configs.registry import QWEN2_72B as CONFIG

__all__ = ["CONFIG"]
