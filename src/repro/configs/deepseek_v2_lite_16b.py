"""Config module for --arch (see registry for the source entry)."""
from repro.configs.registry import DEEPSEEK_V2_LITE_16B as CONFIG

__all__ = ["CONFIG"]
