"""Config module for --arch (see registry for the source entry)."""
from repro.configs.registry import OLMOE_1B_7B as CONFIG

__all__ = ["CONFIG"]
