"""Config module for --arch (see registry for the source entry)."""
from repro.configs.registry import LLAMA3_2_1B as CONFIG

__all__ = ["CONFIG"]
