"""Config module for --arch (see registry for the source entry)."""
from repro.configs.registry import INTERNVL2_76B as CONFIG

__all__ = ["CONFIG"]
