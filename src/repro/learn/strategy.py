"""Population-search strategies behind one ``Strategy`` protocol.

Both strategies are *selection/recombination* methods: they only need a
fitness ORDERING over candidates, so they compose with every
``core.objective`` goal — including rank-based lexicographic and
constrained goals whose costs are pool-relative composed ranks.

Key discipline (mirrors ``core/fan.py``): every draw is keyed

    fold_in(fold_in(PRNGKey(seed), generation), candidate)

so populations are deterministic, resumable from ``(seed, gen)`` alone
(no RNG state lives in checkpoints), and *prefix-stable*: the first N
candidates of a population of M > N are bitwise the candidates of the
population of N. Antithetic pairing keeps the property — candidates
(2j, 2j+1) share draw j with opposite signs.

Fitness is COST (lower is better). Non-finite fitness (deadlocked
rollouts score +inf) is ranked strictly worst.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Protocol, Tuple, runtime_checkable

import jax
import numpy as np


class StrategyState(NamedTuple):
    """Search state: per-dim mean/scale in the free search space."""

    mean: np.ndarray   # (D,) float32
    sigma: np.ndarray  # (D,) float32
    gen: int           # generation counter — drives the draw key chain


@runtime_checkable
class Strategy(Protocol):
    """ask/tell protocol over a D-dim continuous search space."""

    population: int

    def init(self, mean: np.ndarray, sigma: np.ndarray) -> StrategyState:
        ...

    def ask(self, state: StrategyState) -> np.ndarray:
        """Return (population, D) candidate points for ``state.gen``."""
        ...

    def tell(self, state: StrategyState, candidates: np.ndarray,
             fitness: np.ndarray) -> StrategyState:
        """Consume per-candidate costs; return the next-generation state."""
        ...


def _as_state(mean: np.ndarray, sigma: np.ndarray, gen: int) -> StrategyState:
    mean = np.asarray(mean, np.float32).reshape(-1)
    sigma = np.asarray(sigma, np.float32).reshape(-1)
    if mean.shape != sigma.shape:
        raise ValueError(f"mean/sigma shape mismatch: {mean.shape} vs {sigma.shape}")
    return StrategyState(mean=mean, sigma=sigma, gen=int(gen))


def draw_eps(seed: int, gen: int, population: int, dim: int,
             antithetic: bool) -> np.ndarray:
    """(population, dim) standard-normal perturbations, prefix-stable.

    Candidate i's draw is keyed on fold_in(fold_in(key(seed), gen), j)
    where j = i//2 under antithetic pairing (odd i negates), j = i
    otherwise — so growing the population appends rows without
    changing existing ones.
    """
    base = jax.random.fold_in(jax.random.PRNGKey(seed), gen)
    out = np.empty((population, dim), np.float32)
    for i in range(population):
        j, sign = (i // 2, 1.0 if i % 2 == 0 else -1.0) if antithetic else (i, 1.0)
        eps = jax.random.normal(jax.random.fold_in(base, j), (dim,), np.float32)
        out[i] = sign * np.asarray(eps, np.float32)
    return out


def rank_fitness(fitness: np.ndarray) -> np.ndarray:
    """Ordinal ranks of costs, 0 = best; non-finite ranked worst.

    Ties (and all-inf populations) break by candidate index, so the
    result is deterministic for any input.
    """
    f = np.asarray(fitness, np.float64).copy()
    bad = ~np.isfinite(f)
    f[bad] = np.inf
    order = np.argsort(f, kind="stable")
    ranks = np.empty(len(f), np.int64)
    ranks[order] = np.arange(len(f))
    return ranks


def centered_rank_utilities(fitness: np.ndarray) -> np.ndarray:
    """Rank-shaped utilities in [-0.5, 0.5]; best candidate gets +0.5.

    Invariant to monotone transforms of the costs, which makes ES steps
    meaningful even for pool-relative rank-based objectives.
    """
    n = len(fitness)
    if n <= 1:
        return np.zeros(n, np.float32)
    ranks = rank_fitness(fitness)
    return ((n - 1 - ranks) / (n - 1) - 0.5).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class ES:
    """OpenAI-style evolution strategy (rank-shaped, antithetic pairs).

    ask: candidates = mean + sigma * eps with eps from ``draw_eps``.
    tell: mean += lr * sigma * (2/N) Σ u_i eps_i  (u = centered ranks),
    then sigma *= sigma_decay. Minimizes cost.
    """

    population: int = 16
    seed: int = 0
    lr: float = 1.0
    antithetic: bool = True
    sigma_decay: float = 1.0

    def init(self, mean: np.ndarray, sigma: np.ndarray) -> StrategyState:
        return _as_state(mean, sigma, 0)

    def ask(self, state: StrategyState) -> np.ndarray:
        eps = draw_eps(self.seed, state.gen, self.population,
                       state.mean.shape[0], self.antithetic)
        return (state.mean[None, :] + state.sigma[None, :] * eps).astype(np.float32)

    def tell(self, state: StrategyState, candidates: np.ndarray,
             fitness: np.ndarray) -> StrategyState:
        candidates = np.asarray(candidates, np.float32)
        if candidates.shape[0] != self.population:
            raise ValueError(
                f"tell() got {candidates.shape[0]} candidates, expected {self.population}")
        u = centered_rank_utilities(fitness)
        sigma = np.maximum(state.sigma, 1e-8)
        eps = (candidates - state.mean[None, :]) / sigma[None, :]
        grad = (2.0 / self.population) * (u[:, None] * eps).sum(axis=0)
        mean = state.mean + np.float32(self.lr) * state.sigma * grad.astype(np.float32)
        new_sigma = (state.sigma * np.float32(self.sigma_decay)).astype(np.float32)
        return _as_state(mean, new_sigma, state.gen + 1)


@dataclasses.dataclass(frozen=True)
class CEM:
    """Cross-entropy method: refit mean/sigma on the elite fraction.

    Pure selection — depends only on the fitness ordering, so it is the
    safe default for rank-based goals and rugged landscapes.
    """

    population: int = 16
    seed: int = 0
    elite_frac: float = 0.25
    antithetic: bool = True
    sigma_floor: float = 1e-3
    momentum: float = 1.0  # 1.0 = full refit toward the elites

    def elite_count(self) -> int:
        return max(1, min(self.population, int(round(self.elite_frac * self.population))))

    def init(self, mean: np.ndarray, sigma: np.ndarray) -> StrategyState:
        return _as_state(mean, sigma, 0)

    def ask(self, state: StrategyState) -> np.ndarray:
        eps = draw_eps(self.seed, state.gen, self.population,
                       state.mean.shape[0], self.antithetic)
        return (state.mean[None, :] + state.sigma[None, :] * eps).astype(np.float32)

    def tell(self, state: StrategyState, candidates: np.ndarray,
             fitness: np.ndarray) -> StrategyState:
        candidates = np.asarray(candidates, np.float32)
        if candidates.shape[0] != self.population:
            raise ValueError(
                f"tell() got {candidates.shape[0]} candidates, expected {self.population}")
        ranks = rank_fitness(fitness)
        elites = candidates[ranks < self.elite_count()]
        m = np.float32(self.momentum)
        new_mean = elites.mean(axis=0).astype(np.float32)
        new_sigma = np.maximum(elites.std(axis=0), np.float32(self.sigma_floor)).astype(np.float32)
        mean = ((1.0 - m) * state.mean + m * new_mean).astype(np.float32)
        sigma = ((1.0 - m) * state.sigma + m * new_sigma).astype(np.float32)
        return _as_state(mean, sigma, state.gen + 1)
