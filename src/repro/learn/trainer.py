"""The population trainer: close the θ loop on-device (DESIGN.md §13).

Each generation, N candidate θ (plus, at gen 0, the family's static
fixed points as warm-start rows) ride the FORK axis of ONE jitted
``engine.generation_costs`` grid over S training scenarios — the
strategy never sees a rollout, only the (S, N) cost table.  Model
selection is a separate concern from search: the deployed θ is the
best candidate EVER seen on the held-out scenarios, the strategy is
told only training fitness, and early stopping fires when held-out
stops improving.

Pool-relative goals: lexicographic / constrained objectives cost
composed RANKS within the evaluated pool, which is exactly the
ordering selection strategies need — but such costs are not comparable
across different pools.  The trainer therefore always appends the
current incumbent θ to the held-out evaluation pool and compares
WITHIN one grid; absolute-cost curve fields are meaningful when
``goal.elementwise`` (true for all plain/weighted/distributional
goals) and pool-relative otherwise.

Checkpoints (``checkpoint/manager.py``) hold the strategy state, the
incumbent θ, and the full history; all randomness is keyed on
``(seed, generation)`` (``strategy.draw_eps``), so resume needs no RNG
state and a resumed run is bitwise the uninterrupted one.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint.manager import ARRAYS, MANIFEST, CheckpointManager, step_dir
from repro.core import policies
from repro.core.objective import resolve_goal
from repro.core.policies import (FAMILY_NAMES, N_THETA, PolicyPool,
                                 describe_spec, theta_pool)
from repro.learn.evolution import ParamSpace, family_space, static_seeds
from repro.learn.strategy import CEM, ES, StrategyState

#: name under which trainer metadata rides a checkpoint manifest
EXTRA_KEY = "learn"


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Hyperparameters of one training run (JSON-safe by design)."""

    family: str = "lin"            # "lin" | "wfp" | "expf"
    strategy: str = "cem"          # "cem" | "es"
    population: int = 16
    generations: int = 24
    objective: str = "score"       # objective grammar (or Objective)
    seed: int = 0
    sigma_scale: float = 1.0       # scales the space's default sigma0
    lr: float = 1.0                # ES step size
    sigma_decay: float = 1.0       # ES per-gen sigma shrink
    elite_frac: float = 0.25       # CEM elite fraction
    antithetic: bool = True        # paired ±eps draws (variance reduction)
    warm_start: bool = True        # inject static fixed points at gen 0
    fan: Any = None                # FanSpec: domain-randomize training traces
    patience: int = 6              # held-out early stop (0 = off)

    def make_strategy(self):
        kind = self.strategy.strip().lower()
        if kind == "es":
            return ES(population=self.population, seed=self.seed,
                      lr=self.lr, antithetic=self.antithetic,
                      sigma_decay=self.sigma_decay)
        if kind == "cem":
            return CEM(population=self.population, seed=self.seed,
                       elite_frac=self.elite_frac,
                       antithetic=self.antithetic)
        raise ValueError(f"unknown strategy {self.strategy!r}; "
                         f"have 'cem', 'es'")


@dataclasses.dataclass
class TrainResult:
    """Outcome of ``train``: the deployable incumbent + its audit trail."""

    pool: PolicyPool               # k=1 pool of the incumbent θ
    theta: np.ndarray              # (N_THETA,) incumbent
    family: str
    label: str                     # pool display name
    best_heldout: float            # incumbent held-out cost (see module doc)
    best_train: float
    best_desc: str                 # describe_spec of the incumbent
    history: List[Dict[str, Any]]  # one record per generation
    generations_run: int
    stopped_early: bool
    checkpoint_dir: Optional[str]


def _aggregate(costs: np.ndarray) -> np.ndarray:
    """(S, P) per-scenario costs -> (P,) fitness: mean over scenarios
    in float64 (deadlocked rollouts are +inf and propagate)."""
    return np.asarray(costs, np.float64).mean(axis=0)


def _gen0_extras(space: ParamSpace, config: TrainConfig
                 ) -> Tuple[List[str], List[np.ndarray]]:
    """Warm-start rows riding the gen-0 grid (never given to tell()):
    the family's static fixed points, plus the search-space origin
    ``x0`` as the explicit "init" baseline the learning curve and the
    improvement gate measure against."""
    names: List[str] = ["init"]
    thetas: List[np.ndarray] = [
        space.decode(np.asarray(space.x0, np.float32)[None, :])[0]]
    if config.warm_start:
        for name, th in static_seeds(space.family):
            names.append(name)
            thetas.append(th)
    return names, thetas


def train(train_scenarios, heldout_scenarios, config: TrainConfig, *,
          engine=None, eval_fn: Optional[Callable] = None,
          checkpoint_dir: Optional[str] = None, checkpoint_every: int = 1,
          resume: bool = False,
          log_fn: Optional[Callable[[str], None]] = None) -> TrainResult:
    """Run the ES/CEM loop; returns the held-out incumbent.

    ``eval_fn(scenarios, pool_spec) -> (S, P) costs`` overrides the
    generation evaluator — pass ``whatif.sharded_generation_costs(...)``
    for fleet-scale training; the default is the one-shot
    ``engine.generation_costs`` with ``config.fan`` riding along.
    ``resume=True`` continues from the latest checkpoint under
    ``checkpoint_dir`` (bitwise the uninterrupted run).
    """
    goal = resolve_goal(config.objective)
    space = family_space(config.family)
    family_name = FAMILY_NAMES[space.family]
    strat = config.make_strategy()
    say = log_fn or (lambda msg: None)

    if eval_fn is None:
        from repro.core.engine import DEFAULT_ENGINE
        eng = engine or DEFAULT_ENGINE
        eval_fn = lambda scen, pool: eng.generation_costs(
            scen, pool, goal, config.fan)

    sigma0 = np.asarray(space.sigma0, np.float32) * np.float32(config.sigma_scale)
    state = strat.init(np.asarray(space.x0, np.float32), sigma0)
    history: List[Dict[str, Any]] = []
    best_theta: Optional[np.ndarray] = None
    best_name = ""
    best_train = float("inf")
    best_heldout = float("inf")
    stall = 0
    start_gen = 0
    train_curve_floor = float("inf")  # running min -> monotone curves
    cand_curve_floor = float("inf")   # candidates only (search progress)

    manager = CheckpointManager(checkpoint_dir) if checkpoint_dir else None
    if resume:
        if manager is None:
            raise ValueError("resume=True needs a checkpoint_dir")
        restored = _restore(manager, space, config)
        if restored is not None:
            (state, best_theta, best_name, best_train, best_heldout,
             stall, start_gen, history) = restored
            train_curve_floor = min(
                [r["train_best_so_far"] for r in history] or [float("inf")])
            cand_curve_floor = min(
                [r["cand_best_so_far"] for r in history] or [float("inf")])
            say(f"resumed gen {start_gen} from {checkpoint_dir}")

    stopped_early = False
    last_saved = -1
    g = start_gen - 1   # if already at the generation budget (resume)
    for g in range(start_gen, config.generations):
        z = strat.ask(state)                       # (N, D) search points
        cand_thetas = space.decode(z)              # (N, N_THETA)
        extra_names: List[str] = []
        extra_thetas: List[np.ndarray] = []
        if g == 0:
            extra_names, extra_thetas = _gen0_extras(space, config)
        all_thetas = (np.concatenate([cand_thetas, np.stack(extra_thetas)])
                      if extra_thetas else cand_thetas)
        all_names = [f"cand{i}" for i in range(len(cand_thetas))] + extra_names

        grid_pool = theta_pool(space.family, all_thetas, all_names)
        fit = _aggregate(np.asarray(eval_fn(train_scenarios, grid_pool.spec)))

        # Held-out model selection: same candidates + the incumbent in
        # ONE grid, so the comparison is within-pool even for
        # rank-based goals.
        h_thetas, h_names = all_thetas, list(all_names)
        inc_col = None
        if best_theta is not None:
            h_thetas = np.concatenate([all_thetas, best_theta[None, :]])
            h_names = h_names + ["incumbent"]
            inc_col = len(h_names) - 1
        hfit = _aggregate(np.asarray(eval_fn(
            heldout_scenarios, theta_pool(space.family, h_thetas,
                                          h_names).spec)))
        cand_h = hfit[:len(all_thetas)]
        pick = int(np.argmin(np.where(np.isfinite(cand_h), cand_h, np.inf)))
        improved = bool(np.isfinite(cand_h[pick])) and (
            inc_col is None or bool(cand_h[pick] < hfit[inc_col]))
        if improved:
            best_theta = np.asarray(all_thetas[pick], np.float32).copy()
            best_name = all_names[pick]
            best_train = float(fit[pick])
            best_heldout = float(cand_h[pick])
            stall = 0
        else:
            best_heldout = float(hfit[inc_col]) if inc_col is not None \
                else best_heldout
            stall += 1

        state = strat.tell(state, z, fit[:strat.population])

        train_best = float(np.min(fit))
        cand_best = float(np.min(fit[:strat.population]))
        train_curve_floor = min(train_curve_floor, train_best)
        cand_curve_floor = min(cand_curve_floor, cand_best)
        finite = fit[:strat.population][np.isfinite(fit[:strat.population])]
        history.append({
            "gen": g,
            "train_best": train_best,
            "train_best_so_far": train_curve_floor,
            "cand_best": cand_best,
            "cand_best_so_far": cand_curve_floor,
            "train_mean": float(finite.mean()) if finite.size else float("inf"),
            "heldout_best": float(np.min(cand_h)),
            "incumbent_heldout": best_heldout,
            "incumbent": best_name,
            "improved": bool(improved),
            "sigma_mean": float(np.asarray(state.sigma, np.float64).mean()),
        })
        say(f"gen {g:3d}  train best {train_best:.6g}  "
            f"held-out incumbent {best_heldout:.6g} ({best_name})"
            f"{'  *' if improved else ''}")

        if manager is not None and checkpoint_every > 0 and (
                (g + 1) % checkpoint_every == 0
                or g + 1 == config.generations):
            _save(manager, g + 1, state, config, goal, family_name,
                  best_theta, best_name, best_train, best_heldout,
                  stall, history)
            last_saved = g + 1
        if config.patience > 0 and stall >= config.patience:
            stopped_early = True
            say(f"early stop: held-out flat for {stall} generations")
            break

    if best_theta is None:
        raise RuntimeError(
            "training produced no finite-cost candidate (every rollout "
            "deadlocked?) — check the traces fit the cluster")
    if manager is not None and checkpoint_every > 0 and last_saved != g + 1:
        _save(manager, g + 1, state, config, goal, family_name,
              best_theta, best_name, best_train, best_heldout, stall,
              history)

    label = f"trained[{family_name}]"
    desc = describe_spec(space.family, best_theta)
    return TrainResult(
        pool=theta_pool(space.family, best_theta[None, :], (label,)),
        theta=best_theta, family=family_name, label=label,
        best_heldout=best_heldout, best_train=best_train, best_desc=desc,
        history=history, generations_run=g + 1,
        stopped_early=stopped_early, checkpoint_dir=checkpoint_dir)


# ----------------------------------------------------------------------
# Checkpoint round-trip
# ----------------------------------------------------------------------

def _save(manager: CheckpointManager, step: int, state: StrategyState,
          config: TrainConfig, goal, family_name: str,
          best_theta: Optional[np.ndarray], best_name: str,
          best_train: float, best_heldout: float, stall: int,
          history: List[Dict[str, Any]]) -> None:
    tree = {
        "mean": np.asarray(state.mean, np.float32),
        "sigma": np.asarray(state.sigma, np.float32),
        "best_theta": (np.asarray(best_theta, np.float32)
                       if best_theta is not None
                       else np.zeros((N_THETA,), np.float32)),
    }
    cfg = dataclasses.asdict(config)
    cfg["objective"] = goal.spec
    cfg["fan"] = None if config.fan is None else repr(config.fan)
    extra = {EXTRA_KEY: {
        "version": 1,
        "family": family_name,
        "objective": goal.spec,
        "gen": step,
        "stall": stall,
        "has_best": best_theta is not None,
        "best_name": best_name,
        "best_desc": (describe_spec(policies._FAMILY_BY_NAME[family_name],
                                    best_theta)
                      if best_theta is not None else ""),
        "best_train": best_train,
        "best_heldout": best_heldout,
        "config": cfg,
        "history": history,
    }}
    json.dumps(extra)  # fail fast on non-JSON-safe state, not mid-save
    manager.save(step, tree, extra)


def _restore(manager: CheckpointManager, space: ParamSpace,
             config: TrainConfig):
    step = manager.latest_step()
    if step is None:
        return None
    target = {
        "mean": np.zeros((space.dim,), np.float32),
        "sigma": np.zeros((space.dim,), np.float32),
        "best_theta": np.zeros((N_THETA,), np.float32),
    }
    tree, extra = manager.restore(step, target)
    meta = extra.get(EXTRA_KEY)
    if not meta:
        raise ValueError(
            f"checkpoint step {step} has no {EXTRA_KEY!r} metadata — "
            f"not a trainer checkpoint")
    if meta["family"] != FAMILY_NAMES[space.family]:
        raise ValueError(
            f"checkpoint family {meta['family']!r} != configured "
            f"{FAMILY_NAMES[space.family]!r}")
    state = StrategyState(mean=np.asarray(tree["mean"], np.float32),
                          sigma=np.asarray(tree["sigma"], np.float32),
                          gen=int(meta["gen"]))
    best_theta = (np.asarray(tree["best_theta"], np.float32)
                  if meta.get("has_best") else None)
    return (state, best_theta, meta.get("best_name", ""),
            float(meta.get("best_train", float("inf"))),
            float(meta.get("best_heldout", float("inf"))),
            int(meta.get("stall", 0)), int(meta["gen"]),
            list(meta.get("history", [])))


def load_trained_pool(path: str) -> PolicyPool:
    """Load the incumbent θ of a trainer checkpoint directory as a k=1
    ``PolicyPool`` — the ``trained:<ckpt>`` grammar entry and
    ``twin_loop --pool trained:<path>`` resolve through here."""
    if not os.path.isdir(path):
        raise FileNotFoundError(f"no checkpoint directory at {path!r}")
    manager = CheckpointManager(path)
    step = manager.latest_step()
    if step is None:
        raise ValueError(f"no valid checkpoint under {path!r}")
    # read the θ leaf + metadata directly — the search-state leaves
    # have family-dependent dims the loader need not know about
    d = step_dir(path, step)
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    meta = manifest.get("extra", {}).get(EXTRA_KEY)
    if not meta:
        raise ValueError(
            f"{path!r} step {step} is not a trainer checkpoint "
            f"(no {EXTRA_KEY!r} metadata)")
    if not meta.get("has_best"):
        raise ValueError(
            f"{path!r} step {step} holds no trained policy yet")
    data = np.load(os.path.join(d, ARRAYS))
    theta = np.asarray(data["best_theta"], np.float32)
    family = policies._FAMILY_BY_NAME[meta["family"]]
    return theta_pool(family, theta[None, :],
                      (f"trained[{meta['family']}]",))
