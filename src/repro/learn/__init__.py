"""On-device policy learning (DESIGN.md §13).

Population-based training (ES / CEM) of parametric ``PolicySpec`` θ:
every generation — N candidate θ × S training scenarios (× optional
fan members) — is evaluated as ONE jitted replay grid with the
population riding the fork axis, scored by any ``core.objective``
goal, and the trained θ deploys live through the ``trained:<ckpt>``
pool-grammar entry.
"""
from repro.learn.strategy import CEM, ES, Strategy, StrategyState
from repro.learn.evolution import ParamSpace, family_space, static_seeds
from repro.learn.trainer import (TrainConfig, TrainResult, load_trained_pool,
                                 train)

__all__ = [
    "Strategy", "StrategyState", "ES", "CEM",
    "ParamSpace", "family_space", "static_seeds",
    "TrainConfig", "TrainResult", "train", "load_trained_pool",
]
