"""Per-family search spaces: free search coordinates ↔ full θ.

The trainer searches a small unconstrained space z (the family's free
parameters, timescales in log10) and decodes each candidate into a
full (N_THETA,) θ row for ``policies.theta_pool``.  Bounds are clipped
at decode time, so every strategy proposal is a valid policy and the
decode is a pure deterministic function — bitwise-stable across
resume.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import numpy as np

from repro.core import policies
from repro.core.policies import (EXTENDED_POOL, FAM_EXP, FAM_LIN, FAM_WFP,
                                 N_FEATURES, N_THETA, POLICY_NAMES, TH_A,
                                 TH_B, TH_TAU)


@dataclasses.dataclass(frozen=True)
class ParamSpace:
    """Free-dim search space for one policy family.

    ``idx[d]`` is the θ slot dim d writes; ``log10[d]`` dims decode as
    10**z (timescales); z is clipped to [lo, hi] before decoding.
    ``x0``/``sigma0`` are the default initial mean/scale in z-space.
    """

    family: int
    names: Tuple[str, ...]
    idx: Tuple[int, ...]
    lo: Tuple[float, ...]
    hi: Tuple[float, ...]
    log10: Tuple[bool, ...]
    x0: Tuple[float, ...]
    sigma0: Tuple[float, ...]

    @property
    def dim(self) -> int:
        return len(self.names)

    def clip(self, z: np.ndarray) -> np.ndarray:
        z = np.asarray(z, np.float32)
        return np.clip(z, np.asarray(self.lo, np.float32),
                       np.asarray(self.hi, np.float32))

    def decode(self, z: np.ndarray) -> np.ndarray:
        """(N, dim) search points -> (N, N_THETA) full θ rows."""
        z = self.clip(np.atleast_2d(z))
        if z.shape[1] != self.dim:
            raise ValueError(f"expected (N, {self.dim}), got {z.shape}")
        th = np.tile(policies._base_theta(), (z.shape[0], 1))
        for d, slot in enumerate(self.idx):
            col = z[:, d]
            th[:, slot] = np.power(np.float32(10.0), col) if self.log10[d] else col
        return th.astype(np.float32)


_LIN_SPACE = ParamSpace(
    family=FAM_LIN,
    names=policies.FEATURES,
    idx=tuple(range(N_FEATURES)),
    lo=(-3.0,) * N_FEATURES,
    hi=(3.0,) * N_FEATURES,
    log10=(False,) * N_FEATURES,
    x0=(0.0,) * N_FEATURES,
    sigma0=(0.5,) * N_FEATURES,
)

# wfp: exponents (a, b) direct; τ searched as log10 (τ=10^z, z∈[1,7]
# spans 10 s .. 10^7 s — z=7 is effectively aging-off on trace scales).
_WFP_SPACE = ParamSpace(
    family=FAM_WFP,
    names=("a", "b", "log10_tau"),
    idx=(TH_A, TH_B, TH_TAU),
    lo=(0.0, -2.0, 1.0),
    hi=(8.0, 4.0, 7.0),
    log10=(False, False, True),
    x0=(3.0, 1.0, 6.0),
    sigma0=(1.0, 0.5, 1.0),
)

_EXP_SPACE = ParamSpace(
    family=FAM_EXP,
    names=("log10_tau",),
    idx=(TH_TAU,),
    lo=(1.0,),
    hi=(7.0,),
    log10=(True,),
    x0=(math.log10(3600.0),),
    sigma0=(0.5,),
)

_SPACES = {FAM_LIN: _LIN_SPACE, FAM_WFP: _WFP_SPACE, FAM_EXP: _EXP_SPACE}


def family_space(family) -> ParamSpace:
    """The search space of a family (id or name: "lin"/"wfp"/"expf")."""
    if isinstance(family, str):
        try:
            family = policies._FAMILY_BY_NAME[family.strip().lower()]
        except KeyError:
            raise ValueError(
                f"unknown family {family!r}; have "
                f"{sorted(policies._FAMILY_BY_NAME)}") from None
    return _SPACES[int(family)]


def static_seeds(family: int) -> List[Tuple[str, np.ndarray]]:
    """The static fixed points representable in ``family``, as
    (name, full θ) warm-start rows — gen-0 candidates that guarantee
    the search starts no worse than the classical baselines.

    Note WFP's fixed point (τ=∞) and FCFS/SAF's unbounded submit/area
    weights sit OUTSIDE the clipped search box — they are injected as
    exact θ rows precisely because the box cannot express them.
    """
    out: List[Tuple[str, np.ndarray]] = []
    for pid in EXTENDED_POOL:
        spec = policies.static_spec(pid)
        if int(spec.family) == int(family):
            out.append((POLICY_NAMES[pid],
                        np.asarray(spec.theta, np.float32).copy()))
    return out
