"""Serving substrate: jitted prefill/decode + continuous batching."""
from repro.serve.engine import (Request, ServingEngine, make_serve_fns,
                                jit_decode_step, cache_shardings)

__all__ = ["Request", "ServingEngine", "make_serve_fns", "jit_decode_step",
           "cache_shardings"]
