"""Serving substrate: jitted prefill / decode steps + a continuous-
batching engine with twin-driven admission.

``make_serve_fns`` builds the two jitted entry points the dry-run
lowers (``serve_step`` is the decode one — one new token for the whole
batch against a ``seq_len`` KV cache).

``ServingEngine`` is the host-side loop: a fixed pool of batch slots,
each slot running one request; finished slots are refilled from the
admission queue (continuous batching).  Admission is pluggable — the
``examples/serve_twin.py`` driver wires it to SchedTwin so the paper's
adaptive policy selection decides which queued request class to admit
next, closing the same feedback loop as cluster scheduling but at
request granularity.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules
from repro.models import api

Params = Dict[str, jax.Array]


# ----------------------------------------------------------------------
# Jitted model entry points
# ----------------------------------------------------------------------

# (batch_axis, head_axis, seq_axis, width_axis) counted from the END of
# the leaf shape, per cache-leaf name.  None = that axis doesn't exist.
_CACHE_LAYOUT = {
    # attention KV: (..., B, H, S, D)
    "k": (-4, -3, -2, None), "v": (-4, -3, -2, None),
    "self_k": (-4, -3, -2, None), "self_v": (-4, -3, -2, None),
    "cross_k": (-4, -3, -2, None), "cross_v": (-4, -3, -2, None),
    # MLA compressed cache: (..., B, S, R)
    "c_kv": (-3, None, -2, None), "k_pe": (-3, None, -2, None),
    # RWKV: state (..., B, H, N, N); token-shift (..., B, D)
    "wkv": (-4, -3, None, None),
    "tm_x": (-2, None, None, None), "cm_x": (-2, None, None, None),
    # RG-LRU: conv history (..., B, K, W); hidden (..., B, W)
    "conv": (-3, None, None, -1),
    "h": (-2, None, None, -1),
}


def cache_shardings(cfg: ModelConfig, rules: ShardingRules, caches: Any):
    """Shard every cache leaf by name: batch on the DP axes; heads on
    `model` when divisible (GQA with enough KV heads); otherwise the
    sequence axis on `model` when the rules enable distributed
    flash-decode (``kv_seq``); recurrent widths on `model` (matching
    the RG-LRU weight sharding)."""
    mesh = rules.mesh
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    model = "model" if "model" in mesh.shape else None
    kv_seq_on = rules.rules.get("kv_seq") is not None

    def spec_of(path, leaf) -> NamedSharding:
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = str(p.key)
                break
        layout = _CACHE_LAYOUT.get(name)
        shape = leaf.shape
        parts: List[Any] = [None] * len(shape)
        if layout is None or len(shape) < 2:
            return NamedSharding(mesh, P(*parts))
        b_ax, h_ax, s_ax, w_ax = layout

        def ok(ax) -> bool:
            return ax is not None and -ax <= len(shape)

        if ok(b_ax) and dp and shape[b_ax] % _size(mesh, dp) == 0:
            parts[len(shape) + b_ax] = dp if len(dp) > 1 else dp[0]
        if model:
            if ok(h_ax) and shape[h_ax] % mesh.shape[model] == 0:
                parts[len(shape) + h_ax] = model
            elif (ok(s_ax) and kv_seq_on
                  and shape[s_ax] % mesh.shape[model] == 0):
                parts[len(shape) + s_ax] = model
            elif ok(w_ax) and shape[w_ax] % mesh.shape[model] == 0:
                parts[len(shape) + w_ax] = model
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(spec_of, caches)


def _size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def make_serve_fns(cfg: ModelConfig, rules: ShardingRules):
    """Returns (prefill_fn, decode_fn), both ready to jit."""

    def prefill_fn(params: Params, batch: Dict[str, jax.Array]):
        return api.prefill(cfg, rules, params, batch)

    def decode_fn(params: Params, caches: Any,
                  tokens: jax.Array, index: jax.Array):
        logits, caches = api.decode_step(cfg, rules, params, caches,
                                         {"tokens": tokens, "index": index})
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, caches

    return prefill_fn, decode_fn


def jit_decode_step(cfg: ModelConfig, rules: ShardingRules, caches_ab):
    """jit of one decode step with explicit cache shardings (the
    ``serve_step`` the dry-run lowers for decode_* / long_* cells)."""
    _, decode_fn = make_serve_fns(cfg, rules)
    mesh = rules.mesh
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    param_sh = rules.table_shardings(api.param_table(cfg))
    cache_sh = cache_shardings(cfg, rules, caches_ab)
    batch = jax.tree.leaves(caches_ab)[0].shape  # just for divisibility
    b = _batch_size(caches_ab)
    if dp and b % _size(mesh, dp) == 0:
        dp_spec = dp if len(dp) > 1 else dp[0]
        tok_in = NamedSharding(mesh, P(dp_spec, None))
        tok_out = NamedSharding(mesh, P(dp_spec))   # argmax output (B,)
    else:  # tiny batches (long_500k B=1): replicate tokens
        tok_in = NamedSharding(mesh, P(None, None))
        tok_out = NamedSharding(mesh, P(None))
    del batch
    return jax.jit(
        decode_fn,
        in_shardings=(param_sh, cache_sh, tok_in,
                      NamedSharding(mesh, P())),
        out_shardings=(tok_out, cache_sh),
        donate_argnums=(1,))


def _batch_size(caches_ab: Any) -> int:
    """Batch size from any attn/state cache leaf (see _CACHE_LAYOUT)."""
    for path, leaf in jax.tree_util.tree_flatten_with_path(caches_ab)[0]:
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = str(p.key)
                break
        layout = _CACHE_LAYOUT.get(name)
        if layout and layout[0] is not None and -layout[0] <= len(leaf.shape):
            return leaf.shape[len(leaf.shape) + layout[0]]
    raise ValueError("no recognizable cache leaf")


# ----------------------------------------------------------------------
# Continuous batching engine
# ----------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray          # (S_prompt,) int32
    max_new_tokens: int
    arrival_t: float = 0.0
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None


class ServingEngine:
    """Slot-based continuous batching over a single decode batch.

    The engine keeps ``batch_slots`` sequences in flight.  Each loop
    iteration decodes one token for every active slot; finished slots
    are refilled via ``admit()`` (FIFO by default; the twin-driven
    driver overrides admission order).  Prefill for an admitted request
    runs per-slot (the jitted prefill is batch-1 here for simplicity;
    batched prefill is a straightforward extension).
    """

    def __init__(self, cfg: ModelConfig, rules: ShardingRules, params,
                 batch_slots: int, max_seq: int,
                 admission: Optional[Callable[[List[Request]], int]] = None
                 ) -> None:
        self.cfg = cfg
        self.rules = rules
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.admission = admission
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.clock = 0.0

        prefill_fn, decode_fn = make_serve_fns(cfg, rules)
        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn, donate_argnums=(1,))
        self.caches = api.init_caches(cfg, batch_slots, max_seq)
        self._tokens = np.zeros((batch_slots, 1), dtype=np.int32)
        self._pos = np.zeros((batch_slots,), dtype=np.int64)

    # -- admission ------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.arrival_t = self.clock
        self.queue.append(req)

    def _admit_next(self) -> Optional[Request]:
        if not self.queue:
            return None
        idx = self.admission(self.queue) if self.admission else 0
        return self.queue.pop(idx)

    def _fill_slot(self, slot: int, req: Request) -> None:
        prompt = jnp.asarray(req.prompt[None, :], dtype=jnp.int32)
        batch = {"tokens": prompt}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (1, prompt.shape[1], self.cfg.d_model), dtype=jnp.bfloat16)
        logits, caches1 = self._prefill(self.params, batch)
        tok = int(jnp.argmax(logits[0, -1]))
        # copy per-request cache into the batched slot
        self.caches = jax.tree.map(
            lambda big, small: _write_slot(big, small, slot),
            self.caches, caches1)
        req.output.append(tok)
        req.first_token_t = self.clock
        self._tokens[slot, 0] = tok
        self._pos[slot] = len(req.prompt)
        self.active[slot] = req

    # -- main loop ------------------------------------------------------
    def step(self) -> int:
        """One engine iteration; returns #active slots."""
        for s in range(self.slots):
            if self.active[s] is None:
                req = self._admit_next()
                if req is not None:
                    self._fill_slot(s, req)
        if all(a is None for a in self.active):
            return 0

        index = jnp.asarray(int(self._pos.max()), dtype=jnp.int32)
        toks = jnp.asarray(self._tokens)
        next_tok, self.caches = self._decode(self.params, self.caches,
                                             toks, index)
        nt = np.asarray(next_tok)
        self.clock += 1.0
        n_active = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(nt[s])
            req.output.append(tok)
            self._tokens[s, 0] = tok
            self._pos[s] += 1
            if (len(req.output) >= req.max_new_tokens
                    or self._pos[s] >= self.max_seq - 1):
                req.done = True
                req.finish_t = self.clock
                self.active[s] = None
            else:
                n_active += 1
        return n_active + sum(r is not None for r in self.active)

    def run_until_drained(self, max_iters: int = 100_000) -> None:
        for _ in range(max_iters):
            if self.step() == 0 and not self.queue:
                return
        raise RuntimeError("serving engine did not drain")


def _write_slot(big: jax.Array, small: jax.Array, slot: int) -> jax.Array:
    """Write a batch-1 cache leaf into slot ``slot`` of the batched
    cache.  Handles (B, ...) and scanned (L, B, ...) layouts; the
    batch-1 prefill cache may be shorter in the sequence axis."""
    if big.ndim == 0 or big.shape == small.shape:
        return small
    # locate batch axis: the axis where big==slots and small==1
    for ax in range(small.ndim):
        if small.shape[ax] == 1 and big.shape[ax] != small.shape[ax]:
            batch_ax = ax
            break
    else:
        return big
    # pad the (shorter) sequence axis of `small` up to big's length
    pads = []
    for ax in range(small.ndim):
        if ax == batch_ax:
            pads.append((0, 0))
        else:
            pads.append((0, big.shape[ax] - small.shape[ax]))
    small = jnp.pad(small, pads)
    start = [0] * big.ndim
    start[batch_ax] = slot
    return jax.lax.dynamic_update_slice(big, small.astype(big.dtype),
                                        tuple(start))
