"""Pallas TPU kernel for the scheduling pass — the paper's hot spot.

Every SchedTwin cycle runs k drain simulations; each simulation runs a
*scheduling pass* (priority order + greedy starts + EASY backfill) at
every event.  The paper parallelizes this with k CQSim processes on 48
CPU cores; the TPU-native adaptation is a **policy-batched kernel**:

  * grid = the policy/ensemble axis (one program per candidate policy),
  * the queue state (<= max_jobs jobs x 6 f32 fields, ~6 KB at J=256)
    is VMEM-resident for the whole pass,
  * the inherently sequential greedy/backfill dependence is an
    in-kernel ``fori_loop`` over priority ranks,
  * the EASY "shadow time" is computed WITHOUT the CPU algorithm's
    sort: for every candidate end time t_j we evaluate
    ``free_at(t_j) = free + sum(nodes_r * (end_r <= t_j))`` — an O(J^2)
    SIMD broadcast that replaces an O(J log J) sort-scan, which is the
    right trade on the VPU (J^2 = 64K lanes of work, zero data
    movement).  See ``DESIGN.md`` §2 (hardware adaptation) at the repo
    root for the full derivation and the tie-handling caveat.

Two entry points:
  * ``policy_eval_pass`` — shared snapshot, per-policy ``order`` only
    (the first pass of a decision cycle, where all forks still share
    one queue state);
  * ``policy_eval_pass_batched`` — every input carries the fork axis
    (mid-drain, after fork states have diverged).  This is the
    ``pallas`` backend of ``repro.core.engine.DrainEngine``.

The priority *keys* are computed (and argsorted) outside the kernel —
they are embarrassingly parallel and XLA already fuses them; the kernel
owns the sequential part.

Inputs (policy axis k leading where applicable):
  order     (k, J) i32   — job slots in priority order (invalid last)
  queued    (J,)   i32   — 1 if job is QUEUED
  nodes     (J,)   f32   — node request per job
  est       (J,)   f32   — user walltime estimate
  run_end   (J,)   f32   — predicted end for RUNNING jobs else +inf
  run_nodes (J,)   f32   — nodes held by RUNNING jobs else 0
  free0     (1, 1) f32   — free nodes now
  now       (1, 1) f32   — current time
  limit     (1, 1) i32   — rank bound for both sequential loops: ranks
                           in [limit, J) hold no queued slot, so the
                           greedy and backfill ``fori_loop``s stop there
                           (a dynamic trip count — supported by Mosaic;
                           bit-exact, see DESIGN.md §7).  Callers pass
                           J to disable.

Outputs:
  started (k, J) i32 — jobs started by this pass under each policy
  free    (k, 1) f32 — free nodes after the pass
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1.0
BIG = 3.0e38  # ~f32 inf stand-in (pallas-friendly)


def _pass_kernel(order_ref, queued_ref, nodes_ref, est_ref,
                 run_end_ref, run_nodes_ref, free_ref, now_ref,
                 limit_ref, started_ref, free_out_ref):
    """One scheduling pass for ONE policy (grid dim 0 = policy)."""
    order = order_ref[0, :]          # (J,) i32 priority-ranked job ids
    queued = queued_ref[0, :]        # (J,) i32
    nodes = nodes_ref[0, :]          # (J,) f32
    est = est_ref[0, :]
    run_end = run_end_ref[0, :]
    run_nodes = run_nodes_ref[0, :]
    free0 = free_ref[0, 0]
    now = now_ref[0, 0]
    j_cap = order.shape[0]
    # rank bound: ranks >= limit hold no queued slot -> provable no-ops
    # in both sequential loops below (truncation is bit-exact)
    limit = jnp.minimum(limit_ref[0, 0], j_cap)

    q_nodes = jnp.where(queued > 0, nodes, BIG)  # invalid jobs never fit

    # ---- pass 1: greedy in priority order (sequential) ---------------
    def greedy(i, carry):
        free, head_rank, started = carry
        j = order[i]
        fits = q_nodes[j] <= free
        no_head = head_rank < 0
        can_start = fits & no_head
        is_queued = queued[j] > 0
        free = jnp.where(can_start & is_queued, free - nodes[j], free)
        started = jnp.where(can_start & is_queued,
                            started.at[j].set(1), started)
        blocked = is_queued & (~fits) & no_head
        head_rank = jnp.where(blocked, i, head_rank)
        return free, head_rank, started

    started0 = jnp.zeros((j_cap,), dtype=jnp.int32)
    free1, head_rank, started1 = jax.lax.fori_loop(
        0, limit, greedy, (free0, jnp.int32(-1), started0))

    head = order[jnp.maximum(head_rank, 0)]
    has_head = head_rank >= 0
    head_nodes = jnp.where(has_head, nodes[head], 0.0)

    # ---- shadow time without a sort (O(J^2) SIMD) ---------------------
    # running set = RUNNING jobs + jobs started in pass 1 (their end is
    # now + estimate; the twin never sees true runtimes).
    end_eff = jnp.where(started1 > 0, now + est, run_end)       # (J,)
    nodes_eff = jnp.where(started1 > 0, nodes, run_nodes)       # (J,)
    # free_at[i] = free1 + sum_j nodes_eff[j] * (end_eff[j] <= end_eff[i])
    le = (end_eff[None, :] <= end_eff[:, None]).astype(jnp.float32)
    free_at = free1 + le @ nodes_eff                            # (J,)
    feasible = (free_at >= head_nodes) & (end_eff < BIG)
    t_cand = jnp.where(feasible, end_eff, BIG)
    shadow = jnp.where(has_head, jnp.min(t_cand), BIG)
    at_shadow = feasible & (end_eff <= shadow)
    extra_raw = jnp.max(jnp.where(at_shadow, free_at, -BIG)) - head_nodes
    extra = jnp.where(has_head,
                      jnp.where(jnp.any(at_shadow), extra_raw, 0.0),
                      BIG)

    # ---- pass 2: EASY backfill (sequential) ---------------------------
    def backfill(i, carry):
        free, extra, started = carry
        j = order[i]
        cand = (queued[j] > 0) & (started[j] == 0) & (i != head_rank)
        fits_now = nodes[j] <= free
        cond_a = (now + est[j]) <= shadow
        cond_b = nodes[j] <= extra
        start = cand & fits_now & (cond_a | cond_b)
        free = jnp.where(start, free - nodes[j], free)
        extra = jnp.where(start & (~cond_a), extra - nodes[j], extra)
        started = jnp.where(start, started.at[j].set(1), started)
        return free, extra, started

    # ranks <= head_rank cannot backfill (started in pass 1, or the head
    # itself); no head -> nothing left to backfill at all
    back_lo = jnp.where(head_rank >= 0, head_rank + 1, limit)
    free2, _, started = jax.lax.fori_loop(
        back_lo, limit, backfill, (free1, extra, started1))

    started_ref[0, :] = started
    free_out_ref[0, 0] = free2


def _limit_arr(limit, j_cap: int) -> jax.Array:
    """(1, 1) i32 rank bound; ``None`` -> the full static bound J."""
    if limit is None:
        limit = j_cap
    return jnp.asarray(limit, dtype=jnp.int32).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def policy_eval_pass(order: jax.Array, queued: jax.Array,
                     nodes: jax.Array, est: jax.Array,
                     run_end: jax.Array, run_nodes: jax.Array,
                     free0: jax.Array, now: jax.Array,
                     limit: jax.Array | None = None,
                     *, interpret: bool = True):
    """Batched scheduling pass: ``order`` is (k, J); the rest (J,).

    Returns (started (k, J) i32, free (k,) f32).  ``interpret=True``
    runs the kernel body on CPU (this container); on TPU pass False.
    ``limit`` (i32 scalar, shared by all programs) truncates the
    sequential rank loops; None scans all J ranks.
    """
    k, j_cap = order.shape
    f32 = jnp.float32

    shared = lambda: pl.BlockSpec((1, j_cap), lambda p: (0, 0))  # noqa: E731
    per_policy = lambda: pl.BlockSpec((1, j_cap), lambda p: (p, 0))  # noqa: E731
    scalar = lambda: pl.BlockSpec((1, 1), lambda p: (0, 0))  # noqa: E731

    started, free = pl.pallas_call(
        _pass_kernel,
        grid=(k,),
        in_specs=[per_policy(), shared(), shared(), shared(), shared(),
                  shared(), scalar(), scalar(), scalar()],
        out_specs=[per_policy(), pl.BlockSpec((1, 1), lambda p: (p, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((k, j_cap), jnp.int32),
            jax.ShapeDtypeStruct((k, 1), f32),
        ],
        interpret=interpret,
    )(order,
      queued.reshape(1, j_cap).astype(jnp.int32),
      nodes.reshape(1, j_cap).astype(f32),
      est.reshape(1, j_cap).astype(f32),
      run_end.reshape(1, j_cap).astype(f32),
      run_nodes.reshape(1, j_cap).astype(f32),
      free0.reshape(1, 1).astype(f32),
      now.reshape(1, 1).astype(f32),
      _limit_arr(limit, j_cap))
    return started, free[:, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def policy_eval_pass_batched(order: jax.Array, queued: jax.Array,
                             nodes: jax.Array, est: jax.Array,
                             run_end: jax.Array, run_nodes: jax.Array,
                             free0: jax.Array, now: jax.Array,
                             limit: jax.Array | None = None,
                             *, interpret: bool = True):
    """Fully policy-batched scheduling pass: ALL inputs are (k, J)
    (``free0``/``now`` are (k,)) — one grid program per fork, each
    reading its own row.  Used inside the batched drain, where fork
    states have diverged (different jobs running, different clocks,
    ensemble-perturbed estimates).

    Returns (started (k, J) i32, free (k,) f32).  ``limit`` (i32
    scalar, shared by the grid — the engine's ``pass_rank_limit``)
    truncates the sequential rank loops; None scans all J ranks.
    """
    k, j_cap = order.shape
    f32 = jnp.float32

    per_policy = lambda: pl.BlockSpec((1, j_cap), lambda p: (p, 0))  # noqa: E731
    per_scalar = lambda: pl.BlockSpec((1, 1), lambda p: (p, 0))  # noqa: E731
    shared_scalar = lambda: pl.BlockSpec((1, 1), lambda p: (0, 0))  # noqa: E731

    started, free = pl.pallas_call(
        _pass_kernel,
        grid=(k,),
        in_specs=[per_policy()] * 6 + [per_scalar(), per_scalar(),
                                       shared_scalar()],
        out_specs=[per_policy(), per_scalar()],
        out_shape=[
            jax.ShapeDtypeStruct((k, j_cap), jnp.int32),
            jax.ShapeDtypeStruct((k, 1), f32),
        ],
        interpret=interpret,
    )(order.astype(jnp.int32),
      queued.astype(jnp.int32),
      nodes.astype(f32),
      est.astype(f32),
      run_end.astype(f32),
      run_nodes.astype(f32),
      free0.reshape(k, 1).astype(f32),
      now.reshape(k, 1).astype(f32),
      _limit_arr(limit, j_cap))
    return started, free[:, 0]
