"""Pallas TPU flash attention (train/prefill hot spot).

Online-softmax attention with explicit VMEM tiling:

  * grid = (batch * q_heads, Sq / BLOCK_Q)
  * each program holds one (BLOCK_Q, D) query tile, the (BLOCK_Q,)
    running max/denominator and the (BLOCK_Q, D) output accumulator in
    VMEM scratch, and streams (BLOCK_K, D) key/value tiles through a
    ``fori_loop``;
  * causal masking skips fully-masked KV tiles (the loop upper bound is
    derived from the q tile index), so FLOPs stay at ~S^2/2;
  * GQA reads the kv head ``h // group`` straight from the BlockSpec
    index map — repeated KV heads are never materialized.

Block sizes default to (512, 512): at D=128 a program's working set is
q(512x128x4) + k,v(2x512x128x4) + acc(512x128x4) + stats ~= 1 MB of
VMEM — comfortably under the ~16 MB/core budget with double buffering.
MXU dims (BLOCK x D) are multiples of 128.

Oracle: ``repro.models.attention.full_attention`` (ref.py re-exports).
Validated in interpret mode; on TPU pass ``interpret=False``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *,
                  scale: float, causal: bool, block_k: int, s_k: int):
    """One (head, q-tile) program.  q_ref: (1, BQ, D); k/v_ref: full
    (1, Sk, D) rows of this head's KV (streamed in BK tiles below)."""
    q_tile = pl.program_id(1)
    bq = q_ref.shape[1]
    d = q_ref.shape[2]

    q = q_ref[0, :, :].astype(jnp.float32) * scale          # (BQ, D)

    m0 = jnp.full((bq,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bq,), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, d), dtype=jnp.float32)

    if causal:
        # last kv tile that any query in this tile may attend to
        hi = ((q_tile + 1) * bq + block_k - 1) // block_k
        n_k = min if False else None  # noqa  (documentation aid)
        num_tiles = jnp.minimum(hi, s_k // block_k)
    else:
        num_tiles = s_k // block_k

    q_pos = q_tile * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    def body(kt, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(kt * block_k, block_k), :]    # (BK, D)
        v = v_ref[0, pl.dslice(kt * block_k, block_k), :]
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (BQ, BK)
        if causal:
            k_pos = kt * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_tiles, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, :, :] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "scale", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    scale: float | None = None,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D), Hq % Hkv == 0.
    Returns (B, Hq, Sq, D) in q.dtype."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, sk, d)
    vf = v.reshape(b * hkv, sk, d)

    def q_map(h, qt):
        return (h, qt, 0)

    def kv_map(h, qt):
        # GQA: query head h -> kv head h // group, batch-major layout
        return ((h // (hq)) * hkv + (h % hq) // group, 0, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_k=block_k, s_k=sk)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, sq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, sk, d), kv_map),
            pl.BlockSpec((1, sk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
