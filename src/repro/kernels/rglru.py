"""Pallas TPU kernel for the RG-LRU gated linear recurrence
(RecurrentGemma / Griffin):

    h_t = a_t ⊙ h_{t-1} + x_t          (all elementwise, width W)

Sequential in t, parallel over (batch, width):

  * grid = (B, W / BLOCK_W, S / BLOCK_T) — time is the LAST (sequential)
    grid axis so the (1, BLOCK_W) hidden state persists in VMEM scratch
    across time tiles;
  * a/x stream in (BLOCK_T, BLOCK_W) tiles; every step is one fused
    multiply-add row — pure VPU elementwise throughput, the TPU analogue
    of the paper's fused GPU scan;
  * width tiles are independent (grid axis 1), so the kernel scales to
    the model-parallel sharded width without code changes.

Oracle: ``repro.models.blocks_rnn.rglru_scan`` (ref.py re-exports).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 128
DEFAULT_BLOCK_W = 256


def _rglru_kernel(a_ref, x_ref, h0_ref, y_ref, h_ref, *, block_t: int):
    t_tile = pl.program_id(2)

    @pl.when(t_tile == 0)
    def _init():
        h_ref[...] = h0_ref[...]

    def step(i, h):
        h = a_ref[0, i, :] * h + x_ref[0, i, :]
        y_ref[0, i, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_ref[0, :])
    h_ref[0, :] = h


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_w", "interpret"))
def rglru(a: jax.Array, x: jax.Array, h0: jax.Array, *,
          block_t: int = DEFAULT_BLOCK_T,
          block_w: int = DEFAULT_BLOCK_W,
          interpret: bool = True):
    """a, x: (B, S, W) f32; h0: (B, W) f32.
    Returns (h_all (B, S, W) f32, h_final (B, W) f32)."""
    b, s, w = a.shape
    block_t = min(block_t, s)
    block_w = min(block_w, w)
    assert s % block_t == 0 and w % block_w == 0, (s, w)

    def t_map(bb, wb, tt):
        return (bb, tt, wb)

    def h_map(bb, wb, tt):
        return (bb, wb)

    y, h = pl.pallas_call(
        functools.partial(_rglru_kernel, block_t=block_t),
        grid=(b, w // block_w, s // block_t),
        in_specs=[
            pl.BlockSpec((1, block_t, block_w), t_map),
            pl.BlockSpec((1, block_t, block_w), t_map),
            pl.BlockSpec((1, block_w), h_map),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_w), t_map),
            pl.BlockSpec((1, block_w), h_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, w), jnp.float32),
            jax.ShapeDtypeStruct((b, w), jnp.float32),
        ],
        interpret=interpret,
    )(a, x, h0)
    return y, h
