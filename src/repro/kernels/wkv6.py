"""Pallas TPU kernel for the RWKV6 (Finch) WKV recurrence.

The recurrence per head (state S in R^{N x N}, N = head size 64):

    y_t = r_t @ (S + u ⊙ (k_t v_t^T))
    S   = diag(w_t) S + k_t v_t^T

It is sequential in t but embarrassingly parallel over (batch x heads),
so:

  * grid = (B * H, S / BLOCK_T)
  * the (N, N) f32 state lives in a VMEM scratch accumulator that
    PERSISTS across the time-tile grid dimension (TPU grid iteration is
    sequential over the last axis, the standard Pallas accumulation
    idiom), so the state never round-trips to HBM between tiles;
  * r/k/v/w stream through VMEM in (BLOCK_T, N) tiles;
  * each step is rank-1 outer-product + matvec on (64, 64) f32 — VPU
    work with the state held on-chip, which is exactly what the CUDA
    kernel in the RWKV repo does with shared memory (DESIGN.md §2).

Oracle: ``repro.models.blocks_rnn.wkv_scan`` (ref.py re-exports).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 128


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_ref, *,
                block_t: int):
    """One (batch*head, time-tile) program; state persists over tiles."""
    t_tile = pl.program_id(1)

    @pl.when(t_tile == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[0, :]                       # (N,)

    def step(i, state):
        r = r_ref[0, i, :]                # (N,)
        k = k_ref[0, i, :]
        v = v_ref[0, i, :]
        w = w_ref[0, i, :]
        kv = k[:, None] * v[None, :]      # (N, N) outer product
        y = ((state + u[:, None] * kv) * r[:, None]).sum(axis=0)  # (N,)
        y_ref[0, i, :] = y.astype(y_ref.dtype)
        return w[:, None] * state + kv

    state = jax.lax.fori_loop(0, block_t, step, state_ref[0, :, :])
    state_ref[0, :, :] = state


@functools.partial(jax.jit,
                   static_argnames=("block_t", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, *, block_t: int = DEFAULT_BLOCK_T,
         interpret: bool = True):
    """r/k/v/w: (B, S, H, N) f32; u: (H, N) f32.
    Returns (y (B, S, H, N) f32, final state (B, H, N, N) f32)."""
    b, s, h, n = r.shape
    block_t = min(block_t, s)
    assert s % block_t == 0, (s, block_t)

    def bh(x):  # (B, S, H, N) -> (B*H, S, N)
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, n)

    rf, kf, vf, wf = bh(r), bh(k), bh(v), bh(w)
    uf = jnp.broadcast_to(u[None, :, :], (b, h, n)).reshape(b * h, n)

    def t_map(g, tt):
        return (g, tt, 0)

    y, state = pl.pallas_call(
        functools.partial(_wkv_kernel, block_t=block_t),
        grid=(b * h, s // block_t),
        in_specs=[
            pl.BlockSpec((1, block_t, n), t_map),
            pl.BlockSpec((1, block_t, n), t_map),
            pl.BlockSpec((1, block_t, n), t_map),
            pl.BlockSpec((1, block_t, n), t_map),
            pl.BlockSpec((1, n), lambda g, tt: (g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, n), t_map),
            pl.BlockSpec((1, n, n), lambda g, tt: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, n), jnp.float32),
            jax.ShapeDtypeStruct((b * h, n, n), jnp.float32),
        ],
        interpret=interpret,
    )(rf, kf, vf, wf, uf)

    y = y.reshape(b, h, s, n).transpose(0, 2, 1, 3)
    return y, state.reshape(b, h, n, n)
