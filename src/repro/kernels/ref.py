"""Pure-jnp oracles for every kernel in this package.

Each function computes exactly what its kernel computes, with plain
jax.numpy (no pallas) — the tests sweep shapes/dtypes and
``assert_allclose`` kernel-vs-oracle.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import policies
from repro.core.backfill import schedule_pass
from repro.core.state import QUEUED, RUNNING, SimState
from repro.models.attention import full_attention
from repro.models.blocks_rnn import rglru_scan, wkv_scan


# ---------------------------------------------------------------------
# policy_eval oracle: the vectorized schedule_pass from core/backfill.
# ---------------------------------------------------------------------

def policy_eval_ref(state: SimState, pool: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """(started (k, J) i32, free_after (k,) f32) via core.schedule_pass."""
    def one(pid):
        res = schedule_pass(state, pid)
        return res.started.astype(jnp.int32), \
            res.state.free_nodes.astype(jnp.float32)
    started, free = jax.vmap(one)(pool)
    return started, free


def kernel_inputs_from_state(state: SimState, pool: jax.Array):
    """Build the policy_eval kernel's input arrays from a SimState."""
    jobs = state.jobs
    queued = (jobs.state == QUEUED).astype(jnp.int32)
    running = jobs.state == RUNNING
    keys = jax.vmap(
        lambda pid: policies.priority_key(jobs, state.now, pid))(pool)
    keys = jnp.where(queued[None, :] > 0, keys, jnp.inf)
    order = jnp.argsort(keys, axis=1).astype(jnp.int32)
    return dict(
        order=order,
        queued=queued,
        nodes=jobs.nodes.astype(jnp.float32),
        est=jobs.est_runtime.astype(jnp.float32),
        run_end=jnp.where(running, jobs.end_t, jnp.inf).astype(jnp.float32),
        run_nodes=jnp.where(running, jobs.nodes, 0).astype(jnp.float32),
        free0=state.free_nodes.astype(jnp.float32),
        now=state.now.astype(jnp.float32),
    )


# ---------------------------------------------------------------------
# flash attention oracle
# ---------------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal=True, scale=None):
    return full_attention(q, k, v, causal=causal, scale=scale,
                          q_block=max(q.shape[2] // 4, 1))


# ---------------------------------------------------------------------
# recurrence oracles
# ---------------------------------------------------------------------

def wkv6_ref(r, k, v, w, u):
    """(y (B,S,H,N), state (B,H,N,N)) via blocks_rnn.wkv_scan."""
    b, s, h, n = r.shape
    state0 = jnp.zeros((b, h, n, n), dtype=jnp.float32)
    state, y = wkv_scan(state0, r, k, v, w, u)
    return y, state


def rglru_ref(a, x, h0):
    """(h_all (B,S,W), h_final (B,W)) via blocks_rnn.rglru_scan."""
    hT, h = rglru_scan(a, x, h0)
    return h, hT
