"""Pallas TPU kernels for the perf-critical layers.

policy_eval      — the paper's scheduling-pass hot spot (policy-batched)
flash_attention  — train/prefill attention (online softmax, GQA-aware)
wkv6             — RWKV6 recurrence (VMEM-resident state)
rglru            — RG-LRU gated linear scan

Wrappers in ops.py; pure-jnp oracles in ref.py; interpret-mode sweeps
in tests/test_kernels_*.py.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
