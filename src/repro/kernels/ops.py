"""Jit'd public wrappers around the Pallas kernels.

``INTERPRET`` defaults to True (this container is CPU-only; interpret
mode executes kernel bodies in Python for correctness).  On real TPU
set ``repro.kernels.ops.INTERPRET = False`` (or pass interpret=False)
to run the compiled kernels.

``twin_schedule_pass`` is the drop-in replacement for the pure-jnp
``core.backfill.schedule_pass`` inside the what-if engine: it takes a
SimState + policy pool and returns the per-policy started masks.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.state import SimState
from repro.kernels import flash_attention as _fa
from repro.kernels import policy_eval as _pe
from repro.kernels import rglru as _rg
from repro.kernels import wkv6 as _wkv
from repro.kernels.ref import kernel_inputs_from_state

INTERPRET = True


def twin_schedule_pass(state: SimState, pool: jax.Array,
                       interpret: bool | None = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Policy-batched scheduling pass (paper hot spot).

    Returns (started (k, J) i32, free_after (k,) f32)."""
    inp = kernel_inputs_from_state(state, pool)
    return _pe.policy_eval_pass(
        inp["order"], inp["queued"], inp["nodes"], inp["est"],
        inp["run_end"], inp["run_nodes"], inp["free0"], inp["now"],
        interpret=INTERPRET if interpret is None else interpret)


def flash_attention(q, k, v, *, causal=True, block_q=None, block_k=None,
                    scale=None, interpret=None):
    kwargs = {}
    if block_q is not None:
        kwargs["block_q"] = block_q
    if block_k is not None:
        kwargs["block_k"] = block_k
    return _fa.flash_attention(
        q, k, v, causal=causal, scale=scale,
        interpret=INTERPRET if interpret is None else interpret, **kwargs)


def wkv6(r, k, v, w, u, *, block_t=None, interpret=None):
    kwargs = {}
    if block_t is not None:
        kwargs["block_t"] = block_t
    return _wkv.wkv6(r, k, v, w, u,
                     interpret=INTERPRET if interpret is None else interpret,
                     **kwargs)


def rglru(a, x, h0, *, block_t=None, block_w=None, interpret=None):
    kwargs = {}
    if block_t is not None:
        kwargs["block_t"] = block_t
    if block_w is not None:
        kwargs["block_w"] = block_w
    return _rg.rglru(a, x, h0,
                     interpret=INTERPRET if interpret is None else interpret,
                     **kwargs)
