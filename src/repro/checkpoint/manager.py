"""Fault-tolerant checkpointing: atomic manifests, async save, elastic
restore.

Layout (one directory per step)::

    <root>/step_000123/
        arrays.npz        # flat key -> ndarray
        MANIFEST.json     # step, keys, shapes/dtypes, written LAST

A checkpoint only *exists* once its manifest exists: the manifest is
written to a temp file and atomically renamed after the arrays are
durably on disk, so a crash mid-save can never yield a half-readable
checkpoint (restore scans for the newest directory with a valid
manifest and ignores stragglers).

``AsyncCheckpointer`` snapshots device arrays to host (blocking only
for the device->host copy) and writes in a background thread, so the
training loop overlaps checkpoint I/O with the next steps — at fleet
scale this is the difference between a checkpoint stall and none.

Elastic restore: arrays are loaded as host numpy and re-placed with
``jax.device_put`` under the *target* sharding, which may come from a
different mesh shape than the one that saved — checkpoints written on
(16, 16) restore cleanly onto (2, 16, 16) or a shrunken degraded mesh
(see tests/test_checkpoint.py::test_cross_mesh_restore).
"""
from __future__ import annotations

import json
import ml_dtypes
import os
import shutil
import tempfile
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

MANIFEST = "MANIFEST.json"
ARRAYS = "arrays.npz"

PyTree = Any


def _flatten(tree: PyTree) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    """Returns (storage arrays, logical dtypes).  bfloat16 is stored as
    a uint16 view (npz-safe) and restored via the manifest dtype."""
    flat: Dict[str, np.ndarray] = {}
    dtypes: Dict[str, str] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype == ml_dtypes.bfloat16:
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat, dtypes


def _undo_storage(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    if logical_dtype == "bfloat16" and arr.dtype == np.uint16:
        return arr.view(ml_dtypes.bfloat16)
    return arr


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "name"):
        return str(p.name)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _unflatten_into(tree: PyTree, flat: Dict[str, np.ndarray],
                    place: Optional[Callable[[str, np.ndarray], Any]] = None
                    ) -> PyTree:
    """Rebuild ``tree``'s structure with values from ``flat``."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, old_leaf in paths:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = flat[key]
        expected = tuple(old_leaf.shape)
        if tuple(arr.shape) != expected:
            raise ValueError(
                f"checkpoint array {key!r} has shape {arr.shape}, "
                f"expected {expected}")
        leaves.append(place(key, arr) if place else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:09d}")


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3) -> None:
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    # ---------------- save ----------------
    def save(self, step: int, tree: PyTree,
             extra: Optional[Dict[str, Any]] = None) -> str:
        flat, dtypes = _flatten(tree)
        d = step_dir(self.root, step)
        tmp = tempfile.mkdtemp(dir=self.root, prefix=".tmp_save_")
        try:
            with open(os.path.join(tmp, ARRAYS), "wb") as f:
                np.savez(f, **flat)
                f.flush()
                os.fsync(f.fileno())
            manifest = {
                "step": step,
                "keys": sorted(flat),
                "shapes": {k: list(v.shape) for k, v in flat.items()},
                "dtypes": dtypes,
                "extra": extra or {},
            }
            mtmp = os.path.join(tmp, MANIFEST + ".tmp")
            with open(mtmp, "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.rename(mtmp, os.path.join(tmp, MANIFEST))
            if os.path.exists(d):
                shutil.rmtree(d)
            os.rename(tmp, d)            # atomic publish
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return d

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(step_dir(self.root, s), ignore_errors=True)

    # ---------------- discovery ----------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            if not name.startswith("step_"):
                continue
            if not os.path.exists(os.path.join(self.root, name, MANIFEST)):
                continue  # incomplete save — ignored
            try:
                out.append(int(name[len("step_"):]))
            except ValueError:
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ---------------- restore ----------------
    def restore(self, step: int, target: PyTree,
                shardings: Optional[PyTree] = None
                ) -> Tuple[PyTree, Dict[str, Any]]:
        """Load ``step`` into ``target``'s structure.

        ``shardings`` (same structure, NamedSharding leaves) re-places
        every array on the *current* mesh — elastic restore.
        """
        d = step_dir(self.root, step)
        with open(os.path.join(d, MANIFEST)) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, ARRAYS))
        flat = {k: _undo_storage(data[k], manifest["dtypes"].get(k, ""))
                for k in data.files}

        if shardings is not None:
            flat_shardings: Dict[str, Any] = {}
            for path, s in jax.tree_util.tree_flatten_with_path(
                    shardings)[0]:
                key = "/".join(_path_str(p) for p in path)
                flat_shardings[key] = s

            def place(key: str, arr: np.ndarray):
                s = flat_shardings.get(key)
                return jax.device_put(arr, s) if s is not None \
                    else jax.device_put(arr)
        else:
            place = None
        tree = _unflatten_into(target, flat, place)
        return tree, manifest.get("extra", {})

    def restore_latest(self, target: PyTree,
                       shardings: Optional[PyTree] = None
                       ) -> Optional[Tuple[int, PyTree, Dict[str, Any]]]:
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, target, shardings)
        return step, tree, extra


class AsyncCheckpointer:
    """Overlap checkpoint writes with training.

    ``save`` synchronously copies device arrays to host memory (cheap
    relative to a full serialize) and hands the file I/O to a worker
    thread; ``wait`` joins any in-flight save (call before exit or
    before restoring).  A failed background save surfaces on the next
    ``save``/``wait`` call rather than being silently dropped.
    """

    def __init__(self, manager: CheckpointManager) -> None:
        self.manager = manager
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: PyTree,
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # device -> host now

        def work() -> None:
            try:
                self.manager.save(step, host_tree, extra)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
