"""Checkpoint substrate: atomic manifests, async save, elastic restore."""
from repro.checkpoint.manager import (AsyncCheckpointer, CheckpointManager,
                                      step_dir)

__all__ = ["AsyncCheckpointer", "CheckpointManager", "step_dir"]
