import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell this lowers + compiles the
right step function (``train_step`` for train cells, ``prefill`` for
prefill cells, ``serve_step``/decode for decode cells) against the
production mesh — single-pod (16, 16) = 256 chips and multi-pod
(2, 16, 16) = 512 chips — using abstract ShapeDtypeStruct inputs (no
allocation).  It records ``memory_analysis()`` (fits?),
``cost_analysis()`` (FLOPs/bytes) and the collective bytes parsed from
the optimized HLO, which together feed EXPERIMENTS.md §Dry-run and
§Roofline.

Usage::

    python -m repro.launch.dryrun --arch granite-20b --shape train_4k \
        --mesh single --out results/dryrun
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
    python -m repro.launch.dryrun --all --subprocess   # isolate cells

NOTE: the two ``os.environ`` lines above MUST run before any jax
import (jax locks the device count on first init).  This module is the
only place that forces 512 host devices — tests and benchmarks see the
real device count.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import SHAPES, SHAPE_ORDER, cell_applicable, get_config
from repro.configs.registry import ARCH_ORDER
from repro.distributed.sharding import make_rules
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import cell_inputs
from repro.models import api
from repro.models.common import count_params
from repro.serve.engine import jit_decode_step
from repro.train.train_step import jit_train_step


def _active_param_fraction(cfg) -> float:
    """MoE: fraction of params active per token (shared+top_k experts)."""
    if cfg.family != "moe":
        return 1.0
    table = api.param_table(cfg)
    expert = sum(
        int(_prod(shape)) for name, (shape, _) in table.items()
        if ".moe.w_" in name or name.startswith("moe.w_"))
    total = count_params(table)
    m = cfg.moe
    return (total - expert + expert * m.top_k / m.n_experts) / total


def _prod(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Build mesh + jitted fn + abstract args and ``.lower()`` the cell."""
    cell = SHAPES[shape_name]
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = cell_inputs(arch, cell, cfg)

    with mesh:
        if spec.kind == "train":
            rules = make_rules(mesh, "fsdp_tp")
            fn = jit_train_step(cfg, rules)
            lowered = fn.lower(*spec.args)
        elif spec.kind == "prefill":
            rules = make_rules(mesh, "fsdp_tp")
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.train.train_step import batch_shardings

            def prefill_fn(params, batch):
                return api.prefill(cfg, rules, params, batch)

            param_sh = rules.table_shardings(api.param_table(cfg))
            bs = batch_shardings(cfg, rules)
            bs = {k: v for k, v in bs.items() if k in spec.args[1]}
            fn = jax.jit(prefill_fn, in_shardings=(param_sh, bs))
            lowered = fn.lower(*spec.args)
        else:  # decode
            rules = make_rules(mesh, "decode")
            fn = jit_decode_step(cfg, rules, spec.args[1])
            lowered = fn.lower(*spec.args)
    return lowered, mesh, cfg, cell


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             want_roofline: bool = True) -> Dict:
    """Lower + compile one cell; return the §Dry-run record."""
    cell = SHAPES[shape_name]
    cfg = get_config(arch)
    rec: Dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": cell.kind,
        "status": "",
    }
    if not cell_applicable(shape_name, cfg.supports_long_context):
        rec["status"] = "skipped"
        rec["skip_reason"] = ("full quadratic attention at 500k context; "
                              "see DESIGN.md §4")
        return rec

    t0 = time.time()
    lowered, mesh, cfg, cell = lower_cell(arch, shape_name, multi_pod)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    n_dev = 512 if multi_pod else 256
    rec["status"] = "ok"
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)

    try:
        mem = compiled.memory_analysis()
        arg = int(getattr(mem, "argument_size_in_bytes", 0))
        out = int(getattr(mem, "output_size_in_bytes", 0))
        tmp = int(getattr(mem, "temp_size_in_bytes", 0))
        ali = int(getattr(mem, "alias_size_in_bytes", 0))
        peak = int(getattr(mem, "peak_memory_in_bytes", 0))
        rec["memory"] = {
            "argument_bytes": arg, "output_bytes": out,
            "temp_bytes": tmp, "alias_bytes": ali,
            # live = args + temps + non-aliased outputs; `peak` from XLA
            # can under-report argument residency on CPU
            "peak_bytes_per_device": max(peak, arg + tmp + max(out - ali, 0)),
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    if want_roofline:
        text = compiled.as_text()
        rl = hlo_analysis.roofline_from_compiled(compiled, text)
        n_params = count_params(api.param_table(cfg))
        # input-embedding rows do no matmul FLOPs (pure gather); with
        # tied embeddings the table still earns its flops in the
        # unembed dot, so only UNtied input tables are excluded.
        if not cfg.tie_embeddings:
            n_params -= cfg.vocab_size * cfg.d_model
        act = _active_param_fraction(cfg)
        if cell.kind == "train":
            tokens = cell.global_batch * cell.seq_len
            mf = hlo_analysis.model_flops_train(n_params, tokens, act)
        elif cell.kind == "prefill":
            tokens = cell.global_batch * cell.seq_len
            mf = 2.0 * n_params * act * tokens
        else:
            mf = hlo_analysis.model_flops_decode(
                n_params, cell.global_batch, act)
        rl.finalize(model_flops=mf / n_dev)   # per-device useful flops
        rec["roofline"] = rl.to_dict()
        rec["n_params"] = n_params
        rec["active_frac"] = act
        del text
    return rec


def fmt_cell(rec: Dict) -> str:
    if rec["status"] == "skipped":
        return (f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:8s} "
                f"SKIP ({rec['skip_reason'][:40]}...)")
    r = rec.get("roofline", {})
    mem = rec.get("memory", {})
    peak = mem.get("peak_bytes_per_device", 0) / 2**30
    return (f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:8s} "
            f"ok  compile={rec['compile_s']:6.1f}s "
            f"peak={peak:6.2f}GiB/dev "
            f"Tc={r.get('t_compute', 0)*1e3:8.2f}ms "
            f"Tm={r.get('t_memory', 0)*1e3:8.2f}ms "
            f"Tcoll={r.get('t_collective', 0)*1e3:8.2f}ms "
            f"bound={r.get('bottleneck','-'):10s} "
            f"useful={r.get('useful_ratio', 0)*100:5.1f}%")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--subprocess", action="store_true",
                    help="run each cell in its own process (isolation)")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    archs = list(ARCH_ORDER) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPE_ORDER) if args.all or not args.shape \
        else [args.shape]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    with open(path) as f:
                        rec = json.load(f)
                    print("cached:", fmt_cell(rec))
                    continue
                if args.subprocess:
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--mesh", "multi" if multi else "single",
                           "--out", args.out]
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures += 1
                    continue
                try:
                    rec = run_cell(arch, shape, multi)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(fmt_cell(rec) if rec["status"] != "error"
                      else f"{arch:24s} {shape:12s} ERROR {rec['error'][:80]}")
                jax.clear_caches()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
