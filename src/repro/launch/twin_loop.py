"""The paper's loop as a CLI: twin + PBS-emulator co-simulation.

    python -m repro.launch.twin_loop                  # paper §4.1 setup
    python -m repro.launch.twin_loop --pool extended --ensemble 8
    python -m repro.launch.twin_loop --failures 2     # fault injection
    python -m repro.launch.twin_loop --backend pallas # kernel what-ifs
    python -m repro.launch.twin_loop --trace bursty   # diurnal arrivals
    python -m repro.launch.twin_loop --replay-grid 8  # S x P baseline grid
    python -m repro.launch.twin_loop --replay-grid 64 \\
        --shard 0 --block-size 16      # fleet: sharded + block-streamed
    python -m repro.launch.twin_loop --objective avg_wait
    python -m repro.launch.twin_loop \\
        --objective "min:avg_wait@util>=0.85"         # constrained goal
    python -m repro.launch.twin_loop --fan 64 --fan-noise 0.3 \\
        --objective "p95:avg_wait"    # Monte-Carlo fan, tail objective
    python -m repro.launch.twin_loop --replay-grid 8 --fan 128 \\
        --fan-fail 0.2 --objective "cvar:0.9:score" --prune
    python -m repro.launch.twin_loop --fan 64 --race --budget-ms 500 \\
        # raced fan: successive-halving to F_max=64, 500 ms anytime cap
    python -m repro.launch.twin_loop --replay-grid 8 --fan 64 --race \\
        --race-f0 4                   # raced S x F x P grid
    python -m repro.launch.twin_loop --train 24 --train-family lin \\
        --train-dir ckpt/policy       # learn θ (DESIGN.md §13) ...
    python -m repro.launch.twin_loop --pool trained:ckpt/policy,paper \\
        # ... then deploy it live, statics riding as the safety floor

``--objective`` is the administrator-configured optimization goal
(§3.4; ``repro.core.objective``, DESIGN.md §8): the goal grammar is
validated (parse -> spec -> parse round-trip) and the resolved goal is
logged at startup.  In twin mode it drives every decision cycle; in
``--replay-grid`` mode it drives the per-scenario policy selection.

``--replay-grid S`` skips the co-simulation and instead evaluates the
full (S scenarios × pool) baseline grid in ONE batched device replay
(``engine.replay_grid``, DESIGN.md §6), printing per-policy metrics
aggregated over scenarios.

``--race`` turns the fixed-F fan into a successive-halving race
(DESIGN.md §11): every policy starts at ``--race-f0`` members,
per-rung CIs eliminate statistically-dominated policies, survivors
double their fan up to ``--fan`` (= F_max), and CRN prefix-stability
means each rung replays only the new member suffix.  ``--budget-ms`` /
``--race-members`` make the race anytime.  Works in twin mode
(``SchedTwin(race=...)``) and in ``--replay-grid`` mode (including
sharded/block-streamed via ``--shard``/``--block-size``).

``--fan F`` evaluates every policy over an on-device Monte-Carlo fan
of F perturbed futures (DESIGN.md §10) — runtime noise
(``--fan-noise``), arrival-burst warps (``--fan-burst``), node-failure
draws (``--fan-fail``), deterministically keyed by ``--fan-seed``.
One base scenario is uploaded; the fan is expanded inside the jit, so
H2D traffic stays O(1) in F.  In twin mode decisions gain
device-computed confidence intervals (logged per cycle); in
``--replay-grid`` mode the grid becomes S × F × P and ``--prune``
turns on the goal-conditioned low-F pre-pass that drops dominated
policies before the full fan.

``--train G`` runs the on-device policy-learning loop (``repro.learn``,
DESIGN.md §13) for G generations instead of the co-simulation: each
generation's candidate θ population rides the fork axis of ONE batched
replay grid over training scenarios split deterministically from the
held-out set (``workload.split_scenarios``), scored by ``--objective``
(with ``--fan*`` flags domain-randomizing the training traces).  The
incumbent checkpoints to ``--train-dir`` and deploys via
``--pool trained:<dir>``; the final report scores it against the
``--pool`` statics on the held-out scenarios.  ``--resume`` continues
a training run from its latest checkpoint, bitwise.

``--pool`` takes the sweep grammar (``repro.core.policies.parse_pool``):
one fork per grid point, e.g. a DRAS-style 25-point parameter sweep
riding with the 7 static policies (k=32 forks, ONE batched drain):

    python -m repro.launch.twin_loop \\
        --pool "extended,wfp:a=1..5x5:tau=600..7200x5"
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro.cluster.emulator import ClusterEmulator, FailureSpec
from repro.cluster.workload import (bursty_trace, paper_synthetic_trace,
                                    poisson_trace)
from repro.core.engine import PASS_BACKENDS, DrainEngine
from repro.core.events import EventBus
from repro.core.fan import FanSpec
from repro.core.objective import Objective, validate_objective
from repro.core.policies import parse_pool
from repro.core.twin import SchedTwin


def resolve_objective(grammar: str) -> Objective:
    """Parse ``--objective`` with round-trip validation
    (``objective.validate_objective``), CLI-fatal on failure."""
    try:
        return validate_objective(grammar)
    except ValueError as e:
        raise SystemExit(str(e))


def make_fan(args) -> "FanSpec | None":
    """Build the ``FanSpec`` from the --fan* flags (None when off)."""
    if not args.fan:
        return None
    return FanSpec(n=args.fan, runtime_noise=args.fan_noise,
                   burst_amplitude=args.fan_burst,
                   failure_prob=args.fan_fail, seed=args.fan_seed)


def make_race(args):
    """Build the ``RaceSpec`` from --race/--race-f0/--budget-ms/
    --race-members over the --fan* spec (None when --race is off)."""
    if not args.race:
        return None
    from repro.core.race import RaceSpec
    return RaceSpec(fan=make_fan(args), f0=args.race_f0,
                    budget_ms=args.budget_ms or None,
                    max_members=args.race_members or None)


def raced_grid(args, engine, goal, pool, scen) -> None:
    """--replay-grid --race: the raced S × F × P grid.  Eliminated
    policies never reach full fidelity, so the report is the race
    ledger (rungs, members, separation), not the per-policy metric
    table a full grid prints."""
    import time

    race = make_race(args)
    fleet = args.shard != 1 or args.block_size
    t0 = time.perf_counter()
    if fleet:
        from repro.core.whatif import sharded_race_grid
        from repro.launch.mesh import make_fleet_mesh
        mesh = make_fleet_mesh(None if args.shard == 0 else args.shard)
        run = sharded_race_grid(mesh, engine=engine, objective=goal,
                                race=race,
                                block_size=args.block_size or None)
        out = run(scen, pool.spec)
        mode = (f"{mesh.shape['data']} shard(s), "
                f"block={args.block_size or 'whole rung'}")
    else:
        from repro.core.race import race_grid
        out = race_grid(scen, pool.spec, race, goal, engine=engine)
        mode = "one device per rung"
    wall = time.perf_counter() - t0
    S = int(out.costs.shape[0])
    print(f"raced grid: S={S} scenarios x F_max={race.f_max} x "
          f"P={len(pool)} policies ({mode}) in {wall:.2f}s")
    print(f"members: {out.members} of {out.members_full} fixed-F "
          f"({out.members_full / max(out.members, 1):.1f}x reduction), "
          f"{len(out.rungs)} rungs, stopped={out.stopped}")
    for r in out.rungs:
        el = ([pool.names[i] for i in r.eliminated]
              if r.eliminated else "-")
        print(f"  rung [{r.lo:3d},{r.hi:3d}) x {len(r.active)} "
              f"policies: {r.members} members, sep={r.separation:+.2f}, "
              f"eliminated {el}")
    names = [pool.names[int(i)] for i in out.keep]
    best = np.asarray(out.best)
    print(f"survivors at F={out.fan_size}: {names}")
    print(f"objective {goal}: per-scenario winners "
          f"{[pool.names[int(b)] for b in best]}")


def replay_grid(args, engine: DrainEngine, goal: Objective) -> None:
    """--replay-grid: the S × P baseline grid as ONE device replay,
    with the per-scenario policy selection under ``goal`` (S × F × P
    with --fan: every policy judged over F perturbed futures)."""
    import time

    from repro.configs.schedtwin import ReplayGridConfig

    cfg = ReplayGridConfig(scenarios=args.replay_grid, trace=args.trace,
                           n_jobs=args.jobs, total_nodes=args.nodes,
                           pool=args.pool, objective=goal, seed=args.seed,
                           backend=engine.backend)
    pool = cfg.make_pool()
    scen = cfg.make_scenarios()
    if args.race:
        return raced_grid(args, engine, cfg.make_objective(), pool, scen)
    fan = make_fan(args)
    fleet = args.shard != 1 or args.block_size
    prune_info = None
    if fleet:
        # the fleet engine: scenario axis sharded over the mesh and/or
        # streamed in fixed-size blocks (whatif.sharded_replay_grid /
        # sharded_fan_grid, DESIGN.md §§9–10)
        from repro.core.whatif import sharded_fan_grid, sharded_replay_grid
        from repro.launch.mesh import make_fleet_mesh
        mesh = make_fleet_mesh(None if args.shard == 0 else args.shard)
        if fan is not None:
            run = sharded_fan_grid(mesh, engine=engine,
                                   objective=cfg.make_objective(), fan=fan,
                                   block_size=args.block_size or None)
        else:
            run = sharded_replay_grid(mesh, engine=engine,
                                      objective=cfg.make_objective(),
                                      block_size=args.block_size or None,
                                      prefetch_depth=args.prefetch)
        mode = (f"{mesh.shape['data']} shard(s), "
                f"block={args.block_size or 'whole set'}, "
                f"prefetch={args.prefetch}")
    t0 = time.perf_counter()
    if fleet:
        out = run(scen, pool.spec)
    elif fan is not None and args.prune:
        from repro.core.fan import pruned_fan_grid
        out, prune_info = pruned_fan_grid(scen, pool.spec, fan,
                                          cfg.make_objective(),
                                          engine=engine)
        mode = "one device computation, pruned"
    elif fan is not None:
        out = engine.fan_grid(scen, pool.spec, fan, cfg.make_objective())
        mode = "one device computation"
    else:
        out = engine.replay_grid(scen, pool.spec, cfg.make_objective())
        mode = "one device computation"
    np.asarray(out.end_t)  # block
    wall = time.perf_counter() - t0
    S = int(out.deadlocked.shape[0])
    P = int(out.deadlocked.shape[-1])
    fan_txt = (f" x F={fan.n} fan members" if fan is not None else "")
    print(f"replay grid: S={S} scenarios{fan_txt} x P={P} policies "
          f"({int(np.prod(out.deadlocked.shape))} forks, {mode}) "
          f"in {wall:.2f}s")
    if prune_info is not None:
        kept = [pool.names[int(i)] for i in np.asarray(prune_info.keep)]
        print(f"prune: pre-pass F={prune_info.pre_members.shape[1]} "
              f"dropped {prune_info.rate * 100:.0f}% of the pool; "
              f"kept {kept}")
    print(f"{'policy':>16s} {'avg_wait':>9s} {'max_wait':>9s} "
          f"{'avg_sd':>7s} {'util':>6s} {'dead':>5s} {'picked':>7s}")
    m = out.metrics                 # (S, P), or (S, F, P) under --fan
    names = pool.names if prune_info is None \
        else [pool.names[int(i)] for i in np.asarray(prune_info.keep)]
    # per-scenario selection; sub-pool indexed when pruned (matches
    # ``names`` either way)
    best = np.asarray(out.best)
    for p, name in enumerate(names):
        print(f"{name:>16s} "
              f"{float(np.mean(np.asarray(m.avg_wait).reshape(-1, len(names))[:, p])):9.1f} "
              f"{float(np.mean(np.asarray(m.max_wait).reshape(-1, len(names))[:, p])):9.1f} "
              f"{float(np.mean(np.asarray(m.avg_slowdown).reshape(-1, len(names))[:, p])):7.2f} "
              f"{float(np.mean(np.asarray(m.utilization).reshape(-1, len(names))[:, p])):6.3f} "
              f"{int(np.asarray(out.deadlocked).reshape(-1, len(names))[:, p].sum()):5d} "
              f"{int((best == p).sum()):4d}/{S}")
    if fan is not None:
        # device-computed per-policy uncertainty, scenario-averaged
        ci = np.asarray(out.cost_ci)
        wd = np.asarray(out.fan_width)
        parts = " ".join(
            f"{n}={np.mean(ci[:, p]):.2f}±w{np.mean(wd[:, p]):.1f}"
            for p, n in enumerate(names))
        print(f"fan confidence (mean 95% CI half-width ± member "
              f"spread): {parts}")
    print(f"objective {goal}: per-scenario winners "
          f"{[names[int(b)] for b in best]}")


def train_mode(args, engine: DrainEngine, goal: Objective,
               floor_pool) -> None:
    """--train: the repro.learn loop — train θ on a deterministic
    scenario split, checkpoint to --train-dir, then score the incumbent
    against the --pool statics on the held-out scenarios (the same
    comparison ``--pool trained:<dir>,<statics>`` deploys live)."""
    import time

    from repro.cluster.workload import split_scenarios
    from repro.learn import TrainConfig, train

    rng = np.random.default_rng(args.seed)
    if args.trace == "paper":
        trace_fn = lambda r: paper_synthetic_trace(rng=r)
    elif args.trace == "bursty":
        trace_fn = lambda r: bursty_trace(
            args.jobs, args.nodes, 8.0, (1, args.nodes), (30.0, 900.0),
            rng=r)
    else:
        trace_fn = lambda r: poisson_trace(
            args.jobs, args.nodes, 8.0, (1, args.nodes), (30.0, 900.0),
            rng=r)
    train_scen, heldout = split_scenarios(
        rng, trace_fn, args.train_scenarios, args.train_heldout,
        args.nodes)
    cfg = TrainConfig(family=args.train_family,
                      strategy=args.train_strategy,
                      population=args.train_pop, generations=args.train,
                      objective=goal, seed=args.seed, fan=make_fan(args))
    print(f"train: {cfg.strategy}/{cfg.family} pop={cfg.population} x "
          f"{args.train_scenarios} train scenarios "
          f"(+{args.train_heldout} held-out), goal {goal}")
    t0 = time.perf_counter()
    res = train(train_scen, heldout, cfg, engine=engine,
                checkpoint_dir=args.train_dir or None,
                resume=args.resume, log_fn=print)
    wall = time.perf_counter() - t0
    print(f"trained {res.generations_run} generations in {wall:.1f}s"
          f"{' (early stop)' if res.stopped_early else ''}: "
          f"{res.best_desc}")

    # held-out scoreboard: incumbent + the --pool statics in ONE grid
    # (within-pool, so rank-based goals compare apples to apples)
    board = res.pool + floor_pool
    costs = np.asarray(engine.generation_costs(heldout, board.spec, goal),
                       np.float64)
    agg = costs.mean(axis=0)
    print(f"{'policy':>16s} {'held-out cost':>14s}")
    for p, name in enumerate(board.names):
        mark = " <- trained" if p == 0 else ""
        print(f"{name:>16s} {agg[p]:14.4f}{mark}")
    if args.train_dir:
        print(f"deploy: --pool trained:{args.train_dir}"
              f"{',' + args.pool if args.pool else ''}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", choices=("paper", "poisson", "bursty"),
                    default="paper")
    ap.add_argument("--no-compile-cache", action="store_true",
                    help="skip the persistent XLA compilation cache "
                         "(default: cache compiled engines under "
                         "~/.cache/repro-jax-cache so the ~1.5 s replay "
                         "compile is paid once per machine)")
    ap.add_argument("--jobs", type=int, default=150)
    ap.add_argument("--nodes", type=int, default=32)
    ap.add_argument("--pool", default="paper",
                    help="pool grammar: comma-separated policy terms, "
                         "optionally swept, e.g. 'paper', 'extended', "
                         "'wfp,fcfs,sjf,wfp:a=1..5x5' (see "
                         "policies.parse_pool)")
    ap.add_argument("--objective", default="score",
                    help="optimization goal grammar (core.objective."
                         "parse_objective): 'score' (paper default), "
                         "'avg_wait', '0.5*avg_wait+0.5*max_slowdown', "
                         "'lex:avg_wait,makespan', "
                         "'min:avg_wait@util>=0.85'")
    ap.add_argument("--ensemble", type=int, default=1)
    ap.add_argument("--fan", type=int, default=0, metavar="F",
                    help="decide over an on-device Monte-Carlo fan of F "
                         "perturbed futures per policy (DESIGN.md §10); "
                         "works in twin mode and with --replay-grid")
    ap.add_argument("--fan-noise", type=float, default=0.3,
                    help="lognormal runtime-noise sigma for fan members "
                         "(mean-preserving; member 0 stays exact)")
    ap.add_argument("--fan-burst", type=float, default=0.0,
                    help="arrival-burst warp amplitude in [0,1) for fan "
                         "members (replay mode only — a drain has no "
                         "future arrivals)")
    ap.add_argument("--fan-fail", type=float, default=0.0,
                    help="per-member node-failure probability; a hit "
                         "member loses a random fraction of the cluster")
    ap.add_argument("--fan-seed", type=int, default=0,
                    help="fan PRNG seed (member draws are keyed per "
                         "(scenario, member) — deterministic, resumable)")
    ap.add_argument("--race", action="store_true",
                    help="race the --fan via successive halving "
                         "(DESIGN.md §11): start every policy at "
                         "--race-f0 members, CI-eliminate dominated "
                         "policies per rung, double survivors' fans up "
                         "to --fan; prefix-stable CRN means no member "
                         "is ever replayed twice")
    ap.add_argument("--race-f0", type=int, default=8, metavar="F0",
                    help="rung-0 fan size for --race (default 8)")
    ap.add_argument("--budget-ms", type=float, default=0.0, metavar="MS",
                    help="anytime wall-clock budget per race; when it "
                         "runs out mid-race the current best is "
                         "returned with its achieved confidence")
    ap.add_argument("--race-members", type=int, default=0, metavar="M",
                    help="anytime (scenario, member, policy) triple "
                         "budget per race")
    ap.add_argument("--prune", action="store_true",
                    help="goal-conditioned pool pruning for --replay-grid "
                         "--fan: a cheap low-F pre-pass drops policies "
                         "the objective provably never selects, then the "
                         "full fan runs on the survivors")
    ap.add_argument("--failures", type=int, default=0)
    ap.add_argument("--budget-s", type=float, default=0.0, metavar="S",
                    help="wall-clock budget per decision cycle "
                         "(guard.DeadlineGuard, DESIGN.md §12): under "
                         "pressure the twin degrades down the ladder "
                         "(shrunk race/fan -> static pool -> hold "
                         "incumbent) instead of deciding late")
    ap.add_argument("--chaos", action="store_true",
                    help="read the bus through cluster.chaos.ChaosBus "
                         "with the default fault profile (drops, dups, "
                         "reordering, corruption, transient read "
                         "failures) — the hardened ingestion layer must "
                         "absorb all of it")
    ap.add_argument("--snapshot-dir", default="", metavar="DIR",
                    help="persist crash-safe twin snapshots (SimState + "
                         "consumer offset + RNG key + telemetry + "
                         "emulator/bus state) under DIR via "
                         "checkpoint.CheckpointManager")
    ap.add_argument("--snapshot-every", type=int, default=25, metavar="N",
                    help="snapshot every N decision cycles (with "
                         "--snapshot-dir; default 25)")
    ap.add_argument("--kill-after-cycle", type=int, default=0, metavar="K",
                    help="simulate a crash: snapshot and hard-exit after "
                         "decision cycle K (requires --snapshot-dir); "
                         "rerun with --resume to continue")
    ap.add_argument("--resume", action="store_true",
                    help="resume the co-simulation from the latest "
                         "snapshot in --snapshot-dir (same flags as the "
                         "original run)")
    ap.add_argument("--backend",
                    choices=sorted(PASS_BACKENDS) + ["auto"],
                    default="auto",
                    help="scheduling-pass backend for the what-if engine "
                         "(auto: reference on CPU, pallas on TPU)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--train", type=int, default=0, metavar="G",
                    help="train θ for G generations (repro.learn, "
                         "DESIGN.md §13) instead of running the twin: "
                         "each generation is ONE batched replay grid "
                         "with the candidate population on the fork "
                         "axis, scored by --objective")
    ap.add_argument("--train-family", choices=("lin", "wfp", "expf"),
                    default="lin",
                    help="policy family whose θ is searched (lin: "
                         "linear feature scorer; wfp/expf: the "
                         "parametric aging families)")
    ap.add_argument("--train-strategy", choices=("cem", "es"),
                    default="cem",
                    help="search strategy: cross-entropy (cem) or "
                         "OpenAI-style evolution strategy (es)")
    ap.add_argument("--train-pop", type=int, default=16, metavar="N",
                    help="candidate population per generation")
    ap.add_argument("--train-scenarios", type=int, default=8, metavar="S",
                    help="training scenarios (drawn with --trace/--seed "
                         "via workload.split_scenarios)")
    ap.add_argument("--train-heldout", type=int, default=4, metavar="S",
                    help="held-out scenarios for model selection and "
                         "early stopping (disjoint from training by "
                         "construction)")
    ap.add_argument("--train-dir", default="", metavar="DIR",
                    help="checkpoint directory for the trained policy "
                         "(deploy later with --pool trained:DIR); empty "
                         "trains in-memory only")
    ap.add_argument("--replay-grid", type=int, default=0, metavar="S",
                    help="evaluate an S-scenario x pool baseline grid in "
                         "one batched replay instead of running the "
                         "twin co-simulation")
    ap.add_argument("--shard", type=int, default=1, metavar="N",
                    help="shard the --replay-grid scenario axis over N "
                         "devices (0: all local devices) via the fleet "
                         "engine (whatif.sharded_replay_grid)")
    ap.add_argument("--block-size", type=int, default=0, metavar="B",
                    help="stream the --replay-grid in blocks of B "
                         "scenarios per device step (0: one shot); "
                         "bounds device memory at fleet scale")
    ap.add_argument("--prefetch", type=int, default=2, metavar="D",
                    help="host-side ingestion lookahead for block "
                         "streaming (0: ingest inline, no overlap)")
    args = ap.parse_args()
    if (args.shard != 1 or args.block_size or args.prefetch != 2) \
            and not args.replay_grid:
        ap.error("--shard/--block-size/--prefetch apply to --replay-grid")
    if args.replay_grid and (args.failures or args.ensemble > 1):
        ap.error("--replay-grid evaluates static baselines; --failures "
                 "and --ensemble do not apply (run the co-simulation "
                 "for those)")
    if args.fan and args.ensemble > 1:
        ap.error("--fan and --ensemble are mutually exclusive "
                 "(the fan subsumes the estimate-noise ensemble)")
    if args.prune and not (args.fan and args.replay_grid):
        ap.error("--prune applies to --replay-grid --fan")
    if args.race and not args.fan:
        ap.error("--race needs --fan F (F is the race's F_max)")
    if args.race and args.prune:
        ap.error("--race subsumes --prune (elimination is per rung)")
    if (args.race_f0 != 8 or args.budget_ms or args.race_members) \
            and not args.race:
        ap.error("--race-f0/--budget-ms/--race-members apply to --race")
    if args.replay_grid and (args.chaos or args.snapshot_dir
                             or args.budget_s):
        ap.error("--chaos/--snapshot-dir/--budget-s apply to the twin "
                 "co-simulation, not --replay-grid")
    if args.train:
        if args.replay_grid:
            ap.error("--train and --replay-grid are mutually exclusive")
        if (args.failures or args.ensemble > 1 or args.race
                or args.chaos or args.snapshot_dir or args.budget_s
                or args.prune or args.kill_after_cycle):
            ap.error("--train runs the learning loop; co-simulation and "
                     "racing flags do not apply")
        if args.resume and not args.train_dir:
            ap.error("--train --resume requires --train-dir")
    elif (args.train_dir or args.train_pop != 16
          or args.train_scenarios != 8 or args.train_heldout != 4):
        ap.error("--train-* flags apply to --train G")
    if (args.kill_after_cycle or args.resume) and not (
            args.snapshot_dir or args.train):
        ap.error("--kill-after-cycle/--resume require --snapshot-dir "
                 "(or --train --train-dir)")
    from repro.launch.cache import enable_persistent_cache
    enable_persistent_cache(enabled=not args.no_compile_cache)
    engine = DrainEngine(backend=args.backend)
    pool = parse_pool(args.pool)
    goal = resolve_objective(args.objective)
    print(f"pool: k={len(pool)} forks "
          f"[{', '.join(pool.names[:8])}{', ...' if len(pool) > 8 else ''}] "
          f"backend={engine.backend}")
    print(f"objective: {goal} ({type(goal).__name__})")

    if args.replay_grid:
        return replay_grid(args, engine, goal)
    if args.train:
        return train_mode(args, engine, goal, pool)

    if args.trace == "paper":
        trace = paper_synthetic_trace(seed=args.seed)
    elif args.trace == "bursty":
        trace = bursty_trace(args.jobs, args.nodes, 8.0, (1, args.nodes),
                             (30.0, 900.0), seed=args.seed)
    else:
        trace = poisson_trace(args.jobs, args.nodes, 8.0, (1, args.nodes),
                              (30.0, 900.0), seed=args.seed)

    rng = np.random.default_rng(args.seed)
    makespan_guess = len(trace) * 8.0
    failures = [FailureSpec(time=float(rng.uniform(0.2, 0.8) * makespan_guess),
                            nodes=max(1, args.nodes // 8),
                            duration=300.0)
                for _ in range(args.failures)]

    bus = EventBus()
    manager = None
    if args.snapshot_dir:
        from repro.checkpoint import CheckpointManager
        manager = CheckpointManager(args.snapshot_dir)
    if args.resume:
        # Peek at the manifest for the persisted bus log BEFORE building
        # the emulator/twin (both need the bus); twin.restore() then
        # re-reads the same step for everything else.
        import json
        import os

        from repro.checkpoint.manager import MANIFEST, step_dir
        step = manager.latest_step()
        if step is None:
            raise SystemExit(f"--resume: no snapshot under "
                             f"{args.snapshot_dir!r}")
        with open(os.path.join(step_dir(args.snapshot_dir, step),
                               MANIFEST)) as f:
            peek = json.load(f).get("extra", {}).get("app", {})
        bus = EventBus.from_dump(peek.get("bus", []))
    em = ClusterEmulator(trace, args.nodes, bus=bus, failures=failures,
                         check_invariants=True, engine=engine)
    race = make_race(args)
    view = bus
    if args.chaos:
        from repro.cluster.chaos import DEFAULT_PROFILE, ChaosBus
        view = ChaosBus(bus, dataclasses.replace(DEFAULT_PROFILE,
                                                 seed=args.seed))
        print(f"chaos: {view.spec}")
    twin = SchedTwin(
        bus=view, qrun=em.qrun, total_nodes=args.nodes,
        max_jobs=em.max_jobs, pool=pool, objective=goal,
        free_nodes_probe=lambda: em.free_nodes,
        jobs_probe=em.jobs_view, guard=args.budget_s or None,
        ensemble=args.ensemble, fan=None if race else make_fan(args),
        race=race, engine=engine)
    if args.resume:
        step, app = twin.restore(manager)
        em.restore_state(app["emulator"])
        print(f"resumed from snapshot step {step} "
              f"({len(twin.telemetry.cycles)} cycles already decided)")

    def take_snapshot():
        twin.snapshot(manager, app_extra={
            "emulator": em.snapshot_state(), "bus": bus.dump()})

    snap_next = [args.snapshot_every]

    def pump():
        twin.pump()
        cyc = len(twin.telemetry.cycles)
        if manager is not None and cyc >= snap_next[0]:
            take_snapshot()
            snap_next[0] = cyc + args.snapshot_every
        if args.kill_after_cycle and cyc >= args.kill_after_cycle:
            take_snapshot()
            raise SystemExit(
                f"killed after cycle {cyc} (snapshot persisted under "
                f"{args.snapshot_dir!r}; rerun with --resume)")

    report = em.run(on_event=pump, objective=goal,
                    on_quiesce=twin.flush)
    if manager is not None:
        take_snapshot()

    print(f"jobs={report.n_jobs} events={report.n_events} "
          f"restarts={report.n_restarts}")
    for k, v in report.metric_dict().items():
        print(f"  {k:14s} {v:10.2f}")
    if report.objective_cost is not None:
        print(f"objective cost ({report.objective}): "
              f"{report.objective_cost:.3f}")
    else:
        # rank-based goal: a lone run has no scalar cost — show terms
        terms = " ".join(f"{t}={v:.2f}"
                         for t, v in (report.objective_terms or {}).items())
        print(f"objective terms ({report.objective}): {terms}")
    breakdown = twin.telemetry.objective_breakdown()
    for name, terms in breakdown.items():
        parts = " ".join(f"{t}={v:.2f}" for t, v in terms.items())
        print(f"  whatif breakdown {name:>10s}: {parts}")
    print("policy mix:", {k: f"{v:.1f}%" for k, v in
                          twin.telemetry.policy_start_distribution().items()})
    conf = twin.telemetry.confidence_stats()
    if conf:
        # device-computed fan uncertainty (decide_fan / decide_race
        # stamps; DESIGN.md §§10–11) — no host recompute.  Racing makes
        # the per-cycle fan size variable; report the range actually
        # used, not cycle 0's.
        fmin = min(st["min_fan"] for st in conf.values())
        fmax = max(st["max_fan"] for st in conf.values())
        f_txt = (f"F={fmin:.0f}" if fmin == fmax
                 else f"F={fmin:.0f}..{fmax:.0f}")
        parts = " ".join(
            f"{n}=±{st['mean_ci']:.2f}(w{st['mean_width']:.1f})"
            for n, st in sorted(conf.items()))
        print(f"fan confidence ({f_txt}, mean 95% CI half-width, "
              f"member spread): {parts}")
    if race is not None and twin.telemetry.cycles:
        cs = [c for c in twin.telemetry.cycles if c.race_stopped]
        if cs:
            memb = sum(c.race_members for c in cs)
            full = len(cs) * race.f_max * len(pool)
            stops = {}
            for c in cs:
                stops[c.race_stopped] = stops.get(c.race_stopped, 0) + 1
            print(f"race: {memb} members over {len(cs)} cycles vs "
                  f"{full} fixed-F ({full / max(memb, 1):.1f}x "
                  f"reduction), mean {memb / len(cs):.1f}/cycle, "
                  f"stops {stops}")
    lat = twin.telemetry.cycle_latency_stats()
    print(f"cycle latency: mean {lat['mean_s'] * 1e3:.1f} ms, "
          f"p50 {lat['p50_s'] * 1e3:.1f} ms over {lat['n']} cycles")
    res = twin.telemetry.resilience_stats()
    print(f"resilience: miss_rate={res['miss_rate']:.3f} "
          f"(misses={res['deadline_misses']}/{res['cycles']}, "
          f"ladder_engaged={res['ladder_engaged']}, "
          f"max_level={res['max_level']}), ingest: "
          f"quarantined={res['quarantined']} dup={res['duplicates']} "
          f"reordered={res['reordered']} gaps={res['gaps']} "
          f"lost={res['lost']} resyncs={res['resyncs']} "
          f"read_retries={res['read_retries']}")
    print(f"bus health: {bus.health()}"
          + (f", chaos injected: {view.stats}" if args.chaos else ""))
    if twin.dead_letters:
        print(f"dead letters: {len(twin.dead_letters)} quarantined "
              f"(first: {twin.dead_letters[0].reason})")


if __name__ == "__main__":
    main()
