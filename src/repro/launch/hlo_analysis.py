"""Roofline terms from a compiled dry-run artifact.

``compiled.cost_analysis()`` counts every while-loop body ONCE
(verified empirically on this XLA build: a scan of 10 matmuls reports
the FLOPs of one), which would undercount scanned-layer models by
``n_layers * accum_steps``.  So this module walks the optimized HLO
text itself, with loop trip counts:

  cost(computation) = sum(local instruction costs)
                    + sum_over_calls(multiplier * cost(callee))

  * ``while`` ops multiply their body cost by the trip count parsed
    from the loop condition (the `compare(iv, constant(N)), LT`
    pattern XLA emits for counted loops);
  * fusions/calls/branches recurse with multiplier 1.

Local costs per instruction:
  * FLOPs: ``dot`` ops — 2 * numel(result) * contracted_size (batch
    dims excluded automatically since they appear in the result);
    convolutions likewise (we only use matmul-style einsums).
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (async `-start`
    counted, `-done` skipped).
  * HBM bytes: operands + result of every *top-level* instruction
    (fusion internals live in registers/VMEM and are not re-counted,
    matching HloCostAnalysis' post-fusion convention).

The three roofline terms (per device — the module is the per-device
SPMD program)::

    compute    = flops / PEAK_FLOPS_BF16
    memory     = bytes / HBM_BW
    collective = collective_bytes / ICI_BW
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"\b(pred|bf16|[sufc]\d+)\[([\d,]*)\]")
_INSTR_OP_RE = re.compile(r"=\s*(?:\([^=]*?\)|[\w\[\]\{\},\s]*?)\s*"
                          r"([\w\-]+)\(")
_CALLEE_RE = re.compile(r"(?:calls|to_apply|branch_computations)="
                        r"\{?%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'known_trip_count[\\":{]+n[\\"\s:]*\\?"?(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_numel(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _shape_numel(dims) * _DTYPE_BYTES.get(dtype, 4)


# Ring-algorithm wire factors: an all-reduce moves 2(n-1)/n ~= 2x its
# operand over the links; all-gather / reduce-scatter / all-to-all move
# (n-1)/n ~= 1x; a permute moves exactly 1x.  ``coll_bytes`` keeps the
# assignment's operand-sum convention; ``wire_bytes`` applies these
# factors so AR->RS conversions show their true effect (§Perf H2).
WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0,
               "reduce-scatter": 1.0, "all-to-all": 1.0,
               "collective-permute": 1.0}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_OPS})
    coll_count: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_OPS})

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k in COLLECTIVE_OPS:
            self.coll_bytes[k] += mult * other.coll_bytes[k]
            self.coll_count[k] += mult * other.coll_count[k]

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    @property
    def wire_bytes(self) -> float:
        return sum(WIRE_FACTOR[k] * v for k, v in self.coll_bytes.items())


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    line: str
    callees: List[str]
    result_shapes: List[Tuple[str, str]]     # [(dtype, dims), ...]
    operands: List[str]                      # %-names inside the call


_NAME_RE = re.compile(r"^%?([\w\.\-]+)\s*=")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_COMMENT_RE = re.compile(r"/\*.*?\*/")


class HloModule:
    """Parsed-enough view of an optimized HLO module dump.

    Scheduled dumps omit inline operand types, so every computation
    carries a symbol table (instruction name -> result shapes) used to
    look up operand sizes for dots / collectives / byte counts.
    """

    def __init__(self, text: str) -> None:
        self.computations: Dict[str, List[_Instr]] = {}
        self.symtab: Dict[str, Dict[str, List[Tuple[str, str]]]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._cost_memo: Dict[str, Cost] = {}
        self._trip_memo: Dict[str, float] = {}

    # ------------------------- parsing -------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.strip()
            if "/*" in line:
                line = _COMMENT_RE.sub("", line)  # /*index=N*/ in tuples
            if cur is None:
                # computation header: "%name (params...) -> result {"
                # or "ENTRY %name (params...) -> result {"
                if line.endswith("{") and "->" in line:
                    tok = line.split()
                    name = tok[1] if tok[0] == "ENTRY" else tok[0]
                    cur = name.lstrip("%")
                    self.computations[cur] = []
                    self.symtab[cur] = {}
                    if tok[0] == "ENTRY":
                        self.entry = cur
                continue
            if line.startswith("}"):
                cur = None
                continue
            if "=" not in line:
                continue
            om = _INSTR_OP_RE.search(line)
            if not om:
                continue
            nm = _NAME_RE.match(line.removeprefix("ROOT ").strip())
            name = nm.group(1) if nm else ""
            op = om.group(1)
            # result shapes: between '=' and the op token
            head = line.split("=", 1)[1]
            head = head[:head.index(op + "(")]
            res_shapes = _SHAPE_RE.findall(head)
            # operand names: inside the call parens, before any attrs
            args = line[line.index(op + "(") + len(op) + 1:]
            depth = 1
            end = 0
            for i, ch in enumerate(args):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operands = _OPERAND_RE.findall(args[:end])
            callees = _CALLEE_RE.findall(line)
            ins = _Instr(name=name, op=op, line=line, callees=callees,
                         result_shapes=res_shapes, operands=operands)
            self.computations[cur].append(ins)
            if name:
                self.symtab[cur][name] = res_shapes

    # ------------------------- shape lookups --------------------------
    def _operand_shapes(self, comp: str, ins: _Instr
                        ) -> List[Tuple[str, str]]:
        # prefer inline types (unscheduled dumps); else symbol table
        args = ins.line[ins.line.index(ins.op + "(") + len(ins.op) + 1:]
        inline = _SHAPE_RE.findall(args.split("),", 1)[0])
        if inline:
            return inline
        out: List[Tuple[str, str]] = []
        tab = self.symtab.get(comp, {})
        for o in ins.operands:
            out.extend(tab.get(o, []))
        return out

    def _operand_bytes(self, comp: str, ins: _Instr) -> float:
        return float(sum(_shape_bytes(d, s)
                         for d, s in self._operand_shapes(comp, ins)))

    def _result_bytes(self, ins: _Instr) -> float:
        return float(sum(_shape_bytes(d, s) for d, s in ins.result_shapes))

    def _inplace_update_bytes(self, comp: str, ins: _Instr) -> float:
        """dynamic-update-slice traffic: the big buffer is updated in
        place (XLA aliases it), so real bytes = 2x the update slice +
        scalars — NOT operand+result (which would charge the full
        KV-cache per decode step)."""
        shapes = self._operand_shapes(comp, ins)
        if len(shapes) < 2:
            return self._result_bytes(ins)
        sizes = sorted(_shape_bytes(d, s) for d, s in shapes)
        big = sizes[-1]
        rest = sum(sizes[:-1])
        return float(2 * rest + 0 * big)

    def _root_op(self, comp: str) -> str:
        instrs = self.computations.get(comp, [])
        for ins in instrs:
            if "ROOT" in ins.line:
                return ins.op
        return instrs[-1].op if instrs else ""

    _FREE_CONVERT_OPS = frozenset(
        {"parameter", "convert", "bitcast", "constant"})
    _UPCAST_OPS = _FREE_CONVERT_OPS | frozenset(
        {"copy", "reshape", "broadcast", "transpose", "compare", "select",
         "dynamic-update-slice", "dynamic-slice", "iota", "partition-id",
         "concatenate", "gather", "add", "subtract", "multiply", "divide",
         "and", "or", "not", "xor", "minimum", "maximum", "negate",
         "clamp", "abs", "sign", "floor", "ceil"})

    def _is_pure_convert(self, comp: str) -> bool:
        """A fusion that only converts dtypes.  On the TPU target these
        never hit HBM: the MXU consumes bf16 operands of mixed-precision
        dots directly, so XLA:TPU fuses the convert into the consumer.
        XLA:CPU materializes them — charging those bytes would put a
        CPU-only artifact into the roofline (DESIGN.md §2)."""
        instrs = self.computations.get(comp, [])
        return bool(instrs) and all(
            i.op in self._FREE_CONVERT_OPS for i in instrs)

    def _upcast_fusion_bytes(self, comp: str, ins: _Instr
                             ) -> Optional[float]:
        """XLA:CPU fuses (in-place cache update + bf16->f32 upcast) into
        one cache-shaped f32 fusion feeding a dot.  On TPU the dot reads
        the bf16 cache directly, so the honest charge is the in-place
        update traffic only (the cache read is charged at the dot).
        Returns None when the fusion doesn't match this pattern."""
        if not ins.callees:
            return None
        if not all(i.op in self._UPCAST_OPS
                   for c in ins.callees
                   for i in self.computations.get(c, [])):
            return None
        if not ins.result_shapes:
            return None
        res_d, res_s = ins.result_shapes[0]
        shapes = self._operand_shapes(comp, ins)
        if not shapes:
            return None
        big_d, big_s = max(shapes, key=lambda p: _shape_bytes(*p))
        if (_shape_numel(res_s) == _shape_numel(big_s)
                and _DTYPE_BYTES.get(res_d, 4) >= _DTYPE_BYTES.get(big_d, 4)):
            rest = sum(_shape_bytes(d, s) for d, s in shapes) \
                - _shape_bytes(big_d, big_s)
            return float(2 * rest)
        return None

    # ------------------------- trip counts ----------------------------
    def trip_count(self, while_line: str, cond_comp: Optional[str]) -> float:
        """XLA annotates counted loops with
        ``backend_config={"known_trip_count":{"n":"10"}, ...}`` — use it
        directly; fall back to the largest constant in the loop
        condition computation (the loop bound) when absent."""
        m = _TRIP_RE.search(while_line)
        if m:
            return float(m.group(1))
        if not cond_comp:
            return 1.0
        if cond_comp in self._trip_memo:
            return self._trip_memo[cond_comp]
        consts = []
        for ins in self.computations.get(cond_comp, []):
            consts += [int(c) for c in _CONST_RE.findall(ins.line)]
        n = float(max(consts)) if consts else 1.0
        self._trip_memo[cond_comp] = n
        return n

    # ------------------------- instruction costs ---------------------
    def _dot_flops(self, comp: str, ins: _Instr) -> float:
        if not ins.result_shapes:
            return 0.0
        ops = self._operand_shapes(comp, ins)
        cm = _CONTRACT_RE.search(ins.line)
        if not ops or cm is None:
            return 0.0
        lhs_dims = ops[0][1].split(",") if ops[0][1] else []
        k = 1
        for idx in (cm.group(1).split(",") if cm.group(1) else []):
            k *= int(lhs_dims[int(idx)])
        return 2.0 * _shape_numel(ins.result_shapes[0][1]) * k

    # ------------------------- recursion ------------------------------
    def cost(self, comp: Optional[str] = None) -> Cost:
        comp = comp or self.entry
        if comp in self._cost_memo:
            return self._cost_memo[comp]
        total = Cost()
        self._cost_memo[comp] = total  # break cycles defensively
        for ins in self.computations.get(comp, []):
            base = ins.op.replace("-start", "")
            io_bytes = self._result_bytes(ins) \
                + self._operand_bytes(comp, ins)
            if base in COLLECTIVE_OPS and not ins.op.endswith("-done"):
                total.coll_bytes[base] += self._operand_bytes(comp, ins)
                total.coll_count[base] += 1
                total.bytes += io_bytes
            elif ins.op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                trips = self.trip_count(ins.line, cond)
                if body:
                    total.add(self.cost(body), trips)
            elif ins.op == "dot":
                total.flops += self._dot_flops(comp, ins)
                total.bytes += io_bytes
            elif ins.op == "dynamic-update-slice":
                total.bytes += self._inplace_update_bytes(comp, ins)
            elif ins.op == "dynamic-slice":
                # read the slice + write it: 2x result, not the operand
                total.bytes += 2 * self._result_bytes(ins)
            elif ins.op in ("fusion", "call", "conditional",
                            "custom-call", "map", "reduce",
                            "reduce-window", "sort", "scatter",
                            "async-start"):
                roots = {self._root_op(c) for c in ins.callees}
                upcast = self._upcast_fusion_bytes(comp, ins)
                if any(self._is_pure_convert(c) for c in ins.callees):
                    pass  # TPU-fused dtype convert: no HBM traffic
                elif upcast is not None:
                    total.bytes += upcast
                elif "dynamic-update-slice" in roots:
                    total.bytes += self._inplace_update_bytes(comp, ins)
                else:
                    total.bytes += io_bytes
                for callee in ins.callees:
                    # fusions: recurse for dots/collectives hidden
                    # inside; internal bytes are registers — skip.
                    sub = self.cost(callee)
                    total.flops += sub.flops
                    for k in COLLECTIVE_OPS:
                        total.coll_bytes[k] += sub.coll_bytes[k]
                        total.coll_count[k] += sub.coll_count[k]
            elif ins.op in ("parameter", "constant", "get-tuple-element",
                            "tuple", "bitcast", "after-all", "iota",
                            "partition-id", "replica-id", "convert"):
                # convert: free under the TPU-dot convention (the MXU
                # reads bf16 operands directly; XLA:TPU fuses converts
                # into consumers — XLA:CPU materializes them).
                pass  # free
            else:
                total.bytes += io_bytes
        self._cost_memo[comp] = total
        return total


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device FLOPs (trip-count aware)
    bytes_accessed: float        # per-device bytes (proxy, see module doc)
    collective_bytes: float      # per-device collective operand bytes
    collective_counts: Dict[str, float]
    collective_by_kind: Dict[str, float]
    wire_bytes: float = 0.0      # ring-factor-weighted (see WIRE_FACTOR)
    xla_flops_raw: float = 0.0   # cost_analysis() raw value (no trips)
    xla_bytes_raw: float = 0.0
    # derived (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0     # useful flops (per-device share)
    useful_ratio: float = 0.0    # model_flops / hlo_flops

    def finalize(self, model_flops: float = 0.0) -> "Roofline":
        self.t_compute = self.flops / PEAK_FLOPS_BF16
        self.t_memory = self.bytes_accessed / HBM_BW
        wire = self.wire_bytes if self.wire_bytes else self.collective_bytes
        self.t_collective = wire / ICI_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        self.model_flops = model_flops
        self.useful_ratio = (model_flops / self.flops) if self.flops else 0.0
        return self

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def roofline_from_compiled(compiled, hlo_text: Optional[str] = None
                           ) -> Roofline:
    text = hlo_text if hlo_text is not None else compiled.as_text()
    mod = HloModule(text)
    cost = mod.cost(mod.entry)
    try:
        xc = compiled.cost_analysis()
        if isinstance(xc, list):
            xc = xc[0]
        xla_flops = float(xc.get("flops", 0.0))
        xla_bytes = float(xc.get("bytes accessed", 0.0))
    except Exception:  # pragma: no cover
        xla_flops = xla_bytes = 0.0
    return Roofline(
        flops=cost.flops,
        bytes_accessed=cost.bytes,
        collective_bytes=cost.total_coll_bytes,
        collective_counts=dict(cost.coll_count),
        collective_by_kind=dict(cost.coll_bytes),
        wire_bytes=cost.wire_bytes,
        xla_flops_raw=xla_flops,
        xla_bytes_raw=xla_bytes,
    )


def model_flops_train(n_params: int, n_tokens: int,
                      active_frac: float = 1.0) -> float:
    """6*N*D (fwd+bwd) useful FLOPs; MoE passes active param fraction."""
    return 6.0 * n_params * active_frac * n_tokens


def model_flops_decode(n_params: int, n_tokens: int,
                       active_frac: float = 1.0) -> float:
    """2*N per generated token (fwd only)."""
    return 2.0 * n_params * active_frac * n_tokens


# Back-compat: tests import collective_stats for targeted HLO snippets.
def collective_stats(hlo_lines) -> Cost:
    mod = HloModule("\n".join(
        ["ENTRY %main () -> f32[] {"] + list(hlo_lines) + ["}"]))
    return mod.cost("main")
