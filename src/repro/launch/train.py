"""Training driver.

CPU-scale (this container)::

    python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Production (TPU pod; full config + production mesh)::

    python -m repro.launch.train --arch qwen2-72b --mesh production

The loop wires every substrate together: synthetic data pipeline,
microbatched AdamW step, async checkpointing with restart-on-launch,
and (optionally) int8 gradient compression.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, SyntheticLM, host_slice, prefetch
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train import (OptimizerConfig, init_train_state, jit_train_step,
                         state_shardings)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--mesh", choices=("host", "production", "multipod"),
                    default="host")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression + error feedback")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
    rules = make_rules(mesh, "fsdp_tp")

    opt_cfg = OptimizerConfig(peak_lr=args.lr, warmup_steps=args.steps // 20,
                              total_steps=args.steps)
    step_fn = jit_train_step(cfg, rules, opt_cfg, compress=args.compress,
                             accum_steps=args.accum)

    state = init_train_state(jax.random.PRNGKey(args.seed), cfg,
                             compress=args.compress)
    start_step = 0
    ckpt = saver = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        saver = AsyncCheckpointer(ckpt)
        got = ckpt.restore_latest(state)
        if got is not None:
            start_step, state, extra = got
            print(f"restored checkpoint at step {start_step}")

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed))

    def batches():
        s = start_step
        while True:
            yield s, data.batch(s)
            s += 1

    with mesh:
        t0 = time.time()
        tokens = 0
        for s, host_batch in prefetch(iter(batches()), depth=2):
            if s >= args.steps:
                break
            batch = {k: jnp.asarray(v) for k, v in
                     host_slice(host_batch).items()}
            if cfg.family == "vlm":
                batch["patches"] = jnp.zeros(
                    (args.batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
            if cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (args.batch, args.seq, cfg.d_model), jnp.bfloat16)
            state, metrics = step_fn(state, batch)
            tokens += args.batch * args.seq
            if (s + 1) % args.log_every == 0:
                loss = float(metrics["loss"])
                dt = time.time() - t0
                print(f"step {s + 1:5d} loss {loss:7.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"tok/s {tokens / dt:9.0f}")
            if saver is not None and (s + 1) % args.ckpt_every == 0:
                saver.save(s + 1, state, extra={"tokens": tokens})
        if saver is not None:
            saver.save(args.steps, state, extra={"tokens": tokens})
            saver.wait()
    print("done.")


if __name__ == "__main__":
    main()
