"""Abstract input specs for the dry-run: ShapeDtypeStruct stand-ins for
every model input — weak-type-correct, shardable, no device allocation.

``cell_inputs(arch, shape)`` returns everything ``dryrun`` needs to
lower the right step function for that cell:

  train cells   -> (abstract TrainState, abstract batch)
  prefill cells -> (abstract params, abstract batch)
  decode cells  -> (abstract params, abstract caches, tokens, index)
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import ShapeCell, get_config
from repro.configs.base import ModelConfig
from repro.models import api
from repro.models.common import abstract_params
from repro.train.train_step import abstract_train_state


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


class CellSpec(NamedTuple):
    kind: str                 # "train" | "prefill" | "decode"
    cfg: ModelConfig
    args: tuple               # abstract positional args for the step fn


def train_batch_specs(cfg: ModelConfig, batch: int, seq: int
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    out = {
        "tokens": sds((batch, seq), jnp.int32),
        "labels": sds((batch, seq), jnp.int32),
        "mask": sds((batch, seq), jnp.int32),
    }
    if cfg.family == "vlm":
        out["patches"] = sds((batch, cfg.n_patches, cfg.d_model),
                             jnp.bfloat16)
    if cfg.family == "encdec":
        s_enc = max(int(seq * cfg.encoder_seq_ratio), 1)
        out["frames"] = sds((batch, s_enc, cfg.d_model), jnp.bfloat16)
    return out


def prefill_batch_specs(cfg: ModelConfig, batch: int, seq: int
                        ) -> Dict[str, jax.ShapeDtypeStruct]:
    out = {"tokens": sds((batch, seq), jnp.int32)}
    if cfg.family == "vlm":
        out["patches"] = sds((batch, cfg.n_patches, cfg.d_model),
                             jnp.bfloat16)
    if cfg.family == "encdec":
        s_enc = max(int(seq * cfg.encoder_seq_ratio), 1)
        out["frames"] = sds((batch, s_enc, cfg.d_model), jnp.bfloat16)
    return out


def abstract_caches(cfg: ModelConfig, batch: int, seq: int) -> Any:
    """ShapeDtypeStruct cache tree (eval_shape — no allocation)."""
    return jax.eval_shape(lambda: api.init_caches(cfg, batch, seq))


def cell_inputs(arch: str, cell: ShapeCell,
                cfg: Optional[ModelConfig] = None) -> CellSpec:
    cfg = cfg or get_config(arch)
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        state = abstract_train_state(cfg)
        batch = train_batch_specs(cfg, b, s)
        return CellSpec("train", cfg, (state, batch))
    if cell.kind == "prefill":
        params = abstract_params(api.param_table(cfg))
        batch = prefill_batch_specs(cfg, b, s)
        return CellSpec("prefill", cfg, (params, batch))
    if cell.kind == "decode":
        params = abstract_params(api.param_table(cfg))
        caches = abstract_caches(cfg, b, s)
        tokens = sds((b, 1), jnp.int32)
        index = sds((), jnp.int32)
        return CellSpec("decode", cfg, (params, caches, tokens, index))
    raise ValueError(cell.kind)


def input_specs(arch: str, shape_name: str = "train_4k") -> Dict[str, Any]:
    """Flat convenience view (README snippets / quick inspection)."""
    from repro.configs import SHAPES
    spec = cell_inputs(arch, SHAPES[shape_name])
    if spec.kind == "train":
        return {"state": spec.args[0], "batch": spec.args[1]}
    if spec.kind == "prefill":
        return {"params": spec.args[0], "batch": spec.args[1]}
    return {"params": spec.args[0], "caches": spec.args[1],
            "tokens": spec.args[2], "index": spec.args[3]}
