"""Serving driver: continuous batching over synthetic requests.

    python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --requests 16 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import api
from repro.models.common import init_params
from repro.serve import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", choices=("host", "production"), default="host")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh())
    rules = make_rules(mesh, "decode")
    params = init_params(jax.random.PRNGKey(args.seed),
                         api.param_table(cfg))

    rng = np.random.default_rng(args.seed)
    reqs = []
    for r in range(args.requests):
        plen = int(rng.integers(4, args.max_seq // 2))
        reqs.append(Request(
            req_id=r,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=args.max_new))

    with mesh:
        eng = ServingEngine(cfg, rules, params, batch_slots=args.slots,
                            max_seq=args.max_seq)
        t0 = time.time()
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        dt = time.time() - t0

    toks = sum(len(r.output) for r in reqs)
    ttfts = [r.first_token_t - r.arrival_t for r in reqs
             if r.first_token_t is not None]
    print(f"served {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")
    print(f"mean TTFT {np.mean(ttfts):.1f} engine-steps, "
          f"mean tokens/req {toks / len(reqs):.1f}")


if __name__ == "__main__":
    main()
