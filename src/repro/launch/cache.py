"""Persistent JAX compilation cache for the launch/benchmark CLIs.

The batched replay's first call costs ~1.2–1.7 s of XLA compilation
(``batched_first_s`` in BENCH_replay.json) and every drain-engine
configuration (backend × pool shape × compaction flags) compiles its
own while-loop.  Those compilations are deterministic, so they should
be paid once per machine, not once per process: this module points
JAX's persistent compilation cache at a per-user directory so repeat
invocations of ``repro.launch.twin_loop`` and ``benchmarks.run`` start
from warm HLO.

Opt-out: pass ``--no-compile-cache`` on the CLIs (or call
``enable_persistent_cache(enabled=False)``), e.g. when benchmarking
cold-compile latency itself or on read-only filesystems.  The cache
directory resolves from ``REPRO_JAX_CACHE_DIR`` when set.
"""
from __future__ import annotations

import logging
import os
from pathlib import Path
from typing import Optional

import jax

logger = logging.getLogger(__name__)

ENV_VAR = "REPRO_JAX_CACHE_DIR"
DEFAULT_DIR = "~/.cache/repro-jax-cache"


def enable_persistent_cache(cache_dir: Optional[str] = None,
                            enabled: bool = True) -> Optional[str]:
    """Point JAX's persistent compilation cache at a durable directory.

    Returns the resolved cache path, or None when disabled or when the
    directory cannot be created (the run proceeds uncached — never
    fatal).  Thresholds are zeroed so even sub-second kernels (the
    engine's many small jits) are cached.
    """
    if not enabled:
        logger.info("persistent compilation cache disabled (opt-out)")
        return None
    path = Path(cache_dir or os.environ.get(ENV_VAR, DEFAULT_DIR))
    path = path.expanduser()
    try:
        path.mkdir(parents=True, exist_ok=True)
    except OSError as e:
        logger.warning("cannot create compilation cache dir %s (%s); "
                       "continuing uncached", path, e)
        return None
    try:
        jax.config.update("jax_compilation_cache_dir", str(path))
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except AttributeError as e:  # older jax without these flags
        logger.warning("persistent compilation cache unavailable in this "
                       "jax (%s); continuing uncached", e)
        return None
    logger.info("persistent compilation cache at %s", path)
    return str(path)
