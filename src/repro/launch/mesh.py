"""Production mesh builders.

``make_production_mesh`` is a FUNCTION — importing this module never
touches jax device state.  Single pod = (data=16, model=16) over 256
chips (TPU v5e pod); multi-pod adds a leading ``pod`` axis (2 pods =
512 chips).  The ``pod`` axis defaults to extra data parallelism
(FSDP over ('pod','data')); the sharding rules in
``repro/distributed/sharding.py`` treat ('pod','data') as the DP axes
everywhere, so the same model code runs on either mesh.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit/auto axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x — meshes are implicitly "auto"
    AxisType = None


def shard_map(f, mesh: Mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` (the fleet engine's per-device
    SPMD primitive): top-level ``jax.shard_map`` on new jax, the
    ``jax.experimental`` spelling on 0.4.x.  Replication checking is
    disabled where the knob exists — every fleet output is explicitly
    sharded or reduced by the caller, and the checker predates
    while-loop-heavy bodies like the drain."""
    try:
        smap = jax.shard_map                      # jax >= 0.6
    except AttributeError:
        from jax.experimental.shard_map import shard_map as smap
    try:
        return smap(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_rep=False)
    except TypeError:                             # knob renamed/removed
        return smap(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def _make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Generic builder (tests / degraded-fleet elastic re-mesh)."""
    return _make_mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None) -> Mesh:
    """Whatever this host has (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    m = model or 1
    assert n % m == 0
    return make_mesh((n // m, m), ("data", "model"))


def make_fleet_mesh(shards: Optional[int] = None) -> Mesh:
    """A (data=shards, model=1) mesh for the fleet replay engine
    (``whatif.sharded_replay_grid``): scenarios shard over ``data``.
    Defaults to every local device; unlike ``jax.make_mesh`` it accepts
    a PREFIX of the device list, so ``--shard 2`` works on an 8-chip
    host without reshaping the rest of the fleet away."""
    n = len(jax.devices())
    s = n if shards is None else int(shards)
    if not 1 <= s <= n:
        raise ValueError(f"shards={s} outside [1, {n}] local devices")
    return Mesh(np.asarray(jax.devices()[:s]).reshape(s, 1),
                ("data", "model"))


# TPU v5e hardware constants (per chip) — the roofline denominators.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link
