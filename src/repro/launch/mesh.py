"""Production mesh builders.

``make_production_mesh`` is a FUNCTION — importing this module never
touches jax device state.  Single pod = (data=16, model=16) over 256
chips (TPU v5e pod); multi-pod adds a leading ``pod`` axis (2 pods =
512 chips).  The ``pod`` axis defaults to extra data parallelism
(FSDP over ('pod','data')); the sharding rules in
``repro/distributed/sharding.py`` treat ('pod','data') as the DP axes
everywhere, so the same model code runs on either mesh.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit/auto axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x — meshes are implicitly "auto"
    AxisType = None


def _make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Generic builder (tests / degraded-fleet elastic re-mesh)."""
    return _make_mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None) -> Mesh:
    """Whatever this host has (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    m = model or 1
    assert n % m == 0
    return make_mesh((n // m, m), ("data", "model"))


# TPU v5e hardware constants (per chip) — the roofline denominators.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link
