"""Shared model building blocks + the parameter-table convention.

Every architecture describes its parameters declaratively via a
*param table*: ``name -> (shape, logical_axes)``.  From one table we
derive (a) random initialization, (b) abstract ShapeDtypeStructs for
the dry-run, and (c) PartitionSpecs through a logical->mesh axis rule
set (``repro/distributed/sharding.py``).  Layer stacks add a leading
``"layers"`` axis and are applied with ``lax.scan``.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

ParamTable = Dict[str, Tuple[Tuple[int, ...], Tuple[Optional[str], ...]]]
Params = Dict[str, jax.Array]


# ----------------------------------------------------------------------
# Param-table helpers
# ----------------------------------------------------------------------

def stack_table(table: ParamTable, n_layers: int) -> ParamTable:
    """Add a leading scanned-layers axis to every entry."""
    return {k: ((n_layers,) + shape, ("layers",) + axes)
            for k, (shape, axes) in table.items()}


def prefix_table(prefix: str, table: ParamTable) -> ParamTable:
    return {f"{prefix}.{k}": v for k, v in table.items()}


def merge_tables(*tables: ParamTable) -> ParamTable:
    out: ParamTable = {}
    for t in tables:
        dup = set(out) & set(t)
        if dup:
            raise ValueError(f"duplicate param names: {dup}")
        out.update(t)
    return out


def init_params(key: jax.Array, table: ParamTable,
                dtype=jnp.bfloat16, scale: float = 0.02) -> Params:
    """Truncated-normal-ish init; norm gains/biases get ones/zeros."""
    params: Params = {}
    keys = jax.random.split(key, max(len(table), 1))
    for (name, (shape, _)), k in zip(sorted(table.items()), keys):
        if name.endswith(("norm.scale", "ln.scale")):
            params[name] = jnp.ones(shape, dtype=dtype)
        elif name.endswith((".bias", "norm.bias", ".decay_bias")):
            params[name] = jnp.zeros(shape, dtype=dtype)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = min(scale, 1.0 / math.sqrt(max(fan_in, 1)))
            params[name] = (std * jax.random.normal(k, shape)).astype(dtype)
    return params


def abstract_params(table: ParamTable, dtype=jnp.bfloat16) -> Params:
    """ShapeDtypeStruct pytree — dry-run stand-in (no allocation)."""
    return {name: jax.ShapeDtypeStruct(shape, dtype)
            for name, (shape, _) in table.items()}


# ----------------------------------------------------------------------
# Primitive layers (pure functions over the params dict)
# ----------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale + bias


def rope(x: jax.Array, positions: jax.Array,
         theta: float = 10000.0) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, D) or (..., S, D); positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == ang.ndim + 1:          # (..., S, H, D): broadcast over heads
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(dt)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
             w_out: jax.Array, b_out: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_in) + b_in)
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out


# ----------------------------------------------------------------------
# Vocabulary / loss
# ----------------------------------------------------------------------

def embed(tokens: jax.Array, embedding: jax.Array) -> jax.Array:
    return jnp.take(embedding, tokens, axis=0)


def unembed(x: jax.Array, embedding: jax.Array) -> jax.Array:
    """Logits via tied or untied unembedding: (..., d) @ (V, d)^T."""
    return jnp.einsum("...d,vd->...v", x, embedding)


def chunked_softmax_xent(x: jax.Array, labels: jax.Array,
                         unembed_w: jax.Array, mask: jax.Array,
                         chunk: int = 1024) -> jax.Array:
    """Cross-entropy over the vocab without materializing full-seq f32
    logits: scan over sequence chunks (bounds peak memory to
    B*chunk*V).  ``x``: (B, S, d); ``labels``/``mask``: (B, S)."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    n_chunks = s // chunk
    rem = s - n_chunks * chunk

    def chunk_loss(xc, yc, mc):
        logits = unembed(xc, unembed_w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mc)

    def body(carry, inp):
        xc, yc, mc = inp
        return carry + chunk_loss(xc, yc, mc), ()

    xs = (x[:, :n_chunks * chunk].reshape(b, n_chunks, chunk, d)
          .transpose(1, 0, 2, 3))
    ys = (labels[:, :n_chunks * chunk].reshape(b, n_chunks, chunk)
          .transpose(1, 0, 2))
    ms = (mask[:, :n_chunks * chunk].reshape(b, n_chunks, chunk)
          .transpose(1, 0, 2).astype(jnp.float32))
    total, _ = jax.lax.scan(body, jnp.float32(0.0), (xs, ys, ms))
    if rem:
        total = total + chunk_loss(x[:, -rem:], labels[:, -rem:],
                                   mask[:, -rem:].astype(jnp.float32))
    denom = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    return total / denom


# ----------------------------------------------------------------------
# Misc
# ----------------------------------------------------------------------

def causal_positions(batch: int, seq: int) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))


def count_params(table: ParamTable) -> int:
    total = 0
    for shape, _ in table.values():
        n = 1
        for d in shape:
            n *= int(d)  # python ints: no int32 overflow on 7B+ models
        total += n
    return total
