"""Model facade: family-dispatched entry points with one signature.

Everything downstream (train step, serving engine, dry-run lowering)
talks to models through these five functions; ``encdec`` (Whisper) is
the only family with its own implementations, the rest share ``lm``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax

from repro.configs.base import ModelConfig
from repro.models import encdec, lm
from repro.models.common import ParamTable


def param_table(cfg: ModelConfig) -> ParamTable:
    if cfg.family == "encdec":
        return encdec.encdec_table(cfg)
    return lm.lm_table(cfg)


def train_loss(cfg: ModelConfig, rules, params, batch
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    if cfg.family == "encdec":
        return encdec.train_loss(cfg, rules, params, batch)
    return lm.train_loss(cfg, rules, params, batch)


def prefill(cfg: ModelConfig, rules, params, batch):
    if cfg.family == "encdec":
        return encdec.prefill(cfg, rules, params, batch)
    return lm.prefill(cfg, rules, params, batch)


def decode_step(cfg: ModelConfig, rules, params, caches, batch):
    if cfg.family == "encdec":
        return encdec.decode_step(cfg, rules, params, caches, batch)
    return lm.decode_step(cfg, rules, params, caches, batch)


def init_caches(cfg: ModelConfig, batch: int, seq: int, dtype=None) -> Any:
    import jax.numpy as jnp
    dtype = dtype or jnp.bfloat16
    if cfg.family == "encdec":
        return encdec.init_caches(cfg, batch, seq, dtype)
    return lm.init_caches(cfg, batch, seq, dtype)
