"""Generic decoder LM: embeddings + block segments + unembedding.

A model is a list of *segments*; each segment is ``n`` layers of one
block kind.  Uniform segments are scanned (``lax.scan`` over stacked
params — one compiled layer body regardless of depth, which keeps the
80-layer dry-runs tractable) and rematerialized in training.
Non-uniform prefixes/suffixes (DeepSeek's dense first layer,
RecurrentGemma's trailing recurrent layers) are unrolled.

Block kinds: dense (GQA/MQA + SwiGLU), moe ((MLA|GQA) + MoE),
dense_mla (MLA + dense FFN), rwkv, rglru, local (windowed attention),
pattern (RecurrentGemma's (rglru, rglru, local) unit).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks_attn, blocks_moe, blocks_rnn
from repro.models.common import (ParamTable, Params, chunked_softmax_xent,
                                 merge_tables, prefix_table, rms_norm,
                                 stack_table, unembed)


class Segment(NamedTuple):
    kind: str
    n: int
    scanned: bool


def plan_segments(cfg: ModelConfig) -> List[Segment]:
    if cfg.family in ("dense", "vlm"):
        return [Segment("dense", cfg.n_layers, cfg.use_scan)]
    if cfg.family == "moe":
        fd = cfg.moe.first_dense_layers
        segs: List[Segment] = []
        if fd:
            segs.append(Segment("dense_mla", fd, False))
        segs.append(Segment("moe", cfg.n_layers - fd, cfg.use_scan))
        return segs
    if cfg.family == "ssm":
        return [Segment("rwkv", cfg.n_layers, cfg.use_scan)]
    if cfg.family == "hybrid":
        p = len(cfg.block_pattern)
        reps, rem = divmod(cfg.n_layers, p)
        segs = [Segment("pattern", reps, cfg.use_scan)]
        for k in range(rem):  # remainder layers follow the pattern order
            segs.append(Segment(cfg.block_pattern[k], 1, False))
        return segs
    raise ValueError(f"family {cfg.family} not handled by lm.py")


# ----------------------------------------------------------------------
# Block registry
# ----------------------------------------------------------------------

def _local_apply(cfg, rules, params, x, *, mode, cache, positions):
    return blocks_attn.apply(cfg, rules, params, x, mode=mode, cache=cache,
                             positions=positions,
                             local_window=cfg.local_window)


def _pattern_table(cfg: ModelConfig) -> ParamTable:
    tabs = []
    for j, kind in enumerate(cfg.block_pattern):
        tabs.append(prefix_table(f"p{j}", BLOCKS[kind][0](cfg)))
    return merge_tables(*tabs)


def _pattern_apply(cfg, rules, params, x, *, mode, cache, positions):
    new_cache = {} if mode in ("decode", "prefill") else None
    aux: Dict[str, jax.Array] = {}
    for j, kind in enumerate(cfg.block_pattern):
        sub = {k[len(f"p{j}."):]: v for k, v in params.items()
               if k.startswith(f"p{j}.")}
        c_in = cache.get(f"p{j}") if cache else None
        x, c_out, a = BLOCKS[kind][1](cfg, rules, sub, x, mode=mode,
                                      cache=c_in, positions=positions)
        if new_cache is not None:
            new_cache[f"p{j}"] = c_out
        for k, v in a.items():
            aux[k] = aux.get(k, 0.0) + v
    return x, new_cache, aux


def _pattern_cache(cfg, batch, seq, dtype=jnp.bfloat16):
    return {f"p{j}": BLOCKS[kind][2](cfg, batch, seq, dtype)
            for j, kind in enumerate(cfg.block_pattern)}


def _local_cache(cfg, batch, seq, dtype=jnp.bfloat16):
    return blocks_attn.init_attn_cache(cfg, batch, seq, dtype)


BLOCKS: Dict[str, Tuple[Any, Any, Any]] = {
    "dense": (blocks_attn.table, blocks_attn.apply, blocks_attn.init_cache),
    "moe": (blocks_moe.table, blocks_moe.apply, blocks_moe.init_cache),
    "dense_mla": (blocks_moe.dense_mla_table, blocks_moe.dense_mla_apply,
                  blocks_moe.init_cache),
    "rwkv": (blocks_rnn.table, blocks_rnn.apply, blocks_rnn.init_cache),
    "rglru": (blocks_rnn.rglru_table, blocks_rnn.rglru_block_apply,
              blocks_rnn.init_cache_rglru),
    "local": (blocks_attn.table, _local_apply, _local_cache),
}
BLOCKS["pattern"] = (_pattern_table, _pattern_apply, _pattern_cache)


# ----------------------------------------------------------------------
# Whole-model param table
# ----------------------------------------------------------------------

def lm_table(cfg: ModelConfig) -> ParamTable:
    tabs = [{
        "embed": ((cfg.vocab_size, cfg.d_model), ("vocab", "d_model")),
        "final_norm.scale": ((cfg.d_model,), (None,)),
    }]
    if not cfg.tie_embeddings:
        tabs.append({"unembed": ((cfg.vocab_size, cfg.d_model),
                                 ("vocab", "d_model"))})
    if cfg.family == "vlm":
        # stub frontend: a projection applied to precomputed patch embeds
        tabs.append({"patch_proj": ((cfg.d_model, cfg.d_model),
                                    ("d_model", None))})
    for i, seg in enumerate(plan_segments(cfg)):
        tab = BLOCKS[seg.kind][0](cfg)
        if seg.scanned:
            tabs.append(prefix_table(f"seg{i}", stack_table(tab, seg.n)))
        else:
            for j in range(seg.n):
                tabs.append(prefix_table(f"seg{i}.l{j}", tab))
    return merge_tables(*tabs)


def _seg_params(params: Params, i: int, j: Optional[int] = None) -> Params:
    pre = f"seg{i}." if j is None else f"seg{i}.l{j}."
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}


# ----------------------------------------------------------------------
# Forward pass over segments
# ----------------------------------------------------------------------

def run_blocks(cfg: ModelConfig, rules, params: Params, x: jax.Array, *,
               mode: str, caches: Optional[Dict[str, Any]],
               positions: jax.Array
               ) -> Tuple[jax.Array, Optional[Dict[str, Any]],
                          Dict[str, jax.Array]]:
    new_caches: Optional[Dict[str, Any]] = (
        {} if mode in ("decode", "prefill") else None)
    aux_total: Dict[str, jax.Array] = {}

    for i, seg in enumerate(plan_segments(cfg)):
        apply_fn = BLOCKS[seg.kind][1]
        if seg.scanned:
            sp = _seg_params(params, i)

            if mode == "train":
                def body(xc, p_i):
                    y, _, aux = apply_fn(cfg, rules, p_i, xc, mode="train",
                                         cache=None, positions=positions)
                    return y, aux
                if cfg.remat:
                    body = jax.checkpoint(
                        body, policy=jax.checkpoint_policies.nothing_saveable)
                x, auxs = jax.lax.scan(body, x, sp)
                for k, v in auxs.items():
                    aux_total[k] = aux_total.get(k, 0.0) + jnp.sum(v)
            elif mode == "prefill":
                def body_p(xc, p_i):
                    y, c, _ = apply_fn(cfg, rules, p_i, xc, mode="prefill",
                                       cache=None, positions=positions)
                    return y, c
                x, seg_cache = jax.lax.scan(body_p, x, sp)
                # emit per-layer caches (the decode layout): scanning
                # decode over a stacked cache carry would force whole-
                # cache dynamic-update-slices + hoisted converts;
                # unrolled decode updates each layer's cache in place.
                for j in range(seg.n):
                    new_caches[f"seg{i}.l{j}"] = jax.tree.map(
                        lambda a, j=j: a[j], seg_cache)
            else:  # decode: unrolled layers, per-layer caches
                for j in range(seg.n):
                    p_j = jax.tree.map(lambda a, j=j: a[j], sp)
                    key = f"seg{i}.l{j}"
                    x, c_out, _ = apply_fn(cfg, rules, p_j, x,
                                           mode="decode",
                                           cache=caches[key],
                                           positions=positions)
                    new_caches[key] = c_out
        else:
            for j in range(seg.n):
                sp = _seg_params(params, i, j)
                key = f"seg{i}.l{j}"
                c_in = caches.get(key) if caches else None
                x, c_out, aux = apply_fn(cfg, rules, sp, x, mode=mode,
                                         cache=c_in, positions=positions)
                if new_caches is not None:
                    new_caches[key] = c_out
                for k, v in aux.items():
                    aux_total[k] = aux_total.get(k, 0.0) + v
    return x, new_caches, aux_total


def init_caches(cfg: ModelConfig, batch: int, seq: int,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Per-layer cache layout (matches unrolled decode / prefill out)."""
    caches: Dict[str, Any] = {}
    for i, seg in enumerate(plan_segments(cfg)):
        cache_fn = BLOCKS[seg.kind][2]
        for j in range(seg.n):
            caches[f"seg{i}.l{j}"] = cache_fn(cfg, batch, seq, dtype)
    return caches


# ----------------------------------------------------------------------
# Embedding front ends
# ----------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, rules, params: Params,
                 batch: Dict[str, jax.Array], *,
                 mode: str) -> Tuple[jax.Array, jax.Array]:
    """Returns (x (B,S,d), positions (B,S)).

    VLM: precomputed patch embeddings (stub frontend) are projected and
    prepended to the token embeddings.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "vlm" and mode != "decode":
        patches = batch["patches"].astype(x.dtype)      # (B, P, d)
        patches = jnp.einsum("bpd,de->bpe", patches, params["patch_proj"])
        x = jnp.concatenate([patches, x], axis=1)
    s_full = x.shape[1]
    if mode == "decode":
        positions = jnp.broadcast_to(batch["index"][None, None],
                                     (b, 1)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(s_full, dtype=jnp.int32),
                                     (b, s_full))
    x = rules.constraint(x, "batch", "seq", None)
    return x, positions


# ----------------------------------------------------------------------
# Entry points
# ----------------------------------------------------------------------

def train_loss(cfg: ModelConfig, rules, params: Params,
               batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
    x, positions = embed_inputs(cfg, rules, params, batch, mode="train")
    x, _, aux = run_blocks(cfg, rules, params, x, mode="train",
                           caches=None, positions=positions)
    x = rms_norm(x, params["final_norm.scale"], cfg.norm_eps)
    if cfg.family == "vlm":     # loss only on the text positions
        x = x[:, batch["patches"].shape[1]:]
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    loss = chunked_softmax_xent(x, batch["labels"], w, batch["mask"],
                                cfg.logit_chunk)
    total = loss
    metrics = {"xent": loss}
    for k, v in aux.items():
        metrics[k] = v
        if k in ("moe_aux", "moe_z"):
            total = total + v
    metrics["loss"] = total
    return total, metrics


def prefill(cfg: ModelConfig, rules, params: Params,
            batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, Any]]:
    """Process the full prompt; return (last-position logits, caches)."""
    x, positions = embed_inputs(cfg, rules, params, batch, mode="prefill")
    x, caches, _ = run_blocks(cfg, rules, params, x, mode="prefill",
                              caches=None, positions=positions)
    x = rms_norm(x[:, -1:], params["final_norm.scale"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(x, w).astype(jnp.float32)
    logits = rules.constraint(logits, "batch", None, "act_vocab")
    return logits, caches


def decode_step(cfg: ModelConfig, rules, params: Params,
                caches: Dict[str, Any], batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One token for every sequence in the batch.

    ``batch`` = {"tokens": (B, 1), "index": scalar position}.
    """
    x, positions = embed_inputs(cfg, rules, params, batch, mode="decode")
    x, caches, _ = run_blocks(cfg, rules, params, x, mode="decode",
                              caches=caches, positions=positions)
    x = rms_norm(x, params["final_norm.scale"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(x, w).astype(jnp.float32)
    logits = rules.constraint(logits, "batch", None, "act_vocab")
    return logits, caches
