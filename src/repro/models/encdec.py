"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/audio frontend is a STUB: ``input_specs``
feeds precomputed frame embeddings (B, S_enc, d_model).  The
transformer backbone (12L encoder + 12L decoder, cross-attention,
pre-LN, GELU MLP, biased projections) is exact.  Positions are
sinusoidal (whisper's decoder uses a learned table; sinusoidal keeps
the table independent of the assigned 4k-32k shape cells — noted as a
deviation in DESIGN.md §7).

Decode caches: per-decoder-layer self-attention KV (written per step)
plus cross-attention KV (computed once at prefill, static afterwards).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention
from repro.models.common import (ParamTable, Params, chunked_softmax_xent,
                                 layer_norm, merge_tables, prefix_table,
                                 stack_table, unembed)

Cache = Dict[str, Any]


def sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------
# Param tables
# ----------------------------------------------------------------------

def _attn_table(cfg: ModelConfig, name: str) -> ParamTable:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    return {
        f"{name}.wq": ((d, h, hd), ("d_model", "heads", "head_dim")),
        f"{name}.wk": ((d, h, hd), ("d_model", "heads", "head_dim")),
        f"{name}.wv": ((d, h, hd), ("d_model", "heads", "head_dim")),
        f"{name}.wo": ((h, hd, d), ("heads", "head_dim", "d_model")),
        f"{name}.bq": ((h, hd), ("heads", "head_dim")),
        f"{name}.bv": ((h, hd), ("heads", "head_dim")),
        f"{name}.bo": ((d,), (None,)),
        f"{name}_ln.scale": ((d,), (None,)),
        f"{name}_ln.bias": ((d,), (None,)),
    }


def _mlp_table(cfg: ModelConfig) -> ParamTable:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mlp.w_in": ((d, f), ("d_model", "d_ff")),
        "mlp.b_in": ((f,), ("d_ff",)),
        "mlp.w_out": ((f, d), ("d_ff", "d_model")),
        "mlp.b_out": ((d,), (None,)),
        "mlp_ln.scale": ((d,), (None,)),
        "mlp_ln.bias": ((d,), (None,)),
    }


def enc_block_table(cfg: ModelConfig) -> ParamTable:
    return merge_tables(_attn_table(cfg, "self"), _mlp_table(cfg))


def dec_block_table(cfg: ModelConfig) -> ParamTable:
    return merge_tables(_attn_table(cfg, "self"), _attn_table(cfg, "cross"),
                        _mlp_table(cfg))


def encdec_table(cfg: ModelConfig) -> ParamTable:
    return merge_tables(
        {
            "embed": ((cfg.vocab_size, cfg.d_model), ("vocab", "d_model")),
            "enc_ln_post.scale": ((cfg.d_model,), (None,)),
            "enc_ln_post.bias": ((cfg.d_model,), (None,)),
            "dec_ln_post.scale": ((cfg.d_model,), (None,)),
            "dec_ln_post.bias": ((cfg.d_model,), (None,)),
        },
        prefix_table("enc", stack_table(enc_block_table(cfg),
                                        cfg.n_encoder_layers)),
        prefix_table("dec", stack_table(dec_block_table(cfg),
                                        cfg.n_layers)),
    )


# ----------------------------------------------------------------------
# Sub-layers
# ----------------------------------------------------------------------

def _proj_qkv(params, name, xq, xkv):
    q = jnp.einsum("bsd,dhk->bshk", xq, params[f"{name}.wq"]) \
        + params[f"{name}.bq"]
    k = jnp.einsum("bsd,dhk->bshk", xkv, params[f"{name}.wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, params[f"{name}.wv"]) \
        + params[f"{name}.bv"]
    return (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3))


def _out_proj(params, name, out):
    return jnp.einsum("bshk,hkd->bsd", out.transpose(0, 2, 1, 3),
                      params[f"{name}.wo"]) + params[f"{name}.bo"]


def _mlp(params, x):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, params["mlp.w_in"])
                    + params["mlp.b_in"])
    return jnp.einsum("bsf,fd->bsd", h, params["mlp.w_out"]) \
        + params["mlp.b_out"]


def _ln(params, name, x, eps):
    return layer_norm(x, params[f"{name}.scale"], params[f"{name}.bias"],
                      eps)


# ----------------------------------------------------------------------
# Encoder
# ----------------------------------------------------------------------

def encode(cfg: ModelConfig, rules, params: Params,
           frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, d) precomputed embeddings (stub frontend)."""
    b, s, d = frames.shape
    x = frames + sinusoid(jnp.arange(s), d)[None].astype(frames.dtype)
    x = rules.constraint(x, "batch", "seq", None)

    def body(xc, p_i):
        h = _ln(p_i, "self_ln", xc, cfg.norm_eps)
        q, k, v = _proj_qkv(p_i, "self", h, h)
        q = rules.constraint(q, "batch", "act_heads", None, None)
        a = attention.full_attention(q, k, v, causal=False,
                                     q_block=cfg.q_block)
        xc = xc + _out_proj(p_i, "self", a)
        h = _ln(p_i, "mlp_ln", xc, cfg.norm_eps)
        xc = xc + _mlp(p_i, h)
        xc = rules.constraint(xc, "batch", "seq", None)
        return xc, ()

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    enc_params = {k[len("enc."):]: v for k, v in params.items()
                  if k.startswith("enc.")}
    x, _ = jax.lax.scan(body, x, enc_params)
    return _ln(params, "enc_ln_post", x, cfg.norm_eps)


# ----------------------------------------------------------------------
# Decoder
# ----------------------------------------------------------------------

def _dec_blocks(cfg: ModelConfig, rules, params: Params, x: jax.Array, *,
                mode: str, caches: Optional[Cache], enc_out: Optional[jax.Array],
                positions: jax.Array) -> Tuple[jax.Array, Optional[Cache]]:
    dec_params = {k[len("dec."):]: v for k, v in params.items()
                  if k.startswith("dec.")}

    if mode == "train":
        def body(xc, p_i):
            h = _ln(p_i, "self_ln", xc, cfg.norm_eps)
            q, k, v = _proj_qkv(p_i, "self", h, h)
            q = rules.constraint(q, "batch", "act_heads", None, None)
            a = attention.full_attention(q, k, v, causal=True,
                                         q_block=cfg.q_block)
            xc = xc + _out_proj(p_i, "self", a)
            h = _ln(p_i, "cross_ln", xc, cfg.norm_eps)
            q, k, v = _proj_qkv(p_i, "cross", h, enc_out)
            a = attention.full_attention(q, k, v, causal=False,
                                         q_block=cfg.q_block)
            xc = xc + _out_proj(p_i, "cross", a)
            h = _ln(p_i, "mlp_ln", xc, cfg.norm_eps)
            xc = xc + _mlp(p_i, h)
            xc = rules.constraint(xc, "batch", "seq", None)
            return xc, ()
        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, dec_params)
        return x, None

    if mode == "prefill":
        def body_p(xc, p_i):
            h = _ln(p_i, "self_ln", xc, cfg.norm_eps)
            q, k, v = _proj_qkv(p_i, "self", h, h)
            q = rules.constraint(q, "batch", "act_heads", None, None)
            a = attention.full_attention(q, k, v, causal=True,
                                         q_block=cfg.q_block)
            xc = xc + _out_proj(p_i, "self", a)
            h = _ln(p_i, "cross_ln", xc, cfg.norm_eps)
            qc, kc, vc = _proj_qkv(p_i, "cross", h, enc_out)
            a = attention.full_attention(qc, kc, vc, causal=False,
                                         q_block=cfg.q_block)
            xc = xc + _out_proj(p_i, "cross", a)
            h = _ln(p_i, "mlp_ln", xc, cfg.norm_eps)
            xc = xc + _mlp(p_i, h)
            xc = rules.constraint(xc, "batch", "seq", None)
            cache = {
                "self_k": rules.constraint(k, "batch", "act_kv_heads",
                                           "kv_seq", None),
                "self_v": rules.constraint(v, "batch", "act_kv_heads",
                                           "kv_seq", None),
                "cross_k": rules.constraint(kc, "batch", "act_kv_heads",
                                            "kv_seq", None),
                "cross_v": rules.constraint(vc, "batch", "act_kv_heads",
                                            "kv_seq", None),
            }
            return xc, cache
        x, cache = jax.lax.scan(body_p, x, dec_params)
        return x, cache

    # decode: unrolled layers, per-layer caches (see lm.run_blocks)
    idx = positions[0, 0]
    new_caches: Cache = {}
    for j in range(cfg.n_layers):
        p_i = jax.tree.map(lambda a, j=j: a[j], dec_params)
        c_i = caches[f"dec.l{j}"]
        h = _ln(p_i, "self_ln", x, cfg.norm_eps)
        q, k, v = _proj_qkv(p_i, "self", h, h)
        kc, vc = attention.update_cache(c_i["self_k"], c_i["self_v"],
                                        k, v, idx)
        kc = rules.constraint(kc, "batch", "act_kv_heads", "kv_seq", None)
        vc = rules.constraint(vc, "batch", "act_kv_heads", "kv_seq", None)
        valid = jnp.arange(kc.shape[2])[None, :] <= idx
        valid = jnp.broadcast_to(valid, (x.shape[0], kc.shape[2]))
        a = attention.decode_attention(q, kc, vc, kv_valid=valid)
        x = x + _out_proj(p_i, "self", a)
        h = _ln(p_i, "cross_ln", x, cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", h, p_i["cross.wq"]) \
            + p_i["cross.bq"]
        a = attention.decode_attention(qx.transpose(0, 2, 1, 3),
                                       c_i["cross_k"], c_i["cross_v"])
        x = x + _out_proj(p_i, "cross", a)
        h = _ln(p_i, "mlp_ln", x, cfg.norm_eps)
        x = x + _mlp(p_i, h)
        new_c = dict(c_i)
        new_c["self_k"], new_c["self_v"] = kc, vc
        new_caches[f"dec.l{j}"] = new_c
    return x, new_caches


# ----------------------------------------------------------------------
# Entry points (match lm.py signatures)
# ----------------------------------------------------------------------

def train_loss(cfg: ModelConfig, rules, params: Params,
               batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
    enc_out = encode(cfg, rules, params, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + sinusoid(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, _ = _dec_blocks(cfg, rules, params, x, mode="train", caches=None,
                       enc_out=enc_out, positions=positions)
    x = _ln(params, "dec_ln_post", x, cfg.norm_eps)
    loss = chunked_softmax_xent(x, batch["labels"], params["embed"],
                                batch["mask"], cfg.logit_chunk)
    return loss, {"xent": loss, "loss": loss}


def prefill(cfg: ModelConfig, rules, params: Params,
            batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Cache]:
    enc_out = encode(cfg, rules, params, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + sinusoid(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, cache = _dec_blocks(cfg, rules, params, x, mode="prefill",
                           caches=None, enc_out=enc_out,
                           positions=positions)
    x = _ln(params, "dec_ln_post", x[:, -1:], cfg.norm_eps)
    logits = unembed(x, params["embed"]).astype(jnp.float32)
    per_layer = {f"dec.l{j}": jax.tree.map(lambda a, j=j: a[j], cache)
                 for j in range(cfg.n_layers)}
    return logits, per_layer


def decode_step(cfg: ModelConfig, rules, params: Params, caches: Cache,
                batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Cache]:
    tokens = batch["tokens"]
    b, _ = tokens.shape
    idx = batch["index"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + sinusoid(idx[None, None], cfg.d_model).astype(x.dtype)
    positions = jnp.broadcast_to(idx[None, None], (b, 1)).astype(jnp.int32)
    x, cache = _dec_blocks(cfg, rules, params, x, mode="decode",
                           caches=caches, enc_out=None, positions=positions)
    x = _ln(params, "dec_ln_post", x, cfg.norm_eps)
    logits = unembed(x, params["embed"]).astype(jnp.float32)
    logits = rules.constraint(logits, "batch", None, "act_vocab")
    return logits, cache


def init_caches(cfg: ModelConfig, batch: int, seq: int,
                dtype=jnp.bfloat16) -> Cache:
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    s_enc = max(int(seq * cfg.encoder_seq_ratio), 1)
    return {f"dec.l{j}": {
        "self_k": jnp.zeros((batch, h, seq, hd), dtype=dtype),
        "self_v": jnp.zeros((batch, h, seq, hd), dtype=dtype),
        "cross_k": jnp.zeros((batch, h, s_enc, hd), dtype=dtype),
        "cross_v": jnp.zeros((batch, h, s_enc, hd), dtype=dtype),
    } for j in range(cfg.n_layers)}
