"""Attention in pure JAX — differentiable, XLA/SPMD-friendly.

``full_attention`` is *triangle-blocked*: the query axis is split into
static blocks (Python-unrolled), and each block attends only to its key
prefix (causal), a sliding window (local), or the full sequence
(bidirectional).  Static slicing keeps causal FLOPs at ~S^2/2 (the
useful count — important for the MODEL_FLOPS/HLO_FLOPs roofline ratio),
bounds peak score memory to (B, H, q_block, ctx), needs no custom VJP,
and lets XLA SPMD shard heads/sequence freely.

GQA/MQA never materializes repeated KV heads: queries are reshaped to
(B, kv_heads, group, S, D) and contracted against the raw KV.

The Pallas TPU kernel (`repro/kernels/flash_attention.py`) implements
the same online-softmax computation with explicit VMEM tiling; this
module is its oracle (see tests/test_kernels_flash.py).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def _group_heads(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, Hq, S, D) -> (B, Hkv, G, S, D)."""
    b, hq, s, d = q.shape
    return q.reshape(b, n_kv, hq // n_kv, s, d)


def _expand_kv(k: jax.Array, group: int) -> jax.Array:
    """(B, Hkv, S, D) -> (B, Hkv*G, S, D) by broadcast.

    Perf note (EXPERIMENTS.md §Perf H1): the grouped-query formulation
    reshapes q to (B, Hkv, G, S, D), which splits the sharded head axis
    into (Hkv, G); when Hkv doesn't divide the mesh's model axis the
    SPMD partitioner falls back to *involuntary full rematerialization*
    — a full replicate+repartition of activation-sized tensors in every
    layer.  Broadcasting KV up to the query heads keeps one contiguous
    head axis that stays sharded end-to-end; XLA fuses the broadcast
    into the dot, so no repeated-KV tensor is materialized in HBM.
    """
    if group == 1:
        return k
    b, hkv, s, d = k.shape
    k = jnp.broadcast_to(k[:, :, None], (b, hkv, group, s, d))
    return k.reshape(b, hkv * group, s, d)


def _attend_block(q: jax.Array, k: jax.Array, v: jax.Array,
                  mask: Optional[jax.Array], scale: float) -> jax.Array:
    """q: (B, H, Bq, D); k/v: (B, H, Ctx, D) (KV pre-broadcast for GQA);
    mask broadcastable to (B, H, Bq, Ctx).  Softmax in f32."""
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)
    return out


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   *,
                   causal: bool = True,
                   local_window: int = 0,
                   q_block: int = 512,
                   q_offset: int = 0,
                   scale: Optional[float] = None) -> jax.Array:
    """Triangle-blocked multi-(grouped-)head attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D).  Returns (B, Hq, Sq, D).
    ``q_offset``: global position of q[...,0,:] (cross-chunk prefill).
    ``local_window`` > 0 limits attention to the last W positions
    (RecurrentGemma local attention); implies causal.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    q_block = min(q_block, sq)
    n_blocks = (sq + q_block - 1) // q_block
    k = _expand_kv(k, hq // hkv)
    v = _expand_kv(v, hq // hkv)

    if not (causal or local_window):
        # bidirectional: one shot per q block against full KV
        outs = []
        for i in range(n_blocks):
            lo = i * q_block
            hi = min(lo + q_block, sq)
            outs.append(_attend_block(q[:, :, lo:hi], k, v, None, scale))
        return jnp.concatenate(outs, axis=2)

    outs = []
    for i in range(n_blocks):
        lo = i * q_block
        hi = min(lo + q_block, sq)
        q_pos_hi = q_offset + hi  # exclusive global end of this block
        if local_window > 0:
            k_lo = max(0, q_pos_hi - local_window - (hi - lo))
        else:
            k_lo = 0
        k_hi = min(q_pos_hi, sk)
        kb = k[:, :, k_lo:k_hi]
        vb = v[:, :, k_lo:k_hi]
        q_pos = (q_offset + jnp.arange(lo, hi))[:, None]        # (Bq, 1)
        k_pos = jnp.arange(k_lo, k_hi)[None, :]                 # (1, Ctx)
        mask = k_pos <= q_pos
        if local_window > 0:
            mask &= k_pos > (q_pos - local_window)
        outs.append(_attend_block(
            q[:, :, lo:hi], kb, vb, mask[None, None], scale))
    return jnp.concatenate(outs, axis=2)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     *,
                     kv_valid: Optional[jax.Array] = None,
                     scale: Optional[float] = None) -> jax.Array:
    """Single-step decode: q (B, Hq, 1, D) vs cache (B, Hkv, S, D).

    ``kv_valid`` (B, S) masks unwritten/ring-buffer slots.  The score
    row is tiny (S per head), so no blocking; with the cache sequence
    axis sharded over the mesh `model` axis, XLA SPMD inserts the
    distributed max/sum reductions (flash-decode equivalent).
    """
    b, hq, _, d = q.shape
    _, hkv, s, _ = k_cache.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = _group_heads(q, hkv)  # (B, Hkv, G, 1, D)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    if kv_valid is not None:
        scores = jnp.where(kv_valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(b, hq, 1, v_cache.shape[-1])


def update_cache(k_cache: jax.Array, v_cache: jax.Array,
                 k_new: jax.Array, v_new: jax.Array,
                 index: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Write one decode step into the cache at ``index`` (ring semantics
    when index is taken modulo the cache length by the caller)."""
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), index, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), index, axis=2)
    return k_cache, v_cache
