"""Dense attention + MLP blocks (llama-family, local-attention hybrid).

Block contract (shared by all block modules):

  table(cfg) -> ParamTable                       # declarative params
  apply(cfg, rules, params, x, *, mode, cache, positions)
      -> (y, new_cache, aux)

``mode`` is one of "train" | "prefill" | "decode".  ``positions`` is
(B, S) global token positions (decode: S=1, the write index).  Caches
are dicts of arrays; ``init_cache`` builds them (the sequence axis is
sharded per the active rules, e.g. over `model` for flash-decode).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import megatron_sp
from repro.models import attention
from repro.models.common import ParamTable, rms_norm, swiglu

Aux = Dict[str, jax.Array]
Cache = Optional[Dict[str, jax.Array]]


# ----------------------------------------------------------------------
# GQA/MQA attention sub-layer
# ----------------------------------------------------------------------

def attn_table(cfg: ModelConfig) -> ParamTable:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    t: ParamTable = {
        "attn.wq": ((d, cfg.n_heads, hd), ("d_model", "heads", "head_dim")),
        "attn.wk": ((d, cfg.n_kv_heads, hd),
                    ("d_model", "kv_heads", "head_dim")),
        "attn.wv": ((d, cfg.n_kv_heads, hd),
                    ("d_model", "kv_heads", "head_dim")),
        "attn.wo": ((cfg.n_heads, hd, d), ("heads", "head_dim", "d_model")),
        "attn_norm.scale": ((d,), (None,)),
    }
    if cfg.qkv_bias:
        t["attn.bq"] = ((cfg.n_heads, hd), ("heads", "head_dim"))
        t["attn.bk"] = ((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"))
        t["attn.bv"] = ((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"))
    return t


def init_attn_cache(cfg: ModelConfig, batch: int, seq: int,
                    dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    hd = cfg.resolved_head_dim
    kv = max(cfg.n_kv_heads, 1)
    window = cfg.local_window or seq
    s = min(seq, window) if cfg.local_window else seq
    return {
        "k": jnp.zeros((batch, kv, s, hd), dtype=dtype),
        "v": jnp.zeros((batch, kv, s, hd), dtype=dtype),
    }


def attn_apply(cfg: ModelConfig, rules, params, x: jax.Array, *,
               mode: str, cache: Cache, positions: jax.Array,
               local_window: int = 0,
               prefix: str = "attn") -> Tuple[jax.Array, Cache]:
    """x: (B, S, d) -> (B, S, d).  RoPE + GQA + causal (or local)."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim

    heads_shard = rules.spec_for(
        ("d_model", "heads", "head_dim"),
        params[f"{prefix}.wq"].shape)[1] is not None
    if (mode != "decode" and heads_shard
            and megatron_sp.sp_enabled(rules, s, b)):
        # fused SP->TP: one seq all-gather + QKV projections in one
        # shard_map so backward is a single reduce-scatter (§Perf).
        # Archs whose heads don't divide TP (recurrentgemma: 10 on 16)
        # keep token-parallel projections — gathering the sequence for
        # replicated heads would 16x-duplicate the QKV compute.
        q, k, v = megatron_sp.in_project_ag(
            x, [params[f"{prefix}.wq"], params[f"{prefix}.wk"],
                params[f"{prefix}.wv"]],
            rules=rules, kinds=("dhk", "dhk", "dhk"))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params[f"{prefix}.wq"])
        k = jnp.einsum("bsd,dhk->bshk", x, params[f"{prefix}.wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params[f"{prefix}.wv"])
    if cfg.qkv_bias:
        q = q + params[f"{prefix}.bq"]
        k = k + params[f"{prefix}.bk"]
        v = v + params[f"{prefix}.bv"]

    q = attention_rope(q, positions, cfg.rope_theta)
    k = attention_rope(k, positions, cfg.rope_theta)

    # (B, S, H, D) -> (B, H, S, D); shard attention compute by heads
    q = rules.constraint(q.transpose(0, 2, 1, 3),
                         "batch", "act_heads", None, None)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    if mode == "decode":
        assert cache is not None
        idx = positions[0, 0]  # uniform decode step across the batch
        if local_window:
            w = cache["k"].shape[2]
            widx = jnp.mod(idx, w)
            kc, vc = attention.update_cache(cache["k"], cache["v"], k, v,
                                            widx)
            # until the ring fills, only slots <= idx have been written
            valid = (jnp.arange(w)[None, :] <= idx) | (idx + 1 >= w)
            valid = jnp.broadcast_to(valid, (b, w))
        else:
            kc, vc = attention.update_cache(cache["k"], cache["v"], k, v,
                                            idx)
            kv_pos = jnp.arange(kc.shape[2])[None, :]
            valid = kv_pos <= idx
        kc = rules.constraint(kc, "batch", "act_kv_heads", "kv_seq", None)
        vc = rules.constraint(vc, "batch", "act_kv_heads", "kv_seq", None)
        out = attention.decode_attention(q, kc, vc, kv_valid=valid)
        new_cache = {"k": kc, "v": vc}
    else:
        out = attention.full_attention(
            q, k, v, causal=True, local_window=local_window,
            q_block=cfg.q_block)
        new_cache = None
        if mode == "prefill":
            if local_window:
                w = local_window
                kc = k[:, :, -w:]
                vc = v[:, :, -w:]
                # ring layout: slot = pos % window
                roll = jnp.mod(s, w)
                kc = jnp.roll(kc, roll, axis=2)
                vc = jnp.roll(vc, roll, axis=2)
            else:
                kc, vc = k, v
            kc = rules.constraint(kc, "batch", "act_kv_heads", "kv_seq", None)
            vc = rules.constraint(vc, "batch", "act_kv_heads", "kv_seq", None)
            new_cache = {"k": kc, "v": vc}

    out = out.transpose(0, 2, 1, 3)  # (B, S, H, D)
    wo = params[f"{prefix}.wo"]
    if (mode != "decode" and heads_shard
            and megatron_sp.sp_enabled(rules, s, b)):
        # explicit TP->SP transition: partial sums reduce-scatter onto
        # the sequence axis in bf16 (see distributed/megatron_sp.py)
        y = megatron_sp.out_project_rs(out, wo, rules=rules,
                                       contract="hkd")
    else:
        y = jnp.einsum("bshk,hkd->bsd", out, wo)
    return y, new_cache


def attention_rope(x: jax.Array, positions: jax.Array,
                   theta: float) -> jax.Array:
    """RoPE on (B, S, H, D) with (B, S) positions."""
    from repro.models.common import rope
    return rope(x, positions, theta)


# ----------------------------------------------------------------------
# SwiGLU MLP sub-layer
# ----------------------------------------------------------------------

def mlp_table(cfg: ModelConfig, d_ff: Optional[int] = None) -> ParamTable:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "mlp.w_gate": ((d, f), ("d_model", "d_ff")),
        "mlp.w_up": ((d, f), ("d_model", "d_ff")),
        "mlp.w_down": ((f, d), ("d_ff", "d_model")),
        "mlp_norm.scale": ((d,), (None,)),
    }


def mlp_apply(cfg: ModelConfig, rules, params, x: jax.Array,
              prefix: str = "mlp", mode: str = "train") -> jax.Array:
    sp = mode != "decode" and megatron_sp.sp_enabled(rules, x.shape[1], x.shape[0])
    if sp:
        g, u = megatron_sp.in_project_ag(
            x, [params[f"{prefix}.w_gate"], params[f"{prefix}.w_up"]],
            rules=rules, kinds=("df", "df"))
    else:
        g = jnp.einsum("bsd,df->bsf", x, params[f"{prefix}.w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, params[f"{prefix}.w_up"])
    h = rules.constraint(jax.nn.silu(g) * u, "batch", None, "act_d_ff")
    w_down = params[f"{prefix}.w_down"]
    if sp:
        return megatron_sp.out_project_rs(h, w_down, rules=rules,
                                          contract="fd")
    return jnp.einsum("bsf,fd->bsd", h, w_down)


# ----------------------------------------------------------------------
# Full dense decoder block (pre-norm residual)
# ----------------------------------------------------------------------

def table(cfg: ModelConfig) -> ParamTable:
    return {**attn_table(cfg), **mlp_table(cfg)}


def apply(cfg: ModelConfig, rules, params, x: jax.Array, *,
          mode: str, cache: Cache, positions: jax.Array,
          local_window: int = 0) -> Tuple[jax.Array, Cache, Aux]:
    h = rms_norm(x, params["attn_norm.scale"], cfg.norm_eps)
    a, new_cache = attn_apply(cfg, rules, params, h, mode=mode, cache=cache,
                              positions=positions,
                              local_window=local_window)
    x = x + a
    x = rules.constraint(x, "batch", "seq", None)
    h = rms_norm(x, params["mlp_norm.scale"], cfg.norm_eps)
    x = x + mlp_apply(cfg, rules, params, h, mode=mode)
    x = rules.constraint(x, "batch", "seq", None)
    return x, new_cache, {}


def init_cache(cfg: ModelConfig, batch: int, seq: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    return init_attn_cache(cfg, batch, seq, dtype)
