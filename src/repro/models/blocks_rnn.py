"""Recurrent blocks: RWKV6 "Finch" time/channel mix and RG-LRU (Griffin /
RecurrentGemma).

Both carry O(1)-per-stream state (no KV cache growth), which is why
these architectures run the ``long_500k`` cell.  Training uses a
*chunked* scan — an outer ``lax.scan`` over time chunks whose inner
step is ``jax.checkpoint``-ed — so backward memory is O(S/chunk)
boundary states instead of O(S) step intermediates.

The WKV6 recurrence has a Pallas TPU kernel
(`repro/kernels/wkv6.py`, state resident in VMEM, grid over B*H);
``wkv_scan`` here is its oracle.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamTable, layer_norm, rms_norm

Aux = Dict[str, jax.Array]
Cache = Optional[Dict[str, jax.Array]]

TIME_CHUNK = 128     # scan chunk length (remat boundary)
LORA_MIX = 32        # token-shift ddlerp LoRA rank
LORA_DECAY = 64      # data-dependent decay LoRA rank


# ======================================================================
# RWKV6
# ======================================================================

def rwkv_table(cfg: ModelConfig) -> ParamTable:
    d, f = cfg.d_model, cfg.d_ff
    n = cfg.rwkv_head_size
    h = d // n
    return {
        "ln1.scale": ((d,), (None,)), "ln1.bias": ((d,), (None,)),
        "ln2.scale": ((d,), (None,)), "ln2.bias": ((d,), (None,)),
        # time-mix: data-dependent token-shift interpolation (ddlerp)
        "tm.mu_x": ((d,), (None,)),
        "tm.mu": ((5, d), (None, None)),
        "tm.w1": ((d, 5 * LORA_MIX), ("d_model", None)),
        "tm.w2": ((5, LORA_MIX, d), (None, None, "d_model")),
        # data-dependent decay
        "tm.decay_base": ((d,), (None,)),
        "tm.dw1": ((d, LORA_DECAY), ("d_model", None)),
        "tm.dw2": ((LORA_DECAY, d), (None, "d_model")),
        "tm.bonus": ((h, n), ("heads", None)),
        "tm.wr": ((d, d), ("d_model", "heads_x")),
        "tm.wk": ((d, d), ("d_model", "heads_x")),
        "tm.wv": ((d, d), ("d_model", "heads_x")),
        "tm.wg": ((d, d), ("d_model", "heads_x")),
        "tm.wo": ((d, d), ("heads_x", "d_model")),
        "tm.ln_x.scale": ((d,), (None,)), "tm.ln_x.bias": ((d,), (None,)),
        # channel-mix
        "cm.mu_k": ((d,), (None,)), "cm.mu_r": ((d,), (None,)),
        "cm.wk": ((d, f), ("d_model", "d_ff")),
        "cm.wv": ((f, d), ("d_ff", "d_model")),
        "cm.wr": ((d, d), ("d_model", None)),
    }


def init_rwkv_cache(cfg: ModelConfig, batch: int, seq: int,
                    dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    d = cfg.d_model
    n = cfg.rwkv_head_size
    h = d // n
    return {
        "tm_x": jnp.zeros((batch, d), dtype=dtype),
        "cm_x": jnp.zeros((batch, d), dtype=dtype),
        # wkv state is f32: it integrates over the whole context
        "wkv": jnp.zeros((batch, h, n, n), dtype=jnp.float32),
    }


def wkv_step(state: jax.Array, r, k, v, w, u) -> Tuple[jax.Array, jax.Array]:
    """One WKV6 step.  state: (B,H,N,N) [key x value]; r/k/v/w: (B,H,N);
    u: (H,N).  Returns (new_state, y (B,H,N))."""
    kv = jnp.einsum("bhi,bhj->bhij", k, v)              # outer product
    y = jnp.einsum("bhi,bhij->bhj", r, state + u[None, :, :, None] * kv)
    new_state = w[..., None] * state + kv
    return new_state, y


def wkv_scan(state: jax.Array, r, k, v, w, u,
             chunk: int = TIME_CHUNK) -> Tuple[jax.Array, jax.Array]:
    """Sequence WKV6.  r/k/v/w: (B,S,H,N) f32; u: (H,N).
    Returns (final_state, y (B,S,H,N)).  Chunked + rematerialized."""
    b, s, h, n = r.shape

    def step(st, inp):
        rt, kt, vt, wt = inp
        st, y = wkv_step(st, rt, kt, vt, wt, u)
        return st, y

    def chunk_body(st, inp):
        return jax.lax.scan(step, st, inp)

    chunk = min(chunk, s)
    if s % chunk == 0 and s > chunk:
        nc = s // chunk
        # (B,S,H,N) -> (nc, chunk, B,H,N)
        def to_chunks(x):
            return (x.transpose(1, 0, 2, 3)
                    .reshape(nc, chunk, b, h, n))
        inp = tuple(to_chunks(x) for x in (r, k, v, w))

        def outer(st, ci):
            return jax.checkpoint(chunk_body)(st, ci)

        state, ys = jax.lax.scan(outer, state, inp)
        y = ys.reshape(s, b, h, n).transpose(1, 0, 2, 3)
    else:
        inp = tuple(x.transpose(1, 0, 2, 3) for x in (r, k, v, w))
        state, ys = jax.lax.scan(step, state, inp)
        y = ys.transpose(1, 0, 2, 3)
    return state, y


WKV_CHUNK = 32   # chunked-formulation block length (§Perf H4)


def wkv_chunked(state: jax.Array, r, k, v, w, u,
                chunk: int = WKV_CHUNK) -> Tuple[jax.Array, jax.Array]:
    """Chunked-parallel WKV6 — same recurrence as ``wkv_scan`` but
    processed ``chunk`` steps at a time with matmuls (§Perf H4).

    The per-step scan writes the (B, H, N, N) f32 state to HBM every
    token (XLA cannot keep a 4 MB carry in registers), which makes the
    RWKV train cells memory-bound by an order of magnitude.  Within a
    chunk, using inclusive decay products P_t = prod_{tau<=t} w_tau:

      y_t  = (r_t . P_{t-1}) @ S_0                     (inter-chunk)
           + sum_{s<t} [r_t k_s exp(L_{t-1}-L_s)] v_s  (intra-chunk)
           + (r_t . u . k_t) v_t                       (bonus diag)
      S'   = P_C . S_0 + (k . P_C/P_tau)^T @ V         (state update)

    All exponentials are of NON-POSITIVE quantities (log-decays), so
    every factor lives in [0, 1]: unconditionally stable, unlike the
    separated r*P / k/P factorization which overflows for long chunks.
    The (C, C, N) decay tensor is the price — C=32 keeps it at 256 KB
    per (b, h), ~8x less HBM traffic than the per-step carry, and the
    state now round-trips HBM once per chunk instead of once per step.

    r/k/v/w: (B, S, H, N) f32; u: (H, N); state: (B, H, N, N).
    Returns (final_state, y (B, S, H, N)) — same contract as wkv_scan.
    """
    b, s, h, n = r.shape
    chunk = min(chunk, s)
    if s % chunk != 0:
        return wkv_scan(state, r, k, v, w, u)
    nc = s // chunk

    def to_chunks(x):
        return (x.reshape(b, nc, chunk, h, n)
                .transpose(1, 0, 3, 2, 4))        # (nc, B, H, C, N)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))
    log_w = jnp.log(jnp.maximum(wc, 1e-30))       # (nc, B, H, C, N) <= 0

    def chunk_body(S0, inp):
        rt, kt, vt, lw = inp
        L = jnp.cumsum(lw, axis=2)
        P_prev = jnp.exp(L - lw)
        P_end = jnp.exp(L[:, :, -1:, :])

        y_inter = jnp.einsum("bhtn,bhnm->bhtm", rt * P_prev, S0)

        diff = (L - lw)[:, :, :, None, :] - L[:, :, None, :, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), dtype=bool), -1)
        D = jnp.where(tri[None, None, :, :, None],
                      jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        A = jnp.einsum("bhtn,bhsn,bhtsn->bhts", rt, kt, D)
        bonus = jnp.sum(rt * u[None, :, None, :] * kt, axis=-1)  # (B,H,C)
        y_intra = jnp.einsum("bhts,bhsm->bhtm", A, vt) \
            + bonus[..., None] * vt

        decay_to_end = jnp.exp(L[:, :, -1:, :] - L)   # (B,H,C,N) in [0,1]
        S_new = P_end.transpose(0, 1, 3, 2) * S0 + jnp.einsum(
            "bhsn,bhsm->bhnm", kt * decay_to_end, vt)
        return S_new, y_inter + y_intra

    state, ys = jax.lax.scan(chunk_body, state, (rc, kc, vc, log_w))
    # ys: (nc, B, H, C, N) -> (B, S, H, N)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h, n)
    return state, y


def _ddlerp(params, x: jax.Array, dx: jax.Array) -> Tuple[jax.Array, ...]:
    """Data-dependent token-shift mixing -> (xw, xk, xv, xr, xg)."""
    mix_in = x + dx * params["tm.mu_x"]
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", mix_in, params["tm.w1"]))
    b, s, _ = lora.shape
    lora = lora.reshape(b, s, 5, LORA_MIX)
    delta = jnp.einsum("bsir,ird->bsid", lora, params["tm.w2"])
    mixes = params["tm.mu"][None, None] + delta          # (B,S,5,d)
    return tuple(x + dx * mixes[:, :, i] for i in range(5))


def rwkv_time_mix(cfg: ModelConfig, rules, params, x: jax.Array, *,
                  mode: str, cache: Cache) -> Tuple[jax.Array, Cache]:
    b, s, d = x.shape
    n = cfg.rwkv_head_size
    h = d // n

    if mode == "decode":
        x_prev = cache["tm_x"][:, None, :].astype(x.dtype)
    else:
        x_prev = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    dx = x_prev - x

    xw, xk, xv, xr, xg = _ddlerp(params, x, dx)
    r = jnp.einsum("bsd,de->bse", xr, params["tm.wr"])
    k = jnp.einsum("bsd,de->bse", xk, params["tm.wk"])
    v = jnp.einsum("bsd,de->bse", xv, params["tm.wv"])
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, params["tm.wg"]))
    decay_in = (params["tm.decay_base"]
                + jnp.einsum("bsd,dr->bsr",
                             jnp.tanh(jnp.einsum("bsd,dr->bsr", xw,
                                                 params["tm.dw1"])),
                             params["tm.dw2"]))
    w = jnp.exp(-jnp.exp(decay_in.astype(jnp.float32)))   # (0,1) decay

    def heads(t):
        return t.reshape(b, s, h, n).astype(jnp.float32)

    r_, k_, v_, w_ = heads(r), heads(k), heads(v), heads(w)
    u = params["tm.bonus"].astype(jnp.float32)

    state0 = (cache["wkv"] if mode == "decode"
              else jnp.zeros((b, h, n, n), dtype=jnp.float32))
    if mode == "decode":
        state, y = wkv_step(state0, r_[:, 0], k_[:, 0], v_[:, 0], w_[:, 0], u)
        y = y[:, None]                                   # (B,1,H,N)
    else:
        # On TPU the hot path is the Pallas wkv6 kernel (state resident
        # in VMEM — repro/kernels/wkv6.py).  The pure-XLA fallback is
        # the chunk-rematerialized scan; the chunked-matmul variant
        # (wkv_chunked) LOST to it under XLA:CPU lowering because the
        # (C, C, N) decay tensor never fuses — measured + recorded in
        # EXPERIMENTS.md §Perf H4 (refuted hypothesis).
        state, y = wkv_scan(state0, r_, k_, v_, w_, u)

    y = y.reshape(b, s, d)
    # per-head group norm
    yh = y.reshape(b, s, h, n)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(b, s, d).astype(x.dtype)
    y = y * params["tm.ln_x.scale"] + params["tm.ln_x.bias"]
    y = jnp.einsum("bse,ed->bsd", y * g, params["tm.wo"])

    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"tm_x": x[:, -1].astype(jnp.bfloat16), "wkv": state}
    return y, new_cache


def rwkv_channel_mix(cfg: ModelConfig, params, x: jax.Array, *,
                     mode: str, cache: Cache) -> Tuple[jax.Array, Cache]:
    if mode == "decode":
        x_prev = cache["cm_x"][:, None, :].astype(x.dtype)
    else:
        x_prev = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    dx = x_prev - x
    xk = x + dx * params["cm.mu_k"]
    xr = x + dx * params["cm.mu_r"]
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk,
                                          params["cm.wk"])))
    kv = jnp.einsum("bsf,fd->bsd", k, params["cm.wv"])
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["cm.wr"])) * kv
    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"cm_x": x[:, -1].astype(jnp.bfloat16)}
    return out, new_cache


def table(cfg: ModelConfig) -> ParamTable:
    return rwkv_table(cfg)


def apply(cfg: ModelConfig, rules, params, x: jax.Array, *,
          mode: str, cache: Cache, positions: jax.Array
          ) -> Tuple[jax.Array, Cache, Aux]:
    h = layer_norm(x, params["ln1.scale"], params["ln1.bias"], cfg.norm_eps)
    a, c_tm = rwkv_time_mix(cfg, rules, params, h, mode=mode, cache=cache)
    x = x + a
    x = rules.constraint(x, "batch", "seq", None)
    h = layer_norm(x, params["ln2.scale"], params["ln2.bias"], cfg.norm_eps)
    m, c_cm = rwkv_channel_mix(cfg, params, h, mode=mode, cache=cache)
    x = x + m
    x = rules.constraint(x, "batch", "seq", None)
    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {**(c_tm or {}), **(c_cm or {})}
    return x, new_cache, {}


def init_cache(cfg: ModelConfig, batch: int, seq: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    return init_rwkv_cache(cfg, batch, seq, dtype)


# ======================================================================
# RG-LRU (RecurrentGemma / Griffin)
# ======================================================================

_RGLRU_C = 8.0


def rglru_table(cfg: ModelConfig) -> ParamTable:
    d = cfg.d_model
    w = cfg.rnn_width or d
    cw = cfg.conv_width
    return {
        "norm.scale": ((d,), (None,)),
        "rg.w_branch": ((d, w), ("d_model", "rnn")),
        "rg.w_in": ((d, w), ("d_model", "rnn")),
        "rg.conv_w": ((cw, w), (None, "rnn")),
        "rg.conv_b": ((w,), ("rnn",)),
        "rg.w_rgate": ((w, w), ("rnn", None)),
        "rg.w_igate": ((w, w), ("rnn", None)),
        "rg.rgate_bias": ((w,), ("rnn",)),
        "rg.igate_bias": ((w,), ("rnn",)),
        "rg.lambda": ((w,), ("rnn",)),
        "rg.w_out": ((w, d), ("rnn", "d_model")),
        # GeGLU MLP
        "mlp.w_gate": ((d, cfg.d_ff), ("d_model", "d_ff")),
        "mlp.w_up": ((d, cfg.d_ff), ("d_model", "d_ff")),
        "mlp.w_down": ((cfg.d_ff, d), ("d_ff", "d_model")),
        "mlp_norm.scale": ((d,), (None,)),
    }


def init_rglru_cache(cfg: ModelConfig, batch: int, seq: int,
                     dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    w = cfg.rnn_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype=dtype),
        "h": jnp.zeros((batch, w), dtype=jnp.float32),
    }


def _causal_conv(params, x: jax.Array, state: Optional[jax.Array],
                 mode: str) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Depthwise causal conv, width cw.  x: (B, S, W)."""
    cw = params["rg.conv_w"].shape[0]
    if mode == "decode":
        hist = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B,cw,W)
        y = jnp.einsum("bkw,kw->bw", hist, params["rg.conv_w"])
        y = (y + params["rg.conv_b"])[:, None]
        return y, hist[:, 1:]
    pads = [jnp.pad(x[:, :x.shape[1] - i], ((0, 0), (i, 0), (0, 0)))
            for i in range(cw)]
    y = sum(pads[cw - 1 - k] * params["rg.conv_w"][k] for k in range(cw))
    y = y + params["rg.conv_b"]
    new_state = x[:, -(cw - 1):] if mode == "prefill" else None
    return y, new_state


def rglru_scan(a: jax.Array, gx: jax.Array, h0: jax.Array,
               chunk: int = TIME_CHUNK) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + gx_t.  a/gx: (B,S,W) f32. Returns (hT, h)."""
    b, s, w = a.shape

    def step(h, inp):
        at, gt = inp
        h = at * h + gt
        return h, h

    def chunk_body(h, inp):
        return jax.lax.scan(step, h, inp)

    chunk = min(chunk, s)
    if s % chunk == 0 and s > chunk:
        nc = s // chunk
        a_c = a.transpose(1, 0, 2).reshape(nc, chunk, b, w)
        g_c = gx.transpose(1, 0, 2).reshape(nc, chunk, b, w)

        def outer(h, ci):
            return jax.checkpoint(chunk_body)(h, ci)

        hT, hs = jax.lax.scan(outer, h0, (a_c, g_c))
        h = hs.reshape(s, b, w).transpose(1, 0, 2)
    else:
        hT, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2),
                                         gx.transpose(1, 0, 2)))
        h = hs.transpose(1, 0, 2)
    return hT, h


def rglru_apply(cfg: ModelConfig, rules, params, x: jax.Array, *,
                mode: str, cache: Cache) -> Tuple[jax.Array, Cache]:
    """The Griffin recurrent block: GeLU branch ⊙ RG-LRU branch."""
    branch = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x,
                                    params["rg.w_branch"]))
    u = jnp.einsum("bsd,dw->bsw", x, params["rg.w_in"])
    u = rules.constraint(u, "batch", None, "rnn")
    u, conv_state = _causal_conv(
        params, u, cache.get("conv") if cache else None, mode)

    r = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", u, params["rg.w_rgate"])
        + params["rg.rgate_bias"]).astype(jnp.float32)
    i = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", u, params["rg.w_igate"])
        + params["rg.igate_bias"]).astype(jnp.float32)
    log_a = -_RGLRU_C * jax.nn.softplus(
        params["rg.lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * u.astype(jnp.float32)

    h0 = (cache["h"] if (cache is not None and mode == "decode")
          else jnp.zeros(a.shape[::2], dtype=jnp.float32))
    if mode == "decode":
        hT = a[:, 0] * h0 + gated[:, 0]
        h = hT[:, None]
    else:
        hT, h = rglru_scan(a, gated, h0)

    y = (branch * h.astype(branch.dtype))
    y = jnp.einsum("bsw,wd->bsd", y, params["rg.w_out"])
    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"conv": conv_state, "h": hT}
    return y, new_cache


def geglu_mlp(params, x: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, params["mlp.w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, params["mlp.w_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.gelu(g) * u,
                      params["mlp.w_down"])


def rglru_block_apply(cfg: ModelConfig, rules, params, x: jax.Array, *,
                      mode: str, cache: Cache, positions: jax.Array
                      ) -> Tuple[jax.Array, Cache, Aux]:
    h = rms_norm(x, params["norm.scale"], cfg.norm_eps)
    a, new_cache = rglru_apply(cfg, rules, params, h, mode=mode, cache=cache)
    x = x + a
    x = rules.constraint(x, "batch", "seq", None)
    h = rms_norm(x, params["mlp_norm.scale"], cfg.norm_eps)
    x = x + geglu_mlp(params, h)
    x = rules.constraint(x, "batch", "seq", None)
    return x, new_cache, {}


def init_cache_rglru(cfg: ModelConfig, batch: int, seq: int,
                     dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    return init_rglru_cache(cfg, batch, seq, dtype)
