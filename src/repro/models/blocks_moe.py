"""Mixture-of-Experts FFN (OLMoE/DeepSeek) and MLA attention (DeepSeek-V2).

MoE dispatch is the GShard einsum formulation with *token chunking*:
tokens are routed in chunks (``MOE_CHUNK`` tokens) so the dispatch
tensors stay small and the expert all-to-all is naturally pipelined
against expert compute.  Experts are sharded over the mesh `model`
axis (EP); XLA SPMD turns the dispatch/combine einsums into
all-to-alls.

MLA (Multi-head Latent Attention) caches the *compressed* latent
c_kv (kv_lora_rank + rope dims per token) instead of full K/V — the
decode path uses the published weight-absorption trick so the cache
is never decompressed.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention
from repro.models.common import ParamTable, rms_norm, rope

Aux = Dict[str, jax.Array]
Cache = Optional[Dict[str, jax.Array]]

MOE_CHUNK = 2048  # tokens per dispatch chunk


# ----------------------------------------------------------------------
# MoE FFN
# ----------------------------------------------------------------------

def moe_table(cfg: ModelConfig) -> ParamTable:
    d = cfg.d_model
    m = cfg.moe
    f = m.d_ff_expert or cfg.d_ff
    t: ParamTable = {
        "moe.router": ((d, m.n_experts), ("d_model", "experts")),
        "moe.w_gate": ((m.n_experts, d, f), ("experts", "d_model", "d_ff")),
        "moe.w_up": ((m.n_experts, d, f), ("experts", "d_model", "d_ff")),
        "moe.w_down": ((m.n_experts, f, d), ("experts", "d_ff", "d_model")),
        "moe_norm.scale": ((d,), (None,)),
    }
    if m.n_shared:
        fs = f * m.n_shared
        t["moe.shared_gate"] = ((d, fs), ("d_model", "d_ff"))
        t["moe.shared_up"] = ((d, fs), ("d_model", "d_ff"))
        t["moe.shared_down"] = ((fs, d), ("d_ff", "d_model"))
    return t


def _route_chunk(cfg: ModelConfig, rules, params, xc: jax.Array,
                 capacity: int) -> Tuple[jax.Array, Aux]:
    """xc: (T, d) one chunk of tokens -> (T, d) expert mixture."""
    m = cfg.moe
    t, d = xc.shape
    logits = jnp.einsum("td,de->te", xc, params["moe.router"]).astype(
        jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)           # (T, k)
    gates = gates / jnp.maximum(
        jnp.sum(gates, axis=-1, keepdims=True), 1e-9)    # renormalize top-k

    onehot_e = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32)  # (T,k,E)
    # position of each (token, choice) within its expert, in token order
    flat = onehot_e.reshape(t * m.top_k, m.n_experts)
    pos_flat = jnp.cumsum(flat, axis=0) - flat
    pos = jnp.einsum("tke,tke->tk", onehot_e,
                     pos_flat.reshape(t, m.top_k, m.n_experts))
    keep = pos < capacity
    onehot_c = jax.nn.one_hot(pos.astype(jnp.int32), capacity,
                              dtype=jnp.float32) \
        * keep[..., None]                                 # (T, k, C)
    dispatch = jnp.einsum("tke,tkc->tec", onehot_e, onehot_c)
    combine = jnp.einsum("tec,tk->tec", dispatch,
                         gates * keep.astype(gates.dtype))

    xin = jnp.einsum("tec,td->ecd", dispatch.astype(xc.dtype), xc)
    xin = rules.constraint(xin, "act_experts", None, None)
    g = jnp.einsum("ecd,edf->ecf", xin, params["moe.w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xin, params["moe.w_up"])
    h = jax.nn.silu(g) * u
    xout = jnp.einsum("ecf,efd->ecd", h, params["moe.w_down"])
    xout = rules.constraint(xout, "act_experts", None, None)
    y = jnp.einsum("tec,ecd->td", combine.astype(xout.dtype), xout)

    # load-balance + router-z aux losses (train)
    me = jnp.mean(probs, axis=0)                        # mean router prob
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, m.n_experts), axis=1), axis=0)
    aux = {
        "moe_aux": m.n_experts * jnp.sum(me * ce) * m.aux_loss,
        "moe_z": jnp.mean(
            jnp.square(jax.nn.logsumexp(logits, axis=-1))) * m.router_z_loss,
        "moe_dropped": jnp.sum(1.0 - keep.astype(jnp.float32)),
    }
    return y, aux


def _route_chunk_gather(cfg: ModelConfig, rules, params, xc: jax.Array,
                        capacity: int) -> Tuple[jax.Array, Aux]:
    """Gather-based dispatch (§Perf H3) — same math as ``_route_chunk``
    but without the (T, E, C) one-hot dispatch/combine tensors.

    The GShard einsum formulation costs 2*T*E*C*d FLOPs per dispatch
    and combine — MORE than the expert matmuls themselves at top-8/64
    — and materializes (T, E, C) one-hots.  Here the permutation is
    computed on int32 index arrays (a scatter of T*k indices, ~KB) and
    the data movement is two gathers:

      xin[e, c]   = xc[src_token[e, c]]          (token -> expert)
      y[t]       += gate * xout[expert_slot[t]]  (expert -> token)

    so the only O(big) traffic is the tokens themselves, once each
    way.  Expert tensors stay EP-sharded over `model` exactly as
    before (XLA turns the cross-shard gathers into all-to-alls).
    """
    m = cfg.moe
    t, d = xc.shape
    k = m.top_k
    logits = jnp.einsum("td,de->te", xc,
                        params["moe.router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                   # (T, k)
    gates = gates / jnp.maximum(
        jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert (arrival order)
    onehot = jax.nn.one_hot(idx.reshape(-1), m.n_experts,
                            dtype=jnp.int32)               # (T*k, E)
    pos_flat = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos_flat, idx.reshape(-1)[:, None],
                              axis=1)[:, 0]                # (T*k,)
    e_flat = idx.reshape(-1)
    keep = pos < capacity
    slot = e_flat * capacity + pos                          # (T*k,)
    slot = jnp.where(keep, slot, m.n_experts * capacity)    # dropped bin

    # inverse permutation on INDEX arrays only (tiny scatter)
    tok_of_choice = jnp.arange(t * k, dtype=jnp.int32) // k
    src = jnp.full((m.n_experts * capacity + 1,), t,        # t = pad row
                   dtype=jnp.int32)
    src = src.at[slot].set(tok_of_choice)
    src = src[:-1].reshape(m.n_experts, capacity)           # (E, C)

    # token -> expert gather (pad row of zeros for empty slots)
    xpad = jnp.concatenate([xc, jnp.zeros((1, d), xc.dtype)], axis=0)
    xin = xpad[src]                                         # (E, C, d)
    xin = rules.constraint(xin, "act_experts", None, None)
    g = jnp.einsum("ecd,edf->ecf", xin, params["moe.w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xin, params["moe.w_up"])
    h = jax.nn.silu(g) * u
    xout = jnp.einsum("ecf,efd->ecd", h, params["moe.w_down"])
    xout = rules.constraint(xout, "act_experts", None, None)

    # expert -> token gather + gate-weighted combine
    flat_out = xout.reshape(m.n_experts * capacity, d)
    flat_out = jnp.concatenate(
        [flat_out, jnp.zeros((1, d), flat_out.dtype)], axis=0)
    safe_slot = jnp.where(keep, slot, m.n_experts * capacity)
    per_choice = flat_out[safe_slot]                        # (T*k, d)
    w = (gates.reshape(-1) * keep.astype(jnp.float32)).astype(xc.dtype)
    y = jnp.sum((per_choice * w[:, None]).reshape(t, k, d), axis=1)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, m.n_experts), axis=1), axis=0)
    aux = {
        "moe_aux": m.n_experts * jnp.sum(me * ce) * m.aux_loss,
        "moe_z": jnp.mean(
            jnp.square(jax.nn.logsumexp(logits, axis=-1))) * m.router_z_loss,
        "moe_dropped": jnp.sum(1.0 - keep.astype(jnp.float32)),
    }
    return y, aux


def _ep_enabled(cfg: ModelConfig, rules, x: jax.Array) -> bool:
    mesh = rules.mesh
    if "model" not in mesh.shape or mesh.shape["model"] == 1:
        return False
    tp = mesh.shape["model"]
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    return (cfg.moe.n_experts % tp == 0 and x.shape[1] % tp == 0
            and x.shape[0] % dp == 0
            and rules.rules.get("seq") == ("model",))


def _moe_apply_ep(cfg: ModelConfig, rules, params, x: jax.Array
                  ) -> Tuple[jax.Array, Aux]:
    """Expert parallelism via shard_map + all_to_all (§Perf H3b).

    Tokens stay sequence-sharded (they already are between blocks);
    experts live E/TP per shard.  Each shard routes its own tokens,
    packs (E, C_src, d) send buffers with local index arithmetic, and
    one tiled ``all_to_all`` delivers every token to its expert's
    shard — the canonical GShard/MaxText EP exchange.  All heavy
    tensors are token-sized; the only cross-shard traffic is the two
    all-to-alls (a few MB each), vs the hundreds of GB of resharding
    the einsum formulation triggers under SPMD (see EXPERIMENTS.md).

    Capacity bookkeeping is per source shard (C_src = C_global / TP),
    so a shard-local burst can drop tokens a global counter would
    admit — same expected drop rate, simpler = faster; on a 1-shard
    mesh it equals the global-capacity reference exactly (tested).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    mesh = rules.mesh
    tp = mesh.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp_spec = (dp if len(dp) > 1 else dp[0]) if dp else None
    b, s, d = x.shape
    e_loc = m.n_experts // tp
    t_loc = (b // _size(mesh, dp)) * (s // tp) if dp else b * (s // tp)
    cap_src = max(int(m.top_k * t_loc * m.capacity_factor
                      / m.n_experts), 4)

    router_spec = rules.spec_for(("d_model", "experts"),
                                 params["moe.router"].shape)
    w_specs = {
        name: rules.spec_for(("experts", "d_model", "d_ff"),
                             params[name].shape)
        for name in ("moe.w_gate", "moe.w_up", "moe.w_down")}
    # w_down is (E, F, D): logical axes differ
    w_specs["moe.w_down"] = rules.spec_for(
        ("experts", "d_ff", "d_model"), params["moe.w_down"].shape)

    def body(x_loc, router, wg, wu, wd):
        bl, sl, _ = x_loc.shape
        t = bl * sl
        xc = x_loc.reshape(t, d)
        # gather replicated views of the small sharded params
        if router_spec[0] is not None:
            router = jax.lax.all_gather(router, router_spec[0], axis=0,
                                        tiled=True)
        router = jax.lax.all_gather(router, "model", axis=1, tiled=True)
        for name, w in (("moe.w_gate", wg), ("moe.w_up", wu),
                        ("moe.w_down", wd)):
            pass  # expert weights stay local (E_loc shard)
        if w_specs["moe.w_gate"][1] is not None:
            wg = jax.lax.all_gather(wg, w_specs["moe.w_gate"][1],
                                    axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, w_specs["moe.w_up"][1],
                                    axis=1, tiled=True)
        if w_specs["moe.w_down"][2] is not None:
            wd = jax.lax.all_gather(wd, w_specs["moe.w_down"][2],
                                    axis=2, tiled=True)

        logits = jnp.einsum("td,de->te", xc, router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, m.top_k)
        gates = gates / jnp.maximum(
            jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

        onehot = jax.nn.one_hot(idx.reshape(-1), m.n_experts,
                                dtype=jnp.int32)
        pos_flat = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.take_along_axis(pos_flat, idx.reshape(-1)[:, None],
                                  axis=1)[:, 0]
        e_flat = idx.reshape(-1)
        keep = pos < cap_src
        slot = jnp.where(keep, e_flat * cap_src + pos,
                         m.n_experts * cap_src)

        tok_of_choice = jnp.arange(t * m.top_k, dtype=jnp.int32) \
            // m.top_k
        src = jnp.full((m.n_experts * cap_src + 1,), t, dtype=jnp.int32)
        src = src.at[slot].set(tok_of_choice)
        src = src[:-1].reshape(m.n_experts, cap_src)

        xpad = jnp.concatenate([xc, jnp.zeros((1, d), xc.dtype)], 0)
        xsend = xpad[src]                          # (E, C_src, d) local
        # ---- the EP exchange: tokens -> their expert's shard --------
        xrecv = jax.lax.all_to_all(xsend, "model", split_axis=0,
                                   concat_axis=1, tiled=True)
        # (E_loc, C_src * TP, d)
        g = jnp.einsum("ecd,edf->ecf", xrecv, wg)
        u = jnp.einsum("ecd,edf->ecf", xrecv, wu)
        h = jax.nn.silu(g) * u
        xout = jnp.einsum("ecf,efd->ecd", h, wd)
        # ---- reverse exchange: results back to the token's shard ----
        yback = jax.lax.all_to_all(xout, "model", split_axis=1,
                                   concat_axis=0, tiled=True)
        # (E, C_src, d)
        flat_out = yback.reshape(m.n_experts * cap_src, d)
        flat_out = jnp.concatenate(
            [flat_out, jnp.zeros((1, d), flat_out.dtype)], 0)
        per_choice = flat_out[jnp.where(keep, slot,
                                        m.n_experts * cap_src)]
        wgt = (gates.reshape(-1)
               * keep.astype(jnp.float32)).astype(xc.dtype)
        y = jnp.sum((per_choice * wgt[:, None]).reshape(t, m.top_k, d),
                    axis=1)

        # aux stats: global over the model axis (token partition)
        n_tok = t * tp
        me = jax.lax.psum(jnp.sum(probs, axis=0), "model") / n_tok
        ce = jax.lax.psum(
            jnp.sum(jax.nn.one_hot(idx, m.n_experts), axis=(0, 1)),
            "model") / n_tok
        aux = {
            "moe_aux": m.n_experts * jnp.sum(me * ce) * m.aux_loss,
            "moe_z": jax.lax.psum(jnp.sum(jnp.square(
                jax.nn.logsumexp(logits, axis=-1))), "model") / n_tok
            * m.router_z_loss,
            "moe_dropped": jax.lax.psum(
                jnp.sum(1.0 - keep.astype(jnp.float32)), "model"),
        }
        return y.reshape(bl, sl, d), aux

    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_spec, "model", None), router_spec,
                  w_specs["moe.w_gate"], w_specs["moe.w_up"],
                  w_specs["moe.w_down"]),
        out_specs=(P(dp_spec, "model", None), P()),
        check_rep=False,
    )(x, params["moe.router"], params["moe.w_gate"],
      params["moe.w_up"], params["moe.w_down"])

    if m.n_shared:
        from repro.distributed import megatron_sp
        if megatron_sp.sp_enabled(rules, x.shape[1], x.shape[0]):
            g, u = megatron_sp.in_project_ag(
                x, [params["moe.shared_gate"], params["moe.shared_up"]],
                rules=rules, kinds=("df", "df"))
            h = jax.nn.silu(g) * u
            y = y + megatron_sp.out_project_rs(
                h, params["moe.shared_down"], rules=rules, contract="fd")
        else:
            g = jnp.einsum("bsd,df->bsf", x, params["moe.shared_gate"])
            u = jnp.einsum("bsd,df->bsf", x, params["moe.shared_up"])
            y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                               params["moe.shared_down"])
    return y, aux


def _size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def moe_apply(cfg: ModelConfig, rules, params, x: jax.Array
              ) -> Tuple[jax.Array, Aux]:
    """x: (B, S, d).  Chunked routing; shared experts added densely."""
    m = cfg.moe
    if m.dispatch == "gather" and _ep_enabled(cfg, rules, x):
        return _moe_apply_ep(cfg, rules, params, x)
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    n_tok = tokens.shape[0]
    chunk = min(MOE_CHUNK, n_tok)
    n_chunks = max(n_tok // chunk, 1)
    capacity = max(int(m.top_k * chunk * m.capacity_factor / m.n_experts), 4)
    route = (_route_chunk_gather if m.dispatch == "gather"
             else _route_chunk)

    if n_chunks * chunk != n_tok:  # ragged tail: single-chunk fallback
        y, aux = route(cfg, rules, params, tokens, capacity=max(
            int(m.top_k * n_tok * m.capacity_factor / m.n_experts), 4))
    else:
        xs = tokens.reshape(n_chunks, chunk, d)

        def body(carry, xc):
            y, aux = route(cfg, rules, params, xc, capacity)
            return carry, (y, aux)

        _, (ys, auxs) = jax.lax.scan(body, (), xs)
        y = ys.reshape(n_tok, d)
        aux = jax.tree.map(lambda a: jnp.sum(a) / n_chunks, auxs)
        aux["moe_dropped"] = aux["moe_dropped"] * n_chunks  # total, not mean

    y = y.reshape(b, s, d)
    if m.n_shared:
        g = jnp.einsum("bsd,df->bsf", x, params["moe.shared_gate"])
        u = jnp.einsum("bsd,df->bsf", x, params["moe.shared_up"])
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                           params["moe.shared_down"])
    return y, aux


# ----------------------------------------------------------------------
# MLA attention (DeepSeek-V2)
# ----------------------------------------------------------------------

def mla_table(cfg: ModelConfig) -> ParamTable:
    d, h = cfg.d_model, cfg.n_heads
    a = cfg.mla
    qk = a.qk_nope_dim + a.qk_rope_dim
    return {
        "mla.wq": ((d, h, qk), ("d_model", "heads", None)),
        "mla.w_dkv": ((d, a.kv_lora_rank + a.qk_rope_dim), ("d_model", None)),
        "mla.kv_norm.scale": ((a.kv_lora_rank,), (None,)),
        "mla.w_uk": ((a.kv_lora_rank, h, a.qk_nope_dim),
                     (None, "heads", None)),
        "mla.w_uv": ((a.kv_lora_rank, h, a.v_head_dim),
                     (None, "heads", None)),
        "mla.wo": ((h, a.v_head_dim, d), ("heads", None, "d_model")),
        "attn_norm.scale": ((d,), (None,)),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, seq: int,
                   dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    a = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, seq, a.kv_lora_rank), dtype=dtype),
        "k_pe": jnp.zeros((batch, seq, a.qk_rope_dim), dtype=dtype),
    }


def mla_apply(cfg: ModelConfig, rules, params, x: jax.Array, *,
              mode: str, cache: Cache, positions: jax.Array
              ) -> Tuple[jax.Array, Cache]:
    a = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    scale = (a.qk_nope_dim + a.qk_rope_dim) ** -0.5

    from repro.distributed import megatron_sp
    sp = (mode != "decode"
          and megatron_sp.sp_enabled(rules, s, b)
          and rules.spec_for(("d_model", "heads", "head_dim"),
                             params["mla.wq"].shape)[1] is not None)
    if sp:
        (q,) = megatron_sp.in_project_ag(x, [params["mla.wq"]],
                                         rules=rules, kinds=("dhk",))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["mla.wq"])
    q_nope, q_pe = q[..., :a.qk_nope_dim], q[..., a.qk_nope_dim:]
    q_pe = rope(q_pe, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, params["mla.w_dkv"])
    c_kv = rms_norm(ckv_full[..., :a.kv_lora_rank],
                    params["mla.kv_norm.scale"], cfg.norm_eps)
    k_pe = rope(ckv_full[..., a.kv_lora_rank:], positions, cfg.rope_theta)

    if mode == "decode":
        assert cache is not None
        idx = positions[0, 0]
        c_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), idx, axis=1)
        p_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), idx, axis=1)
        c_cache = rules.constraint(c_cache, "batch", "kv_seq", None)
        p_cache = rules.constraint(p_cache, "batch", "kv_seq", None)
        # absorbed decode: scores/context in the compressed space
        q_c = jnp.einsum("bshk,rhk->bshr", q_nope, params["mla.w_uk"])
        scores = (jnp.einsum("bshr,btr->bhst", q_c, c_cache,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshk,btk->bhst", q_pe, p_cache,
                               preferred_element_type=jnp.float32)) * scale
        valid = jnp.arange(c_cache.shape[1])[None, :] <= idx
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", probs.astype(c_cache.dtype),
                         c_cache)
        out = jnp.einsum("bshr,rhv->bshv", ctx, params["mla.w_uv"])
        new_cache = {"c_kv": c_cache, "k_pe": p_cache}
    else:
        # train/prefill: decompress K/V (sequence-parallel friendly)
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["mla.w_uk"])
        v = jnp.einsum("bsr,rhv->bshv", c_kv, params["mla.w_uv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None, :],
                                      (b, s, h, a.qk_rope_dim))], axis=-1)
        qq = jnp.concatenate([q_nope, q_pe], axis=-1)
        # pad V to qk dim so we can reuse the blocked kernel, then slice
        qt = qq.transpose(0, 2, 1, 3)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        qt = rules.constraint(qt, "batch", "act_heads", None, None)
        out = attention.full_attention(qt, kt, vt, causal=True,
                                       q_block=cfg.q_block, scale=scale)
        out = out.transpose(0, 2, 1, 3)
        new_cache = None
        if mode == "prefill":
            c_cache = rules.constraint(c_kv, "batch", "kv_seq", None)
            p_cache = rules.constraint(k_pe, "batch", "kv_seq", None)
            new_cache = {"c_kv": c_cache.astype(x.dtype),
                         "k_pe": p_cache.astype(x.dtype)}

    if sp:
        y = megatron_sp.out_project_rs(out, params["mla.wo"],
                                       rules=rules, contract="hkd")
    else:
        y = jnp.einsum("bshv,hvd->bsd", out, params["mla.wo"])
    return y, new_cache


# ----------------------------------------------------------------------
# Full MoE decoder blocks
# ----------------------------------------------------------------------

def table(cfg: ModelConfig) -> ParamTable:
    """MoE block: (MLA | GQA) attention + MoE FFN."""
    from repro.models import blocks_attn
    at = mla_table(cfg) if cfg.mla else blocks_attn.attn_table(cfg)
    return {**at, **moe_table(cfg)}


def apply(cfg: ModelConfig, rules, params, x: jax.Array, *,
          mode: str, cache: Cache, positions: jax.Array
          ) -> Tuple[jax.Array, Cache, Aux]:
    from repro.models import blocks_attn
    h = rms_norm(x, params["attn_norm.scale"], cfg.norm_eps)
    if cfg.mla:
        a, new_cache = mla_apply(cfg, rules, params, h, mode=mode,
                                 cache=cache, positions=positions)
    else:
        a, new_cache = blocks_attn.attn_apply(
            cfg, rules, params, h, mode=mode, cache=cache,
            positions=positions)
    x = x + a
    x = rules.constraint(x, "batch", "seq", None)
    hh = rms_norm(x, params["moe_norm.scale"], cfg.norm_eps)
    y, aux = moe_apply(cfg, rules, params, hh)
    x = x + y
    x = rules.constraint(x, "batch", "seq", None)
    return x, new_cache, aux


def init_cache(cfg: ModelConfig, batch: int, seq: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    from repro.models import blocks_attn
    if cfg.mla:
        return init_mla_cache(cfg, batch, seq, dtype)
    return blocks_attn.init_attn_cache(cfg, batch, seq, dtype)


# Dense-FFN + MLA block (DeepSeek first_dense_layers)

def dense_mla_table(cfg: ModelConfig) -> ParamTable:
    from repro.models import blocks_attn
    at = mla_table(cfg) if cfg.mla else blocks_attn.attn_table(cfg)
    return {**at, **blocks_attn.mlp_table(cfg, d_ff=cfg.moe.d_ff_dense)}


def dense_mla_apply(cfg: ModelConfig, rules, params, x: jax.Array, *,
                    mode: str, cache: Cache, positions: jax.Array
                    ) -> Tuple[jax.Array, Cache, Aux]:
    from repro.models import blocks_attn
    h = rms_norm(x, params["attn_norm.scale"], cfg.norm_eps)
    if cfg.mla:
        a, new_cache = mla_apply(cfg, rules, params, h, mode=mode,
                                 cache=cache, positions=positions)
    else:
        a, new_cache = blocks_attn.attn_apply(
            cfg, rules, params, h, mode=mode, cache=cache,
            positions=positions)
    x = x + a
    h = rms_norm(x, params["mlp_norm.scale"], cfg.norm_eps)
    x = x + blocks_attn.mlp_apply(cfg, rules, params, h)
    x = rules.constraint(x, "batch", "seq", None)
    return x, new_cache, {}
