"""Gradient compression with error feedback (distributed-optimization
substrate, beyond-paper).

At 1000+-node scale the data-parallel gradient all-reduce dominates the
interconnect; int8 block-quantized gradients cut those bytes 4x.  The
scheme is EF-SGD-style error feedback:

    acc   = grad + error            (carry what compression dropped)
    q     = quantize(acc)           (int8 + per-block f32 scale)
    error = acc - dequantize(q)     (next step's correction)

Quantization happens *before* the (simulated) all-reduce boundary in
``train_step``; because the compressed representation is what crosses
the mesh, the roofline collective term for DP gradient sync shrinks by
the same 4x (see EXPERIMENTS.md §Perf).  Error-feedback buffers live in
the train state and are sharded like the gradients themselves.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256  # quantization block (last-dim groups)


class Compressed(NamedTuple):
    q: jax.Array      # int8 payload
    scale: jax.Array  # f32 per-block scale


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def quantize(x: jax.Array) -> Compressed:
    """Symmetric int8 per-block quantization of an f32 tensor."""
    blocks, _ = _pad_to_block(x)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return Compressed(q=q, scale=scale.astype(jnp.float32))


def dequantize(c: Compressed, shape: Tuple[int, ...]) -> jax.Array:
    flat = (c.q.astype(jnp.float32) * c.scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_with_feedback(grads: Dict[str, jax.Array],
                           errors: Dict[str, jax.Array]
                           ) -> Tuple[Dict[str, jax.Array],
                                      Dict[str, jax.Array],
                                      jax.Array]:
    """Returns (decompressed grads as seen post-all-reduce, new error
    buffers, mean abs quantization error) — the lossy round trip the
    gradients experience on the wire."""
    out: Dict[str, jax.Array] = {}
    new_err: Dict[str, jax.Array] = {}
    tot_err = jnp.float32(0.0)
    n = 0
    for name, g in grads.items():
        acc = g.astype(jnp.float32) + errors[name]
        c = quantize(acc)
        deq = dequantize(c, g.shape)
        out[name] = deq
        new_err[name] = acc - deq
        tot_err = tot_err + jnp.mean(jnp.abs(new_err[name]))
        n += 1
    return out, new_err, tot_err / max(n, 1)


def init_error_buffers(params: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    return {k: jnp.zeros(v.shape, dtype=jnp.float32)
            for k, v in params.items()}


def abstract_error_buffers(params: Any) -> Dict[str, Any]:
    return {k: jax.ShapeDtypeStruct(v.shape, jnp.float32)
            for k, v in params.items()}
