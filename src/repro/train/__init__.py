"""Training substrate: optimizer, microbatched step, grad compression."""
from repro.train.optimizer import (OptimizerConfig, OptState, adamw_update,
                                   init_opt_state, lr_at, global_norm)
from repro.train.train_step import (TrainState, init_train_state,
                                    abstract_train_state, make_train_step,
                                    jit_train_step, state_shardings,
                                    batch_shardings)

__all__ = ["OptimizerConfig", "OptState", "adamw_update", "init_opt_state",
           "lr_at", "global_norm", "TrainState", "init_train_state",
           "abstract_train_state", "make_train_step", "jit_train_step",
           "state_shardings", "batch_shardings"]
