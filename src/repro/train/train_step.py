"""The jitted training step: microbatched grad accumulation + AdamW.

``make_train_step(cfg, rules, opt_cfg)`` returns ``(step_fn,
state_shardings, batch_shardings)`` where ``step_fn(state, batch) ->
(state, metrics)`` is ready for ``jax.jit`` with those shardings.

Memory shape: the global batch is split into ``cfg.accum_steps``
microbatches scanned sequentially; gradients accumulate in f32 into
FSDP-sharded buffers, so peak activation memory is one microbatch and
the optimizer never sees unsharded state.  Optional int8 gradient
compression with error feedback (``compress=True``) shrinks the DP
all-reduce bytes 4x (see train/compression.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules
from repro.models import api
from repro.models.common import abstract_params, init_params
from repro.train import compression
from repro.train.optimizer import (OptimizerConfig, OptState, adamw_update,
                                   abstract_opt_state, init_opt_state)

Params = Dict[str, jax.Array]


class TrainState(NamedTuple):
    params: Params
    opt: OptState
    ef: Optional[Params]   # error-feedback buffers (compression only)


def init_train_state(key: jax.Array, cfg: ModelConfig, *,
                     compress: bool = False) -> TrainState:
    params = init_params(key, api.param_table(cfg))
    return TrainState(
        params=params,
        opt=init_opt_state(params),
        ef=compression.init_error_buffers(params) if compress else None)


def abstract_train_state(cfg: ModelConfig, *,
                         compress: bool = False) -> TrainState:
    params = abstract_params(api.param_table(cfg))
    return TrainState(
        params=params,
        opt=abstract_opt_state(params),
        ef=compression.abstract_error_buffers(params) if compress else None)


def state_shardings(cfg: ModelConfig, rules: ShardingRules) -> TrainState:
    """PartitionSpecs for the train state (moments/EF like the params)."""
    table = api.param_table(cfg)
    p = rules.table_shardings(table)
    return TrainState(
        params=p,
        opt=OptState(mu=dict(p), nu=dict(p),
                     count=NamedSharding(rules.mesh, P())),
        ef=None)


def batch_shardings(cfg: ModelConfig, rules: ShardingRules
                    ) -> Dict[str, NamedSharding]:
    """Batch arrays are sharded over the DP axes on dim 0."""
    dp = tuple(a for a in ("pod", "data") if a in rules.mesh.shape)
    spec2 = NamedSharding(rules.mesh, P(dp, None))
    spec3 = NamedSharding(rules.mesh, P(dp, None, None))
    out = {"tokens": spec2, "labels": spec2, "mask": spec2}
    if cfg.family == "vlm":
        out["patches"] = spec3
    if cfg.family == "encdec":
        out["frames"] = spec3
    return out


def _split_microbatches(batch: Dict[str, jax.Array], accum: int
                        ) -> Dict[str, jax.Array]:
    def split(x):
        b = x.shape[0]
        assert b % accum == 0, f"batch {b} not divisible by accum {accum}"
        return x.reshape(accum, b // accum, *x.shape[1:])
    return {k: split(v) for k, v in batch.items()}


def make_train_step(cfg: ModelConfig, rules: ShardingRules,
                    opt_cfg: OptimizerConfig = OptimizerConfig(), *,
                    compress: bool = False,
                    accum_steps: Optional[int] = None):
    """Returns ``step_fn(state, batch) -> (state, metrics)``."""
    accum = accum_steps if accum_steps is not None else cfg.accum_steps

    def loss_fn(params: Params, mb: Dict[str, jax.Array]):
        return api.train_loss(cfg, rules, params, mb)

    def step_fn(state: TrainState, batch: Dict[str, jax.Array]
                ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if accum > 1:
            mbs = _split_microbatches(batch, accum)

            def body(carry, mb):
                gsum, lsum = carry
                (loss, metrics), grads = grad_fn(state.params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss), metrics

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, dtype=jnp.float32),
                state.params)
            (gsum, lsum), metrics_stack = jax.lax.scan(
                body, (gzero, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics_stack)
        else:
            (loss, metrics), grads = grad_fn(state.params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        ef = state.ef
        if compress:
            grads, ef, qerr = compression.compress_with_feedback(grads, ef)
            metrics = dict(metrics)
            metrics["compression_err"] = qerr

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt, ef), metrics

    return step_fn


def jit_train_step(cfg: ModelConfig, rules: ShardingRules,
                   opt_cfg: OptimizerConfig = OptimizerConfig(), *,
                   compress: bool = False,
                   accum_steps: Optional[int] = None,
                   donate: bool = True):
    """jit-wrapped step with explicit in/out shardings (dry-run ready)."""
    step = make_train_step(cfg, rules, opt_cfg, compress=compress,
                           accum_steps=accum_steps)
    ss = state_shardings(cfg, rules)
    if compress:
        ss = ss._replace(ef=dict(ss.params))
    bs = batch_shardings(cfg, rules)
    return jax.jit(
        step,
        in_shardings=(ss, bs),
        out_shardings=(ss, None),
        donate_argnums=(0,) if donate else ())
