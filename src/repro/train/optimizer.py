"""Optimizer substrate: AdamW + cosine schedule + global-norm clipping.

Pure-pytree implementation (no optax dependency).  The optimizer state
is a pytree of the same structure as the params, so it inherits the
params' PartitionSpecs (FSDP-sharded moments — ZeRO-style) without any
extra sharding rules.

``scale_by_schedule`` composes warmup + cosine decay; ``adamw_update``
is a single fused-form update used inside the jitted train step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any  # pytree


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    end_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    mu: Params       # first moment (f32)
    nu: Params       # second moment (f32)
    count: jax.Array # i32 step


def init_opt_state(params: Params) -> OptState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, dtype=jnp.float32), params)
    return OptState(mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros),
                    count=jnp.zeros((), dtype=jnp.int32))


def abstract_opt_state(params: Params) -> OptState:
    ab = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return OptState(mu=ab, nu=ab,
                    count=jax.ShapeDtypeStruct((), jnp.int32))


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to ``end_lr_frac * peak``."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    floor = cfg.peak_lr * cfg.end_lr_frac
    cos = floor + (cfg.peak_lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


_NO_DECAY_SUFFIXES = (".bias", "norm.scale", "norm.bias", "ln.scale",
                      "ln.bias", ".mu", ".mu_x", ".mu_k", ".mu_r",
                      ".decay_base", ".bonus", ".lambda",
                      ".rgate_bias", ".igate_bias")


def _decays(name: str) -> bool:
    return not name.endswith(_NO_DECAY_SUFFIXES)


def adamw_update(cfg: OptimizerConfig, params: Dict[str, jax.Array],
                 grads: Dict[str, jax.Array], opt: OptState
                 ) -> Tuple[Dict[str, jax.Array], OptState, Dict[str, jax.Array]]:
    """One AdamW step over the flat param dict.  Grads are expected in
    f32 (the accumulation dtype); params stay in their storage dtype."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = opt.count + 1
    lr = lr_at(cfg, count)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    new_params: Dict[str, jax.Array] = {}
    new_mu: Dict[str, jax.Array] = {}
    new_nu: Dict[str, jax.Array] = {}
    for name, p in params.items():
        g = grads[name].astype(jnp.float32)
        mu = cfg.b1 * opt.mu[name] + (1 - cfg.b1) * g
        nu = cfg.b2 * opt.nu[name] + (1 - cfg.b2) * jnp.square(g)
        upd = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        if _decays(name):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_params[name] = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        new_mu[name] = mu
        new_nu[name] = nu

    return (new_params,
            OptState(mu=new_mu, nu=new_nu, count=count),
            {"lr": lr, "grad_norm": gnorm})
