"""Explicit Megatron-style sequence-parallel transitions.

With activations sequence-sharded between blocks and heads/d_ff
TP-sharded inside them, the mathematically right collective after the
attention-out / MLP-down projections is a **reduce-scatter** over the
sequence axis (bf16, 1/TP of the bytes of a full all-reduce).  The
SPMD partitioner is free to emit an all-reduce + slice instead — and
XLA:CPU always does (this build never creates reduce-scatters; see
EXPERIMENTS.md §Perf H2) — promoting the operand to f32 on the way,
which quadruples the dominant collective term of the dense train
cells.

These helpers make the transition explicit with ``shard_map`` +
``jax.lax.psum_scatter`` so the collective schedule is what a TPU
deployment would run, independent of backend pass availability:

  out_project_rs   y = einsum(h, w)  -> reduce-scatter(seq)
                   (FSDP weight shards are all-gathered inside, which
                   is the ZeRO-3 gather XLA would insert anyway.)

Differentiable: the transpose of psum_scatter is all-gather and vice
versa, so the backward pass gets the mirrored schedule for free.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import ShardingRules


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def sp_enabled(rules: ShardingRules, seq: int,
               batch: Optional[int] = None) -> bool:
    """SP transitions apply when the rules sequence-shard activations
    over a real model axis that divides the sequence length, and (when
    given) the batch divides the DP axes — shard_map requires exact
    divisibility where pjit would pad."""
    mesh = rules.mesh
    if "model" not in mesh.shape or mesh.shape["model"] == 1:
        return False
    if rules.rules.get("seq") != ("model",):
        return False
    if batch is not None:
        dp = _dp_axes(mesh)
        n = 1
        for a in dp:
            n *= mesh.shape[a]
        if dp and batch % n != 0:
            return False
    return seq % mesh.shape["model"] == 0


def out_project_rs(h: jax.Array, w: jax.Array, *, rules: ShardingRules,
                   contract: str, batch_sharded: bool = True) -> jax.Array:
    """TP out-projection with an explicit reduce-scatter over sequence.

    contract="hkd": h (B, S, H, K) head-sharded,  w (H, K, D)
    contract="fd":  h (B, S, F)   d_ff-sharded,   w (F, D)

    Weights may be FSDP-sharded on their d_model axis (ZeRO-3); the
    shard is all-gathered over the DP axes inside, exactly the gather
    XLA inserts for the implicit path.  Returns (B, S/TP, D) sequence-
    sharded bf16 — the inter-block layout.
    """
    mesh = rules.mesh
    dp = _dp_axes(mesh)
    dp_spec = (dp if len(dp) > 1 else dp[0]) if batch_sharded and dp \
        else None

    if contract == "hkd":
        w_spec = rules.spec_for(("heads", "head_dim", "d_model"), w.shape)
        # h's head axis mirrors the weight's (replicated when heads
        # don't divide TP, e.g. recurrentgemma's 10 heads on 16)
        h_spec = P(dp_spec, None, w_spec[0], None)
        eins = "bshk,hkd->bsd"
        w_dm_axis = 2
    elif contract == "fd":
        w_spec = rules.spec_for(("d_ff", "d_model"), w.shape)
        h_spec = P(dp_spec, None, w_spec[0])
        eins = "bsf,fd->bsd"
        w_dm_axis = 1
    else:
        raise ValueError(contract)

    w_dp = w_spec[w_dm_axis]  # how the weight's d_model axis is sharded

    def body(h_loc, w_loc):
        if w_dp is not None:
            w_loc = jax.lax.all_gather(
                w_loc, w_dp, axis=w_dm_axis, tiled=True)  # ZeRO-3 gather
        partial = jnp.einsum(eins, h_loc, w_loc)          # local TP sum
        return jax.lax.psum_scatter(partial, "model",
                                    scatter_dimension=1, tiled=True)

    out_spec = P(dp_spec, "model", None)
    return shard_map(body, mesh=mesh, in_specs=(h_spec, w_spec),
                     out_specs=out_spec, check_rep=False)(h, w)


def in_project_ag(x: jax.Array, weights, *, rules: ShardingRules,
                  kinds, batch_sharded: bool = True):
    """Fused SP->TP input projections: gather the sequence axis once
    and apply every projection inside ONE shard_map.

    Why fused: if the gather and the einsums live in separate SPMD
    regions, the einsums' input-gradient resolves its partial sums with
    a full all-reduce *and then* the gather's transpose scatters it —
    two reductions for one mathematical reduce-scatter.  Inside one
    shard_map, AD emits exactly ``psum_scatter(dout @ w^T)`` (the fused
    reduce-scatter) and nothing else (§Perf H2, iteration 3).

    x: (B, S, D) sequence-sharded.  kinds per weight: "df" ((D, F),
    F TP-sharded) or "dhk" ((D, H, K), H TP-sharded when divisible).
    Weight d_model axes may be FSDP-sharded; gathered inside (ZeRO-3).
    Returns one output per weight, full-seq, TP-sharded on F/H.
    """
    mesh = rules.mesh
    dp = _dp_axes(mesh)
    dp_spec = (dp if len(dp) > 1 else dp[0]) if batch_sharded and dp \
        else None

    w_specs = []
    for w, kind in zip(weights, kinds):
        logical = ("d_model", "d_ff") if kind == "df" \
            else ("d_model", "heads", "head_dim")
        w_specs.append(rules.spec_for(logical, w.shape))

    def body(x_loc, *w_locs):
        x_full = jax.lax.all_gather(x_loc, "model", axis=1, tiled=True)
        outs = []
        for w_loc, spec, kind in zip(w_locs, w_specs, kinds):
            if spec[0] is not None:  # ZeRO-3: gather the FSDP shard
                w_loc = jax.lax.all_gather(w_loc, spec[0], axis=0,
                                           tiled=True)
            eins = "bsd,df->bsf" if kind == "df" else "bsd,dhk->bshk"
            outs.append(jnp.einsum(eins, x_full, w_loc))
        return tuple(outs)

    out_specs = tuple(
        P(dp_spec, None, s[1]) if kind == "df"
        else P(dp_spec, None, s[1], None)
        for s, kind in zip(w_specs, kinds))
    return shard_map(body, mesh=mesh,
                     in_specs=(P(dp_spec, "model", None), *w_specs),
                     out_specs=out_specs,
                     check_rep=False)(x, *weights)


def gather_seq(x: jax.Array, *, rules: ShardingRules,
               batch_sharded: bool = True) -> jax.Array:
    """SP->TP transition: all-gather the sequence axis (bf16).

    Explicit so that (a) the gather happens on the bf16 residual (the
    implicit XLA path hoists an f32 convert through it) and (b) the
    BACKWARD is ``psum_scatter`` — a true reduce-scatter — instead of
    the all-reduce+slice XLA:CPU falls back to (EXPERIMENTS.md §Perf).
    x: (B, S, D) sequence-sharded -> (B, S, D) replicated over model.
    """
    mesh = rules.mesh
    dp = _dp_axes(mesh)
    dp_spec = (dp if len(dp) > 1 else dp[0]) if batch_sharded and dp \
        else None

    def body(x_loc):
        return jax.lax.all_gather(x_loc, "model", axis=1, tiled=True)

    return shard_map(body, mesh=mesh,
                     in_specs=P(dp_spec, "model", None),
                     out_specs=P(dp_spec, None, None),
                     check_rep=False)(x)
