"""Logical-axis -> mesh-axis sharding rules (DP / FSDP / TP / EP / SP).

Every parameter in a model's param table carries *logical* axis names
(("d_model", "d_ff"), ("experts", "d_model", "d_ff"), ...).  A
``ShardingRules`` maps logical names to mesh axes; unknown/None axes
replicate.  Divisibility is checked per-tensor: a logical rule that
does not divide the concrete dim falls back to replication (e.g. GQA
kv_heads=8 on a model axis of 16 — the KV heads stay replicated and
the sequence axis carries the parallelism instead).

Strategies
----------
``fsdp_tp``   (train default)  params: d_model->fsdp axes, d_ff/heads/
              vocab/experts->model; activations: batch->dp axes,
              seq->model (Megatron-style sequence parallelism between
              blocks).
``dp_tp``     params replicated over data (pure DP + TP).
``decode``    like fsdp_tp but KV cache sequence axis -> model
              (flash-decode style distributed attention).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ParamTable

MeshAxes = Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of axes, or None)."""
    rules: Dict[str, Optional[Tuple[str, ...]]]
    mesh: Mesh

    def spec_for(self, logical_axes: Tuple[Optional[str], ...],
                 shape: Tuple[int, ...]) -> P:
        parts = []
        used: set = set()
        for dim, name in zip(shape, logical_axes):
            axes = self.rules.get(name) if name else None
            if axes is None:
                parts.append(None)
                continue
            axes = tuple(a for a in axes if a in self.mesh.shape
                         and a not in used)
            size = 1
            for a in axes:
                size *= self.mesh.shape[a]
            if axes and dim % size == 0 and dim > 0:
                parts.append(axes if len(axes) > 1 else axes[0])
                used.update(axes)
            else:
                parts.append(None)  # non-divisible -> replicate
        return P(*parts)

    def sharding_for(self, logical_axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical_axes, shape))

    def table_shardings(self, table: ParamTable) -> Dict[str, NamedSharding]:
        return {name: self.sharding_for(axes, shape)
                for name, (shape, axes) in table.items()}

    def constraint(self, x: jax.Array,
                   *logical_axes: Optional[str]) -> jax.Array:
        """with_sharding_constraint by logical axis names."""
        spec = self.spec_for(tuple(logical_axes), x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def make_rules(mesh: Mesh, strategy: str = "fsdp_tp") -> ShardingRules:
    dp = _dp_axes(mesh)
    model = ("model",) if "model" in mesh.shape else ()

    if strategy == "fsdp_tp":
        rules = {
            # --- parameters ---
            "d_model": dp,            # FSDP shard of the big dims
            "d_ff": model,            # TP
            "heads": model,
            "kv_heads": model,        # falls back to replicate if ¬divisible
            "head_dim": None,
            "vocab": model,
            "experts": model,         # EP
            "rnn": model,
            "layers": None,
            # --- activations ---
            "batch": dp,
            "seq": model,             # sequence parallelism between blocks
            "act_heads": model,       # attention compute sharded by heads
            "act_kv_heads": model,
            "kv_seq": None,           # train/prefill KV seq replicated
            "act_d_model": None,
            "act_d_ff": model,
            "act_vocab": model,
            "act_experts": model,
        }
    elif strategy == "dp_tp":
        rules = {
            "d_model": None, "d_ff": model, "heads": model,
            "kv_heads": model, "head_dim": None, "vocab": model,
            "experts": model, "rnn": model, "layers": None,
            "batch": dp, "seq": None, "act_heads": model,
            "act_kv_heads": model, "kv_seq": None, "act_d_model": None,
            "act_d_ff": model, "act_vocab": model, "act_experts": model,
        }
    elif strategy == "decode":
        rules = {
            "d_model": dp, "d_ff": model, "heads": model,
            "kv_heads": model, "head_dim": None, "vocab": model,
            "experts": model, "rnn": model, "layers": None,
            "batch": dp, "seq": None,
            "act_heads": model, "act_kv_heads": model,
            # the KV cache's sequence axis carries model parallelism:
            # distributed flash-decode (XLA inserts masked max/sum
            # all-reduces for the softmax over the sharded axis)
            "kv_seq": model,
            "act_d_model": None, "act_d_ff": model, "act_vocab": model,
            "act_experts": model,
        }
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return ShardingRules(rules=rules, mesh=mesh)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
