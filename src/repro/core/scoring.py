"""Policy scoring (§3.4) and the Kiviat/radar evaluation (§4.2).

The paper's objective:

    Score(p) = 0.25*maxWT(p) + 0.25*maxSD(p) + 0.25*avgWT(p) + 0.25*avgSD(p)

over the jobs waiting in the queue at decision time.  All four terms are
costs (smaller is better); we therefore *minimize* Score — the paper's
"highest score is selected" phrasing is read as intent (best policy),
see DESIGN.md §4.  Wait times are scored in minutes so the WT and SD
terms live on comparable scales within one trace.

Ties: identical costs are broken by pool *position* (``select_policy``
is an argmin with first-occurrence wins).  With the parametric
``PolicySpec`` pools this stays the tie-break: the paper's WFP -> FCFS
-> SJF priority is simply the order those fixed points occupy in the
pool, and sweep grid points rank by their expansion order.

This module defines the paper score's arithmetic; the *configurable*
goal layer on top of it — single-metric, weighted, lexicographic and
constrained objectives, plus the goal grammar — lives in
``repro.core.objective`` (DESIGN.md §8).  ``objective="score"`` (the
default everywhere) routes back through ``policy_cost`` bit-exactly.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.des import DrainMetrics


class ScoreWeights(NamedTuple):
    max_wait: float = 0.25
    max_slowdown: float = 0.25
    avg_wait: float = 0.25
    avg_slowdown: float = 0.25


PAPER_WEIGHTS = ScoreWeights()
_WT_SCALE = 1.0 / 60.0  # seconds -> minutes


def policy_cost(metrics: DrainMetrics,
                weights: ScoreWeights = PAPER_WEIGHTS) -> jax.Array:
    """The paper's Score(p), as a cost to minimize.  Broadcasts over a
    leading policy axis when metrics come from a vmapped what-if."""
    return (weights.max_wait * metrics.max_wait * _WT_SCALE
            + weights.max_slowdown * metrics.max_slowdown
            + weights.avg_wait * metrics.avg_wait * _WT_SCALE
            + weights.avg_slowdown * metrics.avg_slowdown)


def select_policy(costs: jax.Array) -> jax.Array:
    """argmin with first-occurrence tie-break = paper's priority order."""
    return jnp.argmin(costs)


# ----------------------------------------------------------------------
# Kiviat (radar) chart evaluation — Figure 3.
# ----------------------------------------------------------------------

RADAR_AXES = ("avg_wait", "max_wait", "avg_slowdown", "max_slowdown",
              "utilization")
_COST_AXES = ("avg_wait", "max_wait", "avg_slowdown", "max_slowdown")


def radar_normalize(per_policy: Dict[str, Dict[str, float]],
                    axes: tuple = RADAR_AXES,
                    cost_axes: tuple = _COST_AXES) -> Dict[str, Dict[str, float]]:
    """Min-max normalize each axis across policies so that the *best*
    policy gets radius 1 and the worst radius 0 (paper: larger area =
    better overall performance; FCFS measured area 0.00 => worst on all
    axes maps to the origin).

    ``axes``/``cost_axes`` default to the paper's five metrics; pass
    the term names of an objective breakdown
    (``Telemetry.objective_breakdown``) to chart the administrator's
    goal instead — objective terms are ALL costs (rewards arrive
    pre-negated), so ``cost_axes=axes`` there."""
    names = list(per_policy)
    out: Dict[str, Dict[str, float]] = {n: {} for n in names}
    for axis in axes:
        vals = np.array([per_policy[n][axis] for n in names], dtype=np.float64)
        lo, hi = vals.min(), vals.max()
        span = hi - lo
        for n, v in zip(names, vals):
            if span <= 0:
                r = 1.0
            elif axis in cost_axes:
                r = (hi - v) / span      # lower cost -> larger radius
            else:
                r = (v - lo) / span      # higher utilization -> larger radius
            out[n][axis] = float(r)
    return out


def radar_area(radii: Dict[str, float], axes: tuple = RADAR_AXES) -> float:
    """Area of the radar polygon over ``axes`` (unit pentagon ~ 2.38)."""
    r = np.array([radii[a] for a in axes], dtype=np.float64)
    k = len(r)
    ang = 2.0 * np.pi / k
    return float(0.5 * np.sin(ang) * np.sum(r * np.roll(r, -1)))


def radar_report(per_policy: Dict[str, Dict[str, float]],
                 axes: tuple = RADAR_AXES,
                 cost_axes: tuple = _COST_AXES) -> Dict[str, float]:
    normed = radar_normalize(per_policy, axes, cost_axes)
    return {n: radar_area(v, axes) for n, v in normed.items()}


def summarize_pool(names, metrics: DrainMetrics) -> Dict[str, Dict[str, float]]:
    """Stack vmapped DrainMetrics (leading policy axis) into dicts.

    ``names`` is a sequence of per-fork labels or a
    ``policies.PolicyPool`` (whose family+θ names are used), so sweep
    reports identify each grid point, not just "policy i"."""
    if hasattr(names, "names"):  # PolicyPool
        names = names.names
    out = {}
    for i, n in enumerate(names):
        out[n] = {
            "avg_wait": float(metrics.avg_wait[i]),
            "max_wait": float(metrics.max_wait[i]),
            "avg_slowdown": float(metrics.avg_slowdown[i]),
            "max_slowdown": float(metrics.max_slowdown[i]),
            "utilization": float(metrics.utilization[i]),
            "makespan": float(metrics.makespan[i]),
        }
    return out
