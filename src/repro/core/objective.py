"""First-class optimization goals (§3.4's "administrator configured
optimization goal") — DESIGN.md §8.

The paper promises that SchedTwin "dynamically selects the [policy]
satisfying the administrator configured optimization goal", but until
this layer existed the repo hardcoded ONE goal: the 4-term
``ScoreWeights`` argmin in ``scoring.policy_cost``.  Related work shows
the goal space is wide and user-facing — RLScheduler optimizes avg
wait / bounded slowdown / utilization and selects materially different
policies per goal; DRAS treats the reward as the primary configuration
knob — so the goal is lifted into a first-class **``Objective``**:

* every ``Objective`` **compiles to a pure device-side function**
  ``costs(metrics: DrainMetrics) -> (..., k) costs`` (smaller = better)
  over the candidate axis (the LAST leading axis of the metrics: the k
  fork axis of a decision, the P policy axis of an (S, P) replay
  grid), so selection stays inside the jitted decide/replay
  computations — an argmin with first-occurrence tie-break, exactly as
  before;
* objectives are **hashable** (frozen dataclasses of floats, strings
  and tuples), so they ride jit as *static* arguments: each goal
  compiles once and is cached, like the engine itself;
* a **sweep-style grammar** (``parse_objective``) mirrors
  ``policies.parse_pool`` so configs and CLIs spell goals as strings:

      "score"                          the paper's 4-term score (default;
                                       bit-identical to the legacy
                                       ScoreWeights path)
      "avg_wait"                       one metric (utilization is a
                                       reward: its cost is negated)
      "0.5*avg_wait+0.5*max_slowdown"  weighted combination (raw metric
                                       units — no minute rescale)
      "lex:avg_wait,makespan"          lexicographic: minimize avg_wait,
                                       break exact ties by makespan
      "min:avg_wait@util>=0.85"        constrained: minimize avg_wait
                                       over forks with utilization
                                       >= 0.85; if NO fork is feasible,
                                       fall back to least total
                                       constraint violation
      "p95:avg_wait"                   distributional (fan goals,
      "cvar:0.9:avg_wait"              DESIGN.md §10): reduce an inner
      "worst:score"                    goal's per-member costs over the
      "regret:avg_wait"                Monte-Carlo fan axis — nearest-
      "mean:avg_wait"                  rank quantile, CVaR (mean of the
                                       worst (1-α)·F members), max,
                                       minimax regret, or mean — BEFORE
                                       the per-scenario argmin

Distributional goals wrap any base goal (the prefix must be outermost
and cannot nest) and only change selection when a fan axis exists
(``engine.fan_grid`` / ``decide_fan``); under a plain decide/replay
they degenerate to the inner goal.  The fan size F is static to the
jit, so the sorted-reduction indices (``des.quantile_index`` /
``des.cvar_tail_count``) are trace-time constants — selection stays
inside the compiled computation.

Rank-based goals (``lex:``/``min:...@``) compose **dense ranks** along
the candidate axis — ``r[i] = #{j : v[j] < v[i]}``, an O(k²)
broadcast-compare, exact for float ties — into a single cost
``Σ r_l · (k+1)^(L-1-l)``, so the compiled function still returns
plain ``(..., k)`` costs and the selection argmin is untouched.  Ranks
are monotone under candidate removal, so the engine's post-hoc
deadlock masking (``where(dead, inf, costs)``) cannot reorder live
forks.  The integer composition is exact in f32 up to
``(k+1)^L < 2^24`` (k=128 pools with 3 levels are fine).

This is also the ROADMAP θ-training reward hook: an ``Objective`` IS
the reward for ``engine.replay_grid`` rollouts — register a custom
goal (``register_objective``) and score ``ReplayOutcome.metrics`` with
it.
"""
from __future__ import annotations

import dataclasses
import re
import warnings
from typing import (Callable, Dict, Mapping, Optional, Sequence, Tuple,
                    Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scoring
from repro.core.des import DrainMetrics, cvar_tail_count, quantile_index
from repro.core.scoring import PAPER_WEIGHTS, ScoreWeights

__all__ = [
    "Objective", "PaperScore", "Weighted", "Lexicographic", "Constraint",
    "Constrained", "Distributional", "ObjectiveLike", "DEFAULT_OBJECTIVE",
    "METRICS", "REWARD_METRICS", "parse_objective", "validate_objective",
    "normalize_objective", "resolve_goal", "as_distributional",
    "register_objective", "registered_objectives", "metric_cost",
    "metrics_from_rows", "report_costs",
]

#: Metric fields an objective may reference — the ``DrainMetrics``
#: fields produced by ``des.drain_metrics`` / ``des.state_metrics``.
METRICS: Tuple[str, ...] = DrainMetrics._fields

#: Metrics that are *rewards* (higher = better): their cost is negated
#: so every objective stays a minimization.
REWARD_METRICS = frozenset({"utilization"})

_ALIASES = {"util": "utilization"}

_WT_SCALE = scoring._WT_SCALE  # seconds -> minutes, the paper score's scale


def _metric(name: str) -> str:
    name = name.strip().lower()
    name = _ALIASES.get(name, name)
    if name not in METRICS:
        raise ValueError(
            f"unknown metric {name!r}; objectives index {METRICS} "
            f"(aliases: {sorted(_ALIASES)})")
    return name


def metric_cost(metrics: DrainMetrics, name: str) -> jax.Array:
    """One metric as a cost (rewards negated), broadcasting over any
    leading candidate axes."""
    v = getattr(metrics, name)
    return -v if name in REWARD_METRICS else v


def _fmt(v: float) -> str:
    """Full-precision float formatting for canonical specs: ``repr``
    is the shortest string that round-trips through ``float`` exactly,
    so ``parse_objective(obj.spec) == obj`` holds for ANY coefficient
    (``%g`` truncated to 6 significant digits and broke round-trip)."""
    return repr(float(v))


# ----------------------------------------------------------------------
# Dense-rank composition (lex / constrained goals).
# ----------------------------------------------------------------------

def _dense_rank(v: jax.Array) -> jax.Array:
    """(..., k) -> (..., k) dense ranks along the candidate axis:
    ``r[i] = #{j : v[j] < v[i]}``.  Equal values share a rank, so exact
    float ties stay ties (the argmin's first-occurrence tie-break —
    pool position — then decides, as everywhere else)."""
    lt = v[..., None, :] < v[..., :, None]            # [..., i, j]
    return jnp.sum(lt, axis=-1).astype(jnp.float32)


def _rank_compose(levels: Sequence[jax.Array]) -> jax.Array:
    """Lexicographic composition of cost levels into ONE (..., k) cost:
    ``Σ rank_l · (k+1)^(L-1-l)``.  Exact in f32 while
    ``(k+1)^L < 2^24``."""
    k = levels[0].shape[-1]
    cost = jnp.zeros_like(levels[0], dtype=jnp.float32)
    for v in levels:
        cost = cost * (k + 1) + _dense_rank(v)
    return cost


# ----------------------------------------------------------------------
# The Objective hierarchy.
# ----------------------------------------------------------------------

class Objective:
    """A first-class optimization goal.

    Subclasses are frozen dataclasses (hashable -> static jit args)
    implementing:

    * ``costs(metrics)``      — pure device-side ``(..., k)`` costs
      over the candidate axis (last axis of the metric fields);
      smaller is better, ties break by pool position downstream;
    * ``cost_terms(metrics)`` — the per-term breakdown as a dict of
      ``(..., k)`` arrays (telemetry: every fork's cost decomposition,
      not just the winner's);
    * ``spec``                — the canonical grammar string;
      ``parse_objective(obj.spec) == obj`` round-trips.
    """

    #: Whether ``costs`` is a per-candidate scalar in metric units
    #: (True: score/weighted goals) or a composed RANK over the
    #: candidate field (False: lex/constrained) — rank costs only
    #: order candidates and are meaningless for a single candidate.
    elementwise: bool = True

    def costs(self, metrics: DrainMetrics) -> jax.Array:
        raise NotImplementedError

    def cost_terms(self, metrics: DrainMetrics) -> Dict[str, jax.Array]:
        raise NotImplementedError

    @property
    def spec(self) -> str:
        raise NotImplementedError

    def __str__(self) -> str:
        return self.spec


@dataclasses.dataclass(frozen=True)
class PaperScore(Objective):
    """The paper's §3.4 score — the bit-exact default goal.

    ``costs`` IS ``scoring.policy_cost`` (same arithmetic, same wait
    minute-scale), so ``objective="score"`` decisions are bit-identical
    to the pre-objective ``ScoreWeights`` path, and a legacy
    ``weights=ScoreWeights(...)`` kwarg lifts here losslessly.
    """
    weights: ScoreWeights = PAPER_WEIGHTS

    def costs(self, metrics: DrainMetrics) -> jax.Array:
        return scoring.policy_cost(metrics, self.weights)

    def cost_terms(self, metrics: DrainMetrics) -> Dict[str, jax.Array]:
        w = self.weights
        return {
            "max_wait": w.max_wait * metrics.max_wait * _WT_SCALE,
            "max_slowdown": w.max_slowdown * metrics.max_slowdown,
            "avg_wait": w.avg_wait * metrics.avg_wait * _WT_SCALE,
            "avg_slowdown": w.avg_slowdown * metrics.avg_slowdown,
        }

    @property
    def spec(self) -> str:
        if self.weights == PAPER_WEIGHTS:
            return "score"
        return "score:" + ":".join(
            f"{f}={_fmt(v)}" for f, v in zip(ScoreWeights._fields,
                                             self.weights))


@dataclasses.dataclass(frozen=True)
class Weighted(Objective):
    """``Σ coeff · metric_cost`` in raw metric units (waits in seconds
    — unlike the paper score's minute scale; pick coefficients
    accordingly).  A single ``(1, metric)`` term is the single-metric
    goal the grammar spells as the bare metric name."""
    terms: Tuple[Tuple[float, str], ...]

    def __post_init__(self) -> None:
        if not self.terms:
            raise ValueError("Weighted objective needs at least one term")
        for _, m in self.terms:
            if m not in METRICS:
                raise ValueError(f"unknown metric {m!r}; have {METRICS}")

    def costs(self, metrics: DrainMetrics) -> jax.Array:
        total = None
        for c, m in self.terms:
            t = c * metric_cost(metrics, m)
            total = t if total is None else total + t
        return total

    def cost_terms(self, metrics: DrainMetrics) -> Dict[str, jax.Array]:
        out: Dict[str, jax.Array] = {}
        for i, (c, m) in enumerate(self.terms):
            key = m if c == 1.0 else f"{c:g}*{m}"
            if key in out:                      # duplicate metric terms
                key = f"{key}#{i}"
            out[key] = c * metric_cost(metrics, m)
        return out

    @property
    def spec(self) -> str:
        return "+".join(m if c == 1.0 else f"{_fmt(c)}*{m}"
                        for c, m in self.terms)


@dataclasses.dataclass(frozen=True)
class Lexicographic(Objective):
    """Minimize ``levels[0]``; break exact cost ties by ``levels[1]``;
    and so on.  Compiled via dense-rank composition (module docstring),
    so the result is still one ``(..., k)`` cost vector — the reported
    costs are composed *ranks* (a total order), while ``cost_terms``
    carries each level's raw values."""
    levels: Tuple[Objective, ...]
    elementwise = False

    def __post_init__(self) -> None:
        if len(self.levels) < 2:
            raise ValueError("lex: needs at least two levels")

    def costs(self, metrics: DrainMetrics) -> jax.Array:
        return _rank_compose([lv.costs(metrics) for lv in self.levels])

    def cost_terms(self, metrics: DrainMetrics) -> Dict[str, jax.Array]:
        return {f"lex{i}:{lv.spec}": lv.costs(metrics)
                for i, lv in enumerate(self.levels)}

    @property
    def spec(self) -> str:
        return "lex:" + ",".join(lv.spec for lv in self.levels)


_CONSTRAINT_OPS = (">=", "<=")


@dataclasses.dataclass(frozen=True)
class Constraint:
    """``metric >= bound`` or ``metric <= bound`` on a raw metric value
    (NOT the negated cost: ``util>=0.85`` means utilization >= 0.85)."""
    metric: str
    op: str
    bound: float

    def __post_init__(self) -> None:
        if self.metric not in METRICS:
            raise ValueError(f"unknown metric {self.metric!r}")
        if self.op not in _CONSTRAINT_OPS:
            raise ValueError(
                f"constraint op must be one of {_CONSTRAINT_OPS}, "
                f"got {self.op!r}")

    def violation(self, metrics: DrainMetrics) -> jax.Array:
        """How far outside the feasible region (>= 0; 0 = satisfied)."""
        v = getattr(metrics, self.metric)
        gap = self.bound - v if self.op == ">=" else v - self.bound
        return jnp.maximum(gap, 0.0)

    @property
    def spec(self) -> str:
        return f"{self.metric}{self.op}{_fmt(self.bound)}"


@dataclasses.dataclass(frozen=True)
class Constrained(Objective):
    """Minimize ``primary`` subject to ``constraints``, with a
    feasibility fallback: feasible candidates always beat infeasible
    ones; among feasible ones the primary decides; if NO candidate is
    feasible, the least total violation wins (primary breaks exact
    violation ties) — the twin degrades gracefully instead of picking
    arbitrarily when the goal is unsatisfiable."""
    primary: Objective
    constraints: Tuple[Constraint, ...]
    elementwise = False

    def __post_init__(self) -> None:
        if not self.constraints:
            raise ValueError("constrained objective needs >= 1 constraint")

    def _violation(self, metrics: DrainMetrics) -> jax.Array:
        total = None
        for c in self.constraints:
            v = c.violation(metrics)
            total = v if total is None else total + v
        return total

    def costs(self, metrics: DrainMetrics) -> jax.Array:
        return _rank_compose([self._violation(metrics),
                              self.primary.costs(metrics)])

    def cost_terms(self, metrics: DrainMetrics) -> Dict[str, jax.Array]:
        out = {f"violation:{c.spec}": c.violation(metrics)
               for c in self.constraints}
        out.update(self.primary.cost_terms(metrics))
        return out

    @property
    def spec(self) -> str:
        return ("min:" + self.primary.spec
                + "".join("@" + c.spec for c in self.constraints))


_REDUCTIONS = ("mean", "worst", "regret", "quantile", "cvar")


def _fmt_level(v: float) -> str:
    """Exact round-trip float formatting with the trailing ``.0``
    dropped, so canonical specs read ``p95:`` rather than ``p95.0:``."""
    s = _fmt(v)
    return s[:-2] if s.endswith(".0") else s


@dataclasses.dataclass(frozen=True)
class Distributional(Objective):
    """A risk reduction of an inner goal over the Monte-Carlo fan axis
    (DESIGN.md §10).

    ``member_costs`` evaluates the inner goal per fan member (the
    candidate axis stays last, so rank-based inner goals compose ranks
    *within* each member), and ``reduce_fan`` collapses the fan axis
    (second-to-last) with the chosen reduction:

    * ``quantile`` — nearest-rank order statistic (``p95:`` = sorted
      member ``ceil(0.95·F) - 1``); exact, no interpolation, so device
      f32 results match a numpy oracle bitwise;
    * ``cvar``     — mean of the worst ``max(1, ceil((1-α)·F))`` sorted
      members (``α`` in ``level``): expected cost in the tail;
    * ``worst``    — max over members (robust / adversarial);
    * ``regret``   — minimax regret: per member subtract the best
      candidate's cost (common-random-number futures make the per-member
      min meaningful), then max over members;
    * ``mean``     — the risk-neutral default a plain goal lifts to
      under a fan (``as_distributional``).

    Deadlocked members carry ``+inf`` member costs, so a policy whose
    tail deadlocks is poisoned exactly as far into the distribution as
    the reduction looks (p50 forgives a rare deadlock, ``worst:`` never
    does).  Without a fan axis (plain decide/replay), ``costs`` / ``
    cost_terms`` degenerate to the inner goal.
    """
    reduction: str
    inner: Objective
    level: float = 0.0

    def __post_init__(self) -> None:
        if self.reduction not in _REDUCTIONS:
            raise ValueError(f"unknown fan reduction {self.reduction!r}; "
                             f"have {_REDUCTIONS}")
        if isinstance(self.inner, Distributional):
            raise ValueError("distributional reductions cannot nest: "
                             "there is only one fan axis")
        if self.reduction == "quantile" and not 0.0 < self.level <= 100.0:
            raise ValueError(
                f"quantile level must be in (0, 100], got {self.level!r}")
        if self.reduction == "cvar" and not 0.0 <= self.level < 1.0:
            raise ValueError(
                f"cvar alpha must be in [0, 1), got {self.level!r}")
        if self.reduction in ("mean", "worst", "regret") and self.level:
            raise ValueError(
                f"{self.reduction}: takes no level, got {self.level!r}")

    @property
    def elementwise(self) -> bool:  # type: ignore[override]
        return self.inner.elementwise

    # -- fan-axis interface (engine.fan_select) ------------------------

    def member_costs(self, metrics: DrainMetrics) -> jax.Array:
        """Inner costs per fan member — metrics shaped ``(..., F, k)``,
        candidates last, fan second-to-last."""
        return self.inner.costs(metrics)

    def reduce_fan(self, member_costs: jax.Array) -> jax.Array:
        """``(..., F, k)`` member costs -> ``(..., k)`` reduced costs.
        F is a trace-time constant, so the sorted-reduction indices are
        static — the whole reduction compiles into the selection jit."""
        F = member_costs.shape[-2]
        if self.reduction == "mean":
            return jnp.mean(member_costs, axis=-2)
        if self.reduction == "worst":
            return jnp.max(member_costs, axis=-2)
        if self.reduction == "regret":
            best = jnp.min(member_costs, axis=-1, keepdims=True)
            reg = jnp.where(jnp.isfinite(member_costs),
                            member_costs - best, jnp.inf)
            return jnp.max(reg, axis=-2)
        srt = jnp.sort(member_costs, axis=-2)
        if self.reduction == "quantile":
            return srt[..., quantile_index(self.level / 100.0, F), :]
        m = cvar_tail_count(self.level, F)
        return jnp.mean(srt[..., F - m:, :], axis=-2)

    # -- degenerate (no fan axis) interface ----------------------------

    def costs(self, metrics: DrainMetrics) -> jax.Array:
        return self.inner.costs(metrics)

    def cost_terms(self, metrics: DrainMetrics) -> Dict[str, jax.Array]:
        return self.inner.cost_terms(metrics)

    @property
    def spec(self) -> str:
        if self.reduction == "quantile":
            return f"p{_fmt_level(self.level)}:{self.inner.spec}"
        if self.reduction == "cvar":
            return f"cvar:{_fmt_level(self.level)}:{self.inner.spec}"
        return f"{self.reduction}:{self.inner.spec}"


def as_distributional(objective: "ObjectiveLike") -> Distributional:
    """Lift any goal to a fan goal: distributional goals pass through,
    anything else wraps in the risk-neutral ``mean:`` reduction (so a
    plain ``"score"`` under an F=1 fan selects bit-identically to the
    fan-less path: the mean over a singleton axis is the identity)."""
    obj = normalize_objective(objective)
    if isinstance(obj, Distributional):
        return obj
    return Distributional("mean", obj)


#: The administrator default: the paper's own goal.
DEFAULT_OBJECTIVE = PaperScore()


# ----------------------------------------------------------------------
# Registry: named goals, extensible (a learned-θ reward registers here).
# ----------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], Objective]] = {}


def register_objective(name: str, factory: Callable[[], Objective],
                       overwrite: bool = False) -> None:
    """Register a named goal for ``parse_objective``/configs/CLIs.
    ``factory`` is called per lookup (objectives are immutable, so a
    ``lambda: OBJ`` constant is fine)."""
    name = name.strip().lower()
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"objective {name!r} already registered")
    _REGISTRY[name] = factory


def registered_objectives() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_objective("score", lambda: PaperScore())
for _m in METRICS:
    register_objective(_m, lambda _m=_m: Weighted(((1.0, _m),)))
register_objective("util", lambda: Weighted(((1.0, "utilization"),)))


# ----------------------------------------------------------------------
# Grammar.
# ----------------------------------------------------------------------

def _parse_term(text: str) -> Tuple[float, str]:
    """``metric`` or ``coeff*metric`` (coeff may be negative)."""
    if "*" in text:
        c_s, m_s = text.split("*", 1)
        try:
            c = float(c_s)
        except ValueError:
            raise ValueError(f"bad coefficient {c_s!r} in term {text!r}")
        return c, _metric(m_s)
    return 1.0, _metric(text)


def _parse_expr(text: str) -> Objective:
    """``score[:field=val...]`` | weighted sum of metric terms."""
    text = text.strip()
    if not text:
        raise ValueError("empty objective expression")
    head = text.split(":", 1)[0].strip().lower()
    if head == "score":
        if ":" not in text:
            return PaperScore()
        kw: Dict[str, float] = {}
        for assign in text.split(":")[1:]:
            if "=" not in assign:
                raise ValueError(f"bad score weight {assign!r}; expected "
                                 f"field=value")
            key, val = assign.split("=", 1)
            key = key.strip().lower()
            if key not in ScoreWeights._fields:
                raise ValueError(
                    f"score weights index {ScoreWeights._fields}, "
                    f"got {key!r}")
            kw[key] = float(val)
        return PaperScore(PAPER_WEIGHTS._replace(**kw))
    lname = text.strip().lower()
    if lname in _REGISTRY:
        return _REGISTRY[lname]()
    terms = tuple(_parse_term(t.strip()) for t in text.split("+"))
    return Weighted(terms)


def _parse_constraint(text: str) -> Constraint:
    for op in _CONSTRAINT_OPS:
        if op in text:
            m_s, b_s = text.split(op, 1)
            return Constraint(_metric(m_s), op, float(b_s))
    raise ValueError(
        f"bad constraint {text!r}; expected metric>=bound or "
        f"metric<=bound")


_QUANTILE_RE = re.compile(r"^p(\d+(?:\.\d+)?):(.+)$", re.IGNORECASE)


def _match_distributional(
        text: str) -> Optional[Tuple[str, float, str]]:
    """``(reduction, level, inner_body)`` if ``text`` starts with a
    distributional prefix, else None.  Malformed prefixes (``cvar:``
    without an alpha) raise."""
    low = text.lower()
    for red in ("mean", "worst", "regret"):
        if low.startswith(red + ":"):
            return red, 0.0, text[len(red) + 1:]
    if low.startswith("cvar:"):
        rest = text[5:]
        if ":" not in rest:
            raise ValueError(
                f"bad cvar goal {text!r}; expected cvar:ALPHA:goal "
                f"(e.g. cvar:0.9:avg_wait)")
        a_s, body = rest.split(":", 1)
        try:
            alpha = float(a_s)
        except ValueError:
            raise ValueError(f"bad cvar alpha {a_s!r} in {text!r}")
        return "cvar", alpha, body
    m = _QUANTILE_RE.match(text)
    if m:
        return "quantile", float(m.group(1)), m.group(2)
    return None


def parse_objective(grammar: str) -> Objective:
    """Parse a goal grammar string (module docstring) into an
    ``Objective``.  ``obj.spec`` (== ``str(obj)``) round-trips:
    ``parse_objective(obj.spec) == obj``."""
    text = grammar.strip()
    if not text:
        raise ValueError("empty objective grammar")
    dist = _match_distributional(text)
    if dist is not None:
        red, level, body = dist
        body = body.strip()
        if not body:
            raise ValueError(f"empty inner goal in {text!r}")
        if _match_distributional(body) is not None:
            raise ValueError(
                f"distributional reductions cannot nest ({text!r}): "
                f"there is only one fan axis")
        return Distributional(red, parse_objective(body), level)
    low = text.lower()
    if low.startswith("lex:"):
        body = text[4:]
        if "@" in body:
            raise ValueError(
                "lex: goals do not take @constraints; constrain the "
                "whole goal as min:...@... with a single primary")
        # a single level raises in Lexicographic.__post_init__: a
        # one-level "lex:" is almost certainly a forgotten tie-break
        return Lexicographic(tuple(_parse_expr(p) for p in body.split(",")))
    if low.startswith("min:"):
        text = text[4:]
    if "@" in text:
        expr, *cons = text.split("@")
        return Constrained(_parse_expr(expr),
                           tuple(_parse_constraint(c) for c in cons))
    return _parse_expr(text)


def validate_objective(grammar: str) -> Objective:
    """Parse a goal grammar AND assert its canonical spec round-trips
    (``parse_objective(goal.spec) == goal``) — the one validation
    every CLI/entry point shares.  A round-trip failure is a grammar
    bug, not user error; raise so it cannot pass silently."""
    goal = parse_objective(grammar)
    if parse_objective(goal.spec) != goal:
        raise ValueError(
            f"objective grammar does not round-trip: {grammar!r} -> "
            f"{goal.spec!r} — report this as a grammar bug")
    return goal


#: Anything the public entry points accept as a goal.  ``ScoreWeights``
#: is the deprecated legacy spelling (lifted with a warning).
ObjectiveLike = Union[Objective, str, ScoreWeights, None]


def normalize_objective(objective: ObjectiveLike) -> Objective:
    """Coerce any goal spelling to an ``Objective``:

    * ``None``        — the default (the paper score);
    * ``Objective``   — returned as is;
    * ``str``         — grammar (``parse_objective``);
    * ``ScoreWeights``— deprecated: lifted to ``PaperScore(weights)``
      (bit-identical to the legacy path) with a ``DeprecationWarning``.
    """
    if objective is None:
        return DEFAULT_OBJECTIVE
    if isinstance(objective, Objective):
        return objective
    if isinstance(objective, ScoreWeights):
        warnings.warn(
            "passing ScoreWeights as the goal is deprecated; use "
            "objective=\"score\" (or PaperScore(weights) for custom "
            "weights) — decisions are bit-identical",
            DeprecationWarning, stacklevel=3)
        return PaperScore(objective)
    if isinstance(objective, str):
        return parse_objective(objective)
    raise TypeError(
        f"cannot interpret {type(objective).__name__} as an objective; "
        f"pass an Objective, a grammar string, or None")


def resolve_goal(objective: ObjectiveLike = None,
                 weights: Optional[ScoreWeights] = None) -> Objective:
    """The one shim behind every public entry point's
    ``(objective=, weights=)`` pair: a legacy ``weights=`` kwarg lifts
    to ``PaperScore(weights)`` with a ``DeprecationWarning``; passing
    both is an error."""
    if weights is not None:
        if objective is not None:
            raise ValueError(
                "pass either objective= or the deprecated weights=, "
                "not both")
        warnings.warn(
            "weights= is deprecated; pass objective=\"score\" (default) "
            "or objective=PaperScore(weights) — decisions are "
            "bit-identical",
            DeprecationWarning, stacklevel=3)
        return PaperScore(weights)
    return normalize_objective(objective)


# ----------------------------------------------------------------------
# Host-side report scoring (benchmarks: adaptive vs static).
# ----------------------------------------------------------------------

def metrics_from_rows(rows: Sequence[Mapping[str, float]]) -> DrainMetrics:
    """Stack metric dicts (e.g. ``RunReport.metric_dict()``) into a
    ``DrainMetrics`` with one (n,) candidate axis, so host-side reports
    score through the SAME compiled cost semantics as device
    decisions."""
    if not rows:
        raise ValueError("no metric rows")
    arr = lambda f: jnp.asarray([float(r[f]) for r in rows],
                                dtype=jnp.float32)
    return DrainMetrics(**{f: arr(f) for f in METRICS})


def report_costs(objective: ObjectiveLike,
                 rows: Sequence[Mapping[str, float]]) -> np.ndarray:
    """(n,) costs of n metric-dict candidates under ``objective`` —
    relative order is what matters (rank-based goals return composed
    ranks)."""
    obj = normalize_objective(objective)
    return np.asarray(obj.costs(metrics_from_rows(rows)))
