"""Policy-batched drain engine with pluggable scheduling-pass backends.

This is the hot spot of the whole system (DESIGN.md §1): every decision
cycle forks the synchronized snapshot into k what-if simulations — one
per candidate policy (times ``n_ens`` ensemble members) — and drains
each to completion.  Instead of ``jax.vmap`` over a scalar DES, the
``DrainEngine`` carries all forks as an explicit leading batch axis on
``SimState`` and advances them in lock-step with ONE ``lax.while_loop``
(``repro.core.des.simulate_to_drain_batched``).  Per event:

  1. priority keys are computed and argsorted once for the WHOLE batch
     (one (k, J) argsort, not k separate sorts inside each fork) — the
     pool is a parametric ``policies.PolicySpec`` PyTree (family (k,),
     θ (k, P)), so DRAS-style parameter sweeps and learned scorers are
     just more rows on the fork axis; legacy i32 id pools still work
     through the same entry points (the bit-exact oracle path);
  2. the inherently sequential greedy + EASY-backfill pass runs through
     a registered *backend* on the batch axis;
  3. starts are applied and every fork advances to its own next
     predicted completion, with per-fork done/dead masks.

Backends (registered in ``PASS_BACKENDS``):

  * ``reference`` — today's pure-JAX ``schedule_pass`` logic
    (``backfill.schedule_pass_with_order``) vmapped over the fork axis.
    The semantic oracle: bit-identical to the scalar DES.
  * ``pallas``    — ``kernels.policy_eval.policy_eval_pass_batched``,
    the TPU kernel with the fork axis on the grid and the queue in
    VMEM.  Interpret-mode on CPU (this container), compiled on TPU
    (``interpret=None`` auto-detects).

Every consumer routes through here: ``whatif.decide`` /
``decide_ensemble`` (ensemble members ride the same batch axis —
k * n_ens forks in one drain), ``whatif.sharded_whatif`` (shards the
fork axis), ``SchedTwin`` (engine injected at construction) and the
cluster emulator's static mode (a k=1 engine, so baselines stay
bit-identical to the twin's simulator).

The engine also hosts the **scenario-vectorized replay** (DESIGN.md
§6): ``replay`` / ``replay_grid`` drive ``des.simulate_replay_batched``
over a ``workload.ScenarioSet``, stacking an S-scenario axis on top of
the P-policy fork axis (flat fork f = s·P + p) — a whole baseline grid
in one device computation, bit-identical to the host emulator's event
loop, sharded by scenario via ``whatif.sharded_replay_grid``.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
from typing import Callable, Dict, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import scoring
from repro.core.backfill import priority_order, schedule_pass_with_order
from repro.core.des import (DrainMetrics, DrainResult, ReplayResult,
                            broadcast_state, drain_metrics,
                            simulate_replay_batched,
                            simulate_to_drain_batched, state_metrics)
from repro.core.policies import PolicySpec
from repro.core.state import (QUEUED, RUNNING, TIME_NONE, JobTable,
                              SimState)
from repro.kernels import policy_eval as _pe

logger = logging.getLogger(__name__)

#: What the engine accepts as a pool: a parametric ``PolicySpec`` with
#: a leading fork axis (the post-tentpole representation) or a legacy
#: i32 id vector (kept as the bit-exact pre-parametric oracle path).
EnginePool = Union[PolicySpec, jax.Array]


def pool_size(pool: EnginePool) -> int:
    """Number of forks k in a pool of either representation."""
    if isinstance(pool, PolicySpec):
        return pool.family.shape[0]
    return pool.shape[0]


def tile_pool(pool: EnginePool, n: int) -> EnginePool:
    """Repeat a pool n times along the fork axis (ensemble stacking /
    one pool copy per replay scenario)."""
    if isinstance(pool, PolicySpec):
        return PolicySpec(jnp.tile(pool.family, n),
                          jnp.tile(pool.theta, (n, 1)))
    return jnp.tile(pool, n)


def as_pool(policy) -> EnginePool:
    """Lift a single policy — a ``PolicySpec`` fork or a legacy integer
    id — into a k=1 pool (pools pass through unchanged)."""
    if isinstance(policy, PolicySpec):
        if policy.family.ndim == 0:
            return PolicySpec(policy.family.reshape(1),
                              policy.theta.reshape(1, -1))
        return policy
    arr = jnp.asarray(policy, jnp.int32)
    return arr.reshape(1) if arr.ndim == 0 else arr


class Decision(NamedTuple):
    """One scheduling cycle's outcome (re-exported by ``whatif``)."""
    policy_index: jax.Array   # index into the pool (NOT the policy id)
    costs: jax.Array          # (k,) per-policy cost
    run_mask: jax.Array       # bool (max_jobs,) jobs to start now (qrun set)
    metrics: DrainMetrics     # (k,)-leading metrics for telemetry
    deadlocked: jax.Array     # (k,) bool


class ReplayOutcome(NamedTuple):
    """A replayed (scenario × policy) grid (DESIGN.md §6).

    Leading axes are (S, P) from ``replay_grid`` — flat fork f = s·P + p
    — and (P,) from ``replay`` (S squeezed).  ``start_t``/``end_t`` are
    ACTUAL times (completions retire at ground-truth ends); ``metrics``
    score true outcomes (runtime = ground truth) over each scenario's
    real slots, per-scenario ``total_nodes`` included.
    """
    start_t: jax.Array        # f32 (..., J)
    end_t: jax.Array          # f32 (..., J)
    metrics: DrainMetrics     # (...)-leading
    deadlocked: jax.Array     # bool (...)
    events: jax.Array         # i32 (...) — events processed per fork
    result: ReplayResult      # the raw flat (k = S·P) replay result


# ----------------------------------------------------------------------
# Pass backends: (batched SimState, order (k, J)) -> started (k, J) bool
# ----------------------------------------------------------------------

PassFn = Callable[[SimState, jax.Array], jax.Array]
PASS_BACKENDS: Dict[str, Callable[["DrainEngine"], PassFn]] = {}


def register_backend(name: str):
    """Register a pass-backend factory under ``name`` (the value of the
    ``backend`` knob on ``configs.schedtwin.TwinConfig``)."""
    def deco(factory: Callable[["DrainEngine"], PassFn]):
        PASS_BACKENDS[name] = factory
        return factory
    return deco


@register_backend("reference")
def _reference_backend(engine: "DrainEngine") -> PassFn:
    """The pure-JAX oracle pass, vmapped over the fork axis."""
    def pass_fn(states: SimState, order: jax.Array) -> jax.Array:
        res = jax.vmap(schedule_pass_with_order)(states, order)
        return res.started
    return pass_fn


@register_backend("pallas")
def _pallas_backend(engine: "DrainEngine") -> PassFn:
    interpret = engine.resolved_interpret()

    def pass_fn(states: SimState, order: jax.Array) -> jax.Array:
        jobs = states.jobs
        running = jobs.state == RUNNING
        started, _ = _pe.policy_eval_pass_batched(
            order,
            jobs.state == QUEUED,
            jobs.nodes,
            jobs.est_runtime,
            jnp.where(running, jobs.end_t, jnp.inf),
            jnp.where(running, jobs.nodes, 0),
            states.free_nodes,
            states.now,
            interpret=interpret)
        return started > 0
    return pass_fn


def batched_priority_order(states: SimState, pool: EnginePool) -> jax.Array:
    """(k, J) priority order for the whole fork batch: one batched key
    evaluation + ONE argsort per event (stable; ties -> slot order).
    Single-sourced from ``backfill.priority_order`` so the engine can
    never drift from the scalar oracle's tie-break semantics.

    ``pool`` is a ``PolicySpec`` PyTree (family (k,), theta (k, P)) or
    a legacy (k,) id vector; either way the fork axis is the leading
    axis vmap maps over.  θ stays in this stage — outside the pass
    kernel — so backends are untouched by pool parameterization."""
    return jax.vmap(priority_order)(states, pool)


# ----------------------------------------------------------------------
# The engine.
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DrainEngine:
    """Pluggable, policy-batched what-if engine.

    Frozen + hashable so an engine instance is a static jit argument:
    each (backend, interpret) pair compiles once and is cached.

    Parameters
    ----------
    backend : name in ``PASS_BACKENDS`` ("reference" | "pallas"), or
        "auto" — resolved at construction to "pallas" on TPU and
        "reference" on CPU/GPU (interpret-mode pallas is ~2.3x slower
        than reference at k=32 on CPU, see BENCH_overhead.json; the
        kernel only pays off compiled).  The resolved choice is logged.
    interpret : Pallas interpret-mode override.  ``None`` auto-detects:
        interpret on CPU (this container), compiled on TPU.
    """

    backend: str = "reference"
    interpret: Optional[bool] = None

    def __post_init__(self) -> None:
        if self.backend == "auto":
            platform = jax.default_backend()
            resolved = "pallas" if platform == "tpu" else "reference"
            logger.info("DrainEngine backend='auto' resolved to %r "
                        "(jax platform: %s)", resolved, platform)
            object.__setattr__(self, "backend", resolved)
        if self.backend not in PASS_BACKENDS:
            raise ValueError(
                f"unknown pass backend {self.backend!r}; "
                f"registered: {sorted(PASS_BACKENDS)}")

    def resolved_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    def pass_fn(self) -> PassFn:
        return PASS_BACKENDS[self.backend](self)

    # -- drains --------------------------------------------------------
    def drain_batched(self, states: SimState, pool: EnginePool) -> DrainResult:
        """Drain pre-batched fork states (leading axis == pool)."""
        return _drain(self, states, pool)

    def drain(self, state: SimState, pool: EnginePool) -> DrainResult:
        """Fork one snapshot across the pool and drain all forks."""
        return _drain(self, broadcast_state(state, pool_size(pool)), pool)

    # -- decision cycles ----------------------------------------------
    def decide(self, state: SimState, pool: EnginePool,
               weights: scoring.ScoreWeights = scoring.PAPER_WEIGHTS
               ) -> Decision:
        return _decide(self, state, pool, weights)

    def decide_ensemble(self, state: SimState, pool: EnginePool,
                        key: jax.Array, n_ens: int = 8, noise: float = 0.3,
                        weights: scoring.ScoreWeights = scoring.PAPER_WEIGHTS,
                        ) -> Decision:
        return _decide_ensemble(self, state, pool, key, n_ens, noise, weights)

    # -- single pass (k=1) — the emulator's static baseline mode -------
    def schedule_pass_starts(self, state: SimState, policy) -> jax.Array:
        """Started mask (J,) for ONE policy (``PolicySpec`` fork or
        legacy integer id) on an unbatched state."""
        return _single_pass(self, state, as_pool(policy))

    # -- trace replay (DESIGN.md §6) -----------------------------------
    def replay(self, scenario, pool) -> ReplayOutcome:
        """Replay ONE scenario (an S=1 ``workload.ScenarioSet``) under
        every fork of ``pool`` — (P,)-leading outcome.  Bit-identical
        to P host-emulator static-mode runs (tests/test_replay.py)."""
        S = int(scenario.total_nodes.shape[0])
        if S != 1:
            raise ValueError(
                f"replay takes one scenario (got {S}); use replay_grid")
        pool = as_pool(pool)
        res, metrics = _replay(self, *replay_inputs(scenario, pool))
        return _shape_outcome(res, metrics, (pool_size(pool),))

    def replay_grid(self, scenarios, pool) -> ReplayOutcome:
        """Evaluate the full (scenario × policy) grid — S·P forks, ONE
        device computation.  Fork f = s·P + p; outcome axes (S, P)."""
        pool = as_pool(pool)
        S = int(scenarios.total_nodes.shape[0])
        res, metrics = _replay(self, *replay_inputs(scenarios, pool))
        return _shape_outcome(res, metrics, (S, pool_size(pool)))


# ----------------------------------------------------------------------
# Jitted implementations (engine static -> cached per configuration).
# ----------------------------------------------------------------------

def _drain_impl(engine: DrainEngine, states: SimState,
                pool: EnginePool) -> DrainResult:
    return simulate_to_drain_batched(
        states,
        lambda st: batched_priority_order(st, pool),
        engine.pass_fn())


@functools.partial(jax.jit, static_argnames=("engine",))
def _drain(engine: DrainEngine, states: SimState,
           pool: EnginePool) -> DrainResult:
    return _drain_impl(engine, states, pool)


def _decide_impl(engine: DrainEngine, state: SimState, pool: EnginePool,
                 weights: scoring.ScoreWeights) -> Decision:
    k = pool_size(pool)
    eval_mask = state.jobs.state == QUEUED
    res = _drain_impl(engine, broadcast_state(state, k), pool)
    metrics = jax.vmap(drain_metrics, in_axes=(0, None))(res, eval_mask)
    costs = scoring.policy_cost(metrics, weights)
    costs = jnp.where(res.deadlocked, jnp.inf, costs)
    best = scoring.select_policy(costs)
    return Decision(
        policy_index=best,
        costs=costs,
        run_mask=res.first_started[best],
        metrics=metrics,
        deadlocked=res.deadlocked,
    )


@functools.partial(jax.jit, static_argnames=("engine", "weights"))
def _decide(engine: DrainEngine, state: SimState, pool: EnginePool,
            weights: scoring.ScoreWeights) -> Decision:
    return _decide_impl(engine, state, pool, weights)


@functools.partial(jax.jit,
                   static_argnames=("engine", "n_ens", "noise", "weights"))
def _decide_ensemble(engine: DrainEngine, state: SimState, pool: EnginePool,
                     key: jax.Array, n_ens: int, noise: float,
                     weights: scoring.ScoreWeights) -> Decision:
    """k * n_ens forks ride ONE batch axis through ONE drain.

    Fork f = e * k + p simulates policy ``pool[p]`` under ensemble
    member e's lognormal walltime-estimate perturbation (member 0 is
    exact, so actions stay consistent with the mirror).  The policy
    cost is the ensemble mean; the qrun set comes from member 0 of the
    winning policy.
    """
    k = pool_size(pool)
    cap = state.jobs.capacity

    eps = jax.random.normal(key, (n_ens, cap))
    eps = eps.at[0].set(0.0)
    scale = jnp.exp(noise * eps - 0.5 * noise * noise)       # (n_ens, J)
    est_b = jnp.repeat(scale, k, axis=0) * state.jobs.est_runtime[None, :]

    states = broadcast_state(state, n_ens * k)
    states = states._replace(jobs=states.jobs._replace(est_runtime=est_b))
    pool_b = tile_pool(pool, n_ens)

    eval_mask = state.jobs.state == QUEUED
    res = _drain_impl(engine, states, pool_b)
    metrics = jax.vmap(drain_metrics, in_axes=(0, None))(res, eval_mask)
    mean_metrics = jax.tree.map(
        lambda x: jnp.mean(x.reshape(n_ens, k), axis=0), metrics)
    dead = jnp.any(res.deadlocked.reshape(n_ens, k), axis=0)
    costs = scoring.policy_cost(mean_metrics, weights)
    costs = jnp.where(dead, jnp.inf, costs)
    best = scoring.select_policy(costs)
    return Decision(
        policy_index=best,
        costs=costs,
        run_mask=res.first_started.reshape(n_ens, k, cap)[0, best],
        metrics=mean_metrics,
        deadlocked=dead,
    )


# ----------------------------------------------------------------------
# Scenario-vectorized replay (DESIGN.md §6).
# ----------------------------------------------------------------------

def replay_inputs(scenarios, pool: EnginePool):
    """Device inputs for the flat (k = S·P) replay batch from a
    ``workload.ScenarioSet``-shaped object: scenario rows repeat P times
    (fork f = s·P + p), the pool tiles once per scenario, and the job
    table is preloaded but fully INVALID — arrivals inject slots as the
    replay reaches them.  Shared by ``DrainEngine.replay_grid`` and
    ``whatif.sharded_replay_grid`` (which shards the leading axis)."""
    P = pool_size(pool)
    rep = lambda x, dt: jnp.repeat(jnp.asarray(x, dtype=dt), P, axis=0)
    submit = rep(scenarios.submit_t, jnp.float32)           # (S*P, J)
    valid = rep(scenarios.valid, bool)
    k, J = submit.shape
    none = jnp.full((k, J), TIME_NONE, dtype=jnp.float32)
    jobs = JobTable(
        submit_t=submit,
        nodes=rep(scenarios.nodes, jnp.int32),
        est_runtime=rep(scenarios.est_runtime, jnp.float32),
        start_t=none,
        end_t=none,
        state=jnp.zeros((k, J), dtype=jnp.int32),           # INVALID
    )
    total = rep(scenarios.total_nodes, jnp.int32)           # (S*P,)
    states = SimState(jobs=jobs, free_nodes=total, total_nodes=total,
                      now=jnp.zeros((k,), dtype=jnp.float32))
    arrival_t = jnp.where(valid, submit, jnp.inf)
    true_rt = rep(scenarios.true_runtime, jnp.float32)
    S = int(scenarios.total_nodes.shape[0])
    return states, arrival_t, true_rt, tile_pool(pool, S), valid


def _replay_impl(engine: DrainEngine, states: SimState,
                 arrival_t: jax.Array, true_rt: jax.Array,
                 pool: EnginePool, valid: jax.Array):
    res = simulate_replay_batched(
        states, arrival_t, true_rt,
        lambda st: batched_priority_order(st, pool),
        engine.pass_fn())
    metrics = jax.vmap(state_metrics)(res.state, valid, true_rt)
    return res, metrics


@functools.partial(jax.jit, static_argnames=("engine",))
def _replay(engine: DrainEngine, states: SimState, arrival_t: jax.Array,
            true_rt: jax.Array, pool: EnginePool, valid: jax.Array):
    return _replay_impl(engine, states, arrival_t, true_rt, pool, valid)


def _shape_outcome(res: ReplayResult, metrics: DrainMetrics,
                   shape) -> ReplayOutcome:
    rs = lambda x: x.reshape(shape + x.shape[1:])
    return ReplayOutcome(
        start_t=rs(res.state.jobs.start_t),
        end_t=rs(res.state.jobs.end_t),
        metrics=jax.tree.map(rs, metrics),
        deadlocked=rs(res.deadlocked),
        events=rs(res.events),
        result=res,
    )


@functools.partial(jax.jit, static_argnames=("engine",))
def _single_pass(engine: DrainEngine, state: SimState,
                 pool: EnginePool) -> jax.Array:
    states = broadcast_state(state, 1)
    order = batched_priority_order(states, pool)
    return engine.pass_fn()(states, order)[0]


DEFAULT_ENGINE = DrainEngine(backend="reference")
