"""Policy-batched drain engine with pluggable scheduling-pass backends.

This is the hot spot of the whole system (DESIGN.md §1): every decision
cycle forks the synchronized snapshot into k what-if simulations — one
per candidate policy (times ``n_ens`` ensemble members) — and drains
each to completion.  Instead of ``jax.vmap`` over a scalar DES, the
``DrainEngine`` carries all forks as an explicit leading batch axis on
``SimState`` and advances them in lock-step with ONE ``lax.while_loop``
(``repro.core.des.simulate_to_drain_batched``).  Per event:

  1. priority keys are computed and argsorted once for the WHOLE batch
     (one (k, J) argsort, not k separate sorts inside each fork) — the
     pool is a parametric ``policies.PolicySpec`` PyTree (family (k,),
     θ (k, P)), so DRAS-style parameter sweeps and learned scorers are
     just more rows on the fork axis; legacy i32 id pools still work
     through the same entry points (the bit-exact oracle path);
  2. the inherently sequential greedy + EASY-backfill pass runs through
     a registered *backend* on the batch axis;
  3. starts are applied and every fork advances to its own next
     predicted completion, with per-fork done/dead masks.

Backends (registered in ``PASS_BACKENDS``):

  * ``reference`` — today's pure-JAX ``schedule_pass`` logic
    (``backfill.schedule_pass_with_order``) vmapped over the fork axis.
    The semantic oracle: bit-identical to the scalar DES.
  * ``pallas``    — ``kernels.policy_eval.policy_eval_pass_batched``,
    the TPU kernel with the fork axis on the grid and the queue in
    VMEM.  Interpret-mode on CPU (this container), compiled on TPU
    (``interpret=None`` auto-detects).

Every consumer routes through here: ``whatif.decide`` /
``decide_ensemble`` (ensemble members ride the same batch axis —
k * n_ens forks in one drain), ``whatif.sharded_whatif`` (shards the
fork axis), ``SchedTwin`` (engine injected at construction) and the
cluster emulator's static mode (a k=1 engine, so baselines stay
bit-identical to the twin's simulator).

The engine also hosts the **scenario-vectorized replay** (DESIGN.md
§6): ``replay`` / ``replay_grid`` drive ``des.simulate_replay_batched``
over a ``workload.ScenarioSet``, stacking an S-scenario axis on top of
the P-policy fork axis (flat fork f = s·P + p) — a whole baseline grid
in one device computation, bit-identical to the host emulator's event
loop, sharded by scenario via ``whatif.sharded_replay_grid``.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import warnings
from typing import Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scoring
from repro.core.backfill import (priority_order,
                                 schedule_pass_with_order,
                                 static_priority_order)
from repro.core.fan import (FanSpec, normalize_fan, perturb_block,
                            perturb_window)
from repro.core.objective import (DEFAULT_OBJECTIVE, Objective,
                                  ObjectiveLike, as_distributional,
                                  resolve_goal)
from repro.core.des import (DrainMetrics, DrainResult, ReplayResult,
                            broadcast_state, drain_metrics,
                            simulate_replay_batched,
                            simulate_to_drain_batched, state_metrics)
from repro.core.policies import PolicySpec, time_invariant_mask
from repro.core.state import (QUEUED, RUNNING, TIME_NONE, JobTable,
                              SimState)
from repro.kernels import policy_eval as _pe

logger = logging.getLogger(__name__)


def _quiet_donation(jitted):
    """Buffer donation on ``_drain``/``_replay`` lets XLA update the
    (k, J) while-loop carries in place; backends without donation
    support (CPU) warn per compile.  Suppress exactly that warning,
    exactly around this engine's donated calls — never globally."""
    @functools.wraps(jitted)
    def call(*args, **kwargs):
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return jitted(*args, **kwargs)
    return call

#: What the engine accepts as a pool: a parametric ``PolicySpec`` with
#: a leading fork axis (the post-tentpole representation) or a legacy
#: i32 id vector (kept as the bit-exact pre-parametric oracle path).
EnginePool = Union[PolicySpec, jax.Array]


def pool_size(pool: EnginePool) -> int:
    """Number of forks k in a pool of either representation."""
    if isinstance(pool, PolicySpec):
        return pool.family.shape[0]
    return pool.shape[0]


def tile_pool(pool: EnginePool, n: int) -> EnginePool:
    """Repeat a pool n times along the fork axis (ensemble stacking /
    one pool copy per replay scenario)."""
    if isinstance(pool, PolicySpec):
        return PolicySpec(jnp.tile(pool.family, n),
                          jnp.tile(pool.theta, (n, 1)))
    return jnp.tile(pool, n)


def as_pool(policy) -> EnginePool:
    """Lift a single policy — a ``PolicySpec`` fork or a legacy integer
    id — into a k=1 pool (pools pass through unchanged)."""
    if isinstance(policy, PolicySpec):
        if policy.family.ndim == 0:
            return PolicySpec(policy.family.reshape(1),
                              policy.theta.reshape(1, -1))
        return policy
    arr = jnp.asarray(policy, jnp.int32)
    return arr.reshape(1) if arr.ndim == 0 else arr


class Decision(NamedTuple):
    """One scheduling cycle's outcome (re-exported by ``whatif``).

    ``costs`` is the goal's compiled cost per fork (argmin = winner);
    ``cost_terms`` the goal's per-term breakdown for ALL k forks
    (``Objective.cost_terms`` — telemetry records every fork's
    decomposition, not just the winning index).

    Fan/ensemble decisions (``decide_fan`` / ``decide_ensemble``) also
    stamp per-policy uncertainty, computed on DEVICE from the member
    costs (no host recompute): ``cost_ci`` is the 95% normal CI
    half-width of the member-cost mean (``1.96·σ/√F``; +inf when any
    member deadlocked), ``fan_width`` the full member-cost spread
    (worst − best member; the "how sure is the twin" headline), and
    ``fan_size`` the member count F.  Single-future decisions leave
    them None/1."""
    policy_index: jax.Array   # index into the pool (NOT the policy id)
    costs: jax.Array          # (k,) per-policy objective cost
    run_mask: jax.Array       # bool (max_jobs,) jobs to start now (qrun set)
    metrics: DrainMetrics     # (k,)-leading metrics for telemetry
    deadlocked: jax.Array     # (k,) bool
    cost_terms: Optional[Dict[str, jax.Array]] = None  # term -> (k,)
    cost_ci: Optional[jax.Array] = None    # (k,) 95% CI half-width
    fan_width: Optional[jax.Array] = None  # (k,) member-cost spread
    fan_size: int = 1                      # members behind the costs


class ReplayOutcome(NamedTuple):
    """A replayed (scenario × policy) grid (DESIGN.md §6).

    Leading axes are (S, P) from ``replay_grid`` — flat fork f = s·P + p
    — and (P,) from ``replay`` (S squeezed).  ``start_t``/``end_t`` are
    ACTUAL times (completions retire at ground-truth ends); ``metrics``
    score true outcomes (runtime = ground truth) over each scenario's
    real slots, per-scenario ``total_nodes`` included.

    ``costs``/``best`` are the per-objective selection (DESIGN.md §8):
    the goal's compiled cost over the policy axis ((S, P) / (P,),
    deadlocked forks at +inf) and its per-scenario argmin ((S,) /
    scalar) — the policy the twin would pick for each replayed future.
    """
    start_t: jax.Array        # f32 (..., J)
    end_t: jax.Array          # f32 (..., J)
    metrics: DrainMetrics     # (...)-leading
    deadlocked: jax.Array     # bool (...)
    events: jax.Array        # i32 (...) — events processed per fork
    result: ReplayResult      # the raw flat (k = S·P) replay result
    costs: Optional[jax.Array] = None   # objective costs (..., P)-shaped
    best: Optional[jax.Array] = None    # per-scenario winning pool index


class FanOutcome(NamedTuple):
    """A (scenario × fan member × policy) Monte-Carlo grid
    (DESIGN.md §10) from ``DrainEngine.fan_grid``.

    Leading axes are (S, F, P) — flat fork ``f = (s·F + φ)·P + p`` —
    with member φ=0 the unperturbed base future.  ``member_costs`` is
    the inner goal's cost per member (deadlocked members at +inf);
    ``costs`` the distributional reduction over the fan axis (what the
    argmin ``best`` selects per scenario); ``cost_ci``/``fan_width``
    the per-(s, p) uncertainty stamps (``member_uncertainty``)."""
    start_t: jax.Array        # f32 (S, F, P, J) — actual start times
    end_t: jax.Array          # f32 (S, F, P, J)
    metrics: DrainMetrics     # (S, F, P)-leading
    deadlocked: jax.Array     # bool (S, F, P)
    events: jax.Array         # i32 (S, F, P)
    result: Optional[ReplayResult]  # raw flat (k = S·F·P) replay result;
                              # None when the outcome was ASSEMBLED from
                              # donated pieces (pruned/raced grids)
    member_costs: jax.Array   # (S, F, P) inner costs per member
    costs: jax.Array          # (S, P) reduced distributional costs
    best: jax.Array           # (S,) per-scenario winning pool index
    cost_ci: jax.Array        # (S, P) 95% CI half-width of member mean
    fan_width: jax.Array      # (S, P) worst − best member cost


# ----------------------------------------------------------------------
# Pass backends: (batched SimState, order (k, J), rank limit (i32
# scalar | None)) -> started (k, J) bool
# ----------------------------------------------------------------------

PassFn = Callable[[SimState, jax.Array, object], jax.Array]
PASS_BACKENDS: Dict[str, Callable[["DrainEngine"], PassFn]] = {}


def register_backend(name: str):
    """Register a pass-backend factory under ``name`` (the value of the
    ``backend`` knob on ``configs.schedtwin.TwinConfig``)."""
    def deco(factory: Callable[["DrainEngine"], PassFn]):
        PASS_BACKENDS[name] = factory
        return factory
    return deco


@register_backend("reference")
def _reference_backend(engine: "DrainEngine") -> PassFn:
    """The pure-JAX oracle pass, vmapped over the fork axis (the rank
    limit is a lock-step scalar shared by every fork, so it maps with
    ``in_axes=None``)."""
    def pass_fn(states: SimState, order: jax.Array, limit) -> jax.Array:
        res = jax.vmap(schedule_pass_with_order,
                       in_axes=(0, 0, None))(states, order, limit)
        return res.started
    return pass_fn


@register_backend("pallas")
def _pallas_backend(engine: "DrainEngine") -> PassFn:
    interpret = engine.resolved_interpret()

    def pass_fn(states: SimState, order: jax.Array, limit) -> jax.Array:
        jobs = states.jobs
        running = jobs.state == RUNNING
        started, _ = _pe.policy_eval_pass_batched(
            order,
            jobs.state == QUEUED,
            jobs.nodes,
            jobs.est_runtime,
            jnp.where(running, jobs.end_t, jnp.inf),
            jnp.where(running, jobs.nodes, 0),
            states.free_nodes,
            states.now,
            limit,
            interpret=interpret)
        return started > 0
    return pass_fn


def batched_priority_order(states: SimState, pool: EnginePool) -> jax.Array:
    """(k, J) priority order for the whole fork batch: one batched key
    evaluation + ONE argsort per event (stable; ties -> slot order).
    Single-sourced from ``backfill.priority_order`` so the engine can
    never drift from the scalar oracle's tie-break semantics.

    ``pool`` is a ``PolicySpec`` PyTree (family (k,), theta (k, P)) or
    a legacy (k,) id vector; either way the fork axis is the leading
    axis vmap maps over.  θ stays in this stage — outside the pass
    kernel — so backends are untouched by pool parameterization."""
    return jax.vmap(priority_order)(states, pool)


# ----------------------------------------------------------------------
# Static-key hoisting (DESIGN.md §7): forks whose keys never depend on
# the clock get their argsort computed ONCE, outside the event loop.
# ----------------------------------------------------------------------

#: A hoist plan: per-fork "keys are time-invariant" bools, decided on
#: the HOST (``policies.time_invariant_mask`` over the concrete pool)
#: and passed as a *static* jit argument — the fork-axis split must be
#: known at trace time for the gather/sort/scatter below to have static
#: shapes.  ``None`` disables hoisting (every fork re-sorts per event).
HoistPlan = Optional[Tuple[bool, ...]]


def hoist_plan(pool: EnginePool, enabled: bool = True) -> HoistPlan:
    """Derive the static hoist plan from a CONCRETE pool.  Returns None
    when hoisting is disabled, no fork qualifies, or the pool is a
    tracer (e.g. inside a caller's jit / under sharding constraints) —
    the engine then falls back to per-event sorting for all forks."""
    if not enabled:
        return None
    leaves = jax.tree.leaves(pool)
    if any(isinstance(leaf, jax.core.Tracer) for leaf in leaves):
        return None
    mask = time_invariant_mask(pool)
    if not mask.any():
        return None
    return tuple(bool(b) for b in mask)


def shard_local_plan(plan: HoistPlan, n_shards: int) -> HoistPlan:
    """Repartition a full-pool hoist plan for a ``shard_map`` body that
    sees only its shard's block of the fork axis (DESIGN.md §9).

    ``shard_map`` traces ONE program executed by every device, so a
    static per-shard plan is only expressible when every shard's chunk
    of the full plan is IDENTICAL — then the common chunk simply *is*
    the local plan, and each device hoists its own forks' argsorts with
    zero cross-shard traffic (this is what re-enables the PR-4
    compaction win under sharding; the replay grid's plan is periodic
    in P, so its chunks always agree).  Heterogeneous chunks (or a fork
    count that doesn't block-split) fall back to ``None`` — per-event
    sorting for all forks, bit-identical either way."""
    if plan is None or n_shards <= 1:
        return plan
    k = len(plan)
    if k % n_shards:
        return None
    chunk = k // n_shards
    first = plan[:chunk]
    for i in range(1, n_shards):
        if plan[i * chunk:(i + 1) * chunk] != first:
            return None
    return first if any(first) else None


def _index_pool(pool: EnginePool, idx: jax.Array) -> EnginePool:
    if isinstance(pool, PolicySpec):
        return PolicySpec(pool.family[idx], pool.theta[idx])
    return pool[idx]


def _compact_queued_first(order: jax.Array, queued: jax.Array) -> jax.Array:
    """Stable-partition each fork's rank order so QUEUED slots occupy
    the leading ranks — one cumsum + row scatter, O(k·J), no sort.

    The relative order of queued ranks is preserved, so the pass visits
    the exact same queued sequence (non-queued ranks are no-ops either
    way) — bit-exact — while restoring ``des.pass_rank_limit``'s
    queued-first contract for hoisted static orders, whose queued slots
    would otherwise sit scattered at arbitrary rank depths and pin the
    dynamic bound near J."""
    q = jnp.take_along_axis(queued, order, axis=1)          # (k, J)
    nq = jnp.cumsum(q, axis=1)
    pos = jnp.where(q, nq - 1, nq[:, -1:] + jnp.cumsum(~q, axis=1) - 1)
    k = order.shape[0]
    return jnp.zeros_like(order).at[jnp.arange(k)[:, None], pos].set(order)


def hoisted_orders(states0: SimState, pool: EnginePool, plan: HoistPlan,
                   ever_queued: jax.Array) -> jax.Array:
    """The (n_ti, J) static priority orders of ``plan``'s
    time-invariant forks — the argsorts ``make_order_fn`` hoists out of
    the event loop.  Split out so fleet callers can compute it OUTSIDE
    a ``shard_map`` body and feed it back in as a sharded argument:
    jax 0.4 miscompiles an argsort that is loop-invariant to a
    ``while_loop`` consuming it via gathers inside ``shard_map``
    (non-leading shards read corrupted orders); a sort performed in the
    surrounding GSPMD region and passed through the shard boundary as
    an input is partitioned correctly (tests/test_fleet.py pins the
    parity)."""
    plan_arr = np.asarray(plan, dtype=bool)
    ti_idx = jnp.asarray(np.nonzero(plan_arr)[0], dtype=jnp.int32)
    states_ti = jax.tree.map(lambda x: x[ti_idx], states0)
    return jax.vmap(static_priority_order)(
        states_ti, _index_pool(pool, ti_idx), ever_queued[ti_idx])


def make_order_fn(states0: SimState, pool: EnginePool, plan: HoistPlan,
                  ever_queued: jax.Array,
                  hoisted: Optional[jax.Array] = None,
                  ) -> Callable[[SimState], jax.Array]:
    """The per-event order stage, with static-key forks hoisted.

    ``ever_queued`` (k, J) marks every slot that can EVER be queued
    during this drain/replay (drain: currently queued; replay: slots
    with a finite arrival).  Time-invariant forks (per ``plan``) rank
    those slots once via ``backfill.static_priority_order`` — exact
    because their keys never change and the pass skips non-QUEUED
    ranks — so each event's (k, J) sort shrinks to the time-varying
    rows only (or disappears entirely for an all-static pool).  The
    hoisted rows are re-compacted queued-first per event (a cumsum, not
    a sort) to keep the dynamic pass bound tight.

    ``hoisted`` optionally supplies the precomputed static orders
    (``hoisted_orders``) — the shard-local fleet paths pass their
    shard's rows in to keep the argsort outside the ``shard_map`` body
    (see ``hoisted_orders`` for why).
    """
    if plan is None:
        return lambda st: batched_priority_order(st, pool)
    plan_arr = np.asarray(plan, dtype=bool)
    ti_idx = jnp.asarray(np.nonzero(plan_arr)[0], dtype=jnp.int32)
    if hoisted is None:
        hoisted = hoisted_orders(states0, pool, plan, ever_queued)

    if plan_arr.all():
        # zero per-event sorting: just repartition the fixed ranking
        def order_fn_all(st: SimState) -> jax.Array:
            return _compact_queued_first(hoisted, st.jobs.state == QUEUED)
        return order_fn_all

    tv_idx = jnp.asarray(np.nonzero(~plan_arr)[0], dtype=jnp.int32)
    pool_tv = _index_pool(pool, tv_idx)
    # merge hoisted + fresh rows with ONE static gather (a concat and
    # an inverse permutation) instead of two row scatters
    perm = np.concatenate([np.nonzero(plan_arr)[0], np.nonzero(~plan_arr)[0]])
    inv = jnp.asarray(np.argsort(perm), dtype=jnp.int32)

    def order_fn(st: SimState) -> jax.Array:
        compacted = _compact_queued_first(
            hoisted, (st.jobs.state == QUEUED)[ti_idx])
        st_tv = jax.tree.map(lambda x: x[tv_idx], st)
        fresh = batched_priority_order(st_tv, pool_tv)
        return jnp.concatenate([compacted, fresh], axis=0)[inv]
    return order_fn


# ----------------------------------------------------------------------
# The engine.
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DrainEngine:
    """Pluggable, policy-batched what-if engine.

    Frozen + hashable so an engine instance is a static jit argument:
    each (backend, interpret) pair compiles once and is cached.

    Parameters
    ----------
    backend : name in ``PASS_BACKENDS`` ("reference" | "pallas"), or
        "auto" — resolved at construction to "pallas" on TPU and
        "reference" on CPU/GPU (interpret-mode pallas is ~2.3x slower
        than reference at k=32 on CPU, see BENCH_overhead.json; the
        kernel only pays off compiled).  The resolved choice is logged.
    interpret : Pallas interpret-mode override.  ``None`` auto-detects:
        interpret on CPU (this container), compiled on TPU.
    dynamic_bounds : truncate the pass's sequential rank loops at the
        deepest live queued rank each event (``des.pass_rank_limit``) —
        bit-exact; collapses the O(J)-rank loops to the queue depth.
    hoist_static : hoist the argsort of time-invariant forks
        (``policies.time_invariant_mask``) out of the event loop.
    elide_empty : skip keys + argsort + pass entirely on replay
        iterations where no live fork has a queued job.

    The three compaction knobs (DESIGN.md §7) exist for ablation
    benchmarks and bit-identity tests against the uncompacted engine;
    production code leaves them on.
    """

    backend: str = "reference"
    interpret: Optional[bool] = None
    dynamic_bounds: bool = True
    hoist_static: bool = True
    elide_empty: bool = True

    def __post_init__(self) -> None:
        if self.backend == "auto":
            platform = jax.default_backend()
            resolved = "pallas" if platform == "tpu" else "reference"
            logger.info("DrainEngine backend='auto' resolved to %r "
                        "(jax platform: %s)", resolved, platform)
            object.__setattr__(self, "backend", resolved)
        if self.backend not in PASS_BACKENDS:
            raise ValueError(
                f"unknown pass backend {self.backend!r}; "
                f"registered: {sorted(PASS_BACKENDS)}")

    def resolved_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    def pass_fn(self) -> PassFn:
        return PASS_BACKENDS[self.backend](self)

    def plan(self, pool: EnginePool) -> HoistPlan:
        """The static hoist plan this engine uses for ``pool`` (None
        when ``hoist_static`` is off or no fork qualifies)."""
        return hoist_plan(pool, enabled=self.hoist_static)

    # -- drains --------------------------------------------------------
    def drain_batched(self, states: SimState, pool: EnginePool) -> DrainResult:
        """Drain pre-batched fork states (leading axis == pool).

        ``states`` buffers are DONATED to the computation (in-place
        carry updates on backends that support it) — don't reuse them
        after the call."""
        return _drain(self, states, pool, self.plan(pool))

    def drain(self, state: SimState, pool: EnginePool) -> DrainResult:
        """Fork one snapshot across the pool and drain all forks."""
        return _drain(self, broadcast_state(state, pool_size(pool)),
                      pool, self.plan(pool))

    # -- decision cycles ----------------------------------------------
    def decide(self, state: SimState, pool: EnginePool,
               objective: ObjectiveLike = None, *,
               weights: Optional[scoring.ScoreWeights] = None) -> Decision:
        """One decision cycle under ``objective`` (an ``Objective``, a
        grammar string, or None for the paper score).  ``weights=`` is
        the deprecated legacy spelling (lifted, bit-identical)."""
        goal = resolve_goal(objective, weights)
        return _decide(self, state, pool, goal, self.plan(pool))

    def decide_ensemble(self, state: SimState, pool: EnginePool,
                        key: jax.Array, n_ens: int = 8, noise: float = 0.3,
                        objective: ObjectiveLike = None, *,
                        weights: Optional[scoring.ScoreWeights] = None,
                        ) -> Decision:
        goal = resolve_goal(objective, weights)
        d = _decide_ensemble(self, state, pool, key, n_ens, noise,
                             goal, self.plan(pool))
        return d._replace(fan_size=n_ens)

    def decide_fan(self, state: SimState, pool: EnginePool, fan,
                   objective: ObjectiveLike = None, *,
                   weights: Optional[scoring.ScoreWeights] = None
                   ) -> Decision:
        """One decision cycle over a Monte-Carlo fan of F perturbed
        futures per policy (DESIGN.md §10): fork ``f = φ·k + p`` drains
        policy p under member φ's estimate-noise and node-failure draws
        (member 0 exact; arrival-burst warps are a replay concern — a
        drain has no future arrivals).  ``objective`` may be
        distributional (``"p95:avg_wait"``, ``"cvar:0.9:score"``, ...);
        plain goals reduce by the member mean.  The returned
        ``Decision`` carries ``cost_ci``/``fan_width``/``fan_size``.
        ``fan`` is a ``FanSpec`` or a bare int F."""
        goal = resolve_goal(objective, weights)
        spec = normalize_fan(fan)
        d = _decide_fan(self, state, pool, spec, goal, self.plan(pool))
        return d._replace(fan_size=spec.n)

    # -- single pass (k=1) — the emulator's static baseline mode -------
    def schedule_pass_starts(self, state: SimState, policy) -> jax.Array:
        """Started mask (J,) for ONE policy (``PolicySpec`` fork or
        legacy integer id) on an unbatched state."""
        return _single_pass(self, state, as_pool(policy))

    # -- trace replay (DESIGN.md §6) -----------------------------------
    def replay(self, scenario, pool, objective: ObjectiveLike = None, *,
               weights: Optional[scoring.ScoreWeights] = None
               ) -> ReplayOutcome:
        """Replay ONE scenario (an S=1 ``workload.ScenarioSet``) under
        every fork of ``pool`` — (P,)-leading outcome.  Bit-identical
        to P host-emulator static-mode runs (tests/test_replay.py).
        ``objective`` drives the outcome's ``costs``/``best``
        selection (the trace times themselves are goal-independent)."""
        S = int(scenario.total_nodes.shape[0])
        if S != 1:
            raise ValueError(
                f"replay takes one scenario (got {S}); use replay_grid")
        goal = resolve_goal(objective, weights)
        pool = as_pool(pool)
        P = pool_size(pool)
        inputs = replay_inputs(scenario, pool)
        res, metrics, costs, best = _replay(self, *inputs, self.plan(pool),
                                            goal, P)
        return _shape_outcome(res, metrics, (P,), costs, best)

    def replay_grid(self, scenarios, pool, objective: ObjectiveLike = None,
                    *, weights: Optional[scoring.ScoreWeights] = None
                    ) -> ReplayOutcome:
        """Evaluate the full (scenario × policy) grid — S·P forks, ONE
        device computation.  Fork f = s·P + p; outcome axes (S, P).
        ``objective`` selects per scenario: ``best[s]`` is the pool
        index the goal picks for scenario s (costs over the P axis)."""
        goal = resolve_goal(objective, weights)
        pool = as_pool(pool)
        S = int(scenarios.total_nodes.shape[0])
        P = pool_size(pool)
        inputs = replay_inputs(scenarios, pool)
        plan = self.plan(pool)                 # fork f = s·P + p
        res, metrics, costs, best = _replay(
            self, *inputs, plan * S if plan is not None else None, goal, P)
        return _shape_outcome(res, metrics, (S, P), costs, best)

    def fan_grid(self, scenarios, pool, fan,
                 objective: ObjectiveLike = None, *,
                 weights: Optional[scoring.ScoreWeights] = None
                 ) -> FanOutcome:
        """The Monte-Carlo fan grid (DESIGN.md §10): every (scenario,
        policy) cell of ``replay_grid`` evaluated under F perturbed
        futures — S·F·P forks, ONE device computation, with the base
        scenarios uploaded once and the perturbations expanded on
        device (fork ``f = (s·F + φ)·P + p``).  ``fan`` is a
        ``FanSpec`` (or a bare int F for a degenerate fan);
        ``objective`` selects per scenario after the distributional
        reduction over the fan axis.  ``FanSpec(n=1)`` (and any
        degenerate spec) is bitwise ``replay_grid``."""
        goal = resolve_goal(objective, weights)
        spec = normalize_fan(fan)
        pool = as_pool(pool)
        S = int(scenarios.total_nodes.shape[0])
        P = pool_size(pool)
        plan = self.plan(pool)                 # fork f = (s·F + φ)·P + p
        res, metrics, member, costs, best, ci, width = _fan_replay(
            self, *_scenario_arrays(scenarios), pool,
            plan * (S * spec.n) if plan is not None else None,
            goal, P, S, spec)
        shape = (S, spec.n, P)
        rs = lambda x: x.reshape(shape + x.shape[1:])
        return FanOutcome(
            start_t=rs(res.state.jobs.start_t),
            end_t=rs(res.state.jobs.end_t),
            metrics=jax.tree.map(rs, metrics),
            deadlocked=rs(res.deadlocked),
            events=rs(res.events),
            result=res,
            member_costs=member,
            costs=costs,
            best=best,
            cost_ci=ci,
            fan_width=width,
        )

    def fan_window_grid(self, scenarios, pool, fan,
                        objective: ObjectiveLike = None, *,
                        lo: int = 0, width: Optional[int] = None,
                        weights: Optional[scoring.ScoreWeights] = None
                        ) -> FanOutcome:
        """Replay ONLY members ``φ ∈ [lo, lo+width)`` of the fan — the
        racing/donation suffix.  CRN prefix-stability (``fan.
        perturb_rows`` keys on (s, φ) alone) makes every returned
        member bitwise the corresponding member of the full
        ``fan_grid``, so windows replayed at different times
        concatenate into the full fan without ever re-replaying a
        (scenario, policy, member) triple.  The outcome's fan axis has
        ``width`` members and its reduction/selection treats the
        window as the whole fan — racing callers re-reduce over the
        accumulated members instead (``race.rung_stats``)."""
        goal = resolve_goal(objective, weights)
        spec = normalize_fan(fan)
        if width is None:
            width = spec.n - lo
        if not (0 <= lo and lo + width <= spec.n and width >= 1):
            raise ValueError(
                f"member window [{lo}, {lo + width}) outside fan of "
                f"size {spec.n}")
        pool = as_pool(pool)
        S = int(scenarios.total_nodes.shape[0])
        P = pool_size(pool)
        plan = self.plan(pool)              # fork f = (s·width + w)·P + p
        res, metrics, member, costs, best, ci, cwidth = _fan_window_replay(
            self, *_scenario_arrays(scenarios), pool,
            plan * (S * width) if plan is not None else None,
            goal, P, S, spec, lo, width)
        shape = (S, width, P)
        rs = lambda x: x.reshape(shape + x.shape[1:])
        return FanOutcome(
            start_t=rs(res.state.jobs.start_t),
            end_t=rs(res.state.jobs.end_t),
            metrics=jax.tree.map(rs, metrics),
            deadlocked=rs(res.deadlocked),
            events=rs(res.events),
            result=res,
            member_costs=member,
            costs=costs,
            best=best,
            cost_ci=ci,
            fan_width=cwidth,
        )

    # -- population training (DESIGN.md §13) ---------------------------
    def generation_costs(self, scenarios, pool,
                         objective: ObjectiveLike = None,
                         fan=None) -> jax.Array:
        """The trainer's generation-eval entry point: per-(scenario,
        candidate) costs, (S, P), for a candidate population riding
        the fork axis.  ONE jitted grid — ``replay_grid`` when ``fan``
        is None, else ``fan_grid`` with ``FanSpec``-driven domain
        randomization of the training traces (costs are then the
        goal's distributional reduction over the fan axis).
        Deadlocked rollouts cost +inf, so they rank strictly worst
        under any goal."""
        if fan is None:
            return self.replay_grid(scenarios, pool, objective).costs
        return self.fan_grid(scenarios, pool, fan, objective).costs

    # -- adaptive racing (DESIGN.md §11) -------------------------------
    def race_grid(self, scenarios, pool, race,
                  objective: ObjectiveLike = None):
        """Successive-halving fan evaluation: start every policy at a
        low rung F₀, eliminate CI-dominated policies between rungs,
        replay only the new member suffix for survivors
        (``core/race.py``).  ``race`` is a ``RaceSpec``, a ``FanSpec``
        (raced to ``spec.n`` with default rungs), or a bare int F.
        Returns a ``race.RaceOutcome``."""
        from repro.core.race import race_grid as _race_grid
        return _race_grid(scenarios, pool, race, objective, engine=self)

    def decide_race(self, state: SimState, pool: EnginePool, race,
                    objective: ObjectiveLike = None):
        """One raced decision cycle: the ``decide_fan`` fan grown rung
        by rung with CI elimination and anytime budgets.  Returns
        ``(Decision, race.RaceOutcome)`` — the decision's ``fan_size``
        is the members the winner actually ran, the outcome carries
        the rung accounting (see ``core.race.decide_race``)."""
        from repro.core.race import decide_race as _decide_race
        return _decide_race(state, pool, race, objective, engine=self)


# ----------------------------------------------------------------------
# Jitted implementations (engine static -> cached per configuration).
# ----------------------------------------------------------------------

def _drain_impl(engine: DrainEngine, states: SimState, pool: EnginePool,
                plan: HoistPlan = None,
                hoisted: Optional[jax.Array] = None) -> DrainResult:
    # Mid-drain, no new jobs appear: only slots queued at entry can
    # ever be queued — the tightest hoist domain.
    order_fn = make_order_fn(states, pool, plan,
                             ever_queued=states.jobs.state == QUEUED,
                             hoisted=hoisted)
    return simulate_to_drain_batched(
        states, order_fn, engine.pass_fn(),
        dynamic_bounds=engine.dynamic_bounds)


@_quiet_donation
@functools.partial(jax.jit, static_argnames=("engine", "plan"),
                   donate_argnames=("states",))
def _drain(engine: DrainEngine, states: SimState,
           pool: EnginePool, plan: HoistPlan = None) -> DrainResult:
    return _drain_impl(engine, states, pool, plan)


def _decide_impl(engine: DrainEngine, state: SimState, pool: EnginePool,
                 objective: Objective = DEFAULT_OBJECTIVE,
                 plan: HoistPlan = None) -> Decision:
    k = pool_size(pool)
    eval_mask = state.jobs.state == QUEUED
    res = _drain_impl(engine, broadcast_state(state, k), pool, plan)
    metrics = jax.vmap(drain_metrics, in_axes=(0, None))(res, eval_mask)
    costs = objective.costs(metrics)
    costs = jnp.where(res.deadlocked, jnp.inf, costs)
    best = scoring.select_policy(costs)
    return Decision(
        policy_index=best,
        costs=costs,
        run_mask=res.first_started[best],
        metrics=metrics,
        deadlocked=res.deadlocked,
        cost_terms=objective.cost_terms(metrics),
    )


@functools.partial(jax.jit, static_argnames=("engine", "objective", "plan"))
def _decide(engine: DrainEngine, state: SimState, pool: EnginePool,
            objective: Objective = DEFAULT_OBJECTIVE,
            plan: HoistPlan = None) -> Decision:
    return _decide_impl(engine, state, pool, objective, plan)


@functools.partial(jax.jit,
                   static_argnames=("engine", "n_ens", "noise", "objective",
                                    "plan"))
def _decide_ensemble(engine: DrainEngine, state: SimState, pool: EnginePool,
                     key: jax.Array, n_ens: int, noise: float,
                     objective: Objective = DEFAULT_OBJECTIVE,
                     plan: HoistPlan = None) -> Decision:
    """k * n_ens forks ride ONE batch axis through ONE drain.

    Fork f = e * k + p simulates policy ``pool[p]`` under ensemble
    member e's lognormal walltime-estimate perturbation (member 0 is
    exact, so actions stay consistent with the mirror).  The policy
    cost is the ensemble mean; the qrun set comes from member 0 of the
    winning policy.
    """
    k = pool_size(pool)
    cap = state.jobs.capacity

    eps = jax.random.normal(key, (n_ens, cap))
    eps = eps.at[0].set(0.0)
    scale = jnp.exp(noise * eps - 0.5 * noise * noise)       # (n_ens, J)
    est_b = jnp.repeat(scale, k, axis=0) * state.jobs.est_runtime[None, :]

    states = broadcast_state(state, n_ens * k)
    states = states._replace(jobs=states.jobs._replace(est_runtime=est_b))
    pool_b = tile_pool(pool, n_ens)
    plan_b = plan * n_ens if plan is not None else None

    eval_mask = state.jobs.state == QUEUED
    res = _drain_impl(engine, states, pool_b, plan_b)
    metrics = jax.vmap(drain_metrics, in_axes=(0, None))(res, eval_mask)
    mean_metrics = jax.tree.map(
        lambda x: jnp.mean(x.reshape(n_ens, k), axis=0), metrics)
    member_dead = res.deadlocked.reshape(n_ens, k)
    dead = jnp.any(member_dead, axis=0)
    costs = objective.costs(mean_metrics)
    costs = jnp.where(dead, jnp.inf, costs)
    best = scoring.select_policy(costs)
    # Per-member costs back the CI/width stamps only — selection stays
    # the cost of the MEAN metrics, bit-identical to the pre-fan path.
    member_costs = jnp.where(
        member_dead, jnp.inf,
        objective.costs(jax.tree.map(
            lambda x: x.reshape(n_ens, k), metrics)))
    ci, width = member_uncertainty(member_costs, axis=0)
    return Decision(
        policy_index=best,
        costs=costs,
        run_mask=res.first_started.reshape(n_ens, k, cap)[0, best],
        metrics=mean_metrics,
        deadlocked=dead,
        cost_terms=objective.cost_terms(mean_metrics),
        cost_ci=ci,
        fan_width=width,
    )


@functools.partial(jax.jit,
                   static_argnames=("engine", "spec", "objective", "plan"))
def _decide_fan(engine: DrainEngine, state: SimState, pool: EnginePool,
                spec: FanSpec = FanSpec(),
                objective: Objective = DEFAULT_OBJECTIVE,
                plan: HoistPlan = None) -> Decision:
    """k · F forks ride ONE batch axis through ONE drain (fork
    f = φ·k + p, the ``_decide_ensemble`` layout).  Member φ's draws
    come from the same ``fan._member_draws`` chains as the replay fan
    (s=0: a decision has one base snapshot), so fans are deterministic
    and prefix-stable here too.  Perturbations with a drain-side
    meaning: ``runtime_noise`` scales the walltime ESTIMATES (the
    drain's predicted ends — what the twin is unsure about) and
    ``failure_prob`` draws capacity reductions; arrival warps are
    no-ops (drains simulate no future arrivals).  Member 0 is exact.
    Selection is the goal's distributional reduction of the per-member
    costs; deadlocked members cost +inf (a policy whose tail deadlocks
    is exactly as bad as the reduction is risk-averse)."""
    from repro.core.fan import _member_draws, failure_downs
    k = pool_size(pool)
    cap = state.jobs.capacity
    F = spec.n
    dist = as_distributional(objective)

    states = broadcast_state(state, F * k)
    if not spec.degenerate:
        phi = jnp.arange(F)
        eps, _, u = jax.vmap(
            lambda p: _member_draws(spec.seed, jnp.int32(0), p, cap))(phi)
        exact = phi == 0
        if spec.runtime_noise > 0.0:
            sig = spec.runtime_noise
            scale = jnp.exp(sig * eps - 0.5 * sig * sig)     # (F, J)
            est = state.jobs.est_runtime[None, :]
            est_m = jnp.where(exact[:, None], est, est * scale)
            states = states._replace(jobs=states.jobs._replace(
                est_runtime=jnp.repeat(est_m, k, axis=0)))
        if spec.failure_prob > 0.0:
            tot = states.total_nodes                          # (F·k,)
            # one shared implementation with the replay-side fan
            # (fan.failure_downs): same i.i.d. draws bitwise, same
            # correlated rack/power-domain model when failure_domains>0
            # (s=0 — a decision has one base snapshot)
            down = failure_downs(
                spec, jnp.zeros_like(phi), phi, u,
                jnp.broadcast_to(state.total_nodes, (F,)))
            down_b = jnp.repeat(down, k)
            states = states._replace(
                free_nodes=jnp.maximum(states.free_nodes - down_b, 0),
                total_nodes=jnp.maximum(tot - down_b, 1))

    pool_b = tile_pool(pool, F)
    plan_b = plan * F if plan is not None else None
    eval_mask = state.jobs.state == QUEUED
    res = _drain_impl(engine, states, pool_b, plan_b)
    metrics = jax.vmap(drain_metrics, in_axes=(0, None))(res, eval_mask)
    member_metrics = jax.tree.map(lambda x: x.reshape(F, k), metrics)
    member_dead = res.deadlocked.reshape(F, k)
    member_costs = jnp.where(member_dead, jnp.inf,
                             dist.member_costs(member_metrics))
    costs = dist.reduce_fan(member_costs)                    # (k,)
    best = scoring.select_policy(costs)
    ci, width = member_uncertainty(member_costs, axis=0)
    mean_metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0),
                                member_metrics)
    return Decision(
        policy_index=best,
        costs=costs,
        run_mask=res.first_started.reshape(F, k, cap)[0, best],
        metrics=mean_metrics,
        deadlocked=jnp.any(member_dead, axis=0),
        cost_terms=dist.cost_terms(mean_metrics),
        cost_ci=ci,
        fan_width=width,
    )


# ----------------------------------------------------------------------
# Scenario-vectorized replay (DESIGN.md §6).
# ----------------------------------------------------------------------

def _assemble_replay_inputs(submit, nodes, est, true_rt, valid, totals,
                            pool: EnginePool, P: int):
    """Scenario-row arrays (S, J) -> the flat (k = S·P) replay inputs:
    each row repeats P times (fork f = s·P + p), the pool tiles once
    per row, and the job table is preloaded but fully INVALID.  Pure
    ops — called inside ``_tiled_replay_inputs`` AND the fan jits
    (where the rows are device-perturbed pseudo-scenarios), so both
    paths assemble bit-identically."""
    rep = lambda x: jnp.repeat(x, P, axis=0)
    submit = rep(submit)                                    # (S*P, J)
    valid = rep(valid)
    k, J = submit.shape
    # distinct buffers per leaf (no aliasing): ``states`` is DONATED to
    # the jitted replay, and XLA rejects donating one buffer twice
    none = lambda: jnp.full((k, J), TIME_NONE, dtype=jnp.float32)
    jobs = JobTable(
        submit_t=submit,
        nodes=rep(nodes),
        est_runtime=rep(est),
        start_t=none(),
        end_t=none(),
        state=jnp.zeros((k, J), dtype=jnp.int32),           # INVALID
    )
    states = SimState(jobs=jobs,
                      free_nodes=rep(totals),
                      total_nodes=rep(totals),
                      now=jnp.zeros((k,), dtype=jnp.float32))
    arrival_t = jnp.where(valid, submit, jnp.inf)
    S = totals.shape[0]
    return states, arrival_t, rep(true_rt), tile_pool(pool, S), valid


@functools.partial(jax.jit, static_argnames=("P",))
def _tiled_replay_inputs(submit, nodes, est, true_rt, valid, totals,
                         pool: EnginePool, P: int):
    """The tiling proper, jitted so the ~10 repeat/fill ops fuse into
    one dispatch (eager per-op dispatch used to cost as much as the
    replay itself at small S·P)."""
    return _assemble_replay_inputs(submit, nodes, est, true_rt, valid,
                                   totals, pool, P)


#: Per-``ScenarioSet`` memo of the UNTILED device conversions (the six
#: ``jnp.asarray`` host->device transfers).  Keyed on object identity,
#: evicted by ``weakref.finalize`` when the set dies — never on raw id
#: reuse.  Only the untiled buffers are safe to reuse: the tiled
#: ``states`` is DONATED to the jitted replay, so ``replay_inputs``
#: reruns the (jitted, ~free) tiling per call to mint fresh donatable
#: buffers.  Callers must not mutate a ``ScenarioSet``'s arrays after
#: its first replay (``stack_scenarios`` fills them before returning).
_SCENARIO_ARRAY_CACHE: Dict[int, Tuple] = {}


def _scenario_arrays(scenarios) -> Tuple:
    import weakref
    key = id(scenarios)
    hit = _SCENARIO_ARRAY_CACHE.get(key)
    if hit is not None:
        return hit
    cvt = lambda x, dt: jnp.asarray(x, dtype=dt)
    arrs = (cvt(scenarios.submit_t, jnp.float32),
            cvt(scenarios.nodes, jnp.int32),
            cvt(scenarios.est_runtime, jnp.float32),
            cvt(scenarios.true_runtime, jnp.float32),
            cvt(scenarios.valid, bool),
            cvt(scenarios.total_nodes, jnp.int32))
    try:
        weakref.finalize(scenarios, _SCENARIO_ARRAY_CACHE.pop, key, None)
    except TypeError:
        return arrs          # un-weakref-able stand-in: serve uncached
    _SCENARIO_ARRAY_CACHE[key] = arrs
    return arrs


def replay_inputs(scenarios, pool: EnginePool):
    """Device inputs for the flat (k = S·P) replay batch from a
    ``workload.ScenarioSet``-shaped object: scenario rows repeat P times
    (fork f = s·P + p), the pool tiles once per scenario, and the job
    table is preloaded but fully INVALID — arrivals inject slots as the
    replay reaches them.  Shared by ``DrainEngine.replay_grid`` and
    ``whatif.sharded_replay_grid`` (which shards the leading axis).
    The host->device conversion of the scenario arrays is memoized per
    ``ScenarioSet`` identity (``_scenario_arrays``); the tiling reruns
    per call because its output is donated."""
    P = pool_size(pool)
    return _tiled_replay_inputs(*_scenario_arrays(scenarios), pool, P)


def grid_select(objective: Objective, metrics: DrainMetrics,
                deadlocked: jax.Array, P: int):
    """Per-objective selection over a flat (k = S·P) replay batch:
    reshape the metric fields to (S, P), compile the goal's costs over
    the policy axis (deadlocked forks at +inf), argmin per scenario.
    Pure device code — called inside the jitted replay; the sharded
    streamer calls the jitted ``grid_select_jit`` below (op-by-op eager
    dispatch loses XLA's fused-multiply-add contraction of the score
    arithmetic, breaking cost bitwise-parity with the local path)."""
    grid = jax.tree.map(lambda x: x.reshape((-1, P) + x.shape[1:]), metrics)
    costs = objective.costs(grid)                              # (S, P)
    costs = jnp.where(deadlocked.reshape(-1, P), jnp.inf, costs)
    return costs, jnp.argmin(costs, axis=-1)


@functools.partial(jax.jit, static_argnames=("objective", "P"))
def grid_select_jit(objective: Objective, metrics: DrainMetrics,
                    deadlocked: jax.Array, P: int):
    return grid_select(objective, metrics, deadlocked, P)


def member_uncertainty(member_costs: jax.Array, axis: int = -2):
    """``(ci, width)`` over the fan axis of per-member costs: the 95%
    normal CI half-width of the member mean (``1.96·σ/√F``) and the
    worst−best member spread.  Any non-finite member (a deadlocked
    future) poisons both stamps to +inf — "not sure at all"."""
    F = member_costs.shape[axis]
    finite = jnp.all(jnp.isfinite(member_costs), axis=axis)
    safe = jnp.where(jnp.isfinite(member_costs), member_costs, 0.0)
    ci = 1.96 * jnp.std(safe, axis=axis) / np.sqrt(F)
    width = (jnp.max(member_costs, axis=axis)
             - jnp.min(member_costs, axis=axis))
    return (jnp.where(finite, ci, jnp.inf),
            jnp.where(finite, width, jnp.inf))


def fan_select(objective: ObjectiveLike, metrics: DrainMetrics,
               deadlocked: jax.Array, F: int, P: int):
    """Distributional selection over a flat (k = S·F·P) fan batch:
    reshape to (S, F, P), evaluate the inner goal per member
    (deadlocked members at +inf), reduce the fan axis with the goal's
    ``Distributional`` reduction (plain goals lift to ``mean:``), and
    argmin per scenario.  F is static, so the sorted-reduction indices
    are trace-time constants — pure device code, called inside the
    fan jit (the sharded streamer uses ``fan_select_jit``).

    Returns ``(member_costs (S,F,P), costs (S,P), best (S,), ci, width)``.
    """
    dist = as_distributional(objective)
    grid = jax.tree.map(
        lambda x: x.reshape((-1, F, P) + x.shape[1:]), metrics)
    member = dist.member_costs(grid)                       # (S, F, P)
    member = jnp.where(deadlocked.reshape(-1, F, P), jnp.inf, member)
    costs = dist.reduce_fan(member)                        # (S, P)
    best = jnp.argmin(costs, axis=-1)
    ci, width = member_uncertainty(member, axis=-2)
    return member, costs, best, ci, width


@functools.partial(jax.jit, static_argnames=("objective", "F", "P"))
def fan_select_jit(objective: Objective, metrics: DrainMetrics,
                   deadlocked: jax.Array, F: int, P: int):
    return fan_select(objective, metrics, deadlocked, F, P)


def _replay_impl(engine: DrainEngine, states: SimState,
                 arrival_t: jax.Array, true_rt: jax.Array,
                 pool: EnginePool, valid: jax.Array,
                 plan: HoistPlan = None,
                 hoisted: Optional[jax.Array] = None):
    # Every slot with a finite arrival will be queued at some point
    # (plus any slot already queued at entry): the hoist domain.
    ever_queued = jnp.isfinite(arrival_t) | (states.jobs.state == QUEUED)
    order_fn = make_order_fn(states, pool, plan, ever_queued=ever_queued,
                             hoisted=hoisted)
    res = simulate_replay_batched(
        states, arrival_t, true_rt, order_fn, engine.pass_fn(),
        dynamic_bounds=engine.dynamic_bounds,
        elide_empty=engine.elide_empty)
    metrics = jax.vmap(state_metrics)(res.state, valid, true_rt)
    return res, metrics


@_quiet_donation
@functools.partial(jax.jit,
                   static_argnames=("engine", "plan", "objective", "P"),
                   donate_argnames=("states",))
def _replay(engine: DrainEngine, states: SimState, arrival_t: jax.Array,
            true_rt: jax.Array, pool: EnginePool, valid: jax.Array,
            plan: HoistPlan = None,
            objective: Objective = DEFAULT_OBJECTIVE, P: int = 1):
    res, metrics = _replay_impl(engine, states, arrival_t, true_rt, pool,
                                valid, plan)
    costs, best = grid_select(objective, metrics, res.deadlocked, P)
    return res, metrics, costs, best


@functools.partial(jax.jit,
                   static_argnames=("engine", "plan", "objective", "P",
                                    "S", "spec"))
def _fan_replay(engine: DrainEngine, submit, nodes, est, true_rt, valid,
                totals, pool: EnginePool, plan: HoistPlan = None,
                objective: Objective = DEFAULT_OBJECTIVE, P: int = 1,
                S: int = 1, spec: FanSpec = FanSpec()):
    """The fused fan: perturbation expansion + (S·F·P)-fork replay +
    distributional selection in ONE compiled computation.  Only the
    UNTILED base (S, J) arrays cross host->device — H2D is O(1) in F —
    and every expanded buffer is born inside the jit, so XLA reuses it
    in place without donation bookkeeping."""
    g = jnp.arange(S * spec.n)
    rows = perturb_block(submit, nodes, est, true_rt, valid, totals,
                         spec, g, S)
    states, arrival_t, true_rep, pool_t, valid_rep = \
        _assemble_replay_inputs(*rows, pool, P)
    res, metrics = _replay_impl(engine, states, arrival_t, true_rep,
                                pool_t, valid_rep, plan)
    member, costs, best, ci, width = fan_select(
        objective, metrics, res.deadlocked, spec.n, P)
    return res, metrics, member, costs, best, ci, width


@functools.partial(jax.jit,
                   static_argnames=("engine", "plan", "objective", "P",
                                    "S", "spec", "lo", "width"))
def _fan_window_replay(engine: DrainEngine, submit, nodes, est, true_rt,
                       valid, totals, pool: EnginePool,
                       plan: HoistPlan = None,
                       objective: Objective = DEFAULT_OBJECTIVE,
                       P: int = 1, S: int = 1, spec: FanSpec = FanSpec(),
                       lo: int = 0, width: int = 1):
    """``_fan_replay`` restricted to members ``φ ∈ [lo, lo+width)`` —
    the racing-rung suffix.  Row ``r = s·width + w`` is member
    ``lo + w`` of scenario s (fork ``f = r·P + p``); the per-member
    draws key on (seed, s, φ) alone, so each row is bitwise the
    ``s·F + φ`` row of the full fan.  ``lo``/``width`` are static —
    the rung schedule is fixed, so each rung shape compiles once."""
    r = jnp.arange(S * width)
    rows = perturb_window(submit, nodes, est, true_rt, valid, totals,
                          spec, r, lo, width, S)
    states, arrival_t, true_rep, pool_t, valid_rep = \
        _assemble_replay_inputs(*rows, pool, P)
    res, metrics = _replay_impl(engine, states, arrival_t, true_rep,
                                pool_t, valid_rep, plan)
    member, costs, best, ci, cwidth = fan_select(
        objective, metrics, res.deadlocked, width, P)
    return res, metrics, member, costs, best, ci, cwidth


@functools.partial(jax.jit,
                   static_argnames=("engine", "spec", "objective", "plan",
                                    "lo", "width"))
def _decide_fan_window(engine: DrainEngine, state: SimState,
                       pool: EnginePool, spec: FanSpec = FanSpec(),
                       objective: Objective = DEFAULT_OBJECTIVE,
                       plan: HoistPlan = None, lo: int = 0,
                       width: int = 1):
    """``_decide_fan`` restricted to members ``φ ∈ [lo, lo+width)`` —
    the drain-side racing rung (fork ``f = w·k + p``, member
    ``φ = lo + w``).  Same (seed, φ) draw chains as ``_decide_fan``,
    so window members are bitwise the full fan's members and rungs
    concatenate without replaying a member twice.  Returns per-member
    pieces (costs, deadlocks, metrics, member-0 first-started) for the
    host-side race controller to accumulate — selection over the
    concatenated members happens in ``race.rung_stats``."""
    from repro.core.fan import _member_draws, failure_downs
    k = pool_size(pool)
    cap = state.jobs.capacity
    dist = as_distributional(objective)
    phi = lo + jnp.arange(width)

    states = broadcast_state(state, width * k)
    if not spec.degenerate:
        eps, _, u = jax.vmap(
            lambda p: _member_draws(spec.seed, jnp.int32(0), p, cap))(phi)
        exact = phi == 0
        if spec.runtime_noise > 0.0:
            sig = spec.runtime_noise
            scale = jnp.exp(sig * eps - 0.5 * sig * sig)     # (W, J)
            est = state.jobs.est_runtime[None, :]
            est_m = jnp.where(exact[:, None], est, est * scale)
            states = states._replace(jobs=states.jobs._replace(
                est_runtime=jnp.repeat(est_m, k, axis=0)))
        if spec.failure_prob > 0.0:
            tot = states.total_nodes                          # (W·k,)
            # shared with fan.perturb_rows / _decide_fan: bitwise the
            # full fan's member draws (CRN window contract) under both
            # the i.i.d. and the correlated-domain model
            down = failure_downs(
                spec, jnp.zeros_like(phi), phi, u,
                jnp.broadcast_to(state.total_nodes, (width,)))
            down_b = jnp.repeat(down, k)
            states = states._replace(
                free_nodes=jnp.maximum(states.free_nodes - down_b, 0),
                total_nodes=jnp.maximum(tot - down_b, 1))

    pool_b = tile_pool(pool, width)
    plan_b = plan * width if plan is not None else None
    eval_mask = state.jobs.state == QUEUED
    res = _drain_impl(engine, states, pool_b, plan_b)
    metrics = jax.vmap(drain_metrics, in_axes=(0, None))(res, eval_mask)
    member_metrics = jax.tree.map(lambda x: x.reshape(width, k), metrics)
    member_dead = res.deadlocked.reshape(width, k)
    member_costs = jnp.where(member_dead, jnp.inf,
                             dist.member_costs(member_metrics))
    first0 = res.first_started.reshape(width, k, cap)[0]
    return member_costs, member_dead, member_metrics, first0


def _shape_outcome(res: ReplayResult, metrics: DrainMetrics, shape,
                   costs: Optional[jax.Array] = None,
                   best: Optional[jax.Array] = None) -> ReplayOutcome:
    rs = lambda x: x.reshape(shape + x.shape[1:])
    return ReplayOutcome(
        start_t=rs(res.state.jobs.start_t),
        end_t=rs(res.state.jobs.end_t),
        metrics=jax.tree.map(rs, metrics),
        deadlocked=rs(res.deadlocked),
        events=rs(res.events),
        result=res,
        costs=costs.reshape(shape) if costs is not None else None,
        best=best.reshape(shape[:-1]) if best is not None else None,
    )


@functools.partial(jax.jit, static_argnames=("engine",))
def _single_pass(engine: DrainEngine, state: SimState,
                 pool: EnginePool) -> jax.Array:
    # The emulator's per-event oracle path: deliberately uncompacted
    # (full static rank bound, fresh sort) — it is what the compacted
    # loops are parity-tested against.
    states = broadcast_state(state, 1)
    order = batched_priority_order(states, pool)
    return engine.pass_fn()(states, order, None)[0]


DEFAULT_ENGINE = DrainEngine(backend="reference")
