"""On-device Monte-Carlo scenario fans (DESIGN.md §10).

A replay-grid decision evaluates ONE predicted future per (scenario,
policy) cell — fragile exactly when adaptivity matters: user runtime
estimates are notoriously wrong, clusters lose nodes, and arrival
bursts reshape the queue.  A **fan** evaluates F *perturbed* futures
per cell instead, and selects by a distributional goal
(``objective.Distributional``: ``p95:avg_wait``, ``cvar:0.9:...``,
``worst:``, ``regret:``).

The perf contract is that the fan is expanded **inside the jitted
replay**: the base ``ScenarioSet`` is uploaded once ((S, J) arrays, the
same H2D traffic as a fan-less grid) and the F perturbations are
derived on device from per-member PRNG keys — no host materialization,
padding, or shipping of F trace copies (``benchmarks/risk.py`` gates
the ≥10× H2D reduction — exactly F× by construction — plus the
wall-clock win over that baseline, bitwise member parity included).  Fan member φ of scenario s rides the
existing fork axis as pseudo-scenario ``g = s·F + φ`` (flat fork
``f = g·P + p``), which keeps the §7 hoist plans P-periodic and lets
the §9 fleet streamer shard the fan like any other scenario axis.

Three perturbation models, all gated *statically* on ``FanSpec`` fields
(a zeroed model compiles to the identity, so the degenerate spec is
bit-exact to ``engine.replay_grid``), all keyed per (s, φ)
independently of F (``jax.random.fold_in`` chains — fans are
deterministic, resumable, and **prefix-stable**: the members of a low-F
pre-pass are literally the first members of the full fan, the
common-random-numbers property the pruning below and the CVaR/regret
comparisons across policies rely on):

* ``runtime_noise`` — mean-preserving multiplicative lognormal noise on
  TRUE runtimes (``exp(σ·ε − σ²/2)``): reality diverging from the
  submitted estimates, which stay untouched (the §3.2 asymmetry);
* ``burst_amplitude``/``burst_period`` — a monotone sinusoidal time
  warp of the arrival timeline with a per-member random phase (the
  ``workload.bursty_trace`` rate modulation applied as a time change):
  derivative ``1 + A·cos ≥ 1 − A > 0`` preserves submission order;
* ``failure_prob``/``failure_frac`` — per-member node-failure draws
  against the horizon: with probability ``failure_prob`` the member
  loses ``U[0, failure_frac]`` of its nodes for the whole replay (the
  emulator's ``FailureSpec`` timeline collapsed to its worst case);
  members whose capacity can no longer fit a job legitimately deadlock
  and contribute ``+inf`` member costs.  With ``failure_domains = D >
  0`` the i.i.d. per-member draw is replaced by a CORRELATED
  rack/power-domain model (ROADMAP risk residual c): the cluster is
  split into D equal domains, each domain d of scenario s carries a
  latent fragility ``q[s, d]`` keyed on ``(seed, s, d)`` ONLY — shared
  by every member and persistent across racing rungs, member windows,
  and repeated decisions (the same domains are the weak ones
  everywhere) — and member φ fails exactly the domains whose
  threshold ``min(2·failure_prob·q[s, d], 1)`` exceeds its single
  uniform draw.  Failures therefore arrive in domain-sized chunks,
  member failure sets are NESTED (a more unlucky member loses a
  superset of domains), and members are positively correlated through
  the shared fragilities, while the marginal per-domain failure rate
  stays ``failure_prob`` (exactly for ``failure_prob ≤ 0.5``; clipped
  above).  ``failure_frac`` caps the total fraction lost.  ``D = 0``
  (default) keeps the legacy i.i.d. model bit-for-bit.

Member φ=0 is always EXACT (no perturbation): it is the fan-less
prediction, so an F=1 fan is bitwise the PR-6 replay for ANY spec, and
the distinguished member the twin's qrun actions come from.

**Goal-conditioned pool pruning** (``pruned_fan_grid``): a cheap low-F
pre-pass drops policies a dominance bound proves the objective never
selects, before the full-F grid runs.  The bound is index-guarded
first-order dominance on member costs — policy p is dropped iff in
EVERY scenario some earlier-index policy q is no worse on every sorted
member cost (unsorted/pointwise for ``regret:``, whose per-member best
is CRN-aligned).  Sorted dominance implies ``reduce(q) ≤ reduce(p)``
for every symmetric monotone reduction (quantiles, CVaR, mean, worst),
and the ``q < p`` index guard means q also wins the argmin's
first-occurrence tie-break — so removing p cannot change the selected
policy.  The theorem is exact when the pre-pass fan IS the deciding fan
(``pre_n == n``, the property tested in tests/test_fan.py); for
``pre_n < n`` prefix-stability makes it a strong empirical bound,
gated end-to-end by benchmarks/risk.py (selection identical on every
(scenario, objective) cell).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FanSpec", "PruneInfo", "perturb_block", "perturb_rows",
    "perturb_window", "materialize_fan", "dominance_keep",
    "pruned_fan_grid", "normalize_fan", "fit_runtime_sigma",
    "failure_downs",
]


@dataclasses.dataclass(frozen=True)
class FanSpec:
    """How to grow F perturbed futures from one base scenario.

    Frozen + hashable → a static jit argument: each (spec, shape) pair
    compiles once.  All randomness derives from ``seed`` through
    per-(scenario, member) ``fold_in`` chains — no global RNG state,
    same member → same perturbation regardless of F or block slicing.
    """

    n: int = 1                    # fan size F (members per scenario)
    runtime_noise: float = 0.0    # σ of lognormal true-runtime noise
    burst_amplitude: float = 0.0  # arrival warp amplitude A in [0, 1)
    burst_period: float = 3600.0  # arrival warp period (seconds)
    failure_prob: float = 0.0     # P(member loses nodes) in [0, 1]
    failure_frac: float = 0.25    # max fraction of nodes lost
    failure_domains: int = 0      # D rack/power domains (0 = i.i.d.)
    seed: int = 0

    def __post_init__(self) -> None:
        if self.n < 1:
            raise ValueError(f"fan size must be >= 1, got {self.n}")
        if not 0.0 <= self.burst_amplitude < 1.0:
            raise ValueError(
                f"burst_amplitude must be in [0, 1) to keep the arrival "
                f"warp monotone, got {self.burst_amplitude}")
        if self.burst_period <= 0.0:
            raise ValueError("burst_period must be positive")
        if not 0.0 <= self.failure_prob <= 1.0:
            raise ValueError("failure_prob must be in [0, 1]")
        if not 0.0 <= self.failure_frac <= 1.0:
            raise ValueError("failure_frac must be in [0, 1]")
        if self.failure_domains < 0:
            raise ValueError("failure_domains must be >= 0")
        if self.runtime_noise < 0.0:
            raise ValueError("runtime_noise must be >= 0")

    @property
    def degenerate(self) -> bool:
        """True when every perturbation model is off — the fan compiles
        to exactly the base expansion (bitwise ``replay_grid`` parity)."""
        return (self.runtime_noise == 0.0 and self.burst_amplitude == 0.0
                and self.failure_prob == 0.0)

    @classmethod
    def from_history(cls, telemetry, n: int = 64, *,
                     min_samples: int = 8, fallback: float = 0.3,
                     **kwargs) -> "FanSpec":
        """Fit ``runtime_noise`` to the twin's OWN observed §3.2
        estimate-vs-true residuals instead of an administrator guess.

        ``telemetry`` is a ``Telemetry`` (its ``runtime_residuals``
        list, recorded by the twin at every JOBOBIT as ``(estimated,
        actual)`` runtime pairs) or any iterable of such pairs.  The
        lognormal model is exactly the fan's perturbation model
        (``actual = est · exp(σ·ε − σ²/2)`` mean-preserving), so the
        MLE is the sample std of ``log(actual/est)``; until
        ``min_samples`` completions are observed the ``fallback`` σ is
        used.  Host-side fitting only — the returned spec enters the
        device path like any other ``FanSpec``."""
        res = getattr(telemetry, "runtime_residuals", telemetry)
        sigma = fit_runtime_sigma(res, min_samples=min_samples,
                                  fallback=fallback)
        return cls(n=n, runtime_noise=sigma, **kwargs)


def normalize_fan(fan) -> FanSpec:
    """Accept a ``FanSpec`` or a bare int F (a degenerate F-member fan
    — useful for parity tests and CLI defaults)."""
    if isinstance(fan, FanSpec):
        return fan
    return FanSpec(n=int(fan))


def fit_runtime_sigma(residuals, *, min_samples: int = 8,
                      fallback: float = 0.3) -> float:
    """σ̂ = sample std (ddof=1) of ``log(actual/est)`` over the finite
    positive ``(est, actual)`` pairs; ``fallback`` below ``min_samples``
    usable pairs.  Pure host arithmetic."""
    logs = []
    for est, actual in residuals:
        e, a = float(est), float(actual)
        if e > 0.0 and a > 0.0 and np.isfinite(e) and np.isfinite(a):
            logs.append(np.log(a / e))
    if len(logs) < max(min_samples, 2):
        return float(fallback)
    return float(np.std(np.asarray(logs), ddof=1))


# ----------------------------------------------------------------------
# Per-member PRNG derivation.  Key chain: seed -> scenario s -> member φ
# -> draw tag.  φ-keyed (not F-keyed): prefixes are stable.
# ----------------------------------------------------------------------

def _member_draws(seed: int, s: jax.Array, phi: jax.Array, J: int):
    """Perturbation draws for ONE (scenario, member): runtime-noise
    normals (J,), a burst phase scalar, and two uniforms (failure hit +
    severity).  Scalar ``s``/``phi`` — vmapped over the block axis."""
    k = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), s), phi)
    eps = jax.random.normal(jax.random.fold_in(k, 0), (J,))
    phase = jax.random.uniform(jax.random.fold_in(k, 1), (),
                               minval=0.0, maxval=2.0 * np.pi)
    u = jax.random.uniform(jax.random.fold_in(k, 2), (2,))
    return eps, phase, u


# Domain-fragility key tag: folded where the member φ normally goes, so
# the chain stays (seed → s → ·) but can NEVER collide with a real
# member (fans are orders of magnitude smaller than 2^31 − 1).
_DOMAIN_TAG = 0x7FFFFFFF


def _domain_fragility(seed: int, s: jax.Array, D: int) -> jax.Array:
    """Latent fragilities ``q[s, :] ∈ [0, 1)`` of the D rack/power
    domains of ONE scenario — keyed on ``(seed, s, d)`` only, NO member
    φ in the chain: every member, racing rung window, and repeated
    decision sees the SAME weak domains (persistence across time)."""
    k = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), s), _DOMAIN_TAG)
    return jax.random.uniform(k, (D,))


def failure_downs(spec: FanSpec, s: jax.Array, phi: jax.Array,
                  u: jax.Array, tot: jax.Array) -> jax.Array:
    """Per-row node-capacity reductions of the failure model — the ONE
    implementation shared by ``perturb_rows`` (replay-side fans) and
    the drain-side ``engine._decide_fan``, so both fan surfaces agree
    on the correlation structure.

    ``u`` is the (B, 2) member uniform pair from ``_member_draws``;
    ``s``/``phi`` are the (B,) scenario/member ids; ``tot`` the (B,)
    capacities.  Returns (B,) reductions in ``tot``'s dtype; exact
    members (φ=0) always get 0.  ``failure_domains == 0`` reproduces
    the legacy i.i.d. draw bit-for-bit; ``D > 0`` is the comonotone
    domain model documented in the module docstring (member φ fails
    domain d iff ``u[φ, 0] < min(2·p·q[s, d], 1)`` — one uniform per
    member thresholded against the shared fragilities, so failure sets
    are nested across members and marginally P(fail) = p per domain
    for p ≤ 0.5), losing ``floor(tot · n_failed / D)`` nodes capped at
    ``floor(tot · failure_frac)``."""
    exact = phi == 0
    totf = tot.astype(jnp.float32)
    if spec.failure_domains > 0:
        D = spec.failure_domains
        q = jax.vmap(functools.partial(
            _domain_fragility, spec.seed, D=D))(s)            # (B, D)
        thresh = jnp.minimum(2.0 * spec.failure_prob * q, 1.0)
        hit_d = (u[:, :1] < thresh) & (~exact)[:, None]       # (B, D)
        n_fail = hit_d.sum(axis=1).astype(jnp.float32)
        down = jnp.floor(totf * (n_fail / D))
        down = jnp.minimum(down, jnp.floor(totf * spec.failure_frac))
    else:
        hit = (u[:, 0] < spec.failure_prob) & ~exact
        frac = u[:, 1] * spec.failure_frac
        down = jnp.where(hit, jnp.floor(totf * frac), 0.0)
    return down.astype(tot.dtype)


def perturb_rows(submit, nodes, est, true_rt, valid, totals,
                 spec: FanSpec, s: jax.Array, phi: jax.Array,
                 inert: jax.Array):
    """Perturb explicit (scenario, member) row vectors — the shared
    core of ``perturb_block`` (contiguous fans, ``φ = g mod F``) and
    ``perturb_window`` (member windows ``φ ∈ [lo, hi)``, the racing
    suffix replays).  Perturbations depend ONLY on ``(spec.seed, s,
    φ)`` — never on how the rows were batched — which is the CRN
    prefix-stability contract the donation/racing paths rely on: a row
    built here is bitwise the same row of the full fan."""
    sub = submit[s]
    nod = nodes[s]
    es = est[s]
    tr = true_rt[s]
    val = valid[s]
    tot = totals[s]

    if not spec.degenerate:
        J = submit.shape[1]
        eps, phase, u = jax.vmap(
            functools.partial(_member_draws, spec.seed, J=J))(s, phi)
        exact = phi == 0
        if spec.runtime_noise > 0.0:
            sig = spec.runtime_noise
            scale = jnp.exp(sig * eps - 0.5 * sig * sig)
            tr = jnp.where(exact[:, None], tr, tr * scale)
        if spec.burst_amplitude > 0.0:
            omega = 2.0 * np.pi / spec.burst_period
            amp = spec.burst_amplitude / omega
            warped = sub + amp * (jnp.sin(omega * sub + phase[:, None])
                                  - jnp.sin(phase)[:, None])
            # monotone in exact arithmetic (derivative >= 1 - A > 0) and
            # >= 0 (|sin(a+d) - sin a| <= d); cummax irons out any f32
            # rounding inversion so the replay's arrival cursor stays
            # valid — and is applied identically by the host oracle
            warped = jax.lax.cummax(warped, axis=1)
            sub = jnp.where(exact[:, None], sub, warped)
        if spec.failure_prob > 0.0:
            down = failure_downs(spec, s, phi, u, tot)
            tot = jnp.maximum(tot - down, 1)

    val = val & ~inert[:, None]
    tot = jnp.where(inert, jnp.ones_like(tot), tot)
    return sub, nod, es, tr, val, tot


def perturb_block(submit, nodes, est, true_rt, valid, totals,
                  spec: FanSpec, g: jax.Array, S: int):
    """Expand base (S, J) scenario arrays into a block of perturbed
    pseudo-scenarios — pure device code, called INSIDE the fan jits.

    ``g`` is the (G,) pseudo-scenario id vector (``g = s·F + φ``); ids
    past ``S·F`` become INERT rows (valid all-False, ``total_nodes=1``,
    the ``pad_scenarios`` convention) so the fleet streamer can pad its
    last block.  Member φ=0 selects the unperturbed base bitwise
    (``jnp.where``, not arithmetic), and each model is gated on a
    static Python ``if`` — a degenerate spec compiles to the plain
    gather, which is how F=1 parity with ``replay_grid`` is bit-exact.
    """
    F = spec.n
    inert = g >= S * F
    gc = jnp.minimum(g, S * F - 1)
    s, phi = gc // F, gc % F
    return perturb_rows(submit, nodes, est, true_rt, valid, totals,
                        spec, s, phi, inert)


def perturb_window(submit, nodes, est, true_rt, valid, totals,
                   spec: FanSpec, r: jax.Array, lo, width: int, S: int):
    """Expand ONLY members ``φ ∈ [lo, lo+width)`` of each scenario —
    the racing suffix: row ``r = s·width + w`` is member ``φ = lo + w``
    of scenario s, bitwise the row ``s·F + φ`` of the full fan
    (``perturb_rows`` keys on (s, φ) alone).  ``lo`` may be a traced
    scalar so fleet blocks at different offsets share one compile; ids
    past ``S·width`` are inert padding rows as in ``perturb_block``."""
    inert = r >= S * width
    rc = jnp.minimum(r, S * width - 1)
    s, w = rc // width, rc % width
    return perturb_rows(submit, nodes, est, true_rt, valid, totals,
                        spec, s, lo + w, inert)


# ----------------------------------------------------------------------
# Host materialization — the bit-exact oracle (and the benchmark's
# naive baseline): the SAME per-member perturbations pulled to host and
# packed as an (S·F)-scenario ScenarioSet for the fan-less replay_grid.
# ----------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("spec", "S"))
def _materialize_arrays(submit, nodes, est, true_rt, valid, totals,
                        spec: FanSpec, S: int):
    g = jnp.arange(S * spec.n)
    return perturb_block(submit, nodes, est, true_rt, valid, totals,
                         spec, g, S)


def materialize_fan(scenarios, spec: FanSpec):
    """The fan as a plain host-side ``ScenarioSet`` of S·F
    pseudo-scenarios (row ``s·F + φ`` = member φ of scenario s), with
    the IDENTICAL device-derived perturbations — so
    ``replay_grid(materialize_fan(sc, spec), pool)`` is bitwise equal
    to ``fan_grid(sc, pool, spec)`` member metrics (tests/test_fan.py).
    This is what the naive host path has to build, pad, and ship per
    decision; ``benchmarks/risk.py`` times it as the baseline."""
    S = int(scenarios.total_nodes.shape[0])
    arrs = (jnp.asarray(scenarios.submit_t, jnp.float32),
            jnp.asarray(scenarios.nodes, jnp.int32),
            jnp.asarray(scenarios.est_runtime, jnp.float32),
            jnp.asarray(scenarios.true_runtime, jnp.float32),
            jnp.asarray(scenarios.valid, bool),
            jnp.asarray(scenarios.total_nodes, jnp.int32))
    sub, nod, es, tr, val, tot = (np.asarray(x) for x in
                                  _materialize_arrays(*arrs, spec, S))
    return dataclasses.replace(
        scenarios, submit_t=sub, nodes=nod, est_runtime=es,
        true_runtime=tr, valid=val,
        n_jobs=np.repeat(np.asarray(scenarios.n_jobs), spec.n),
        total_nodes=tot)


# ----------------------------------------------------------------------
# Goal-conditioned pool pruning.
# ----------------------------------------------------------------------

class PruneInfo(NamedTuple):
    """What the pre-pass dropped and how the sub-grid maps back."""
    keep: np.ndarray        # kept FULL-pool indices, ascending
    best: np.ndarray        # (S,) winners as FULL-pool indices
    rate: float             # fraction of the pool pruned
    pre_members: np.ndarray  # (S, pre_n, P) pre-pass member costs
    members: int = 0        # (s, φ, p) triples actually replayed
    members_full: int = 0   # triples an unpruned full fan replays


def dominance_keep(member_costs: np.ndarray,
                   pointwise: bool = False) -> np.ndarray:
    """(P,) keep mask from (S, F0, P) member costs.

    Policy p is DROPPED iff in every scenario some policy q with
    ``q < p`` (pool order — the argmin tie-break) satisfies
    ``c[s, ·, q] <= c[s, ·, p]`` on every member — over SORTED member
    costs for the symmetric monotone reductions (first-order stochastic
    dominance), or raw CRN-aligned members for ``regret:``
    (``pointwise=True``; removing a pointwise-dominated policy leaves
    every member's per-policy min unchanged).  The index guard makes
    dominance a sub-relation of pool order: acyclic, and the surviving
    argmin equals the full-pool argmin (module docstring).  ``inf``
    member costs (deadlocks) compare like any value; NaNs never
    dominate."""
    c = np.asarray(member_costs, dtype=np.float64)
    if c.ndim != 3:
        raise ValueError(f"member costs must be (S, F, P), got {c.shape}")
    if not pointwise:
        c = np.sort(c, axis=1)
    # le[s, q, p]: q no worse than p on every (sorted) member of s
    le = (c[:, :, :, None] <= c[:, :, None, :]).all(axis=1)
    P = c.shape[-1]
    earlier = np.arange(P)[:, None] < np.arange(P)[None, :]   # q < p
    dominated = (le & earlier).any(axis=1)                    # (S, P)
    return ~dominated.all(axis=0)


def pruned_fan_grid(scenarios, pool, fan, objective=None, *,
                    engine=None, pre_n: int = 16):
    """Two-pass fan evaluation: a cheap ``pre_n``-member pre-pass, the
    dominance prune, then ONLY the remaining member suffix
    ``φ ∈ [pre_n, F)`` over the kept sub-pool.

    The pre-pass members are DONATED into the deciding fan via CRN
    prefix-stability — member φ of the pre-pass is bitwise member φ of
    the full fan (``perturb_rows`` keys on (s, φ) alone), so the
    donated prefix concatenates with the ``fan_window_grid`` suffix
    into exactly the full fan's member grid without replaying any
    (scenario, policy, member) triple twice.  ``info.members`` vs
    ``info.members_full`` accounts for the saving: the old double-pay
    was ``S·(pre_n·P + F·P_kept)``; donation makes it
    ``S·(pre_n·P + (F − pre_n)·P_kept)`` — with ``pre_n == F`` the
    second pass vanishes entirely.

    Returns ``(outcome, info)`` — ``outcome`` is the full-F
    ``engine.FanOutcome`` over the KEPT pool (its ``costs``/``metrics``
    have ``len(info.keep)`` policy columns; its ``result`` is None —
    the outcome is assembled from donated pieces, not one flat
    replay); ``info.best`` maps the per-scenario winners back to
    FULL-pool indices.  Selection is bitwise identical to the
    pre-donation double-replay (tests/test_fan.py asserts member
    parity against the unpruned grid); with ``pre_n == fan.n`` the
    winner is provably identical to the unpruned grid."""
    from repro.core import engine as _eng
    from repro.core.objective import as_distributional, resolve_goal
    eng = engine if engine is not None else _eng.DEFAULT_ENGINE
    spec = normalize_fan(fan)
    goal = resolve_goal(objective)
    pool = _eng.as_pool(pool)
    pre = dataclasses.replace(spec, n=min(pre_n, spec.n))
    pre_out = eng.fan_grid(scenarios, pool, pre, goal)
    pre_members = np.asarray(pre_out.member_costs)
    pointwise = as_distributional(goal).reduction == "regret"
    keep = dominance_keep(pre_members, pointwise=pointwise)
    keep_idx = np.nonzero(keep)[0]
    P = keep.shape[0]
    Pk = len(keep_idx)
    S = int(scenarios.total_nodes.shape[0])
    sub_pool = (pool if Pk == P
                else _eng._index_pool(pool, jnp.asarray(keep_idx)))
    kp = jnp.asarray(keep_idx)
    take = lambda x: x[:, :, kp]
    if pre.n == spec.n:
        metrics_k = jax.tree.map(take, pre_out.metrics)
        dead_k = take(pre_out.deadlocked)
        start_k = take(pre_out.start_t)
        end_k = take(pre_out.end_t)
        events_k = take(pre_out.events)
    else:
        suf = eng.fan_window_grid(scenarios, sub_pool, spec, goal,
                                  lo=pre.n, width=spec.n - pre.n)
        cat = lambda a, b: jnp.concatenate([take(a), b], axis=1)
        metrics_k = jax.tree.map(cat, pre_out.metrics, suf.metrics)
        dead_k = cat(pre_out.deadlocked, suf.deadlocked)
        start_k = cat(pre_out.start_t, suf.start_t)
        end_k = cat(pre_out.end_t, suf.end_t)
        events_k = cat(pre_out.events, suf.events)
    # Re-select over the concatenated (S, F, Pk) members in the SAME
    # jitted selection the sharded streamer uses (bitwise contract:
    # fan_select_jit on concatenated metrics == in-jit fan_select).
    flat = jax.tree.map(
        lambda x: x.reshape((S * spec.n * Pk,) + x.shape[3:]), metrics_k)
    member, costs, best, ci, width = _eng.fan_select_jit(
        goal, flat, dead_k.reshape(-1), spec.n, Pk)
    out = _eng.FanOutcome(
        start_t=start_k, end_t=end_k, metrics=metrics_k,
        deadlocked=dead_k, events=events_k, result=None,
        member_costs=member, costs=costs, best=best,
        cost_ci=ci, fan_width=width)
    info = PruneInfo(
        keep=keep_idx,
        best=keep_idx[np.asarray(out.best)],
        rate=1.0 - Pk / P,
        pre_members=pre_members,
        members=S * (pre.n * P + (spec.n - pre.n) * Pk),
        members_full=S * spec.n * P,
    )
    return out, info
