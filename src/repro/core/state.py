"""Struct-of-array job/cluster state — the twin's JAX-side mirror.

Fixed-capacity arrays (``max_jobs`` slots) so every simulation has a
static shape: slot ``i`` is job ``i`` for the lifetime of a trace.  The
same structures are used by (a) the twin's mirror of the physical system,
(b) each what-if simulation fork, and (c) the cluster emulator's
ground-truth state (which additionally knows true runtimes).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Job lifecycle states.
INVALID = 0   # empty slot
QUEUED = 1
RUNNING = 2
DONE = 3

# Sentinel for "not yet" times.
TIME_NONE = -1.0
INF = jnp.inf


class JobTable(NamedTuple):
    """All arrays have shape (max_jobs,).

    ``est_runtime`` is the user-provided walltime estimate — the only
    runtime the twin is allowed to see (§3.2: user estimates are
    commonly inaccurate; the sync stage corrects end events as they
    actually happen).
    """

    submit_t: jax.Array    # f32 — submission time
    nodes: jax.Array       # i32 — node request
    est_runtime: jax.Array # f32 — user walltime estimate
    start_t: jax.Array     # f32 — TIME_NONE until started
    end_t: jax.Array       # f32 — predicted (running) or actual (done) end
    state: jax.Array       # i32 — INVALID/QUEUED/RUNNING/DONE

    @property
    def capacity(self) -> int:
        return self.submit_t.shape[-1]


class SimState(NamedTuple):
    """One simulation instance (or the twin's live mirror)."""

    jobs: JobTable
    free_nodes: jax.Array   # i32 scalar
    total_nodes: jax.Array  # i32 scalar (changes on NODEFAIL/NODEUP)
    now: jax.Array          # f32 scalar


def empty_jobs(max_jobs: int) -> JobTable:
    f = jnp.full((max_jobs,), TIME_NONE, dtype=jnp.float32)
    return JobTable(
        submit_t=f,
        nodes=jnp.zeros((max_jobs,), dtype=jnp.int32),
        est_runtime=jnp.zeros((max_jobs,), dtype=jnp.float32),
        start_t=f,
        end_t=f,
        state=jnp.zeros((max_jobs,), dtype=jnp.int32),
    )


def empty_state(max_jobs: int, total_nodes: int) -> SimState:
    return SimState(
        jobs=empty_jobs(max_jobs),
        free_nodes=jnp.asarray(total_nodes, dtype=jnp.int32),
        total_nodes=jnp.asarray(total_nodes, dtype=jnp.int32),
        now=jnp.asarray(0.0, dtype=jnp.float32),
    )


# --- functional updates (jit-safe) -------------------------------------

def add_job(state: SimState, job_id, submit_t, nodes, est_runtime) -> SimState:
    """QUEUEJOB: place a job in its slot."""
    jobs = state.jobs
    jobs = jobs._replace(
        submit_t=jobs.submit_t.at[job_id].set(submit_t),
        nodes=jobs.nodes.at[job_id].set(nodes),
        est_runtime=jobs.est_runtime.at[job_id].set(est_runtime),
        start_t=jobs.start_t.at[job_id].set(TIME_NONE),
        end_t=jobs.end_t.at[job_id].set(TIME_NONE),
        state=jobs.state.at[job_id].set(QUEUED),
    )
    return state._replace(jobs=jobs, now=jnp.maximum(state.now, submit_t))


def start_job(state: SimState, job_id, t) -> SimState:
    """RUNJOB: mark running; predicted end = t + user estimate (§3.2)."""
    jobs = state.jobs
    predicted_end = t + jobs.est_runtime[job_id]
    jobs = jobs._replace(
        start_t=jobs.start_t.at[job_id].set(t),
        end_t=jobs.end_t.at[job_id].set(predicted_end),
        state=jobs.state.at[job_id].set(RUNNING),
    )
    return state._replace(
        jobs=jobs,
        free_nodes=state.free_nodes - jobs.nodes[job_id],
        now=jnp.maximum(state.now, t),
    )


def end_job(state: SimState, job_id, t) -> SimState:
    """JOBOBIT: actual completion — §3.2 pull-back / push-forward.

    The predicted end event (at start + estimate) is replaced by the
    actual end time ``t``, whether early (common: users overestimate) or
    late (scheduler cleanup delay).
    """
    jobs = state.jobs
    jobs = jobs._replace(
        end_t=jobs.end_t.at[job_id].set(t),
        state=jobs.state.at[job_id].set(DONE),
    )
    return state._replace(
        jobs=jobs,
        free_nodes=state.free_nodes + jobs.nodes[job_id],
        now=jnp.maximum(state.now, t),
    )


def requeue_job(state: SimState, job_id, t) -> SimState:
    """Node failure kills a running job: release nodes, back to queue."""
    jobs = state.jobs
    was_running = jobs.state[job_id] == RUNNING
    freed = jnp.where(was_running, jobs.nodes[job_id], 0)
    jobs = jobs._replace(
        start_t=jobs.start_t.at[job_id].set(TIME_NONE),
        end_t=jobs.end_t.at[job_id].set(TIME_NONE),
        state=jobs.state.at[job_id].set(
            jnp.where(was_running, QUEUED, jobs.state[job_id])),
    )
    return state._replace(
        jobs=jobs, free_nodes=state.free_nodes + freed,
        now=jnp.maximum(state.now, t))


def resize_cluster(state: SimState, delta_nodes) -> SimState:
    """NODEFAIL (negative delta) / NODEUP (positive delta)."""
    return state._replace(
        total_nodes=state.total_nodes + delta_nodes,
        free_nodes=state.free_nodes + delta_nodes,
    )


def queued_mask(jobs: JobTable) -> jax.Array:
    return jobs.state == QUEUED


def running_mask(jobs: JobTable) -> jax.Array:
    return jobs.state == RUNNING


def validate_invariants(state: SimState) -> dict:
    """Host-side invariant check used by tests and the emulator.

    Returns a dict of boolean invariants; all must be True.
    """
    jobs = state.jobs
    used = jnp.sum(jnp.where(running_mask(jobs), jobs.nodes, 0))
    started = jobs.start_t >= 0
    valid = jobs.state != INVALID
    return {
        "free_plus_used_is_total": bool(
            (state.free_nodes + used) == state.total_nodes),
        "free_nonnegative": bool(state.free_nodes >= 0),
        "no_start_before_submit": bool(jnp.all(
            jnp.where(valid & started, jobs.start_t >= jobs.submit_t, True))),
        "running_have_start": bool(jnp.all(
            jnp.where(running_mask(jobs), started, True))),
        "done_have_end": bool(jnp.all(
            jnp.where(jobs.state == DONE, jobs.end_t >= 0, True))),
    }
