"""One scheduling pass: priority order + EASY backfilling (vectorized).

This is the inner loop of every what-if simulation and of the live
scheduler — the paper's hot spot (each cycle runs k full drain
simulations, each of which runs this pass at every event).

EASY backfilling (Mu'alem & Feitelson, ref [18] of the paper):
  1. Walk queued jobs in priority order; start each while it fits.
     The first job that does not fit becomes the *head* and receives a
     resource reservation.
  2. The reservation ("shadow") time is the earliest time the head can
     run given the predicted completion times of running jobs; ``extra``
     is the node surplus at that time.
  3. Later queued jobs may *backfill* now iff they fit now AND either
     (a) finish (by estimate) before the shadow time, or
     (b) use no more than ``extra`` nodes (then they may run past it).

Everything is fixed-shape: scans over all ``max_jobs`` slots with
validity masks, so the pass is vmappable over the policy axis and
lowerable inside ``lax.while_loop``.

A Pallas TPU kernel implementing the same pass with the queue resident
in VMEM and the policy/ensemble batch on the grid lives in
``repro/kernels/policy_eval.py`` (validated against this function).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import policies
from repro.core.state import (QUEUED, RUNNING, JobTable, SimState)


class PassResult(NamedTuple):
    state: SimState
    started: jax.Array      # bool (max_jobs,) — jobs started in this pass
    head_idx: jax.Array     # i32 scalar — reserved job slot (-1 if none)
    shadow_time: jax.Array  # f32 scalar — reservation time (+inf if none)


def priority_order(state: SimState, policy) -> jax.Array:
    """Priority-ranked job slots for one policy: queued jobs first by
    key, invalid/running/done last.  Stable argsort -> ties fall back to
    slot (submission) order.  Batched callers (``core.engine``) compute
    this once per event for the whole policy axis.

    ``policy`` is either a parametric ``policies.PolicySpec`` fork or a
    legacy integer policy id (the pre-parametric oracle path)."""
    queued = state.jobs.state == QUEUED
    if isinstance(policy, policies.PolicySpec):
        keys = policies.priority_key_spec(state.jobs, state.now, policy)
    else:
        keys = policies.priority_key(state.jobs, state.now, policy)
    keys = jnp.where(queued, keys, jnp.inf)
    return jnp.argsort(keys)


def schedule_pass(state: SimState, policy) -> PassResult:
    """Keys + argsort + the order-driven pass (scalar convenience)."""
    return schedule_pass_with_order(state, priority_order(state, policy))


def schedule_pass_with_order(state: SimState, order: jax.Array) -> PassResult:
    """The pass proper, given a precomputed priority ``order``.

    This is the sequential part every backend must implement; the
    ``reference`` engine backend is exactly this function vmapped over
    the policy/ensemble batch axis.
    """
    jobs = state.jobs
    now = state.now
    max_jobs = jobs.capacity

    queued = jobs.state == QUEUED
    nodes = jobs.nodes
    est = jobs.est_runtime

    # ---- pass 1: greedy start until the first blocked job (the head) ----
    def greedy_body(i, carry):
        free, head_idx, head_found, started = carry
        j = order[i]
        is_q = queued[j]
        fits = nodes[j] <= free
        can_start = is_q & fits & (~head_found)
        free = jnp.where(can_start, free - nodes[j], free)
        started = started.at[j].set(started[j] | can_start)
        blocked = is_q & (~fits) & (~head_found)
        head_idx = jnp.where(blocked, j, head_idx)
        head_found = head_found | blocked
        return free, head_idx, head_found, started

    free0 = state.free_nodes
    started0 = jnp.zeros((max_jobs,), dtype=bool)
    free1, head_idx, head_found, started1 = jax.lax.fori_loop(
        0, max_jobs, greedy_body,
        (free0, jnp.int32(-1), jnp.asarray(False), started0))

    # ---- shadow time: when can the head start, given predicted ends? ----
    # Running set includes jobs started in pass 1 (their predicted end is
    # now + estimate; the twin never sees true runtimes).
    running = (jobs.state == RUNNING) | started1
    end_eff = jnp.where(started1, now + est, jobs.end_t)
    end_eff = jnp.where(running, end_eff, jnp.inf)
    nodes_r = jnp.where(running, nodes, 0)

    sort_idx = jnp.argsort(end_eff)
    ends_sorted = end_eff[sort_idx]
    cum_free = free1 + jnp.cumsum(nodes_r[sort_idx])

    head_nodes = jnp.where(head_found, nodes[head_idx], 0)
    feasible = (cum_free >= head_nodes) & jnp.isfinite(ends_sorted)
    any_feasible = jnp.any(feasible)
    k = jnp.argmax(feasible)  # first feasible completion
    shadow_time = jnp.where(
        head_found,
        jnp.where(any_feasible, ends_sorted[k], jnp.inf),
        jnp.inf)
    extra = jnp.where(
        head_found & any_feasible,
        cum_free[k] - head_nodes,
        # no head -> unconstrained (vacuous: no queued jobs remain)
        jnp.where(head_found, 0, jnp.iinfo(jnp.int32).max // 2))

    # ---- pass 2: EASY backfill --------------------------------------
    def backfill_body(i, carry):
        free, extra, started = carry
        j = order[i]
        cand = queued[j] & (~started[j]) & (j != head_idx)
        fits_now = nodes[j] <= free
        cond_a = (now + est[j]) <= shadow_time
        cond_b = nodes[j] <= extra
        start = cand & fits_now & (cond_a | cond_b)
        free = jnp.where(start, free - nodes[j], free)
        runs_past = start & (~cond_a)
        extra = jnp.where(runs_past, extra - nodes[j], extra)
        started = started.at[j].set(started[j] | start)
        return free, extra, started

    free2, _, started = jax.lax.fori_loop(
        0, max_jobs, backfill_body, (free1, extra, started1))

    # ---- apply -------------------------------------------------------
    new_jobs = jobs._replace(
        start_t=jnp.where(started, now, jobs.start_t),
        end_t=jnp.where(started, now + est, jobs.end_t),
        state=jnp.where(started, RUNNING, jobs.state),
    )
    new_state = state._replace(jobs=new_jobs, free_nodes=free2)
    return PassResult(
        state=new_state,
        started=started,
        head_idx=jnp.where(head_found, head_idx, -1),
        shadow_time=shadow_time,
    )


def schedule_pass_starts(state: SimState, policy_id) -> Tuple[jax.Array, SimState]:
    """Convenience: (started mask, new state)."""
    res = schedule_pass(state, policy_id)
    return res.started, res.state
