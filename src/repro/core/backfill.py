"""One scheduling pass: priority order + EASY backfilling (vectorized).

This is the inner loop of every what-if simulation and of the live
scheduler — the paper's hot spot (each cycle runs k full drain
simulations, each of which runs this pass at every event).

EASY backfilling (Mu'alem & Feitelson, ref [18] of the paper):
  1. Walk queued jobs in priority order; start each while it fits.
     The first job that does not fit becomes the *head* and receives a
     resource reservation.
  2. The reservation ("shadow") time is the earliest time the head can
     run given the predicted completion times of running jobs; ``extra``
     is the node surplus at that time.
  3. Later queued jobs may *backfill* now iff they fit now AND either
     (a) finish (by estimate) before the shadow time, or
     (b) use no more than ``extra`` nodes (then they may run past it).

Everything is fixed-shape: scans over all ``max_jobs`` slots with
validity masks, so the pass is vmappable over the policy axis and
lowerable inside ``lax.while_loop``.

A Pallas TPU kernel implementing the same pass with the queue resident
in VMEM and the policy/ensemble batch on the grid lives in
``repro/kernels/policy_eval.py`` (validated against this function).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import policies
from repro.core.state import (QUEUED, RUNNING, JobTable, SimState)


class PassResult(NamedTuple):
    state: SimState
    started: jax.Array      # bool (max_jobs,) — jobs started in this pass
    head_idx: jax.Array     # i32 scalar — reserved job slot (-1 if none)
    shadow_time: jax.Array  # f32 scalar — reservation time (+inf if none)


def priority_order(state: SimState, policy) -> jax.Array:
    """Priority-ranked job slots for one policy: queued jobs first by
    key, invalid/running/done last.  Stable argsort -> ties fall back to
    slot (submission) order.  Batched callers (``core.engine``) compute
    this once per event for the whole policy axis.

    ``policy`` is either a parametric ``policies.PolicySpec`` fork or a
    legacy integer policy id (the pre-parametric oracle path)."""
    queued = state.jobs.state == QUEUED
    if isinstance(policy, policies.PolicySpec):
        keys = policies.priority_key_spec(state.jobs, state.now, policy)
    else:
        keys = policies.priority_key(state.jobs, state.now, policy)
    keys = jnp.where(queued, keys, jnp.inf)
    return jnp.argsort(keys)


def static_priority_order(state: SimState, policy,
                          ever_queued: jax.Array) -> jax.Array:
    """Hoisted priority order for a TIME-INVARIANT fork (DESIGN.md §7):
    ranked over every slot that can EVER be queued (``ever_queued``),
    not just the currently-queued set, so it is computed ONCE per drain
    or replay and reused at every event.

    Exactness: for a fork in ``policies.time_invariant_mask`` the keys
    of ever-queued slots never change, so at any event the
    currently-queued slots form a subsequence of this order sorted by
    (key, slot) — identical to the fresh ``priority_order`` ranking —
    and the scheduling pass skips non-QUEUED ranks as no-ops."""
    if isinstance(policy, policies.PolicySpec):
        keys = policies.priority_key_spec(state.jobs, state.now, policy)
    else:
        keys = policies.priority_key(state.jobs, state.now, policy)
    keys = jnp.where(ever_queued, keys, jnp.inf)
    return jnp.argsort(keys)


def schedule_pass(state: SimState, policy) -> PassResult:
    """Keys + argsort + the order-driven pass (scalar convenience)."""
    return schedule_pass_with_order(state, priority_order(state, policy))


def schedule_pass_with_order(state: SimState, order: jax.Array,
                             limit=None) -> PassResult:
    """The pass proper, given a precomputed priority ``order``.

    This is the sequential part every backend must implement; the
    ``reference`` engine backend is exactly this function vmapped over
    the policy/ensemble batch axis.

    ``limit`` (optional i32 scalar) bounds both rank loops: ranks in
    ``[limit, max_jobs)`` must hold no queued slot (the caller computes
    it as ``des.pass_rank_limit``), making them provably no-ops in both
    the greedy and the backfill walk — so truncation is bit-exact while
    collapsing the O(J)-rank loops to the live queue depth.  ``None``
    keeps the full static bound (the pre-compaction behavior).
    """
    jobs = state.jobs
    now = state.now
    max_jobs = jobs.capacity

    queued = jobs.state == QUEUED
    nodes = jobs.nodes
    est = jobs.est_runtime

    # ---- pass 1: greedy start until the first blocked job (the head) ----
    # "Start each queued job in order while it fits; the first one that
    # does not fit blocks everything behind it" is a PREFIX property,
    # so the historical sequential rank loop has a closed form: with
    # need(r) = cumulative node demand over queued ranks <= r, a queued
    # rank starts iff need(r) <= free0 (before the head, free at rank r
    # is exactly free0 - (need(r) - nodes_r); at and past the head,
    # need(r) > free0 by monotonicity).  One cumsum replaces the O(J)
    # dependent-iteration loop — bit-exact, all-integer arithmetic.
    rank_hi = max_jobs if limit is None else limit
    free0 = state.free_nodes
    q_rank = queued[order]                          # rank space (J,)
    nodes_rank = jnp.where(q_rank, nodes[order], 0)
    need = jnp.cumsum(nodes_rank)
    fits_rank = need <= free0
    blocked_rank = q_rank & ~fits_rank
    head_found = jnp.any(blocked_rank)
    head_rank = jnp.argmax(blocked_rank)            # first blocked rank
    started_rank = q_rank & fits_rank
    started1 = jnp.zeros((max_jobs,), dtype=bool).at[order].set(started_rank)
    free1 = free0 - jnp.sum(jnp.where(started_rank, nodes_rank, 0))
    head_idx = jnp.where(head_found, order[head_rank], jnp.int32(-1))

    # ---- shadow time: when can the head start, given predicted ends? ----
    # Running set includes jobs started in pass 1 (their predicted end is
    # now + estimate; the twin never sees true runtimes).
    #
    # Historically: stable argsort by end time + cumsum scan, taking the
    # FIRST feasible sorted position.  The sort is replaced by an O(J²)
    # broadcast-reduce (the Pallas kernel's trade, DESIGN.md §2) that
    # keeps the sort-scan's exact semantics — ties included — by
    # contracting over the LEXICOGRAPHIC (end, slot) order the stable
    # argsort would have produced: cum(i) = free1 + Σ_j nodes_r(j) over
    # (e_j, j) <= (e_i, i).  cum is nondecreasing along that order, so
    # the first feasible position is the lex-min feasible item and both
    # its end time and its cumulative count are plain min-reductions.
    # All-integer node arithmetic -> bit-exact vs the sort-scan.
    running = (jobs.state == RUNNING) | started1
    end_eff = jnp.where(started1, now + est, jobs.end_t)
    end_eff = jnp.where(running, end_eff, jnp.inf)
    nodes_r = jnp.where(running, nodes, 0)

    slots = jnp.arange(max_jobs)
    lex_le = ((end_eff[None, :] < end_eff[:, None])
              | ((end_eff[None, :] == end_eff[:, None])
                 & (slots[None, :] <= slots[:, None])))
    # contraction as an f32 matvec (BLAS beats a masked reduce on CPU);
    # node counts are tiny integers, so f32 accumulation is exact and
    # the round-trip back to i32 is lossless
    cum_free = free1 + jnp.einsum(
        "ij,j->i", lex_le.astype(jnp.float32),
        nodes_r.astype(jnp.float32)).astype(jnp.int32)      # (J,)

    head_nodes = jnp.where(head_found, nodes[head_idx], 0)
    feasible = (cum_free >= head_nodes) & jnp.isfinite(end_eff)
    any_feasible = jnp.any(feasible)
    shadow_time = jnp.where(
        head_found,
        jnp.min(jnp.where(feasible, end_eff, jnp.inf)),
        jnp.inf)
    cum_first = jnp.min(
        jnp.where(feasible, cum_free, jnp.iinfo(jnp.int32).max))
    extra = jnp.where(
        head_found & any_feasible,
        cum_first - head_nodes,
        # no head -> unconstrained (vacuous: no queued jobs remain)
        jnp.where(head_found, 0, jnp.iinfo(jnp.int32).max // 2))

    # ---- pass 2: EASY backfill --------------------------------------
    def backfill_body(i, carry):
        free, extra, started = carry
        j = order[i]
        cand = queued[j] & (~started[j]) & (j != head_idx)
        fits_now = nodes[j] <= free
        cond_a = (now + est[j]) <= shadow_time
        cond_b = nodes[j] <= extra
        start = cand & fits_now & (cond_a | cond_b)
        free = jnp.where(start, free - nodes[j], free)
        runs_past = start & (~cond_a)
        extra = jnp.where(runs_past, extra - nodes[j], extra)
        started = started.at[j].set(started[j] | start)
        return free, extra, started

    # Every rank up to and including the head is a provable non-candidate
    # (queued ranks before the head all started in pass 1; the head is
    # excluded by ``j != head_idx``), so the walk starts past it — and
    # is empty when there is no head (every queued job already started).
    back_lo = jnp.where(head_found, head_rank + 1, rank_hi)
    free2, _, started = jax.lax.fori_loop(
        back_lo, rank_hi, backfill_body, (free1, extra, started1))

    # ---- apply -------------------------------------------------------
    new_jobs = jobs._replace(
        start_t=jnp.where(started, now, jobs.start_t),
        end_t=jnp.where(started, now + est, jobs.end_t),
        state=jnp.where(started, RUNNING, jobs.state),
    )
    new_state = state._replace(jobs=new_jobs, free_nodes=free2)
    return PassResult(
        state=new_state,
        started=started,
        head_idx=jnp.where(head_found, head_idx, -1),
        shadow_time=shadow_time,
    )


def schedule_pass_starts(state: SimState, policy_id) -> Tuple[jax.Array, SimState]:
    """Convenience: (started mask, new state)."""
    res = schedule_pass(state, policy_id)
    return res.started, res.state
