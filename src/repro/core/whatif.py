"""Parallel what-if exploration (§3.3) — the paper's k simulator forks.

The paper forks k simulator processes (one per policy) sharing a common
database.  On TPU the natural equivalent is a *policy batch axis*: one
batched DES advanced in lock-step for all policies by the
``repro.core.engine.DrainEngine`` (DESIGN.md §3).  The snapshot is
shared (broadcast, never copied per policy) — the same "objects share a
common database, only carry event metadata" property, but in SPMD form.

A pool is a **``PolicyPool``** — a stacked parametric ``PolicySpec``
(family (k,), θ (k, P)) plus per-fork display names (DESIGN.md §5).
Every entry point also accepts a sweep-grammar string (``"paper"``,
``"wfp:a=1..5x5"``), a raw ``PolicySpec`` stack, or a legacy i32 id
vector (``pool_array`` is the thin adapter that builds one); ids flow
through the engine's bit-exact pre-parametric oracle path.

Every entry point takes ``objective=`` — the administrator-configured
optimization goal (``core.objective``, DESIGN.md §8) as an
``Objective`` or grammar string; the deprecated ``weights=`` kwarg
lifts to the bit-identical paper-score objective.

This module is the thin public API over the engine:

  * ``decide`` / ``decide_ensemble`` — one scheduling cycle on the
    default (or a caller-supplied) engine; ensemble members ride the
    same batch axis, so k * n_ens forks drain in ONE while_loop;
  * ``sharded_whatif`` — the fork axis of the batched engine sharded
    over a device mesh for pools of hundreds of forks (θ shards with
    the fork axis: a parameter sweep is just a longer, shardable pool);
  * ``decide_legacy_vmap`` — the pre-engine path (``jax.vmap`` over the
    scalar DES), kept as a regression oracle and as the baseline the
    overhead benchmark compares the batched engine against.
"""
from __future__ import annotations

import functools
import itertools
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import scoring
from repro.core.des import drain_metrics, simulate_to_drain
from repro.core.engine import (DEFAULT_ENGINE, Decision, DrainEngine,
                               EnginePool, _quiet_donation)
from repro.core.objective import ObjectiveLike, resolve_goal
from repro.core.policies import (PolicyPool, PolicySpec, normalize_pool,
                                 parse_pool)
from repro.core.state import QUEUED, SimState

__all__ = [
    "Decision", "PolicyPool", "decide", "decide_ensemble",
    "decide_legacy_vmap", "sharded_whatif", "sharded_replay_grid",
    "sharded_fan_grid", "sharded_race_grid", "paper_pool", "pool_array",
]

#: Anything the public decide functions take as a pool.
PoolArg = Union[PolicyPool, PolicySpec, str, jax.Array]


def _engine_pool(pool: PoolArg) -> EnginePool:
    """Unwrap to what the engine consumes: a PolicySpec stack or a
    legacy id vector (passed through untouched — the oracle path)."""
    if isinstance(pool, PolicyPool):
        return pool.spec
    if isinstance(pool, str):
        return parse_pool(pool).spec
    return pool  # PolicySpec stack or legacy id array


def decide(state: SimState, pool: PoolArg,
           objective: ObjectiveLike = None, *,
           weights: Optional[scoring.ScoreWeights] = None,
           engine: Optional[DrainEngine] = None) -> Decision:
    """One scheduling cycle: fork k sims, score, select, extract qrun set.

    ``pool`` is a ``PolicyPool`` / ``PolicySpec`` stack / grammar
    string / legacy i32 id vector, ordered by tie-break priority.
    ``objective`` is the administrator's goal (DESIGN.md §8): an
    ``Objective``, a grammar string (``"score"``, ``"avg_wait"``,
    ``"min:avg_wait@util>=0.85"``), or None for the paper score;
    ``weights=`` is the deprecated legacy spelling (lifted
    bit-identically with a DeprecationWarning).  Everything (all k
    drain simulations included) is a single XLA computation — the
    per-cycle overhead the paper reports as "a few seconds" is
    microseconds here (see benchmarks/overhead.py).
    """
    return (engine or DEFAULT_ENGINE).decide(
        state, _engine_pool(pool), objective, weights=weights)


def decide_ensemble(state: SimState, pool: PoolArg, key: jax.Array,
                    n_ens: int = 8, noise: float = 0.3,
                    objective: ObjectiveLike = None, *,
                    weights: Optional[scoring.ScoreWeights] = None,
                    engine: Optional[DrainEngine] = None) -> Decision:
    """Uncertainty-aware cycle (beyond paper).

    Each ensemble member rescales every job's estimate by a lognormal
    factor (sigma=``noise``) before simulating; the policy cost is the
    ensemble mean (under ``objective``, as in ``decide``).  The qrun
    set is taken from the unperturbed member so actions stay consistent
    with the mirror.  All k * n_ens forks ride one batch axis through
    one drain.
    """
    return (engine or DEFAULT_ENGINE).decide_ensemble(
        state, _engine_pool(pool), key, n_ens=n_ens, noise=noise,
        objective=objective, weights=weights)


# ----------------------------------------------------------------------
# Legacy path: vmap over the scalar DES (pre-engine).  Benchmark /
# regression oracle only — new code should use the engine.
# ----------------------------------------------------------------------

def _single_whatif(state: SimState, policy_id) -> tuple:
    eval_mask = state.jobs.state == QUEUED
    res = simulate_to_drain(state, policy_id)
    m = drain_metrics(res, eval_mask)
    return m, res.first_started, res.deadlocked


@functools.partial(jax.jit, static_argnames=("weights",))
def decide_legacy_vmap(state: SimState, pool: jax.Array,
                       weights: scoring.ScoreWeights = scoring.PAPER_WEIGHTS
                       ) -> Decision:
    metrics, first_started, dead = jax.vmap(
        _single_whatif, in_axes=(None, 0))(state, pool)
    costs = scoring.policy_cost(metrics, weights)
    costs = jnp.where(dead, jnp.inf, costs)
    best = scoring.select_policy(costs)
    return Decision(
        policy_index=best,
        costs=costs,
        run_mask=first_started[best],
        metrics=metrics,
        deadlocked=dead,
    )


# ----------------------------------------------------------------------
# Fleet scale: shard the fork axis of the batched engine (DESIGN.md §9).
# ----------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("engine", "mesh", "axis", "objective",
                                    "plan"))
def _decide_fleet(engine: DrainEngine, mesh: Mesh, axis: str,
                  state: SimState, pool: EnginePool, objective, plan):
    """The sharded decision cycle: the DRAIN runs per shard under
    ``shard_map`` (each device forks/drains its own chunk of the pool,
    with its own shard-local hoist plan and pass bound), the selection
    (metrics -> costs -> argmin) runs on the concatenated result so the
    winner is global and keeps ``select_policy``'s first-occurrence
    tie-break over the FULL pool order."""
    from repro.core.des import broadcast_state, drain_metrics
    from repro.core.engine import _drain_impl, hoisted_orders, pool_size
    from repro.launch.mesh import shard_map

    n_shards = mesh.shape[axis]
    k_local = pool_size(pool) // n_shards

    if plan is None:
        def local(st: SimState, pool_shard: EnginePool):
            return _drain_impl(engine, broadcast_state(st, k_local),
                               pool_shard, plan)

        res = shard_map(local, mesh, in_specs=(P(), P(axis)),
                        out_specs=P(axis))(state, pool)
    else:
        # Hoisted static argsorts are computed HERE, in the GSPMD region,
        # and cross the shard boundary as a sharded input — jax 0.4
        # miscompiles the sort if it is traced inside the shard_map body
        # (see engine.hoisted_orders).  `plan` is shard-local; the global
        # plan is its n_shards-fold tile, so np.nonzero enumerates each
        # shard's time-invariant rows contiguously and P(axis) hands
        # every device exactly its own rows.
        states_full = broadcast_state(state, k_local * n_shards)
        hoisted = hoisted_orders(states_full, pool, plan * n_shards,
                                 states_full.jobs.state == QUEUED)

        def local(st: SimState, pool_shard: EnginePool, hoist_shard):
            return _drain_impl(engine, broadcast_state(st, k_local),
                               pool_shard, plan, hoisted=hoist_shard)

        res = shard_map(local, mesh, in_specs=(P(), P(axis), P(axis)),
                        out_specs=P(axis))(state, pool, hoisted)

    eval_mask = state.jobs.state == QUEUED
    metrics = jax.vmap(drain_metrics, in_axes=(0, None))(res, eval_mask)
    costs = objective.costs(metrics)
    costs = jnp.where(res.deadlocked, jnp.inf, costs)
    best = scoring.select_policy(costs)
    return Decision(
        policy_index=best,
        costs=costs,
        run_mask=res.first_started[best],
        metrics=metrics,
        deadlocked=res.deadlocked,
        cost_terms=objective.cost_terms(metrics),
    )


def sharded_whatif(mesh: Mesh, axis: str = "data",
                   engine: Optional[DrainEngine] = None,
                   objective: ObjectiveLike = None, *,
                   weights: Optional[scoring.ScoreWeights] = None):
    """Fleet-scale what-if: the fork (policy/ensemble) axis of the
    batched engine sharded over ``axis`` of ``mesh``.  Returns a jitted
    function with the same signature as ``decide`` whose pool size must
    be divisible by the axis size.  The snapshot is replicated (it is a
    few KB); only the fork axis is split, mirroring "k simulator copies
    sharing one database" at pod scale.

    The pool sharding is a PyTree prefix, so it applies equally to a
    legacy (k,) id vector and to a ``PolicySpec`` stack — for specs the
    θ matrix (k, P) is partitioned on its fork axis together with the
    family vector: a 128-point parameter sweep splits across devices
    exactly like 128 distinct policies.

    Static-key hoisting (DESIGN.md §7) is SHARD-LOCAL here (§9): the
    drain runs per device under ``shard_map``, so each shard hoists the
    argsorts of its own chunk's time-invariant forks — no cross-shard
    regrouping, the same gather/compact as the local engine, applied to
    a shorter fork axis.  ``engine.shard_local_plan`` derives the local
    plan; when the shards' chunks differ (SPMD traces one program) it
    falls back to per-event sorting, bit-identical either way.  The
    dynamic pass bound is likewise shard-local: a deep queue on one
    shard no longer widens every other shard's pass.
    """
    from repro.core.engine import pool_size, shard_local_plan

    eng = engine or DEFAULT_ENGINE
    goal = resolve_goal(objective, weights)
    n_shards = mesh.shape[axis]

    def wrapper(state: SimState, pool: PoolArg) -> Decision:
        pool = _engine_pool(pool)
        k = pool_size(pool)
        if k % n_shards:
            raise ValueError(
                f"pool size k={k} not divisible by {n_shards}-way "
                f"'{axis}' axis")
        plan = shard_local_plan(eng.plan(pool), n_shards)
        return _decide_fleet(eng, mesh, axis, state, pool, goal, plan)

    return wrapper


@_quiet_donation
@functools.partial(jax.jit,
                   static_argnames=("engine", "mesh", "axis", "plan"),
                   donate_argnames=("states",))
def _replay_block_sharded(engine: DrainEngine, mesh: Mesh, axis: str,
                          plan, states, arrival_t, true_rt, pool, valid):
    """One fixed-shape scenario block replayed under ``shard_map``:
    every leading (k = B·P) axis splits over ``axis``, each device
    drains its B/n_shards scenarios with the shard-local hoist plan and
    its own pass bound / elision / early-exit (no collectives inside
    the event loop — shards finish independently).  The scalar
    telemetry (``iters``/``pass_invocations``) is lifted to (1,) per
    shard so the stacked output carries one count per device; the
    streamer sums them.  ``states`` is donated — the per-block carry
    updates in place across the stream."""
    from repro.core.engine import _replay_impl, hoisted_orders
    from repro.launch.mesh import shard_map

    if plan is None:
        def local(states, arrival_t, true_rt, pool, valid):
            res, metrics = _replay_impl(engine, states, arrival_t,
                                        true_rt, pool, valid, plan)
            res = res._replace(
                iters=res.iters.reshape(1),
                pass_invocations=res.pass_invocations.reshape(1))
            return res, metrics

        return shard_map(local, mesh, in_specs=(P(axis),) * 5,
                         out_specs=P(axis))(states, arrival_t, true_rt,
                                            pool, valid)

    # Hoisting on: the static argsorts cross the shard boundary as a
    # sharded input (engine.hoisted_orders — jax 0.4 miscompiles them
    # when traced inside the shard_map body).  `plan` is shard-local
    # and periodic, so its n_shards-fold tile is the global plan and
    # P(axis) gives each device its own forks' rows.
    ever_q = jnp.isfinite(arrival_t) | (states.jobs.state == QUEUED)
    hoisted = hoisted_orders(states, pool, plan * mesh.shape[axis],
                             ever_q)

    def local(states, arrival_t, true_rt, pool, valid, hoist_shard):
        res, metrics = _replay_impl(engine, states, arrival_t, true_rt,
                                    pool, valid, plan,
                                    hoisted=hoist_shard)
        res = res._replace(
            iters=res.iters.reshape(1),
            pass_invocations=res.pass_invocations.reshape(1))
        return res, metrics

    return shard_map(local, mesh, in_specs=(P(axis),) * 6,
                     out_specs=P(axis))(states, arrival_t, true_rt,
                                        pool, valid, hoisted)


def _round_up(n: int, multiple: int) -> int:
    return -(-n // multiple) * multiple


def sharded_replay_grid(mesh: Mesh, axis: str = "data",
                        engine: Optional[DrainEngine] = None,
                        objective: ObjectiveLike = None, *,
                        weights: Optional[scoring.ScoreWeights] = None,
                        block_size: Optional[int] = None,
                        prefetch_depth: int = 2):
    """Fleet-scale replay: the SCENARIO axis of ``engine.replay_grid``
    sharded over ``axis`` of ``mesh`` and STREAMED in fixed-size blocks
    (DESIGN.md §9).

    The flat fork axis is f = s·P + p, so sharding the leading axis of
    every input by blocks keeps each scenario's P policy forks on one
    device — scenarios are the unit of partition, the natural layout
    for multi-host what-if farms (each host replays its own futures).

    **Block streaming** — ``block_size`` (scenarios per device step;
    rounded up to the axis size) bounds every device computation: an
    S=1024 × P=100 grid runs as a pipeline of identical (B·P, J)
    replays — ONE compiled shape, donated buffers — instead of one
    monolithic 102 400-fork allocation.  ``None`` keeps the single-shot
    behavior (one block of the whole set).  Any S works on any mesh:
    the scenario axis is padded internally to the block multiple with
    inert rows (``workload.pad_scenarios`` — born-drained forks that
    never touch real forks' dynamics) and padded rows are dropped
    before selection.

    **Host/device overlap** — with ``prefetch_depth > 0`` the host-side
    ingestion of block i+1 (slicing/padding — and, for iterable
    sources, whatever synthesis the iterable performs) runs on a
    background thread (``data.pipeline.prefetch``) while the device
    drains block i; ``prefetch_depth=0`` ingests inline and blocks on
    every device step (the ablation baseline).  The ingest thread is
    numpy-only by design: a jax dispatch there (e.g. the jitted
    ``replay_inputs`` tiling) blocks on the in-flight replay and
    re-serializes the pipeline, so the device conversion runs on the
    main thread between dispatches.  Results are bit-identical at any
    depth.

    **Shard-local hoisting** — the replay's hoist plan is periodic in P
    (one pool copy per scenario), so every shard's chunk is the same
    ``plan_P * (B / n_shards)``: each device hoists its own forks'
    static argsorts exactly as the local engine does (DESIGN.md §7),
    composing the compaction win with sharding bit-exactly.

    ``scenarios`` may be a ``workload.ScenarioSet`` or an ITERABLE of
    them (pre-cut blocks, e.g. generated on the fly — trace synthesis
    then overlaps with device compute too).  Iterable blocks share one
    job capacity J; each is padded up to the block size.

    Returns a function ``(scenarios, pool) -> ReplayOutcome`` with the
    same semantics as ``replay_grid``, including the per-objective
    ``costs``/``best`` selection; ``iters``/``pass_invocations`` on the
    raw result aggregate over (shard, block).
    """
    from repro.core.des import ReplayResult
    from repro.core.engine import (_shape_outcome, as_pool,
                                   grid_select_jit, pool_size,
                                   replay_inputs)
    from repro.cluster.workload import (ScenarioSet, pad_scenarios,
                                        slice_scenarios)
    from repro.data.pipeline import prefetch

    eng = engine or DEFAULT_ENGINE
    goal = resolve_goal(objective, weights)
    n_shards = mesh.shape[axis]

    def wrapper(scenarios, pool: PoolArg):
        pool = as_pool(_engine_pool(pool))
        Psz = pool_size(pool)
        plan_P = eng.plan(pool)          # per-scenario chunk (hoisting)

        if isinstance(scenarios, ScenarioSet):
            S_real = scenarios.n_scenarios
            B = _round_up(block_size or S_real, n_shards)
            raw = (slice_scenarios(scenarios, lo, min(lo + B, S_real))
                   for lo in range(0, S_real, B))
        else:
            raw = iter(scenarios)
            try:
                head = next(raw)
            except StopIteration:
                raise ValueError("no scenario blocks") from None
            B = _round_up(block_size or head.n_scenarios, n_shards)
            raw = itertools.chain([head], raw)
            S_real = None                # discovered while streaming

        plan_blk = (plan_P * (B // n_shards)
                    if plan_P is not None else None)
        n_reals: list = []

        def ingest():
            # numpy ONLY in this thread: jax dispatch (the jitted
            # tiling in replay_inputs) blocks on the in-flight replay,
            # which would serialize ingestion with device compute —
            # the conversion runs on the main thread below instead
            for blk in raw:
                n = blk.n_scenarios
                if n > B:
                    raise ValueError(
                        f"scenario block of {n} > block size {B}")
                n_reals.append(n)
                yield pad_scenarios(blk, B)

        stream = ingest()
        if prefetch_depth > 0:
            stream = prefetch(stream, depth=prefetch_depth)

        res_blocks, met_blocks = [], []
        for padded in stream:
            res, metrics = _replay_block_sharded(
                eng, mesh, axis, plan_blk,
                *replay_inputs(padded, pool))
            if prefetch_depth <= 0:
                jax.block_until_ready((res, metrics))
            n_keep = n_reals[len(res_blocks)] * Psz
            if n_keep != B * Psz:        # only partial blocks pay a trim
                trim = lambda x: x[:n_keep]
                res = res._replace(
                    state=jax.tree.map(trim, res.state),
                    events=trim(res.events),
                    deadlocked=trim(res.deadlocked))
                metrics = jax.tree.map(trim, metrics)
            res_blocks.append(res)
            met_blocks.append(metrics)
        if not res_blocks:
            raise ValueError("no scenario blocks")
        S_out = sum(n_reals)

        cat = (lambda *xs: xs[0] if len(xs) == 1
               else jnp.concatenate(xs, axis=0))
        res = ReplayResult(
            state=jax.tree.map(cat, *[r.state for r in res_blocks]),
            events=cat(*[r.events for r in res_blocks]),
            iters=sum(r.iters.sum() for r in res_blocks),
            deadlocked=cat(*[r.deadlocked for r in res_blocks]),
            pass_invocations=sum(r.pass_invocations.sum()
                                 for r in res_blocks))
        metrics = jax.tree.map(cat, *met_blocks)
        costs, best = grid_select_jit(goal, metrics, res.deadlocked, Psz)
        return _shape_outcome(res, metrics, (S_out, Psz), costs, best)

    return wrapper


@functools.partial(jax.jit,
                   static_argnames=("spec", "P", "B", "S"))
def _fan_block_inputs(submit, nodes, est, true_rt, valid, totals, pool,
                      spec, P, B, S, lo):
    """One fixed-shape fan block, expanded ON DEVICE: pseudo-scenarios
    ``g = lo .. lo+B`` (``g = s·F + φ``; ids past S·F are inert
    padding) perturbed from the shared base arrays and assembled into
    donatable (B·P)-fork replay inputs.  ``lo`` is a dynamic operand —
    every block reuses ONE compiled expansion."""
    from repro.core.engine import _assemble_replay_inputs
    from repro.core.fan import perturb_block
    g = lo + jnp.arange(B)
    rows = perturb_block(submit, nodes, est, true_rt, valid, totals,
                         spec, g, S)
    return _assemble_replay_inputs(*rows, pool, P)


def sharded_fan_grid(mesh: Mesh, axis: str = "data",
                     engine: Optional[DrainEngine] = None,
                     objective: ObjectiveLike = None, *,
                     fan=None,
                     block_size: Optional[int] = None):
    """Fleet-scale Monte-Carlo fans (DESIGN.md §§9–10): the
    ``engine.fan_grid`` pseudo-scenario axis (``g = s·F + φ``, G = S·F
    rows) sharded over ``axis`` of ``mesh`` and streamed in fixed-size
    blocks, exactly like ``sharded_replay_grid`` streams scenarios.

    The fan stacks on the PR-6 block machinery unchanged because fan
    members ARE pseudo-scenarios: hoist plans stay P-periodic
    (``plan_P · (B / n_shards)`` per shard), padding rows are inert,
    and ``_replay_block_sharded`` is reused as is.  What changes is
    ingestion: there is NO host ingest thread to overlap — each block
    is expanded on device from the one uploaded base (H2D stays O(1)
    in F), so blocks dispatch back-to-back and jax's async dispatch
    pipelines them.  Fan member draws are keyed per (s, φ)
    independently of the block cut, so any ``block_size`` is
    bit-identical to the one-shot ``fan_grid``.

    ``fan`` is a ``FanSpec`` (or bare int F); ``block_size`` counts
    pseudo-scenarios per device step (i.e. ``block_size // F`` base
    scenarios), rounded up to the axis size.  Returns a function
    ``(scenarios, pool) -> FanOutcome``.
    """
    from repro.core.des import ReplayResult
    from repro.core.engine import (FanOutcome, _scenario_arrays, as_pool,
                                   fan_select_jit, pool_size)
    from repro.core.fan import normalize_fan

    eng = engine or DEFAULT_ENGINE
    goal = resolve_goal(objective)
    spec = normalize_fan(fan if fan is not None else 1)
    n_shards = mesh.shape[axis]

    def wrapper(scenarios, pool: PoolArg) -> "FanOutcome":
        pool = as_pool(_engine_pool(pool))
        Psz = pool_size(pool)
        S = int(scenarios.total_nodes.shape[0])
        G = S * spec.n
        B = _round_up(block_size or G, n_shards)
        plan_P = eng.plan(pool)
        plan_blk = (plan_P * (B // n_shards)
                    if plan_P is not None else None)
        base = _scenario_arrays(scenarios)

        res_blocks, met_blocks = [], []
        for lo in range(0, G, B):
            inputs = _fan_block_inputs(*base, pool, spec, Psz, B, S,
                                       jnp.int32(lo))
            res, metrics = _replay_block_sharded(
                eng, mesh, axis, plan_blk, *inputs)
            n_keep = (min(lo + B, G) - lo) * Psz
            if n_keep != B * Psz:        # only the tail block pays a trim
                trim = lambda x: x[:n_keep]
                res = res._replace(
                    state=jax.tree.map(trim, res.state),
                    events=trim(res.events),
                    deadlocked=trim(res.deadlocked))
                metrics = jax.tree.map(trim, metrics)
            res_blocks.append(res)
            met_blocks.append(metrics)

        cat = (lambda *xs: xs[0] if len(xs) == 1
               else jnp.concatenate(xs, axis=0))
        res = ReplayResult(
            state=jax.tree.map(cat, *[r.state for r in res_blocks]),
            events=cat(*[r.events for r in res_blocks]),
            iters=sum(r.iters.sum() for r in res_blocks),
            deadlocked=cat(*[r.deadlocked for r in res_blocks]),
            pass_invocations=sum(r.pass_invocations.sum()
                                 for r in res_blocks))
        metrics = jax.tree.map(cat, *met_blocks)
        member, costs, best, ci, width = fan_select_jit(
            goal, metrics, res.deadlocked, spec.n, Psz)
        shape = (S, spec.n, Psz)
        rs = lambda x: x.reshape(shape + x.shape[1:])
        return FanOutcome(
            start_t=rs(res.state.jobs.start_t),
            end_t=rs(res.state.jobs.end_t),
            metrics=jax.tree.map(rs, metrics),
            deadlocked=rs(res.deadlocked),
            events=rs(res.events),
            result=res,
            member_costs=member,
            costs=costs,
            best=best,
            cost_ci=ci,
            fan_width=width,
        )

    return wrapper


def sharded_generation_costs(mesh: Mesh, axis: str = "data",
                             engine: Optional[DrainEngine] = None,
                             objective: ObjectiveLike = None, *,
                             fan=None,
                             block_size: Optional[int] = None,
                             prefetch_depth: int = 2):
    """Fleet-scale generation evaluation for the ``learn`` trainer:
    the sharded twin of ``engine.generation_costs``.  Returns a
    function ``(scenarios, pool) -> (S, P) costs`` — one candidate
    population riding the fork axis, streamed over the mesh via
    ``sharded_replay_grid`` (``fan=None``) or ``sharded_fan_grid``
    (FanSpec domain randomization), both bit-identical to the
    one-shot engine entry point."""
    if fan is None:
        run = sharded_replay_grid(mesh, axis, engine, objective,
                                  block_size=block_size,
                                  prefetch_depth=prefetch_depth)
    else:
        run = sharded_fan_grid(mesh, axis, engine, objective, fan=fan,
                               block_size=block_size)
    return lambda scenarios, pool: run(scenarios, pool).costs


@functools.partial(jax.jit,
                   static_argnames=("spec", "P", "B", "S", "lo", "width"))
def _race_block_inputs(submit, nodes, est, true_rt, valid, totals, pool,
                       spec, P, B, S, lo, width, blo):
    """One fixed-shape RACING-WINDOW block, expanded on device: window
    rows ``r = blo .. blo+B`` of the rung's ``S·width`` rectangle
    (``r = s·width + w`` ⇒ member ``φ = lo + w``; ids past S·width are
    inert padding).  ``lo``/``width`` are STATIC — the rung schedule is
    fixed, so each rung compiles once — while ``blo`` is a dynamic
    operand: all blocks within a rung share one compiled expansion."""
    from repro.core.engine import _assemble_replay_inputs
    from repro.core.fan import perturb_window
    r = blo + jnp.arange(B)
    rows = perturb_window(submit, nodes, est, true_rt, valid, totals,
                          spec, r, lo, width, S)
    return _assemble_replay_inputs(*rows, pool, P)


def sharded_race_grid(mesh: Mesh, axis: str = "data",
                      engine: Optional[DrainEngine] = None,
                      objective: ObjectiveLike = None, *,
                      race=None,
                      block_size: Optional[int] = None):
    """Fleet-scale adaptive racing (DESIGN.md §§9–11): each rung of the
    successive-halving race streams its ``S·width`` member-window rows
    through the PR-6 block machinery (``_replay_block_sharded``
    unchanged — window rows are pseudo-scenarios like any other), and
    the controller (``race.run_race``) eliminates/terminates between
    rungs exactly as the local ``race_grid`` does.

    Because window rows are keyed per (s, φ) independently of the
    block cut AND of the rung cut, any ``block_size`` on any mesh is
    bit-identical to the local race — which is itself member-bitwise
    the full ``fan_grid`` prefix (tests/test_race.py).  ``race`` is a
    ``RaceSpec`` / ``FanSpec`` / bare int F_max; ``block_size`` counts
    window rows per device step, rounded up to the axis size.  Returns
    a function ``(scenarios, pool) -> race.RaceOutcome``.
    """
    from repro.core.des import ReplayResult
    from repro.core.engine import (_index_pool, _scenario_arrays, as_pool,
                                   fan_select_jit, pool_size)
    from repro.core.race import normalize_race, run_race

    eng = engine or DEFAULT_ENGINE
    goal = resolve_goal(objective)
    spec = normalize_race(race if race is not None else 1)
    n_shards = mesh.shape[axis]

    def wrapper(scenarios, pool: PoolArg):
        pool_full = as_pool(_engine_pool(pool))
        Psz = pool_size(pool_full)
        S = int(scenarios.total_nodes.shape[0])
        base = _scenario_arrays(scenarios)
        sub_pools = {}
        passes = [0]

        def eval_window(active, lo, hi):
            key = tuple(int(i) for i in active)
            sub = sub_pools.get(key)
            if sub is None:
                sub = (pool_full if len(active) == Psz
                       else _index_pool(pool_full, jnp.asarray(active)))
                sub_pools[key] = sub
            Pa = pool_size(sub)
            width = hi - lo
            R = S * width                      # window rows this rung
            B = _round_up(block_size or R, n_shards)
            plan_P = eng.plan(sub)
            plan_blk = (plan_P * (B // n_shards)
                        if plan_P is not None else None)
            met_blocks, dead_blocks = [], []
            for blo in range(0, R, B):
                inputs = _race_block_inputs(*base, sub, spec.fan, Pa,
                                            B, S, lo, width,
                                            jnp.int32(blo))
                res, metrics = _replay_block_sharded(
                    eng, mesh, axis, plan_blk, *inputs)
                passes[0] += int(res.pass_invocations.sum())
                n_keep = (min(blo + B, R) - blo) * Pa
                if n_keep != B * Pa:     # only the tail block trims
                    trim = lambda x: x[:n_keep]
                    metrics = jax.tree.map(trim, metrics)
                    dead_blocks.append(trim(res.deadlocked))
                else:
                    dead_blocks.append(res.deadlocked)
                met_blocks.append(metrics)
            cat = (lambda *xs: xs[0] if len(xs) == 1
                   else jnp.concatenate(xs, axis=0))
            metrics = jax.tree.map(cat, *met_blocks)
            dead = cat(*dead_blocks)
            member, _, _, _, _ = fan_select_jit(
                goal, metrics, dead, width, Pa)
            return member

        out = run_race(spec, S, Psz, goal, eval_window)
        return out._replace(passes=passes[0])

    return wrapper


def paper_pool() -> jax.Array:
    from repro.core.policies import PAPER_POOL
    return jnp.asarray(PAPER_POOL, dtype=jnp.int32)


def pool_array(ids: Sequence[int]) -> jax.Array:
    """Thin adapter: legacy id pool in the CALLER's order.  Position is
    tie-break priority (``select_policy`` is an argmin with
    first-occurrence wins), so the order must be preserved — an earlier
    version sorted ids here, silently discarding custom tie-break
    orders.  ``policies.PolicyPool.from_ids`` lifts the same ids into
    the parametric space."""
    return jnp.asarray(list(ids), dtype=jnp.int32)
