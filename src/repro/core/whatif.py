"""Parallel what-if exploration (§3.3) — the paper's k simulator forks.

The paper forks k simulator processes (one per policy) sharing a common
database.  On TPU the natural equivalent is a *policy batch axis*: one
batched DES advanced in lock-step for all policies by the
``repro.core.engine.DrainEngine`` (DESIGN.md §3).  The snapshot is
shared (broadcast, never copied per policy) — the same "objects share a
common database, only carry event metadata" property, but in SPMD form.

A pool is a **``PolicyPool``** — a stacked parametric ``PolicySpec``
(family (k,), θ (k, P)) plus per-fork display names (DESIGN.md §5).
Every entry point also accepts a sweep-grammar string (``"paper"``,
``"wfp:a=1..5x5"``), a raw ``PolicySpec`` stack, or a legacy i32 id
vector (``pool_array`` is the thin adapter that builds one); ids flow
through the engine's bit-exact pre-parametric oracle path.

Every entry point takes ``objective=`` — the administrator-configured
optimization goal (``core.objective``, DESIGN.md §8) as an
``Objective`` or grammar string; the deprecated ``weights=`` kwarg
lifts to the bit-identical paper-score objective.

This module is the thin public API over the engine:

  * ``decide`` / ``decide_ensemble`` — one scheduling cycle on the
    default (or a caller-supplied) engine; ensemble members ride the
    same batch axis, so k * n_ens forks drain in ONE while_loop;
  * ``sharded_whatif`` — the fork axis of the batched engine sharded
    over a device mesh for pools of hundreds of forks (θ shards with
    the fork axis: a parameter sweep is just a longer, shardable pool);
  * ``decide_legacy_vmap`` — the pre-engine path (``jax.vmap`` over the
    scalar DES), kept as a regression oracle and as the baseline the
    overhead benchmark compares the batched engine against.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import scoring
from repro.core.des import drain_metrics, simulate_to_drain
from repro.core.engine import (DEFAULT_ENGINE, Decision, DrainEngine,
                               EnginePool)
from repro.core.objective import ObjectiveLike, resolve_goal
from repro.core.policies import (PolicyPool, PolicySpec, normalize_pool,
                                 parse_pool)
from repro.core.state import QUEUED, SimState

__all__ = [
    "Decision", "PolicyPool", "decide", "decide_ensemble",
    "decide_legacy_vmap", "sharded_whatif", "sharded_replay_grid",
    "paper_pool", "pool_array",
]

#: Anything the public decide functions take as a pool.
PoolArg = Union[PolicyPool, PolicySpec, str, jax.Array]


def _engine_pool(pool: PoolArg) -> EnginePool:
    """Unwrap to what the engine consumes: a PolicySpec stack or a
    legacy id vector (passed through untouched — the oracle path)."""
    if isinstance(pool, PolicyPool):
        return pool.spec
    if isinstance(pool, str):
        return parse_pool(pool).spec
    return pool  # PolicySpec stack or legacy id array


def decide(state: SimState, pool: PoolArg,
           objective: ObjectiveLike = None, *,
           weights: Optional[scoring.ScoreWeights] = None,
           engine: Optional[DrainEngine] = None) -> Decision:
    """One scheduling cycle: fork k sims, score, select, extract qrun set.

    ``pool`` is a ``PolicyPool`` / ``PolicySpec`` stack / grammar
    string / legacy i32 id vector, ordered by tie-break priority.
    ``objective`` is the administrator's goal (DESIGN.md §8): an
    ``Objective``, a grammar string (``"score"``, ``"avg_wait"``,
    ``"min:avg_wait@util>=0.85"``), or None for the paper score;
    ``weights=`` is the deprecated legacy spelling (lifted
    bit-identically with a DeprecationWarning).  Everything (all k
    drain simulations included) is a single XLA computation — the
    per-cycle overhead the paper reports as "a few seconds" is
    microseconds here (see benchmarks/overhead.py).
    """
    return (engine or DEFAULT_ENGINE).decide(
        state, _engine_pool(pool), objective, weights=weights)


def decide_ensemble(state: SimState, pool: PoolArg, key: jax.Array,
                    n_ens: int = 8, noise: float = 0.3,
                    objective: ObjectiveLike = None, *,
                    weights: Optional[scoring.ScoreWeights] = None,
                    engine: Optional[DrainEngine] = None) -> Decision:
    """Uncertainty-aware cycle (beyond paper).

    Each ensemble member rescales every job's estimate by a lognormal
    factor (sigma=``noise``) before simulating; the policy cost is the
    ensemble mean (under ``objective``, as in ``decide``).  The qrun
    set is taken from the unperturbed member so actions stay consistent
    with the mirror.  All k * n_ens forks ride one batch axis through
    one drain.
    """
    return (engine or DEFAULT_ENGINE).decide_ensemble(
        state, _engine_pool(pool), key, n_ens=n_ens, noise=noise,
        objective=objective, weights=weights)


# ----------------------------------------------------------------------
# Legacy path: vmap over the scalar DES (pre-engine).  Benchmark /
# regression oracle only — new code should use the engine.
# ----------------------------------------------------------------------

def _single_whatif(state: SimState, policy_id) -> tuple:
    eval_mask = state.jobs.state == QUEUED
    res = simulate_to_drain(state, policy_id)
    m = drain_metrics(res, eval_mask)
    return m, res.first_started, res.deadlocked


@functools.partial(jax.jit, static_argnames=("weights",))
def decide_legacy_vmap(state: SimState, pool: jax.Array,
                       weights: scoring.ScoreWeights = scoring.PAPER_WEIGHTS
                       ) -> Decision:
    metrics, first_started, dead = jax.vmap(
        _single_whatif, in_axes=(None, 0))(state, pool)
    costs = scoring.policy_cost(metrics, weights)
    costs = jnp.where(dead, jnp.inf, costs)
    best = scoring.select_policy(costs)
    return Decision(
        policy_index=best,
        costs=costs,
        run_mask=first_started[best],
        metrics=metrics,
        deadlocked=dead,
    )


# ----------------------------------------------------------------------
# Fleet scale: shard the fork axis of the batched engine.
# ----------------------------------------------------------------------

def sharded_whatif(mesh: Mesh, axis: str = "data",
                   engine: Optional[DrainEngine] = None,
                   objective: ObjectiveLike = None, *,
                   weights: Optional[scoring.ScoreWeights] = None):
    """Fleet-scale what-if: the fork (policy/ensemble) axis of the
    batched engine sharded over ``axis`` of ``mesh``.  Returns a jitted
    function with the same signature as ``decide`` whose pool size must
    be divisible by the axis size.  The snapshot is replicated (it is a
    few KB); only the fork axis is split, mirroring "k simulator copies
    sharing one database" at pod scale.

    The pool sharding is a PyTree prefix, so it applies equally to a
    legacy (k,) id vector and to a ``PolicySpec`` stack — for specs the
    θ matrix (k, P) is partitioned on its fork axis together with the
    family vector: a 128-point parameter sweep splits across devices
    exactly like 128 distinct policies.

    Static-key hoisting (DESIGN.md §7) is disabled on sharded paths:
    the hoist gather/scatter would regroup the fork axis across shards
    (cross-device collectives per event).  Dynamic pass bounds stay on
    — the rank-limit max is the same kind of lock-step all-reduce the
    loop condition already performs.  Results are bit-identical either
    way (tests assert sharded == local).
    """
    from repro.core.engine import _decide_impl  # the unjitted body

    eng = engine or DEFAULT_ENGINE
    goal = resolve_goal(objective, weights)
    pool_sharding = NamedSharding(mesh, P(axis))
    replicated = NamedSharding(mesh, P())

    @functools.partial(jax.jit,
                       in_shardings=(replicated, pool_sharding),
                       out_shardings=replicated)
    def decide_sharded(state: SimState, pool: EnginePool) -> Decision:
        return _decide_impl(eng, state, pool, goal)

    def wrapper(state: SimState, pool: PoolArg) -> Decision:
        return decide_sharded(state, _engine_pool(pool))

    return wrapper


def sharded_replay_grid(mesh: Mesh, axis: str = "data",
                        engine: Optional[DrainEngine] = None,
                        objective: ObjectiveLike = None, *,
                        weights: Optional[scoring.ScoreWeights] = None):
    """Fleet-scale replay: the SCENARIO axis of ``engine.replay_grid``
    sharded over ``axis`` of ``mesh`` (DESIGN.md §6).

    The flat fork axis is f = s·P + p, so sharding the leading axis of
    every input by blocks keeps each scenario's P policy forks on one
    device — scenarios are the unit of partition, the natural layout
    for multi-host what-if farms (each host replays its own futures).
    Requires the scenario count S to be divisible by the axis size.
    As with ``sharded_whatif``, static-key hoisting is disabled here
    (its fork-axis regrouping fights the sharding); dynamic bounds and
    pass elision stay on and results remain bit-identical.

    Returns a function ``(scenarios: workload.ScenarioSet, pool) ->
    ReplayOutcome`` with the same semantics as ``replay_grid``,
    including the per-objective ``costs``/``best`` selection (computed
    on the replicated metrics after the sharded replay — a handful of
    (S, P)-sized device ops).
    """
    from repro.core.engine import (_replay_impl, _shape_outcome, as_pool,
                                   grid_select, pool_size, replay_inputs)

    eng = engine or DEFAULT_ENGINE
    goal = resolve_goal(objective, weights)
    sharded = NamedSharding(mesh, P(axis))
    replicated = NamedSharding(mesh, P())
    n_shards = mesh.shape[axis]

    @functools.partial(jax.jit,
                       in_shardings=(sharded,) * 5,
                       out_shardings=replicated)
    def run(states, arrival_t, true_rt, pool, valid):
        return _replay_impl(eng, states, arrival_t, true_rt, pool, valid)

    def wrapper(scenarios, pool: PoolArg):
        pool = as_pool(_engine_pool(pool))
        S = int(scenarios.total_nodes.shape[0])
        if S % n_shards:
            raise ValueError(
                f"S={S} scenarios not divisible by {n_shards}-way "
                f"'{axis}' axis")
        res, metrics = run(*replay_inputs(scenarios, pool))
        costs, best = grid_select(goal, metrics, res.deadlocked,
                                  pool_size(pool))
        return _shape_outcome(res, metrics, (S, pool_size(pool)),
                              costs, best)

    return wrapper


def paper_pool() -> jax.Array:
    from repro.core.policies import PAPER_POOL
    return jnp.asarray(PAPER_POOL, dtype=jnp.int32)


def pool_array(ids: Sequence[int]) -> jax.Array:
    """Thin adapter: legacy id pool in the CALLER's order.  Position is
    tie-break priority (``select_policy`` is an argmin with
    first-occurrence wins), so the order must be preserved — an earlier
    version sorted ids here, silently discarding custom tie-break
    orders.  ``policies.PolicyPool.from_ids`` lifts the same ids into
    the parametric space."""
    return jnp.asarray(list(ids), dtype=jnp.int32)
