"""Parallel what-if exploration (§3.3) — the paper's k simulator forks.

The paper forks k simulator processes (one per policy) sharing a common
database.  On TPU the natural equivalent is a *policy batch axis*: one
vectorized DES advanced in lock-step for all policies via ``jax.vmap``.
The snapshot is shared (closed over, never copied per policy) — the
same "objects share a common database, only carry event metadata"
property, but in SPMD form.

Beyond the paper:
  * ensemble mode — each policy is simulated under ``n_ens`` sampled
    walltime-estimate perturbations (users overestimate; §3.2), and the
    policy cost is the ensemble mean: decisions become robust to
    estimate noise at zero extra latency (the ensemble rides the same
    batch axis);
  * ``sharded_whatif`` — shard_map over a device mesh for pools of
    hundreds of policies (fleet-scale twins).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import scoring
from repro.core.des import DrainMetrics, drain_metrics, simulate_to_drain
from repro.core.state import QUEUED, SimState


class Decision(NamedTuple):
    policy_index: jax.Array   # index into the pool (NOT the policy id)
    costs: jax.Array          # (k,) per-policy cost
    run_mask: jax.Array       # bool (max_jobs,) jobs to start now (qrun set)
    metrics: DrainMetrics     # (k,)-leading metrics for telemetry
    deadlocked: jax.Array     # (k,) bool


def _single_whatif(state: SimState, policy_id) -> tuple:
    eval_mask = state.jobs.state == QUEUED
    res = simulate_to_drain(state, policy_id)
    m = drain_metrics(res, eval_mask)
    return m, res.first_started, res.deadlocked


@functools.partial(jax.jit, static_argnames=("weights",))
def decide(state: SimState, pool: jax.Array,
           weights: scoring.ScoreWeights = scoring.PAPER_WEIGHTS) -> Decision:
    """One scheduling cycle: fork k sims, score, select, extract qrun set.

    ``pool`` is an i32 vector of policy ids ordered by tie-break
    priority.  Everything (k drain simulations included) is a single
    XLA computation — the per-cycle overhead the paper reports as "a
    few seconds" is microseconds here (see benchmarks/overhead.py).
    """
    metrics, first_started, dead = jax.vmap(
        _single_whatif, in_axes=(None, 0))(state, pool)
    costs = scoring.policy_cost(metrics, weights)
    costs = jnp.where(dead, jnp.inf, costs)
    best = scoring.select_policy(costs)
    return Decision(
        policy_index=best,
        costs=costs,
        run_mask=first_started[best],
        metrics=metrics,
        deadlocked=dead,
    )


@functools.partial(jax.jit, static_argnames=("weights", "n_ens", "noise"))
def decide_ensemble(state: SimState, pool: jax.Array, key: jax.Array,
                    n_ens: int = 8, noise: float = 0.3,
                    weights: scoring.ScoreWeights = scoring.PAPER_WEIGHTS,
                    ) -> Decision:
    """Uncertainty-aware cycle (beyond paper).

    Each ensemble member rescales every job's *remaining* estimate by a
    lognormal factor (sigma=``noise``) before simulating; the policy
    cost is the ensemble mean.  The qrun set is taken from the
    unperturbed member so actions stay consistent with the mirror.
    """
    k = pool.shape[0]

    def member(state_m, policy_id):
        return _single_whatif(state_m, policy_id)

    def perturbed_state(eps):
        jobs = state.jobs
        est = jobs.est_runtime * jnp.exp(noise * eps - 0.5 * noise * noise)
        return state._replace(jobs=jobs._replace(est_runtime=est))

    eps = jax.random.normal(key, (n_ens, state.jobs.capacity))
    eps = eps.at[0].set(0.0)  # member 0 = exact estimates
    states = jax.vmap(perturbed_state)(eps)

    metrics, first_started, dead = jax.vmap(
        jax.vmap(member, in_axes=(None, 0)), in_axes=(0, None))(states, pool)
    # metrics: (n_ens, k); reduce over ensemble
    mean_metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), metrics)
    costs = scoring.policy_cost(mean_metrics, weights)
    costs = jnp.where(jnp.any(dead, axis=0), jnp.inf, costs)
    best = scoring.select_policy(costs)
    return Decision(
        policy_index=best,
        costs=costs,
        run_mask=first_started[0, best],
        metrics=mean_metrics,
        deadlocked=jnp.any(dead, axis=0),
    )


def sharded_whatif(mesh: Mesh, axis: str = "data"):
    """Fleet-scale what-if: the policy/ensemble axis sharded over
    ``axis`` of ``mesh``.  Returns a jitted function with the same
    signature as ``decide`` whose pool must be divisible by the axis
    size.  The snapshot is replicated (it is a few KB); only the policy
    axis is split, mirroring "k simulator copies sharing one database"
    at pod scale.
    """
    pool_sharding = NamedSharding(mesh, P(axis))
    replicated = NamedSharding(mesh, P())

    @functools.partial(jax.jit,
                       in_shardings=(replicated, pool_sharding),
                       out_shardings=replicated)
    def decide_sharded(state: SimState, pool: jax.Array) -> Decision:
        metrics, first_started, dead = jax.vmap(
            _single_whatif, in_axes=(None, 0))(state, pool)
        costs = scoring.policy_cost(metrics)
        costs = jnp.where(dead, jnp.inf, costs)
        best = scoring.select_policy(costs)
        return Decision(best, costs, first_started[best], metrics, dead)

    return decide_sharded


def paper_pool() -> jax.Array:
    from repro.core.policies import PAPER_POOL
    return jnp.asarray(PAPER_POOL, dtype=jnp.int32)


def pool_array(ids: Sequence[int]) -> jax.Array:
    return jnp.asarray(sorted(ids), dtype=jnp.int32)
