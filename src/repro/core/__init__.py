"""SchedTwin core: the paper's contribution as composable JAX modules."""
from repro.core.events import Event, EventBus, EventKind
from repro.core.state import (DONE, INVALID, QUEUED, RUNNING, JobTable,
                              SimState, empty_jobs, empty_state)
from repro.core.policies import (EXTENDED_POOL, FCFS, PAPER_POOL, SJF, WFP,
                                 policy_name, priority_key)
from repro.core.backfill import PassResult, schedule_pass
from repro.core.des import (DrainMetrics, DrainResult, drain_metrics,
                            simulate_to_drain)
from repro.core.scoring import (PAPER_WEIGHTS, ScoreWeights, policy_cost,
                                radar_area, radar_normalize, radar_report,
                                select_policy)
from repro.core.whatif import Decision, decide, decide_ensemble, sharded_whatif
from repro.core.twin import SchedTwin

__all__ = [
    "Event", "EventBus", "EventKind",
    "JobTable", "SimState", "empty_jobs", "empty_state",
    "INVALID", "QUEUED", "RUNNING", "DONE",
    "WFP", "FCFS", "SJF", "PAPER_POOL", "EXTENDED_POOL",
    "policy_name", "priority_key",
    "PassResult", "schedule_pass",
    "DrainResult", "DrainMetrics", "simulate_to_drain", "drain_metrics",
    "ScoreWeights", "PAPER_WEIGHTS", "policy_cost", "select_policy",
    "radar_area", "radar_normalize", "radar_report",
    "Decision", "decide", "decide_ensemble", "sharded_whatif",
    "SchedTwin",
]
