"""SchedTwin core: the paper's contribution as composable JAX modules."""
from repro.core.events import (BusReadError, DeadLetter, Event, EventBus,
                               EventKind, SeqTracker, read_with_retry,
                               validate_event)
from repro.core.guard import LEVEL_NAMES, DeadlineGuard, GuardSpec
from repro.core.state import (DONE, INVALID, QUEUED, RUNNING, JobTable,
                              SimState, empty_jobs, empty_state)
from repro.core.policies import (EXTENDED_POOL, FAM_EXP, FAM_LIN, FAM_WFP,
                                 FCFS, PAPER_POOL, SJF, WFP, PolicyPool,
                                 PolicySpec, batched_priority_keys,
                                 exp_spec, job_features, linear_spec,
                                 normalize_pool, parse_pool, policy_name,
                                 priority_key, priority_key_spec,
                                 static_spec, wfp_spec)
from repro.core.backfill import (PassResult, priority_order, schedule_pass,
                                 schedule_pass_with_order)
from repro.core.des import (DrainMetrics, DrainResult, ReplayResult,
                            broadcast_state, drain_metrics,
                            simulate_replay_batched, simulate_to_drain,
                            simulate_to_drain_batched, state_metrics)
from repro.core.scoring import (PAPER_WEIGHTS, ScoreWeights, policy_cost,
                                radar_area, radar_normalize, radar_report,
                                select_policy)
from repro.core.objective import (DEFAULT_OBJECTIVE, Constrained,
                                  Constraint, Lexicographic, Objective,
                                  PaperScore, Weighted, metrics_from_rows,
                                  normalize_objective, parse_objective,
                                  register_objective,
                                  registered_objectives, report_costs,
                                  resolve_goal, validate_objective)
from repro.core.engine import (DEFAULT_ENGINE, PASS_BACKENDS, DrainEngine,
                               FanOutcome, ReplayOutcome, register_backend)
from repro.core.fan import FanSpec, normalize_fan, pruned_fan_grid
from repro.core.race import (RaceOutcome, RaceSpec, decide_race,
                             normalize_race, race_grid)
from repro.core.whatif import (Decision, decide, decide_ensemble,
                               decide_legacy_vmap, pool_array,
                               sharded_fan_grid, sharded_race_grid,
                               sharded_replay_grid, sharded_whatif)
from repro.core.twin import SchedTwin

__all__ = [
    "Event", "EventBus", "EventKind",
    "BusReadError", "DeadLetter", "SeqTracker", "read_with_retry",
    "validate_event",
    "GuardSpec", "DeadlineGuard", "LEVEL_NAMES",
    "JobTable", "SimState", "empty_jobs", "empty_state",
    "INVALID", "QUEUED", "RUNNING", "DONE",
    "WFP", "FCFS", "SJF", "PAPER_POOL", "EXTENDED_POOL",
    "policy_name", "priority_key",
    "PolicySpec", "PolicyPool", "FAM_LIN", "FAM_WFP", "FAM_EXP",
    "priority_key_spec", "batched_priority_keys", "job_features",
    "linear_spec", "wfp_spec", "exp_spec", "static_spec",
    "parse_pool", "normalize_pool",
    "PassResult", "priority_order", "schedule_pass",
    "schedule_pass_with_order",
    "DrainResult", "DrainMetrics", "simulate_to_drain",
    "simulate_to_drain_batched", "broadcast_state", "drain_metrics",
    "ReplayResult", "simulate_replay_batched", "state_metrics",
    "ScoreWeights", "PAPER_WEIGHTS", "policy_cost", "select_policy",
    "radar_area", "radar_normalize", "radar_report",
    "Objective", "PaperScore", "Weighted", "Lexicographic",
    "Constraint", "Constrained", "DEFAULT_OBJECTIVE",
    "parse_objective", "validate_objective", "normalize_objective",
    "resolve_goal", "register_objective", "registered_objectives",
    "metrics_from_rows", "report_costs",
    "DrainEngine", "DEFAULT_ENGINE", "PASS_BACKENDS", "register_backend",
    "ReplayOutcome", "FanOutcome",
    "FanSpec", "normalize_fan", "pruned_fan_grid",
    "RaceSpec", "RaceOutcome", "normalize_race", "race_grid",
    "decide_race",
    "Decision", "decide", "decide_ensemble", "decide_legacy_vmap",
    "pool_array", "sharded_whatif", "sharded_replay_grid",
    "sharded_fan_grid", "sharded_race_grid",
    "SchedTwin",
]
