"""Synchronization stage (§3.2) — keep the mirror consistent with the
physical scheduler.

Event handling mirrors the paper's block ④:
  * RUNJOB  -> insert predicted end event (start + user estimate) and
               exit immediately (run events imply no new scheduling
               opportunity);
  * JOBOBIT -> pull back / push forward the predicted end to the actual
               completion time (④A) and trigger a scheduling cycle;
  * QUEUEJOB-> add the job to the wait queue and trigger a cycle;
  * NODEFAIL/NODEUP -> resize capacity, requeue victims, trigger a
               cycle (beyond paper: fault tolerance / elasticity).

``resync_free_nodes`` reproduces the paper's "synchronize node
availability using command-line tools": the mirror's free-node count is
overwritten from the authoritative source (pbsnodes equivalent) rather
than trusted from event replay — this makes the twin self-healing if an
event was dropped.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core.events import Event, EventKind
from repro.core.state import (SimState, add_job, end_job, requeue_job,
                              resize_cluster, start_job)


def apply_event(state: SimState, ev: Event) -> Tuple[SimState, bool]:
    """Returns (new mirror state, needs_decision_cycle)."""
    if ev.kind == EventKind.QUEUEJOB:
        state = add_job(
            state, ev.job_id,
            submit_t=jnp.float32(ev.time),
            nodes=jnp.int32(int(ev.payload["nodes"])),
            est_runtime=jnp.float32(ev.payload["est_runtime"]),
        )
        return state, True

    if ev.kind == EventKind.RUNJOB:
        # Predicted end event enters the virtual horizon; no cycle (§3.2).
        state = start_job(state, ev.job_id, jnp.float32(ev.time))
        return state, False

    if ev.kind == EventKind.JOBOBIT:
        # ④A pull-back (early finish) or push-forward (cleanup delay):
        # the predicted end is replaced with the actual one.
        state = end_job(state, ev.job_id, jnp.float32(ev.time))
        return state, True

    if ev.kind == EventKind.NODEFAIL:
        state = resize_cluster(state, -jnp.int32(int(ev.payload["nodes"])))
        victim = int(ev.payload.get("victim_job", -1))
        if victim >= 0:
            state = requeue_job(state, victim, jnp.float32(ev.time))
        state = state._replace(now=jnp.maximum(state.now, jnp.float32(ev.time)))
        return state, True

    if ev.kind == EventKind.NODEUP:
        state = resize_cluster(state, jnp.int32(int(ev.payload["nodes"])))
        state = state._replace(now=jnp.maximum(state.now, jnp.float32(ev.time)))
        return state, True

    raise ValueError(f"unknown event kind: {ev.kind}")


def resync_free_nodes(state: SimState, authoritative_free: int) -> SimState:
    """Overwrite mirror free-node count from the physical system."""
    return state._replace(free_nodes=jnp.int32(authoritative_free))
