"""Synchronization stage (§3.2) — keep the mirror consistent with the
physical scheduler.

Event handling mirrors the paper's block ④:
  * RUNJOB  -> insert predicted end event (start + user estimate) and
               exit immediately (run events imply no new scheduling
               opportunity);
  * JOBOBIT -> pull back / push forward the predicted end to the actual
               completion time (④A) and trigger a scheduling cycle;
  * QUEUEJOB-> add the job to the wait queue and trigger a cycle;
  * NODEFAIL/NODEUP -> resize capacity, requeue victims, trigger a
               cycle (beyond paper: fault tolerance / elasticity).

``resync_free_nodes`` reproduces the paper's "synchronize node
availability using command-line tools": the mirror's free-node count is
overwritten from the authoritative source (pbsnodes equivalent) rather
than trusted from event replay — this makes the twin self-healing if an
event was dropped.  ``resync_jobs`` is the job-table analogue (qstat
equivalent): the whole mirror job table is reconciled from an
authoritative probe, healing drops that per-event logic can never see
(a lost QUEUEJOB leaves the twin unaware the job exists at all).

Hardened ingestion (DESIGN.md §12): ``apply_event(..., idempotent=
True)`` guards every handler on the job's CURRENT mirror state, so
duplicate and out-of-order deliveries (which the bus-level
``SeqTracker`` classifies but cannot repair) degrade to monotone
fill-ins instead of corrupting the free-node accounting — a RUNJOB
landing after its JOBOBIT only backfills ``start_t``; a JOBOBIT whose
RUNJOB never arrived marks the job DONE without freeing nodes it never
took.  As long as each job's own lifecycle order is preserved, any
cross-job interleaving of deliveries yields the identical final mirror
(the hypothesis property in tests/test_resilience.py).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core.events import Event, EventKind
from repro.core.state import (DONE, INVALID, QUEUED, RUNNING, TIME_NONE,
                              JobTable, SimState, add_job, end_job,
                              requeue_job, resize_cluster, start_job)


def apply_event(state: SimState, ev: Event,
                idempotent: bool = False) -> Tuple[SimState, bool]:
    """Returns (new mirror state, needs_decision_cycle)."""
    if idempotent and ev.kind in (EventKind.QUEUEJOB, EventKind.RUNJOB,
                                  EventKind.JOBOBIT):
        return _apply_job_event_idempotent(state, ev)
    if ev.kind == EventKind.QUEUEJOB:
        state = add_job(
            state, ev.job_id,
            submit_t=jnp.float32(ev.time),
            nodes=jnp.int32(int(ev.payload["nodes"])),
            est_runtime=jnp.float32(ev.payload["est_runtime"]),
        )
        return state, True

    if ev.kind == EventKind.RUNJOB:
        # Predicted end event enters the virtual horizon; no cycle (§3.2).
        state = start_job(state, ev.job_id, jnp.float32(ev.time))
        return state, False

    if ev.kind == EventKind.JOBOBIT:
        # ④A pull-back (early finish) or push-forward (cleanup delay):
        # the predicted end is replaced with the actual one.
        state = end_job(state, ev.job_id, jnp.float32(ev.time))
        return state, True

    if ev.kind == EventKind.NODEFAIL:
        state = resize_cluster(state, -jnp.int32(int(ev.payload["nodes"])))
        victim = int(ev.payload.get("victim_job", -1))
        if victim >= 0:
            state = requeue_job(state, victim, jnp.float32(ev.time))
        state = state._replace(now=jnp.maximum(state.now, jnp.float32(ev.time)))
        return state, True

    if ev.kind == EventKind.NODEUP:
        state = resize_cluster(state, jnp.int32(int(ev.payload["nodes"])))
        state = state._replace(now=jnp.maximum(state.now, jnp.float32(ev.time)))
        return state, True

    raise ValueError(f"unknown event kind: {ev.kind}")


def _apply_job_event_idempotent(state: SimState,
                                ev: Event) -> Tuple[SimState, bool]:
    """State-guarded job-event handlers: each transition fires only from
    the lifecycle state it is valid from, so re-delivery is a no-op and
    a late straggler can only FILL IN what it knows (never re-run a
    resource effect).  One host-side state read per event — the same
    host-driven granularity as the normal path."""
    cur = int(state.jobs.state[ev.job_id])

    if ev.kind == EventKind.QUEUEJOB:
        if cur != INVALID:          # already known (duplicate / late)
            return state, False
        state = add_job(
            state, ev.job_id,
            submit_t=jnp.float32(ev.time),
            nodes=jnp.int32(int(ev.payload["nodes"])),
            est_runtime=jnp.float32(ev.payload["est_runtime"]),
        )
        return state, True

    if ev.kind == EventKind.RUNJOB:
        if cur == QUEUED:           # the one valid transition
            return start_job(state, ev.job_id, jnp.float32(ev.time)), False
        if cur == DONE:             # arrived after its JOBOBIT: backfill
            jobs = state.jobs      # start_t only — no resource effect
            jobs = jobs._replace(
                start_t=jobs.start_t.at[ev.job_id].set(
                    jnp.float32(ev.time)))
            return state._replace(jobs=jobs), False
        return state, False         # RUNNING duplicate / unknown job

    # EventKind.JOBOBIT
    if cur == RUNNING:              # the one valid transition
        return end_job(state, ev.job_id, jnp.float32(ev.time)), True
    if cur == QUEUED:
        # RUNJOB never arrived: the job is over, but this mirror never
        # charged its nodes — mark DONE without freeing anything.
        jobs = state.jobs
        jobs = jobs._replace(
            end_t=jobs.end_t.at[ev.job_id].set(jnp.float32(ev.time)),
            state=jobs.state.at[ev.job_id].set(DONE),
        )
        return state._replace(
            jobs=jobs,
            now=jnp.maximum(state.now, jnp.float32(ev.time))), True
    return state, False              # DONE duplicate / unknown job


def resync_free_nodes(state: SimState, authoritative_free: int) -> SimState:
    """Overwrite mirror free-node count from the physical system."""
    return state._replace(free_nodes=jnp.int32(authoritative_free))


def resync_jobs(state: SimState, view: dict) -> SimState:
    """Full job-table reconcile from an authoritative probe (the qstat
    analogue of ``resync_free_nodes``) — the heal of last resort when
    the stream has LOST events the idempotent handlers cannot repair
    (above all a dropped QUEUEJOB: the twin otherwise never learns the
    job exists and can never feed it to ``qrun``).

    ``view`` is ``ClusterEmulator.jobs_view()`` (or a real qstat
    adapter): per-slot ``submit_t``/``nodes``/``est_runtime``/
    ``start_t``/``end_t``/``state`` plus ``total_nodes`` and
    ``free_nodes`` scalars.  The §3.2 estimate asymmetry is preserved —
    running jobs get predicted ends ``start + estimate`` exactly as a
    replayed RUNJOB would; only DONE jobs carry their actual end."""
    submit = jnp.asarray(view["submit_t"], jnp.float32)
    nodes = jnp.asarray(view["nodes"], jnp.int32)
    est = jnp.asarray(view["est_runtime"], jnp.float32)
    st = jnp.asarray(view["state"], jnp.int32)
    start = jnp.asarray(view["start_t"], jnp.float32)
    end = jnp.asarray(view["end_t"], jnp.float32)
    pred_end = jnp.where(st == RUNNING, start + est, end)
    none = jnp.float32(TIME_NONE)
    jobs = JobTable(
        submit_t=jnp.where(st != INVALID, submit, none),
        nodes=jnp.where(st != INVALID, nodes, 0),
        est_runtime=jnp.where(st != INVALID, est, 0.0),
        start_t=jnp.where(st == QUEUED, none,
                          jnp.where(st != INVALID, start, none)),
        end_t=jnp.where((st == RUNNING) | (st == DONE), pred_end, none),
        state=st,
    )
    return state._replace(
        jobs=jobs,
        free_nodes=jnp.int32(int(view["free_nodes"])),
        total_nodes=jnp.int32(int(view["total_nodes"])),
    )
