"""Event streaming layer.

The paper streams PBS hook events (``queuejob``, ``runjob``, ``jobobit``)
through a Redis stream: the scheduler is the producer, SchedTwin the
consumer.  This module provides an in-process event bus with the same
stream semantics (append-only log, independent consumer offsets, replay)
so the twin's consumption logic is identical whether the producer is our
cluster emulator or a real scheduler hook.

Events are plain host-side records — they cross the host/accelerator
boundary only when the twin synchronizes its JAX-side mirror state.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import threading
from typing import Callable, Dict, Iterator, List, Optional


class EventKind(enum.IntEnum):
    """PBS-hook-equivalent event kinds (§3.1 of the paper)."""

    QUEUEJOB = 0   # job submitted  (paper: hollow triangle)
    RUNJOB = 1     # job started    (paper: half triangle)
    JOBOBIT = 2    # job completed  (paper: filled triangle)
    NODEFAIL = 3   # node(s) failed           (beyond paper: fault tolerance)
    NODEUP = 4     # node(s) recovered/added  (beyond paper: elasticity)


@dataclasses.dataclass(frozen=True)
class Event:
    """A single scheduler event.

    ``time`` is physical-system time in seconds.  ``job_id`` is the dense
    job-slot index assigned at submission (also the twin's array slot).
    ``payload`` carries kind-specific metadata (job size, walltimes, node
    counts for NODEFAIL/NODEUP, ...).
    """

    kind: EventKind
    time: float
    job_id: int = -1
    payload: Dict[str, float] = dataclasses.field(default_factory=dict)
    seq: int = -1  # assigned by the bus on publish


class EventBus:
    """Append-only event log with per-consumer offsets (Redis-stream-like).

    The bus is deliberately synchronous and deterministic: tests and the
    co-simulation loop rely on replayable ordering.  A Redis-backed
    implementation would only need to reimplement ``publish`` / ``read``.
    """

    def __init__(self) -> None:
        self._log: List[Event] = []
        self._offsets: Dict[str, int] = {}
        self._seq = itertools.count()
        self._lock = threading.Lock()
        self._subscribers: List[Callable[[Event], None]] = []

    # -- producer side -------------------------------------------------
    def publish(self, event: Event) -> Event:
        with self._lock:
            stamped = dataclasses.replace(event, seq=next(self._seq))
            self._log.append(stamped)
        for cb in self._subscribers:
            cb(stamped)
        return stamped

    # -- consumer side -------------------------------------------------
    def read(self, consumer: str, max_events: Optional[int] = None) -> List[Event]:
        """Read new events for ``consumer`` and advance its offset."""
        with self._lock:
            start = self._offsets.get(consumer, 0)
            end = len(self._log) if max_events is None else min(
                len(self._log), start + max_events)
            out = self._log[start:end]
            self._offsets[consumer] = end
        return out

    def subscribe(self, callback: Callable[[Event], None]) -> None:
        """Push-mode delivery (used by the co-simulation loop)."""
        self._subscribers.append(callback)

    @property
    def has_listeners(self) -> bool:
        """Anyone push-subscribed or pull-reading this bus — producers
        that cannot stream (the emulator's ``fast=True`` replay) must
        refuse rather than silently starve them."""
        return bool(self._subscribers) or bool(self._offsets)

    def replay(self) -> Iterator[Event]:
        """Full-log replay (recovery after a twin restart)."""
        return iter(list(self._log))

    def __len__(self) -> int:
        return len(self._log)

    # -- recovery ------------------------------------------------------
    def snapshot_offsets(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._offsets)

    def restore_offsets(self, offsets: Dict[str, int]) -> None:
        with self._lock:
            self._offsets.update(offsets)
