"""Event streaming layer.

The paper streams PBS hook events (``queuejob``, ``runjob``, ``jobobit``)
through a Redis stream: the scheduler is the producer, SchedTwin the
consumer.  This module provides an in-process event bus with the same
stream semantics (append-only log, independent consumer offsets, replay)
so the twin's consumption logic is identical whether the producer is our
cluster emulator or a real scheduler hook.

Events are plain host-side records — they cross the host/accelerator
boundary only when the twin synchronizes its JAX-side mirror state.

Resilience layer (DESIGN.md §12): real producers misbehave, so this
module also carries the stream-sanitization primitives the hardened
twin pump is built from — ``validate_event`` (malformed-event triage
for the dead-letter queue), ``SeqTracker`` (duplicate / out-of-order /
gap classification against the per-consumer ``seq`` stamps, with a
bounded reorder window so permanently dropped events are eventually
declared lost instead of pending forever), ``read_with_retry``
(bounded exponential backoff over transient ``BusReadError``), and
subscriber isolation in ``publish`` (a raising callback is counted in
``EventBus.health()`` instead of propagating into the producer).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import math
import threading
import time
from typing import (Callable, Dict, Iterator, List, NamedTuple, Optional,
                    Set)


class EventKind(enum.IntEnum):
    """PBS-hook-equivalent event kinds (§3.1 of the paper)."""

    QUEUEJOB = 0   # job submitted  (paper: hollow triangle)
    RUNJOB = 1     # job started    (paper: half triangle)
    JOBOBIT = 2    # job completed  (paper: filled triangle)
    NODEFAIL = 3   # node(s) failed           (beyond paper: fault tolerance)
    NODEUP = 4     # node(s) recovered/added  (beyond paper: elasticity)


@dataclasses.dataclass(frozen=True)
class Event:
    """A single scheduler event.

    ``time`` is physical-system time in seconds.  ``job_id`` is the dense
    job-slot index assigned at submission (also the twin's array slot).
    ``payload`` carries kind-specific metadata (job size, walltimes, node
    counts for NODEFAIL/NODEUP, ...).
    """

    kind: EventKind
    time: float
    job_id: int = -1
    payload: Dict[str, float] = dataclasses.field(default_factory=dict)
    seq: int = -1  # assigned by the bus on publish

    # -- snapshot serialization (checkpoint extra is JSON) -------------
    def to_dict(self) -> Dict:
        return {"kind": int(self.kind), "time": float(self.time),
                "job_id": int(self.job_id),
                "payload": {str(k): float(v)
                            for k, v in self.payload.items()},
                "seq": int(self.seq)}

    @classmethod
    def from_dict(cls, d: Dict) -> "Event":
        kind = int(d["kind"])
        try:
            kind = EventKind(kind)
        except ValueError:
            pass  # quarantined (corrupted) events carry unknown kinds
        return cls(kind=kind, time=float(d["time"]),
                   job_id=int(d.get("job_id", -1)),
                   payload=dict(d.get("payload", {})),
                   seq=int(d.get("seq", -1)))


# ----------------------------------------------------------------------
# Malformed-event triage (the dead-letter queue's gatekeeper).
# ----------------------------------------------------------------------

_JOB_KINDS = (EventKind.QUEUEJOB, EventKind.RUNJOB, EventKind.JOBOBIT)
_NODE_KINDS = (EventKind.NODEFAIL, EventKind.NODEUP)


def validate_event(ev, max_jobs: Optional[int] = None) -> Optional[str]:
    """Triage one event BEFORE it reaches ``sync.apply_event``: returns
    ``None`` for a well-formed event, else a short reason string the
    dead-letter queue records.  Checks are the corruption modes a real
    hook stream exhibits (and ``cluster.chaos`` injects): unknown kind,
    non-finite/negative time, job events without a valid ``job_id``
    (out of the mirror's slot range when ``max_jobs`` is given), and
    kind-specific payload fields that are missing, non-numeric,
    non-finite, or out of range."""
    try:
        kind = EventKind(ev.kind)
    except (ValueError, TypeError):
        return f"unknown kind {ev.kind!r}"
    t = ev.time
    if not isinstance(t, (int, float)) or not math.isfinite(t) or t < 0.0:
        return f"bad time {t!r}"
    if kind in _JOB_KINDS:
        jid = ev.job_id
        if not isinstance(jid, int) or jid < 0:
            return f"bad job_id {jid!r}"
        if max_jobs is not None and jid >= max_jobs:
            return f"job_id {jid} out of range (max_jobs={max_jobs})"
    required = {EventKind.QUEUEJOB: ("nodes", "est_runtime"),
                EventKind.NODEFAIL: ("nodes",),
                EventKind.NODEUP: ("nodes",)}.get(kind, ())
    for field in required:
        v = ev.payload.get(field)
        if not isinstance(v, (int, float)) or not math.isfinite(v):
            return f"bad payload {field}={v!r}"
        if v < 0.0 or (kind == EventKind.QUEUEJOB and field == "nodes"
                       and v < 1.0):
            return f"bad payload {field}={v!r}"
    return None


class DeadLetter(NamedTuple):
    """One quarantined event + why it was rejected."""
    event: Event
    reason: str


# ----------------------------------------------------------------------
# Sequence tracking: duplicate / reorder / gap classification.
# ----------------------------------------------------------------------

class SeqObservation(NamedTuple):
    """What ``SeqTracker.observe`` concluded about one delivery.
    ``status`` ∈ {'new', 'duplicate', 'reordered'}; ``new_gaps`` counts
    seqs newly detected as missing (holes opened by a jump past the
    high-water mark); ``newly_lost`` counts holes abandoned this
    observation because they aged past the reorder window (the stream
    will never heal them — resync territory)."""
    status: str
    new_gaps: int
    newly_lost: int


class SeqTracker:
    """Classify per-consumer ``seq`` stamps under duplication, reordering
    and loss, in O(pending holes) memory.

    Invariant: every seq < ``max_seen`` is either APPLIED (seen),
    PENDING (in ``holes`` — expected to arrive late within
    ``reorder_window`` of the high-water mark), or LOST (was a hole,
    aged out).  A delivery is a *duplicate* iff its seq was already
    applied or declared lost, *reordered* iff it fills a pending hole,
    *new* otherwise.  The bounded window is what keeps a permanently
    dropped seq from pinning memory and from deferring the
    loss-triggered resync forever."""

    def __init__(self, reorder_window: int = 64):
        if reorder_window < 1:
            raise ValueError("reorder_window must be >= 1")
        self.window = int(reorder_window)
        self.max_seen = -1
        self.holes: Set[int] = set()
        self.lost: Set[int] = set()

    def observe(self, seq: int) -> SeqObservation:
        if seq <= self.max_seen:
            if seq in self.holes:
                self.holes.discard(seq)
                return SeqObservation("reordered", 0, self._age_out())
            return SeqObservation("duplicate", 0, self._age_out())
        gaps = range(self.max_seen + 1, seq)
        self.holes.update(gaps)
        self.max_seen = seq
        return SeqObservation("new", len(gaps), self._age_out())

    def flush(self) -> int:
        """Declare every pending hole lost (end-of-stream: nothing can
        fill them anymore).  Returns how many were newly declared."""
        n = len(self.holes)
        self.lost |= self.holes
        self.holes = set()
        return n

    def _age_out(self) -> int:
        horizon = self.max_seen - self.window
        aged = {h for h in self.holes if h < horizon}
        self.holes -= aged
        self.lost |= aged
        return len(aged)

    # -- snapshot serialization ----------------------------------------
    def to_dict(self) -> Dict:
        return {"window": self.window, "max_seen": self.max_seen,
                "holes": sorted(self.holes), "lost": sorted(self.lost)}

    @classmethod
    def from_dict(cls, d: Dict) -> "SeqTracker":
        t = cls(reorder_window=int(d["window"]))
        t.max_seen = int(d["max_seen"])
        t.holes = {int(h) for h in d.get("holes", [])}
        t.lost = {int(h) for h in d.get("lost", [])}
        return t


# ----------------------------------------------------------------------
# Bounded retry over transient read failures.
# ----------------------------------------------------------------------

class BusReadError(RuntimeError):
    """A transient bus read failure (network blip, Redis timeout — or
    ``cluster.chaos`` injecting one).  Retryable."""


def read_with_retry(bus, consumer: str,
                    max_events: Optional[int] = None, *,
                    retries: int = 3, backoff_s: float = 0.01,
                    sleep: Callable[[float], None] = time.sleep,
                    on_retry: Optional[Callable[[int, Exception], None]]
                    = None) -> List[Event]:
    """``bus.read`` with bounded exponential backoff over
    ``BusReadError``: up to ``retries`` re-reads, sleeping
    ``backoff_s · 2^attempt`` between them (injectable ``sleep`` keeps
    tests and the chaos benchmark instant).  ``on_retry(attempt, exc)``
    fires per retry so the twin can count them.  Exhausting every
    retry re-raises the last error — the caller decides whether that
    aborts the pump or just skips a beat."""
    last: Optional[Exception] = None
    for attempt in range(retries + 1):
        try:
            return bus.read(consumer, max_events)
        except BusReadError as exc:
            last = exc
            if attempt == retries:
                break
            if on_retry is not None:
                on_retry(attempt, exc)
            sleep(backoff_s * (2.0 ** attempt))
    raise last  # type: ignore[misc]


class EventBus:
    """Append-only event log with per-consumer offsets (Redis-stream-like).

    The bus is deliberately synchronous and deterministic: tests and the
    co-simulation loop rely on replayable ordering.  A Redis-backed
    implementation would only need to reimplement ``publish`` / ``read``.
    """

    def __init__(self) -> None:
        self._log: List[Event] = []
        self._offsets: Dict[str, int] = {}
        self._seq = itertools.count()
        self._lock = threading.Lock()
        self._subscribers: List[Callable[[Event], None]] = []
        self._callback_failures = 0
        self._last_callback_error = ""

    # -- producer side -------------------------------------------------
    def publish(self, event: Event) -> Event:
        with self._lock:
            stamped = dataclasses.replace(event, seq=next(self._seq))
            self._log.append(stamped)
        for cb in self._subscribers:
            # Subscriber isolation: a consumer's bug must never crash
            # the PRODUCER (the physical scheduler hook).  Failures are
            # counted and surfaced via health(); the event stays in the
            # log, so a pull-mode reader can still recover it.
            try:
                cb(stamped)
            except Exception as exc:  # noqa: BLE001 — isolation boundary
                with self._lock:
                    self._callback_failures += 1
                    self._last_callback_error = (
                        f"{type(exc).__name__}: {exc}")
        return stamped

    def health(self) -> Dict:
        """Producer-visible bus vitals: log length, consumer offsets,
        and the subscriber-isolation counters."""
        with self._lock:
            return {"events": len(self._log),
                    "consumers": dict(self._offsets),
                    "subscribers": len(self._subscribers),
                    "callback_failures": self._callback_failures,
                    "last_callback_error": self._last_callback_error}

    # -- consumer side -------------------------------------------------
    def read(self, consumer: str, max_events: Optional[int] = None) -> List[Event]:
        """Read new events for ``consumer`` and advance its offset."""
        with self._lock:
            start = self._offsets.get(consumer, 0)
            end = len(self._log) if max_events is None else min(
                len(self._log), start + max_events)
            out = self._log[start:end]
            self._offsets[consumer] = end
        return out

    def subscribe(self, callback: Callable[[Event], None]) -> None:
        """Push-mode delivery (used by the co-simulation loop)."""
        self._subscribers.append(callback)

    @property
    def has_listeners(self) -> bool:
        """Anyone push-subscribed or pull-reading this bus — producers
        that cannot stream (the emulator's ``fast=True`` replay) must
        refuse rather than silently starve them."""
        return bool(self._subscribers) or bool(self._offsets)

    def replay(self) -> Iterator[Event]:
        """Full-log replay (recovery after a twin restart)."""
        return iter(list(self._log))

    def __len__(self) -> int:
        return len(self._log)

    # -- recovery ------------------------------------------------------
    def snapshot_offsets(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._offsets)

    def restore_offsets(self, offsets: Dict[str, int]) -> None:
        with self._lock:
            self._offsets.update(offsets)

    def dump(self) -> List[Dict]:
        """Whole log as JSON-safe dicts (cross-process resume)."""
        with self._lock:
            return [ev.to_dict() for ev in self._log]

    @classmethod
    def from_dump(cls, events: List[Dict]) -> "EventBus":
        """Rebuild a bus whose log (and next seq) match ``dump``."""
        bus = cls()
        bus._log = [Event.from_dict(d) for d in events]
        bus._seq = itertools.count(len(bus._log))
        return bus
