"""SchedTwin orchestrator — the simulation-in-the-loop digital twin.

Wires together the paper's workflow (Figure 2):

  ① physical event --> ② produced onto the event bus -->
  ③ twin consumes --> ④ synchronization (sync.py) -->
  ⑤ parallel what-if DES (whatif.py) --> ⑥ policy selection
  (scoring.py) --> ⑥A extract next job-run events -->
  ⑦ decision feedback: ``qrun`` the selected jobs.

The twin never sees true runtimes — only user estimates and actual
completion events as they occur, exactly the information a production
PBS deployment exposes.
"""
from __future__ import annotations

from typing import Callable, List, Optional

import jax
import numpy as np

from repro.core import sync, telemetry
from repro.core.engine import DrainEngine
from repro.core.events import Event, EventBus, EventKind
from repro.core.fan import FanSpec, normalize_fan
from repro.core.objective import ObjectiveLike, resolve_goal
from repro.core.policies import PAPER_POOL, PoolLike, normalize_pool
from repro.core.race import RaceSpec, normalize_race
from repro.core.scoring import ScoreWeights
from repro.core.state import SimState, empty_state


class SchedTwin:
    """Real-time digital twin for adaptive scheduling.

    Parameters
    ----------
    bus : EventBus
        Stream carrying scheduler hook events (②→③).
    qrun : callable(list[int], float) -> None
        Decision feedback into the physical system (⑦) — the PBS
        ``qrun <jobid>`` equivalent, supplied by the cluster emulator
        (or by a real PBS adapter).
    free_nodes_probe : callable() -> int, optional
        Authoritative node-availability probe (§3.2's "command-line
        tools"); when given, the mirror's free count is resynced before
        every decision.
    pool : candidate pool in tie-break order (default: paper's WFP,
        FCFS, SJF).  Any ``policies.normalize_pool`` input works: a
        ``PolicyPool``, a stacked ``PolicySpec``, a sweep-grammar
        string (``"paper,wfp:a=1..5x5"``), or a sequence of legacy
        policy ids — ids are lifted to their parametric fixed points,
        which produce bit-identical decisions (tests/test_policyspec).
    objective : the administrator-configured optimization goal (§3.4;
        DESIGN.md §8) policy selection minimizes — an
        ``objective.Objective``, a grammar string (``"score"``,
        ``"avg_wait"``, ``"min:avg_wait@util>=0.85"``), or None for
        the paper's 4-term score.
    weights : DEPRECATED legacy goal spelling; a ``ScoreWeights`` here
        lifts to the bit-identical paper-score objective (with a
        ``DeprecationWarning``).
    ensemble : if > 1, use uncertainty-ensemble decisions (beyond paper).
    fan : optional ``fan.FanSpec`` (or bare int F) — decide over an
        on-device Monte-Carlo fan of F perturbed futures per policy
        (DESIGN.md §10) instead of the single nominal future; pairs
        naturally with a distributional ``objective``
        (``"p95:avg_wait"``, ``"cvar:0.9:score"``).  Decisions then
        carry device-computed per-policy confidence intervals, recorded
        in telemetry with no host recompute.  Mutually exclusive with
        ``ensemble > 1``.
    race : optional ``race.RaceSpec`` (or bare ``FanSpec``/int) — decide
        via the successive-halving fan race (DESIGN.md §11): every
        policy starts at a small fan F₀, per-rung CIs eliminate
        statistically-dominated policies, survivors double F, and CRN
        prefix-stability means each rung replays only the new member
        suffix.  Same winner as ``fan=`` at the race's F_max, at a
        fraction of the member budget; per-cycle rungs/members/
        separation land in ``CycleRecord``.  Mutually exclusive with
        ``fan=`` and ``ensemble > 1``.
    engine : the policy-batched what-if engine (``core.engine``); pick
        the scheduling-pass backend here (``DrainEngine("pallas")`` for
        the TPU kernel, ``DrainEngine("auto")`` to pick per platform).
        Default: the pure-JAX reference backend.
    """

    CONSUMER = "schedtwin"

    def __init__(self,
                 bus: EventBus,
                 qrun: Callable[[List[int], float], None],
                 total_nodes: int,
                 max_jobs: int = 256,
                 pool: PoolLike = PAPER_POOL,
                 objective: ObjectiveLike = None,
                 weights: Optional[ScoreWeights] = None,
                 free_nodes_probe: Optional[Callable[[], int]] = None,
                 ensemble: int = 1,
                 ensemble_noise: float = 0.3,
                 fan: Optional[FanSpec] = None,
                 race: Optional[RaceSpec] = None,
                 engine: Optional[DrainEngine] = None,
                 seed: int = 0) -> None:
        if fan is not None and ensemble > 1:
            raise ValueError("fan= and ensemble>1 are mutually exclusive")
        if race is not None and (fan is not None or ensemble > 1):
            raise ValueError(
                "race= is mutually exclusive with fan= and ensemble>1")
        self.bus = bus
        self.qrun = qrun
        self.pool = normalize_pool(pool)
        self.objective = resolve_goal(objective, weights)
        self.state: SimState = empty_state(max_jobs, total_nodes)
        self.telemetry = telemetry.Telemetry()
        self.free_nodes_probe = free_nodes_probe
        self.ensemble = ensemble
        self.ensemble_noise = ensemble_noise
        self.fan = normalize_fan(fan) if fan is not None else None
        self.race = normalize_race(race) if race is not None else None
        self.engine = engine if engine is not None else DrainEngine()
        self._key = jax.random.PRNGKey(seed)

    # ------------------------------------------------------------------
    def pump(self) -> int:
        """③ consume pending events; run a decision cycle if any event
        opened a scheduling opportunity.  Returns #events consumed."""
        events = self.bus.read(self.CONSUMER)
        needs_cycle = False
        t_latest = float(self.state.now)
        for ev in events:
            self._capture_residual(ev)
            self.state, cycle = sync.apply_event(self.state, ev)
            needs_cycle |= cycle
            t_latest = max(t_latest, ev.time)
        if needs_cycle:
            self._decision_cycle(t_latest)
        return len(events)

    def on_event(self, ev: Event) -> None:
        """Push-mode entry point (bus.subscribe)."""
        self.bus.read(self.CONSUMER)  # keep offset in step with pushes
        self._capture_residual(ev)
        self.state, needs_cycle = sync.apply_event(self.state, ev)
        if needs_cycle:
            self._decision_cycle(ev.time)

    def _capture_residual(self, ev: Event) -> None:
        """§3.2 estimate-vs-true runtime residual: a JOBOBIT reveals the
        actual walltime (obit time − recorded start) of a job the twin
        only ever knew by its user estimate.  Recorded host-side into
        telemetry before the mirror forgets the start time;
        ``FanSpec.from_history`` fits its lognormal σ to these pairs."""
        if ev.kind != EventKind.JOBOBIT or ev.job_id < 0:
            return
        start = float(self.state.jobs.start_t[ev.job_id])
        if start < 0.0:  # never started in the mirror — no ground truth
            return
        est = float(self.state.jobs.est_runtime[ev.job_id])
        self.telemetry.record_residual(est, ev.time - start)

    # ------------------------------------------------------------------
    def _decision_cycle(self, t: float) -> None:
        """④→⑦ : sync, simulate, select, feed back."""
        if self.free_nodes_probe is not None:
            self.state = sync.resync_free_nodes(
                self.state, self.free_nodes_probe())

        race_out = None
        with telemetry.StopWatch() as sw:
            if self.race is not None:
                decision, race_out = self.engine.decide_race(
                    self.state, self.pool.spec, self.race,
                    objective=self.objective)
            elif self.fan is not None:
                decision = self.engine.decide_fan(
                    self.state, self.pool.spec, self.fan,
                    objective=self.objective)
            elif self.ensemble > 1:
                self._key, sub = jax.random.split(self._key)
                decision = self.engine.decide_ensemble(
                    self.state, self.pool.spec, sub,
                    n_ens=self.ensemble, noise=self.ensemble_noise,
                    objective=self.objective)
            else:
                decision = self.engine.decide(self.state, self.pool.spec,
                                              self.objective)
            run_mask = np.asarray(decision.run_mask)  # blocks for timing

        job_ids = [int(j) for j in np.nonzero(run_mask)[0]]
        # decisions are reported by family name + θ ("WFP",
        # "wfp[a=2,tau=600]", ...); pool position stays the tie-break.
        winner = self.pool.names[int(decision.policy_index)]
        costs = {name: float(c)
                 for name, c in zip(self.pool.names,
                                    np.asarray(decision.costs))}
        # the goal's per-term device-computed breakdown for ALL k forks
        # (policy -> term -> cost): downstream reports (radar areas,
        # summarize-style tables) consume this instead of recomputing
        # costs on the host from raw metrics.
        term_arrays = {term: np.asarray(v)
                       for term, v in (decision.cost_terms or {}).items()}
        term_costs = {name: {term: float(v[i])
                             for term, v in term_arrays.items()}
                      for i, name in enumerate(self.pool.names)}
        # fan/ensemble decisions carry device-computed per-policy
        # uncertainty (DESIGN.md §10); record it as-is, no host math.
        cost_ci = {}
        fan_width = {}
        if decision.cost_ci is not None:
            cost_ci = {name: float(c)
                       for name, c in zip(self.pool.names,
                                          np.asarray(decision.cost_ci))}
        if decision.fan_width is not None:
            fan_width = {name: float(w)
                         for name, w in zip(self.pool.names,
                                            np.asarray(decision.fan_width))}
        race_fields = {}
        if race_out is not None:
            race_fields = dict(
                race_rungs=len(race_out.rungs),
                race_members=int(race_out.members),
                race_separation=float(np.min(race_out.separation)),
                race_stopped=race_out.stopped)
        self.telemetry.record(telemetry.CycleRecord(
            time=t, wall_seconds=sw.seconds, policy=winner,
            costs=costs, n_started=len(job_ids), started_jobs=job_ids,
            objective=str(self.objective), term_costs=term_costs,
            cost_ci=cost_ci, fan_width=fan_width,
            fan_size=decision.fan_size, **race_fields))

        if job_ids:
            # ⑦ qrun — the physical system will emit RUNJOB events that
            # flow back through the bus and insert predicted-end events.
            self.qrun(job_ids, t)

    # ------------------------------------------------------------------
    def recover(self) -> None:
        """Rebuild the mirror from a full bus replay (twin restart)."""
        self.state = empty_state(self.state.jobs.capacity,
                                 int(self.state.total_nodes))
        for ev in self.bus.replay():
            self.state, _ = sync.apply_event(self.state, ev)
