"""SchedTwin orchestrator — the simulation-in-the-loop digital twin.

Wires together the paper's workflow (Figure 2):

  ① physical event --> ② produced onto the event bus -->
  ③ twin consumes --> ④ synchronization (sync.py) -->
  ⑤ parallel what-if DES (whatif.py) --> ⑥ policy selection
  (scoring.py) --> ⑥A extract next job-run events -->
  ⑦ decision feedback: ``qrun`` the selected jobs.

The twin never sees true runtimes — only user estimates and actual
completion events as they occur, exactly the information a production
PBS deployment exposes.

Resilience layer (DESIGN.md §12): ingestion is HARDENED by default —
malformed events are quarantined into ``dead_letters`` instead of
raising mid-cycle, duplicate/out-of-order ``seq`` deliveries are
absorbed idempotently (``events.SeqTracker`` + state-guarded
``sync.apply_event``), sequence gaps trigger probe resyncs, and bus
reads retry transient failures with bounded backoff.  On a clean
in-order stream every hardened path reduces to the original handlers
bit-for-bit.  Decision cycles can run under a wall-clock budget
(``guard.DeadlineGuard``) that degrades the decision down a ladder
rather than letting it arrive late, and ``snapshot()``/``restore()``
make the whole twin crash-safe through ``checkpoint.manager``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sync, telemetry
from repro.core.engine import DrainEngine
from repro.core.events import (BusReadError, DeadLetter, Event, EventBus,
                               EventKind, SeqTracker, read_with_retry,
                               validate_event)
from repro.core.fan import FanSpec, normalize_fan
from repro.core.guard import DeadlineGuard, GuardSpec
from repro.core.objective import ObjectiveLike, resolve_goal
from repro.core.policies import (PAPER_POOL, PolicyPool, PolicySpec,
                                 PoolLike, normalize_pool)
from repro.core.race import RaceSpec, normalize_race
from repro.core.scoring import ScoreWeights
from repro.core.state import QUEUED, SimState, empty_state


def _jsonable(x):
    """Recursively strip numpy/JAX scalar types out of snapshot extras
    (CycleRecord cost dicts hold device scalars; ``json.dump`` chokes
    on them bitlessly — ``.item()`` round-trips f32 exactly)."""
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (bool, int, float, str)) or x is None:
        return x
    arr = np.asarray(x)
    return arr.item() if arr.ndim == 0 else arr.tolist()


def _fork_pool(pool: PolicyPool, p: int) -> PolicyPool:
    """Pool member p as a k=1 pool (one schedule pass, no comparison)."""
    return PolicyPool(
        spec=PolicySpec(pool.spec.family[p:p + 1],
                        pool.spec.theta[p:p + 1]),
        names=(pool.names[p],))


class SchedTwin:
    """Real-time digital twin for adaptive scheduling.

    Parameters
    ----------
    bus : EventBus
        Stream carrying scheduler hook events (②→③).
    qrun : callable(list[int], float) -> None
        Decision feedback into the physical system (⑦) — the PBS
        ``qrun <jobid>`` equivalent, supplied by the cluster emulator
        (or by a real PBS adapter).
    free_nodes_probe : callable() -> int, optional
        Authoritative node-availability probe (§3.2's "command-line
        tools"); when given, the mirror's free count is resynced before
        every decision.
    pool : candidate pool in tie-break order (default: paper's WFP,
        FCFS, SJF).  Any ``policies.normalize_pool`` input works: a
        ``PolicyPool``, a stacked ``PolicySpec``, a sweep-grammar
        string (``"paper,wfp:a=1..5x5"``), or a sequence of legacy
        policy ids — ids are lifted to their parametric fixed points,
        which produce bit-identical decisions (tests/test_policyspec).
    objective : the administrator-configured optimization goal (§3.4;
        DESIGN.md §8) policy selection minimizes — an
        ``objective.Objective``, a grammar string (``"score"``,
        ``"avg_wait"``, ``"min:avg_wait@util>=0.85"``), or None for
        the paper's 4-term score.
    weights : DEPRECATED legacy goal spelling; a ``ScoreWeights`` here
        lifts to the bit-identical paper-score objective (with a
        ``DeprecationWarning``).
    ensemble : if > 1, use uncertainty-ensemble decisions (beyond paper).
    fan : optional ``fan.FanSpec`` (or bare int F) — decide over an
        on-device Monte-Carlo fan of F perturbed futures per policy
        (DESIGN.md §10) instead of the single nominal future; pairs
        naturally with a distributional ``objective``
        (``"p95:avg_wait"``, ``"cvar:0.9:score"``).  Decisions then
        carry device-computed per-policy confidence intervals, recorded
        in telemetry with no host recompute.  Mutually exclusive with
        ``ensemble > 1``.
    race : optional ``race.RaceSpec`` (or bare ``FanSpec``/int) — decide
        via the successive-halving fan race (DESIGN.md §11): every
        policy starts at a small fan F₀, per-rung CIs eliminate
        statistically-dominated policies, survivors double F, and CRN
        prefix-stability means each rung replays only the new member
        suffix.  Same winner as ``fan=`` at the race's F_max, at a
        fraction of the member budget; per-cycle rungs/members/
        separation land in ``CycleRecord``.  Mutually exclusive with
        ``fan=`` and ``ensemble > 1``.
    engine : the policy-batched what-if engine (``core.engine``); pick
        the scheduling-pass backend here (``DrainEngine("pallas")`` for
        the TPU kernel, ``DrainEngine("auto")`` to pick per platform).
        Default: the pure-JAX reference backend.
    guard : optional ``guard.GuardSpec`` (or a bare float budget in
        seconds, or a prebuilt ``DeadlineGuard``) — run every decision
        cycle under a wall-clock budget, walking the degradation ladder
        (shrunk race/fan → static fallback pool → hold incumbent) on
        budget pressure so ``qrun`` is always fed on time (DESIGN.md
        §12).  Ladder level / margin / misses land in ``CycleRecord``.
    jobs_probe : callable() -> dict, optional
        Authoritative full job-table probe (the qstat analogue of
        ``free_nodes_probe``; ``ClusterEmulator.jobs_view``).  When the
        stream declares events LOST (a sequence hole aged past the
        reorder window), the mirror is rebuilt from this probe — the
        only heal for a dropped QUEUEJOB.
    fallback_pool : the static pool the ladder's level 2 decides over
        (default: the paper's §4.1 pool).
    clock / sleep : injectable time sources (ladder determinism under a
        fake clock in tests; instant backoff in the chaos benchmark).
    reorder_window : how many seqs behind the high-water mark a missing
        event may lag before it is declared lost (``SeqTracker``).
    read_retries / read_backoff_s : bounded-backoff policy for
        transient ``BusReadError`` on bus reads.
    """

    CONSUMER = "schedtwin"

    def __init__(self,
                 bus: EventBus,
                 qrun: Callable[[List[int], float], None],
                 total_nodes: int,
                 max_jobs: int = 256,
                 pool: PoolLike = PAPER_POOL,
                 objective: ObjectiveLike = None,
                 weights: Optional[ScoreWeights] = None,
                 free_nodes_probe: Optional[Callable[[], int]] = None,
                 ensemble: int = 1,
                 ensemble_noise: float = 0.3,
                 fan: Optional[FanSpec] = None,
                 race: Optional[RaceSpec] = None,
                 engine: Optional[DrainEngine] = None,
                 seed: int = 0,
                 guard=None,
                 jobs_probe: Optional[Callable[[], dict]] = None,
                 fallback_pool: PoolLike = PAPER_POOL,
                 clock: Callable[[], float] = time.perf_counter,
                 sleep: Callable[[float], None] = time.sleep,
                 reorder_window: int = 64,
                 read_retries: int = 3,
                 read_backoff_s: float = 0.01) -> None:
        if fan is not None and ensemble > 1:
            raise ValueError("fan= and ensemble>1 are mutually exclusive")
        if race is not None and (fan is not None or ensemble > 1):
            raise ValueError(
                "race= is mutually exclusive with fan= and ensemble>1")
        self.bus = bus
        self.qrun = qrun
        self.pool = normalize_pool(pool)
        self.objective = resolve_goal(objective, weights)
        self.max_jobs = max_jobs
        self.state: SimState = empty_state(max_jobs, total_nodes)
        self.telemetry = telemetry.Telemetry()
        self.free_nodes_probe = free_nodes_probe
        self.ensemble = ensemble
        self.ensemble_noise = ensemble_noise
        self.fan = normalize_fan(fan) if fan is not None else None
        self.race = normalize_race(race) if race is not None else None
        self.engine = engine if engine is not None else DrainEngine()
        self._key = jax.random.PRNGKey(seed)
        # -- resilience layer (DESIGN.md §12) --------------------------
        if isinstance(guard, DeadlineGuard):
            self.guard: Optional[DeadlineGuard] = guard
        elif isinstance(guard, GuardSpec):
            self.guard = DeadlineGuard(guard)
        elif guard is not None:
            self.guard = DeadlineGuard(GuardSpec(budget_s=float(guard)))
        else:
            self.guard = None
        self.jobs_probe = jobs_probe
        self.fallback_pool = normalize_pool(fallback_pool)
        self.dead_letters: List[DeadLetter] = []
        self._tracker = SeqTracker(reorder_window)
        self._clock = clock
        self._sleep = sleep
        self.read_retries = read_retries
        self.read_backoff_s = read_backoff_s
        # last winner as (source pool, index) — the ladder's level-3
        # incumbent; JSON-serializable for snapshots.
        self._incumbent: Optional[Tuple[str, int]] = None

    # ------------------------------------------------------------------
    def pump(self) -> int:
        """③ consume pending events; run a decision cycle if any event
        opened a scheduling opportunity.  Returns #events consumed.

        Hardened: transient read failures retry with bounded backoff
        (exhaustion skips this pump rather than crashing — the events
        stay in the log for the next one); each event then passes
        through ``_ingest`` (quarantine / dedup / reorder / gap
        classification) and losses trigger a probe resync."""
        ing = self.telemetry.ingest

        def _count_retry(attempt: int, exc: Exception) -> None:
            ing.read_retries += 1

        try:
            events = read_with_retry(
                self.bus, self.CONSUMER, retries=self.read_retries,
                backoff_s=self.read_backoff_s, sleep=self._sleep,
                on_retry=_count_retry)
        except BusReadError:
            ing.read_failures += 1
            return 0
        needs_cycle = False
        lost_any = False
        t_latest = float(self.state.now)
        for ev in events:
            applied, cycle, gap, lost = self._ingest(ev)
            needs_cycle |= cycle
            lost_any |= lost
            if gap or lost:
                needs_cycle = True   # something is missing — resync+look
            if applied:
                t_latest = max(t_latest, float(ev.time))
        if lost_any and self.jobs_probe is not None:
            # events are gone for good (aged past the reorder window):
            # rebuild the job table from the authoritative probe — the
            # only heal for a dropped QUEUEJOB.
            self.state = sync.resync_jobs(self.state, self.jobs_probe())
            ing.resyncs += 1
            t_latest = max(t_latest, float(self.state.now))
        if needs_cycle:
            self._decision_cycle(t_latest)
        return len(events)

    def on_event(self, ev: Event) -> None:
        """Push-mode entry point (bus.subscribe)."""
        self.bus.read(self.CONSUMER)  # keep offset in step with pushes
        applied, needs_cycle, gap, lost = self._ingest(ev)
        if lost and self.jobs_probe is not None:
            self.state = sync.resync_jobs(self.state, self.jobs_probe())
            self.telemetry.ingest.resyncs += 1
        if needs_cycle or gap or lost:
            self._decision_cycle(float(ev.time) if applied
                                 else float(self.state.now))

    def _ingest(self, ev: Event) -> Tuple[bool, bool, bool, bool]:
        """Sanitize + apply ONE event.  Returns ``(applied, needs_cycle,
        gap_detected, losses_declared)``.  Never raises: malformed
        events and handler failures land in ``dead_letters``."""
        ing = self.telemetry.ingest
        reason = validate_event(ev, self.max_jobs)
        if reason is not None:
            self.dead_letters.append(DeadLetter(ev, reason))
            ing.quarantined += 1
            return False, False, False, False
        gap = lost = False
        if ev.seq >= 0:
            obs = self._tracker.observe(ev.seq)
            if obs.new_gaps:
                ing.gaps += obs.new_gaps
                gap = True
            if obs.newly_lost:
                ing.lost += obs.newly_lost
                lost = True
            if obs.status == "duplicate":
                ing.duplicates += 1
                return False, False, gap, lost
            if obs.status == "reordered":
                ing.reordered += 1
        self._capture_residual(ev)
        try:
            self.state, cycle = sync.apply_event(self.state, ev,
                                                 idempotent=True)
        except Exception as exc:  # noqa: BLE001 — quarantine boundary
            self.dead_letters.append(
                DeadLetter(ev, f"apply failed: {type(exc).__name__}: "
                               f"{exc}"))
            ing.quarantined += 1
            return False, False, gap, lost
        return True, cycle, gap, lost

    def _capture_residual(self, ev: Event) -> None:
        """§3.2 estimate-vs-true runtime residual: a JOBOBIT reveals the
        actual walltime (obit time − recorded start) of a job the twin
        only ever knew by its user estimate.  Recorded host-side into
        telemetry before the mirror forgets the start time;
        ``FanSpec.from_history`` fits its lognormal σ to these pairs."""
        if ev.kind != EventKind.JOBOBIT or ev.job_id < 0:
            return
        start = float(self.state.jobs.start_t[ev.job_id])
        if start < 0.0:  # never started in the mirror — no ground truth
            return
        est = float(self.state.jobs.est_runtime[ev.job_id])
        self.telemetry.record_residual(est, ev.time - start)

    # ------------------------------------------------------------------
    def _decide_at_level(self, level: int):
        """One decision at the given ladder level (DESIGN.md §12).
        Returns ``(decision, race_out, names, source)`` where ``names``
        label the decision's forks and ``source`` ∈ {'pool',
        'fallback'} says which pool the winning index refers to (the
        incumbent bookkeeping).  Level 0 is the configured decision
        mode verbatim; a mode with nothing to shrink falls through
        level 1 to the static pool."""
        if level >= 3 and self._incumbent is not None:
            # hold the incumbent: one k=1 schedule pass, no comparison
            src, idx = self._incumbent
            base = self.pool if src == "pool" else self.fallback_pool
            pool1 = _fork_pool(base, idx)
            decision = self.engine.decide(self.state, pool1.spec,
                                          self.objective)
            return decision, None, pool1.names, self._incumbent
        if level >= 2 or (level == 1 and self.race is None
                          and self.fan is None and self.ensemble <= 1):
            # static fallback pool, single nominal future — the paper's
            # own baseline twin (also level 3 before any incumbent)
            decision = self.engine.decide(
                self.state, self.fallback_pool.spec, self.objective)
            return (decision, None, self.fallback_pool.names,
                    ("fallback", None))
        if level == 1:
            shrink = self.guard.spec.shrink
            if self.race is not None:
                r = self.race
                fan1 = dataclasses.replace(
                    r.fan, n=max(r.f0, int(np.ceil(r.fan.n * shrink))))
                bm = (r.budget_ms * shrink
                      if getattr(r, "budget_ms", None) else r.budget_ms)
                shrunk = dataclasses.replace(r, fan=fan1, budget_ms=bm)
                decision, race_out = self.engine.decide_race(
                    self.state, self.pool.spec, shrunk,
                    objective=self.objective)
                return decision, race_out, self.pool.names, ("pool", None)
            if self.fan is not None:
                fan1 = dataclasses.replace(
                    self.fan, n=max(1, int(np.ceil(self.fan.n * shrink))))
                decision = self.engine.decide_fan(
                    self.state, self.pool.spec, fan1,
                    objective=self.objective)
                return decision, None, self.pool.names, ("pool", None)
            # ensemble: shrink member count (key consumption below is
            # identical at levels 0 and 1 — snapshot determinism)
            self._key, sub = jax.random.split(self._key)
            n1 = max(2, int(np.ceil(self.ensemble * shrink)))
            decision = self.engine.decide_ensemble(
                self.state, self.pool.spec, sub, n_ens=n1,
                noise=self.ensemble_noise, objective=self.objective)
            return decision, None, self.pool.names, ("pool", None)
        # level 0 — the configured decision mode
        if self.race is not None:
            decision, race_out = self.engine.decide_race(
                self.state, self.pool.spec, self.race,
                objective=self.objective)
            return decision, race_out, self.pool.names, ("pool", None)
        if self.fan is not None:
            decision = self.engine.decide_fan(
                self.state, self.pool.spec, self.fan,
                objective=self.objective)
            return decision, None, self.pool.names, ("pool", None)
        if self.ensemble > 1:
            self._key, sub = jax.random.split(self._key)
            decision = self.engine.decide_ensemble(
                self.state, self.pool.spec, sub,
                n_ens=self.ensemble, noise=self.ensemble_noise,
                objective=self.objective)
            return decision, None, self.pool.names, ("pool", None)
        decision = self.engine.decide(self.state, self.pool.spec,
                                      self.objective)
        return decision, None, self.pool.names, ("pool", None)

    def _decision_cycle(self, t: float) -> None:
        """④→⑦ : sync, simulate, select, feed back — under the deadline
        guard's ladder when one is configured."""
        if self.free_nodes_probe is not None:
            self.state = sync.resync_free_nodes(
                self.state, self.free_nodes_probe())

        level = self.guard.plan() if self.guard is not None else 0
        with telemetry.StopWatch(self._clock) as sw:
            decision, race_out, names, source = self._decide_at_level(level)
            run_mask = np.asarray(decision.run_mask)  # blocks for timing
        guard_fields = {}
        if self.guard is not None:
            missed, margin = self.guard.observe(level, sw.seconds)
            guard_fields = dict(
                guard_level=level,
                deadline_s=self.guard.spec.budget_s,
                margin_s=margin, deadline_miss=missed)

        job_ids = [int(j) for j in np.nonzero(run_mask)[0]]
        # decisions are reported by family name + θ ("WFP",
        # "wfp[a=2,tau=600]", ...); pool position stays the tie-break.
        win_idx = int(decision.policy_index)
        winner = names[win_idx]
        src, idx = source
        self._incumbent = (src, win_idx) if idx is None else (src, idx)
        costs = {name: float(c)
                 for name, c in zip(names, np.asarray(decision.costs))}
        # the goal's per-term device-computed breakdown for ALL k forks
        # (policy -> term -> cost): downstream reports (radar areas,
        # summarize-style tables) consume this instead of recomputing
        # costs on the host from raw metrics.
        term_arrays = {term: np.asarray(v)
                       for term, v in (decision.cost_terms or {}).items()}
        term_costs = {name: {term: float(v[i])
                             for term, v in term_arrays.items()}
                      for i, name in enumerate(names)}
        # fan/ensemble decisions carry device-computed per-policy
        # uncertainty (DESIGN.md §10); record it as-is, no host math.
        cost_ci = {}
        fan_width = {}
        if decision.cost_ci is not None:
            cost_ci = {name: float(c)
                       for name, c in zip(names,
                                          np.asarray(decision.cost_ci))}
        if decision.fan_width is not None:
            fan_width = {name: float(w)
                         for name, w in zip(names,
                                            np.asarray(decision.fan_width))}
        race_fields = {}
        if race_out is not None:
            race_fields = dict(
                race_rungs=len(race_out.rungs),
                race_members=int(race_out.members),
                race_separation=float(np.min(race_out.separation)),
                race_stopped=race_out.stopped)
        self.telemetry.record(telemetry.CycleRecord(
            time=t, wall_seconds=sw.seconds, policy=winner,
            costs=costs, n_started=len(job_ids), started_jobs=job_ids,
            objective=str(self.objective), term_costs=term_costs,
            cost_ci=cost_ci, fan_width=fan_width,
            fan_size=decision.fan_size, **race_fields, **guard_fields))

        if job_ids:
            # ⑦ qrun — the physical system will emit RUNJOB events that
            # flow back through the bus and insert predicted-end events.
            self.qrun(job_ids, t)

    def flush(self) -> bool:
        """End-of-stream reconcile (the emulator's ``on_quiesce`` hook):
        when the producer has quiesced but jobs look unfinished, any
        still-pending sequence holes can never heal — declare them lost,
        rebuild from the authoritative probe, and run one more decision
        cycle if the reconciled mirror still holds queued work.  Returns
        True iff a cycle ran (progress was possible).  A clean stream
        never reaches here with pending holes or queued jobs, so the
        happy path is untouched."""
        ing = self.telemetry.ingest
        newly = self._tracker.flush()
        if newly:
            ing.lost += newly
        if self.jobs_probe is not None:
            self.state = sync.resync_jobs(self.state, self.jobs_probe())
            ing.resyncs += 1
        if bool((np.asarray(self.state.jobs.state) == QUEUED).any()):
            self._decision_cycle(float(self.state.now))
            return True
        return False

    # ------------------------------------------------------------------
    def recover(self) -> None:
        """Rebuild the mirror from a full bus replay (twin restart)."""
        self.state = empty_state(self.state.jobs.capacity,
                                 int(self.state.total_nodes))
        for ev in self.bus.replay():
            self.state, _ = sync.apply_event(self.state, ev)

    # -- crash-safe snapshots (DESIGN.md §12) ---------------------------
    def snapshot(self, manager, step: Optional[int] = None,
                 app_extra: Optional[Dict] = None) -> str:
        """Serialize the ENTIRE decision-relevant twin runtime through
        ``checkpoint.CheckpointManager``: the SimState mirror and RNG
        key ride the array tree (bitwise npz round-trip); the consumer
        offset, SeqTracker, guard ladder state, incumbent, dead letters,
        and telemetry ride the JSON ``extra``.  A twin built with the
        same configuration and ``restore()``d from this snapshot
        produces the uninterrupted run's remaining decision sequence
        bitwise (benchmarks/chaos.py gates this end to end).  ``step``
        defaults to the number of recorded cycles.  ``app_extra`` lets
        the caller (e.g. ``twin_loop`` persisting the emulator + bus for
        cross-process resume) ride JSON payload in the same manifest."""
        step = len(self.telemetry.cycles) if step is None else int(step)
        tm = self.telemetry
        extra = {
            "consumer_offset": int(
                self.bus.snapshot_offsets().get(self.CONSUMER, 0)),
            "tracker": self._tracker.to_dict(),
            "guard": (self.guard.to_dict()
                      if self.guard is not None else None),
            "incumbent": (list(self._incumbent)
                          if self._incumbent is not None else None),
            "dead_letters": [[dl.event.to_dict(), dl.reason]
                             for dl in self.dead_letters],
            "telemetry": {
                "cycles": [dataclasses.asdict(c) for c in tm.cycles],
                "job_start_policy": {str(k): v for k, v in
                                     tm.job_start_policy.items()},
                "runtime_residuals": [[e, a] for e, a
                                      in tm.runtime_residuals],
                "ingest": tm.ingest.as_dict(),
            },
        }
        if app_extra is not None:
            extra["app"] = app_extra
        return manager.save(step, {"state": self.state, "key": self._key},
                            _jsonable(extra))

    def restore(self, manager,
                step: Optional[int] = None) -> Tuple[int, Optional[Dict]]:
        """Inverse of ``snapshot`` — call on a twin built with the SAME
        configuration (pool/objective/fan/race/guard/engine are code,
        not checkpoint payload).  Also rewinds the bus consumer offset,
        so the next ``pump()`` resumes exactly where the snapshot cut.
        Returns ``(step_restored, app_extra_or_None)``."""
        step = manager.latest_step() if step is None else int(step)
        if step is None:
            raise FileNotFoundError(
                f"no checkpoint to restore under {manager.root!r}")
        target = {"state": self.state, "key": self._key}
        tree, extra = manager.restore(step, target)
        tree = jax.tree.map(jnp.asarray, tree)  # np -> jax (.at[] needed)
        self.state = tree["state"]
        self._key = tree["key"]
        self.bus.restore_offsets(
            {self.CONSUMER: int(extra["consumer_offset"])})
        self._tracker = SeqTracker.from_dict(extra["tracker"])
        if self.guard is not None:
            self.guard.restore(extra.get("guard"))
        inc = extra.get("incumbent")
        self._incumbent = (inc[0], int(inc[1])) if inc else None
        self.dead_letters = [
            DeadLetter(Event.from_dict(e), r)
            for e, r in extra.get("dead_letters", [])]
        tmd = extra.get("telemetry", {})
        tm = telemetry.Telemetry()
        tm.cycles = [telemetry.CycleRecord(**c)
                     for c in tmd.get("cycles", [])]
        tm.job_start_policy = {int(k): v for k, v in
                               tmd.get("job_start_policy", {}).items()}
        tm.runtime_residuals = [(float(e), float(a)) for e, a in
                                tmd.get("runtime_residuals", [])]
        tm.ingest = telemetry.IngestStats(**tmd.get("ingest", {}))
        self.telemetry = tm
        return step, extra.get("app")
