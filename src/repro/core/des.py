"""Discrete-event simulation engine (§3.3).

``simulate_to_drain`` runs one what-if fork: starting from the twin's
synchronized snapshot (running jobs with predicted ends + queued jobs),
apply one policy until the queue drains.  Future arrivals are *not*
simulated — per §3.2, submit events cannot be predicted; the event
horizon contains only predicted job-end events.

Time advances event-to-event via ``lax.while_loop``; each iteration is
(schedule pass) -> (advance to next predicted completion).  The loop
bound is ``max_jobs + 1``: every iteration with a non-empty queue either
starts jobs or retires at least one running job.

The same engine also powers trace-replay mode (arrivals injected from a
trace) used by the static-policy baselines in the benchmarks — see
``repro/cluster/emulator.py`` which wraps it with ground-truth runtimes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.backfill import schedule_pass
from repro.core.state import DONE, QUEUED, RUNNING, SimState


class DrainResult(NamedTuple):
    state: SimState          # all previously-queued jobs DONE (or deadlocked)
    first_started: jax.Array # bool (max_jobs,) — jobs started at t=now(0):
                             # the twin's actionable decision (§3.4, 6A)
    iters: jax.Array         # i32 — events processed
    deadlocked: jax.Array    # bool — a queued job can never fit


def simulate_to_drain(state: SimState, policy_id) -> DrainResult:
    max_jobs = state.jobs.capacity
    max_iters = max_jobs + 1

    def cond(carry):
        st, first, it, dead = carry
        return (it < max_iters) & (~dead) & jnp.any(st.jobs.state == QUEUED)

    def body(carry):
        st, first, it, dead = carry
        res = schedule_pass(st, policy_id)
        st = res.state
        # capture the decision: jobs started at the snapshot instant
        first = jnp.where(it == 0, res.started, first)

        jobs = st.jobs
        running = jobs.state == RUNNING
        has_queued = jnp.any(jobs.state == QUEUED)
        ends = jnp.where(running, jobs.end_t, jnp.inf)
        # stale predicted ends (a job "should" have finished before the
        # snapshot instant — user estimates are inaccurate, §3.2) are
        # processed AT the current time: virtual time never rewinds.
        t_next = jnp.maximum(jnp.min(ends), st.now)
        can_advance = has_queued & jnp.isfinite(t_next)
        # a queued job that can never run (req > total nodes) -> deadlock
        dead = dead | (has_queued & ~jnp.isfinite(t_next))

        ending = running & (jobs.end_t <= t_next) & can_advance
        freed = jnp.sum(jnp.where(ending, jobs.nodes, 0))
        jobs = jobs._replace(
            state=jnp.where(ending, DONE, jobs.state))
        st = st._replace(
            jobs=jobs,
            free_nodes=st.free_nodes + freed,
            now=jnp.where(can_advance, t_next, st.now),
        )
        return st, first, it + 1, dead

    init = (state,
            jnp.zeros((max_jobs,), dtype=bool),
            jnp.int32(0),
            jnp.asarray(False))
    st, first, it, dead = jax.lax.while_loop(cond, body, init)
    return DrainResult(state=st, first_started=first, iters=it, deadlocked=dead)


class DrainMetrics(NamedTuple):
    avg_wait: jax.Array
    max_wait: jax.Array
    avg_slowdown: jax.Array
    max_slowdown: jax.Array
    makespan: jax.Array
    utilization: jax.Array


SLOWDOWN_TAU = 10.0  # bounded-slowdown floor (seconds), standard practice


def drain_metrics(result: DrainResult, eval_mask: jax.Array,
                  runtime: jax.Array | None = None) -> DrainMetrics:
    """User/system metrics over ``eval_mask`` jobs (§3.4: the jobs
    waiting in the queue at decision time).

    ``runtime`` defaults to the estimate (all the twin knows); the
    emulator passes true runtimes when scoring *actual* outcomes.
    """
    jobs = result.state.jobs
    rt = jobs.est_runtime if runtime is None else runtime
    n = jnp.maximum(jnp.sum(eval_mask), 1)

    wait = jnp.where(eval_mask, jobs.start_t - jobs.submit_t, 0.0)
    wait = jnp.maximum(wait, 0.0)
    sd = (wait + rt) / jnp.maximum(rt, SLOWDOWN_TAU)
    sd = jnp.maximum(sd, 1.0)
    sd = jnp.where(eval_mask, sd, 0.0)

    makespan = jnp.max(jnp.where(eval_mask, jobs.end_t, 0.0))
    node_seconds = jnp.sum(jnp.where(eval_mask, jobs.nodes * rt, 0.0))
    span = jnp.maximum(
        makespan - jnp.min(jnp.where(eval_mask, jobs.submit_t, jnp.inf)), 1e-6)
    util = node_seconds / (result.state.total_nodes.astype(jnp.float32) * span)

    return DrainMetrics(
        avg_wait=jnp.sum(wait) / n,
        max_wait=jnp.max(wait),
        avg_slowdown=jnp.sum(sd) / n,
        max_slowdown=jnp.max(jnp.where(eval_mask, sd, 1.0)),
        makespan=makespan,
        utilization=jnp.clip(util, 0.0, 1.0),
    )
