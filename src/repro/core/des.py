"""Discrete-event simulation engine (§3.3).

Two drain implementations share the same event semantics
(DESIGN.md §3):

* ``simulate_to_drain`` — the scalar oracle: one what-if fork advanced
  event-to-event via ``lax.while_loop``.  Kept as the semantic
  reference (tests assert the batched drain against it) and as the
  legacy ``jax.vmap`` path the benchmarks compare against.

* ``simulate_to_drain_batched`` — the hot path: ALL k forks carried as
  a leading batch axis on ``SimState`` and advanced in lock-step by ONE
  ``lax.while_loop`` with per-fork done/dead masks.  The scheduling
  pass runs on the whole batch at once through a pluggable backend
  (``repro.core.engine``): priority keys are computed and argsorted
  once per event for the entire batch, and the sequential
  greedy/backfill part executes either as a vmapped reference pass or
  as the Pallas kernel with the fork axis on the grid.

Starting from the twin's synchronized snapshot (running jobs with
predicted ends + queued jobs), each fork applies one policy until the
queue drains.  Future arrivals are *not* simulated — per §3.2, submit
events cannot be predicted; the event horizon contains only predicted
job-end events.  The loop bound is ``max_jobs + 1``: every iteration
with a non-empty queue either starts jobs or retires at least one
running job.

The same engine also powers trace-replay mode (arrivals injected from a
trace) used by the static-policy baselines in the benchmarks — see
``repro/cluster/emulator.py`` which wraps it with ground-truth runtimes.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.backfill import schedule_pass
from repro.core.state import DONE, QUEUED, RUNNING, SimState


class DrainResult(NamedTuple):
    state: SimState          # all previously-queued jobs DONE (or deadlocked)
    first_started: jax.Array # bool (max_jobs,) — jobs started at t=now(0):
                             # the twin's actionable decision (§3.4, 6A)
    iters: jax.Array         # i32 — events processed
    deadlocked: jax.Array    # bool — a queued job can never fit


def simulate_to_drain(state: SimState, policy_id) -> DrainResult:
    max_jobs = state.jobs.capacity
    max_iters = max_jobs + 1

    def cond(carry):
        st, first, it, dead = carry
        return (it < max_iters) & (~dead) & jnp.any(st.jobs.state == QUEUED)

    def body(carry):
        st, first, it, dead = carry
        res = schedule_pass(st, policy_id)
        st = res.state
        # capture the decision: jobs started at the snapshot instant
        first = jnp.where(it == 0, res.started, first)

        jobs = st.jobs
        running = jobs.state == RUNNING
        has_queued = jnp.any(jobs.state == QUEUED)
        ends = jnp.where(running, jobs.end_t, jnp.inf)
        # stale predicted ends (a job "should" have finished before the
        # snapshot instant — user estimates are inaccurate, §3.2) are
        # processed AT the current time: virtual time never rewinds.
        t_next = jnp.maximum(jnp.min(ends), st.now)
        can_advance = has_queued & jnp.isfinite(t_next)
        # a queued job that can never run (req > total nodes) -> deadlock
        dead = dead | (has_queued & ~jnp.isfinite(t_next))

        ending = running & (jobs.end_t <= t_next) & can_advance
        freed = jnp.sum(jnp.where(ending, jobs.nodes, 0))
        jobs = jobs._replace(
            state=jnp.where(ending, DONE, jobs.state))
        st = st._replace(
            jobs=jobs,
            free_nodes=st.free_nodes + freed,
            now=jnp.where(can_advance, t_next, st.now),
        )
        return st, first, it + 1, dead

    init = (state,
            jnp.zeros((max_jobs,), dtype=bool),
            jnp.int32(0),
            jnp.asarray(False))
    st, first, it, dead = jax.lax.while_loop(cond, body, init)
    return DrainResult(state=st, first_started=first, iters=it, deadlocked=dead)


# ----------------------------------------------------------------------
# Batched drain — the engine's hot path.
# ----------------------------------------------------------------------

# A batched pass: (batched SimState, order (k, J) i32) -> started (k, J)
# bool.  Implementations live in repro/core/engine.py (the backend
# registry); des.py only defines the drain loop around them.
BatchedPassFn = Callable[[SimState, jax.Array], jax.Array]


def broadcast_state(state: SimState, k: int) -> SimState:
    """Fan one snapshot out to k forks (a broadcast, not k copies —
    XLA materializes lazily; the paper's "share a common database")."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (k,) + jnp.shape(x)), state)


def simulate_to_drain_batched(states: SimState, order_fn: Callable[[SimState], jax.Array],
                              pass_fn: BatchedPassFn) -> DrainResult:
    """Drain all k forks of ``states`` (leading batch axis on every
    leaf) in lock-step with per-fork done/dead masks.

    ``order_fn`` maps the batched state to the (k, J) priority order —
    ONE batched key computation + argsort per event for the whole fork
    axis.  ``pass_fn`` runs the sequential greedy/backfill pass on the
    batch (reference vmap or the Pallas grid).

    Per-fork semantics are identical to ``simulate_to_drain``: a fork
    that drains (or deadlocks) freezes while the rest keep stepping, so
    the batched result is bit-for-bit the stack of k scalar drains
    (asserted by tests/test_engine.py).
    """
    k = states.now.shape[0]
    max_jobs = states.jobs.capacity
    max_iters = max_jobs + 1

    def active_mask(st, dead):
        return (~dead) & jnp.any(st.jobs.state == QUEUED, axis=1)

    def cond(carry):
        st, first, it, dead, iters = carry
        return (it < max_iters) & jnp.any(active_mask(st, dead))

    def body(carry):
        st, first, it, dead, iters = carry
        active = active_mask(st, dead)                      # (k,)

        # ---- schedule pass on the whole batch ------------------------
        order = order_fn(st)                                # (k, J)
        started = pass_fn(st, order) & active[:, None]      # (k, J)
        jobs = st.jobs
        now_col = st.now[:, None]
        jobs = jobs._replace(
            start_t=jnp.where(started, now_col, jobs.start_t),
            end_t=jnp.where(started, now_col + jobs.est_runtime, jobs.end_t),
            state=jnp.where(started, RUNNING, jobs.state),
        )
        st = st._replace(
            jobs=jobs,
            free_nodes=st.free_nodes
            - jnp.sum(jnp.where(started, jobs.nodes, 0), axis=1),
        )
        first = jnp.where(it == 0, started, first)

        # ---- advance each fork to its next predicted completion ------
        jobs = st.jobs
        running = jobs.state == RUNNING
        has_queued = jnp.any(jobs.state == QUEUED, axis=1)  # (k,)
        ends = jnp.where(running, jobs.end_t, jnp.inf)
        t_next = jnp.maximum(jnp.min(ends, axis=1), st.now)  # (k,)
        can_advance = active & has_queued & jnp.isfinite(t_next)
        dead = dead | (active & has_queued & ~jnp.isfinite(t_next))

        ending = running & (jobs.end_t <= t_next[:, None]) & can_advance[:, None]
        freed = jnp.sum(jnp.where(ending, jobs.nodes, 0), axis=1)
        jobs = jobs._replace(state=jnp.where(ending, DONE, jobs.state))
        st = st._replace(
            jobs=jobs,
            free_nodes=st.free_nodes + freed,
            now=jnp.where(can_advance, t_next, st.now),
        )
        return st, first, it + 1, dead, iters + active.astype(jnp.int32)

    init = (states,
            jnp.zeros((k, max_jobs), dtype=bool),
            jnp.int32(0),
            jnp.zeros((k,), dtype=bool),
            jnp.zeros((k,), dtype=jnp.int32))
    st, first, _, dead, iters = jax.lax.while_loop(cond, body, init)
    return DrainResult(state=st, first_started=first, iters=iters,
                       deadlocked=dead)


class DrainMetrics(NamedTuple):
    avg_wait: jax.Array
    max_wait: jax.Array
    avg_slowdown: jax.Array
    max_slowdown: jax.Array
    makespan: jax.Array
    utilization: jax.Array


SLOWDOWN_TAU = 10.0  # bounded-slowdown floor (seconds), standard practice


def drain_metrics(result: DrainResult, eval_mask: jax.Array,
                  runtime: jax.Array | None = None) -> DrainMetrics:
    """User/system metrics over ``eval_mask`` jobs (§3.4: the jobs
    waiting in the queue at decision time).

    ``runtime`` defaults to the estimate (all the twin knows); the
    emulator passes true runtimes when scoring *actual* outcomes.
    """
    jobs = result.state.jobs
    rt = jobs.est_runtime if runtime is None else runtime
    n = jnp.maximum(jnp.sum(eval_mask), 1)

    wait = jnp.where(eval_mask, jobs.start_t - jobs.submit_t, 0.0)
    wait = jnp.maximum(wait, 0.0)
    sd = (wait + rt) / jnp.maximum(rt, SLOWDOWN_TAU)
    sd = jnp.maximum(sd, 1.0)
    sd = jnp.where(eval_mask, sd, 0.0)

    makespan = jnp.max(jnp.where(eval_mask, jobs.end_t, 0.0))
    node_seconds = jnp.sum(jnp.where(eval_mask, jobs.nodes * rt, 0.0))
    span = jnp.maximum(
        makespan - jnp.min(jnp.where(eval_mask, jobs.submit_t, jnp.inf)), 1e-6)
    util = node_seconds / (result.state.total_nodes.astype(jnp.float32) * span)

    return DrainMetrics(
        avg_wait=jnp.sum(wait) / n,
        max_wait=jnp.max(wait),
        avg_slowdown=jnp.sum(sd) / n,
        max_slowdown=jnp.max(jnp.where(eval_mask, sd, 1.0)),
        makespan=makespan,
        utilization=jnp.clip(util, 0.0, 1.0),
    )
