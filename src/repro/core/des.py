"""Discrete-event simulation engine (§3.3).

Two drain implementations share the same event semantics
(DESIGN.md §3):

* ``simulate_to_drain`` — the scalar oracle: one what-if fork advanced
  event-to-event via ``lax.while_loop``.  Kept as the semantic
  reference (tests assert the batched drain against it) and as the
  legacy ``jax.vmap`` path the benchmarks compare against.

* ``simulate_to_drain_batched`` — the hot path: ALL k forks carried as
  a leading batch axis on ``SimState`` and advanced in lock-step by ONE
  ``lax.while_loop`` with per-fork done/dead masks.  The scheduling
  pass runs on the whole batch at once through a pluggable backend
  (``repro.core.engine``): priority keys are computed and argsorted
  once per event for the entire batch, and the sequential
  greedy/backfill part executes either as a vmapped reference pass or
  as the Pallas kernel with the fork axis on the grid.

Starting from the twin's synchronized snapshot (running jobs with
predicted ends + queued jobs), each fork applies one policy until the
queue drains.  Future arrivals are *not* simulated — per §3.2, submit
events cannot be predicted; the event horizon contains only predicted
job-end events.  The loop bound is ``max_jobs + 1``: every iteration
with a non-empty queue either starts jobs or retires at least one
running job.

* ``simulate_replay_batched`` — the drain generalized into an
  event-driven **trace replay** (DESIGN.md §6): each fork additionally
  carries a pending-arrival cursor into a per-fork arrival timeline and
  a ground-truth runtime array.  Every step advances one fork-local
  event — ``min(next arrival, next actual completion)`` — injecting
  arrivals and retiring completions at their *true* end times while the
  scheduling pass keeps reasoning over *predicted* ends
  (start + estimate): the §3.2 pull-back/push-forward asymmetry that
  previously only the host-side ``cluster/emulator.py`` loop modeled.
  The three-stage keys/pass/advance decomposition and both pass
  backends are reused unchanged, so an S-scenario × P-policy baseline
  grid is ONE device computation (``engine.replay_grid``) instead of
  S·P Python event loops, bit-identical to the host emulator's static
  mode (tests/test_replay.py).
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.backfill import schedule_pass
from repro.core.state import DONE, QUEUED, RUNNING, SimState


class DrainResult(NamedTuple):
    state: SimState          # all previously-queued jobs DONE (or deadlocked)
    first_started: jax.Array # bool (max_jobs,) — jobs started at t=now(0):
                             # the twin's actionable decision (§3.4, 6A)
    iters: jax.Array         # i32 — events processed
    deadlocked: jax.Array    # bool — a queued job can never fit
    pass_invocations: jax.Array  # i32 — scheduling passes executed; the
                                 # batched drain runs one per lock-step
                                 # iteration (same count for every fork)


def simulate_to_drain(state: SimState, policy_id) -> DrainResult:
    max_jobs = state.jobs.capacity
    max_iters = max_jobs + 1

    def cond(carry):
        st, first, it, dead = carry
        return (it < max_iters) & (~dead) & jnp.any(st.jobs.state == QUEUED)

    def body(carry):
        st, first, it, dead = carry
        res = schedule_pass(st, policy_id)
        st = res.state
        # capture the decision: jobs started at the snapshot instant
        first = jnp.where(it == 0, res.started, first)

        jobs = st.jobs
        running = jobs.state == RUNNING
        has_queued = jnp.any(jobs.state == QUEUED)
        ends = jnp.where(running, jobs.end_t, jnp.inf)
        # stale predicted ends (a job "should" have finished before the
        # snapshot instant — user estimates are inaccurate, §3.2) are
        # processed AT the current time: virtual time never rewinds.
        t_next = jnp.maximum(jnp.min(ends), st.now)
        can_advance = has_queued & jnp.isfinite(t_next)
        # a queued job that can never run (req > total nodes) -> deadlock
        dead = dead | (has_queued & ~jnp.isfinite(t_next))

        ending = running & (jobs.end_t <= t_next) & can_advance
        freed = jnp.sum(jnp.where(ending, jobs.nodes, 0))
        jobs = jobs._replace(
            state=jnp.where(ending, DONE, jobs.state))
        st = st._replace(
            jobs=jobs,
            free_nodes=st.free_nodes + freed,
            now=jnp.where(can_advance, t_next, st.now),
        )
        return st, first, it + 1, dead

    init = (state,
            jnp.zeros((max_jobs,), dtype=bool),
            jnp.int32(0),
            jnp.asarray(False))
    st, first, it, dead = jax.lax.while_loop(cond, body, init)
    return DrainResult(state=st, first_started=first, iters=it,
                       deadlocked=dead, pass_invocations=it)


# ----------------------------------------------------------------------
# Batched drain — the engine's hot path.
# ----------------------------------------------------------------------

# A batched pass: (batched SimState, order (k, J) i32, rank limit — an
# i32 scalar or None for the full static bound) -> started (k, J) bool.
# Implementations live in repro/core/engine.py (the backend registry);
# des.py only defines the drain loop around them.
BatchedPassFn = Callable[[SimState, jax.Array, object], jax.Array]


def pass_rank_limit(states: SimState, fork_mask: jax.Array) -> jax.Array:
    """Dynamic pass bound (DESIGN.md §7): the batch-max queued count
    over live forks — an i32 scalar shared by the whole lock-step batch.

    Contract: every (k, J) order the engine produces is QUEUED-FIRST —
    fresh argsorts mask non-queued keys to +inf, and hoisted static
    orders are stable-partition-compacted per event
    (``engine.make_order_fn``) — so each fork's queued slots occupy
    ranks ``[0, n_queued)`` and every rank at or past the batch-max
    count cannot start anything (the pass skips non-QUEUED slots).
    Truncating the sequential rank loops there is therefore bit-exact.
    ``fork_mask`` excludes forks whose pass output is masked away
    anyway (done/dead/not-live), so a deadlocked fork's eternally-queued
    job cannot pin the bound at J.

    Under the fleet engine (DESIGN.md §9) the bound is SHARD-LOCAL:
    ``shard_map`` runs this over each device's chunk of the fork axis,
    so one shard's deep queue never widens another shard's pass.  The
    bound only changes how much work a pass performs, never what it
    computes, so results stay bit-identical to the unsharded batch —
    only ``pass_invocations``-style telemetry differs."""
    n_queued = jnp.sum(states.jobs.state == QUEUED, axis=1)      # (k,)
    return jnp.max(jnp.where(fork_mask, n_queued, 0)).astype(jnp.int32)


def broadcast_state(state: SimState, k: int) -> SimState:
    """Fan one snapshot out to k forks (a broadcast, not k copies —
    XLA materializes lazily; the paper's "share a common database")."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (k,) + jnp.shape(x)), state)


def apply_starts(st: SimState, started: jax.Array) -> SimState:
    """Apply a batched pass's start decisions (k, J): start at ``now``,
    predicted end = now + estimate (§3.2), nodes claimed.  The single
    copy of this parity-critical state evolution — shared by the drain
    and replay loops so they cannot drift."""
    jobs = st.jobs
    now_col = st.now[:, None]
    jobs = jobs._replace(
        start_t=jnp.where(started, now_col, jobs.start_t),
        end_t=jnp.where(started, now_col + jobs.est_runtime, jobs.end_t),
        state=jnp.where(started, RUNNING, jobs.state),
    )
    return st._replace(
        jobs=jobs,
        free_nodes=st.free_nodes
        - jnp.sum(jnp.where(started, jobs.nodes, 0), axis=1),
    )


def simulate_to_drain_batched(states: SimState, order_fn: Callable[[SimState], jax.Array],
                              pass_fn: BatchedPassFn,
                              dynamic_bounds: bool = True) -> DrainResult:
    """Drain all k forks of ``states`` (leading batch axis on every
    leaf) in lock-step with per-fork done/dead masks.

    ``order_fn`` maps the batched state to the (k, J) priority order —
    ONE batched key computation + argsort per event for the whole fork
    axis.  ``pass_fn`` runs the sequential greedy/backfill pass on the
    batch (reference vmap or the Pallas grid) up to a rank limit:
    ``dynamic_bounds`` truncates both rank loops at the batch-max
    queued rank (``pass_rank_limit`` — bit-exact; DESIGN.md §7), which
    also shrinks the drain tail where only a few forks remain active.

    Per-fork semantics are identical to ``simulate_to_drain``: a fork
    that drains (or deadlocks) freezes while the rest keep stepping, so
    the batched result is bit-for-bit the stack of k scalar drains
    (asserted by tests/test_engine.py).

    No pass-elision ``cond`` here: the loop condition already requires
    some fork to be active (~dead with a queued job), so "no live fork
    has a queued job" can never hold inside the body — elision lives in
    the replay loop, where completion-only stretches make it fire.
    """
    k = states.now.shape[0]
    max_jobs = states.jobs.capacity
    max_iters = max_jobs + 1

    def active_mask(st, dead):
        return (~dead) & jnp.any(st.jobs.state == QUEUED, axis=1)

    def cond(carry):
        st, first, it, dead, iters = carry
        return (it < max_iters) & jnp.any(active_mask(st, dead))

    def body(carry):
        st, first, it, dead, iters = carry
        active = active_mask(st, dead)                      # (k,)

        # ---- schedule pass on the whole batch ------------------------
        order = order_fn(st)                                # (k, J)
        limit = (pass_rank_limit(st, active)
                 if dynamic_bounds else None)
        started = pass_fn(st, order, limit) & active[:, None]  # (k, J)
        st = apply_starts(st, started)
        first = jnp.where(it == 0, started, first)

        # ---- advance each fork to its next predicted completion ------
        jobs = st.jobs
        running = jobs.state == RUNNING
        has_queued = jnp.any(jobs.state == QUEUED, axis=1)  # (k,)
        ends = jnp.where(running, jobs.end_t, jnp.inf)
        t_next = jnp.maximum(jnp.min(ends, axis=1), st.now)  # (k,)
        can_advance = active & has_queued & jnp.isfinite(t_next)
        dead = dead | (active & has_queued & ~jnp.isfinite(t_next))

        ending = running & (jobs.end_t <= t_next[:, None]) & can_advance[:, None]
        freed = jnp.sum(jnp.where(ending, jobs.nodes, 0), axis=1)
        jobs = jobs._replace(state=jnp.where(ending, DONE, jobs.state))
        st = st._replace(
            jobs=jobs,
            free_nodes=st.free_nodes + freed,
            now=jnp.where(can_advance, t_next, st.now),
        )
        return st, first, it + 1, dead, iters + active.astype(jnp.int32)

    init = (states,
            jnp.zeros((k, max_jobs), dtype=bool),
            jnp.int32(0),
            jnp.zeros((k,), dtype=bool),
            jnp.zeros((k,), dtype=jnp.int32))
    st, first, it, dead, iters = jax.lax.while_loop(cond, body, init)
    return DrainResult(state=st, first_started=first, iters=iters,
                       deadlocked=dead,
                       pass_invocations=jnp.full((k,), it, dtype=jnp.int32))


# ----------------------------------------------------------------------
# Scenario-vectorized trace replay (DESIGN.md §6).
# ----------------------------------------------------------------------

class ReplayResult(NamedTuple):
    state: SimState          # final state: start_t/end_t are ACTUAL times
    events: jax.Array        # i32 (k,) — events processed per fork
    iters: jax.Array         # i32 scalar — lock-step iterations
    deadlocked: jax.Array    # bool (k,) — a queued job can never run
    pass_invocations: jax.Array  # i32 scalar — scheduling passes actually
                                 # executed (< iters when elision fires)


def simulate_replay_batched(states: SimState, arrival_t: jax.Array,
                            true_rt: jax.Array,
                            order_fn: Callable[[SimState], jax.Array],
                            pass_fn: BatchedPassFn,
                            dynamic_bounds: bool = True,
                            elide_empty: bool = True) -> ReplayResult:
    """Replay k trace forks event-by-event in lock-step.

    ``states`` is a batched ``SimState`` whose job table is *preloaded*
    (submit_t/nodes/est_runtime filled for every slot) but entirely
    INVALID: slots become visible to the scheduler only when their
    arrival is injected.  ``arrival_t`` (k, J) is the per-fork arrival
    timeline — non-decreasing along J, ``inf`` on padding slots — and
    ``true_rt`` (k, J) the ground-truth runtimes the scheduler never
    sees.

    Each iteration processes exactly ONE event per live fork, mirroring
    the host emulator's heap semantics bit-for-bit:

      * the next event is ``min(next arrival, next actual end)``;
        arrivals win ties (they were pushed first), simultaneous ends
        retire in start order (push order of their end events);
      * completions retire at ``start + true_rt`` — the *actual* end —
        and overwrite the predicted ``end_t``, while running jobs keep
        advertising ``start + est_runtime`` to the scheduling pass
        (§3.2: the twin schedules against estimates; reality corrects);
      * after the event, one scheduling pass runs on the whole batch
        through the same ``order_fn``/``pass_fn`` stages as the drain.

    A fork with no next event freezes: done if nothing is queued,
    deadlocked if a queued job remains (its request exceeds that fork's
    cluster) — other forks keep stepping either way.  The iteration
    bound is 2·J + 2: every live iteration consumes one arrival or one
    completion (≤ J of each), plus one iteration to flag deadlock.

    Hot-loop compaction (DESIGN.md §7): ``dynamic_bounds`` truncates
    the pass's rank loops at the deepest live queued rank
    (``pass_rank_limit``); ``elide_empty`` wraps keys + argsort + pass
    in a scalar ``lax.cond`` that skips the whole stage on iterations
    where no live fork has a queued job after the event is applied
    (completion-only stretches of sparse traces) — bit-exact, since the
    pass can only ever start queued jobs of live forks.
    """
    k = states.now.shape[0]
    max_jobs = states.jobs.capacity
    max_iters = 2 * max_jobs + 2
    slots = jnp.arange(max_jobs)
    ord_none = jnp.iinfo(jnp.int32).max

    def next_arrival(cursor):
        cur = jnp.clip(cursor, 0, max_jobs - 1)
        t = jnp.take_along_axis(arrival_t, cur[:, None], axis=1)[:, 0]
        return jnp.where(cursor < max_jobs, t, jnp.inf), cur

    def cond(carry):
        st, cursor, true_end, start_ord, it, dead, events, passes = carry
        next_arr, _ = next_arrival(cursor)
        jstate = st.jobs.state
        work = (jnp.isfinite(next_arr)
                | jnp.any(jstate == RUNNING, axis=1)
                | jnp.any(jstate == QUEUED, axis=1))
        return (it < max_iters) & jnp.any(work & ~dead)

    def body(carry):
        st, cursor, true_end, start_ord, it, dead, events, passes = carry
        jobs = st.jobs

        # ---- pick each fork's next event -----------------------------
        next_arr, cur = next_arrival(cursor)
        running = jobs.state == RUNNING
        te = jnp.where(running, true_end, jnp.inf)
        next_end = jnp.min(te, axis=1)                       # (k,)
        # among simultaneous actual ends, retire the earliest-started
        # (the host heap pops end events in push == start order)
        at_min = running & (te <= next_end[:, None])
        j_end = jnp.argmin(jnp.where(at_min, start_ord, ord_none), axis=1)

        is_arr = next_arr <= next_end        # equal times: arrival first
        t_ev = jnp.minimum(next_arr, next_end)
        has_event = jnp.isfinite(t_ev)
        dead = dead | (~has_event & jnp.any(jobs.state == QUEUED, axis=1))
        live = has_event & ~dead                             # (k,)

        # ---- inject the arrival (slot = cursor) ----------------------
        arr = live & is_arr
        hit_arr = (slots[None, :] == cur[:, None]) & arr[:, None]
        jstate = jnp.where(hit_arr, QUEUED, jobs.state)
        cursor = cursor + arr.astype(jnp.int32)

        # ---- retire the completion at its TRUE end time --------------
        fin = live & ~is_arr
        hit_end = (slots[None, :] == j_end[:, None]) & fin[:, None]
        jstate = jnp.where(hit_end, DONE, jstate)
        end_t = jnp.where(hit_end, true_end, jobs.end_t)
        freed = jnp.sum(jnp.where(hit_end, jobs.nodes, 0), axis=1)
        st = st._replace(
            jobs=jobs._replace(state=jstate, end_t=end_t),
            free_nodes=st.free_nodes + freed,
            now=jnp.where(live, t_ev, st.now),
        )

        # ---- one scheduling pass on the whole batch ------------------
        # Only live forks' starts survive the mask below, so the pass
        # is pure overhead whenever no live fork has a queued job:
        # elide keys + argsort + pass behind one scalar cond.  The rank
        # limit doubles as the elision predicate — limit > 0 iff some
        # live fork has a queued job.
        limit = pass_rank_limit(st, live)

        def run_pass(op):
            st, true_end, start_ord, passes = op
            order = order_fn(st)
            started = pass_fn(st, order,
                              limit if dynamic_bounds else None)
            started = started & live[:, None]
            st = apply_starts(st, started)
            true_end = jnp.where(started, st.now[:, None] + true_rt,
                                 true_end)
            start_ord = jnp.where(started,
                                  it * (max_jobs + 1) + slots[None, :],
                                  start_ord)
            return st, true_end, start_ord, passes + 1

        op = (st, true_end, start_ord, passes)
        if elide_empty:
            st, true_end, start_ord, passes = jax.lax.cond(
                limit > 0, run_pass, lambda o: o, op)
        else:
            st, true_end, start_ord, passes = run_pass(op)
        return (st, cursor, true_end, start_ord, it + 1, dead,
                events + live.astype(jnp.int32), passes)

    init = (states,
            jnp.zeros((k,), dtype=jnp.int32),
            jnp.full((k, max_jobs), jnp.inf, dtype=jnp.float32),
            jnp.full((k, max_jobs), ord_none, dtype=jnp.int32),
            jnp.int32(0),
            jnp.zeros((k,), dtype=bool),
            jnp.zeros((k,), dtype=jnp.int32),
            jnp.int32(0))
    st, _, _, _, it, dead, events, passes = jax.lax.while_loop(
        cond, body, init)
    return ReplayResult(state=st, events=events, iters=it, deadlocked=dead,
                        pass_invocations=passes)


class DrainMetrics(NamedTuple):
    avg_wait: jax.Array
    max_wait: jax.Array
    avg_slowdown: jax.Array
    max_slowdown: jax.Array
    makespan: jax.Array
    utilization: jax.Array


SLOWDOWN_TAU = 10.0  # bounded-slowdown floor (seconds), standard practice


def drain_metrics(result: DrainResult, eval_mask: jax.Array,
                  runtime: jax.Array | None = None) -> DrainMetrics:
    """User/system metrics over ``eval_mask`` jobs (§3.4: the jobs
    waiting in the queue at decision time).

    ``runtime`` defaults to the estimate (all the twin knows); the
    emulator passes true runtimes when scoring *actual* outcomes.
    """
    return state_metrics(result.state, eval_mask, runtime)


def state_metrics(state: SimState, eval_mask: jax.Array,
                  runtime: jax.Array | None = None) -> DrainMetrics:
    """The same metrics over any final state — replay results score
    with ``runtime`` = ground truth and ``eval_mask`` = the scenario's
    real (non-padding) slots."""
    jobs = state.jobs
    rt = jobs.est_runtime if runtime is None else runtime
    n = jnp.maximum(jnp.sum(eval_mask), 1)

    wait = jnp.where(eval_mask, jobs.start_t - jobs.submit_t, 0.0)
    wait = jnp.maximum(wait, 0.0)
    sd = (wait + rt) / jnp.maximum(rt, SLOWDOWN_TAU)
    sd = jnp.maximum(sd, 1.0)
    sd = jnp.where(eval_mask, sd, 0.0)

    makespan = jnp.max(jnp.where(eval_mask, jobs.end_t, 0.0))
    node_seconds = jnp.sum(jnp.where(eval_mask, jobs.nodes * rt, 0.0))
    span = jnp.maximum(
        makespan - jnp.min(jnp.where(eval_mask, jobs.submit_t, jnp.inf)), 1e-6)
    util = node_seconds / (state.total_nodes.astype(jnp.float32) * span)

    return DrainMetrics(
        avg_wait=jnp.sum(wait) / n,
        max_wait=jnp.max(wait),
        avg_slowdown=jnp.sum(sd) / n,
        max_slowdown=jnp.max(jnp.where(eval_mask, sd, 1.0)),
        makespan=makespan,
        utilization=jnp.clip(util, 0.0, 1.0),
    )


# ----------------------------------------------------------------------
# Distributional reductions over the Monte-Carlo fan axis (DESIGN.md
# §10).  A fan evaluation stacks F perturbed futures per (scenario,
# policy) cell on the fork axis; risk goals reduce per-member costs
# over that axis with ORDER STATISTICS, not moments.  The fan size F is
# static to the jits, so these index computations happen at trace time
# and the device reduction is a plain sort + static gather — bit-exact
# against a numpy ``np.sort`` oracle.
# ----------------------------------------------------------------------

def quantile_index(q: float, n: int) -> int:
    """Nearest-rank quantile index into an ascending sort of ``n``
    values: ``ceil(q·n) - 1`` clamped to ``[0, n-1]``.  Exact order
    statistic (no interpolation): p50 of 4 members is sorted[1], p95 of
    256 is sorted[243]."""
    if not 0.0 < q <= 1.0:
        raise ValueError(f"quantile must be in (0, 1], got {q!r}")
    return min(n - 1, max(0, math.ceil(q * n) - 1))


def cvar_tail_count(alpha: float, n: int) -> int:
    """How many worst members the CVaR_α tail averages:
    ``max(1, ceil((1-α)·n))``.  α=0 is the plain mean, α→1 approaches
    the worst case; always >= 1 so the reduction is defined for any F."""
    if not 0.0 <= alpha < 1.0:
        raise ValueError(f"cvar alpha must be in [0, 1), got {alpha!r}")
    return max(1, min(n, math.ceil((1.0 - alpha) * n)))
