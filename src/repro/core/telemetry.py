"""Twin telemetry: per-cycle latency, decisions, policy mix (Table 1)."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


@dataclasses.dataclass
class CycleRecord:
    time: float                # virtual (cluster) time of the cycle
    wall_seconds: float        # host wall time of the decision
    policy: str                # winning policy name
    costs: Dict[str, float]    # per-policy objective cost
    n_started: int             # jobs qrun this cycle
    started_jobs: List[int]
    # the goal this cycle minimized (objective grammar spec) and its
    # per-term cost breakdown for ALL k forks (policy -> term -> cost),
    # as computed on device by Objective.cost_terms — reports consume
    # these instead of recomputing costs from raw metrics on the host.
    objective: str = "score"
    term_costs: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass
class Telemetry:
    cycles: List[CycleRecord] = dataclasses.field(default_factory=list)
    # job_id -> policy that started it (paper Table 1 attributes each
    # *job start* to the policy selected in that cycle)
    job_start_policy: Dict[int, str] = dataclasses.field(default_factory=dict)

    def record(self, rec: CycleRecord) -> None:
        self.cycles.append(rec)
        for j in rec.started_jobs:
            self.job_start_policy[j] = rec.policy

    # ---- Table 1 ------------------------------------------------------
    def policy_start_distribution(self) -> Dict[str, float]:
        """Percentage of job starts attributed to each policy."""
        total = max(len(self.job_start_policy), 1)
        counts: Dict[str, int] = {}
        for p in self.job_start_policy.values():
            counts[p] = counts.get(p, 0) + 1
        return {p: 100.0 * c / total for p, c in sorted(counts.items())}

    # ---- objective breakdown (DESIGN.md §8) ---------------------------
    def objective_breakdown(self) -> Dict[str, Dict[str, float]]:
        """Mean per-term objective cost per policy across all recorded
        cycles (policy -> term -> mean cost) — the device-computed
        decomposition of what each candidate would have cost under the
        administrator's goal, ready for radar/summary reports with no
        host-side recomputation."""
        sums: Dict[str, Dict[str, float]] = {}
        counts: Dict[str, int] = {}
        for c in self.cycles:
            for pol, terms in c.term_costs.items():
                acc = sums.setdefault(pol, {})
                counts[pol] = counts.get(pol, 0) + 1
                for term, v in terms.items():
                    acc[term] = acc.get(term, 0.0) + v
        return {pol: {term: s / counts[pol] for term, s in acc.items()}
                for pol, acc in sums.items()}

    # ---- overhead (paper: "a few seconds per scheduling cycle") -------
    def cycle_latency_stats(self) -> Dict[str, float]:
        if not self.cycles:
            return {"mean_s": 0.0, "max_s": 0.0, "p50_s": 0.0, "n": 0}
        ws = sorted(c.wall_seconds for c in self.cycles)
        n = len(ws)
        return {
            "mean_s": sum(ws) / n,
            "max_s": ws[-1],
            "p50_s": ws[n // 2],
            "n": n,
        }


class StopWatch:
    def __enter__(self) -> "StopWatch":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> Optional[bool]:
        self.seconds = time.perf_counter() - self._t0
        return None
