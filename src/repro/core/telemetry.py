"""Twin telemetry: per-cycle latency, decisions, policy mix (Table 1)."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class CycleRecord:
    time: float                # virtual (cluster) time of the cycle
    wall_seconds: float        # host wall time of the decision
    policy: str                # winning policy name
    costs: Dict[str, float]    # per-policy objective cost
    n_started: int             # jobs qrun this cycle
    started_jobs: List[int]
    # the goal this cycle minimized (objective grammar spec) and its
    # per-term cost breakdown for ALL k forks (policy -> term -> cost),
    # as computed on device by Objective.cost_terms — reports consume
    # these instead of recomputing costs from raw metrics on the host.
    objective: str = "score"
    term_costs: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    # fan/ensemble uncertainty, stamped on DEVICE by decide_fan /
    # decide_ensemble (DESIGN.md §10) — never recomputed on the host.
    # cost_ci: per-policy 95% CI half-width of the member-cost mean;
    # fan_width: per-policy member-cost spread (worst − best member);
    # fan_size: member count F (1 = single-future decision, no fan).
    cost_ci: Dict[str, float] = dataclasses.field(default_factory=dict)
    fan_width: Dict[str, float] = dataclasses.field(default_factory=dict)
    fan_size: int = 1
    # racing accounting (DESIGN.md §11), stamped by SchedTwin(race=...):
    # rungs the race executed, (s, φ, p) member triples actually
    # replayed (vs fan_size·k for a fixed fan), the achieved winner
    # separation (rival CI lower bound − winner upper bound; > 0 means
    # the decision was statistically settled), and why the race ended
    # ('separated' | 'budget_ms' | 'max_members' | 'exhausted'; ""
    # for non-raced cycles).
    race_rungs: int = 0
    race_members: int = 0
    race_separation: float = 0.0
    race_stopped: str = ""
    # deadline guard accounting (DESIGN.md §12), stamped by
    # SchedTwin(guard=...): the degradation-ladder level this cycle ran
    # at (0 = full decision, 1 = shrunk race/fan, 2 = static fallback
    # pool, 3 = hold incumbent), the wall-clock budget it ran under
    # (0 = unguarded), the remaining margin (budget − wall_seconds;
    # negative on a miss), and whether the cycle overran its budget.
    guard_level: int = 0
    deadline_s: float = 0.0
    margin_s: float = 0.0
    deadline_miss: bool = False


@dataclasses.dataclass
class IngestStats:
    """Hardened-ingestion counters (DESIGN.md §12), bumped by the twin's
    pump path as it sanitizes the stream: events quarantined to the
    dead-letter queue, duplicate/out-of-order ``seq`` deliveries
    absorbed idempotently, sequence gaps detected (and those abandoned
    as lost after the reorder window), probe resyncs triggered, and
    bus-read retry/backoff activity."""

    quarantined: int = 0     # malformed events sent to the DLQ
    duplicates: int = 0      # already-applied seq, dropped idempotently
    reordered: int = 0       # events that arrived behind a newer seq
    gaps: int = 0            # seq gaps first observed (pending holes)
    lost: int = 0            # holes abandoned after the reorder window
    resyncs: int = 0         # authoritative probe reconciliations
    read_retries: int = 0    # bus reads retried after transient failure
    read_failures: int = 0   # reads that exhausted every retry

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Telemetry:
    cycles: List[CycleRecord] = dataclasses.field(default_factory=list)
    # job_id -> policy that started it (paper Table 1 attributes each
    # *job start* to the policy selected in that cycle)
    job_start_policy: Dict[int, str] = dataclasses.field(default_factory=dict)
    # §3.2 estimate-vs-true runtime residuals: one (estimated, actual)
    # walltime pair per observed JOBOBIT, recorded by the twin as
    # ground truth reveals itself.  ``fan.FanSpec.from_history`` fits
    # its lognormal runtime-noise σ to these (ROADMAP residual (b)).
    runtime_residuals: List[tuple] = dataclasses.field(default_factory=list)
    # hardened-ingestion counters, owned here so one resilience report
    # covers both the guard (per-cycle records) and the pump (stream
    # sanitization) — the twin bumps these in place.
    ingest: IngestStats = dataclasses.field(default_factory=IngestStats)

    def record(self, rec: CycleRecord) -> None:
        self.cycles.append(rec)
        for j in rec.started_jobs:
            self.job_start_policy[j] = rec.policy

    def record_residual(self, est: float, actual: float) -> None:
        """One revealed (estimated, actual) runtime pair."""
        self.runtime_residuals.append((float(est), float(actual)))

    # ---- Table 1 ------------------------------------------------------
    def policy_start_distribution(self) -> Dict[str, float]:
        """Percentage of job starts attributed to each policy."""
        total = max(len(self.job_start_policy), 1)
        counts: Dict[str, int] = {}
        for p in self.job_start_policy.values():
            counts[p] = counts.get(p, 0) + 1
        return {p: 100.0 * c / total for p, c in sorted(counts.items())}

    # ---- objective breakdown (DESIGN.md §8) ---------------------------
    def objective_breakdown(self) -> Dict[str, Dict[str, float]]:
        """Mean per-term objective cost per policy across all recorded
        cycles (policy -> term -> mean cost) — the device-computed
        decomposition of what each candidate would have cost under the
        administrator's goal, ready for radar/summary reports with no
        host-side recomputation."""
        sums: Dict[str, Dict[str, float]] = {}
        counts: Dict[str, int] = {}
        for c in self.cycles:
            for pol, terms in c.term_costs.items():
                acc = sums.setdefault(pol, {})
                counts[pol] = counts.get(pol, 0) + 1
                for term, v in terms.items():
                    acc[term] = acc.get(term, 0.0) + v
        return {pol: {term: s / counts[pol] for term, s in acc.items()}
                for pol, acc in sums.items()}

    # ---- fan uncertainty (DESIGN.md §10/§11) --------------------------
    def confidence_stats(self) -> Dict[str, Dict[str, float]]:
        """Mean device-computed uncertainty per policy across all fan
        cycles (policy -> {mean_ci, mean_width, mean_sigma, mean_fan,
        min_fan, max_fan, n}); cycles whose CI is infinite (a fan
        member deadlocked) are counted separately as ``n_inf`` rather
        than polluting the means.  Empty when no cycle ran a
        fan/ensemble.

        Racing makes the per-cycle fan size F variable (a policy
        eliminated at rung r carries the CI of F_r members, a survivor
        that of F_max), so a raw mean of CI half-widths conflates noise
        with sample size.  ``mean_sigma`` de-scales each cycle's CI back
        to the member-cost standard deviation (ci·√F/1.96), an
        F-independent noise estimate comparable across cycles of any
        fan size; ``min_fan``/``max_fan``/``mean_fan`` report the fan
        sizes actually used."""
        acc: Dict[str, Dict[str, float]] = {}
        for c in self.cycles:
            if c.fan_size <= 1 or not c.cost_ci:
                continue
            for pol, ci in c.cost_ci.items():
                st = acc.setdefault(
                    pol, {"mean_ci": 0.0, "mean_width": 0.0,
                          "mean_sigma": 0.0, "mean_fan": 0.0,
                          "min_fan": float(c.fan_size),
                          "max_fan": float(c.fan_size),
                          "n": 0, "n_inf": 0})
                width = c.fan_width.get(pol, float("inf"))
                if ci == float("inf") or width == float("inf"):
                    st["n_inf"] += 1
                    continue
                st["mean_ci"] += ci
                st["mean_width"] += width
                st["mean_sigma"] += ci * (c.fan_size ** 0.5) / 1.96
                st["mean_fan"] += c.fan_size
                st["min_fan"] = min(st["min_fan"], float(c.fan_size))
                st["max_fan"] = max(st["max_fan"], float(c.fan_size))
                st["n"] += 1
        for st in acc.values():
            n = max(int(st["n"]), 1)
            st["mean_ci"] /= n
            st["mean_width"] /= n
            st["mean_sigma"] /= n
            st["mean_fan"] /= n
        return acc

    # ---- resilience (DESIGN.md §12) -----------------------------------
    def resilience_stats(self) -> Dict[str, float]:
        """One flat report of how hard the runtime had to fight: deadline
        misses and ladder engagements from the per-cycle guard stamps,
        plus the ingestion counters.  ``ladder_engaged`` counts cycles
        decided at level > 0 (the guard degraded the decision to make
        the deadline); ``miss_rate`` is misses over guarded cycles
        (cycles with a budget), 0.0 when nothing was guarded."""
        guarded = [c for c in self.cycles if c.deadline_s > 0.0]
        misses = sum(1 for c in guarded if c.deadline_miss)
        engaged = sum(1 for c in self.cycles if c.guard_level > 0)
        out: Dict[str, float] = {
            "cycles": len(self.cycles),
            "guarded_cycles": len(guarded),
            "deadline_misses": misses,
            "miss_rate": misses / len(guarded) if guarded else 0.0,
            "ladder_engaged": engaged,
            "max_level": max((c.guard_level for c in self.cycles),
                             default=0),
            "min_margin_s": min((c.margin_s for c in guarded),
                                default=0.0),
        }
        for lvl in range(1, 4):
            out[f"level{lvl}_cycles"] = sum(
                1 for c in self.cycles if c.guard_level == lvl)
        out.update(self.ingest.as_dict())
        return out

    # ---- overhead (paper: "a few seconds per scheduling cycle") -------
    def cycle_latency_stats(self) -> Dict[str, float]:
        if not self.cycles:
            return {"mean_s": 0.0, "max_s": 0.0, "p50_s": 0.0, "n": 0}
        ws = sorted(c.wall_seconds for c in self.cycles)
        n = len(ws)
        return {
            "mean_s": sum(ws) / n,
            "max_s": ws[-1],
            "p50_s": ws[n // 2],
            "n": n,
        }


class StopWatch:
    """Wall-clock context manager.  ``clock`` is injectable so the
    deadline guard's ladder decisions are reproducible under a fake
    clock in tests (the same seam ``race.run_race`` exposes)."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock

    def __enter__(self) -> "StopWatch":
        self._t0 = self._clock()
        return self

    def __exit__(self, *exc) -> Optional[bool]:
        self.seconds = self._clock() - self._t0
        return None
