"""Scheduling policy pool.

A policy is a *priority key function*: lower key = scheduled earlier.
The paper's pool (§4.1) is {WFP (ALCF utility), FCFS, SJF}, all with
EASY backfilling.  Policy ids are ordered by the paper's tie-break
priority WFP -> FCFS -> SJF (§4.2), so an argmin over per-policy costs
naturally resolves ties the way the paper does.

Beyond the paper we add common static policies (SAF, LJF, LXF, EXP)
— the twin's design explicitly allows "a pool of candidate policies ...
provided that they exhibit complementary strengths" (§3); a wider pool
is where the vectorized what-if engine shines.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.state import JobTable

# Canonical ids — tie-break order is numeric order (paper §4.2).
WFP = 0    # ALCF utility: run job maximizing (wait/est)^3 * nodes
FCFS = 1   # first-come-first-served
SJF = 2    # shortest (estimated) job first
# --- beyond-paper pool extensions ---
SAF = 3    # smallest area (nodes * est) first
LJF = 4    # longest job first
LXF = 5    # largest expansion factor first: (wait + est) / est
EXPF = 6   # exponential aging of wait time

POLICY_NAMES = {
    WFP: "WFP", FCFS: "FCFS", SJF: "SJF",
    SAF: "SAF", LJF: "LJF", LXF: "LXF", EXPF: "EXPF",
}
PAPER_POOL: Sequence[int] = (WFP, FCFS, SJF)
EXTENDED_POOL: Sequence[int] = (WFP, FCFS, SJF, SAF, LJF, LXF, EXPF)

_EST_FLOOR = 1.0  # seconds; guards division by tiny estimates


def priority_key(jobs: JobTable, now: jax.Array, policy_id) -> jax.Array:
    """Per-job priority keys (lower = run first) for ``policy_id``.

    Utility policies (WFP, LXF, EXPF) are re-evaluated at every
    scheduling instance with the current wait time, exactly as a live
    utility scheduler recomputes job scores each cycle.

    Stable argsort + slot-ids-in-submission-order means ties fall back
    to FCFS order, the conventional secondary key.
    """
    wait = jnp.maximum(now - jobs.submit_t, 0.0)
    est = jnp.maximum(jobs.est_runtime, _EST_FLOOR)
    nodes = jobs.nodes.astype(jnp.float32)

    # Scores where higher = more deserving; keys are negated scores.
    wfp_score = (wait / est) ** 3 * nodes
    lxf_score = (wait + est) / est
    expf_score = jnp.expm1(jnp.minimum(wait / 3600.0, 30.0))  # hourly aging

    keys = jnp.stack([
        -wfp_score,            # WFP
        jobs.submit_t,         # FCFS
        est,                   # SJF
        nodes * est,           # SAF
        -est,                  # LJF
        -lxf_score,            # LXF
        -expf_score,           # EXPF
    ])
    return keys[policy_id]


def policy_name(policy_id: int) -> str:
    return POLICY_NAMES[int(policy_id)]
