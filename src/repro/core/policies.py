"""Scheduling policy space.

A policy is a *priority key function*: lower key = scheduled earlier.
The paper's pool (§4.1) is {WFP (ALCF utility), FCFS, SJF}, all with
EASY backfilling, and its design explicitly allows "a pool of candidate
policies ... provided that they exhibit complementary strengths" (§3).

Two representations live here:

* **Integer policy ids** (`WFP` ... `EXPF`, `priority_key`) — the
  original hardcoded 7-row key stack.  Kept verbatim as the *oracle*
  the parametric path is parity-tested against, and as the input type
  of the `pool_array` adapter.

* **`PolicySpec` — the parametric policy space (tentpole).**  Every
  what-if fork carries `(family, theta)`: the priority key is a linear
  contraction of a per-job *feature matrix* (wait, est, nodes, area,
  xfactor, submit) against the fork's θ, plus a family-specific
  nonlinear term (WFP-style power utilities, exponential aging).  The
  7 static policies are **fixed points** of this space (e.g. WFP =
  `-(wait/est)^a · nodes^b` with a=3, b=1) and are constructed so
  their keys are *bit-identical* to the integer-id stack: one-hot
  linear weights select single features exactly, and `_pow`
  special-cases small integer exponents so `x^3` lowers to the same
  `x·x·x` as `lax.integer_pow`.

  This is what unlocks DRAS-style parameter sweeps (one fork per grid
  point, Fan & Lan 2021) and RLScheduler-style learned priority
  scorers (Zhang et al. 2020, a learned θ on the `lin` family) riding
  the same fork axis of the batched drain engine — see DESIGN.md §5.

θ deliberately lives in **stage 1** of the engine (keys + argsort,
outside the Pallas scheduling-pass kernel): key evaluation is
embarrassingly parallel and XLA-fused, and the kernel's working set
stays the six queue fields regardless of pool parameterization.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, NamedTuple, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import JobTable

# Canonical ids — tie-break order is numeric order (paper §4.2).
WFP = 0    # ALCF utility: run job maximizing (wait/est)^3 * nodes
FCFS = 1   # first-come-first-served
SJF = 2    # shortest (estimated) job first
# --- beyond-paper pool extensions ---
SAF = 3    # smallest area (nodes * est) first
LJF = 4    # longest job first
LXF = 5    # largest expansion factor first: (wait + est) / est
EXPF = 6   # exponential aging of wait time

POLICY_NAMES = {
    WFP: "WFP", FCFS: "FCFS", SJF: "SJF",
    SAF: "SAF", LJF: "LJF", LXF: "LXF", EXPF: "EXPF",
}
PAPER_POOL: Sequence[int] = (WFP, FCFS, SJF)
EXTENDED_POOL: Sequence[int] = (WFP, FCFS, SJF, SAF, LJF, LXF, EXPF)

_EST_FLOOR = 1.0  # seconds; guards division by tiny estimates


def priority_key(jobs: JobTable, now: jax.Array, policy_id) -> jax.Array:
    """Per-job priority keys (lower = run first) for integer ``policy_id``.

    The pre-parametric 7-row key stack, kept bit-for-bit as the oracle
    `tests/test_policyspec.py` asserts the `PolicySpec` fixed points
    against.  Utility policies (WFP, LXF, EXPF) are re-evaluated at
    every scheduling instance with the current wait time, exactly as a
    live utility scheduler recomputes job scores each cycle.

    Stable argsort + slot-ids-in-submission-order means ties fall back
    to FCFS order, the conventional secondary key.
    """
    wait = jnp.maximum(now - jobs.submit_t, 0.0)
    est = jnp.maximum(jobs.est_runtime, _EST_FLOOR)
    nodes = jobs.nodes.astype(jnp.float32)

    # Scores where higher = more deserving; keys are negated scores.
    wfp_score = (wait / est) ** 3 * nodes
    lxf_score = (wait + est) / est
    expf_score = jnp.expm1(jnp.minimum(wait / 3600.0, 30.0))  # hourly aging

    keys = jnp.stack([
        -wfp_score,            # WFP
        jobs.submit_t,         # FCFS
        est,                   # SJF
        nodes * est,           # SAF
        -est,                  # LJF
        -lxf_score,            # LXF
        -expf_score,           # EXPF
    ])
    return keys[policy_id]


def policy_name(policy_id: int) -> str:
    return POLICY_NAMES[int(policy_id)]


# ======================================================================
# Parametric policy space: PolicySpec = (family, theta)
# ======================================================================

#: Feature-matrix columns (order = θ linear-weight layout).
FEATURES: Tuple[str, ...] = ("wait", "est", "nodes", "area", "xfactor",
                             "submit")
N_FEATURES = len(FEATURES)

# θ layout: [0:N_FEATURES] linear weights over FEATURES, then the
# family-specific nonlinear parameters.
TH_A = N_FEATURES        # WFP family: exponent on wait/est
TH_B = N_FEATURES + 1    # WFP family: exponent on nodes
TH_TAU = N_FEATURES + 2  # WFP/EXP families: aging timescale (seconds)
N_THETA = N_FEATURES + 3

AGING_CAP = 30.0  # cap on wait/tau before exp() — matches legacy EXPF

# Families.
FAM_LIN = 0   # key = Φ·θ_lin                     (FCFS/SJF/SAF/LJF/LXF)
FAM_WFP = 1   # key = Φ·θ_lin - (wait/est)^a · nodes^b · e^min(wait/τ,cap)
FAM_EXP = 2   # key = Φ·θ_lin - expm1(min(wait/τ, cap))        (EXPF)

FAMILY_NAMES = {FAM_LIN: "lin", FAM_WFP: "wfp", FAM_EXP: "expf"}

#: Per-family nonlinear parameters exposed to the sweep grammar,
#: with their fixed-point defaults.
FAMILY_PARAMS: Dict[int, Dict[str, Tuple[int, float]]] = {
    FAM_LIN: {},
    FAM_WFP: {"a": (TH_A, 3.0), "b": (TH_B, 1.0), "tau": (TH_TAU, np.inf)},
    FAM_EXP: {"tau": (TH_TAU, 3600.0)},
}


class PolicySpec(NamedTuple):
    """One policy fork (or a stacked pool of k forks) in parameter space.

    ``family`` — i32, scalar (one fork) or (k,) (a pool).
    ``theta``  — f32, (N_THETA,) or (k, N_THETA): linear feature
    weights followed by the family's nonlinear parameters.

    A PyTree, so a pool rides jit/vmap/sharding like any array: the
    fork axis of the batched drain engine IS the leading axis of both
    leaves, and ``sharded_whatif`` partitions θ together with it.
    """
    family: jax.Array
    theta: jax.Array


def job_features(jobs: JobTable, now: jax.Array) -> jax.Array:
    """The (J, N_FEATURES) feature matrix Φ every priority key is a
    function of.  Columns follow ``FEATURES``; ``est`` is floored at
    ``_EST_FLOOR`` exactly as the legacy key stack does."""
    wait = jnp.maximum(now - jobs.submit_t, 0.0)
    est = jnp.maximum(jobs.est_runtime, _EST_FLOOR)
    nodes = jobs.nodes.astype(jnp.float32)
    return jnp.stack([
        wait,
        est,
        nodes,
        nodes * est,          # area
        (wait + est) / est,   # xfactor (expansion factor)
        jobs.submit_t,
    ], axis=-1)


def _pow(x: jax.Array, p: jax.Array) -> jax.Array:
    """x^p with exact products for the small integer exponents the
    static fixed points use: `x*x*x` is bit-identical to
    `lax.integer_pow(x, 3)` (same association under exponentiation by
    squaring), while `jnp.power` would lower to exp(p·log x) and drift
    in the last ulp.  x must be >= 0 (ratios and node counts are)."""
    return jnp.where(p == 1.0, x,
           jnp.where(p == 2.0, x * x,
           jnp.where(p == 3.0, x * x * x,
                     jnp.power(x, p))))


def priority_key_spec(jobs: JobTable, now: jax.Array,
                      spec: PolicySpec) -> jax.Array:
    """Per-job priority keys (J,) for ONE parametric fork.

    key = Φ·θ_lin + nonlinear(family, θ): the linear contraction is
    shared by every family; WFP/EXP add their nonlinear utility
    (negated — higher utility = lower key = runs first).
    """
    feats = job_features(jobs, now)                     # (J, F)
    wait, est, nodes = feats[:, 0], feats[:, 1], feats[:, 2]

    lin = feats @ spec.theta[:N_FEATURES]               # (J,)

    a, b = spec.theta[TH_A], spec.theta[TH_B]
    tau = spec.theta[TH_TAU]
    aged = jnp.minimum(wait / tau, AGING_CAP)           # 0 when tau=inf
    wfp_nl = -(_pow(wait / est, a) * _pow(nodes, b) * jnp.exp(aged))
    exp_nl = -jnp.expm1(aged)

    nl = jnp.where(spec.family == FAM_WFP, wfp_nl,
         jnp.where(spec.family == FAM_EXP, exp_nl, 0.0))
    return lin + nl


def batched_priority_keys(jobs: JobTable, now: jax.Array,
                          pool_spec: PolicySpec) -> jax.Array:
    """(k, J) priority keys for a whole pool against ONE shared
    snapshot — the first scheduling pass of a decision cycle, before
    fork states diverge.  (Mid-drain, the engine vmaps
    ``priority_key_spec`` over per-fork states instead.)"""
    return jax.vmap(priority_key_spec, in_axes=(None, None, 0))(
        jobs, now, pool_spec)


# ----------------------------------------------------------------------
# Time-invariance: which forks' keys never depend on ``now``?
# ----------------------------------------------------------------------

#: Legacy ids whose key is a pure function of static job fields
#: (submit_t / est / nodes) — WFP, LXF and EXPF re-score with the
#: current wait time every cycle and are excluded.
STATIC_KEY_IDS = frozenset({FCFS, SJF, SAF, LJF})

_WAIT_COL = FEATURES.index("wait")
_XF_COL = FEATURES.index("xfactor")


#: ``time_invariant_mask`` memo: id(leaf)-tuple -> mask.  The
#: ``np.asarray`` over concrete pool leaves is a device sync PER
#: DECISION CYCLE on the hot path (``engine.plan`` runs it every call);
#: the pool arrays are immutable device buffers, so identity is a
#: sound cache key as long as entries are evicted when the leaves die
#: (``weakref.finalize`` below — never on raw id reuse).
_TI_MASK_CACHE: dict = {}


def time_invariant_mask(pool) -> np.ndarray:
    """Host-side (k,) bool: forks whose priority keys are independent
    of the clock, so their argsort can be hoisted OUT of the per-event
    loop (DESIGN.md §7).

    A fork qualifies iff its key is a function of static job fields
    only (``submit_t``/``est``/``nodes``/``area``):

    * ``lin``-family specs with zero weight on the ``wait`` and
      ``xfactor`` feature columns (FCFS, SJF, SAF, LJF and most learned
      scorers sit here);
    * legacy ids in ``STATIC_KEY_IDS``.

    ``wfp``/``expf`` family forks always re-score with the current wait
    time, so they stay time-varying regardless of θ.  The mask is a
    *host* computation over concrete pool arrays — it partitions the
    fork axis statically, before jit — memoized per pool identity so
    the repeated device->host sync disappears from the cycle loop."""
    import weakref
    leaves = ((pool.family, pool.theta) if isinstance(pool, PolicySpec)
              else (pool,))
    key = tuple(id(leaf) for leaf in leaves)
    hit = _TI_MASK_CACHE.get(key)
    if hit is not None:
        return hit
    if isinstance(pool, PolicySpec):
        fam = np.asarray(pool.family).reshape(-1)
        th = np.asarray(pool.theta).reshape(fam.shape[0], -1)
        mask = ((fam == FAM_LIN)
                & (th[:, _WAIT_COL] == 0.0)
                & (th[:, _XF_COL] == 0.0))
    else:
        ids = np.asarray(pool).reshape(-1)
        mask = np.isin(ids, sorted(STATIC_KEY_IDS))
    mask.setflags(write=False)
    try:
        for leaf in leaves:
            weakref.finalize(leaf, _TI_MASK_CACHE.pop, key, None)
    except TypeError:
        return mask          # un-weakref-able leaf: serve uncached
    _TI_MASK_CACHE[key] = mask
    return mask


# ----------------------------------------------------------------------
# Spec constructors: families and the 7 static fixed points.
# ----------------------------------------------------------------------

def _base_theta() -> np.ndarray:
    th = np.zeros((N_THETA,), dtype=np.float32)
    th[TH_TAU] = np.inf  # aged = wait/inf = 0: aging off by default
    return th


def linear_spec(**weights: float) -> PolicySpec:
    """`lin` family: key = Σ w_f · feature_f.  Keyword names index
    ``FEATURES`` (e.g. ``linear_spec(est=1.0)`` is SJF).  A learned
    priority scorer (RLScheduler-style) is just a trained θ here."""
    th = _base_theta()
    for name, w in weights.items():
        if name not in FEATURES:
            raise ValueError(f"unknown feature {name!r}; have {FEATURES}")
        th[FEATURES.index(name)] = w
    return PolicySpec(jnp.int32(FAM_LIN), jnp.asarray(th))


def wfp_spec(a: float = 3.0, b: float = 1.0,
             tau: float = np.inf) -> PolicySpec:
    """`wfp` family: key = -(wait/est)^a · nodes^b · e^min(wait/τ, cap).
    Defaults (a=3, b=1, τ=∞) are the paper's WFP exactly; sweeping
    (a, τ) is the DRAS-style dynamic parameterization axis."""
    if tau <= 0:
        raise ValueError(f"wfp tau must be > 0, got {tau}")
    th = _base_theta()
    th[TH_A], th[TH_B], th[TH_TAU] = a, b, tau
    return PolicySpec(jnp.int32(FAM_WFP), jnp.asarray(th))


def exp_spec(tau: float = 3600.0) -> PolicySpec:
    """`expf` family: key = -expm1(min(wait/τ, cap)).  τ=3600 is the
    legacy EXPF (hourly aging)."""
    if tau <= 0:
        raise ValueError(f"expf tau must be > 0, got {tau}")
    th = _base_theta()
    th[TH_TAU] = tau
    return PolicySpec(jnp.int32(FAM_EXP), jnp.asarray(th))


#: The 7 static policies as fixed points of the parametric space.
_STATIC_SPECS = {
    WFP: lambda: wfp_spec(),
    FCFS: lambda: linear_spec(submit=1.0),
    SJF: lambda: linear_spec(est=1.0),
    SAF: lambda: linear_spec(area=1.0),
    LJF: lambda: linear_spec(est=-1.0),
    LXF: lambda: linear_spec(xfactor=-1.0),
    EXPF: lambda: exp_spec(),
}


def static_spec(policy_id: int) -> PolicySpec:
    """The parametric fixed point of one integer policy id.  Its keys
    are bit-identical to ``priority_key(jobs, now, policy_id)``."""
    return _STATIC_SPECS[int(policy_id)]()


def stack_specs(specs: Sequence[PolicySpec]) -> PolicySpec:
    """Stack scalar specs into a pool with a leading fork axis."""
    if not specs:
        raise ValueError("empty policy pool")
    return PolicySpec(
        family=jnp.stack([s.family for s in specs]),
        theta=jnp.stack([s.theta for s in specs]),
    )


def spec_rows(pool: PolicySpec) -> List[PolicySpec]:
    """The scalar per-fork specs of a stacked pool (host-side)."""
    fam = np.asarray(pool.family)
    th = np.asarray(pool.theta)
    return [PolicySpec(jnp.int32(int(fam[i])), jnp.asarray(th[i]))
            for i in range(fam.shape[0])]


def describe_spec(family: int, theta: np.ndarray) -> str:
    """Human-readable name for one fork: canonical static names where
    θ sits exactly on a fixed point, else ``family[k=v,...]``."""
    family = int(family)
    theta = np.asarray(theta)
    for pid, ctor in _STATIC_SPECS.items():
        ref = ctor()
        if int(ref.family) == family and np.array_equal(
                np.asarray(ref.theta), theta.astype(np.float32)):
            return POLICY_NAMES[pid]
    parts = []
    if family == FAM_LIN:
        for i, fname in enumerate(FEATURES):
            if theta[i] != 0.0:
                parts.append(f"{fname}={theta[i]:g}")
    else:
        for pname, (idx, default) in FAMILY_PARAMS[family].items():
            if theta[idx] != np.float32(default):
                parts.append(f"{pname}={theta[idx]:g}")
    base = FAMILY_NAMES[family]
    return f"{base}[{','.join(parts)}]" if parts else base


# ----------------------------------------------------------------------
# PolicyPool: the user-facing pool (specs + display names) + grammar.
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class PolicyPool:
    """A candidate pool: stacked ``PolicySpec`` + per-fork names.

    Pool *position* is tie-break priority (``select_policy`` is an
    argmin with first-occurrence wins), exactly as with the legacy id
    arrays.  ``spec`` is what flows into the engine; ``names`` feed
    telemetry/scoring reports.
    """
    spec: PolicySpec
    names: Tuple[str, ...]

    def __post_init__(self) -> None:
        if len(self.names) != self.spec.family.shape[0]:
            raise ValueError(
                f"{len(self.names)} names for "
                f"{self.spec.family.shape[0]} forks")

    def __len__(self) -> int:
        return self.spec.family.shape[0]

    @property
    def size(self) -> int:
        return len(self)

    def __add__(self, other: "PolicyPool") -> "PolicyPool":
        return PolicyPool(
            spec=PolicySpec(
                jnp.concatenate([self.spec.family, other.spec.family]),
                jnp.concatenate([self.spec.theta, other.spec.theta])),
            names=self.names + other.names)

    def fork(self, p: int) -> PolicySpec:
        """Fork p as a scalar ``PolicySpec`` — e.g. to baseline one
        pool member through the emulator's static mode."""
        return PolicySpec(self.spec.family[p], self.spec.theta[p])

    @classmethod
    def from_ids(cls, ids: Sequence[int]) -> "PolicyPool":
        """Static fixed points for a legacy id pool (caller's order =
        tie-break order, as with ``pool_array``)."""
        ids = [int(i) for i in np.asarray(list(ids))]
        return cls(spec=stack_specs([static_spec(i) for i in ids]),
                   names=tuple(POLICY_NAMES[i] for i in ids))

    @classmethod
    def from_specs(cls, specs: Sequence[PolicySpec],
                   names: Sequence[str] | None = None) -> "PolicyPool":
        pool = stack_specs(list(specs))
        if names is None:
            names = [describe_spec(s.family, np.asarray(s.theta))
                     for s in specs]
        return cls(spec=pool, names=tuple(names))


def theta_pool(family: int, thetas: np.ndarray,
               names: Sequence[str] | None = None) -> PolicyPool:
    """Pool construction from trained θ: (N, N_THETA) rows of ONE
    family become a PolicyPool riding the fork axis — this is how the
    ``learn`` trainer evaluates a whole candidate generation as one
    replay grid, and how a checkpointed θ deploys."""
    th = np.asarray(thetas, np.float32)
    if th.ndim == 1:
        th = th[None, :]
    if th.ndim != 2 or th.shape[1] != N_THETA:
        raise ValueError(f"thetas must be (N, {N_THETA}), got {th.shape}")
    if int(family) not in FAMILY_NAMES:
        raise ValueError(f"unknown family {family}; have {FAMILY_NAMES}")
    spec = PolicySpec(jnp.full((th.shape[0],), int(family), jnp.int32),
                      jnp.asarray(th))
    if names is None:
        names = [describe_spec(int(family), th[i])
                 for i in range(th.shape[0])]
    return PolicyPool(spec=spec, names=tuple(names))


_STATIC_BY_NAME = {POLICY_NAMES[i].lower(): i for i in EXTENDED_POOL}
_FAMILY_BY_NAME = {v: k for k, v in FAMILY_NAMES.items()}


def _parse_values(text: str) -> List[float]:
    """``v`` -> [v];  ``lo..hixN`` -> linspace(lo, hi, N)."""
    if ".." in text:
        lo_s, rest = text.split("..", 1)
        if "x" not in rest:
            raise ValueError(
                f"sweep {text!r} must be 'lo..hixN' (e.g. 1..5x5)")
        hi_s, n_s = rest.rsplit("x", 1)
        n = int(n_s)
        if n < 2:
            raise ValueError(f"sweep {text!r} needs >= 2 points")
        return [float(v) for v in np.linspace(float(lo_s), float(hi_s), n)]
    return [float(text)]


def parse_pool(grammar: str) -> PolicyPool:
    """Expand a pool grammar into a PolicyPool — one fork per grid point.

    Grammar: comma-separated terms, each
    ``name[:param=value | :param=lo..hixN]...`` where multiple swept
    params take their cartesian product (rightmost fastest):

      ``paper``                      -> WFP, FCFS, SJF (statics)
      ``extended``                   -> all 7 statics
      ``wfp,fcfs,sjf``               -> 3 static fixed points
      ``wfp:a=2``                    -> one parametric WFP fork
      ``wfp:a=1..5x5:tau=600..7200x5`` -> 25-point DRAS-style grid
      ``expf:tau=600``               -> fast-aging EXPF
      ``lin:est=1:wait=-0.01``       -> linear scorer over features
      ``trained:<ckpt-dir>``         -> learned θ from a checkpoint
                                        (``learn.train``); statics can
                                        ride alongside as a safety
                                        floor: ``trained:ckpt,paper``

    Term order is tie-break priority, matching ``pool_array``.
    """
    specs: List[PolicySpec] = []
    names: List[str] = []
    for term in (t.strip() for t in grammar.split(",")):
        if not term:
            continue
        if term.lower().startswith("trained:"):
            # Everything after the prefix is a filesystem path — keep
            # it out of the ":"-assignment split below.
            path = term[len("trained:"):].strip()
            if not path:
                raise ValueError(
                    "trained: needs a checkpoint dir, e.g. "
                    "trained:checkpoints/policy")
            from repro.learn.trainer import load_trained_pool  # lazy: learn imports core
            trained = load_trained_pool(path)
            specs.extend(spec_rows(trained.spec))
            names.extend(trained.names)
            continue
        head, *assigns = term.split(":")
        name = head.strip().lower()
        if not assigns:
            if name == "paper":
                ids = PAPER_POOL
            elif name == "extended":
                ids = EXTENDED_POOL
            elif name in _STATIC_BY_NAME:
                ids = (_STATIC_BY_NAME[name],)
            elif name in _FAMILY_BY_NAME:
                # bare family name -> its default point
                fam = _FAMILY_BY_NAME[name]
                spec = {FAM_LIN: linear_spec, FAM_WFP: wfp_spec,
                        FAM_EXP: exp_spec}[fam]()
                specs.append(spec)
                names.append(describe_spec(spec.family,
                                           np.asarray(spec.theta)))
                continue
            else:
                raise ValueError(
                    f"unknown pool term {head!r}; statics: "
                    f"{sorted(_STATIC_BY_NAME)}, families: "
                    f"{sorted(_FAMILY_BY_NAME)}")
            for pid in ids:
                specs.append(static_spec(pid))
                names.append(POLICY_NAMES[pid])
            continue

        # parametric term: resolve the family
        if name in _FAMILY_BY_NAME:
            fam = _FAMILY_BY_NAME[name]
        else:
            raise ValueError(
                f"term {head!r} takes no parameters; parametric "
                f"families: {sorted(_FAMILY_BY_NAME)}")

        keys: List[str] = []
        grids: List[List[float]] = []
        for assign in assigns:
            if "=" not in assign:
                raise ValueError(f"bad assignment {assign!r} in {term!r}")
            key, val = assign.split("=", 1)
            key = key.strip().lower()
            if fam == FAM_LIN:
                if key not in FEATURES:
                    raise ValueError(
                        f"lin weights index features {FEATURES}, "
                        f"got {key!r}")
            elif key not in FAMILY_PARAMS[fam]:
                raise ValueError(
                    f"{FAMILY_NAMES[fam]!r} params are "
                    f"{sorted(FAMILY_PARAMS[fam])}, got {key!r}")
            keys.append(key)
            grids.append(_parse_values(val.strip()))

        for combo in itertools.product(*grids):
            kw = dict(zip(keys, combo))
            if fam == FAM_LIN:
                spec = linear_spec(**kw)
            elif fam == FAM_WFP:
                spec = wfp_spec(**kw)
            else:
                spec = exp_spec(**kw)
            specs.append(spec)
            label = ",".join(f"{k}={v:g}" for k, v in zip(keys, combo))
            names.append(f"{FAMILY_NAMES[fam]}[{label}]")
    return PolicyPool.from_specs(specs, names)


PoolLike = Union["PolicyPool", PolicySpec, str, jax.Array,
                 np.ndarray, Sequence[int]]


def normalize_pool(pool: PoolLike) -> PolicyPool:
    """Coerce any pool representation to a PolicyPool:

    * ``PolicyPool``        — returned as is;
    * ``PolicySpec`` (k,)   — named via ``describe_spec``;
    * ``str``               — sweep grammar (``parse_pool``);
    * id array / sequence   — static fixed points (``from_ids``).
    """
    if isinstance(pool, PolicyPool):
        return pool
    if isinstance(pool, PolicySpec):
        if pool.family.ndim == 0:  # scalar fork -> k=1 pool
            pool = PolicySpec(pool.family.reshape(1),
                              pool.theta.reshape(1, -1))
        fam = np.asarray(pool.family)
        th = np.asarray(pool.theta)
        return PolicyPool(
            spec=pool,
            names=tuple(describe_spec(fam[i], th[i])
                        for i in range(fam.shape[0])))
    if isinstance(pool, str):
        return parse_pool(pool)
    return PolicyPool.from_ids(pool)
