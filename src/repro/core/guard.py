"""Deadline guard + degradation ladder (DESIGN.md §12).

The paper's real-time contract is that the twin keeps "a few seconds
per scheduling cycle" of overhead against a live stream — but a racing
fan cycle's latency is workload-dependent, and a decision that arrives
after the physical scheduler needed it is worth nothing.  The guard
puts every decision cycle under a configurable wall-clock budget and
walks a **degradation ladder** when the budget comes under pressure,
so ``qrun`` is ALWAYS fed a decision on time — a cheaper decision
beats a late one:

  level 0  full decision (race / fan / ensemble, as configured)
  level 1  shrunk race: ``budget_ms`` and fan F cut to fit the margin
  level 2  static fallback pool (the paper's §4.1 {WFP, FCFS, SJF}),
           single-future decide — the paper's own baseline twin
  level 3  hold the incumbent: re-issue the last chosen policy with
           one k=1 schedule pass (no pool comparison at all)

The controller is *predictive + reactive*: it keeps a per-level EWMA
of observed cycle latencies and refuses to run a level whose estimate
exceeds ``safety × budget`` (predictive — the cycle that WOULD have
missed is degraded before it runs), and any actual overrun escalates
immediately (reactive).  De-escalation is hysteretic: only after
``recover_after`` consecutive comfortable cycles does the guard step
back down one level, so a borderline workload doesn't oscillate.

Determinism: the guard's decisions are a pure function of the observed
latency sequence, and the clock is injectable (the same seam
``race.run_race`` exposes), so tests drive the whole ladder with a
fake clock and the chaos benchmark's kill+resume gate can reproduce
ladder decisions bitwise — the guard state is snapshot-serializable
via ``to_dict``/``from_dict``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["GuardSpec", "DeadlineGuard", "LEVEL_NAMES"]

LEVEL_NAMES = ("full", "shrunk_race", "static_pool", "hold_incumbent")


@dataclasses.dataclass(frozen=True)
class GuardSpec:
    """Deadline-guard configuration.

    ``budget_s <= 0`` disables the guard entirely (every cycle runs at
    level 0, nothing is stamped as guarded).  ``safety`` is the
    fraction of the budget a level's latency estimate must fit inside
    to be allowed to run (and to count as a comfortable cycle for
    recovery).  ``shrink`` is the factor applied to the race
    ``budget_ms`` / fan F at level 1."""

    budget_s: float = 0.0       # wall-clock budget per decision cycle
    safety: float = 0.8         # planning headroom fraction
    ewma_alpha: float = 0.4     # latency-estimate smoothing
    recover_after: int = 3      # comfy cycles before stepping down
    max_level: int = 3          # deepest ladder level the guard may use
    shrink: float = 0.25        # level-1 race-budget / fan-F factor

    def __post_init__(self) -> None:
        if not 0.0 < self.safety <= 1.0:
            raise ValueError("safety must be in (0, 1]")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if self.recover_after < 1:
            raise ValueError("recover_after must be >= 1")
        if not 0 <= self.max_level <= 3:
            raise ValueError("max_level must be in [0, 3]")
        if not 0.0 < self.shrink <= 1.0:
            raise ValueError("shrink must be in (0, 1]")

    @property
    def enabled(self) -> bool:
        return self.budget_s > 0.0


class DeadlineGuard:
    """The ladder controller.  One instance per twin; host-side only."""

    def __init__(self, spec: GuardSpec):
        self.spec = spec
        self.level = 0                       # current operating level
        self._est: Dict[int, float] = {}     # per-level latency EWMA
        self._comfy = 0                      # consecutive easy cycles
        self.misses = 0
        self.engagements = 0                 # cycles planned at level>0

    # -- planning (before the cycle runs) ------------------------------
    def plan(self) -> int:
        """Level this cycle must run at.  Escalates past any level whose
        latency estimate exceeds the safety margin; never skips levels
        it has no estimate for (optimism: an untried level gets one
        chance to prove itself before the reactive path escalates)."""
        if not self.spec.enabled:
            return 0
        lvl = self.level
        headroom = self.spec.safety * self.spec.budget_s
        while (lvl < self.spec.max_level
               and self._est.get(lvl, 0.0) > headroom):
            lvl += 1
        self.level = lvl
        if lvl > 0:
            self.engagements += 1
        return lvl

    # -- observation (after the cycle ran) ------------------------------
    def observe(self, level: int,
                seconds: float) -> Tuple[bool, float]:
        """Record one cycle's wall time at ``level``.  Returns
        ``(missed, margin_s)``; escalates on a miss, steps down one
        level after ``recover_after`` consecutive comfortable cycles."""
        if not self.spec.enabled:
            return False, 0.0
        a = self.spec.ewma_alpha
        prev = self._est.get(level)
        self._est[level] = (seconds if prev is None
                            else (1.0 - a) * prev + a * seconds)
        margin = self.spec.budget_s - seconds
        missed = margin < 0.0
        if missed:
            self.misses += 1
            self.level = min(level + 1, self.spec.max_level)
            self._comfy = 0
        elif seconds <= self.spec.safety * self.spec.budget_s:
            self._comfy += 1
            if self.level > 0 and self._comfy >= self.spec.recover_after:
                self.level -= 1
                self._comfy = 0
        else:
            self._comfy = 0      # made it, but without headroom
        return missed, margin

    # -- snapshot serialization (JSON-safe) -----------------------------
    def to_dict(self) -> Dict:
        return {"level": self.level,
                "est": {str(k): v for k, v in self._est.items()},
                "comfy": self._comfy, "misses": self.misses,
                "engagements": self.engagements}

    def restore(self, d: Optional[Dict]) -> "DeadlineGuard":
        if d:
            self.level = int(d["level"])
            self._est = {int(k): float(v)
                         for k, v in d.get("est", {}).items()}
            self._comfy = int(d.get("comfy", 0))
            self.misses = int(d.get("misses", 0))
            self.engagements = int(d.get("engagements", 0))
        return self
