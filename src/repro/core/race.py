"""Adaptive fan racing: successive halving over the fan substrate
(DESIGN.md §11).

A fixed-F fan (§10) spends ``S·F·P`` members per decision no matter how
obvious the winner is.  Racing spends members only where the decision
is still statistically open: every policy starts at a low rung ``F₀``;
after each rung the per-policy costs and CIs over the members so far
are computed ON DEVICE (``rung_stats`` — the goal's distributional
reduction plus ``engine.member_uncertainty``); policies whose CI lower
bound exceeds the incumbent's CI upper bound are eliminated; the fan
doubles for survivors.  The unlock is the §10 CRN prefix-stability:
member draws key on ``fold_in(fold_in(key, s), φ)`` — independent of F
— so rung i+1 replays ONLY the new member suffix
(``engine.fan_window_grid`` / ``_decide_fan_window``) and concatenates
it with the donated prior-rung members.  No (scenario, policy, member)
triple is ever replayed twice.

Elimination rule (per scenario s, incumbent i = argmin cost):

    drop p  iff  cost[s,p] − z·σₚ/√f  >  cost[s,i] + z·σᵢ/√f   (strict)

Strict ``>`` means exact ties (CRN-identical member costs) never
eliminate each other, and a non-finite bound (a +inf member poisons the
CI to +inf) never eliminates — deadlock-tainted policies survive to
full fidelity rather than being guessed away.  A policy leaves the
replay rectangle only when eliminated in EVERY scenario; the incumbent
of any scenario is never eliminated there, so each scenario's running
winner always survives to the end and the final argmin is unchanged by
the drops.  With an unbounded budget the race therefore returns the
same argmin as the full-F ``fan_grid`` on every (scenario, objective)
cell whenever the CI rule held — property-tested (tests/test_race.py)
and gated per workload by ``benchmarks/race.py``, not assumed.

Termination is ANYTIME: the race stops early when every scenario's
winner CI-separates from all surviving rivals (``separation > 0``), or
when ``RaceSpec.budget_ms`` / ``max_members`` is exhausted mid-race —
in every case returning the current best with its achieved confidence
(``RaceOutcome.separation``/``stopped``).  Rung windows are a fixed
schedule (``RaceSpec.rungs()``), so each (rung width, survivor count)
pair compiles once and is reused across cycles.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fan import FanSpec, normalize_fan

__all__ = [
    "RaceSpec", "RungRecord", "RaceOutcome", "normalize_race",
    "rung_stats", "race_grid", "decide_race",
]


@dataclasses.dataclass(frozen=True)
class RaceSpec:
    """Racing schedule over a ``FanSpec``'s members.

    ``fan.n`` is F_max — the full-fidelity fan a non-raced ``fan_grid``
    would evaluate (and the fidelity survivors reach when nothing
    separates).  Frozen + hashable, like every other static config.
    """

    fan: FanSpec = FanSpec(n=64)
    f0: int = 8                # rung-0 members (capped at fan.n)
    growth: int = 2            # fan multiplier between rungs
    z: float = 1.96            # CI multiplier for elimination/separation
    budget_ms: Optional[float] = None   # wall-clock budget per race
    max_members: Optional[int] = None   # (s, φ, p) triple budget per race

    def __post_init__(self) -> None:
        if self.f0 < 1:
            raise ValueError(f"f0 must be >= 1, got {self.f0}")
        if self.growth < 2:
            raise ValueError(f"growth must be >= 2, got {self.growth}")
        if self.z <= 0.0:
            raise ValueError("z must be positive")
        if self.budget_ms is not None and self.budget_ms <= 0.0:
            raise ValueError("budget_ms must be positive")
        if self.max_members is not None and self.max_members < 1:
            raise ValueError("max_members must be >= 1")

    @property
    def f_max(self) -> int:
        return self.fan.n

    def rungs(self) -> Tuple[Tuple[int, int], ...]:
        """The fixed member-window schedule ``[(0, F₀), (F₀, F₀·g),
        ...]``, capped at F_max — rung i replays ONLY window
        ``[lo, hi)``; cumulative fidelity after rung i is ``hi``."""
        hi = min(self.f0, self.f_max)
        out = [(0, hi)]
        while hi < self.f_max:
            lo, hi = hi, min(hi * self.growth, self.f_max)
            out.append((lo, hi))
        return tuple(out)


def normalize_race(race) -> RaceSpec:
    """Accept a ``RaceSpec``, a ``FanSpec`` (raced to ``spec.n`` under
    the default schedule), or a bare int F_max (degenerate fan)."""
    if isinstance(race, RaceSpec):
        return race
    return RaceSpec(fan=normalize_fan(race))


class RungRecord(NamedTuple):
    """Accounting for one executed rung."""
    lo: int                      # member window replayed: [lo, hi)
    hi: int
    active: Tuple[int, ...]      # full-pool indices evaluated this rung
    members: int                 # (s, φ, p) triples replayed this rung
    eliminated: Tuple[int, ...]  # indices dropped from the rectangle
    separation: float            # min-scenario rival_lb − winner_ub
    wall_s: float


class RaceOutcome(NamedTuple):
    """What a race decided and what it paid (host-side: the race
    controller is a host loop over device rungs, so the arrays land as
    numpy).  Policy columns cover the SURVIVING rectangle ``keep``
    (full-pool indices, ascending); ``best`` is already mapped back to
    full-pool indices."""
    member_costs: np.ndarray     # (S, fan_size, len(keep)) accumulated
    costs: np.ndarray            # (S, len(keep)) reduced at fan_size
    best: np.ndarray             # (S,) winners as FULL-pool indices
    cost_ci: np.ndarray          # (S, len(keep)) z-scaled CI half-width
    fan_width: np.ndarray        # (S, len(keep)) member-cost spread
    keep: np.ndarray             # surviving full-pool indices
    rungs: Tuple[RungRecord, ...]
    members: int                 # triples replayed across all rungs
    members_full: int            # S·F_max·P — the fixed-F bill
    fan_size: int                # members behind costs (last rung's hi)
    separated: bool              # every scenario separated at the end
    separation: np.ndarray       # (S,) achieved rival_lb − winner_ub
    stopped: str  # 'separated' | 'budget_ms' | 'max_members' | 'exhausted'
    passes: int = 0              # DES pass_invocations across all rungs
    #                              (0 on surfaces that don't expose it)


@functools.partial(jax.jit, static_argnames=("dist",))
def _rung_stats_impl(dist, member: jax.Array, scale: float):
    from repro.core.engine import member_uncertainty
    costs = dist.reduce_fan(member)
    ci, width = member_uncertainty(member, axis=-2)
    return costs, ci * scale, width


def rung_stats(objective, member, z: float = 1.96):
    """Per-policy decision stats over the members accumulated so far:
    the goal's distributional reduction (what the argmin selects) plus
    the z-scaled CI half-width and member spread — computed on device
    (``engine.member_uncertainty`` emits ``1.96·σ/√f``; rescaled to
    ``z``).  ``member`` is (S, f, Pa); any +inf member poisons that
    cell's CI/width to +inf, which the elimination rule treats as
    "never eliminate"."""
    from repro.core.objective import as_distributional
    dist = as_distributional(objective)
    return _rung_stats_impl(dist, jnp.asarray(member), z / 1.96)


def _separation(costs: np.ndarray, ci: np.ndarray) -> np.ndarray:
    """(S,) how far the winner's CI upper bound sits below EVERY
    rival's lower bound (min over rivals); positive ⇒ the scenario's
    decision is settled at z confidence.  +inf with a single column;
    non-finite bound arithmetic (inf − inf) counts as unseparated."""
    S, Pa = costs.shape
    if Pa == 1:
        return np.full(S, np.inf, np.float32)
    with np.errstate(invalid="ignore"):
        lb = costs - ci
        ub = costs + ci
        rows = np.arange(S)
        inc = np.argmin(costs, axis=1)
        lb_rivals = lb.copy()
        lb_rivals[rows, inc] = np.inf
        sep = lb_rivals.min(axis=1) - ub[rows, inc]
    return np.where(np.isnan(sep), -np.inf, sep).astype(np.float32)


def run_race(spec: RaceSpec, S: int, P: int, objective,
             eval_window: Callable[[np.ndarray, int, int], np.ndarray],
             on_rung: Optional[Callable] = None,
             clock: Callable[[], float] = time.perf_counter
             ) -> RaceOutcome:
    """The racing controller, shared by the grid, sharded, and drain
    surfaces.  ``eval_window(active, lo, hi)`` replays ONLY members
    ``φ ∈ [lo, hi)`` for the full-pool indices ``active`` and returns
    their (S, hi−lo, len(active)) member costs (+inf-poisoned for
    deadlocks); everything else — accumulation, CI elimination,
    separation, budgets — happens here, identically on every surface.
    ``on_rung(active, costs, ci, width)`` (post-rung, pre-drop) lets
    callers mirror per-policy stats for eliminated columns."""
    schedule = spec.rungs()
    active = np.arange(P)
    elim = np.zeros((S, P), bool)        # per-scenario CI eliminations
    buf = np.full((S, spec.f_max, P), np.nan, np.float32)
    rungs = []
    spent = 0
    rows = np.arange(S)
    t0 = clock()
    stopped = "exhausted"
    costs = ci = width = None
    f_done = 0

    for lo, hi in schedule:
        w = hi - lo
        if lo > 0:       # rung 0 always runs: anytime ⇒ SOME answer
            if (spec.budget_ms is not None
                    and (clock() - t0) * 1e3 >= spec.budget_ms):
                stopped = "budget_ms"
                break
            if (spec.max_members is not None
                    and spent + S * w * len(active) > spec.max_members):
                stopped = "max_members"
                break
        t_r = clock()
        # Prefix-reuse invariant: the window being paid for has never
        # been evaluated (the buffer cell is still NaN).  This is the
        # "no (s, φ, p) triple replayed twice" guarantee, enforced —
        # not assumed — on every surface that goes through run_race.
        if not np.isnan(buf[:, lo:hi, :][:, :, active]).all():
            raise RuntimeError(
                f"racing window [{lo}, {hi}) would replay an already-"
                f"evaluated member")
        mc = np.asarray(eval_window(active, lo, hi), np.float32)
        buf[:, lo:hi, active] = mc
        spent += S * w * len(active)
        f_done = hi
        cur = buf[:, :hi, :][:, :, active]           # (S, hi, Pa)
        costs, ci, width = (np.asarray(x) for x in
                            rung_stats(objective, cur, spec.z))
        if on_rung is not None:
            on_rung(active, costs, ci, width)

        # CI elimination: strict ``>`` (ties survive) on possibly
        # non-finite bounds (``nan > x`` is False — +inf-poisoned CIs
        # never eliminate); each scenario's incumbent is immune there.
        inc = np.argmin(costs, axis=1)
        with np.errstate(invalid="ignore"):
            kill = (costs - ci) > (costs + ci)[rows, inc][:, None]
        kill[rows, inc] = False
        el = elim[:, active] | kill
        elim[:, active] = el
        survives = ~el.all(axis=0)                   # (Pa,)
        dropped = active[~survives]
        sep = _separation(costs, ci)
        rungs.append(RungRecord(
            lo=lo, hi=hi, active=tuple(int(i) for i in active),
            members=S * w * len(active),
            eliminated=tuple(int(i) for i in dropped),
            separation=float(sep.min()), wall_s=clock() - t_r))

        # Restrict the carried stats to survivors so an early budget
        # stop on the NEXT rung still reports a consistent rectangle.
        active = active[survives]
        costs, ci, width = (x[:, survives] for x in (costs, ci, width))
        if len(active) == 1 or sep.min() > 0.0:
            stopped = "separated"
            break

    sep = _separation(costs, ci)
    best_col = np.argmin(costs, axis=1)
    return RaceOutcome(
        member_costs=buf[:, :f_done, :][:, :, active],
        costs=costs,
        best=active[best_col],
        cost_ci=ci,
        fan_width=width,
        keep=active,
        rungs=tuple(rungs),
        members=spent,
        members_full=S * spec.f_max * P,
        fan_size=f_done,
        separated=bool((sep > 0.0).all()),
        separation=sep,
        stopped=stopped,
    )


# ----------------------------------------------------------------------
# Grid surface: the raced replay grid.
# ----------------------------------------------------------------------

def race_grid(scenarios, pool, race, objective=None, *,
              engine=None) -> RaceOutcome:
    """Race the (scenario × policy) fan grid: rung suffixes come from
    ``engine.fan_window_grid`` over the surviving sub-pool (ascending
    indices, so the argmin tie-break matches the full pool's).  With an
    unbounded budget this selects the same winner as the full-F
    ``fan_grid`` on every scenario (module docstring; property-tested).
    """
    from repro.core import engine as _eng
    eng = engine if engine is not None else _eng.DEFAULT_ENGINE
    spec = normalize_race(race)
    from repro.core.objective import resolve_goal
    goal = resolve_goal(objective)
    pool = _eng.as_pool(pool)
    P = _eng.pool_size(pool)
    S = int(scenarios.total_nodes.shape[0])
    sub_pools = {}
    passes = [0]

    def eval_window(active, lo, hi):
        key = tuple(int(i) for i in active)
        sub = sub_pools.get(key)
        if sub is None:
            sub = (pool if len(active) == P
                   else _eng._index_pool(pool, jnp.asarray(active)))
            sub_pools[key] = sub
        out = eng.fan_window_grid(scenarios, sub, spec.fan, goal,
                                  lo=lo, width=hi - lo)
        passes[0] += int(out.result.pass_invocations)
        return out.member_costs

    out = run_race(spec, S, P, goal, eval_window)
    return out._replace(passes=passes[0])


# ----------------------------------------------------------------------
# Drain surface: the raced decision cycle.
# ----------------------------------------------------------------------

def decide_race(state, pool, race, objective=None, *, engine=None):
    """One raced decision cycle: ``decide_fan``'s member fan grown rung
    by rung (``engine._decide_fan_window``) with CI elimination and
    anytime budgets.  Returns ``(Decision, RaceOutcome)`` — the
    decision spans the FULL pool (eliminated policies keep the
    costs/CI from their elimination rung; their members simply stopped
    growing), ``fan_size`` is the fidelity the survivors reached, and
    the qrun set comes from member 0 of the winner (member 0 is exact
    and always in rung 0)."""
    from repro.core import engine as _eng
    from repro.core.objective import as_distributional, resolve_goal
    eng = engine if engine is not None else _eng.DEFAULT_ENGINE
    spec = normalize_race(race)
    goal = resolve_goal(objective)
    dist = as_distributional(goal)
    pool = _eng.as_pool(pool)
    k = _eng.pool_size(pool)

    sub_pools = {}
    full = {"costs": np.full(k, np.inf, np.float32),
            "ci": np.full(k, np.inf, np.float32),
            "width": np.full(k, np.inf, np.float32)}
    dead = np.zeros(k, bool)
    msum = None                      # metric sums per policy (tree)
    mcount = np.zeros(k, np.int64)
    first0 = {}

    def eval_window(active, lo, hi):
        nonlocal msum
        key = tuple(int(i) for i in active)
        sub = sub_pools.get(key)
        if sub is None:
            sub = (pool if len(active) == k
                   else _eng._index_pool(pool, jnp.asarray(active)))
            sub_pools[key] = sub
        mc, md, mm, f0 = _eng._decide_fan_window(
            eng, state, sub, spec.fan, goal, eng.plan(sub),
            lo, hi - lo)
        if lo == 0:
            first0["mask"] = np.asarray(f0)      # (k, J): rung 0 = full pool
        dead[active] |= np.asarray(md).any(axis=0)
        sums = jax.tree.map(lambda x: np.asarray(x).sum(axis=0,
                                                        dtype=np.float64),
                            mm)
        if msum is None:
            msum = jax.tree.map(lambda s: np.zeros(k, np.float64), sums)
        msum = jax.tree.map(
            lambda acc, s: _scatter_add(acc, active, s), msum, sums)
        mcount[active] += hi - lo
        return np.asarray(mc)[None]              # (S=1, W, Pa)

    def on_rung(active, costs, ci, width):
        full["costs"][active] = costs[0]
        full["ci"][active] = ci[0]
        full["width"][active] = width[0]

    out = run_race(spec, 1, k, goal, eval_window, on_rung=on_rung)

    mean_metrics = jax.tree.map(
        lambda s: jnp.asarray(s / np.maximum(mcount, 1), jnp.float32),
        msum)
    best = int(out.best[0])
    decision = _eng.Decision(
        policy_index=jnp.asarray(best),
        costs=jnp.asarray(full["costs"]),
        run_mask=jnp.asarray(first0["mask"][best]),
        metrics=mean_metrics,
        deadlocked=jnp.asarray(dead),
        cost_terms=dist.cost_terms(mean_metrics),
        cost_ci=jnp.asarray(full["ci"]),
        fan_width=jnp.asarray(full["width"]),
        fan_size=out.fan_size,
    )
    return decision, out


def _scatter_add(acc: np.ndarray, idx: np.ndarray, val: np.ndarray):
    acc = acc.copy()
    np.add.at(acc, idx, val)
    return acc
