"""Data substrate: deterministic synthetic pipeline."""
from repro.data.pipeline import DataConfig, SyntheticLM, host_slice, prefetch

__all__ = ["DataConfig", "SyntheticLM", "host_slice", "prefetch"]
