"""Deterministic synthetic data pipeline.

Produces language-model batches with a reproducible structure-bearing
distribution (a small-order Markov chain over the vocab, so the loss
actually decreases during the end-to-end example runs — uniform random
tokens would pin the loss at log V).

Sharding: ``host_slice`` gives each host its slice of the global batch
(process_index-based) so the same pipeline works under multi-host
pjit; on one host it is the identity.  ``prefetch`` overlaps host-side
generation with device compute.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_order: int = 1
    branch: int = 32        # out-degree of the markov chain


class SyntheticLM:
    """Markov-chain token stream, deterministic per (seed, step, row)."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # each state has `branch` allowed successors with dirichlet probs
        self._succ = rng.integers(0, v, size=(v, cfg.branch))
        p = rng.dirichlet(np.ones(cfg.branch) * 0.5, size=v)
        self._cum = np.cumsum(p, axis=-1).astype(np.float32)

    def _row(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        out = np.empty(cfg.seq_len + 1, dtype=np.int32)
        s = int(rng.integers(0, cfg.vocab_size))
        u = rng.random(cfg.seq_len + 1).astype(np.float32)
        for t in range(cfg.seq_len + 1):
            out[t] = s
            k = int(np.searchsorted(self._cum[s], u[t]))
            s = int(self._succ[s, min(k, cfg.branch - 1)])
        return out

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        toks = np.empty((cfg.global_batch, cfg.seq_len + 1), dtype=np.int32)
        for b in range(cfg.global_batch):
            rng = np.random.default_rng(
                (cfg.seed, step, b))  # content-addressed: restart-safe
            toks[b] = self._row(rng)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((cfg.global_batch, cfg.seq_len), dtype=np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def host_slice(batch: Dict[str, np.ndarray],
               process_index: Optional[int] = None,
               process_count: Optional[int] = None
               ) -> Dict[str, np.ndarray]:
    """This host's rows of the global batch (contiguous block split)."""
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count
    def cut(x: np.ndarray) -> np.ndarray:
        b = x.shape[0]
        assert b % pc == 0, (b, pc)
        per = b // pc
        return x[pi * per:(pi + 1) * per]
    return {k: cut(v) for k, v in batch.items()}


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Background-thread prefetch (overlap host datagen with compute).

    A worker exception is captured and re-raised in the CONSUMER (the
    original ``finally: put(_END)`` silently truncated the stream on
    ingest errors — a failed trace stack looked like a shorter grid)."""
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    _END = object()
    err: list = []

    def worker() -> None:
        try:
            for item in it:
                q.put(item)
        except BaseException as e:           # noqa: BLE001 — re-raised below
            err.append(e)
        finally:
            q.put(_END)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is _END:
            if err:
                raise err[0]
            return
        yield item
