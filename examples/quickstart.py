"""Quickstart: SchedTwin in 40 lines.

Builds the paper's §4.1 setup — a PBS-like 32-node cluster emulator, a
four-phase synthetic workload, and the real-time digital twin — runs
the co-simulation, and prints the adaptive-vs-static comparison.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.cluster import ClusterEmulator, paper_synthetic_trace
from repro.cluster.workload import stack_scenarios
from repro.core import EventBus, SchedTwin
from repro.core.engine import DrainEngine
from repro.core.policies import FCFS, SJF, WFP, parse_pool, policy_name
from repro.core.scoring import radar_report
from repro.core.whatif import sharded_replay_grid
from repro.launch.mesh import make_fleet_mesh

trace = paper_synthetic_trace(seed=0)          # 150 jobs, 4 phases

# --- static baselines (the schedulers the paper compares against) ----
# fast=True replays the whole trace in ONE device computation
# (bit-identical to the per-event host loop, DESIGN.md §6)
per_policy = {}
for pid in (FCFS, WFP, SJF):
    emulator = ClusterEmulator(trace, total_nodes=32)
    report = emulator.run(policy_id=pid, fast=True)
    per_policy[policy_name(pid)] = report.metric_dict()

# --- a whole (scenario x policy) grid in one shot --------------------
# S traces x the 7-policy pool: one batched replay, per-(s, p) metrics.
# The objective (DESIGN.md §8) drives the per-scenario selection —
# here: minimize avg wait subject to >= 70% utilization, with
# feasibility fallback.  Try "avg_wait", "lex:avg_wait,makespan", ...
scenarios = stack_scenarios([paper_synthetic_trace(seed=s)
                             for s in range(4)], total_nodes=32)
pool7 = parse_pool("extended")
grid = DrainEngine().replay_grid(scenarios, pool7.spec,
                                 "min:avg_wait@util>=0.7")
print("grid avg_wait (S=4 x P=7):\n", np.asarray(grid.metrics.avg_wait))
print("per-scenario picks:", [pool7.names[int(b)] for b in grid.best])

# --- fleet scale: the same grid, sharded + streamed ------------------
# The fleet engine (DESIGN.md §9) shards the SCENARIO axis over the
# local device mesh and streams it in fixed-size blocks — one compiled
# shape regardless of S, host-side ingestion of block i+1 overlapping
# the device drain of block i (prefetch_depth), and the §7 static-key
# hoisting applied shard-locally.  Bit-identical to replay_grid above;
# S is unconstrained (inert padding fills the last block).  CLI:
#     python -m repro.launch.twin_loop --replay-grid 1024 \
#         --shard 0 --block-size 128 --prefetch 2
fleet = sharded_replay_grid(make_fleet_mesh(), engine=DrainEngine(),
                            objective="min:avg_wait@util>=0.7",
                            block_size=2, prefetch_depth=2)
big = stack_scenarios([paper_synthetic_trace(seed=s)
                       for s in range(6)], total_nodes=32)
out = fleet(big, pool7.spec)
print("fleet picks (S=6, blocks of 2):",
      [pool7.names[int(b)] for b in out.best])

# --- risk-aware: a Monte-Carlo fan of perturbed futures --------------
# One predicted future per cell is fragile — estimates are wrong and
# nodes fail.  A fan (DESIGN.md §10) grows F perturbed futures per
# (scenario, policy) ON DEVICE from the one uploaded base (runtime
# noise, arrival-burst warps, node-failure draws; member 0 stays
# exact) and selects by a DISTRIBUTIONAL goal: tail quantiles
# ("p95:avg_wait"), CVaR ("cvar:0.9:score"), worst case, or regret.
# FanOutcome.cost_ci / fan_width carry device-computed per-policy
# confidence.  CLI: twin_loop --fan 256 --fan-noise 0.3 [--prune]
from repro.core.fan import FanSpec

fan = DrainEngine().fan_grid(
    scenarios, pool7.spec,
    FanSpec(n=64, runtime_noise=0.3, failure_prob=0.1),
    "cvar:0.9:avg_wait")
print("risk-averse picks (S=4, F=64 futures):",
      [pool7.names[int(b)] for b in fan.best])
print("p0 CI half-widths:", np.round(np.asarray(fan.cost_ci)[0], 1))

# --- adaptive fan racing: pay only for open decisions ----------------
# A fixed fan spends S*F*P members even when the winner is obvious.
# Racing (DESIGN.md §11) starts every policy at f0 members, eliminates
# policies whose CI lower bound clears the incumbent's upper bound,
# and doubles survivors' fans up to F_max — CRN prefix-stability means
# each rung replays ONLY the new member suffix (no member is ever
# replayed twice).  Same winners as the full fan; a fraction of the
# replays.  budget_ms/max_members make it anytime.
# CLI: twin_loop --fan 64 --race --race-f0 4 [--budget-ms 500]
from repro.core.race import RaceSpec, race_grid

race = race_grid(scenarios, pool7.spec,
                 RaceSpec(fan=FanSpec(n=64, runtime_noise=0.3,
                                      failure_prob=0.1), f0=4),
                 "cvar:0.9:avg_wait")
print(f"raced picks ({race.members} of {race.members_full} members, "
      f"{len(race.rungs)} rungs, stopped={race.stopped}):",
      [pool7.names[int(b)] for b in race.best])

# --- the twin: simulation-in-the-loop adaptive scheduling ------------
# ``pool`` takes the sweep grammar (DESIGN.md §5): one what-if fork per
# term/grid point, all drained in ONE batched engine call.  "paper" is
# the §4.1 pool {WFP, FCFS, SJF}; a DRAS-style parameter sweep rides
# the same fork axis, e.g.
#     pool="extended,wfp:a=1..5x5:tau=600..7200x5"   # k=32 forks
#     pool="paper,expf:tau=600,lin:est=1:wait=-0.01" # custom scorers
# ``objective`` is the administrator-configured goal (§3.4, DESIGN.md
# §8) each decision cycle minimizes — "score" (the paper's 4-term
# default), "avg_wait", "0.5*avg_wait+0.5*max_slowdown",
# "min:avg_wait@util>=0.85", ... (see core.objective.parse_objective;
# CLI: python -m repro.launch.twin_loop --objective avg_wait)
bus = EventBus()
emulator = ClusterEmulator(trace, total_nodes=32, bus=bus)
twin = SchedTwin(bus=bus,
                 qrun=emulator.qrun,              # §3.5 decision feedback
                 total_nodes=32,
                 max_jobs=emulator.max_jobs,
                 pool="paper",
                 objective="score",               # the paper's goal
                 free_nodes_probe=lambda: emulator.free_nodes)  # §3.2
report = emulator.run(on_event=twin.pump)         # ①→⑦ loop per event
per_policy["SchedTwin"] = report.metric_dict()

# --- resilience: chaos, deadline guard, crash-safe snapshots ---------
# A real event stream drops, duplicates, reorders, and corrupts.
# ChaosBus (DESIGN.md §12) injects every fault class into the twin's
# READ view only — each fault a pure function of (seed, event seq), so
# runs are reproducible — while the twin quarantines garbage into
# dead_letters, absorbs duplicates idempotently, resyncs on loss, and
# the deadline guard (guard=budget_s) degrades the decision down a
# ladder instead of ever missing a cycle.  snapshot()/restore() make
# the whole runtime crash-safe: a fresh twin resumes bitwise.
# CLI: twin_loop --chaos --budget-s 1.0 --snapshot-dir CK [--resume]
from repro.cluster.chaos import DEFAULT_PROFILE, ChaosBus

bus = EventBus()
emulator = ClusterEmulator(trace, total_nodes=32, bus=bus)
view = ChaosBus(bus, DEFAULT_PROFILE)              # chaos on reads only
twin2 = SchedTwin(bus=view, qrun=emulator.qrun, total_nodes=32,
                  max_jobs=emulator.max_jobs, guard=1.0,
                  free_nodes_probe=lambda: emulator.free_nodes,
                  jobs_probe=emulator.jobs_view)    # loss -> resync
report2 = emulator.run(on_event=twin2.pump, on_quiesce=twin2.flush)
stats = twin2.telemetry.resilience_stats()
print(f"\nchaos survival: {report2.n_jobs} jobs, "
      f"injected={dict(view.stats)}")
print(f"quarantined={stats['quarantined']} resyncs={stats['resyncs']} "
      f"miss_rate={stats['miss_rate']:.3f} "
      f"ladder_engaged={stats['ladder_engaged']}")

# --- train, then deploy: closing the θ loop --------------------------
# The twin so far SELECTS among fixed policies; repro.learn SEARCHES θ
# itself (DESIGN.md §13).  A CEM/ES population of candidate parameter
# vectors rides the same fork axis — one replay grid per generation —
# warm-started from the static fixed points and gated on held-out
# scenarios.  The checkpoint then deploys through the pool grammar:
# ``trained:<ckpt>`` is just another term.  Full walkthrough:
# examples/train_policy.py; CLI:
#     twin_loop --train 12 --train-dir CK --objective avg_wait
#     twin_loop --pool trained:CK,paper
from repro.cluster.workload import split_scenarios
from repro.learn import TrainConfig, train

rng = np.random.default_rng(0)
tr, held = split_scenarios(rng, lambda r: paper_synthetic_trace(rng=r),
                           n_train=3, n_heldout=2, total_nodes=32)
res = train(tr, held, TrainConfig(family="lin", population=8,
                                  generations=4,
                                  objective="avg_wait", seed=0),
            engine=DrainEngine())
print(f"\ntrained {res.best_desc}: held-out {res.best_heldout:.1f} "
      f"({res.generations_run} generations)")

# --- Figure-3-style comparison ----------------------------------------
areas = radar_report(per_policy)
print(f"{'method':10s} {'radar area':>10s} {'avg wait':>9s} "
      f"{'max wait':>9s} {'util':>6s}")
for name, m in per_policy.items():
    print(f"{name:10s} {areas[name]:10.2f} {m['avg_wait']:9.1f} "
          f"{m['max_wait']:9.1f} {m['utilization']:6.3f}")
print("\npolicy mix (Table 1):",
      twin.telemetry.policy_start_distribution())
print("cycle latency:", twin.telemetry.cycle_latency_stats())
