"""Train a scheduler on the twin, then deploy it — end to end.

The θ loop (DESIGN.md §13): an ES/CEM population of candidate policy
parameters rides the FORK AXIS of one batched replay grid per
generation — evaluating N candidates x S scenarios costs one jitted
call, exactly the machinery the twin already uses for what-if sweeps.
Static fixed points warm-start generation 0, held-out scenarios gate
acceptance, and the result checkpoints to disk where the pool grammar
(``trained:<ckpt>``) deploys it live.

    PYTHONPATH=src python examples/train_policy.py
"""
import numpy as np

from repro.cluster import ClusterEmulator, paper_synthetic_trace
from repro.cluster.workload import split_scenarios
from repro.core import EventBus, SchedTwin
from repro.core.engine import DrainEngine
from repro.core.policies import parse_pool
from repro.learn import TrainConfig, train

# --- scenarios: one rng, deterministic train/held-out split ----------
rng = np.random.default_rng(0)
train_scen, heldout = split_scenarios(
    rng, lambda r: paper_synthetic_trace(rng=r),
    n_train=6, n_heldout=3, total_nodes=32)

# --- train: CEM over the linear-scorer family ------------------------
# Each generation = ONE replay grid: (train scenarios) x (population
# + warm-start statics on the fork axis).  Fitness is any DESIGN.md §8
# objective — swap in "cvar:0.9:avg_wait" and pass fan=FanSpec(...) to
# train risk-averse policies on Monte-Carlo fans instead.
engine = DrainEngine()
ckpt = "/tmp/schedtwin_trained"
res = train(train_scen, heldout,
            TrainConfig(family="lin", strategy="cem", population=16,
                        generations=12, objective="avg_wait", seed=0),
            engine=engine, checkpoint_dir=ckpt, log_fn=print)
print(f"\ntrained {res.label}: {res.best_desc}")
print(f"held-out cost {res.best_heldout:.2f} "
      f"({res.generations_run} generations"
      f"{', stopped early' if res.stopped_early else ''})")

# --- score it against the paper's static pool on held-out ------------
board = res.pool + parse_pool("paper")
costs = np.asarray(engine.generation_costs(heldout, board.spec,
                                           "avg_wait"), np.float64)
print("\nheld-out avg_wait (mean over scenarios):")
for name, c in zip(board.names, costs.mean(axis=0)):
    print(f"  {name:14s} {c:8.2f}")

# --- deploy: the checkpoint IS a pool term ---------------------------
# ``trained:<ckpt>`` loads the best θ straight into the sweep grammar,
# so the learned scheduler races the statics live in the twin.
# CLI equivalent:
#     python -m repro.launch.twin_loop --pool trained:/tmp/schedtwin_trained,paper
bus = EventBus()
emulator = ClusterEmulator(paper_synthetic_trace(seed=7),
                           total_nodes=32, bus=bus)
twin = SchedTwin(bus=bus, qrun=emulator.qrun, total_nodes=32,
                 max_jobs=emulator.max_jobs,
                 pool=f"trained:{ckpt},paper", objective="avg_wait",
                 free_nodes_probe=lambda: emulator.free_nodes)
report = emulator.run(on_event=twin.pump)
print(f"\nlive deploy ({report.n_jobs} jobs): policy mix",
      twin.telemetry.policy_start_distribution())
