"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on the synthetic Markov pipeline, with checkpointing and
a restart mid-run (the fault-tolerance story in miniature).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_host_mesh
from repro.models.common import count_params
from repro.models import api
from repro.train import OptimizerConfig, init_train_state, jit_train_step


def hundred_m_config():
    """~110M-param llama3-family config (GPT-2-small-ish shapes).

    CPU note: ~30 s/step at the default batch — pass ``--steps 40
    --restart-at 20`` for a quick demonstration of the restart path.
    """
    base = get_config("llama3.2-1b")
    return dataclasses.replace(
        base, name="llama-110m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=32768,
        tie_embeddings=True, accum_steps=1, q_block=128, logit_chunk=256,
    ).validate()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--restart-at", type=int, default=150,
                    help="simulate a crash+restart at this step")
    args = ap.parse_args()

    cfg = hundred_m_config()
    print(f"model: {cfg.name}, {count_params(api.param_table(cfg)) / 1e6:.1f}M params")
    mesh = make_host_mesh()
    rules = make_rules(mesh, "fsdp_tp")
    opt = OptimizerConfig(peak_lr=1e-3, warmup_steps=20,
                          total_steps=args.steps)
    step_fn = jit_train_step(cfg, rules, opt)
    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch,
                                  seed=0))

    ckpt_dir = tempfile.mkdtemp(prefix="schedtwin_train_")
    mgr = CheckpointManager(ckpt_dir, keep=2)
    saver = AsyncCheckpointer(mgr)

    def run_until(state, start, stop):
        t0, toks = time.time(), 0
        with mesh:
            for s in range(start, stop):
                batch = {k: jnp.asarray(v) for k, v in
                         data.batch(s).items()}
                state, m = step_fn(state, batch)
                toks += args.batch * args.seq
                if (s + 1) % 25 == 0:
                    print(f"  step {s + 1:4d} loss {float(m['loss']):.4f} "
                          f"tok/s {toks / (time.time() - t0):8.0f}")
                if (s + 1) % 50 == 0:
                    saver.save(s + 1, state)
        saver.wait()
        return state

    print(f"phase 1: steps 0..{args.restart_at}")
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    state = run_until(state, 0, args.restart_at)
    del state                                    # "crash"

    print("restart: recovering from latest checkpoint...")
    fresh = init_train_state(jax.random.PRNGKey(0), cfg)
    step0, state, extra = mgr.restore_latest(fresh)
    print(f"  resumed at step {step0}")
    state = run_until(state, step0, args.steps)
    print("done — loss should have decreased monotonically across the "
          "restart (content-addressed data makes the stream seamless).")


if __name__ == "__main__":
    main()
