"""SchedTwin as a TPU-fleet scheduler (the framework tie-in).

The twin is architecture-agnostic: jobs here are training / prefill /
decode workloads of the 10 assigned architectures, with pod footprints
from ``cluster.workload.arch_job_mix``.  A 32-pod fleet (8192 chips at
256/pod) is scheduled adaptively, with a pod-failure event mid-run —
the twin replans from the NODEFAIL event, victims restart, everything
completes.

    PYTHONPATH=src python examples/fleet_twin.py
"""
from __future__ import annotations

import numpy as np

from repro.cluster.emulator import ClusterEmulator, FailureSpec
from repro.cluster.workload import arch_job_mix
from repro.core.events import EventBus
from repro.core.policies import EXTENDED_POOL
from repro.core.twin import SchedTwin

TOTAL_PODS = 32       # 32 pods x 256 chips = 8192 chips

jobs = arch_job_mix(n_jobs=120, total_pods=TOTAL_PODS, seed=1,
                    mean_gap=25.0)
print(f"fleet workload: {len(jobs)} jobs over {TOTAL_PODS} pods")
by_class = {}
for j in jobs:
    by_class[j.tag.split(':')[1]] = by_class.get(j.tag.split(':')[1], 0) + 1
print("  job classes:", by_class)

failures = [FailureSpec(time=900.0, nodes=4, duration=600.0)]  # 4 pods drop

bus = EventBus()
emulator = ClusterEmulator(jobs, TOTAL_PODS, bus=bus, failures=failures,
                           check_invariants=True)
twin = SchedTwin(bus=bus, qrun=emulator.qrun, total_nodes=TOTAL_PODS,
                 max_jobs=emulator.max_jobs,
                 pool=EXTENDED_POOL,            # wider pool than the paper
                 free_nodes_probe=lambda: emulator.free_nodes,
                 ensemble=4, ensemble_noise=0.3)  # runtime-uncertainty
report = emulator.run(on_event=twin.pump)

print(f"\ncompleted {report.n_jobs} jobs, {report.n_restarts} restarted "
      f"after the pod failure")
print(f"avg wait {report.avg_wait:8.1f} s   max wait {report.max_wait:8.1f} s")
print(f"avg slowdown {report.avg_slowdown:5.2f}   utilization "
      f"{report.utilization:.3f}")
print("policy mix:", {k: f"{v:.0f}%" for k, v in
                      twin.telemetry.policy_start_distribution().items()})
lat = twin.telemetry.cycle_latency_stats()
print(f"decision latency p50 {lat['p50_s'] * 1e3:.1f} ms over "
      f"{lat['n']} cycles (paper: 'a few seconds')")
