"""Twin-driven serving admission: the paper's feedback loop at request
granularity.

The ServingEngine's admission hook is wired to a miniature what-if
evaluation: before refilling a free slot, the queue of pending requests
is scored under SJF-like and FCFS-like admission orders using the
twin's predictive machinery (estimated decode lengths stand in for
walltime estimates), and the better order picks the next request.

    PYTHONPATH=src python examples/serve_twin.py
"""
from __future__ import annotations

import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.models.common import init_params
from repro.serve import Request, ServingEngine

cfg = get_smoke_config("llama3.2-1b")
mesh = make_host_mesh()
rules = make_rules(mesh, "decode")
params = init_params(jax.random.PRNGKey(0), api.param_table(cfg))

rng = np.random.default_rng(0)
N = 12
requests = []
for r in range(N):
    plen = int(rng.integers(2, 10))
    new = int(rng.integers(2, 12))
    requests.append(Request(req_id=r,
                            prompt=rng.integers(0, cfg.vocab_size,
                                                plen).astype(np.int32),
                            max_new_tokens=new))

decisions = {"SJF": 0, "FCFS": 0}


def twin_admission(queue):
    """Pick FCFS head unless a much shorter job exists (what-if: the
    shorter job finishes before the head would — the same EASY-style
    reasoning the cluster twin applies, at request scale)."""
    head_cost = requests_cost(queue[0])
    best = min(range(len(queue)), key=lambda i: requests_cost(queue[i]))
    if requests_cost(queue[best]) * 2 < head_cost:
        decisions["SJF"] += 1
        return best
    decisions["FCFS"] += 1
    return 0


def requests_cost(req: Request) -> float:
    return len(req.prompt) + req.max_new_tokens   # estimated service time


with mesh:
    engine = ServingEngine(cfg, rules, params, batch_slots=3, max_seq=32,
                           admission=twin_admission)
    for r in requests:
        engine.submit(r)
    engine.run_until_drained()

waits = [r.first_token_t - r.arrival_t for r in requests]
print(f"served {N} requests with twin-driven admission")
print(f"admission decisions: {decisions}")
print(f"mean queue wait {np.mean(waits):.1f} steps, "
      f"max {np.max(waits):.1f}")
print("every request completed:",
      all(r.done for r in requests))
