import jax
import numpy as np
import pytest

from repro.core.state import add_job, empty_state, start_job
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_mesh


@pytest.fixture(scope="session")
def mesh11():
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="session")
def rules_train(mesh11):
    return make_rules(mesh11, "fsdp_tp")


@pytest.fixture(scope="session")
def rules_decode(mesh11):
    return make_rules(mesh11, "decode")


def make_cluster_state(max_jobs=64, total_nodes=32, n_queued=12,
                       n_running=4, seed=0, now=500.0):
    """A consistent SimState: running jobs fit, the rest are queued."""
    rng = np.random.default_rng(seed)
    st = empty_state(max_jobs, total_nodes)
    jid = 0
    free = total_nodes
    for _ in range(n_running):
        nodes = int(rng.integers(1, max(2, free // 2 + 1)))
        if nodes > free:
            break
        st = add_job(st, jid, float(jid * 7.0), nodes,
                     float(rng.uniform(60, 600)))
        st = start_job(st, jid, float(jid * 7.0 + rng.uniform(0, 50)))
        free -= nodes
        jid += 1
    for _ in range(n_queued):
        st = add_job(st, jid, float(jid * 7.0),
                     int(rng.integers(1, total_nodes + 1)),
                     float(rng.uniform(30, 900)))
        jid += 1
    import jax.numpy as jnp
    return st._replace(now=jnp.float32(now))
