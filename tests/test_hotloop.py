"""Hot-loop compaction (DESIGN.md §7): bit-identity of the compacted
engine paths against the PR-3-equivalent configuration.

The compaction knobs — dynamic pass bounds, static-key hoisting, pass
elision — must be pure speedups: every knob combination produces
bit-for-bit the same replays, drains and decisions as the all-off
configuration (the PR-3 loop shape), under both pass backends,
including adversarial shapes (queue depth == J, mixed
time-invariant/time-varying pools, all-static pools with zero per-event
sorting).
"""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policies
from repro.core.engine import DrainEngine, hoist_plan
from repro.core.policies import (EXTENDED_POOL, FCFS, LJF, SAF, SJF, WFP,
                                 parse_pool, time_invariant_mask)
from repro.cluster.workload import (JobSpec, bursty_trace, make_scenario,
                                    poisson_trace, stack_scenarios)

from conftest import make_cluster_state

POOL = jnp.asarray(EXTENDED_POOL, dtype=jnp.int32)
MAX_JOBS = 64

COMPACT = {
    "reference": DrainEngine("reference"),
    "pallas": DrainEngine("pallas", interpret=True),
}
PR3 = {
    name: DrainEngine(eng.backend, interpret=eng.interpret,
                      dynamic_bounds=False, hoist_static=False,
                      elide_empty=False)
    for name, eng in COMPACT.items()
}


def random_traces(n_traces, n_jobs=20, total_nodes=16):
    """Same trace family as tests/test_replay.py (6 traces x the
    7-policy pool = the 42 parity combos)."""
    out = []
    for i in range(n_traces):
        gen = bursty_trace if i % 2 else poisson_trace
        out.append(gen(n_jobs, total_nodes, 4.0 + i, (1, total_nodes - 4),
                       (30.0, 400.0), seed=100 + i))
    return out


def _assert_replay_identical(a, b, ctx=""):
    np.testing.assert_array_equal(np.asarray(a.start_t),
                                  np.asarray(b.start_t), err_msg=ctx)
    np.testing.assert_array_equal(np.asarray(a.end_t),
                                  np.asarray(b.end_t), err_msg=ctx)
    np.testing.assert_array_equal(np.asarray(a.deadlocked),
                                  np.asarray(b.deadlocked), err_msg=ctx)
    np.testing.assert_array_equal(np.asarray(a.events),
                                  np.asarray(b.events), err_msg=ctx)


def _assert_decisions_identical(da, db, ctx=""):
    assert int(da.policy_index) == int(db.policy_index), ctx
    np.testing.assert_array_equal(np.asarray(da.run_mask),
                                  np.asarray(db.run_mask), err_msg=ctx)
    np.testing.assert_array_equal(np.asarray(da.costs),
                                  np.asarray(db.costs), err_msg=ctx)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_replay_compaction_bit_identity_42_combos(backend):
    """6 traces x 7 policies per backend: the fully-compacted replay is
    bit-identical to the PR-3-equivalent (all knobs off) replay."""
    for i, trace in enumerate(random_traces(6)):
        scen = make_scenario(trace, 16, max_jobs=MAX_JOBS)
        _assert_replay_identical(
            COMPACT[backend].replay(scen, POOL),
            PR3[backend].replay(scen, POOL),
            ctx=f"backend={backend} trace={i}")


def test_every_knob_combination_identical():
    """All 8 knob combinations agree — no pairwise interaction between
    bounds, hoisting and elision breaks exactness."""
    trace = poisson_trace(24, 16, 5.0, (1, 12), (30.0, 300.0), seed=11)
    scen = make_scenario(trace, 16, max_jobs=32)
    ref = None
    for db, hs, ee in itertools.product((False, True), repeat=3):
        eng = DrainEngine("reference", dynamic_bounds=db, hoist_static=hs,
                          elide_empty=ee)
        out = eng.replay(scen, POOL)
        if ref is None:
            ref = out
        else:
            _assert_replay_identical(ref, out,
                                     ctx=f"bounds={db} hoist={hs} "
                                         f"elide={ee}")


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_deep_queue_bounds_degrade_to_full_loop(backend):
    """Adversarial: every job demands the whole cluster, so after the
    arrival burst the queue holds J-1 jobs while one runs — the dynamic
    rank bound sits at ~J (no truncation headroom) and must still be
    bit-exact, serializing all J jobs."""
    J = 24
    trace = [JobSpec(j, round(0.5 * j, 3), 8, 120.0 + j, 100.0 + j, "t")
             for j in range(J)]
    scen = make_scenario(trace, 8, max_jobs=J)   # max_jobs == len(trace)
    a = COMPACT[backend].replay(scen, POOL)
    b = PR3[backend].replay(scen, POOL)
    _assert_replay_identical(a, b, ctx=f"deep queue {backend}")
    # fully serialized: every fork retires one job at a time
    ends = np.sort(np.asarray(a.end_t)[0])
    assert (np.diff(ends) > 0).all()


def test_drain_queue_depth_equals_capacity():
    """Drain-side adversarial shape: queued count == J exactly (every
    slot queued, nothing running) — ``pass_rank_limit`` equals the full
    static bound and the compacted drain must match the uncompacted."""
    state = make_cluster_state(max_jobs=16, total_nodes=8, n_queued=16,
                               n_running=0, seed=3)
    assert int((state.jobs.state == 1).sum()) == 16
    for backend in ("reference", "pallas"):
        _assert_decisions_identical(
            COMPACT[backend].decide(state, POOL),
            PR3[backend].decide(state, POOL),
            ctx=f"deep drain {backend}")


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_mixed_static_and_time_varying_pool(backend):
    """A pool mixing hoistable (lin-family / static ids) and
    time-varying (wfp/expf) forks exercises the gather/sort/merge path:
    replay and decide both bit-identical to the uncompacted engine."""
    pool = parse_pool(
        "extended,wfp:a=1..2x2,lin:est=1:wait=-0.01,expf:tau=600").spec
    mask = time_invariant_mask(pool)
    assert mask.any() and (~mask).any()      # genuinely mixed
    trace = poisson_trace(20, 16, 5.0, (1, 12), (30.0, 300.0), seed=21)
    scen = make_scenario(trace, 16, max_jobs=32)
    _assert_replay_identical(COMPACT[backend].replay(scen, pool),
                             PR3[backend].replay(scen, pool),
                             ctx=f"mixed pool replay {backend}")
    state = make_cluster_state(max_jobs=32, seed=5)
    _assert_decisions_identical(COMPACT[backend].decide(state, pool),
                                PR3[backend].decide(state, pool),
                                ctx=f"mixed pool decide {backend}")


def test_all_static_pool_zero_per_event_sort():
    """An all-hoistable pool takes the constant-order path (no per-event
    argsort at all) and still matches the uncompacted engine."""
    pool = jnp.asarray([FCFS, SJF, SAF, LJF], dtype=jnp.int32)
    assert time_invariant_mask(pool).all()
    trace = bursty_trace(22, 16, 5.0, (1, 10), (30.0, 300.0), seed=31)
    scen = make_scenario(trace, 16, max_jobs=32)
    _assert_replay_identical(COMPACT["reference"].replay(scen, pool),
                             PR3["reference"].replay(scen, pool),
                             ctx="all-static pool")


def test_time_invariant_mask():
    """The hoist predicate: static ids FCFS/SJF/SAF/LJF qualify; the
    wait-rescoring WFP/LXF/EXPF never do; lin specs qualify iff their
    wait and xfactor weights are zero."""
    ids = np.asarray(time_invariant_mask(POOL))
    by_id = dict(zip(EXTENDED_POOL, ids))
    assert by_id[FCFS] and by_id[SJF] and by_id[SAF] and by_id[LJF]
    assert not by_id[WFP]
    spec = policies.stack_specs([
        policies.linear_spec(est=1.0),                  # SJF: hoistable
        policies.linear_spec(est=1.0, wait=-0.01),      # wait weight: no
        policies.linear_spec(area=1.0, xfactor=0.5),    # xfactor: no
        policies.wfp_spec(a=2.0),                       # family: no
        policies.exp_spec(tau=600.0),                   # family: no
    ])
    assert list(time_invariant_mask(spec)) == [True, False, False,
                                               False, False]
    # parity between representations: ids == their spec fixed points
    spec_pool = policies.PolicyPool.from_ids(EXTENDED_POOL).spec
    np.testing.assert_array_equal(time_invariant_mask(spec_pool), ids)


def test_hoist_plan_gating():
    assert hoist_plan(POOL) == tuple(bool(b)
                                     for b in time_invariant_mask(POOL))
    assert hoist_plan(POOL, enabled=False) is None
    # no hoistable fork -> no plan (skip the gather/merge machinery)
    assert hoist_plan(jnp.asarray([WFP], dtype=jnp.int32)) is None


def test_elision_fires_on_sparse_trace_and_counts_recorded():
    """A sparse trace (long gaps, queue usually empty) elides passes on
    completion-only iterations: pass_invocations < iters, while a
    knobs-off engine runs one pass every iteration.  Results stay
    bit-identical."""
    trace = [JobSpec(j, 1000.0 * j, 2, 60.0, 50.0, "t")
             for j in range(8)]
    scen = make_scenario(trace, 16, max_jobs=16)
    a = COMPACT["reference"].replay(scen, POOL)
    b = PR3["reference"].replay(scen, POOL)
    _assert_replay_identical(a, b, ctx="sparse")
    passes = int(a.result.pass_invocations)
    iters = int(a.result.iters)
    assert passes < iters, "elision never fired on a sparse trace"
    assert int(b.result.pass_invocations) == int(b.result.iters)
    # drain counters: one pass per lock-step iteration
    state = make_cluster_state(max_jobs=32, seed=9)
    res = COMPACT["reference"].drain(state, POOL)
    assert (np.asarray(res.pass_invocations) >= 1).all()


def test_ensemble_and_grid_compaction_identity():
    """The tiled-fork paths (ensemble members, scenario grids) tile the
    hoist plan with the pool — both stay bit-identical."""
    import jax
    state = make_cluster_state(max_jobs=32, seed=13)
    key = jax.random.PRNGKey(0)
    da = COMPACT["reference"].decide_ensemble(state, POOL, key, n_ens=3)
    db = PR3["reference"].decide_ensemble(state, POOL, key, n_ens=3)
    _assert_decisions_identical(da, db, ctx="ensemble")

    traces = random_traces(3, n_jobs=12)
    scen = stack_scenarios(traces, 16, max_jobs=32)
    _assert_replay_identical(COMPACT["reference"].replay_grid(scen, POOL),
                             PR3["reference"].replay_grid(scen, POOL),
                             ctx="grid")
