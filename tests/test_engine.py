"""DrainEngine: backend parity + batched-drain equivalence (DESIGN.md).

The contract under test:

* ``pallas`` (interpret mode on CPU) and ``reference`` backends yield
  BIT-IDENTICAL decisions — run_mask, winner, costs, drain metrics —
  across random snapshots over the EXTENDED_POOL;
* the batched drain is bit-for-bit the stack of k scalar drains
  (``jax.vmap(simulate_to_drain)``), per-fork freeze semantics
  included;
* the emulator's static baseline is backend-independent;
* ``whatif.pool_array`` preserves the caller's tie-break order.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import whatif
from repro.core.des import simulate_to_drain
from repro.core.engine import DrainEngine
from repro.core.policies import EXTENDED_POOL, FCFS, PAPER_POOL, SJF, WFP

from conftest import make_cluster_state

REF = DrainEngine("reference")
PAL = DrainEngine("pallas", interpret=True)

N_SNAPSHOTS = 60  # acceptance: >= 50 random snapshots
MAX_JOBS = 48     # fixed shape -> one compile per backend


def _snapshots():
    for seed in range(N_SNAPSHOTS):
        yield make_cluster_state(
            max_jobs=MAX_JOBS, total_nodes=32, seed=seed,
            n_queued=4 + seed % 16, n_running=seed % 5,
            now=100.0 + 37.0 * seed)


def _assert_decisions_identical(da, db, ctx=""):
    assert int(da.policy_index) == int(db.policy_index), ctx
    np.testing.assert_array_equal(np.asarray(da.run_mask),
                                  np.asarray(db.run_mask), err_msg=ctx)
    np.testing.assert_array_equal(np.asarray(da.costs),
                                  np.asarray(db.costs), err_msg=ctx)
    np.testing.assert_array_equal(np.asarray(da.deadlocked),
                                  np.asarray(db.deadlocked), err_msg=ctx)
    for field, a, b in zip(da.metrics._fields, da.metrics, db.metrics):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{ctx} metric={field}")


def test_backend_parity_extended_pool_random_snapshots():
    pool = jnp.asarray(EXTENDED_POOL, dtype=jnp.int32)
    for i, state in enumerate(_snapshots()):
        d_ref = REF.decide(state, pool)
        d_pal = PAL.decide(state, pool)
        _assert_decisions_identical(d_ref, d_pal, ctx=f"snapshot {i}")


def test_batched_drain_matches_vmapped_scalar():
    pool = jnp.asarray(EXTENDED_POOL, dtype=jnp.int32)
    vmapped = jax.jit(jax.vmap(simulate_to_drain, in_axes=(None, 0)))
    for seed in (0, 7, 23, 41):
        state = make_cluster_state(max_jobs=MAX_JOBS, seed=seed,
                                   n_queued=12, n_running=3)
        res_b = REF.drain(state, pool)
        res_v = vmapped(state, pool)
        eq = jax.tree.map(lambda x, y: bool(jnp.array_equal(x, y)),
                          res_b.state, res_v.state)
        assert jax.tree.all(eq), f"seed {seed}: state diverged"
        np.testing.assert_array_equal(np.asarray(res_b.first_started),
                                      np.asarray(res_v.first_started))
        np.testing.assert_array_equal(np.asarray(res_b.deadlocked),
                                      np.asarray(res_v.deadlocked))
        np.testing.assert_array_equal(np.asarray(res_b.iters),
                                      np.asarray(res_v.iters))


def test_batched_drain_deadlock_detected_and_rest_scheduled():
    """Deadlock is policy-independent (req > total nodes), so both
    forks flag it — after scheduling whatever still fits."""
    from repro.core.state import add_job, empty_state
    state = empty_state(16, 8)
    state = add_job(state, 0, 0.0, 9, 100.0)   # 9 > 8: can never fit
    state = add_job(state, 1, 1.0, 2, 50.0)
    pool = jnp.asarray([FCFS, SJF], dtype=jnp.int32)
    res = REF.drain(state, pool)
    dead = np.asarray(res.deadlocked)
    assert dead[0] and dead[1]
    # ... but job 1 still ran in both forks before the deadlock
    assert float(res.state.jobs.start_t[0][1]) >= 0
    assert float(res.state.jobs.start_t[1][1]) >= 0


def test_batched_drain_freezes_finished_fork_while_others_step():
    """The per-fork freeze path proper: forks that need different
    event counts share one while_loop — the early finisher must freeze
    (bit-identical to its scalar drain) while the slow fork keeps
    stepping.  On 4 nodes with A(2n, 10s), B(2n, 30s), C(4n, 5s):
    FCFS packs A+B first and needs 3 events to drain; SJF starts C
    alone and finishes in 2."""
    from repro.core.state import add_job, empty_state
    state = empty_state(16, 4)
    state = add_job(state, 0, 0.0, 2, 10.0)
    state = add_job(state, 1, 1.0, 2, 30.0)
    state = add_job(state, 2, 2.0, 4, 5.0)
    state = state._replace(now=jnp.float32(3.0))
    pool = jnp.asarray([FCFS, SJF], dtype=jnp.int32)
    res = REF.drain(state, pool)
    assert list(np.asarray(res.iters)) == [3, 2]
    assert not np.asarray(res.deadlocked).any()
    for i, pid in enumerate((FCFS, SJF)):
        scalar = simulate_to_drain(state, jnp.int32(pid))
        eq = jax.tree.map(lambda x, y: bool(jnp.array_equal(x, y[i])),
                          scalar.state, res.state)
        assert jax.tree.all(eq), f"fork {i} diverged from scalar drain"
        assert int(scalar.iters) == int(np.asarray(res.iters)[i])


def test_engine_matches_legacy_vmap_decide():
    pool = jnp.asarray(PAPER_POOL, dtype=jnp.int32)
    for seed in (2, 13):
        state = make_cluster_state(max_jobs=MAX_JOBS, seed=seed)
        _assert_decisions_identical(
            REF.decide(state, pool),
            whatif.decide_legacy_vmap(state, pool),
            ctx=f"legacy seed {seed}")


def test_ensemble_rides_batch_axis_both_backends():
    state = make_cluster_state(max_jobs=MAX_JOBS, seed=5)
    pool = jnp.asarray(PAPER_POOL, dtype=jnp.int32)
    key = jax.random.PRNGKey(1)
    d_ref = REF.decide_ensemble(state, pool, key, n_ens=3, noise=0.25)
    d_pal = PAL.decide_ensemble(state, pool, key, n_ens=3, noise=0.25)
    _assert_decisions_identical(d_ref, d_pal, ctx="ensemble")


def test_emulator_static_baseline_backend_independent():
    from repro.cluster.emulator import ClusterEmulator
    from repro.cluster.workload import JobSpec
    rng = np.random.default_rng(0)
    trace = [JobSpec(j, j * 4.0, int(rng.integers(1, 12)),
                     float(rng.uniform(30, 300)),
                     float(rng.uniform(20, 280)), "t")
             for j in range(24)]
    reports = {}
    for eng in (REF, PAL):
        reports[eng.backend] = ClusterEmulator(
            trace, 16, engine=eng, check_invariants=True).run(policy_id=WFP)
    np.testing.assert_array_equal(reports["reference"].start_t,
                                  reports["pallas"].start_t)
    np.testing.assert_array_equal(reports["reference"].end_t,
                                  reports["pallas"].end_t)


def test_twin_runs_on_pallas_engine():
    from repro.cluster.emulator import ClusterEmulator
    from repro.cluster.workload import JobSpec
    from repro.core.events import EventBus
    from repro.core.twin import SchedTwin
    rng = np.random.default_rng(1)
    trace = [JobSpec(j, j * 6.0, int(rng.integers(1, 8)),
                     float(rng.uniform(30, 200)),
                     float(rng.uniform(20, 180)), "t")
             for j in range(12)]
    bus = EventBus()
    em = ClusterEmulator(trace, 16, bus=bus, engine=PAL)
    twin = SchedTwin(bus=bus, qrun=em.qrun, total_nodes=16,
                     max_jobs=em.max_jobs, engine=PAL)
    report = em.run(on_event=twin.pump)
    assert report.n_jobs == len(trace)


def test_config_backend_knob():
    from repro.configs.schedtwin import PALLAS_TWIN, PAPER_TWIN
    assert PAPER_TWIN.make_engine() == DrainEngine("reference")
    assert PALLAS_TWIN.make_engine().backend == "pallas"


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown pass backend"):
        DrainEngine("cuda")


def test_pool_array_preserves_caller_order():
    """Regression: pool_array used to sort ids, discarding the caller's
    tie-break priority (position = priority for select_policy)."""
    ids = [SJF, WFP, FCFS]
    arr = np.asarray(whatif.pool_array(ids))
    assert list(arr) == ids
    assert arr.dtype == np.int32
