"""Shape/dtype sweeps: flash_attention kernel vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk(b, hq, hkv, sq, sk, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 1, 1, 128, 32),
    (2, 4, 4, 128, 64),
    (2, 4, 2, 256, 32),    # GQA group 2
    (1, 8, 1, 256, 64),    # MQA
    (1, 2, 2, 512, 128),   # MXU-aligned head dim
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle_f32(b, hq, hkv, s, d, causal):
    q, k, v = _mk(b, hq, hkv, s, s, d, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal,
                              block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(32, 64), (64, 32),
                                             (128, 128), (256, 64)])
def test_flash_block_size_invariance(block_q, block_k):
    q, k, v = _mk(2, 2, 2, 256, 256, 32, jnp.float32, seed=1)
    out = ops.flash_attention(q, k, v, causal=True,
                              block_q=block_q, block_k=block_k)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16_storage():
    q, k, v = _mk(1, 2, 2, 128, 128, 32, jnp.bfloat16, seed=2)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    assert out.dtype == jnp.bfloat16
    want = ref.flash_attention_ref(q.astype(jnp.float32),
                                   k.astype(jnp.float32),
                                   v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(out).astype(np.float32),
                               np.asarray(want), atol=3e-2, rtol=3e-2)


def test_flash_matches_model_attention_path():
    """The kernel must agree with the exact attention the models use."""
    from repro.models.attention import full_attention
    q, k, v = _mk(2, 4, 2, 256, 256, 64, jnp.float32, seed=3)
    out = ops.flash_attention(q, k, v, causal=True)
    want = full_attention(q, k, v, causal=True, q_block=512)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
