"""First-class Objective API (core/objective.py, DESIGN.md §8).

The contract under test:

* the goal grammar parses, canonicalizes and round-trips
  (``parse_objective(obj.spec) == obj``), and rejects malformed goals;
* ``objective="score"`` is BIT-IDENTICAL to the legacy ``ScoreWeights``
  path on BOTH pass backends (the redesign must not move a single
  decision), and ``weights=`` still works everywhere — lifted with a
  ``DeprecationWarning``, bit-identically;
* constrained goals implement the feasibility-fallback semantics:
  feasible candidates always beat infeasible ones; among all-infeasible
  pools the least total violation wins;
* lexicographic goals break exact primary ties by the next level;
* ``replay_grid``/``replay`` select per scenario under the goal
  (``ReplayOutcome.costs``/``best``), deadlocked forks excluded.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import whatif
from repro.core.des import DrainMetrics
from repro.core.engine import DrainEngine
from repro.core.objective import (DEFAULT_OBJECTIVE, Constrained,
                                  Constraint, Lexicographic, PaperScore,
                                  Weighted, metrics_from_rows,
                                  normalize_objective, parse_objective,
                                  register_objective, report_costs,
                                  resolve_goal)
from repro.core.policies import EXTENDED_POOL, PAPER_POOL, parse_pool
from repro.core.scoring import PAPER_WEIGHTS, ScoreWeights, policy_cost

from conftest import make_cluster_state

REF = DrainEngine("reference")
PAL = DrainEngine("pallas", interpret=True)


def _metrics(**cols):
    """DrainMetrics with a (k,) candidate axis; unspecified fields 0."""
    k = len(next(iter(cols.values())))
    full = {f: jnp.asarray(cols.get(f, [0.0] * k), dtype=jnp.float32)
            for f in DrainMetrics._fields}
    return DrainMetrics(**full)


# ----------------------------------------------------------------------
# Grammar: parse / normalize / round-trip.
# ----------------------------------------------------------------------

def test_parse_single_metric_and_aliases():
    assert parse_objective("avg_wait") == Weighted(((1.0, "avg_wait"),))
    assert parse_objective("util") == Weighted(((1.0, "utilization"),))
    assert parse_objective("UTIL").spec == "utilization"


def test_parse_weighted_combination():
    obj = parse_objective("0.5*avg_wait+0.5*max_slowdown")
    assert obj == Weighted(((0.5, "avg_wait"), (0.5, "max_slowdown")))
    m = _metrics(avg_wait=[10.0, 20.0], max_slowdown=[4.0, 2.0])
    np.testing.assert_allclose(np.asarray(obj.costs(m)), [7.0, 11.0])


def test_parse_score_and_custom_weights():
    assert parse_objective("score") == PaperScore()
    obj = parse_objective("score:max_wait=0.5:avg_wait=0.5"
                          ":max_slowdown=0:avg_slowdown=0")
    assert obj.weights == ScoreWeights(0.5, 0.0, 0.5, 0.0)


def test_parse_constrained_and_lex():
    obj = parse_objective("min:avg_wait@util>=0.85")
    assert isinstance(obj, Constrained)
    assert obj.constraints == (Constraint("utilization", ">=", 0.85),)
    lx = parse_objective("lex:avg_wait,makespan")
    assert isinstance(lx, Lexicographic)
    assert len(lx.levels) == 2


@pytest.mark.parametrize("grammar", [
    "score", "avg_wait", "utilization", "makespan",
    "0.5*avg_wait+0.5*max_slowdown", "2*avg_wait+-1*utilization",
    "lex:avg_wait,makespan", "lex:score,avg_wait,makespan",
    "min:avg_wait@util>=0.85", "min:score@max_wait<=600",
    "min:0.5*avg_wait+0.5*avg_slowdown@utilization>=0.8@max_wait<=600",
    "score:max_wait=0.3:max_slowdown=0.3:avg_wait=0.2:avg_slowdown=0.2",
    # full-precision coefficients/bounds/weights must round-trip too
    # (specs format with repr, not %g's 6 significant digits)
    "0.3333333*avg_wait+0.6666667*max_slowdown",
    "min:avg_wait@util>=0.8512345",
    "score:max_wait=0.12345678:max_slowdown=0.25"
    ":avg_wait=0.25:avg_slowdown=0.25",
])
def test_grammar_round_trips(grammar):
    obj = parse_objective(grammar)
    assert parse_objective(obj.spec) == obj
    assert str(obj) == obj.spec


def test_validate_objective_shared_helper():
    from repro.core.objective import validate_objective
    assert validate_objective("avg_wait") == Weighted(((1.0, "avg_wait"),))
    with pytest.raises(ValueError):
        validate_objective("turnaround")


@pytest.mark.parametrize("bad", [
    "", "nope", "avg_wait@util>0.85", "min:avg_wait@util=0.85",
    "lex:avg_wait", "lex:avg_wait@util>=0.5", "x*avg_wait",
    "score:bogus=1", "0.5*turnaround",
])
def test_grammar_rejects_malformed(bad):
    with pytest.raises((ValueError, SystemExit)):
        parse_objective(bad)


def test_normalize_objective_paths():
    assert normalize_objective(None) is DEFAULT_OBJECTIVE
    obj = parse_objective("avg_wait")
    assert normalize_objective(obj) is obj
    assert normalize_objective("score") == PaperScore()
    with pytest.warns(DeprecationWarning):
        lifted = normalize_objective(PAPER_WEIGHTS)
    assert lifted == PaperScore()
    with pytest.raises(TypeError):
        normalize_objective(3.14)


def test_resolve_goal_rejects_both_spellings():
    with pytest.raises(ValueError, match="not both"):
        resolve_goal("avg_wait", PAPER_WEIGHTS)


def test_registry_extension():
    register_objective("test_tail_goal",
                       lambda: Weighted(((1.0, "max_slowdown"),)))
    assert parse_objective("test_tail_goal") == Weighted(
        ((1.0, "max_slowdown"),))
    with pytest.raises(ValueError, match="already registered"):
        register_objective("test_tail_goal", lambda: PaperScore())


# ----------------------------------------------------------------------
# Bit-exact parity: objective="score" vs the legacy weights path.
# ----------------------------------------------------------------------

@pytest.mark.parametrize("engine", [REF, PAL], ids=["reference", "pallas"])
def test_score_objective_bitwise_parity_both_backends(engine):
    pool = jnp.asarray(EXTENDED_POOL, dtype=jnp.int32)
    for seed in range(8):
        state = make_cluster_state(max_jobs=48, seed=seed,
                                   n_queued=4 + seed * 2, n_running=seed % 4)
        d_obj = engine.decide(state, pool, "score")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            d_leg = engine.decide(state, pool, weights=PAPER_WEIGHTS)
        assert int(d_obj.policy_index) == int(d_leg.policy_index)
        np.testing.assert_array_equal(np.asarray(d_obj.costs),
                                      np.asarray(d_leg.costs))
        np.testing.assert_array_equal(np.asarray(d_obj.run_mask),
                                      np.asarray(d_leg.run_mask))
        # and both match the raw policy_cost arithmetic on the metrics
        # (allclose: recomputing eagerly outside the jitted decide can
        # differ in the last ulp through XLA fusion)
        raw = policy_cost(d_obj.metrics, PAPER_WEIGHTS)
        raw = jnp.where(d_obj.deadlocked, jnp.inf, raw)
        np.testing.assert_allclose(np.asarray(d_obj.costs),
                                   np.asarray(raw), rtol=1e-6)


def test_weights_kwarg_warns_and_matches_via_whatif():
    state = make_cluster_state(seed=3)
    pool = jnp.asarray(PAPER_POOL, dtype=jnp.int32)
    d_obj = whatif.decide(state, pool)
    with pytest.warns(DeprecationWarning):
        d_leg = whatif.decide(state, pool, weights=ScoreWeights())
    np.testing.assert_array_equal(np.asarray(d_obj.costs),
                                  np.asarray(d_leg.costs))


def test_cost_terms_breakdown_sums_to_costs():
    state = make_cluster_state(seed=9)
    pool = jnp.asarray(PAPER_POOL, dtype=jnp.int32)
    d = whatif.decide(state, pool, "score")
    assert set(d.cost_terms) == {"max_wait", "max_slowdown",
                                 "avg_wait", "avg_slowdown"}
    total = sum(np.asarray(v) for v in d.cost_terms.values())
    live = ~np.asarray(d.deadlocked)
    np.testing.assert_allclose(total[live],
                               np.asarray(d.costs)[live], rtol=1e-6)


def test_single_metric_objective_selects_its_metric():
    state = make_cluster_state(seed=11)
    pool = jnp.asarray(EXTENDED_POOL, dtype=jnp.int32)
    d = whatif.decide(state, pool, "avg_wait")
    aw = np.where(np.asarray(d.deadlocked), np.inf,
                  np.asarray(d.metrics.avg_wait))
    assert int(d.policy_index) == int(np.argmin(aw))
    np.testing.assert_array_equal(np.asarray(d.costs)[~np.isinf(aw)],
                                  aw[~np.isinf(aw)])


def test_ensemble_accepts_objective():
    import jax
    state = make_cluster_state(seed=5)
    pool = jnp.asarray(PAPER_POOL, dtype=jnp.int32)
    d = whatif.decide_ensemble(state, pool, jax.random.PRNGKey(0),
                               n_ens=2, noise=0.1, objective="avg_wait")
    assert d.costs.shape == (3,)
    assert set(d.cost_terms) == {"avg_wait"}


# ----------------------------------------------------------------------
# Constrained goals: feasibility fallback semantics.
# ----------------------------------------------------------------------

def test_constrained_feasible_beats_infeasible_primary():
    # candidate 0: better primary but infeasible; candidate 1: feasible
    obj = parse_objective("min:avg_wait@util>=0.85")
    m = _metrics(avg_wait=[1.0, 100.0], utilization=[0.5, 0.9])
    c = np.asarray(obj.costs(m))
    assert c[1] < c[0]


def test_constrained_all_infeasible_least_violation_wins():
    obj = parse_objective("min:avg_wait@util>=0.85")
    # all below the bound; candidate 2 violates least despite the worst
    # primary — the fallback ranks by violation first
    m = _metrics(avg_wait=[1.0, 2.0, 300.0],
                 utilization=[0.2, 0.5, 0.80])
    assert int(np.argmin(np.asarray(obj.costs(m)))) == 2


def test_constrained_among_feasible_primary_decides():
    obj = parse_objective("min:avg_wait@util>=0.5")
    m = _metrics(avg_wait=[30.0, 10.0, 20.0],
                 utilization=[0.9, 0.6, 0.95])
    assert int(np.argmin(np.asarray(obj.costs(m)))) == 1


def test_constrained_multiple_constraints_sum_violations():
    obj = parse_objective("min:avg_wait@util>=0.8@max_wait<=100")
    # 0 violates both slightly; 1 violates one badly; 2 feasible
    m = _metrics(avg_wait=[1.0, 1.0, 50.0],
                 utilization=[0.75, 0.9, 0.85],
                 max_wait=[110.0, 400.0, 90.0])
    c = np.asarray(obj.costs(m))
    assert int(np.argmin(c)) == 2
    assert c[0] < c[1]          # 10.05 total violation < 300


def test_constrained_ties_break_by_pool_position():
    obj = parse_objective("min:avg_wait@util>=0.5")
    m = _metrics(avg_wait=[10.0, 10.0], utilization=[0.6, 0.6])
    c = np.asarray(obj.costs(m))
    assert c[0] == c[1]          # argmin downstream picks index 0


# ----------------------------------------------------------------------
# Lexicographic goals.
# ----------------------------------------------------------------------

def test_lex_tie_broken_by_second_level():
    obj = parse_objective("lex:avg_wait,makespan")
    m = _metrics(avg_wait=[5.0, 5.0, 6.0], makespan=[200.0, 100.0, 1.0])
    c = np.asarray(obj.costs(m))
    assert int(np.argmin(c)) == 1
    assert c[2] > c[0]           # worse primary loses despite makespan


def test_lex_primary_dominates():
    obj = parse_objective("lex:avg_wait,makespan")
    m = _metrics(avg_wait=[1.0, 2.0], makespan=[1e9, 0.0])
    assert int(np.argmin(np.asarray(obj.costs(m)))) == 0


# ----------------------------------------------------------------------
# Per-objective selection over a replay grid.
# ----------------------------------------------------------------------

def _grid(S=3, n_jobs=14, seed=0):
    from repro.cluster.workload import poisson_trace, stack_scenarios
    traces = [poisson_trace(n_jobs, 32, 8.0, (1, 16), (30.0, 900.0),
                            seed=seed + s) for s in range(S)]
    return stack_scenarios(traces, 32)


def test_replay_grid_selects_per_objective():
    scen = _grid()
    pool = parse_pool("extended")
    out_aw = REF.replay_grid(scen, pool.spec, "avg_wait")
    out_ut = REF.replay_grid(scen, pool.spec, "utilization")
    assert out_aw.costs.shape == out_aw.deadlocked.shape
    dead = np.asarray(out_aw.deadlocked)
    aw = np.where(dead, np.inf, np.asarray(out_aw.metrics.avg_wait))
    np.testing.assert_array_equal(np.asarray(out_aw.best),
                                  np.argmin(aw, axis=1))
    ut = np.where(dead, -np.inf, np.asarray(out_ut.metrics.utilization))
    np.testing.assert_array_equal(np.asarray(out_ut.best),
                                  np.argmax(ut, axis=1))
    # replay times themselves are objective-independent
    np.testing.assert_array_equal(np.asarray(out_aw.end_t),
                                  np.asarray(out_ut.end_t))


def test_replay_grid_default_objective_is_score():
    scen = _grid(S=2)
    pool = parse_pool("paper")
    out = REF.replay_grid(scen, pool.spec)
    costs = policy_cost(out.metrics, PAPER_WEIGHTS)
    costs = jnp.where(out.deadlocked, jnp.inf, costs)
    np.testing.assert_array_equal(np.asarray(out.costs),
                                  np.asarray(costs))
    np.testing.assert_array_equal(np.asarray(out.best),
                                  np.argmin(np.asarray(costs), axis=1))


def test_single_scenario_replay_best_scalar():
    scen = _grid(S=1)
    out = REF.replay(scen, parse_pool("extended").spec, "makespan")
    assert out.costs.shape == (7,)
    assert out.best.shape == ()
    ms = np.where(np.asarray(out.deadlocked), np.inf,
                  np.asarray(out.metrics.makespan))
    assert int(out.best) == int(np.argmin(ms))


def test_sharded_replay_grid_objective(mesh11):
    from repro.core.whatif import sharded_replay_grid
    scen = _grid(S=2)
    pool = parse_pool("extended")
    fn = sharded_replay_grid(mesh11, "data", objective="avg_wait")
    out = fn(scen, pool)
    ref = REF.replay_grid(scen, pool.spec, "avg_wait")
    np.testing.assert_array_equal(np.asarray(out.best),
                                  np.asarray(ref.best))
    np.testing.assert_array_equal(np.asarray(out.costs),
                                  np.asarray(ref.costs))


# ----------------------------------------------------------------------
# Host-side report scoring + config/emulator surfaces.
# ----------------------------------------------------------------------

def test_report_costs_matches_device_semantics():
    rows = [
        {"avg_wait": 10.0, "max_wait": 60.0, "avg_slowdown": 2.0,
         "max_slowdown": 4.0, "makespan": 100.0, "utilization": 0.8},
        {"avg_wait": 20.0, "max_wait": 120.0, "avg_slowdown": 3.0,
         "max_slowdown": 6.0, "makespan": 90.0, "utilization": 0.9},
    ]
    m = metrics_from_rows(rows)
    np.testing.assert_allclose(
        report_costs("score", rows),
        np.asarray(policy_cost(m, PAPER_WEIGHTS)))
    np.testing.assert_allclose(report_costs("utilization", rows),
                               [-0.8, -0.9])


def test_twin_config_objective_and_legacy_weights():
    from repro.configs.schedtwin import TwinConfig
    assert TwinConfig().make_objective() == PaperScore()
    cfg = TwinConfig(objective="min:avg_wait@util>=0.85")
    assert isinstance(cfg.make_objective(), Constrained)
    with pytest.warns(DeprecationWarning):
        legacy = TwinConfig(weights=ScoreWeights(0.5, 0.5, 0.0, 0.0)
                            ).make_objective()
    assert legacy == PaperScore(ScoreWeights(0.5, 0.5, 0.0, 0.0))


def test_emulator_run_stamps_objective():
    from repro.cluster.emulator import ClusterEmulator
    from repro.cluster.workload import poisson_trace
    trace = poisson_trace(12, 32, 8.0, (1, 16), (30.0, 900.0), seed=1)
    rep = ClusterEmulator(trace, 32).run(policy_id=1, fast=True,
                                         objective="avg_wait")
    assert rep.objective == "avg_wait"
    np.testing.assert_allclose(rep.objective_cost, rep.avg_wait,
                               rtol=1e-5)
    assert set(rep.objective_terms) == {"avg_wait"}
    rep2 = ClusterEmulator(trace, 32).run(policy_id=1, fast=True)
    assert rep2.objective is None
    # rank-based goals have no scalar cost for a lone run (a single
    # candidate's composed rank is identically 0) — terms only
    rep3 = ClusterEmulator(trace, 32).run(
        policy_id=1, fast=True, objective="min:avg_wait@util>=0.9")
    assert rep3.objective_cost is None
    assert "violation:utilization>=0.9" in rep3.objective_terms
    assert rep3.objective_terms["violation:utilization>=0.9"] > 0.0


def test_twin_records_objective_telemetry():
    from repro.cluster.emulator import ClusterEmulator
    from repro.cluster.workload import poisson_trace
    from repro.core.events import EventBus
    from repro.core.twin import SchedTwin
    trace = poisson_trace(12, 16, 6.0, (1, 8), (30.0, 300.0), seed=2)
    bus = EventBus()
    em = ClusterEmulator(trace, 16, bus=bus)
    twin = SchedTwin(bus=bus, qrun=em.qrun, total_nodes=16,
                     max_jobs=em.max_jobs, pool="paper",
                     objective="min:avg_wait@util>=0.5",
                     free_nodes_probe=lambda: em.free_nodes)
    em.run(on_event=twin.pump)
    assert twin.telemetry.cycles
    rec = twin.telemetry.cycles[0]
    assert rec.objective == "min:avg_wait@utilization>=0.5"
    assert set(rec.term_costs) == {"WFP", "FCFS", "SJF"}
    for terms in rec.term_costs.values():
        assert "avg_wait" in terms
        assert "violation:utilization>=0.5" in terms
    breakdown = twin.telemetry.objective_breakdown()
    assert set(breakdown) == {"WFP", "FCFS", "SJF"}
