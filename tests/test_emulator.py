"""Host-emulator regressions: the stale-end sequence guard and the
failure-timeline utilization denominator."""
import numpy as np
import pytest

from repro.cluster.emulator import ClusterEmulator, FailureSpec
from repro.cluster.workload import JobSpec
from repro.core.policies import FCFS


def test_stale_end_seq_guard_on_colliding_restart():
    """A killed job restarts so soon that its rescheduled end quantizes
    to the SAME event time as the stale end from its first run.  The
    old float-epsilon guard (`t < end_t - 1e-9`) mis-retired the job at
    the stale event — one heap position early — which reordered the
    same-instant scheduling passes: the full-cluster head job then
    waited behind a long backfill (start 400) instead of starting at
    100.  The sequence guard skips the stale event and retires the job
    at the end event its own restart pushed.
    """
    trace = [
        # restarts at 2e-7 after a transient failure; both its stale end
        # (0 + 100) and its real end (2e-7 + 100) quantize to f32 100.0
        JobSpec(0, 0.0, 16, 300.0, 100.0, "restarted"),
        # actual end also at f32(5e-8 + 100) == 100.0, its end event
        # sits BETWEEN job 0's stale and real end events in the heap
        JobSpec(1, 5e-8, 16, 500.0, 100.0, "between"),
        JobSpec(2, 10.0, 32, 10.0, 10.0, "head"),
        JobSpec(3, 20.0, 4, 300.0, 300.0, "backfill"),
    ]
    failures = [FailureSpec(time=1e-7, nodes=5, duration=1e-7)]
    em = ClusterEmulator(trace, 32, failures=failures,
                         check_invariants=True)
    rep = em.run(policy_id=FCFS)
    assert rep.n_restarts == 1
    # correct order: job 1 retires first (pass sees job 0 still running,
    # shadow 300 blocks the backfill), then job 0's REAL end retires it
    # and the head starts at 100; the backfill follows at 110.
    assert rep.start_t[2] == pytest.approx(100.0)
    assert rep.start_t[3] == pytest.approx(110.0)
    assert rep.end_t[0] == pytest.approx(100.0)


def test_utilization_integrates_failure_timeline():
    """A permanent (duration=0) failure halves the cluster mid-run; the
    utilization denominator must integrate the shrunken capacity, not
    divide by the original ``total_nodes`` for the whole span."""
    trace = [
        JobSpec(0, 0.0, 8, 100.0, 100.0, "a"),    # runs 0..100
        JobSpec(1, 60.0, 8, 100.0, 100.0, "b"),   # waits, runs 100..200
    ]
    failures = [FailureSpec(time=50.0, nodes=8, duration=0.0)]
    em = ClusterEmulator(trace, 16, failures=failures,
                         check_invariants=True)
    rep = em.run(policy_id=FCFS)
    assert rep.n_restarts == 0
    np.testing.assert_allclose(rep.start_t, [0.0, 100.0])
    # node-seconds = 2 * 8 * 100 = 1600; available = 16*50 + 8*150 = 2000
    assert rep.utilization == pytest.approx(1600.0 / 2000.0)
    # the old denominator (total_nodes * makespan = 16 * 200) said 0.5
    assert rep.utilization != pytest.approx(0.5)


def test_utilization_unchanged_without_failures():
    trace = [JobSpec(0, 0.0, 8, 100.0, 100.0, "a"),
             JobSpec(1, 0.0, 8, 50.0, 50.0, "b")]
    rep = ClusterEmulator(trace, 16).run(policy_id=FCFS)
    # node-seconds 8*100 + 8*50 = 1200 over 16 * 100
    assert rep.utilization == pytest.approx(1200.0 / 1600.0)


def test_event_times_are_f32_representable():
    """Ingestion quantizes job fields to f32, so every event time (a
    sum of f32 values in f64) is itself exactly f32-representable —
    the property that keeps host and device replays bit-identical."""
    from repro.cluster.workload import poisson_trace
    trace = poisson_trace(16, 16, 5.3, (1, 12), (31.7, 299.9), seed=11)
    rep = ClusterEmulator(trace, 16).run(policy_id=FCFS)
    for arr in (rep.start_t, rep.end_t, rep.submit_t):
        np.testing.assert_array_equal(arr, arr.astype(np.float32))
