"""Data pipeline, event bus, sharding rules, workload, scoring, serve."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.workload import (PAPER_PHASES, paper_synthetic_trace,
                                    poisson_trace, read_swf, trace_stats,
                                    write_swf, arch_job_mix)
from repro.core.events import Event, EventBus, EventKind
from repro.data import DataConfig, SyntheticLM, host_slice
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_mesh


# ---------------------------------------------------------------- data

def test_data_deterministic_and_restart_safe():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=7)
    a = SyntheticLM(cfg).batch(3)
    b = SyntheticLM(cfg).batch(3)       # fresh instance, same step
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg).batch(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_labels_shift():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2, seed=0)
    b = SyntheticLM(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_is_learnable_not_uniform():
    """Markov structure: next-token entropy must be far below log V."""
    cfg = DataConfig(vocab_size=64, seq_len=512, global_batch=2, seed=0,
                     branch=4)
    b = SyntheticLM(cfg).batch(0)
    pairs = set(zip(b["tokens"].ravel(), b["labels"].ravel()))
    # 64 states x 4 successors = <=256 distinct bigrams (uniform: ~4096)
    assert len(pairs) <= 64 * 4 + 1


def test_host_slice_partitions():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=8, seed=0)
    b = SyntheticLM(cfg).batch(0)
    parts = [host_slice(b, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b["tokens"])


# ---------------------------------------------------------------- events

def test_bus_consumer_offsets_independent():
    bus = EventBus()
    for t in range(5):
        bus.publish(Event(EventKind.QUEUEJOB, float(t), t))
    assert len(bus.read("a")) == 5
    assert len(bus.read("a")) == 0
    assert len(bus.read("b")) == 5        # b has its own offset
    bus.publish(Event(EventKind.JOBOBIT, 9.0, 0))
    assert len(bus.read("a")) == 1


def test_bus_replay_and_seq():
    bus = EventBus()
    for t in range(3):
        bus.publish(Event(EventKind.QUEUEJOB, float(t), t))
    seqs = [e.seq for e in bus.replay()]
    assert seqs == [0, 1, 2]


# ---------------------------------------------------------------- sharding

def test_sharding_divisibility_fallback():
    """A 16-way model axis cannot shard 12 heads -> replicate, and the
    sequence axis carries the parallelism instead (whisper case)."""
    import dataclasses
    from types import SimpleNamespace
    from repro.distributed.sharding import ShardingRules
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh, "fsdp_tp")
    fake = dataclasses.replace(
        rules, mesh=SimpleNamespace(shape={"data": 16, "model": 16}))
    spec = fake.spec_for(("heads", "head_dim"), (12, 64))
    assert spec == jax.sharding.PartitionSpec(None, None)
    spec = fake.spec_for(("heads", "head_dim"), (48, 64))
    assert spec == jax.sharding.PartitionSpec("model", None)
    spec = fake.spec_for(("batch", "kv_seq"), (128, 32768))
    assert spec[0] == "data"


def test_sharding_axes_never_reused():
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh, "fsdp_tp")
    spec = rules.spec_for(("d_ff", "d_model"), (128, 64))
    # d_ff -> model, d_model -> data; no axis may appear twice
    flat = [a for part in spec if part for a in
            (part if isinstance(part, tuple) else (part,))]
    assert len(flat) == len(set(flat))


def test_decode_rules_shard_kv_seq():
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh, "decode")
    assert rules.rules["kv_seq"] == ("model",)


# ---------------------------------------------------------------- workload

def test_paper_trace_matches_section_4_1():
    trace = paper_synthetic_trace(seed=0)
    assert len(trace) == 150
    tags = [j.tag for j in trace]
    assert tags.count("warmup") == 25 and tags.count("burst") == 35
    assert tags.count("steady") == 40 and tags.count("tail") == 50
    for j in trace:
        assert j.true_runtime <= j.est_runtime + 1e-6  # users overestimate
    gaps = np.diff([j.submit_t for j in trace])
    assert np.allclose(gaps, 5.0)
    burst = [j for j in trace if j.tag == "burst"]
    assert all(16 <= j.nodes <= 20 for j in burst)
    assert all(500 <= j.est_runtime <= 700 for j in burst)


def test_swf_roundtrip(tmp_path):
    trace = poisson_trace(20, 32, 10.0, (1, 8), (60, 600), seed=1)
    path = str(tmp_path / "w.swf")
    write_swf(trace, path)
    back = read_swf(path)
    assert len(back) == 20
    assert all(abs(a.nodes - b.nodes) == 0 for a, b in zip(trace, back))


def test_arch_job_mix_tags_and_bounds():
    jobs = arch_job_mix(50, total_pods=32, seed=0)
    assert len(jobs) == 50
    assert all(1 <= j.nodes <= 32 for j in jobs)
    assert all(":" in j.tag for j in jobs)


# ---------------------------------------------------------------- serve

def test_serving_engine_continuous_batching(mesh11, rules_decode):
    from repro.configs import get_smoke_config
    from repro.models import api
    from repro.models.common import init_params
    from repro.serve import Request, ServingEngine

    cfg = get_smoke_config("llama3.2-1b")
    params = init_params(jax.random.PRNGKey(0), api.param_table(cfg))
    with mesh11:
        eng = ServingEngine(cfg, rules_decode, params, batch_slots=2,
                            max_seq=24)
        for r in range(5):
            eng.submit(Request(req_id=r,
                               prompt=np.arange(4, dtype=np.int32) + r,
                               max_new_tokens=6))
        eng.run_until_drained(max_iters=500)
    done = [r for r in eng.queue] == []
    assert done
    assert all(r is None for r in eng.active)


def test_serving_admission_override(mesh11, rules_decode):
    """Custom admission: shortest-prompt-first actually reorders."""
    from repro.configs import get_smoke_config
    from repro.models import api
    from repro.models.common import init_params
    from repro.serve import Request, ServingEngine

    cfg = get_smoke_config("llama3.2-1b")
    params = init_params(jax.random.PRNGKey(0), api.param_table(cfg))
    order = []

    def admit(queue):
        idx = min(range(len(queue)), key=lambda i: len(queue[i].prompt))
        order.append(queue[idx].req_id)
        return idx

    with mesh11:
        eng = ServingEngine(cfg, rules_decode, params, batch_slots=1,
                            max_seq=24, admission=admit)
        eng.submit(Request(0, np.arange(8, dtype=np.int32), 2))
        eng.submit(Request(1, np.arange(2, dtype=np.int32), 2))
        eng.submit(Request(2, np.arange(4, dtype=np.int32), 2))
        eng.run_until_drained(max_iters=500)
    assert order[0] == 0 or order[:2] == [1, 2] or order[0] == 1
