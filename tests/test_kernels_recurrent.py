"""Shape sweeps: wkv6 + rglru kernels vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("b,s,h,n", [
    (1, 32, 1, 8),
    (2, 64, 2, 16),
    (2, 128, 4, 32),
    (1, 256, 2, 64),      # production head size
])
@pytest.mark.parametrize("block_t", [16, 64])
def test_wkv6_matches_oracle(b, s, h, n, block_t):
    ks = jax.random.split(jax.random.PRNGKey(b * s + n), 5)
    r = jax.random.normal(ks[0], (b, s, h, n))
    k = jax.random.normal(ks[1], (b, s, h, n))
    v = jax.random.normal(ks[2], (b, s, h, n))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, n)))  # (0,1)
    u = jax.random.normal(ks[4], (h, n))
    y, st = ops.wkv6(r, k, v, w, u, block_t=min(block_t, s))
    y2, st2 = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st2),
                               atol=2e-4, rtol=2e-4)


def test_wkv6_state_streams_across_tiles():
    """Same result whether the sequence is one tile or many."""
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    b, s, h, n = 1, 128, 2, 16
    r = jax.random.normal(ks[0], (b, s, h, n))
    k = jax.random.normal(ks[1], (b, s, h, n))
    v = jax.random.normal(ks[2], (b, s, h, n))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, n)))
    u = jax.random.normal(ks[4], (h, n))
    y1, st1 = ops.wkv6(r, k, v, w, u, block_t=128)
    y2, st2 = ops.wkv6(r, k, v, w, u, block_t=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), atol=1e-5)


@pytest.mark.parametrize("b,s,w", [
    (1, 32, 16),
    (2, 128, 64),
    (2, 256, 256),
    (4, 64, 128),
])
@pytest.mark.parametrize("block_t,block_w", [(16, 16), (64, 64)])
def test_rglru_matches_oracle(b, s, w, block_t, block_w):
    ks = jax.random.split(jax.random.PRNGKey(s + w), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, w)))
    x = jax.random.normal(ks[1], (b, s, w))
    h0 = jax.random.normal(ks[2], (b, w))
    h, hT = ops.rglru(a, x, h0, block_t=min(block_t, s),
                      block_w=min(block_w, w))
    h2, hT2 = ref.rglru_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h2),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT2),
                               atol=1e-5, rtol=1e-5)


def test_rglru_nonzero_initial_state():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    b, s, w = 2, 64, 32
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, w)))
    x = jax.random.normal(ks[1], (b, s, w))
    h0 = 5.0 * jax.random.normal(ks[2], (b, w))
    h, hT = ops.rglru(a, x, h0, block_t=16, block_w=16)
    h2, hT2 = ref.rglru_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT2), atol=1e-4)
