"""On-device policy learning (DESIGN.md §13).

Pins the trainer's load-bearing invariants:

- generation evaluation (the candidate population riding the fork
  axis of ONE ``engine.generation_costs`` grid) is BITWISE the per-θ
  serial ``replay_grid`` oracle, on both pass backends, with and
  without a domain-randomization fan;
- ES and CEM steps are deterministic under a fixed seed, and their
  draws are antithetic-paired and prefix-stable in the population size
  (the ``fold_in`` CRN discipline of ``core/fan.py``);
- a full ``train()`` run is deterministic end-to-end, and
  save -> load -> resume reproduces the uninterrupted run bitwise
  (history, incumbent θ, checkpoint metadata);
- held-out early stopping triggers (σ=0 search is flat after gen 0);
- the ``trained:<ckpt>`` grammar deploys exactly the θ the trainer
  returned, composable with static terms;
- ``split_scenarios`` is seed-reproducible and train/held-out are
  disjoint segments of one rng stream (no leakage).
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.cluster.workload import poisson_trace, split_scenarios
from repro.core.engine import DrainEngine
from repro.core.fan import FanSpec
from repro.core.policies import (FAM_LIN, FAM_WFP, N_THETA, parse_pool,
                                 theta_pool)
from repro.learn import (CEM, ES, TrainConfig, family_space,
                         load_trained_pool, static_seeds, train)
from repro.learn.strategy import centered_rank_utilities, draw_eps

REF = DrainEngine("reference")
PAL = DrainEngine("pallas", interpret=True)

TRACE = lambda r: poisson_trace(16, 16, 45.0, (1, 6), (60.0, 900.0), rng=r)


@pytest.fixture(scope="module")
def split():
    rng = np.random.default_rng(3)
    return split_scenarios(rng, TRACE, 3, 2, 16)


def tiny_config(**kw):
    base = dict(family="lin", strategy="cem", population=6, generations=3,
                objective="avg_wait", seed=5, patience=0)
    base.update(kw)
    return TrainConfig(**base)


# ----------------------------------------------------------------------
# generation eval == per-θ serial oracle, bitwise, both backends
# ----------------------------------------------------------------------

@pytest.mark.parametrize("eng", [REF, PAL], ids=["reference", "pallas"])
def test_generation_costs_bitwise_serial(split, eng):
    train_scen, _ = split
    space = family_space("lin")
    thetas = space.decode(draw_eps(0, 0, 5, space.dim, True))
    pool = theta_pool(FAM_LIN, thetas)
    batched = np.asarray(eng.generation_costs(train_scen, pool.spec,
                                              "avg_wait"))
    serial = np.stack([
        np.asarray(eng.replay_grid(
            train_scen, theta_pool(FAM_LIN, thetas[i:i + 1]).spec,
            "avg_wait").costs)[:, 0]
        for i in range(len(thetas))], axis=1)
    assert np.array_equal(batched, serial)


@pytest.mark.parametrize("eng", [REF, PAL], ids=["reference", "pallas"])
def test_generation_costs_fan_bitwise_serial(split, eng):
    train_scen, _ = split
    fan = FanSpec(n=3, runtime_noise=0.3, seed=2)
    thetas = family_space("wfp").decode(draw_eps(1, 0, 4, 3, True))
    pool = theta_pool(FAM_WFP, thetas)
    batched = np.asarray(eng.generation_costs(train_scen, pool.spec,
                                              "avg_wait", fan))
    serial = np.stack([
        np.asarray(eng.fan_grid(
            train_scen, theta_pool(FAM_WFP, thetas[i:i + 1]).spec, fan,
            "avg_wait").costs)[:, 0]
        for i in range(len(thetas))], axis=1)
    assert np.array_equal(batched, serial)


def test_sharded_generation_costs_bitwise(split):
    from repro.core.whatif import sharded_generation_costs
    from repro.launch.mesh import make_fleet_mesh
    train_scen, _ = split
    thetas = family_space("lin").decode(draw_eps(0, 1, 4, 6, True))
    pool = theta_pool(FAM_LIN, thetas)
    local = np.asarray(REF.generation_costs(train_scen, pool.spec,
                                            "avg_wait"))
    mesh = make_fleet_mesh(1)
    run = sharded_generation_costs(mesh, engine=REF, objective="avg_wait",
                                   block_size=2)
    assert np.array_equal(np.asarray(run(train_scen, pool.spec)), local)


# ----------------------------------------------------------------------
# strategy determinism, antithetic pairing, prefix stability
# ----------------------------------------------------------------------

@pytest.mark.parametrize("strat_cls", [ES, CEM], ids=["es", "cem"])
def test_strategy_step_deterministic(strat_cls):
    space = family_space("wfp")
    fit = np.asarray([3.0, 1.0, np.inf, 2.0, 5.0, 0.5], np.float64)
    states = []
    for _ in range(2):
        s = strat_cls(population=6, seed=9)
        st = s.init(np.asarray(space.x0), np.asarray(space.sigma0))
        z = s.ask(st)
        st2 = s.tell(st, z, fit)
        states.append((z, st2))
    (z_a, st_a), (z_b, st_b) = states
    assert np.array_equal(z_a, z_b)
    assert np.array_equal(st_a.mean, st_b.mean)
    assert np.array_equal(st_a.sigma, st_b.sigma)
    assert st_a.gen == st_b.gen == 1


def test_draws_antithetic_and_prefix_stable():
    small = draw_eps(seed=4, gen=2, population=6, dim=5, antithetic=True)
    big = draw_eps(seed=4, gen=2, population=10, dim=5, antithetic=True)
    # prefix: the 6-candidate population IS the first 6 of the 10
    assert np.array_equal(small, big[:6])
    # antithetic: pairs (2j, 2j+1) mirror exactly
    assert np.array_equal(small[0::2], -small[1::2])
    # CRN across generations: same (seed, gen) reproduces; gens differ
    assert np.array_equal(small, draw_eps(4, 2, 6, 5, True))
    assert not np.array_equal(small, draw_eps(4, 3, 6, 5, True))


def test_rank_utilities_nonfinite_worst():
    u = centered_rank_utilities(np.asarray([2.0, np.inf, 1.0, np.nan]))
    assert u[2] == 0.5                 # best cost -> top utility
    assert {u[1], u[3]} == {min(u), sorted(u)[1]}  # non-finite at bottom
    assert abs(float(u.sum())) < 1e-6


# ----------------------------------------------------------------------
# train(): determinism, checkpoint resume parity, early stop
# ----------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["cem", "es"])
def test_train_deterministic(split, strategy, tmp_path):
    train_scen, heldout = split
    runs = [train(train_scen, heldout, tiny_config(strategy=strategy),
                  engine=REF) for _ in range(2)]
    assert np.array_equal(runs[0].theta, runs[1].theta)
    assert runs[0].history == runs[1].history
    assert runs[0].best_heldout == runs[1].best_heldout


def test_checkpoint_resume_bitwise(split, tmp_path):
    train_scen, heldout = split
    cfg = tiny_config(generations=4)
    full = train(train_scen, heldout, cfg, engine=REF,
                 checkpoint_dir=str(tmp_path / "full"))

    part_dir = str(tmp_path / "part")
    train(train_scen, heldout, dataclasses.replace(cfg, generations=2),
          engine=REF, checkpoint_dir=part_dir)
    resumed = train(train_scen, heldout, cfg, engine=REF,
                    checkpoint_dir=part_dir, resume=True)

    assert np.array_equal(full.theta, resumed.theta)
    assert full.history == resumed.history
    assert full.best_heldout == resumed.best_heldout
    # and the persisted artifacts agree too
    a = load_trained_pool(str(tmp_path / "full"))
    b = load_trained_pool(part_dir)
    assert np.array_equal(np.asarray(a.spec.theta), np.asarray(b.spec.theta))


def test_heldout_early_stop_triggers(split):
    train_scen, heldout = split
    # ES with σ=0 proposes the identical candidate set forever, so
    # held-out can never improve after gen 0 and patience must fire
    cfg = tiny_config(strategy="es", generations=10, patience=2,
                      sigma_scale=0.0)
    res = train(train_scen, heldout, cfg, engine=REF)
    assert res.stopped_early
    assert res.generations_run == 3   # gen 0 improves, then 2 flat gens
    assert all(not r["improved"] for r in res.history[1:])


def test_warm_start_floors_at_best_static(split):
    train_scen, heldout = split
    # the family's static fixed points ride the gen-0 grid as exact θ
    # rows, so the incumbent can never lose to the best representable
    # static on held-out — even after a single degenerate generation
    res = train(train_scen, heldout,
                tiny_config(generations=1, sigma_scale=0.0), engine=REF)
    names, thetas = zip(*static_seeds(FAM_LIN))
    costs = REF.replay_grid(
        heldout, theta_pool(FAM_LIN, np.stack(thetas), names).spec,
        "avg_wait").costs
    agg = np.asarray(costs, np.float64).mean(axis=0)
    assert res.best_heldout <= float(agg.min())


# ----------------------------------------------------------------------
# trained:<ckpt> grammar + deploy parity
# ----------------------------------------------------------------------

def test_trained_grammar_deploy_parity(split, tmp_path):
    train_scen, heldout = split
    ckpt = str(tmp_path / "ck")
    res = train(train_scen, heldout, tiny_config(), engine=REF,
                checkpoint_dir=ckpt)
    pool = parse_pool(f"trained:{ckpt},paper")
    assert pool.names[0] == "trained[lin]"
    assert len(pool) == 4
    assert np.array_equal(np.asarray(pool.spec.theta[0]), res.theta)
    # deploy parity: the loaded pool's costs are bitwise the in-memory
    # result's on the same grid
    via_ckpt = np.asarray(REF.replay_grid(heldout, pool.spec,
                                          "avg_wait").costs)[:, 0]
    in_mem = np.asarray(REF.replay_grid(heldout, res.pool.spec,
                                        "avg_wait").costs)[:, 0]
    assert np.array_equal(via_ckpt, in_mem)


def test_trained_grammar_errors(tmp_path):
    with pytest.raises(ValueError, match="checkpoint"):
        parse_pool("trained:")
    with pytest.raises(FileNotFoundError):
        parse_pool(f"trained:{tmp_path}/nope")
    with pytest.raises(ValueError, match="no valid checkpoint"):
        empty = tmp_path / "empty"
        empty.mkdir()
        parse_pool(f"trained:{empty}")


# ----------------------------------------------------------------------
# split_scenarios: seed parity + leakage-impossible split
# ----------------------------------------------------------------------

def test_split_scenarios_seed_parity():
    a = split_scenarios(np.random.default_rng(11), TRACE, 4, 3, 16)
    b = split_scenarios(np.random.default_rng(11), TRACE, 4, 3, 16)
    for xa, xb in zip(a, b):
        assert np.array_equal(np.asarray(xa.submit_t),
                              np.asarray(xb.submit_t))
        assert np.array_equal(np.asarray(xa.true_runtime),
                              np.asarray(xb.true_runtime))


def test_split_scenarios_disjoint_and_stream_ordered():
    rng = np.random.default_rng(11)
    tr, he = split_scenarios(rng, TRACE, 4, 3, 16)
    assert tr.submit_t.shape[0] == 4 and he.submit_t.shape[0] == 3
    assert tr.submit_t.shape[1] == he.submit_t.shape[1]  # common padding
    # the split is an index partition of ONE stream: drawing 7 traces
    # from a fresh identical rng reproduces train = first 4, held-out
    # = last 3 — held-out rows can never alias training rows
    rng2 = np.random.default_rng(11)
    all7 = [TRACE(rng2) for _ in range(7)]
    from repro.cluster.workload import stack_scenarios
    ref_he = stack_scenarios(all7[4:], 16,
                             max_jobs=int(he.submit_t.shape[1]))
    assert np.array_equal(he.submit_t, ref_he.submit_t)
    assert np.array_equal(he.true_runtime, ref_he.true_runtime)
    # no held-out row equals any training row
    for s in range(3):
        for t in range(4):
            assert not np.array_equal(he.true_runtime[s],
                                      tr.true_runtime[t])


def test_split_scenarios_validation():
    with pytest.raises(ValueError, match="n_train"):
        split_scenarios(np.random.default_rng(0), TRACE, 0, 1, 16)
    with pytest.raises(ValueError, match="total_nodes"):
        split_scenarios(np.random.default_rng(0), TRACE, 2, 1, [16, 16])
