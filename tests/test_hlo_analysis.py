"""HLO analyzer: trip counts, dot FLOPs, collective byte parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import HloModule, roofline_from_compiled

SYNTH = """
ENTRY %main.1 (p0: f32[128,128]) -> f32[128,128] {
  %p0 = f32[128,128]{1,0} parameter(0)
  %ag = f32[128,2048]{1,0} all-gather(%p0), replica_groups={}, dimensions={1}
  %ar = f32[128,128]{1,0} all-reduce(%p0), to_apply=%add.1
  %rs = f32[8,128]{1,0} reduce-scatter(%p0), dimensions={0}
  %cp = f32[128,128]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %dot.1 = f32[128,128]{1,0} dot(%p0, %p0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_synthetic_collectives_and_dot():
    mod = HloModule(SYNTH)
    c = mod.cost(mod.entry)
    f = 128 * 128 * 4  # p0 bytes
    assert c.coll_bytes["all-gather"] == f
    assert c.coll_bytes["all-reduce"] == f
    assert c.coll_bytes["reduce-scatter"] == f
    assert c.coll_bytes["collective-permute"] == f
    assert c.coll_count["all-gather"] == 1
    assert c.flops == 2 * 128 ** 3


def test_trip_count_from_backend_config():
    text = """
%body.1 (t: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %t = (s32[], f32[4,4]{1,0}) parameter(0)
  %g = f32[4,4]{1,0} get-tuple-element(%t), index=1
  %d = f32[4,4]{1,0} dot(%g, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %tp = (s32[], f32[4,4]{1,0}) tuple(%g, %d)
}
%cond.1 (t: (s32[], f32[4,4])) -> pred[] {
  %t = (s32[], f32[4,4]{1,0}) parameter(0)
  ROOT %c = pred[] constant(1)
}
ENTRY %main.9 (x: f32[4,4]) -> f32[4,4] {
  %x = f32[4,4]{1,0} parameter(0)
  %w = (s32[], f32[4,4]{1,0}) while(%x), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %o = f32[4,4]{1,0} get-tuple-element(%w), index=1
}
"""
    mod = HloModule(text)
    c = mod.cost(mod.entry)
    assert c.flops == 7 * 2 * 4 ** 3


def test_real_scan_flops_counted_with_trips():
    x = jnp.ones((64, 64), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=6)
        return y

    compiled = jax.jit(f).lower(x).compile()
    rl = roofline_from_compiled(compiled)
    assert rl.flops == 6 * 2 * 64 ** 3
    # XLA's own analysis counts the body once — ours must exceed it
    assert rl.flops > rl.xla_flops_raw


def test_spmd_collectives_appear(monkeypatch):
    """A sharded matmul on a 1x1 mesh has no collectives; the analyzer
    must return zeros rather than crash."""
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    x = jnp.ones((64, 64))
    f = jax.jit(lambda a: a @ a,
                in_shardings=NamedSharding(mesh, P("data", "model")))
    rl = roofline_from_compiled(f.lower(x).compile())
    assert rl.collective_bytes == 0.0
    assert rl.flops == 2 * 64 ** 3


def test_finalize_terms_and_bottleneck():
    from repro.launch.hlo_analysis import Roofline
    rl = Roofline(flops=197e12, bytes_accessed=819e9 * 2,
                  collective_bytes=0.0, collective_counts={},
                  collective_by_kind={})
    rl.finalize(model_flops=197e12 * 0.5)
    assert abs(rl.t_compute - 1.0) < 1e-9
    assert abs(rl.t_memory - 2.0) < 1e-9
    assert rl.bottleneck == "memory"
    assert abs(rl.useful_ratio - 0.5) < 1e-9


def test_dryrun_cell_inputs_are_abstract():
    """input_specs produce ShapeDtypeStructs (no device allocation)."""
    from repro.configs import SHAPES
    from repro.launch.specs import cell_inputs
    spec = cell_inputs("llama3.2-1b", SHAPES["train_4k"])
    leaves = jax.tree.leaves(spec.args)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    assert spec.args[1]["tokens"].shape == (256, 4096)

    spec_d = cell_inputs("rwkv6-7b", SHAPES["long_500k"])
    assert spec_d.kind == "decode"
    assert spec_d.args[2].shape == (1, 1)   # tokens (B=1, 1)


def test_active_param_fraction_moe():
    from repro.launch.dryrun import _active_param_fraction
    from repro.configs import get_config
    f_dense = _active_param_fraction(get_config("llama3.2-1b"))
    assert f_dense == 1.0
    f_moe = _active_param_fraction(get_config("olmoe-1b-7b"))
    assert 0.0 < f_moe < 0.5      # 8 of 64 experts + backbone
