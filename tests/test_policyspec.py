"""Parametric policy space (PolicySpec) — fixed-point parity + sweeps.

The tentpole contract under test (DESIGN.md §5):

* every static policy expressed as a ``PolicySpec`` fixed point yields
  **bit-identical** priority keys, and — through the engine —
  bit-identical decisions (winner, qrun set, costs, metrics) to the
  pre-refactor integer-id path, over >= 60 random snapshots, under
  BOTH pass backends;
* sweep pools (k >= 32: DRAS-style θ grids + statics) drain through
  one batched engine call;
* the pool grammar expands terms/sweeps predictably;
* ``backend="auto"`` resolves per platform;
* ``bursty_trace`` modulates arrivals and runs through the emulator.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policies, whatif
from repro.core.engine import DrainEngine, pool_size, tile_pool
from repro.core.policies import (EXTENDED_POOL, FAM_EXP, FAM_LIN, FAM_WFP,
                                 PAPER_POOL, PolicyPool, PolicySpec,
                                 normalize_pool, parse_pool, static_spec,
                                 wfp_spec)

from conftest import make_cluster_state

REF = DrainEngine("reference")
PAL = DrainEngine("pallas", interpret=True)

N_SNAPSHOTS = 60  # acceptance: >= 60 random snapshots
MAX_JOBS = 48     # fixed shape -> one compile per (backend, pool kind)

ID_POOL = jnp.asarray(EXTENDED_POOL, dtype=jnp.int32)
SPEC_POOL = PolicyPool.from_ids(EXTENDED_POOL)


def _snapshots(n=N_SNAPSHOTS):
    for seed in range(n):
        yield make_cluster_state(
            max_jobs=MAX_JOBS, total_nodes=32, seed=seed,
            n_queued=4 + seed % 16, n_running=seed % 5,
            now=100.0 + 37.0 * seed)


def _assert_decisions_identical(da, db, ctx=""):
    assert int(da.policy_index) == int(db.policy_index), ctx
    np.testing.assert_array_equal(np.asarray(da.run_mask),
                                  np.asarray(db.run_mask), err_msg=ctx)
    np.testing.assert_array_equal(np.asarray(da.costs),
                                  np.asarray(db.costs), err_msg=ctx)
    np.testing.assert_array_equal(np.asarray(da.deadlocked),
                                  np.asarray(db.deadlocked), err_msg=ctx)
    for field, a, b in zip(da.metrics._fields, da.metrics, db.metrics):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{ctx} metric={field}")


# ----------------------------------------------------------------------
# Fixed-point parity: spec path == integer-id path, bit for bit.
# ----------------------------------------------------------------------

def test_static_specs_bitwise_key_parity():
    """Every static policy's PolicySpec produces bit-identical priority
    keys to the legacy 7-row stack on every snapshot."""
    for i, state in enumerate(_snapshots()):
        for pid in EXTENDED_POOL:
            k_id = np.asarray(policies.priority_key(
                state.jobs, state.now, jnp.int32(pid)))
            k_sp = np.asarray(policies.priority_key_spec(
                state.jobs, state.now, static_spec(pid)))
            np.testing.assert_array_equal(
                k_id, k_sp,
                err_msg=f"snapshot {i} policy {policies.policy_name(pid)}")


@pytest.mark.parametrize("engine", [REF, PAL], ids=["reference", "pallas"])
def test_static_spec_decisions_match_integer_path(engine):
    """Acceptance: spec-pool decisions (winner, qrun set, costs,
    metrics) are bit-identical to the integer-id path over >= 60 random
    snapshots, under both backends."""
    for i, state in enumerate(_snapshots()):
        d_id = engine.decide(state, ID_POOL)
        d_sp = engine.decide(state, SPEC_POOL.spec)
        _assert_decisions_identical(
            d_id, d_sp, ctx=f"snapshot {i} backend {engine.backend}")


def test_spec_ensemble_matches_integer_path():
    state = make_cluster_state(max_jobs=MAX_JOBS, seed=17)
    key = jax.random.PRNGKey(3)
    d_id = REF.decide_ensemble(state, ID_POOL, key, n_ens=3, noise=0.25)
    d_sp = REF.decide_ensemble(state, SPEC_POOL.spec, key,
                               n_ens=3, noise=0.25)
    _assert_decisions_identical(d_id, d_sp, ctx="ensemble")


def test_emulator_static_spec_matches_static_id():
    """The emulator's static baseline is identical whether the policy
    is an integer id or its PolicySpec fixed point."""
    from repro.cluster.emulator import ClusterEmulator
    from repro.cluster.workload import JobSpec
    rng = np.random.default_rng(2)
    trace = [JobSpec(j, j * 4.0, int(rng.integers(1, 12)),
                     float(rng.uniform(30, 300)),
                     float(rng.uniform(20, 280)), "t")
             for j in range(24)]
    rep_id = ClusterEmulator(trace, 16, check_invariants=True).run(
        policy_id=policies.WFP)
    rep_sp = ClusterEmulator(trace, 16, check_invariants=True).run(
        policy_id=static_spec(policies.WFP))
    np.testing.assert_array_equal(rep_id.start_t, rep_sp.start_t)
    np.testing.assert_array_equal(rep_id.end_t, rep_sp.end_t)


def test_twin_on_spec_pool_matches_twin_on_id_pool():
    """SchedTwin normalizes id pools to spec fixed points; a twin fed
    the grammar string must behave identically to one fed the ids."""
    from repro.cluster.emulator import ClusterEmulator
    from repro.cluster.workload import poisson_trace
    from repro.core.events import EventBus
    from repro.core.twin import SchedTwin

    trace = poisson_trace(24, 16, 6.0, (1, 10), (30.0, 300.0), seed=9)
    reports = {}
    for pool in (list(PAPER_POOL), "paper"):
        bus = EventBus()
        em = ClusterEmulator(trace, 16, bus=bus, check_invariants=True)
        twin = SchedTwin(bus=bus, qrun=em.qrun, total_nodes=16,
                         max_jobs=em.max_jobs, pool=pool)
        reports[str(pool)] = (em.run(on_event=twin.pump), twin)
    (rep_a, twin_a), (rep_b, twin_b) = reports.values()
    np.testing.assert_array_equal(rep_a.start_t, rep_b.start_t)
    assert (twin_a.telemetry.policy_start_distribution()
            == twin_b.telemetry.policy_start_distribution())


# ----------------------------------------------------------------------
# Sweep pools: k >= 32 parameter grids through one batched drain.
# ----------------------------------------------------------------------

def test_sweep_pool_k32_drains_batched():
    from repro.configs.schedtwin import DRAS_SWEEP_POOL
    pool = parse_pool(DRAS_SWEEP_POOL)
    assert len(pool) == 32  # 7 statics + 5x5 (a, tau) grid
    state = make_cluster_state(max_jobs=MAX_JOBS, seed=23, n_queued=14,
                               n_running=3)
    d = REF.decide(state, pool.spec)
    costs = np.asarray(d.costs)
    assert costs.shape == (32,)
    assert np.all(np.isfinite(costs))          # nothing deadlocked/nan
    assert not np.asarray(d.deadlocked).any()
    # the winner's qrun set is reproducible from its own fork
    best = int(d.policy_index)
    res = REF.drain(state, pool.spec)
    np.testing.assert_array_equal(np.asarray(d.run_mask),
                                  np.asarray(res.first_started)[best])


def test_sweep_theta_actually_changes_decisions():
    """θ is live: an extreme-aging WFP fork orders the queue unlike
    plain WFP on a snapshot with spread-out waits."""
    state = make_cluster_state(max_jobs=MAX_JOBS, seed=31, n_queued=12,
                               n_running=2)
    k_plain = np.asarray(policies.priority_key_spec(
        state.jobs, state.now, wfp_spec()))
    k_aged = np.asarray(policies.priority_key_spec(
        state.jobs, state.now, wfp_spec(a=1.0, tau=60.0)))
    queued = np.asarray(state.jobs.state) == 1
    assert not np.array_equal(np.argsort(k_plain[queued]),
                              np.argsort(k_aged[queued]))


def test_sharded_whatif_accepts_spec_pool(mesh11):
    decide_sharded = whatif.sharded_whatif(mesh11)
    state = make_cluster_state(max_jobs=MAX_JOBS, seed=4)
    pool = parse_pool("extended,wfp:a=1..3x3")   # k=10, divisible by 1
    d = decide_sharded(state, pool)
    assert d.costs.shape == (10,)
    d_ref = REF.decide(state, pool.spec)
    _assert_decisions_identical(d, d_ref, ctx="sharded vs local")


def test_pool_size_and_tile_pool_both_kinds():
    spec = parse_pool("paper").spec
    assert pool_size(spec) == 3
    assert pool_size(ID_POOL) == 7
    tiled = tile_pool(spec, 2)
    assert pool_size(tiled) == 6
    np.testing.assert_array_equal(np.asarray(tiled.family)[:3],
                                  np.asarray(tiled.family)[3:])
    assert pool_size(tile_pool(ID_POOL, 3)) == 21


# ----------------------------------------------------------------------
# Grammar + naming.
# ----------------------------------------------------------------------

def test_parse_pool_grammar_expansion():
    pool = parse_pool("wfp,fcfs,sjf,wfp:a=1..5x5")
    assert len(pool) == 8
    assert pool.names[:3] == ("WFP", "FCFS", "SJF")
    assert pool.names[3] == "wfp[a=1]" and pool.names[7] == "wfp[a=5]"
    fam = np.asarray(pool.spec.family)
    assert fam[0] == FAM_WFP and fam[1] == FAM_LIN and fam[2] == FAM_LIN
    a = np.asarray(pool.spec.theta)[3:, policies.TH_A]
    np.testing.assert_allclose(a, [1, 2, 3, 4, 5])


def test_parse_pool_cartesian_product_and_families():
    pool = parse_pool("expf:tau=600..1800x3,lin:est=1:wait=-0.01")
    assert len(pool) == 4
    fam = np.asarray(pool.spec.family)
    assert list(fam) == [FAM_EXP] * 3 + [FAM_LIN]
    grid = parse_pool("wfp:a=1..2x2:tau=600..1200x2")
    assert len(grid) == 4  # 2x2 cartesian, rightmost fastest
    th = np.asarray(grid.spec.theta)
    np.testing.assert_allclose(th[:, policies.TH_A], [1, 1, 2, 2])
    np.testing.assert_allclose(th[:, policies.TH_TAU],
                               [600, 1200, 600, 1200])


def test_parse_pool_rejects_bad_terms():
    with pytest.raises(ValueError, match="unknown pool term"):
        parse_pool("nope")
    with pytest.raises(ValueError, match="params are"):
        parse_pool("expf:a=2")
    with pytest.raises(ValueError, match="takes no parameters"):
        parse_pool("fcfs:a=2")
    with pytest.raises(ValueError, match="lin weights index features"):
        parse_pool("lin:bogus=1")
    with pytest.raises(ValueError, match=">= 2 points"):
        parse_pool("wfp:a=1..5x1")


def test_normalize_pool_lifts_scalar_spec():
    """A scalar (unstacked) fork is lifted to a k=1 pool, so
    SchedTwin(pool=wfp_spec(a=2)) works."""
    pool = normalize_pool(wfp_spec(a=2.0))
    assert len(pool) == 1
    assert pool.names == ("wfp[a=2]",)


def test_normalize_pool_roundtrips():
    from_ids = normalize_pool(list(EXTENDED_POOL))
    assert from_ids.names == tuple(
        policies.POLICY_NAMES[i] for i in EXTENDED_POOL)
    as_spec = normalize_pool(from_ids.spec)       # bare PolicySpec stack
    assert as_spec.names == from_ids.names        # statics re-recognized
    assert normalize_pool(from_ids) is from_ids
    assert len(normalize_pool("paper")) == 3


def test_pool_concat_preserves_order():
    pool = parse_pool("paper") + parse_pool("expf:tau=600")
    assert len(pool) == 4
    assert pool.names[-1] == "expf[tau=600]"


# ----------------------------------------------------------------------
# backend="auto" + bursty workload satellites.
# ----------------------------------------------------------------------

def test_backend_auto_resolves_per_platform(caplog):
    import logging
    with caplog.at_level(logging.INFO, logger="repro.core.engine"):
        eng = DrainEngine("auto")
    expected = "pallas" if jax.default_backend() == "tpu" else "reference"
    assert eng.backend == expected
    assert any("resolved" in r.message for r in caplog.records)


def test_twin_config_auto_backend_and_pool():
    from repro.configs.schedtwin import SWEEP_TWIN, TwinConfig
    cfg = TwinConfig()
    assert cfg.backend == "auto"
    assert cfg.make_engine().backend in ("reference", "pallas")
    assert len(SWEEP_TWIN.make_pool()) == 32


def test_bursty_trace_modulates_arrivals():
    from repro.cluster.workload import bursty_trace, poisson_trace
    kw = dict(node_range=(1, 8), walltime_range=(30.0, 300.0), seed=0)
    flat = poisson_trace(400, 16, 10.0, **kw)
    burst = bursty_trace(400, 16, 10.0, period=600.0, amplitude=0.9, **kw)
    assert len(burst) == 400
    sub = np.array([j.submit_t for j in burst])
    assert np.all(np.diff(sub) > 0)
    # burstiness: dispersion of per-window arrival counts well above the
    # flat trace's (nonhomogeneous Poisson -> overdispersed counts)
    def dispersion(trace):
        t = np.array([j.submit_t for j in trace])
        counts, _ = np.histogram(t, bins=np.arange(0, t.max() + 300, 300))
        return counts.var() / max(counts.mean(), 1e-9)
    assert dispersion(burst) > 1.5 * dispersion(flat)
    with pytest.raises(ValueError, match="amplitude"):
        bursty_trace(10, 16, 10.0, amplitude=1.5, **kw)


def test_bursty_trace_runs_through_emulator():
    from repro.cluster.emulator import ClusterEmulator
    from repro.cluster.workload import bursty_trace
    trace = bursty_trace(20, 16, 6.0, (1, 8), (30.0, 200.0), seed=3,
                         period=300.0, amplitude=0.8)
    rep = ClusterEmulator(trace, 16, check_invariants=True).run(
        policy_id=policies.FCFS)
    assert rep.n_jobs == 20
