"""Replay-engine parity: the batched device replay (DESIGN.md §6)
against the host emulator's static mode, which is kept as the
bit-exact oracle.

The acceptance contract: ``engine.replay`` start/end times are
bit-identical to the host event loop over ≥ 40 random (trace, policy)
combinations under BOTH pass backends, and per-scenario metrics agree
exactly (the emulator's ``fast=True`` path runs the same numpy report
code over the replayed arrays).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.emulator import ClusterEmulator
from repro.cluster.workload import (JobSpec, bursty_trace, make_scenario,
                                    poisson_trace, stack_scenarios)
from repro.core.engine import DrainEngine
from repro.core.policies import EXTENDED_POOL, FCFS, WFP, parse_pool
from repro.core.state import DONE, QUEUED

REF = DrainEngine("reference")
PAL = DrainEngine("pallas")       # interpret-mode on CPU
POOL = jnp.asarray(EXTENDED_POOL, dtype=jnp.int32)
MAX_JOBS = 64


def random_traces(n_traces: int, n_jobs: int = 20, total_nodes: int = 16):
    """A mix of poisson and bursty traces across seeds/params."""
    out = []
    for i in range(n_traces):
        gen = bursty_trace if i % 2 else poisson_trace
        out.append(gen(n_jobs, total_nodes, 4.0 + i, (1, total_nodes - 4),
                       (30.0, 400.0), seed=100 + i))
    return out


def assert_replay_matches_host(trace, total_nodes, engine,
                               pool_ids=EXTENDED_POOL):
    """One trace x every pool policy: device replay vs host loop."""
    scen = make_scenario(trace, total_nodes, max_jobs=MAX_JOBS)
    out = engine.replay(scen, jnp.asarray(pool_ids, dtype=jnp.int32))
    n = len(trace)
    start = np.asarray(out.start_t)
    end = np.asarray(out.end_t)
    for p, pid in enumerate(pool_ids):
        em = ClusterEmulator(trace, total_nodes, engine=engine,
                             max_jobs=MAX_JOBS)
        rep = em.run(policy_id=pid)
        np.testing.assert_array_equal(
            start[p, :n], rep.start_t.astype(np.float32),
            err_msg=f"start_t mismatch, policy {pid}")
        np.testing.assert_array_equal(
            end[p, :n], rep.end_t.astype(np.float32),
            err_msg=f"end_t mismatch, policy {pid}")
        # per-scenario metrics to the bit: the fast path runs the SAME
        # numpy report over the replayed arrays
        fast = ClusterEmulator(trace, total_nodes, engine=engine,
                               max_jobs=MAX_JOBS).run(policy_id=pid,
                                                      fast=True)
        assert fast.metric_dict() == rep.metric_dict(), f"policy {pid}"
    assert not np.asarray(out.deadlocked).any()


@pytest.mark.parametrize("engine", [REF, PAL], ids=["reference", "pallas"])
def test_replay_parity_40_combos(engine):
    """6 random traces x 7 policies = 42 bit-identical combinations."""
    for trace in random_traces(6):
        assert_replay_matches_host(trace, 16, engine)


@pytest.mark.parametrize("engine", [REF, PAL], ids=["reference", "pallas"])
def test_fast_path_report_parity(engine):
    """run(fast=True) == the host event loop: arrays AND metrics, to
    the bit (both paths share the numpy report code)."""
    trace = poisson_trace(24, 16, 6.0, (1, 12), (30.0, 300.0), seed=7)
    for pid in (WFP, FCFS):
        a = ClusterEmulator(trace, 16, engine=engine).run(policy_id=pid)
        b = ClusterEmulator(trace, 16, engine=engine).run(policy_id=pid,
                                                         fast=True)
        np.testing.assert_array_equal(a.start_t, b.start_t)
        np.testing.assert_array_equal(a.end_t, b.end_t)
        assert a.metric_dict() == b.metric_dict()
        assert a.n_events == b.n_events


def test_fast_path_rejects_failures_and_twin_mode():
    from repro.cluster.emulator import FailureSpec
    trace = poisson_trace(8, 16, 6.0, (1, 8), (30.0, 120.0), seed=1)
    em = ClusterEmulator(trace, 16,
                         failures=[FailureSpec(50.0, 4, 100.0)])
    with pytest.raises(ValueError, match="failure"):
        em.run(policy_id=WFP, fast=True)
    with pytest.raises(ValueError):
        ClusterEmulator(trace, 16).run(on_event=lambda: None, fast=True)
    # fast mode publishes no events: refuse rather than starve anyone
    # observing the bus (even a consumer that only reads after the run)
    from repro.core.events import EventBus
    with pytest.raises(ValueError, match="stream bus events"):
        ClusterEmulator(trace, 16, bus=EventBus()).run(policy_id=WFP,
                                                       fast=True)


def test_replay_grid_matches_single_replays():
    """The S x P grid is bit-for-bit the stack of per-scenario replays
    — heterogeneous lengths and per-scenario cluster sizes included."""
    traces = [
        poisson_trace(20, 16, 5.0, (1, 12), (30.0, 300.0), seed=0),
        poisson_trace(14, 24, 7.0, (1, 16), (60.0, 600.0), seed=1),
        bursty_trace(26, 32, 4.0, (1, 20), (30.0, 400.0), seed=2),
    ]
    totals = [16, 24, 32]
    scen = stack_scenarios(traces, totals, max_jobs=MAX_JOBS)
    grid = REF.replay_grid(scen, POOL)
    assert grid.start_t.shape == (3, len(EXTENDED_POOL), MAX_JOBS)
    for s, (trace, tn) in enumerate(zip(traces, totals)):
        single = REF.replay(make_scenario(trace, tn, max_jobs=MAX_JOBS),
                            POOL)
        np.testing.assert_array_equal(np.asarray(grid.start_t[s]),
                                      np.asarray(single.start_t))
        np.testing.assert_array_equal(np.asarray(grid.end_t[s]),
                                      np.asarray(single.end_t))
        np.testing.assert_array_equal(np.asarray(grid.events[s]),
                                      np.asarray(single.events))
    # per-scenario metrics use per-scenario total_nodes
    util = np.asarray(grid.metrics.utilization)
    assert util.shape == (3, len(EXTENDED_POOL))
    assert np.all(util > 0) and np.all(util <= 1)


def test_replay_padding_invariant():
    """Padding slots never influence dynamics: J=64 == J=128."""
    trace = poisson_trace(16, 16, 5.0, (1, 12), (30.0, 300.0), seed=9)
    a = REF.replay(make_scenario(trace, 16, max_jobs=64), POOL)
    b = REF.replay(make_scenario(trace, 16, max_jobs=128), POOL)
    n = len(trace)
    np.testing.assert_array_equal(np.asarray(a.start_t)[:, :n],
                                  np.asarray(b.start_t)[:, :n])
    np.testing.assert_array_equal(np.asarray(a.end_t)[:, :n],
                                  np.asarray(b.end_t)[:, :n])


def test_deadlock_freezes_only_its_scenario():
    """A job requesting more than its scenario's cluster deadlocks that
    scenario (flagged, frozen) while every other fork completes — the
    host emulator refuses such traces outright."""
    good = poisson_trace(12, 16, 5.0, (1, 12), (30.0, 200.0), seed=3)
    bad = [JobSpec(0, 0.0, 4, 60.0, 50.0, "ok"),
           JobSpec(1, 5.0, 64, 60.0, 50.0, "too-big"),   # > 16 nodes
           JobSpec(2, 10.0, 4, 60.0, 50.0, "ok")]
    scen = stack_scenarios([good, bad], 16, max_jobs=32)
    grid = REF.replay_grid(scen, POOL)
    dead = np.asarray(grid.deadlocked)
    assert not dead[0].any()
    assert dead[1].all()
    # the poisoned scenario still runs its feasible jobs to completion
    jstate = np.asarray(grid.result.state.jobs.state).reshape(
        2, len(EXTENDED_POOL), 32)
    assert (jstate[1, :, [0, 2]] == DONE).all()
    assert (jstate[1, :, 1] == QUEUED).all()
    # ... and the good scenario is bit-identical to a solo replay
    solo = REF.replay(make_scenario(good, 16, max_jobs=32), POOL)
    np.testing.assert_array_equal(np.asarray(grid.start_t[0]),
                                  np.asarray(solo.start_t))


def test_sharded_replay_grid(mesh11):
    from repro.core import whatif
    run = whatif.sharded_replay_grid(mesh11)
    traces = random_traces(2, n_jobs=12)
    scen = stack_scenarios(traces, 16, max_jobs=32)
    pool = parse_pool("extended")
    sharded = run(scen, pool)
    local = REF.replay_grid(scen, pool.spec)
    np.testing.assert_array_equal(np.asarray(sharded.start_t),
                                  np.asarray(local.start_t))
    np.testing.assert_array_equal(np.asarray(sharded.end_t),
                                  np.asarray(local.end_t))
    np.testing.assert_array_equal(np.asarray(sharded.deadlocked),
                                  np.asarray(local.deadlocked))


def test_stack_scenarios_validates():
    t = [JobSpec(0, 10.0, 1, 30.0, 20.0, ""),
         JobSpec(1, 5.0, 1, 30.0, 20.0, "")]      # out of order
    with pytest.raises(ValueError, match="submission order"):
        stack_scenarios([t], 16)
    perm = [JobSpec(1, 0.0, 1, 30.0, 20.0, ""),   # job_id != position:
            JobSpec(0, 0.0, 1, 30.0, 20.0, "")]   # host keys by id,
    with pytest.raises(ValueError, match="job_id"):  # replay by slot
        stack_scenarios([perm], 16)
    with pytest.raises(ValueError, match="total_nodes"):
        stack_scenarios([t[:1]], [16, 32])
    with pytest.raises(ValueError, match="at least one"):
        stack_scenarios([], 16)


def test_replay_single_scenario_only():
    traces = random_traces(2, n_jobs=6)
    scen = stack_scenarios(traces, 16, max_jobs=32)
    with pytest.raises(ValueError, match="replay_grid"):
        REF.replay(scen, POOL)


# ----------------------------------------------------------------------
# Property-based parity over random traces (hypothesis optional).
# ----------------------------------------------------------------------

def _hypothesis_parity():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000),
           n_jobs=st.integers(4, 14),
           total_nodes=st.sampled_from([8, 16, 24]),
           policy=st.sampled_from(list(EXTENDED_POOL)))
    def inner(seed, n_jobs, total_nodes, policy):
        trace = poisson_trace(n_jobs, total_nodes, 5.0,
                              (1, max(2, total_nodes - 2)),
                              (10.0, 300.0), seed=seed,
                              accuracy=(0.2, 1.2))
        scen = make_scenario(trace, total_nodes, max_jobs=32)
        out = REF.replay(scen, jnp.asarray([policy], dtype=jnp.int32))
        rep = ClusterEmulator(trace, total_nodes, engine=REF,
                              max_jobs=32).run(policy_id=policy)
        np.testing.assert_array_equal(
            np.asarray(out.start_t)[0, :n_jobs],
            rep.start_t.astype(np.float32))
        np.testing.assert_array_equal(
            np.asarray(out.end_t)[0, :n_jobs],
            rep.end_t.astype(np.float32))

    return inner


def test_replay_parity_property():
    _hypothesis_parity()()
