"""Drain-simulation engine tests (§3.3)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import policies
import jax
from repro.core.des import drain_metrics
from repro.core.des import simulate_to_drain as _simulate_to_drain
simulate_to_drain = jax.jit(_simulate_to_drain)
from repro.core.state import (DONE, QUEUED, add_job, empty_state,
                              start_job)

from conftest import make_cluster_state


@given(seed=st.integers(0, 300),
       policy=st.sampled_from(list(policies.EXTENDED_POOL)))
@settings(max_examples=40, deadline=None)
def test_drain_completes_all_queued(seed, policy):
    state = make_cluster_state(seed=seed)
    res = simulate_to_drain(state, jnp.int32(policy))
    assert not bool(res.deadlocked)
    final = np.asarray(res.state.jobs.state)
    assert not np.any(final == QUEUED)


@given(seed=st.integers(0, 300))
@settings(max_examples=30, deadline=None)
def test_drain_time_monotone_and_starts_after_submit(seed):
    state = make_cluster_state(seed=seed)
    res = simulate_to_drain(state, jnp.int32(policies.FCFS))
    jobs = res.state.jobs
    valid = np.asarray(jobs.state) == DONE
    start = np.asarray(jobs.start_t)[valid]
    submit = np.asarray(jobs.submit_t)[valid]
    end = np.asarray(jobs.end_t)[valid]
    assert np.all(start >= submit - 1e-5)
    assert np.all(end >= start)


def test_deadlock_detected():
    state = empty_state(16, 8)
    state = add_job(state, 0, 0.0, 9, 100.0)  # can never fit: 9 > 8
    res = simulate_to_drain(state, jnp.int32(policies.FCFS))
    assert bool(res.deadlocked)


def test_first_started_is_immediate_decision():
    """§3.4 6A: first_started = jobs that run at the snapshot instant."""
    state = empty_state(16, 8)
    state = add_job(state, 0, 0.0, 4, 100.0)
    state = add_job(state, 1, 1.0, 4, 100.0)
    state = add_job(state, 2, 2.0, 4, 100.0)  # must wait
    state = state._replace(now=jnp.float32(5.0))
    res = simulate_to_drain(state, jnp.int32(policies.FCFS))
    first = np.asarray(res.first_started)
    assert first[0] and first[1] and not first[2]
    # ... but job 2 still got scheduled during the drain (the drain
    # stops when the queue empties; last starters remain RUNNING)
    assert np.asarray(res.state.jobs.state)[2] in (2, DONE)
    assert float(res.state.jobs.start_t[2]) > 0


def test_metrics_match_hand_computation():
    state = empty_state(16, 4)
    state = add_job(state, 0, 0.0, 4, 100.0)
    state = add_job(state, 1, 0.0, 4, 100.0)
    eval_mask = state.jobs.state == QUEUED
    res = simulate_to_drain(state, jnp.int32(policies.FCFS))
    m = drain_metrics(res, eval_mask)
    # job0 starts at 0 (wait 0), job1 at 100 (wait 100)
    assert abs(float(m.avg_wait) - 50.0) < 1e-3
    assert abs(float(m.max_wait) - 100.0) < 1e-3
    # slowdown: (0+100)/100=1, (100+100)/100=2
    assert abs(float(m.max_slowdown) - 2.0) < 1e-3
    assert abs(float(m.avg_slowdown) - 1.5) < 1e-3
    assert abs(float(m.makespan) - 200.0) < 1e-3


def test_running_jobs_finish_at_predicted_end():
    state = empty_state(16, 8)
    state = add_job(state, 0, 0.0, 8, 100.0)
    state = start_job(state, 0, 0.0)          # predicted end = 100
    state = add_job(state, 1, 5.0, 8, 50.0)   # queued behind it
    state = state._replace(now=jnp.float32(5.0))
    res = simulate_to_drain(state, jnp.int32(policies.FCFS))
    assert abs(float(res.state.jobs.start_t[1]) - 100.0) < 1e-3
