"""policy_eval kernel vs the core schedule_pass oracle — random states."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.policies import EXTENDED_POOL, PAPER_POOL
from repro.kernels import ops, ref

from conftest import make_cluster_state


@given(seed=st.integers(0, 400),
       n_queued=st.integers(0, 20),
       n_running=st.integers(0, 6))
@settings(max_examples=50, deadline=None)
def test_kernel_matches_schedule_pass(seed, n_queued, n_running):
    state = make_cluster_state(max_jobs=32, seed=seed, n_queued=n_queued,
                               n_running=n_running)
    pool = jnp.asarray(PAPER_POOL, dtype=jnp.int32)
    started_k, free_k = ops.twin_schedule_pass(state, pool)
    started_r, free_r = ref.policy_eval_ref(state, pool)
    np.testing.assert_array_equal(np.asarray(started_k),
                                  np.asarray(started_r))
    np.testing.assert_allclose(np.asarray(free_k), np.asarray(free_r))


def test_kernel_extended_pool():
    state = make_cluster_state(max_jobs=64, seed=42, n_queued=24,
                               n_running=5)
    pool = jnp.asarray(EXTENDED_POOL, dtype=jnp.int32)
    started_k, free_k = ops.twin_schedule_pass(state, pool)
    started_r, free_r = ref.policy_eval_ref(state, pool)
    np.testing.assert_array_equal(np.asarray(started_k),
                                  np.asarray(started_r))


def test_kernel_empty_queue_noop():
    state = make_cluster_state(max_jobs=32, seed=0, n_queued=0,
                               n_running=3)
    pool = jnp.asarray(PAPER_POOL, dtype=jnp.int32)
    started_k, free_k = ops.twin_schedule_pass(state, pool)
    assert not np.any(np.asarray(started_k))
    np.testing.assert_allclose(np.asarray(free_k),
                               float(state.free_nodes))


def test_kernel_policy_axis_is_batched():
    """Different policies genuinely differ on an adversarial queue."""
    from repro.core.state import add_job, empty_state
    state = empty_state(32, 8)
    state = add_job(state, 0, 0.0, 8, 1000.0)   # huge long job first
    state = add_job(state, 1, 1.0, 1, 10.0)     # tiny short job behind
    state = state._replace(now=jnp.float32(5.0))
    pool = jnp.asarray(PAPER_POOL, dtype=jnp.int32)
    started_k, _ = ops.twin_schedule_pass(state, pool)
    s = np.asarray(started_k)
    # FCFS/WFP start job 0; SJF starts job 1 first (then 0 won't fit)
    assert s[1, 0] == 1            # FCFS starts the big job
    assert s[2, 1] == 1            # SJF starts the short job
