"""Training substrate: optimizer, accumulation, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.train import (OptimizerConfig, init_train_state, lr_at,
                         make_train_step)
from repro.train import compression
from repro.train.optimizer import adamw_update, init_opt_state


def test_lr_schedule_shape():
    cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100,
                          end_lr_frac=0.1)
    lrs = [float(lr_at(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9          # peak at end of warmup
    assert lrs[-1] <= lrs[1]
    assert abs(lrs[-1] - 1e-4) < 1e-6          # decays to 10% of peak


def test_adamw_moves_params_against_gradient():
    params = {"w": jnp.ones((4, 4), jnp.float32)}
    grads = {"w": jnp.ones((4, 4), jnp.float32)}
    opt = init_opt_state(params)
    cfg = OptimizerConfig(peak_lr=0.1, warmup_steps=0, total_steps=10,
                          weight_decay=0.0)
    new_params, opt, metrics = adamw_update(cfg, params, grads, opt)
    assert float(new_params["w"][0, 0]) < 1.0
    assert float(metrics["grad_norm"]) > 0


def test_grad_accumulation_equivalence(mesh11, rules_train):
    """accum=2 over a batch == accum=1 over the same batch."""
    cfg = get_smoke_config("llama3.2-1b")
    opt_cfg = OptimizerConfig(warmup_steps=0, total_steps=10,
                              clip_norm=1e9)  # no clipping interference
    data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 4, seed=1))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

    outs = {}
    for accum in (1, 2):
        state = init_train_state(jax.random.PRNGKey(0), cfg)
        step = jax.jit(make_train_step(cfg, rules_train, opt_cfg,
                                       accum_steps=accum))
        with mesh11:
            state, m = step(state, batch)
        outs[accum] = (state.params["embed"], m["loss"])
    np.testing.assert_allclose(
        np.asarray(outs[1][0], np.float32),
        np.asarray(outs[2][0], np.float32), atol=2e-2)
    # bf16 params, f32 grads: small tolerance
    assert abs(float(outs[1][1]) - float(outs[2][1])) < 2e-2


def test_loss_decreases_short_run(mesh11, rules_train):
    cfg = get_smoke_config("granite-3-2b")
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(
        cfg, rules_train,
        OptimizerConfig(peak_lr=3e-3, warmup_steps=2, total_steps=30),
        accum_steps=1))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 4, seed=0))
    losses = []
    with mesh11:
        for i in range(12):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
    assert min(losses[6:]) < losses[0], losses


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    c = compression.quantize(x)
    y = compression.dequantize(c, x.shape)
    err = np.abs(np.asarray(x - y))
    scale = np.asarray(c.scale).max()
    assert err.max() <= scale * 0.5 + 1e-6   # half-ULP of int8 grid


def test_error_feedback_accumulates_dropped_signal():
    """With EF, the quantization error is carried, not lost: summing
    many tiny identical gradients eventually transmits them."""
    g = {"w": jnp.full((compression.BLOCK,), 1e-6, jnp.float32)}
    big = {"w": jnp.full((compression.BLOCK,), 1.0, jnp.float32)}
    err = compression.init_error_buffers(g)
    sent = jnp.zeros_like(g["w"])
    for _ in range(10):
        # a large component keeps the block scale coarse
        grads = {"w": g["w"] + big["w"]}
        out, err, _ = compression.compress_with_feedback(grads, err)
        sent = sent + out["w"] - big["w"]
    # 10 steps of 1e-6 -> ~1e-5 transmitted despite coarse quantization
    assert float(jnp.mean(sent)) > 5e-6


def test_compressed_training_still_learns(mesh11, rules_train):
    cfg = get_smoke_config("llama3.2-1b")
    state = init_train_state(jax.random.PRNGKey(0), cfg, compress=True)
    step = jax.jit(make_train_step(
        cfg, rules_train,
        OptimizerConfig(peak_lr=3e-3, warmup_steps=2, total_steps=30),
        compress=True, accum_steps=1))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 4, seed=3))
    losses = []
    with mesh11:
        for i in range(10):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
    assert min(losses[5:]) < losses[0]
    assert "compression_err" in m
