"""On-device Monte-Carlo scenario fans (DESIGN.md §10).

Pins the tentpole invariants of ``core.fan`` + the fan paths of
``core.engine`` / ``core.whatif``:

- F=1 fans and degenerate specs are BITWISE ``replay_grid`` (both pass
  backends) — the fan rides the same fork axis, same input assembly;
- member φ=0 is exact for ANY spec (the fan-less prediction survives);
- device member metrics are bitwise the host-materialized oracle
  (``materialize_fan`` + plain ``replay_grid`` over S·F rows);
- device p50/p95/p99/CVaR/worst/regret reductions match a numpy oracle
  computed from the member costs;
- member PRNG keys are prefix-stable (common random numbers): an F=4
  fan IS the first 4 members of the F=8 fan;
- the distributional objective grammar parses, round-trips, and
  rejects malformed/nested forms;
- dominance pruning NEVER changes the selected policy when the
  pre-pass fan is the deciding fan (property-tested over random cost
  tensors and end-to-end over real grids);
- ``sharded_fan_grid`` (any block size) is bitwise the local fan grid;
- ``decide_fan`` F=1 is bitwise ``decide``, and fan decisions stamp
  device-computed CIs into telemetry.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.workload import poisson_trace, stack_scenarios
from repro.core import whatif
from repro.core.des import cvar_tail_count, quantile_index
from repro.core.engine import DrainEngine, member_uncertainty
from repro.core.fan import (FanSpec, dominance_keep, materialize_fan,
                            normalize_fan, pruned_fan_grid)
from repro.core.objective import (Distributional, as_distributional,
                                  parse_objective, validate_objective)
from repro.core.policies import parse_pool
from repro.launch.mesh import make_fleet_mesh

REF = DrainEngine("reference")
PAL = DrainEngine("pallas", interpret=True)

POOL = parse_pool("fcfs,sjf,saf")
NOISY = FanSpec(n=8, runtime_noise=0.3, burst_amplitude=0.5,
                burst_period=600.0, failure_prob=0.3, seed=7)


@pytest.fixture(scope="module")
def scen():
    traces = [poisson_trace(12, 16, 30.0, (1, 4), (60.0, 600.0), seed=s)
              for s in range(3)]
    return stack_scenarios(traces, total_nodes=16)


# ----------------------------------------------------------------------
# degenerate parity: the fan collapses to the PR-6 replay, bitwise
# ----------------------------------------------------------------------

@pytest.mark.parametrize("eng", [REF, PAL], ids=["reference", "pallas"])
def test_f1_fan_is_bitwise_replay_grid(scen, eng):
    base = eng.replay_grid(scen, POOL.spec)
    fan = eng.fan_grid(scen, POOL.spec, FanSpec(n=1))
    np.testing.assert_array_equal(np.asarray(base.costs),
                                  np.asarray(fan.costs))
    np.testing.assert_array_equal(np.asarray(base.best),
                                  np.asarray(fan.best))
    np.testing.assert_array_equal(np.asarray(base.start_t),
                                  np.asarray(fan.start_t[:, 0]))
    np.testing.assert_array_equal(np.asarray(base.end_t),
                                  np.asarray(fan.end_t[:, 0]))
    for field, a, b in zip(base.metrics._fields, base.metrics,
                           fan.metrics):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)[:, 0], err_msg=field)


def test_f1_noisy_spec_is_still_bitwise(scen):
    # member 0 is exact for ANY spec, so an F=1 fan of the noisiest
    # spec is STILL the plain replay
    spec = dataclasses.replace(NOISY, n=1)
    base = REF.replay_grid(scen, POOL.spec)
    fan = REF.fan_grid(scen, POOL.spec, spec)
    np.testing.assert_array_equal(np.asarray(base.costs),
                                  np.asarray(fan.costs))


def test_degenerate_members_all_equal_base(scen):
    base = REF.replay_grid(scen, POOL.spec)
    fan = REF.fan_grid(scen, POOL.spec, FanSpec(n=4))
    mc = np.asarray(fan.member_costs)
    for phi in range(4):
        np.testing.assert_array_equal(mc[:, phi], np.asarray(base.costs))


def test_member_zero_exact_under_noise(scen):
    base = REF.replay_grid(scen, POOL.spec)
    fan = REF.fan_grid(scen, POOL.spec, NOISY)
    np.testing.assert_array_equal(np.asarray(fan.member_costs)[:, 0],
                                  np.asarray(base.costs))


# ----------------------------------------------------------------------
# device fan == host-materialized oracle, bitwise; CRN prefix property
# ----------------------------------------------------------------------

@pytest.mark.parametrize("eng", [REF, PAL], ids=["reference", "pallas"])
def test_fan_matches_materialized_oracle_bitwise(scen, eng):
    fan = eng.fan_grid(scen, POOL.spec, NOISY, "avg_wait")
    mat = eng.replay_grid(materialize_fan(scen, NOISY), POOL.spec,
                          "avg_wait")
    S, F, P = np.asarray(fan.member_costs).shape
    np.testing.assert_array_equal(
        np.asarray(mat.costs).reshape(S, F, P),
        np.asarray(fan.member_costs))
    np.testing.assert_array_equal(
        np.asarray(mat.start_t).reshape(S, F, P, -1),
        np.asarray(fan.start_t))


def test_member_keys_are_prefix_stable(scen):
    # common random numbers: the F=4 fan IS members [:4] of the F=8 fan
    f8 = REF.fan_grid(scen, POOL.spec, NOISY, "avg_wait")
    f4 = REF.fan_grid(scen, POOL.spec, dataclasses.replace(NOISY, n=4),
                      "avg_wait")
    np.testing.assert_array_equal(np.asarray(f4.member_costs),
                                  np.asarray(f8.member_costs)[:, :4])


# ----------------------------------------------------------------------
# distributional reductions vs a numpy oracle
# ----------------------------------------------------------------------

def _np_reduce(obj, member):
    """Numpy oracle for Distributional.reduce_fan over (S, F, P)."""
    F = member.shape[1]
    if obj.reduction == "mean":
        return member.mean(axis=1)
    if obj.reduction == "worst":
        return member.max(axis=1)
    if obj.reduction == "regret":
        with np.errstate(invalid="ignore"):
            best = member.min(axis=2, keepdims=True)
            reg = np.where(np.isfinite(member), member - best, np.inf)
        return reg.max(axis=1)
    srt = np.sort(member, axis=1)
    if obj.reduction == "quantile":
        return srt[:, quantile_index(obj.level / 100.0, F)]
    m = cvar_tail_count(obj.level, F)
    return srt[:, F - m:].mean(axis=1)


@pytest.mark.parametrize("goal", [
    "p50:avg_wait", "p95:avg_wait", "p99:avg_wait", "cvar:0.9:avg_wait",
    "cvar:0.5:score", "worst:avg_slowdown", "regret:avg_wait",
    "mean:avg_wait"])
def test_device_reduction_matches_numpy_oracle(scen, goal):
    obj = parse_objective(goal)
    out = REF.fan_grid(scen, POOL.spec, NOISY, obj)
    member = np.asarray(out.member_costs)
    oracle = _np_reduce(obj, member)
    np.testing.assert_allclose(np.asarray(out.costs), oracle,
                               rtol=1e-6, atol=0)
    assert np.array_equal(np.asarray(out.best),
                          np.argmin(oracle, axis=1))


def test_member_uncertainty_oracle():
    rng = np.random.default_rng(0)
    member = rng.normal(100.0, 10.0, size=(4, 16, 3)).astype(np.float32)
    member[2, 5, 1] = np.inf       # a deadlocked member poisons its cell
    ci, width = jax.jit(member_uncertainty)(jnp.asarray(member))
    ci, width = np.asarray(ci), np.asarray(width)
    with np.errstate(invalid="ignore"):
        exp_ci = 1.96 * member.std(axis=1) / np.sqrt(16)
        exp_w = member.max(axis=1) - member.min(axis=1)
    fin = np.isfinite(member).all(axis=1)
    np.testing.assert_allclose(ci[fin], exp_ci[fin], rtol=1e-5)
    np.testing.assert_allclose(width[fin], exp_w[fin], rtol=1e-5)
    assert np.isinf(ci[~fin]).all() and np.isinf(width[~fin]).all()


# ----------------------------------------------------------------------
# grammar
# ----------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    "p95:avg_wait", "p99.9:avg_wait", "cvar:0.9:avg_wait", "worst:score",
    "regret:avg_wait", "mean:score", "p50:0.5*avg_wait+0.5*makespan",
    "cvar:0.95:min:avg_wait@util>=0.5", "worst:lex:avg_wait,makespan"])
def test_grammar_round_trip(spec):
    obj = validate_objective(spec)      # parse -> spec -> parse == obj
    assert isinstance(obj, Distributional)


@pytest.mark.parametrize("bad", [
    "p95:p99:avg_wait", "mean:worst:score", "cvar:0.9:cvar:0.5:x",
    "cvar:1.5:score", "cvar:-0.1:score", "p0:score", "p101:score",
    "cvar:score", "p95:", "worst:"])
def test_grammar_rejects(bad):
    with pytest.raises(ValueError):
        parse_objective(bad)


def test_plain_goal_lifts_to_mean():
    obj = as_distributional("avg_wait")
    assert obj.reduction == "mean"
    assert obj.inner == parse_objective("avg_wait")
    # idempotent on an already-distributional goal
    assert as_distributional(obj) is obj


def test_fanspec_validation():
    with pytest.raises(ValueError):
        FanSpec(n=0)
    with pytest.raises(ValueError):
        FanSpec(n=2, burst_amplitude=1.0)
    with pytest.raises(ValueError):
        FanSpec(n=2, failure_prob=1.5)
    with pytest.raises(ValueError):
        FanSpec(n=2, runtime_noise=-0.1)
    assert normalize_fan(4) == FanSpec(n=4)
    assert normalize_fan(NOISY) is NOISY
    assert FanSpec(n=3).degenerate and not NOISY.degenerate


# ----------------------------------------------------------------------
# pruning: dominance NEVER changes the winner (pre_n == F theorem)
# ----------------------------------------------------------------------

def _winner_invariance(member, obj):
    """Assert argmin(reduce(member)) is unchanged by dominance_keep."""
    full = _np_reduce(obj, member)
    best_full = np.argmin(full, axis=1)
    keep = dominance_keep(member, pointwise=(obj.reduction == "regret"))
    keep_idx = np.nonzero(keep)[0]
    assert keep[best_full].all(), "winner was pruned"
    sub = _np_reduce(obj, member[:, :, keep_idx])
    np.testing.assert_array_equal(keep_idx[np.argmin(sub, axis=1)],
                                  best_full)


_PRUNE_GOALS = ("mean:avg_wait", "worst:avg_wait", "p50:avg_wait",
                "p95:avg_wait", "cvar:0.7:avg_wait", "regret:avg_wait")


@pytest.mark.parametrize("goal", _PRUNE_GOALS)
def test_prune_winner_invariance_random_tensors(goal):
    # seeded fuzz over random member-cost tensors, with ties and infs
    obj = parse_objective(goal)
    rng = np.random.default_rng(42)
    for trial in range(200):
        S = int(rng.integers(1, 4))
        F = int(rng.integers(1, 9))
        P = int(rng.integers(1, 7))
        member = rng.normal(0.0, 1.0, size=(S, F, P))
        member = np.round(member, 1)             # force ties
        if trial % 3 == 0:                       # sprinkle deadlocks
            mask = rng.random(size=member.shape) < 0.1
            member = np.where(mask, np.inf, member)
        _winner_invariance(member, obj)


@pytest.mark.parametrize("goal", _PRUNE_GOALS)
def test_prune_winner_invariance_hypothesis(goal):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra.numpy import arrays

    obj = parse_objective(goal)

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def run(data):
        S = data.draw(st.integers(1, 3))
        F = data.draw(st.integers(1, 6))
        P = data.draw(st.integers(1, 5))
        member = data.draw(arrays(
            np.float64, (S, F, P),
            elements=st.one_of(
                st.integers(-5, 5).map(float),
                st.just(np.inf))))
        _winner_invariance(member, obj)

    run()


@pytest.mark.parametrize("goal", ["p95:avg_wait", "cvar:0.9:score",
                                  "regret:avg_wait"])
def test_pruned_fan_grid_end_to_end(scen, goal):
    # pre_n == n: selection provably identical to the unpruned grid
    full = REF.fan_grid(scen, POOL.spec, NOISY, goal)
    out, info = pruned_fan_grid(scen, POOL.spec, NOISY, goal,
                                engine=REF, pre_n=NOISY.n)
    np.testing.assert_array_equal(info.best, np.asarray(full.best))
    # the kept columns of the full grid are the pruned grid, bitwise
    np.testing.assert_array_equal(
        np.asarray(out.member_costs),
        np.asarray(full.member_costs)[:, :, info.keep])
    assert 0.0 <= info.rate < 1.0
    assert info.pre_members.shape == np.asarray(full.member_costs).shape


# ----------------------------------------------------------------------
# fleet: sharded/streamed fan == local fan, bitwise
# ----------------------------------------------------------------------

def test_sharded_fan_grid_matches_local(scen):
    local = REF.fan_grid(scen, POOL.spec, NOISY, "p95:avg_wait")
    mesh = make_fleet_mesh(1)
    for block in (None, 6, 8):
        got = whatif.sharded_fan_grid(
            mesh, engine=REF, objective="p95:avg_wait", fan=NOISY,
            block_size=block)(scen, POOL)
        np.testing.assert_array_equal(np.asarray(local.member_costs),
                                      np.asarray(got.member_costs),
                                      err_msg=f"block={block}")
        np.testing.assert_array_equal(np.asarray(local.costs),
                                      np.asarray(got.costs))
        np.testing.assert_array_equal(np.asarray(local.best),
                                      np.asarray(got.best))
        np.testing.assert_array_equal(np.asarray(local.cost_ci),
                                      np.asarray(got.cost_ci))


# ----------------------------------------------------------------------
# decide_fan: the twin's per-cycle fan decision
# ----------------------------------------------------------------------

def test_decide_fan_f1_is_bitwise_decide():
    from conftest import make_cluster_state
    pool = jnp.asarray([0, 1, 2], jnp.int32)
    for seed in range(4):
        state = make_cluster_state(max_jobs=48, total_nodes=32,
                                   seed=seed, n_queued=6, n_running=2,
                                   now=100.0 + 40.0 * seed)
        d0 = REF.decide(state, pool)
        d1 = REF.decide_fan(state, pool, FanSpec(n=1))
        assert int(d0.policy_index) == int(d1.policy_index)
        np.testing.assert_array_equal(np.asarray(d0.costs),
                                      np.asarray(d1.costs))
        np.testing.assert_array_equal(np.asarray(d0.run_mask),
                                      np.asarray(d1.run_mask))
        # degenerate F>1 fans also collapse to the plain decision
        d4 = REF.decide_fan(state, pool, 4)
        np.testing.assert_array_equal(np.asarray(d0.costs),
                                      np.asarray(d4.costs))


def test_decide_fan_stamps_uncertainty():
    from conftest import make_cluster_state
    pool = jnp.asarray([0, 1, 2], jnp.int32)
    state = make_cluster_state(max_jobs=48, total_nodes=32, seed=3,
                               n_queued=8, n_running=2, now=500.0)
    d = REF.decide_fan(state, pool, FanSpec(n=8, runtime_noise=0.3),
                       "p95:avg_wait")
    assert d.fan_size == 8
    assert d.cost_ci is not None and d.fan_width is not None
    ci, width = np.asarray(d.cost_ci), np.asarray(d.fan_width)
    assert ci.shape == width.shape == (3,)
    assert (ci[np.isfinite(ci)] >= 0).all()
    assert (width[np.isfinite(width)] >= 0).all()
    # plain decisions don't fan
    d0 = REF.decide(state, pool)
    assert d0.fan_size == 1 and d0.cost_ci is None


def test_twin_records_fan_confidence():
    from repro.cluster.emulator import ClusterEmulator
    from repro.core.events import EventBus
    from repro.core.twin import SchedTwin
    trace = poisson_trace(10, 16, 20.0, (1, 4), (30.0, 300.0), seed=1)
    bus = EventBus()
    em = ClusterEmulator(trace, 16, bus=bus)
    twin = SchedTwin(bus=bus, qrun=em.qrun, total_nodes=16,
                     max_jobs=em.max_jobs,
                     fan=FanSpec(n=4, runtime_noise=0.3),
                     objective="p95:avg_wait",
                     free_nodes_probe=lambda: em.free_nodes)
    em.run(on_event=twin.pump)
    assert twin.telemetry.cycles, "no decision cycles ran"
    rec = twin.telemetry.cycles[0]
    assert rec.fan_size == 4 and rec.cost_ci and rec.fan_width
    stats = twin.telemetry.confidence_stats()
    assert stats and all(st["n"] + st["n_inf"] > 0
                         for st in stats.values())


def test_twin_rejects_fan_plus_ensemble():
    from repro.core.events import EventBus
    from repro.core.twin import SchedTwin
    with pytest.raises(ValueError, match="mutually exclusive"):
        SchedTwin(bus=EventBus(), qrun=lambda j, t: None, total_nodes=8,
                  fan=FanSpec(n=4), ensemble=4)


# ----------------------------------------------------------------------
# correlated failure domains (the rack/power-domain model, D > 0)
# ----------------------------------------------------------------------

DOMAINS = FanSpec(n=16, failure_prob=0.4, failure_frac=0.5,
                  failure_domains=4, seed=11)


def _domain_draws(spec, S, F, tot):
    """(s, phi, u, tot) row vectors + the shared fragilities."""
    from repro.core.fan import _domain_fragility, _member_draws
    idx = jnp.arange(S * F)
    s, phi = idx // F, idx % F
    J = 4
    _, _, u = jax.vmap(
        lambda a, b: _member_draws(spec.seed, a, b, J))(s, phi)
    q = np.asarray(jax.vmap(
        lambda a: _domain_fragility(spec.seed, a, spec.failure_domains)
    )(jnp.arange(S)))
    totv = jnp.full((S * F,), tot, jnp.int32)
    return s, phi, u, totv, q


def test_domain_downs_are_quantized_capacity_levels():
    # failures arrive in domain-sized chunks: every reduction is
    # floor(tot * k / D) for an integer k, capped by failure_frac
    from repro.core.fan import failure_downs
    S, F, tot = 5, 32, 61
    s, phi, u, totv, _ = _domain_draws(DOMAINS, S, F, tot)
    down = np.asarray(failure_downs(DOMAINS, s, phi, u, totv))
    D = DOMAINS.failure_domains
    levels = {min(int(np.float32(tot) * k / D),
                  int(np.float32(tot) * DOMAINS.failure_frac))
              for k in range(D + 1)}
    assert set(down.tolist()) <= levels
    assert len(set(down.tolist())) > 1, "chaos profile too calm"


def test_domain_failure_sets_are_nested_across_members():
    # one uniform per member vs shared per-domain thresholds => the
    # comonotone structure: a member with a smaller draw fails a
    # SUPERSET of every other member's domains (same scenario)
    S, F, tot = 4, 64, 64
    s, phi, u, totv, q = _domain_draws(DOMAINS, S, F, tot)
    from repro.core.fan import failure_downs
    down = np.asarray(failure_downs(DOMAINS, s, phi, u, totv))
    u0 = np.asarray(u)[:, 0]
    for sc in range(S):
        rows = [i for i in range(S * F)
                if int(np.asarray(s)[i]) == sc and np.asarray(phi)[i] > 0]
        order = sorted(rows, key=lambda i: u0[i])
        # smaller draw -> at least as many failed domains -> >= loss
        losses = [down[i] for i in order]
        assert all(a >= b for a, b in zip(losses, losses[1:]))


def test_domain_fragility_is_member_and_fan_independent():
    # q is keyed on (seed, s) only: every member, window, and repeated
    # decision sees the same weak domains (persistence across time)
    from repro.core.fan import _domain_fragility
    q1 = _domain_fragility(11, jnp.asarray(2), 4)
    q2 = _domain_fragility(11, jnp.asarray(2), 4)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    # and distinct scenarios get distinct fragilities
    q3 = _domain_fragility(11, jnp.asarray(3), 4)
    assert not np.array_equal(np.asarray(q1), np.asarray(q3))


def test_domain_marginal_rate_is_failure_prob():
    # E[min(2 p q, 1)] over q ~ U[0,1) equals p for p <= 0.5: the
    # correlation reshapes the joint, not the per-domain marginal
    from repro.core.fan import _domain_fragility
    p, D, S = 0.3, 8, 4000
    q = np.asarray(jax.vmap(
        lambda s: _domain_fragility(11, s, D))(jnp.arange(S)))
    thresh = np.minimum(2.0 * p * q, 1.0)
    assert abs(thresh.mean() - p) < 0.01


def test_domain_member_zero_exact(scen):
    base = REF.replay_grid(scen, POOL.spec)
    fan = REF.fan_grid(scen, POOL.spec, DOMAINS)
    np.testing.assert_array_equal(np.asarray(fan.member_costs)[:, 0],
                                  np.asarray(base.costs))


@pytest.mark.parametrize("eng", [REF, PAL], ids=["reference", "pallas"])
def test_domain_f1_fan_is_bitwise_replay_grid(scen, eng):
    spec = dataclasses.replace(DOMAINS, n=1)
    base = eng.replay_grid(scen, POOL.spec)
    fan = eng.fan_grid(scen, POOL.spec, spec)
    np.testing.assert_array_equal(np.asarray(base.costs),
                                  np.asarray(fan.costs))
    np.testing.assert_array_equal(np.asarray(base.end_t),
                                  np.asarray(fan.end_t[:, 0]))


def test_domain_members_are_prefix_stable(scen):
    f16 = REF.fan_grid(scen, POOL.spec, DOMAINS, "p95:avg_wait")
    f4 = REF.fan_grid(scen, POOL.spec,
                      dataclasses.replace(DOMAINS, n=4), "p95:avg_wait")
    np.testing.assert_array_equal(np.asarray(f4.member_costs),
                                  np.asarray(f16.member_costs)[:, :4])


@pytest.mark.parametrize("eng", [REF, PAL], ids=["reference", "pallas"])
def test_domain_fan_matches_materialized_oracle(scen, eng):
    fan = eng.fan_grid(scen, POOL.spec, DOMAINS, "avg_wait")
    mat = eng.replay_grid(materialize_fan(scen, DOMAINS), POOL.spec,
                          "avg_wait")
    S, F, P = np.asarray(fan.member_costs).shape
    np.testing.assert_array_equal(
        np.asarray(mat.costs).reshape(S, F, P),
        np.asarray(fan.member_costs))


def test_domain_zero_is_legacy_iid_formula():
    # D=0 must keep the legacy draw bit-for-bit (same f32 op order)
    from repro.core.fan import failure_downs
    spec = FanSpec(n=8, failure_prob=0.4, failure_frac=0.5, seed=11)
    S, F, tot = 3, 8, 61
    s, phi, u, totv, _ = _domain_draws(spec, S, F, tot)
    down = np.asarray(failure_downs(spec, s, phi, u, totv))
    un = np.asarray(u)
    totf = np.float32(tot)
    exact = np.asarray(phi) == 0
    hit = (un[:, 0] < np.float32(spec.failure_prob)) & ~exact
    frac = un[:, 1].astype(np.float32) * np.float32(spec.failure_frac)
    legacy = np.where(hit, np.floor(totf * frac), np.float32(0.0))
    np.testing.assert_array_equal(down, legacy.astype(np.int32))


def test_domain_decide_fan_f1_is_bitwise_decide():
    from conftest import make_cluster_state
    pool = jnp.asarray([0, 1, 2], jnp.int32)
    state = make_cluster_state(max_jobs=48, total_nodes=32, seed=5,
                               n_queued=6, n_running=2, now=250.0)
    d0 = REF.decide(state, pool)
    d1 = REF.decide_fan(state, pool, dataclasses.replace(DOMAINS, n=1))
    assert int(d0.policy_index) == int(d1.policy_index)
    np.testing.assert_array_equal(np.asarray(d0.costs),
                                  np.asarray(d1.costs))
    np.testing.assert_array_equal(np.asarray(d0.run_mask),
                                  np.asarray(d1.run_mask))


def test_domain_fanspec_validation():
    with pytest.raises(ValueError, match="failure_domains"):
        FanSpec(n=4, failure_domains=-1)
