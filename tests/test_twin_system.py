"""End-to-end system tests: the twin in the loop with the emulator."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.emulator import ClusterEmulator, FailureSpec
from repro.cluster.workload import JobSpec, paper_synthetic_trace
from repro.core.events import EventBus
from repro.core.policies import FCFS, PAPER_POOL, SJF, WFP
from repro.core.twin import SchedTwin


def tiny_trace(n=24, seed=0):
    rng = np.random.default_rng(seed)
    jobs = []
    t = 0.0
    for j in range(n):
        jobs.append(JobSpec(j, t, int(rng.integers(1, 12)),
                            float(rng.uniform(30, 300)),
                            float(rng.uniform(20, 280)), "t"))
        t += 4.0
    return jobs


def run_twin(trace, total_nodes=16, **twin_kw):
    bus = EventBus()
    em = ClusterEmulator(trace, total_nodes, bus=bus,
                         check_invariants=True)
    twin = SchedTwin(bus=bus, qrun=em.qrun, total_nodes=total_nodes,
                     max_jobs=em.max_jobs,
                     free_nodes_probe=lambda: em.free_nodes, **twin_kw)
    report = em.run(on_event=twin.pump)
    return report, twin


def run_static(trace, policy, total_nodes=16):
    em = ClusterEmulator(trace, total_nodes, check_invariants=True)
    return em.run(policy_id=policy)


def test_twin_completes_all_jobs():
    trace = tiny_trace()
    report, twin = run_twin(trace)
    assert report.n_jobs == len(trace)
    assert report.utilization > 0
    assert len(twin.telemetry.cycles) > 0


def test_twin_not_worse_than_worst_static():
    """The twin picks among the static policies, so its paper-score
    must not be worse than the WORST static policy's."""
    trace = paper_synthetic_trace(seed=1)
    rep_twin, _ = run_twin(trace, total_nodes=32)
    from repro.core.scoring import PAPER_WEIGHTS

    def score(rep):
        return (0.25 * rep.max_wait / 60 + 0.25 * rep.max_slowdown
                + 0.25 * rep.avg_wait / 60 + 0.25 * rep.avg_slowdown)

    worst = max(score(run_static(trace, p, 32)) for p in PAPER_POOL)
    assert score(rep_twin) <= worst * 1.05  # small slack: replanning noise


def test_policy_distribution_sums_to_100():
    trace = tiny_trace(30, seed=2)
    _, twin = run_twin(trace)
    dist = twin.telemetry.policy_start_distribution()
    assert abs(sum(dist.values()) - 100.0) < 1e-6
    assert set(dist) <= {"WFP", "FCFS", "SJF"}


def test_twin_recovery_replays_bus():
    trace = tiny_trace(16, seed=3)
    bus = EventBus()
    em = ClusterEmulator(trace, 16, bus=bus)
    twin = SchedTwin(bus=bus, qrun=em.qrun, total_nodes=16,
                     max_jobs=em.max_jobs)
    em.run(on_event=twin.pump)
    state_before = twin.state
    twin.recover()
    # replay rebuilds the same job table
    np.testing.assert_allclose(np.asarray(state_before.jobs.state),
                               np.asarray(twin.state.jobs.state))
    np.testing.assert_allclose(np.asarray(state_before.jobs.end_t),
                               np.asarray(twin.state.jobs.end_t), atol=1e-4)


def test_node_failure_requeues_and_finishes():
    trace = tiny_trace(20, seed=4)
    bus = EventBus()
    em = ClusterEmulator(trace, 16, bus=bus,
                         failures=[FailureSpec(time=30.0, nodes=8,
                                               duration=120.0)],
                         check_invariants=True)
    twin = SchedTwin(bus=bus, qrun=em.qrun, total_nodes=16,
                     max_jobs=em.max_jobs,
                     free_nodes_probe=lambda: em.free_nodes)
    report = em.run(on_event=twin.pump)
    assert report.n_jobs == 20          # everything still completed
    assert report.n_restarts >= 0       # victims were re-run


def test_stale_qrun_is_ignored():
    trace = tiny_trace(8, seed=5)
    bus = EventBus()
    em = ClusterEmulator(trace, 16, bus=bus)
    twin = SchedTwin(bus=bus, qrun=em.qrun, total_nodes=16,
                     max_jobs=em.max_jobs)
    em.run(on_event=twin.pump)
    # re-running an already-finished job must be a no-op
    free_before = em.free_nodes
    em.qrun([0], em.now)
    assert em.free_nodes == free_before


def test_extended_pool_also_drains():
    from repro.core.policies import EXTENDED_POOL
    trace = tiny_trace(20, seed=6)
    report, twin = run_twin(trace, pool=EXTENDED_POOL)
    assert report.n_jobs == 20


def test_ensemble_twin_drains():
    trace = tiny_trace(16, seed=7)
    report, twin = run_twin(trace, ensemble=4, ensemble_noise=0.3)
    assert report.n_jobs == 16
