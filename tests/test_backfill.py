"""Property tests for the scheduling pass (priority + EASY backfill)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import policies
import jax
from repro.core.backfill import schedule_pass as _schedule_pass
schedule_pass = jax.jit(_schedule_pass)
from repro.core.state import QUEUED, RUNNING, add_job, empty_state, start_job

from conftest import make_cluster_state


def _random_state(draw_nodes, draw_est, n_jobs, total_nodes, running_frac,
                  seed):
    rng = np.random.default_rng(seed)
    st_ = empty_state(max(16, 1 << int(np.ceil(np.log2(n_jobs + 1)))),
                      total_nodes)
    free = total_nodes
    for j in range(n_jobs):
        nodes = draw_nodes[j % len(draw_nodes)]
        est = draw_est[j % len(draw_est)]
        st_ = add_job(st_, j, float(j * 3.0), min(nodes, total_nodes),
                      float(est))
        if rng.random() < running_frac and nodes <= free:
            st_ = start_job(st_, j, float(j * 3.0 + 1.0))
            free -= nodes
    return st_._replace(now=jnp.float32(n_jobs * 3.0 + 10.0))


@given(
    nodes=st.lists(st.integers(1, 16), min_size=1, max_size=8),
    est=st.lists(st.floats(10.0, 1000.0, allow_nan=False), min_size=1,
                 max_size=8),
    n_jobs=st.integers(1, 14),
    policy=st.sampled_from(list(policies.PAPER_POOL)),
    seed=st.integers(0, 1000),
)
@settings(max_examples=60, deadline=None)
def test_pass_never_overallocates(nodes, est, n_jobs, policy, seed):
    state = _random_state(nodes, est, n_jobs, 32, 0.3, seed)
    res = schedule_pass(state, jnp.int32(policy))
    assert int(res.state.free_nodes) >= 0
    used = int(jnp.sum(jnp.where(res.state.jobs.state == RUNNING,
                                 res.state.jobs.nodes, 0)))
    assert used + int(res.state.free_nodes) == int(res.state.total_nodes)


@given(
    nodes=st.lists(st.integers(1, 16), min_size=1, max_size=8),
    est=st.lists(st.floats(10.0, 1000.0, allow_nan=False), min_size=1,
                 max_size=8),
    n_jobs=st.integers(1, 14),
    policy=st.sampled_from(list(policies.EXTENDED_POOL)),
    seed=st.integers(0, 1000),
)
@settings(max_examples=60, deadline=None)
def test_started_jobs_were_queued_and_fit(nodes, est, n_jobs, policy, seed):
    state = _random_state(nodes, est, n_jobs, 32, 0.3, seed)
    res = schedule_pass(state, jnp.int32(policy))
    started = np.asarray(res.started)
    was_queued = np.asarray(state.jobs.state == QUEUED)
    assert not np.any(started & ~was_queued)
    # total started nodes <= initially free nodes
    tot = np.asarray(state.jobs.nodes)[started].sum()
    assert tot <= int(state.free_nodes)


@given(seed=st.integers(0, 500))
@settings(max_examples=40, deadline=None)
def test_backfill_never_delays_head_reservation(seed):
    """EASY invariant: every backfilled job either ends (by estimate)
    before the shadow time or fits in the reservation surplus."""
    state = make_cluster_state(seed=seed, n_queued=10, n_running=3)
    res = schedule_pass(state, jnp.int32(policies.FCFS))
    head = int(res.head_idx)
    if head < 0:
        return  # nothing blocked -> no reservation to protect
    shadow = float(res.shadow_time)
    started = np.asarray(res.started)
    # jobs started strictly after the head in FCFS arrival order are
    # backfills (FCFS key = submit time = slot order here)
    backfills = [j for j in np.nonzero(started)[0] if j > head]
    est = np.asarray(state.jobs.est_runtime)
    now = float(state.now)
    # shadow-time feasibility was computed against predicted ends; a
    # backfill violating BOTH conditions would delay the reservation
    nodes = np.asarray(state.jobs.nodes)
    head_nodes = int(nodes[head])
    free_after = int(res.state.free_nodes)
    for j in backfills:
        cond_a = now + est[j] <= shadow + 1e-5
        assert cond_a or free_after + 0 >= 0  # cond_b consumed surplus
    # the head itself must NOT have been started in this pass
    assert not started[head]


def test_fcfs_orders_by_arrival():
    state = make_cluster_state(n_queued=6, n_running=0, total_nodes=8,
                               seed=3)
    # make all jobs 4 nodes so exactly 2 start
    jobs = state.jobs
    state = state._replace(jobs=jobs._replace(
        nodes=jnp.where(jobs.state == QUEUED, 4, jobs.nodes)))
    res = schedule_pass(state, jnp.int32(policies.FCFS))
    started = np.nonzero(np.asarray(res.started))[0]
    queued = np.nonzero(np.asarray(state.jobs.state == QUEUED))[0]
    assert list(started) == list(queued[:2])  # earliest arrivals first


def test_sjf_prefers_short_jobs():
    state = empty_state(16, 4)
    state = add_job(state, 0, 0.0, 4, 500.0)
    state = add_job(state, 1, 1.0, 4, 50.0)
    state = state._replace(now=jnp.float32(10.0))
    res = schedule_pass(state, jnp.int32(policies.SJF))
    started = np.asarray(res.started)
    assert started[1] and not started[0]
    res = schedule_pass(state, jnp.int32(policies.FCFS))
    started = np.asarray(res.started)
    assert started[0] and not started[1]


def test_wfp_prefers_large_long_waiting():
    state = empty_state(16, 8)
    # same wait, same est: WFP score (wait/est)^3 * nodes -> big job first
    state = add_job(state, 0, 0.0, 2, 100.0)
    state = add_job(state, 1, 0.0, 8, 100.0)
    state = state._replace(now=jnp.float32(50.0))
    res = schedule_pass(state, jnp.int32(policies.WFP))
    started = np.asarray(res.started)
    assert started[1] and not started[0]  # 8-node job won, fills cluster
