"""Correctness of the §Perf optimization paths against their oracles.

Each beyond-paper optimization must be bit-compatible (within bf16/f32
tolerance) with the reference implementation it replaced — on the 1x1
test mesh the shard_map paths reduce to the sequential math exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.common import init_params


def test_moe_gather_dispatch_matches_gshard(mesh11, rules_train):
    from repro.models import blocks_moe
    cfg = get_smoke_config("olmoe-1b-7b")
    params = init_params(jax.random.PRNGKey(0), blocks_moe.moe_table(cfg))
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1),
                                (2, 64, cfg.d_model)).astype(jnp.bfloat16)
    outs = {}
    with mesh11:
        for d in ("gshard", "gather"):
            c = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, dispatch=d))
            y, aux = blocks_moe.moe_apply(c, rules_train, params, x)
            outs[d] = (np.asarray(y, dtype=np.float32), aux)
    np.testing.assert_allclose(outs["gshard"][0], outs["gather"][0],
                               atol=2e-3, rtol=2e-2)
    assert float(outs["gshard"][1]["moe_dropped"]) == \
        float(outs["gather"][1]["moe_dropped"])


def test_wkv_chunked_matches_scan():
    from repro.models.blocks_rnn import wkv_chunked, wkv_scan
    key = jax.random.PRNGKey(3)
    b, s, h, n = 2, 96, 2, 16
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, n))
    k = jax.random.normal(ks[1], (b, s, h, n))
    v = jax.random.normal(ks[2], (b, s, h, n))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, n)) - 1.0))
    u = jax.random.normal(ks[4], (h, n))
    s0 = 0.5 * jax.random.normal(jax.random.PRNGKey(9), (b, h, n, n))
    st1, y1 = wkv_scan(s0, r, k, v, w, u)
    st2, y2 = wkv_chunked(s0, r, k, v, w, u, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                               atol=5e-4, rtol=5e-4)


def test_wkv_chunked_stable_under_extreme_decay():
    from repro.models.blocks_rnn import wkv_chunked
    key = jax.random.PRNGKey(4)
    b, s, h, n = 1, 64, 1, 8
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, n))
    k = jax.random.normal(ks[1], (b, s, h, n))
    v = jax.random.normal(ks[2], (b, s, h, n))
    w = jnp.exp(-jnp.exp(3.0 * jax.random.normal(ks[3], (b, s, h, n))
                         + 1.0))  # decays down to exactly 0.0
    u = jax.random.normal(ks[4], (h, n))
    s0 = jnp.zeros((b, h, n, n))
    st, y = wkv_chunked(s0, r, k, v, w, u, chunk=32)
    assert bool(jnp.all(jnp.isfinite(y))) and bool(
        jnp.all(jnp.isfinite(st)))


def test_sp_projections_identity_on_trivial_mesh(mesh11, rules_train):
    """out_project_rs / in_project_ag == plain einsum on a 1x1 mesh."""
    from repro.distributed import megatron_sp
    h = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 6))  # B,S,H,K
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 12))    # H,K,D
    with mesh11:
        y = megatron_sp.out_project_rs(h, w, rules=rules_train,
                                       contract="hkd")
    want = jnp.einsum("bshk,hkd->bsd", h, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-5, rtol=1e-5)

    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 12))
    wg = jax.random.normal(jax.random.PRNGKey(3), (12, 16))
    wu = jax.random.normal(jax.random.PRNGKey(4), (12, 16))
    with mesh11:
        g, u = megatron_sp.in_project_ag(x, [wg, wu], rules=rules_train,
                                         kinds=("df", "df"))
    np.testing.assert_allclose(np.asarray(g), np.asarray(x @ wg),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(u), np.asarray(x @ wu),
                               atol=1e-5, rtol=1e-5)


def test_sp_projections_differentiable(mesh11, rules_train):
    from repro.distributed import megatron_sp
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 6))
    wg = jax.random.normal(jax.random.PRNGKey(3), (6, 8))

    def loss(x, wg):
        with mesh11:
            (g,) = megatron_sp.in_project_ag(x, [wg], rules=rules_train,
                                             kinds=("df",))
        return jnp.sum(g ** 2)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, wg)
    # reference grads of sum((x@w)^2)
    gref_x = 2 * (x @ wg) @ wg.T
    gref_w = 2 * jnp.einsum("bsd,bsf->df", x, x @ wg)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gref_x),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gref_w),
                               atol=1e-4, rtol=1e-4)


def test_expand_kv_matches_grouped_attention():
    """Broadcast-KV attention == grouped-query attention (H1)."""
    from repro.models.attention import full_attention
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 8, 32, 16))
    k = jax.random.normal(ks[1], (2, 2, 32, 16))   # GQA group 4
    v = jax.random.normal(ks[2], (2, 2, 32, 16))
    out = full_attention(q, k, v, causal=True, q_block=16)
    # manual grouped reference
    kk = jnp.repeat(k, 4, axis=1)
    vv = jnp.repeat(v, 4, axis=1)
    want = full_attention(q, kk, vv, causal=True, q_block=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
