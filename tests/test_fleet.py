"""Fleet-scale sharded replay grids (DESIGN.md §9).

Pins the tentpole invariants of ``whatif.sharded_replay_grid`` /
``sharded_whatif``:

- sharded == local BIT-IDENTITY with static-key hoisting ON (the PR-4
  compaction re-enabled under sharding, shard-local plans);
- block-streamed == one-shot (fixed-shape pipeline vs monolith);
- non-divisible S: internal inert padding never perturbs real rows;
- host/device overlap (``prefetch``) is pure pipelining — results are
  bit-identical at any depth, and worker errors surface in the caller;
- the per-``ScenarioSet`` host->device conversion cache hits on
  identity and evicts on death;
- a REAL ≥2-shard run (subprocess, ``--xla_force_host_platform_
  device_count=2``) matches the unsharded oracle bitwise — this is the
  regression net for the jax-0.4 shard_map/while_loop sort miscompile
  that ``engine.hoisted_orders`` works around.
"""
import gc
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.cluster.workload import (bursty_trace, pad_scenarios,
                                    poisson_trace, slice_scenarios,
                                    stack_scenarios)
from repro.core import whatif
from repro.core.engine import (_SCENARIO_ARRAY_CACHE, DrainEngine,
                               _scenario_arrays, shard_local_plan)
from repro.core.policies import parse_pool
from repro.data.pipeline import prefetch
from repro.launch.mesh import make_fleet_mesh

from conftest import make_cluster_state

REF = DrainEngine("reference")
PAL = DrainEngine("pallas", interpret=True)


def fleet_traces(n_traces, n_jobs=12, total_nodes=16):
    out = []
    for i in range(n_traces):
        gen = bursty_trace if i % 2 else poisson_trace
        out.append(gen(n_jobs, total_nodes, 4.0 + i,
                       (1, total_nodes - 4), (30.0, 400.0), seed=100 + i))
    return out


@pytest.fixture(scope="module")
def scen5():
    return stack_scenarios(fleet_traces(5), 16, max_jobs=16)


def assert_outcomes_identical(a, b, ctx=""):
    for f in ("start_t", "end_t", "deadlocked", "events", "costs",
              "best"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: {f}")
    for f in a.metrics._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a.metrics, f)),
            np.asarray(getattr(b.metrics, f)),
            err_msg=f"{ctx}: metrics.{f}")


# ----------------------------------------------------------------------
# Sharded == local, hoisting on (both backends).
# ----------------------------------------------------------------------

@pytest.mark.parametrize("eng", [REF, PAL], ids=["ref", "pallas"])
def test_sharded_grid_matches_local_with_hoisting(mesh11, scen5, eng):
    assert eng.hoist_static
    pool = parse_pool("extended,wfp:a=1..3x3")     # mixed static/varying
    local = eng.replay_grid(scen5, pool.spec)
    sharded = whatif.sharded_replay_grid(mesh11, engine=eng)(scen5, pool)
    assert_outcomes_identical(sharded, local, "sharded vs local")


def test_sharded_grid_all_static_pool(mesh11, scen5):
    """plan.all() — the zero-per-event-sort path — under sharding."""
    pool = parse_pool("fcfs,sjf,saf,ljf")
    assert all(REF.plan(pool.spec))
    local = REF.replay_grid(scen5, pool.spec)
    sharded = whatif.sharded_replay_grid(mesh11, engine=REF)(scen5, pool)
    assert_outcomes_identical(sharded, local, "all-static")


# ----------------------------------------------------------------------
# Block streaming + padding.
# ----------------------------------------------------------------------

def test_block_streamed_equals_single_shot(mesh11, scen5):
    pool = parse_pool("extended")
    one = whatif.sharded_replay_grid(mesh11, engine=REF)(scen5, pool)
    blk = whatif.sharded_replay_grid(mesh11, engine=REF,
                                     block_size=2)(scen5, pool)
    assert_outcomes_identical(blk, one, "streamed vs one-shot")
    assert blk.start_t.shape[:2] == (5, 7)


def test_padding_invariance_non_divisible(mesh11, scen5):
    """S=5 into B=2 blocks: the last block is padded with an inert
    row; every real row must be bitwise what the unpadded local grid
    computes, and padded rows must not leak into the outcome."""
    pool = parse_pool("extended")
    local = REF.replay_grid(scen5, pool.spec)
    blk = whatif.sharded_replay_grid(mesh11, engine=REF,
                                     block_size=2)(scen5, pool)
    assert blk.costs.shape == (5, 7)
    assert blk.best.shape == (5,)
    assert_outcomes_identical(blk, local, "padded stream vs local")


def test_overlap_determinism(mesh11, scen5):
    pool = parse_pool("extended")
    d0 = whatif.sharded_replay_grid(mesh11, engine=REF, block_size=2,
                                    prefetch_depth=0)(scen5, pool)
    d2 = whatif.sharded_replay_grid(mesh11, engine=REF, block_size=2,
                                    prefetch_depth=2)(scen5, pool)
    assert_outcomes_identical(d0, d2, "depth 0 vs depth 2")


def test_iterator_block_source(mesh11, scen5):
    """Pre-cut block iterables (on-the-fly trace synthesis) match the
    ScenarioSet path — including a ragged final block."""
    pool = parse_pool("extended")
    whole = whatif.sharded_replay_grid(mesh11, engine=REF,
                                       block_size=2)(scen5, pool)
    blocks = (slice_scenarios(scen5, lo, min(lo + 2, 5))
              for lo in range(0, 5, 2))
    streamed = whatif.sharded_replay_grid(mesh11, engine=REF,
                                          block_size=2)(blocks, pool)
    assert_outcomes_identical(streamed, whole, "iterator vs set")


def test_iterator_source_errors(mesh11, scen5):
    pool = parse_pool("extended")
    run = whatif.sharded_replay_grid(mesh11, engine=REF, block_size=2)
    with pytest.raises(ValueError, match="no scenario blocks"):
        run(iter(()), pool)
    oversized = iter([scen5])                    # 5 scenarios > B=2
    with pytest.raises(ValueError, match="block size"):
        run(oversized, pool)


def test_pad_scenarios_semantics(scen5):
    assert pad_scenarios(scen5, 5) is scen5      # identity on divide
    padded = pad_scenarios(scen5, 3)
    assert padded.n_scenarios == 6
    assert not padded.valid[5:].any()            # inert: born drained
    np.testing.assert_array_equal(padded.submit_t[:5], scen5.submit_t)
    with pytest.raises(ValueError, match="positive"):
        pad_scenarios(scen5, 0)


# ----------------------------------------------------------------------
# Host-side machinery: prefetch errors, conversion cache, local plans.
# ----------------------------------------------------------------------

def test_prefetch_propagates_worker_errors():
    def boom():
        yield 1
        raise RuntimeError("ingest failed")
    it = prefetch(boom(), depth=2)
    assert next(it) == 1
    with pytest.raises(RuntimeError, match="ingest failed"):
        next(it)


def test_scenario_array_cache_hit_and_eviction():
    scen = stack_scenarios(fleet_traces(2, n_jobs=6), 16, max_jobs=8)
    first = _scenario_arrays(scen)
    again = _scenario_arrays(scen)
    assert all(x is y for x, y in zip(first, again))   # cache hit
    key = id(scen)
    assert key in _SCENARIO_ARRAY_CACHE
    del scen, first, again
    gc.collect()
    assert key not in _SCENARIO_ARRAY_CACHE            # finalizer ran


def test_shard_local_plan():
    assert shard_local_plan(None, 4) is None
    plan = (True, False, True, False)
    assert shard_local_plan(plan, 1) == plan           # no sharding
    assert shard_local_plan(plan, 2) == (True, False)  # periodic
    assert shard_local_plan((True, False, False, True), 2) is None
    assert shard_local_plan((True, False, True), 2) is None   # 3 % 2
    assert shard_local_plan((False, False), 2) is None  # nothing to hoist


def test_make_fleet_mesh_bounds():
    mesh = make_fleet_mesh()
    assert mesh.shape["model"] == 1
    n = len(jax.devices())
    with pytest.raises(ValueError, match="outside"):
        make_fleet_mesh(n + 1)
    with pytest.raises(ValueError, match="outside"):
        make_fleet_mesh(0)


# ----------------------------------------------------------------------
# sharded_whatif: hoisting parity + divisibility contract.
# ----------------------------------------------------------------------

def test_sharded_whatif_hoist_parity(mesh11):
    state = make_cluster_state(max_jobs=16, total_nodes=32, n_queued=8,
                               n_running=3, seed=4)
    for grammar in ("fcfs,sjf", "extended,wfp:a=1..3x3"):
        pool = parse_pool(grammar)
        ref = REF.decide(state, pool.spec)
        got = whatif.sharded_whatif(mesh11, engine=REF)(state, pool)
        for f in ("policy_index", "costs", "run_mask", "deadlocked"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, f)),
                np.asarray(getattr(got, f)), err_msg=f"{grammar}: {f}")


# ----------------------------------------------------------------------
# Real ≥2-shard parity (fake CPU devices, fresh process).
# ----------------------------------------------------------------------

_TWO_DEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, numpy as np
    from repro.cluster.workload import poisson_trace, stack_scenarios
    from repro.core import whatif
    from repro.core.engine import DrainEngine
    from repro.core.policies import parse_pool
    from repro.launch.mesh import make_fleet_mesh

    assert len(jax.devices()) == 2
    eng = DrainEngine("reference")
    mesh = make_fleet_mesh(2)
    traces = [poisson_trace(8, 16, 4.0 + i, (1, 12), (30.0, 400.0),
                            seed=100 + i) for i in range(3)]
    scen = stack_scenarios(traces, 16, max_jobs=16)
    for grammar in ("fcfs,sjf", "wfp,expf,fcfs,sjf"):
        pool = parse_pool(grammar)
        ref = eng.replay_grid(scen, pool.spec)
        for bs in (None, 2):
            got = whatif.sharded_replay_grid(mesh, engine=eng,
                                             block_size=bs)(scen, pool)
            for f in ("start_t", "end_t", "deadlocked", "costs", "best"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(ref, f)),
                    np.asarray(getattr(got, f)),
                    err_msg=f"{grammar} bs={bs}: {f}")
    print("TWO_DEV_PARITY_OK")
""")


def test_two_shard_parity_subprocess():
    """Hoisting under REAL sharding: 2 fake CPU devices in a fresh
    process (device count is fixed at backend init).  Non-leading
    shards exercise the ``hoisted_orders`` boundary-crossing fix; this
    fails with corrupted shard-1 rows if the static argsort is ever
    moved back inside the ``shard_map`` body."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]))
    out = subprocess.run([sys.executable, "-c", _TWO_DEV], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "TWO_DEV_PARITY_OK" in out.stdout
