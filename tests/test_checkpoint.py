"""Checkpoint manager: atomicity, async, GC, elastic restore."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, CheckpointManager, step_dir
from repro.checkpoint.manager import ARRAYS, MANIFEST
from repro.configs import get_smoke_config
from repro.train import init_train_state, state_shardings
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_mesh


@pytest.fixture()
def state():
    cfg = get_smoke_config("llama3.2-1b")
    return init_train_state(jax.random.PRNGKey(0), cfg)


def _trees_equal(a, b) -> bool:
    return bool(jax.tree.all(jax.tree.map(
        lambda x, y: jnp.all(x.astype(jnp.float32) == y.astype(jnp.float32)),
        a, b)))


def test_save_restore_roundtrip(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, state, extra={"tokens_seen": 123})
    restored, extra = mgr.restore(7, state)
    assert _trees_equal(state, restored)
    assert extra["tokens_seen"] == 123


def test_gc_keeps_last_k(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]


def test_incomplete_checkpoint_ignored(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state)
    # a crash mid-save: arrays without manifest
    d = step_dir(str(tmp_path), 2)
    os.makedirs(d)
    shutil.copy(os.path.join(step_dir(str(tmp_path), 1), ARRAYS),
                os.path.join(d, ARRAYS))
    assert mgr.latest_step() == 1  # step 2 invisible
    got = mgr.restore_latest(state)
    assert got is not None and got[0] == 1


def test_corrupt_shape_rejected(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state)
    other = jax.tree.map(lambda a: jnp.zeros(a.shape + (2,), a.dtype), state)
    with pytest.raises(ValueError):
        mgr.restore(1, other)


def test_async_checkpointer_overlaps_and_surfaces_errors(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path))
    ac = AsyncCheckpointer(mgr)
    ac.save(1, state)
    ac.save(2, state)   # joins the first save implicitly
    ac.wait()
    assert mgr.all_steps() == [1, 2]


def test_cross_mesh_elastic_restore(tmp_path, state):
    """Save unsharded, restore under explicit shardings of a different
    mesh topology — the elastic-restart path."""
    cfg = get_smoke_config("llama3.2-1b")
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, state)

    mesh_b = make_mesh((1, 1), ("data", "model"))
    rules_b = make_rules(mesh_b, "dp_tp")
    sh = state_shardings(cfg, rules_b)
    sh = sh._replace(ef=None)
    restored, _ = mgr.restore(5, state, shardings=sh)
    assert _trees_equal(state, restored)
    # placed arrays carry the requested sharding
    leaf = restored.params["embed"]
    assert leaf.sharding.mesh.shape == {"data": 1, "model": 1}


def test_manifest_is_json_readable(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, state)
    with open(os.path.join(step_dir(str(tmp_path), 3), MANIFEST)) as f:
        m = json.load(f)
    assert m["step"] == 3
    assert len(m["keys"]) == len(jax.tree.leaves(state))
