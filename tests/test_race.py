"""Adaptive fan racing (DESIGN.md §11).

Pins the tentpole invariants of ``core.race`` + the racing surfaces of
``core.engine`` / ``core.whatif`` / ``core.fan`` / ``core.twin``:

- WINNER INVARIANCE: an unbudgeted race selects the same winner as the
  full-F ``fan_grid`` on every scenario — property-tested on both pass
  backends and fuzzed over synthetic member tensors with ties and +inf;
- F₀ == F_max is BITWISE the plain fan grid (one rung == no racing);
- rung suffixes are CRN-prefix-stable: ``fan_window_grid(lo, w)`` is
  bitwise the ``[lo, lo+w)`` slice of the full fan's members;
- no (scenario, member, policy) triple is ever replayed twice — the
  controller raises on an overlapping window and the accounting fields
  add up (``members == Σ rung members``, all windows disjoint);
- edge cases: P=1 pools separate immediately, all-tied costs never
  eliminate (strict ``>``), +inf-poisoned CIs never eliminate,
  budget/max_members stop mid-race with a consistent rectangle;
- ``sharded_race_grid`` (any block size) is bitwise the local race;
- ``decide_race`` at f0=F_max is bitwise ``decide_fan``, and raced twin
  cycles stamp rungs/members/separation into telemetry;
- ``pruned_fan_grid`` donates its pre-pass members (CRN prefix) instead
  of re-replaying them — accounting shows the saving;
- ``FanSpec.from_history`` fits its lognormal σ to §3.2 residuals.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.workload import poisson_trace, stack_scenarios
from repro.core import whatif
from repro.core.engine import DrainEngine
from repro.core.fan import FanSpec, fit_runtime_sigma, pruned_fan_grid
from repro.core.objective import parse_objective
from repro.core.policies import parse_pool
from repro.core.race import (RaceSpec, normalize_race, race_grid,
                             run_race)
from repro.launch.mesh import make_fleet_mesh

REF = DrainEngine("reference")
PAL = DrainEngine("pallas", interpret=True)

POOL = parse_pool("fcfs,sjf,saf")
NOISY = FanSpec(n=8, runtime_noise=0.3, burst_amplitude=0.5,
                burst_period=600.0, failure_prob=0.3, seed=7)
RACE = RaceSpec(fan=NOISY, f0=2)


@pytest.fixture(scope="module")
def scen():
    traces = [poisson_trace(12, 16, 30.0, (1, 4), (60.0, 600.0), seed=s)
              for s in range(3)]
    return stack_scenarios(traces, total_nodes=16)


# ----------------------------------------------------------------------
# schedule / spec validation
# ----------------------------------------------------------------------

def test_rung_schedule():
    spec = RaceSpec(fan=FanSpec(n=64), f0=8, growth=2)
    assert spec.rungs() == ((0, 8), (8, 16), (16, 32), (32, 64))
    # F_max not a power multiple: last rung is clipped
    spec = RaceSpec(fan=FanSpec(n=24), f0=8)
    assert spec.rungs() == ((0, 8), (8, 16), (16, 24))
    # f0 >= F_max degenerates to a single full-fidelity rung
    assert RaceSpec(fan=FanSpec(n=8), f0=8).rungs() == ((0, 8),)
    assert RaceSpec(fan=FanSpec(n=8), f0=64).rungs() == ((0, 8),)


def test_spec_validation():
    with pytest.raises(ValueError):
        RaceSpec(f0=0)
    with pytest.raises(ValueError):
        RaceSpec(growth=1)
    with pytest.raises(ValueError):
        RaceSpec(z=0.0)
    with pytest.raises(ValueError):
        RaceSpec(budget_ms=-1.0)
    with pytest.raises(ValueError):
        RaceSpec(max_members=0)
    # normalize: FanSpec and bare int lift to the default schedule
    assert normalize_race(NOISY).fan is NOISY
    assert normalize_race(16).f_max == 16
    assert normalize_race(RACE) is RACE


# ----------------------------------------------------------------------
# engine substrate: rung windows are CRN prefix-stable suffixes
# ----------------------------------------------------------------------

@pytest.mark.parametrize("eng", [REF, PAL], ids=["reference", "pallas"])
def test_fan_window_is_bitwise_fan_slice(scen, eng):
    full = eng.fan_grid(scen, POOL.spec, NOISY, "avg_wait")
    for lo, hi in ((0, 2), (2, 4), (4, 8)):
        win = eng.fan_window_grid(scen, POOL.spec, NOISY, "avg_wait",
                                  lo=lo, width=hi - lo)
        np.testing.assert_array_equal(
            np.asarray(win.member_costs),
            np.asarray(full.member_costs)[:, lo:hi],
            err_msg=f"window [{lo},{hi})")
        np.testing.assert_array_equal(
            np.asarray(win.start_t),
            np.asarray(full.start_t)[:, lo:hi])


def test_fan_window_validates():
    with pytest.raises(ValueError):
        REF.fan_window_grid(None, POOL.spec, NOISY, lo=-1, width=2)
    with pytest.raises(ValueError):
        REF.fan_window_grid(None, POOL.spec, NOISY, lo=4, width=8)
    with pytest.raises(ValueError):
        REF.fan_window_grid(None, POOL.spec, NOISY, lo=0, width=0)


# ----------------------------------------------------------------------
# winner invariance: race == full-F fan grid, both backends
# ----------------------------------------------------------------------

@pytest.mark.parametrize("eng", [REF, PAL], ids=["reference", "pallas"])
@pytest.mark.parametrize("goal", ["score", "p95:avg_wait"])
def test_race_winner_matches_fan_grid(scen, eng, goal):
    full = eng.fan_grid(scen, POOL.spec, NOISY, goal)
    out = race_grid(scen, POOL.spec, RACE, goal, engine=eng)
    np.testing.assert_array_equal(np.asarray(out.best),
                                  np.asarray(full.best))
    # surviving columns carry the full grid's member costs, bitwise
    np.testing.assert_array_equal(
        out.member_costs,
        np.asarray(full.member_costs)[:, :out.fan_size, :][:, :, out.keep])


def test_race_duplicated_pool_real_ties(scen):
    # CRN makes duplicated policies bitwise-identical columns: exact
    # ties at every rung, which strict > must never eliminate, and the
    # first occurrence must win the tie-break — same as the full grid
    dup = parse_pool("fcfs,sjf,fcfs")
    full = REF.fan_grid(scen, dup.spec, NOISY, "score")
    out = race_grid(scen, dup.spec, RACE, "score", engine=REF)
    np.testing.assert_array_equal(out.best, np.asarray(full.best))
    # a duplicate can only leave with its twin; the surviving set still
    # contains the full grid's winner for every scenario
    assert all(int(b) in set(int(i) for i in out.keep)
               for b in np.asarray(full.best))


def test_race_f0_equals_fmax_is_bitwise_fan_grid(scen):
    full = REF.fan_grid(scen, POOL.spec, NOISY, "score")
    out = race_grid(scen, POOL.spec,
                    RaceSpec(fan=NOISY, f0=NOISY.n), "score", engine=REF)
    assert len(out.rungs) == 1 and out.fan_size == NOISY.n
    assert out.members == out.members_full
    np.testing.assert_array_equal(out.member_costs,
                                  np.asarray(full.member_costs))
    np.testing.assert_array_equal(out.costs, np.asarray(full.costs))
    np.testing.assert_array_equal(out.best, np.asarray(full.best))


def test_race_winner_invariance_synthetic_fuzz():
    # pure-controller fuzz where the CI rule is exactly sound: each
    # column's members are constant (zero sampling noise => CI 0,
    # elimination == true strict dominance) and random (s, p) columns
    # are wholly +inf-poisoned (CI +inf => never eliminated; the cost
    # is inf at EVERY fidelity, so low-rung evidence stays honest —
    # cell-level poisoning would make a column's cost change with
    # fidelity, which no sequential test can see coming).  Ties between
    # columns are frequent (small-int draws).  The raced argmin must
    # equal the full-tensor argmin for every scenario, always.
    goal = parse_objective("mean:avg_wait")
    rng = np.random.default_rng(11)
    for trial in range(60):
        S = int(rng.integers(1, 4))
        P = int(rng.integers(1, 6))
        member = np.tile(
            rng.integers(-5, 6, size=(S, 1, P)).astype(np.float32),
            (1, 8, 1))
        if trial % 3 == 0:
            member[rng.random(size=(S, 1, P)).repeat(8, 1) < 0.2] = np.inf
        spec = RaceSpec(fan=FanSpec(n=8), f0=2)
        out = run_race(spec, S, P, goal,
                       lambda act, lo, hi: member[:, lo:hi, :][:, :, act])
        want = np.argmin(member.mean(axis=1), axis=1)
        np.testing.assert_array_equal(out.best, want,
                                      err_msg=f"trial {trial}")


def test_race_winner_invariance_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra.numpy import arrays

    goal = parse_objective("mean:avg_wait")

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def run(data):
        S = data.draw(st.integers(1, 3))
        P = data.draw(st.integers(1, 5))
        base = data.draw(arrays(
            np.float32, (S, 1, P),
            elements=st.integers(-5, 5).map(float)))
        member = np.tile(base, (1, 8, 1))
        poison = data.draw(arrays(np.bool_, (S, 1, P)))
        member = np.where(np.repeat(poison, 8, 1),
                          np.float32(np.inf), member)
        out = run_race(
            RaceSpec(fan=FanSpec(n=8), f0=2), S, P, goal,
            lambda act, lo, hi: member[:, lo:hi, :][:, :, act])
        want = np.argmin(member.mean(axis=1), axis=1)
        np.testing.assert_array_equal(out.best, want)

    run()


# ----------------------------------------------------------------------
# elimination edge cases (synthetic controller harness)
# ----------------------------------------------------------------------

def _serve(member):
    return lambda act, lo, hi: member[:, lo:hi, :][:, :, act]


def test_single_policy_separates_immediately():
    member = np.abs(np.random.default_rng(0).normal(
        size=(2, 8, 1))).astype(np.float32)
    out = run_race(RaceSpec(fan=FanSpec(n=8), f0=2), 2, 1,
                   parse_objective("mean:avg_wait"), _serve(member))
    assert out.stopped == "separated" and out.separated
    assert out.fan_size == 2 and len(out.rungs) == 1
    assert (out.separation == np.inf).all()
    np.testing.assert_array_equal(out.best, [0, 0])


def test_single_policy_pool_end_to_end(scen):
    solo = parse_pool("fcfs")
    out = race_grid(scen, solo.spec, RACE, "score", engine=REF)
    assert out.stopped == "separated"
    assert out.fan_size == RACE.f0
    np.testing.assert_array_equal(out.best, [0, 0, 0])


def test_all_tied_costs_never_eliminate():
    # CRN-identical columns: strict > keeps every policy to full
    # fidelity and the first column wins the tie-break
    member = np.tile(np.random.default_rng(1).normal(
        size=(2, 8, 1)).astype(np.float32), (1, 1, 4))
    out = run_race(RaceSpec(fan=FanSpec(n=8), f0=2), 2, 4,
                   parse_objective("mean:avg_wait"), _serve(member))
    assert out.stopped == "exhausted" and not out.separated
    assert list(out.keep) == [0, 1, 2, 3]
    assert all(r.eliminated == () for r in out.rungs)
    np.testing.assert_array_equal(out.best, [0, 0])


def test_inf_at_rung0_never_eliminated():
    # policy 1 has one +inf member in rung 0 -> its CI is +inf -> its
    # lower bound is nan/inf arithmetic -> strict > must NOT fire even
    # though its finite members are terrible
    member = np.zeros((1, 8, 3), np.float32)
    member[0, :, 0] = 1.0
    member[0, :, 1] = 100.0
    member[0, 0, 1] = np.inf
    member[0, :, 2] = 50.0                       # finite, clearly worse
    out = run_race(RaceSpec(fan=FanSpec(n=8), f0=2), 1, 3,
                   parse_objective("mean:avg_wait"), _serve(member))
    assert 1 in out.keep           # poisoned CI survived to full fidelity
    assert 2 not in out.keep       # finite loser was eliminated
    np.testing.assert_array_equal(out.best, [0])


def test_max_members_stops_mid_race():
    member = np.random.default_rng(2).normal(
        size=(2, 16, 3)).astype(np.float32)
    # rung-0 members tied across policies: no elimination, no
    # separation -> the race deterministically reaches the rung-1
    # budget check with everyone still active
    member[:, :2, :] = member[:, :2, :1]
    spec = RaceSpec(fan=FanSpec(n=16), f0=2, max_members=12)
    out = run_race(spec, 2, 3, parse_objective("mean:avg_wait"),
                   _serve(member))
    # rung 0 spends 2*2*3=12; any further rung busts the budget
    assert out.stopped == "max_members"
    assert out.members == 12 and out.fan_size == 2
    assert out.members <= spec.max_members
    # the reported rectangle is consistent: stats cover the survivors
    assert out.costs.shape == (2, len(out.keep))
    assert out.cost_ci.shape == out.costs.shape


def test_budget_ms_stops_mid_race():
    member = np.random.default_rng(3).normal(
        size=(1, 16, 3)).astype(np.float32)
    member[:, :2, :] = member[:, :2, :1]         # rung 0 tied -> continue
    t = [0.0]

    def clock():
        t[0] += 1.0                              # 1 s per call
        return t[0]

    spec = RaceSpec(fan=FanSpec(n=16), f0=2, budget_ms=1.0)
    out = run_race(spec, 1, 3, parse_objective("mean:avg_wait"),
                   _serve(member), clock=clock)
    # rung 0 always runs (anytime => SOME answer); rung 1 is refused
    assert out.stopped == "budget_ms"
    assert out.fan_size == 2 and len(out.rungs) == 1


def test_overlapping_window_raises():
    member = np.zeros((1, 8, 2), np.float32)
    hits = []

    def bad(act, lo, hi):                        # replays rung 0 twice
        hits.append((lo, hi))
        return member[:, 0:hi - lo, :][:, :, act]

    class Cheat(RaceSpec):
        def rungs(self):
            return ((0, 2), (0, 2))

    with pytest.raises(RuntimeError, match="replay"):
        run_race(Cheat(fan=FanSpec(n=8), f0=2), 1, 2,
                 parse_objective("mean:avg_wait"), bad)


def test_no_member_replayed_twice_accounting(scen):
    # every (s, phi, p) triple the race pays for is unique, and the
    # ledger adds up: members == sum of rung members == len(triples)
    triples = set()
    seen = []

    eng = REF
    spec = RACE

    def eval_window(active, lo, hi):
        for s in range(3):
            for phi in range(lo, hi):
                for p in active:
                    key = (s, phi, int(p))
                    assert key not in triples, f"replayed {key}"
                    triples.add(key)
        seen.append((lo, hi, tuple(int(i) for i in active)))
        out = eng.fan_window_grid(
            scen, POOL.spec, spec.fan, "score", lo=lo, width=hi - lo)
        return np.asarray(out.member_costs)[:, :, active]

    out = run_race(spec, 3, 3, parse_objective("score"), eval_window)
    assert out.members == len(triples)
    assert out.members == sum(r.members for r in out.rungs)
    los = [w[0] for w in seen]
    his = [w[1] for w in seen]
    assert los == sorted(los) and all(a == b for a, b in
                                      zip(his[:-1], los[1:]))


def test_race_grid_spends_fewer_members_when_separable():
    # an easy workload — contended queue (policies genuinely differ)
    # with low noise (tight CIs) — must separate early and spend far
    # fewer members than the fixed-F bill, at the same winners
    traces = [poisson_trace(24, 8, 5.0, (1, 6), (300.0, 3000.0), seed=s)
              for s in range(3)]
    hard = stack_scenarios(traces, total_nodes=8)
    easy = FanSpec(n=32, runtime_noise=0.02, seed=3)
    out = race_grid(hard, POOL.spec,
                    RaceSpec(fan=easy, f0=2), "avg_wait", engine=REF)
    full = REF.fan_grid(hard, POOL.spec, easy, "avg_wait")
    np.testing.assert_array_equal(out.best, np.asarray(full.best))
    assert out.members * 3 <= out.members_full
    assert out.stopped == "separated"
    # pass_invocations counts batched-drain LOOP TRIPS (max over the
    # batch, not per-fork work), so prefix reuse can't inflate it: the
    # race's summed rung trips never exceed per-rung trip counts times
    # rung count — here one separated rung, so at most the full bill
    assert 0 < out.passes <= int(full.result.pass_invocations)


# ----------------------------------------------------------------------
# fleet: sharded/streamed race == local race, bitwise
# ----------------------------------------------------------------------

def test_sharded_race_grid_matches_local(scen):
    local = race_grid(scen, POOL.spec, RACE, "p95:avg_wait", engine=REF)
    mesh = make_fleet_mesh(1)
    for block in (None, 4):
        got = whatif.sharded_race_grid(
            mesh, engine=REF, objective="p95:avg_wait", race=RACE,
            block_size=block)(scen, POOL)
        np.testing.assert_array_equal(local.member_costs,
                                      got.member_costs,
                                      err_msg=f"block={block}")
        np.testing.assert_array_equal(local.costs, got.costs)
        np.testing.assert_array_equal(local.best, got.best)
        np.testing.assert_array_equal(local.keep, got.keep)
        assert got.stopped == local.stopped


# ----------------------------------------------------------------------
# decide_race: the twin's raced decision cycle
# ----------------------------------------------------------------------

def test_decide_race_f0_fmax_is_bitwise_decide_fan():
    from conftest import make_cluster_state
    pool = jnp.asarray([0, 1, 2], jnp.int32)
    spec = FanSpec(n=8, runtime_noise=0.3, seed=5)
    for seed in range(3):
        state = make_cluster_state(max_jobs=48, total_nodes=32,
                                   seed=seed, n_queued=6, n_running=2,
                                   now=100.0 + 40.0 * seed)
        df = REF.decide_fan(state, pool, spec, "p95:avg_wait")
        dr, out = REF.decide_race(
            state, pool, RaceSpec(fan=spec, f0=spec.n), "p95:avg_wait")
        assert int(df.policy_index) == int(dr.policy_index)
        np.testing.assert_array_equal(np.asarray(df.costs),
                                      np.asarray(dr.costs))
        np.testing.assert_array_equal(np.asarray(df.cost_ci),
                                      np.asarray(dr.cost_ci))
        np.testing.assert_array_equal(np.asarray(df.fan_width),
                                      np.asarray(dr.fan_width))
        np.testing.assert_array_equal(np.asarray(df.run_mask),
                                      np.asarray(dr.run_mask))
        assert dr.fan_size == spec.n == out.fan_size


def test_decide_race_winner_matches_decide_fan():
    from conftest import make_cluster_state
    pool = jnp.asarray([0, 1, 2], jnp.int32)
    spec = FanSpec(n=8, runtime_noise=0.3, seed=5)
    for seed in range(3):
        state = make_cluster_state(max_jobs=48, total_nodes=32,
                                   seed=seed, n_queued=8, n_running=2,
                                   now=200.0 + 30.0 * seed)
        df = REF.decide_fan(state, pool, spec, "score")
        dr, out = REF.decide_race(state, pool,
                                  RaceSpec(fan=spec, f0=2), "score")
        assert int(df.policy_index) == int(dr.policy_index)
        np.testing.assert_array_equal(np.asarray(df.run_mask),
                                      np.asarray(dr.run_mask))
        assert out.fan_size == spec.n or out.stopped == "separated"


def test_twin_race_stamps_telemetry():
    from repro.cluster.emulator import ClusterEmulator
    from repro.core.events import EventBus
    from repro.core.twin import SchedTwin
    trace = poisson_trace(10, 16, 20.0, (1, 4), (30.0, 300.0), seed=1)
    bus = EventBus()
    em = ClusterEmulator(trace, 16, bus=bus)
    twin = SchedTwin(bus=bus, qrun=em.qrun, total_nodes=16,
                     max_jobs=em.max_jobs,
                     race=RaceSpec(fan=FanSpec(n=4, runtime_noise=0.3),
                                   f0=2),
                     objective="p95:avg_wait",
                     free_nodes_probe=lambda: em.free_nodes)
    em.run(on_event=twin.pump)
    assert twin.telemetry.cycles, "no decision cycles ran"
    recs = twin.telemetry.cycles
    assert all(r.race_stopped for r in recs)
    assert all(r.race_rungs >= 1 for r in recs)
    assert all(0 < r.race_members <= 4 * 3 for r in recs)
    assert all(1 <= r.fan_size <= 4 for r in recs)
    # §3.2 residuals: every completed job reveals an (est, actual) pair
    assert twin.telemetry.runtime_residuals
    assert all(e > 0 and a > 0
               for e, a in twin.telemetry.runtime_residuals)
    # heterogeneous-F aggregation works on raced history
    stats = twin.telemetry.confidence_stats()
    for st in stats.values():
        assert st["min_fan"] <= st["max_fan"]
        if st["n"]:
            assert st["mean_sigma"] >= 0.0


def test_twin_rejects_race_plus_fan():
    from repro.core.events import EventBus
    from repro.core.twin import SchedTwin
    with pytest.raises(ValueError, match="mutually exclusive"):
        SchedTwin(bus=EventBus(), qrun=lambda j, t: None, total_nodes=8,
                  race=RaceSpec(), fan=FanSpec(n=4))
    with pytest.raises(ValueError, match="mutually exclusive"):
        SchedTwin(bus=EventBus(), qrun=lambda j, t: None, total_nodes=8,
                  race=RaceSpec(), ensemble=4)


def test_confidence_stats_heterogeneous_fans():
    from repro.core.telemetry import CycleRecord, Telemetry
    tel = Telemetry()
    for t, (f, ci) in enumerate([(4, 2.0), (16, 1.0), (64, 0.5)]):
        tel.record(CycleRecord(
            time=float(t), wall_seconds=0.01, policy="FCFS",
            costs={"FCFS": 1.0}, n_started=0, started_jobs=[],
            cost_ci={"FCFS": ci}, fan_width={"FCFS": 3.0}, fan_size=f))
    st = tel.confidence_stats()["FCFS"]
    assert st["n"] == 3
    assert st["min_fan"] == 4 and st["max_fan"] == 64
    assert st["mean_fan"] == pytest.approx(28.0)
    # mean_sigma de-scales ci by sqrt(F)/1.96 -> F-independent
    want = np.mean([2.0 * 2 / 1.96, 1.0 * 4 / 1.96, 0.5 * 8 / 1.96])
    assert st["mean_sigma"] == pytest.approx(want)


# ----------------------------------------------------------------------
# satellite: pruned_fan_grid donates its pre-pass members
# ----------------------------------------------------------------------

def test_pruned_fan_grid_donation_accounting(scen):
    # low-noise fan -> the pre-pass drops policies -> the full fan only
    # pays for the suffix members of the survivors
    easy = FanSpec(n=16, runtime_noise=0.02, seed=3)
    full = REF.fan_grid(scen, POOL.spec, easy, "avg_wait")
    out, info = pruned_fan_grid(scen, POOL.spec, easy, "avg_wait",
                                engine=REF, pre_n=2)
    np.testing.assert_array_equal(info.best, np.asarray(full.best))
    np.testing.assert_array_equal(
        np.asarray(out.member_costs),
        np.asarray(full.member_costs)[:, :, info.keep])
    S, P, Pk = 3, 3, len(np.asarray(info.keep))
    assert info.members_full == S * easy.n * P
    assert info.members == S * (2 * P + (easy.n - 2) * Pk)
    if Pk < P:
        assert info.members < info.members_full


def test_pruned_fan_grid_no_prune_donates_everything(scen):
    # nothing eliminated -> donation still means the pre-pass members
    # are not paid twice: total == S*(pre*P + (n-pre)*P) == S*n*P
    out, info = pruned_fan_grid(scen, POOL.spec, NOISY, "p95:avg_wait",
                                engine=REF, pre_n=2)
    full = REF.fan_grid(scen, POOL.spec, NOISY, "p95:avg_wait")
    np.testing.assert_array_equal(
        np.asarray(out.member_costs),
        np.asarray(full.member_costs)[:, :, info.keep])
    assert info.members <= info.members_full


# ----------------------------------------------------------------------
# satellite: FanSpec.from_history fits sigma to runtime residuals
# ----------------------------------------------------------------------

def test_fit_runtime_sigma_recovers_lognormal():
    rng = np.random.default_rng(0)
    est = rng.uniform(60.0, 600.0, size=4000)
    true_sigma = 0.4
    actual = est * np.exp(rng.normal(0.0, true_sigma, size=est.shape))
    got = fit_runtime_sigma(list(zip(est, actual)))
    assert got == pytest.approx(true_sigma, rel=0.1)


def test_fit_runtime_sigma_fallback_and_filtering():
    assert fit_runtime_sigma([]) == 0.3
    assert fit_runtime_sigma([(100.0, 110.0)], fallback=0.7) == 0.7
    # non-finite / non-positive pairs are dropped, not propagated
    pairs = ([(100.0, np.inf), (0.0, 50.0), (100.0, -5.0)]
             + [(100.0, 100.0 * np.exp(0.2 * (-1) ** i))
                for i in range(20)])
    got = fit_runtime_sigma(pairs)
    assert np.isfinite(got) and got > 0


def test_fanspec_from_history():
    rng = np.random.default_rng(1)
    est = rng.uniform(60.0, 600.0, size=500)
    actual = est * np.exp(rng.normal(0.0, 0.25, size=est.shape))
    spec = FanSpec.from_history(list(zip(est, actual)), n=16,
                                failure_prob=0.1)
    assert spec.n == 16 and spec.failure_prob == 0.1
    assert spec.runtime_noise == pytest.approx(0.25, rel=0.2)
    # a Telemetry object works directly (reads .runtime_residuals)
    from repro.core.telemetry import Telemetry
    tel = Telemetry()
    for e, a in zip(est, actual):
        tel.record_residual(e, a)
    spec2 = FanSpec.from_history(tel, n=16, failure_prob=0.1)
    assert spec2 == spec
    # too little history -> documented fallback
    assert FanSpec.from_history(Telemetry(), n=4).runtime_noise == 0.3
