"""Resilient twin runtime (DESIGN.md §12).

Pins the hardened-ingestion, deadline-ladder, and crash-safety
contracts:

- ``EventBus.publish`` isolates subscriber exceptions from the
  producer (and ``health()`` surfaces them);
- malformed events are quarantined into the dead-letter queue, never
  raised mid-cycle;
- ``SeqTracker`` classifies duplicates / reordering / gaps / loss in
  bounded memory, and idempotent ``apply_event`` makes ANY cross-job
  interleaving that preserves per-job lifecycle order (plus arbitrary
  re-delivery) converge to the same mirror (hypothesis property);
- lost events trigger the probe resync and the co-simulation still
  completes every job;
- ``read_with_retry`` backs off exponentially and re-raises after
  exhaustion;
- the deadline guard's degradation ladder is DETERMINISTIC under an
  injected clock (same latencies -> same level trajectory);
- a mid-run ``snapshot()`` + ``restore()`` into a FRESH twin
  reproduces the uninterrupted decision sequence bitwise on BOTH pass
  backends;
- ``ChaosBus`` injections are pure functions of (seed, event seq) —
  the same stream corrupts identically twice.
"""
import dataclasses
import itertools

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.cluster.chaos import DEFAULT_PROFILE, ChaosBus, ChaosSpec
from repro.cluster.emulator import ClusterEmulator
from repro.cluster.workload import JobSpec
from repro.core.engine import DrainEngine
from repro.core.events import (BusReadError, Event, EventBus, EventKind,
                               SeqTracker, read_with_retry,
                               validate_event)
from repro.core.guard import (LEVEL_NAMES, DeadlineGuard, GuardSpec)
from repro.core.state import DONE, QUEUED, empty_state
from repro.core.sync import apply_event
from repro.core.twin import SchedTwin


def tiny_trace(n=12, seed=0):
    rng = np.random.default_rng(seed)
    jobs, t = [], 0.0
    for j in range(n):
        jobs.append(JobSpec(job_id=j, submit_t=t,
                            nodes=int(rng.integers(1, 6)),
                            est_runtime=float(rng.uniform(20, 80)),
                            true_runtime=float(rng.uniform(10, 80))))
        t += 4.0
    return jobs


def build_cosim(trace, total_nodes=16, view_wrap=None, **twin_kw):
    bus = EventBus()
    em = ClusterEmulator(trace, total_nodes, bus=bus)
    view = view_wrap(bus) if view_wrap else bus
    twin = SchedTwin(bus=view, qrun=em.qrun, total_nodes=total_nodes,
                     max_jobs=em.max_jobs,
                     free_nodes_probe=lambda: em.free_nodes,
                     jobs_probe=em.jobs_view,
                     sleep=lambda s: None, **twin_kw)
    return bus, em, view, twin


def decisions(twin):
    return [(float(c.time), c.policy,
             tuple(int(j) for j in c.started_jobs))
            for c in twin.telemetry.cycles]


# ----------------------------------------------------------------------
# subscriber isolation + bus health
# ----------------------------------------------------------------------

def test_publish_isolates_subscriber_exceptions():
    bus = EventBus()
    seen = []
    bus.subscribe(lambda ev: (_ for _ in ()).throw(RuntimeError("boom")))
    bus.subscribe(seen.append)
    ev = Event(EventKind.QUEUEJOB, 1.0, 0,
               {"nodes": 1.0, "est_runtime": 10.0})
    out = bus.publish(ev)           # must NOT raise into the producer
    assert out.seq == 0
    assert len(bus) == 1            # the event reached the log anyway
    assert len(seen) == 1           # later subscribers still ran
    h = bus.health()
    assert h["callback_failures"] == 1
    assert "boom" in h["last_callback_error"]
    assert h["events"] == 1


def test_bus_dump_round_trip():
    bus = EventBus()
    for j in range(3):
        bus.publish(Event(EventKind.QUEUEJOB, float(j), j,
                          {"nodes": 1.0, "est_runtime": 5.0}))
    clone = EventBus.from_dump(bus.dump())
    assert [e.seq for e in clone.replay()] == [0, 1, 2]
    # the clone's seq counter continues where the log ended
    nxt = clone.publish(Event(EventKind.JOBOBIT, 9.0, 0))
    assert nxt.seq == 3


# ----------------------------------------------------------------------
# malformed-event quarantine (dead-letter queue)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("ev,reason", [
    (Event(99, 1.0, 0), "kind"),
    (Event(EventKind.QUEUEJOB, float("nan"), 0,
           {"nodes": 1.0, "est_runtime": 5.0}), "time"),
    (Event(EventKind.RUNJOB, 1.0, -1), "job"),
    (Event(EventKind.QUEUEJOB, 1.0, 0, {"est_runtime": 5.0}), "nodes"),
    (Event(EventKind.QUEUEJOB, 1.0, 0,
           {"nodes": 0.0, "est_runtime": 5.0}), "nodes"),
    (Event(EventKind.QUEUEJOB, 1.0, 0, {"nodes": 1.0}), "est_runtime"),
    (Event(EventKind.NODEFAIL, 1.0, -1, {}), "nodes"),
    (Event(EventKind.QUEUEJOB, 1.0, 0,
           {"nodes": float("inf"), "est_runtime": 5.0}), "nodes"),
])
def test_validate_event_rejects(ev, reason):
    err = validate_event(ev, max_jobs=8)
    assert err is not None and reason in err


def test_validate_event_accepts_emulator_shapes():
    ok = [Event(EventKind.QUEUEJOB, 0.0, 0,
                {"nodes": 2.0, "est_runtime": 30.0}),
          Event(EventKind.RUNJOB, 1.0, 0),
          Event(EventKind.JOBOBIT, 2.0, 0),
          Event(EventKind.NODEFAIL, 3.0, -1,
                {"nodes": 0.0, "victim_job": 0.0}),
          Event(EventKind.NODEUP, 4.0, -1, {"nodes": 4.0})]
    for ev in ok:
        assert validate_event(ev, max_jobs=8) is None, ev


def test_twin_quarantines_instead_of_crashing():
    trace = tiny_trace(6)
    bus, em, _, twin = build_cosim(trace)
    # a poisoned producer: every real event is followed by garbage
    real_publish = bus.publish

    def poisoned(ev):
        out = real_publish(ev)
        real_publish(Event(EventKind.QUEUEJOB, -5.0, 10 ** 6, {}))
        return out

    bus.publish = poisoned
    report = em.run(on_event=twin.pump, on_quiesce=twin.flush)
    assert report.n_jobs == len(trace)
    assert len(twin.dead_letters) > 0
    assert twin.telemetry.ingest.quarantined == len(twin.dead_letters)
    assert all(dl.reason for dl in twin.dead_letters)


# ----------------------------------------------------------------------
# SeqTracker classification
# ----------------------------------------------------------------------

def test_seqtracker_classifies_and_ages():
    t = SeqTracker(reorder_window=4)
    assert t.observe(0).status == "new"
    assert t.observe(0).status == "duplicate"
    obs = t.observe(3)              # skips 1, 2
    assert obs.status == "new" and obs.new_gaps == 2
    assert t.observe(2).status == "reordered"   # fills a hole
    assert t.observe(2).status == "duplicate"   # already filled
    obs = t.observe(9)              # opens holes 4..8; 1 and 4 age out
    assert obs.new_gaps == 5
    assert obs.newly_lost == 2 and t.lost == {1, 4}
    assert t.observe(1).status == "duplicate"   # lost => late dup
    t2 = SeqTracker.from_dict(t.to_dict())
    assert (t2.max_seen, t2.holes, t2.lost) == (t.max_seen, t.holes,
                                                t.lost)


def test_seqtracker_flush_declares_pending_holes_lost():
    t = SeqTracker(reorder_window=64)
    t.observe(0)
    t.observe(5)                    # holes 1..4 pending, well in window
    assert t.flush() == 4
    assert t.holes == set() and t.lost == {1, 2, 3, 4}


# ----------------------------------------------------------------------
# read_with_retry backoff
# ----------------------------------------------------------------------

def test_read_with_retry_backs_off_and_recovers():
    class Flaky:
        def __init__(self, fail_n):
            self.fail_n, self.calls = fail_n, 0

        def read(self, consumer, max_events=None):
            self.calls += 1
            if self.calls <= self.fail_n:
                raise BusReadError("blip")
            return ["ok"]

    slept, retried = [], []
    out = read_with_retry(Flaky(2), "c", retries=3, backoff_s=0.01,
                          sleep=slept.append,
                          on_retry=lambda a, e: retried.append(a))
    assert out == ["ok"]
    assert slept == [0.01, 0.02]            # exponential
    assert retried == [0, 1]

    with pytest.raises(BusReadError):
        read_with_retry(Flaky(10), "c", retries=2, backoff_s=0.01,
                        sleep=slept.append)


# ----------------------------------------------------------------------
# idempotent apply: interleaving + re-delivery invariance (hypothesis)
# ----------------------------------------------------------------------

def _lifecycle(j):
    """The 3-event lifecycle of job j (valid per validate_event)."""
    t0 = float(j)
    return [Event(EventKind.QUEUEJOB, t0, j,
                  {"nodes": 1.0 + j % 3, "est_runtime": 30.0}),
            Event(EventKind.RUNJOB, t0 + 10.0, j),
            Event(EventKind.JOBOBIT, t0 + 40.0 + j, j)]


def _apply_all(events, n_jobs, nodes=16):
    state = empty_state(8, nodes)
    for ev in events:
        state, _ = apply_event(state, ev, idempotent=True)
    return state


def _check_invariant(order, dup_at, n_jobs=4):
    """Interleave + re-deliver per ``order``/``dup_at``; final mirror
    must match the clean in-order apply field-for-field."""
    per_job = [_lifecycle(j) for j in range(n_jobs)]
    clean = [ev for life in per_job for ev in life]
    cursors = [0] * n_jobs
    shuffled = []
    for j in order:                 # per-job order preserved by cursors
        shuffled.append(per_job[j][cursors[j]])
        cursors[j] += 1
    for i in sorted(dup_at):        # arbitrary re-delivery at the tail
        shuffled.append(shuffled[i])

    ref = _apply_all(clean, n_jobs)
    got = _apply_all(shuffled, n_jobs)
    for field in ("submit_t", "nodes", "est_runtime", "start_t",
                  "end_t", "state"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ref.jobs, field)),
            np.asarray(getattr(got.jobs, field)), err_msg=field)
    assert int(ref.free_nodes) == int(got.free_nodes)


def test_interleaving_and_redelivery_invariant_mirror_seeded():
    n_jobs = 4
    rng = np.random.default_rng(0)
    tags = np.array([j for j in range(n_jobs) for _ in range(3)])
    for _ in range(50):
        order = rng.permutation(tags)
        dup_at = rng.integers(0, len(tags),
                              size=int(rng.integers(0, 7))).tolist()
        _check_invariant(order.tolist(), dup_at, n_jobs)


def test_interleaving_invariant_mirror_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    n_jobs = 4
    tags = [j for j in range(n_jobs) for _ in range(3)]

    @settings(max_examples=40, deadline=None)
    @given(order=st.permutations(tags),
           dup_at=st.lists(st.integers(0, len(tags) - 1), max_size=6))
    def check(order, dup_at):
        _check_invariant(order, dup_at, n_jobs)

    check()


def test_out_of_order_obit_never_double_frees():
    # JOBOBIT before its RUNJOB: the job ends without the mirror ever
    # charging its nodes — free_nodes must NOT exceed capacity
    q, r, o = _lifecycle(0)
    state = empty_state(8, 16)
    for ev in (q, o, r):            # lifecycle order broken
        state, _ = apply_event(state, ev, idempotent=True)
    assert int(state.free_nodes) == 16
    assert int(state.jobs.state[0]) == DONE
    # the late RUNJOB backfilled the start time
    assert float(state.jobs.start_t[0]) == pytest.approx(10.0)


# ----------------------------------------------------------------------
# loss detection -> probe resync -> the co-simulation still completes
# ----------------------------------------------------------------------

class DropOnce:
    """Bus view that silently drops ONE specific seq from delivery."""

    def __init__(self, inner, drop_seq):
        self.inner, self.drop_seq = inner, drop_seq

    def read(self, consumer, max_events=None):
        return [e for e in self.inner.read(consumer, max_events)
                if e.seq != self.drop_seq]

    def __getattr__(self, name):
        return getattr(self.inner, name)


@pytest.mark.parametrize("drop_seq", [0, 4])
def test_lost_queuejob_heals_via_resync(drop_seq):
    trace = tiny_trace(10, seed=2)
    bus, em, _, twin = build_cosim(
        trace, view_wrap=lambda b: DropOnce(b, drop_seq),
        reorder_window=2)
    report = em.run(on_event=twin.pump, on_quiesce=twin.flush)
    assert report.n_jobs == len(trace)          # nothing stranded
    ing = twin.telemetry.ingest
    assert ing.gaps >= 1 and ing.lost >= 1 and ing.resyncs >= 1


# ----------------------------------------------------------------------
# deadline guard: deterministic ladder under an injected clock
# ----------------------------------------------------------------------

def _drive(spec, latencies):
    g = DeadlineGuard(spec)
    out = []
    for secs in latencies:
        lvl = g.plan()
        out.append(lvl)
        g.observe(lvl, secs)
    return g, out


def test_guard_ladder_walks_all_levels_on_sustained_misses():
    spec = GuardSpec(budget_s=1.0, safety=0.8, ewma_alpha=1.0,
                     recover_after=2)
    lat = (2.0, 2.0, 2.0, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1)
    g, trajectory = _drive(spec, lat)
    # climbs one level per miss; once every lower level's estimate is
    # poisoned (2s >> 0.8s headroom, alpha=1 so no decay) the predictive
    # planner PINS the ladder at hold-incumbent even though the comfy
    # counter keeps voting to step down — degraded-but-on-time beats
    # retrying a level known to blow the budget.
    assert trajectory == [0, 1, 2, 3, 3, 3, 3, 3, 3, 3]
    assert g.misses == 3
    assert g.engagements == sum(1 for lvl in trajectory if lvl > 0)
    # deterministic: same inputs, same trajectory
    _, t2 = _drive(spec, lat)
    assert t2 == trajectory
    # and the ladder state round-trips through the snapshot dict
    g3 = DeadlineGuard(g.spec).restore(g.to_dict())
    assert g3.plan() == g.plan()
    assert g3.misses == g.misses


def test_guard_recovers_after_transient_spike():
    # with a decaying EWMA a single spike escalates reactively, the
    # fast cycles at level 1 satisfy the hysteresis, and the planner
    # lets the ladder back down because level 0's estimate recovered
    spec = GuardSpec(budget_s=1.0, safety=0.8, ewma_alpha=0.1,
                     recover_after=2)
    g, trajectory = _drive(
        spec, (0.1, 0.1, 2.0, 0.1, 0.1, 0.1, 0.1))
    assert trajectory == [0, 0, 0, 1, 1, 0, 0]
    assert g.misses == 1


def test_guard_disabled_never_engages():
    g, trajectory = _drive(GuardSpec(budget_s=0.0), (9.0, 9.0, 9.0))
    assert trajectory == [0, 0, 0]
    assert g.misses == 0 and g.engagements == 0
    assert not g.spec.enabled


def test_twin_ladder_deterministic_under_fake_clock():
    def fake_clock_factory():
        c = itertools.count()
        return lambda: next(c) * 10.0          # every cycle "takes" 10s

    def run():
        trace = tiny_trace(8, seed=3)
        bus, em, _, twin = build_cosim(trace, guard=1.0,
                                       clock=fake_clock_factory())
        em.run(on_event=twin.pump, on_quiesce=twin.flush)
        return [(c.guard_level, c.deadline_miss)
                for c in twin.telemetry.cycles]

    a, b = run(), run()
    assert a == b                               # bit-deterministic
    levels = [lvl for lvl, _ in a]
    assert levels[0] == 0                       # starts at full fidelity
    assert max(levels) == 3                     # walked the whole ladder
    assert any(miss for _, miss in a)           # the 10s cycles missed
    res_names = [LEVEL_NAMES[lvl] for lvl in levels]
    assert "hold_incumbent" in res_names


def test_guarded_cycles_stamp_telemetry_and_stats():
    trace = tiny_trace(8, seed=4)
    bus, em, _, twin = build_cosim(trace, guard=60.0)
    em.run(on_event=twin.pump, on_quiesce=twin.flush)
    stats = twin.telemetry.resilience_stats()
    assert stats["cycles"] == len(twin.telemetry.cycles) > 0
    assert stats["guarded_cycles"] == stats["cycles"]
    assert stats["miss_rate"] == 0.0            # 60s budget never misses
    for c in twin.telemetry.cycles:
        assert c.deadline_s == 60.0 and c.margin_s > 0.0


# ----------------------------------------------------------------------
# crash-safe snapshots: bitwise decision parity on both backends
# ----------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_snapshot_restore_bitwise_decision_parity(tmp_path, backend):
    eng = DrainEngine(backend, interpret=(backend == "pallas"))
    trace = tiny_trace(10, seed=5)

    bus, em, _, twin = build_cosim(trace, engine=eng)
    em.run(on_event=twin.pump, on_quiesce=twin.flush)
    ref = decisions(twin)

    bus, em, _, twin = build_cosim(trace, engine=eng)
    mgr = CheckpointManager(str(tmp_path / backend))
    holder = {"twin": twin, "killed": False}

    def pump():
        t = holder["twin"]
        t.pump()
        if not holder["killed"] and len(t.telemetry.cycles) >= 4:
            t.snapshot(mgr)
            fresh = SchedTwin(bus=bus, qrun=em.qrun, total_nodes=16,
                              max_jobs=em.max_jobs,
                              free_nodes_probe=lambda: em.free_nodes,
                              jobs_probe=em.jobs_view, engine=eng,
                              sleep=lambda s: None)
            step, app = fresh.restore(mgr)
            assert step == len(t.telemetry.cycles) and app is None
            assert len(fresh.telemetry.cycles) == step
            holder["twin"] = fresh
            holder["killed"] = True

    report = em.run(on_event=pump,
                    on_quiesce=lambda: holder["twin"].flush())
    assert holder["killed"]
    assert report.n_jobs == len(trace)
    assert decisions(holder["twin"]) == ref     # bitwise


def test_snapshot_carries_app_extra(tmp_path):
    trace = tiny_trace(6, seed=6)
    bus, em, _, twin = build_cosim(trace)
    em.run(on_event=twin.pump, on_quiesce=twin.flush)
    mgr = CheckpointManager(str(tmp_path))
    twin.snapshot(mgr, app_extra={"emulator": em.snapshot_state(),
                                  "bus": bus.dump()})
    bus2 = EventBus()
    em2 = ClusterEmulator(trace, 16, bus=bus2)
    twin2 = SchedTwin(bus=bus2, qrun=em2.qrun, total_nodes=16,
                      max_jobs=em2.max_jobs, sleep=lambda s: None)
    step, app = twin2.restore(mgr)
    em2.restore_state(app["emulator"])
    assert em2.now == em.now and em2.free_nodes == em.free_nodes
    assert app["bus"] == bus.dump()
    assert decisions(twin2) == decisions(twin)
    assert twin2.telemetry.ingest.as_dict() == \
        twin.telemetry.ingest.as_dict()


# ----------------------------------------------------------------------
# chaos determinism: injections are pure functions of (seed, seq)
# ----------------------------------------------------------------------

def _chaos_delivery(spec, events, reads=8):
    bus = EventBus()
    view = ChaosBus(bus, spec)
    for ev in events:
        bus.publish(ev)
    out = []
    per_read = max(1, len(events) // reads)
    consumed = 0
    while consumed < len(events):
        try:
            got = view.read("c", per_read)
        except BusReadError:
            continue                # retry the same window
        consumed += per_read
        out.extend((e.seq, e.kind, e.time) for e in got)
    return out, dict(view.stats)


def test_chaos_bus_is_deterministic():
    spec = dataclasses.replace(DEFAULT_PROFILE, seed=13)
    events = [Event(EventKind.QUEUEJOB, float(j), j % 8,
                    {"nodes": 1.0, "est_runtime": 5.0})
              for j in range(64)]
    a, stats_a = _chaos_delivery(spec, events)
    b, stats_b = _chaos_delivery(spec, events)
    assert a == b and stats_a == stats_b
    assert sum(stats_a.values()) > 0            # profile actually fired
    # a different seed corrupts differently
    c, _ = _chaos_delivery(dataclasses.replace(spec, seed=14), events)
    assert c != a


def test_chaos_spec_validation():
    with pytest.raises(ValueError, match="drop_prob"):
        ChaosSpec(drop_prob=1.5)
    with pytest.raises(ValueError, match="reorder_delay"):
        ChaosSpec(reorder_delay=0)


def test_chaos_cosim_completes_and_counts(tmp_path):
    trace = tiny_trace(12, seed=7)
    bus, em, view, twin = build_cosim(
        trace, view_wrap=lambda b: ChaosBus(
            b, dataclasses.replace(DEFAULT_PROFILE, seed=3)),
        reorder_window=8)
    report = em.run(on_event=twin.pump, on_quiesce=twin.flush)
    assert report.n_jobs == len(trace)
    stats = twin.telemetry.resilience_stats()
    # whatever was injected must show up in the ingestion ledger
    if view.stats["duplicates"]:
        assert stats["duplicates"] > 0
    if view.stats["corruptions"]:
        assert stats["quarantined"] > 0
    if view.stats["read_failures"]:
        assert stats["read_retries"] > 0
