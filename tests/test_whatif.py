"""Parallel what-if + policy selection tests (§3.3-§3.4)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scoring, whatif
from repro.core.policies import FCFS, PAPER_POOL, SJF, WFP

from conftest import make_cluster_state


def test_decide_picks_min_cost_policy():
    state = make_cluster_state(seed=7)
    pool = jnp.asarray(PAPER_POOL, dtype=jnp.int32)
    d = whatif.decide(state, pool)
    costs = np.asarray(d.costs)
    assert int(d.policy_index) == int(np.argmin(costs))


def test_tie_break_follows_paper_priority():
    costs = jnp.asarray([1.0, 1.0, 1.0])
    assert int(scoring.select_policy(costs)) == 0  # WFP wins ties
    costs = jnp.asarray([2.0, 1.0, 1.0])
    assert int(scoring.select_policy(costs)) == 1  # then FCFS


def test_run_mask_comes_from_winner():
    state = make_cluster_state(seed=11)
    pool = jnp.asarray(PAPER_POOL, dtype=jnp.int32)
    d = whatif.decide(state, pool)
    from repro.core.des import simulate_to_drain
    winner = pool[int(d.policy_index)]
    res = simulate_to_drain(state, winner)
    assert np.array_equal(np.asarray(d.run_mask),
                          np.asarray(res.first_started))


def test_decide_jit_cache_reused_across_states():
    state = make_cluster_state(seed=1)
    pool = jnp.asarray(PAPER_POOL, dtype=jnp.int32)
    d1 = whatif.decide(state, pool)
    state2 = make_cluster_state(seed=2)
    d2 = whatif.decide(state2, pool)  # same jit cache, new data
    assert d1.costs.shape == d2.costs.shape == (3,)


def test_ensemble_decision_shapes_and_member0():
    state = make_cluster_state(seed=3)
    pool = jnp.asarray(PAPER_POOL, dtype=jnp.int32)
    key = jax.random.PRNGKey(0)
    d = whatif.decide_ensemble(state, pool, key, n_ens=4, noise=0.2)
    assert d.costs.shape == (3,)
    assert d.run_mask.shape == (state.jobs.capacity,)


def test_ensemble_zero_noise_matches_plain_decide():
    state = make_cluster_state(seed=5)
    pool = jnp.asarray(PAPER_POOL, dtype=jnp.int32)
    key = jax.random.PRNGKey(0)
    d_plain = whatif.decide(state, pool)
    d_ens = whatif.decide_ensemble(state, pool, key, n_ens=2, noise=0.0)
    assert int(d_plain.policy_index) == int(d_ens.policy_index)
    np.testing.assert_allclose(np.asarray(d_plain.costs),
                               np.asarray(d_ens.costs), rtol=1e-5)


def test_paper_score_weights():
    from repro.core.des import DrainMetrics
    m = DrainMetrics(avg_wait=jnp.float32(120.0), max_wait=jnp.float32(600.0),
                     avg_slowdown=jnp.float32(2.0),
                     max_slowdown=jnp.float32(8.0),
                     makespan=jnp.float32(0.0), utilization=jnp.float32(0.0))
    c = scoring.policy_cost(m)
    # 0.25*(600/60) + 0.25*8 + 0.25*(120/60) + 0.25*2 = 2.5+2+0.5+0.5
    assert abs(float(c) - 5.5) < 1e-5


def test_radar_normalization_and_area():
    per = {
        "A": {"avg_wait": 10, "max_wait": 100, "avg_slowdown": 1,
              "max_slowdown": 2, "utilization": 0.9},
        "B": {"avg_wait": 50, "max_wait": 500, "avg_slowdown": 5,
              "max_slowdown": 10, "utilization": 0.5},
    }
    areas = scoring.radar_report(per)
    # A best on every axis -> radius 1 everywhere -> pentagon area
    assert abs(areas["A"] - 5 * 0.5 * np.sin(2 * np.pi / 5)) < 1e-9
    assert areas["B"] == 0.0
