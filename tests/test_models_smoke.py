"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions (assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_ORDER, get_smoke_config
from repro.models import api
from repro.models.common import init_params

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
        "mask": jnp.ones((B, S), dtype=jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patches"] = 0.1 * jax.random.normal(
            ks[2], (B, cfg.n_patches, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(
            ks[2], (B, S, cfg.d_model)).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_ORDER)
def test_train_step_runs_and_is_finite(arch, rules_train, mesh11):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), api.param_table(cfg))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    with mesh11:
        loss, metrics = jax.jit(
            lambda p, b: api.train_loss(cfg, rules_train, p, b)
        )(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert 0.0 < float(loss) < 20.0
    assert np.isfinite(float(metrics["xent"]))


@pytest.mark.parametrize("arch", ARCH_ORDER)
def test_prefill_then_decode_shapes(arch, rules_decode, mesh11):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), api.param_table(cfg))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    del batch["labels"], batch["mask"]
    with mesh11:
        logits, caches = jax.jit(
            lambda p, b: api.prefill(cfg, rules_decode, p, b))(params, batch)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
        # pad caches out to max_seq for decode
        max_seq = S + 8
        caches_full = api.init_caches(cfg, B, max_seq)
        caches_full = jax.tree.map(_blit, caches_full, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        step = {"tokens": tok, "index": jnp.int32(S)}
        logits2, caches2 = jax.jit(
            lambda p, c, b: api.decode_step(cfg, rules_decode, p, c, b)
        )(params, caches_full, step)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, dtype=np.float32)))


def _blit(big, small):
    if big.shape == small.shape:
        return small
    pads = [(0, b - s) for b, s in zip(big.shape, small.shape)]
    return jnp.pad(small, pads).astype(big.dtype)


def test_decode_matches_full_forward_dense(rules_decode, mesh11):
    """Golden consistency: prefill(s tokens) + decode(token s) logits
    == prefill(s+1 tokens) last-position logits (dense llama family)."""
    cfg = get_smoke_config("llama3.2-1b")
    params = init_params(jax.random.PRNGKey(0), api.param_table(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S + 1), 0,
                              cfg.vocab_size)
    with mesh11:
        # full prefill over s+1 tokens
        want, _ = api.prefill(cfg, rules_decode, params,
                              {"tokens": toks})
        # prefill s, decode 1
        _, caches = api.prefill(cfg, rules_decode, params,
                                {"tokens": toks[:, :S]})
        caches_full = api.init_caches(cfg, B, S + 1)
        caches_full = jax.tree.map(_blit, caches_full, caches)
        got, _ = api.decode_step(cfg, rules_decode, params, caches_full,
                                 {"tokens": toks[:, S:], "index":
                                  jnp.int32(S)})
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-2, rtol=3e-2)


def test_decode_matches_full_forward_rwkv(rules_decode, mesh11):
    """Same golden consistency for the recurrent family (state carry)."""
    cfg = get_smoke_config("rwkv6-7b")
    params = init_params(jax.random.PRNGKey(0), api.param_table(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, S + 1), 0,
                              cfg.vocab_size)
    with mesh11:
        want, _ = api.prefill(cfg, rules_decode, params,
                              {"tokens": toks})
        _, caches = api.prefill(cfg, rules_decode, params,
                                {"tokens": toks[:, :S]})
        got, _ = api.decode_step(cfg, rules_decode, params, caches,
                                 {"tokens": toks[:, S:], "index":
                                  jnp.int32(S)})
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-2, rtol=5e-2)


def test_moe_aux_losses_present(rules_train, mesh11):
    cfg = get_smoke_config("olmoe-1b-7b")
    params = init_params(jax.random.PRNGKey(0), api.param_table(cfg))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    with mesh11:
        loss, metrics = api.train_loss(cfg, rules_train, params, batch)
    assert "moe_aux" in metrics and "moe_z" in metrics
    assert float(metrics["moe_aux"]) >= 0.0
    # total loss includes the aux terms
    assert float(metrics["loss"]) >= float(metrics["xent"])


def test_param_tables_cover_all_archs():
    from repro.models.common import count_params
    for arch in ARCH_ORDER:
        cfg = get_smoke_config(arch)
        n = count_params(api.param_table(cfg))
        assert n > 0, arch
